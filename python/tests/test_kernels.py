"""Kernel-vs-oracle correctness: the core L1 signal.

Every Pallas node-phase kernel must match its pure-jnp oracle bitwise on
integer data. Fixed-shape smoke tests plus hypothesis sweeps over shapes
and dtypes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import node_phases as k
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rnd(shape, dtype=np.int32, seed=0):
    rng = np.random.default_rng(seed)
    if np.issubdtype(dtype, np.integer):
        info = np.iinfo(dtype)
        return rng.integers(info.min // 2, info.max // 2, size=shape, dtype=dtype)
    return rng.standard_normal(shape).astype(dtype)


# ---------- fixed-shape smoke tests ----------

class TestAlltoallPack:
    def test_identity_on_diagonal(self):
        x = rnd((4, 4, 8))
        y = np.asarray(k.alltoall_pack(x))
        for i in range(4):
            np.testing.assert_array_equal(y[i, i], x[i, i])

    def test_matches_ref(self):
        x = rnd((8, 8, 16), seed=1)
        np.testing.assert_array_equal(
            np.asarray(k.alltoall_pack(x)), np.asarray(ref.alltoall_pack(x))
        )

    def test_involution(self):
        x = rnd((4, 4, 4), seed=2)
        y = np.asarray(k.alltoall_pack(np.asarray(k.alltoall_pack(x))))
        np.testing.assert_array_equal(y, x)

    def test_rejects_nonsquare(self):
        with pytest.raises(AssertionError):
            k.alltoall_pack(rnd((4, 5, 8)))


class TestAllgatherConcat:
    def test_matches_ref(self):
        x = rnd((8, 32), seed=3)
        np.testing.assert_array_equal(
            np.asarray(k.allgather_concat(x)), np.asarray(ref.allgather_concat(x))
        )

    def test_every_rank_gets_every_block(self):
        x = rnd((4, 8), seed=4)
        y = np.asarray(k.allgather_concat(x))
        for i in range(4):
            for j in range(4):
                np.testing.assert_array_equal(y[i, j], x[j])


class TestScatterSlice:
    def test_matches_ref(self):
        x = rnd((64,), seed=5)
        np.testing.assert_array_equal(
            np.asarray(k.scatter_slice(x, 8)), np.asarray(ref.scatter_slice(x, 8))
        )

    def test_blocks_partition_input(self):
        x = rnd((32,), seed=6)
        y = np.asarray(k.scatter_slice(x, 4))
        np.testing.assert_array_equal(y.reshape(-1), x)

    def test_rejects_indivisible(self):
        with pytest.raises(AssertionError):
            k.scatter_slice(rnd((10,)), 3)


class TestBcastTile:
    def test_matches_ref(self):
        x = rnd((16,), seed=7)
        np.testing.assert_array_equal(
            np.asarray(k.bcast_tile(x, 8)), np.asarray(ref.bcast_tile(x, 8))
        )

    def test_all_rows_equal_root(self):
        x = rnd((8,), seed=8)
        y = np.asarray(k.bcast_tile(x, 6))
        assert y.shape == (6, 8)
        for i in range(6):
            np.testing.assert_array_equal(y[i], x)


class TestChecksum:
    def test_matches_ref(self):
        x = rnd((1000,), seed=9)
        np.testing.assert_array_equal(
            np.asarray(k.checksum(x)), np.asarray(ref.checksum(x))
        )

    def test_wraparound(self):
        x = np.full((4,), 2**30, dtype=np.int32)
        got = int(np.asarray(k.checksum(x))[0])
        want = int(np.asarray(ref.checksum(jnp.asarray(x)))[0])
        assert got == want

    def test_tiling_boundary(self):
        # exercise padding: length not a multiple of the tile
        x = rnd((1025,), seed=10)
        np.testing.assert_array_equal(
            np.asarray(k.checksum(x, tile=256)), np.asarray(ref.checksum(x))
        )

    def test_small_buffer(self):
        x = np.array([1, -2, 3], dtype=np.int32)
        assert int(np.asarray(k.checksum(x))[0]) == 2


# ---------- hypothesis sweeps ----------

dims = st.integers(min_value=1, max_value=9)
counts = st.integers(min_value=1, max_value=130)
int_dtypes = st.sampled_from([np.int32, np.int8, np.uint16])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@given(n=dims, c=counts, dtype=int_dtypes, seed=seeds)
def test_alltoall_pack_prop(n, c, dtype, seed):
    x = rnd((n, n, c), dtype, seed)
    np.testing.assert_array_equal(
        np.asarray(k.alltoall_pack(x)), np.asarray(ref.alltoall_pack(x))
    )


@given(n=dims, c=counts, dtype=int_dtypes, seed=seeds)
def test_allgather_concat_prop(n, c, dtype, seed):
    x = rnd((n, c), dtype, seed)
    np.testing.assert_array_equal(
        np.asarray(k.allgather_concat(x)), np.asarray(ref.allgather_concat(x))
    )


@given(n=dims, c=counts, dtype=int_dtypes, seed=seeds)
def test_scatter_slice_prop(n, c, dtype, seed):
    x = rnd((n * c,), dtype, seed)
    np.testing.assert_array_equal(
        np.asarray(k.scatter_slice(x, n)), np.asarray(ref.scatter_slice(x, n))
    )


@given(n=dims, c=counts, dtype=int_dtypes, seed=seeds)
def test_bcast_tile_prop(n, c, dtype, seed):
    x = rnd((c,), dtype, seed)
    np.testing.assert_array_equal(
        np.asarray(k.bcast_tile(x, n)), np.asarray(ref.bcast_tile(x, n))
    )


@given(m=st.integers(min_value=1, max_value=5000), seed=seeds)
def test_checksum_prop(m, seed):
    x = rnd((m,), np.int32, seed)
    np.testing.assert_array_equal(np.asarray(k.checksum(x)), np.asarray(ref.checksum(x)))

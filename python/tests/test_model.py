"""L2 model tests: phase shapes, composition, and AOT lowering."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile import aot
from compile.kernels import ref


def rnd(shape, seed=0):
    return np.random.default_rng(seed).integers(-1000, 1000, shape, dtype=np.int32)


class TestPhases:
    def test_node_alltoall_shape(self):
        y = model.node_alltoall(rnd((4, 4, 16)))
        assert y.shape == (4, 4, 16) and y.dtype == jnp.int32

    def test_node_allgather_shape(self):
        y = model.node_allgather(rnd((8, 32)))
        assert y.shape == (8, 8, 32)

    def test_node_scatter_shape(self):
        y = model.node_scatter(rnd((64,)), 8)
        assert y.shape == (8, 8)

    def test_node_bcast_shape(self):
        y = model.node_bcast(rnd((16,)), 4)
        assert y.shape == (4, 16)

    def test_shuffle_step_consistent(self):
        x = rnd((4, 4, 8), seed=1)
        packed, csum = model.shuffle_step(x)
        np.testing.assert_array_equal(
            np.asarray(packed), np.asarray(ref.alltoall_pack(x))
        )
        assert int(np.asarray(csum)[0]) == int(
            np.asarray(ref.checksum(jnp.asarray(x).reshape(-1)))[0]
        )

    def test_fulllane_bcast_composition(self):
        """Full-lane bcast node phases compose to a broadcast (paper §2.2):
        scatter on root node + (network bcast elided at n=N=1 slice level)
        + allgather must reconstruct the root buffer on every rank."""
        n, c = 4, 8
        root_buf = rnd((n * c,), seed=2)
        blocks = model.node_scatter(root_buf, n)  # (n, c)
        gathered = model.node_allgather(blocks)  # (n, n, c)
        for i in range(n):
            np.testing.assert_array_equal(
                np.asarray(gathered[i]).reshape(-1), root_buf
            )


class TestAot:
    def test_hlo_text_emitted(self):
        name, fn, specs = aot.phases(4, 16)[0]
        text = aot.to_hlo_text(fn.lower(*specs))
        assert "HloModule" in text
        assert "ROOT" in text

    def test_all_phases_lower(self):
        for name, fn, specs in aot.phases(4, 16):
            text = aot.to_hlo_text(fn.lower(*specs))
            assert "HloModule" in text, name

    def test_lowered_matches_eager(self):
        """The lowered executable (via jax jit compile+run) must equal eager."""
        x = rnd((4, 4, 16), seed=3)
        eager = model.node_alltoall(x)
        jitted = jax.jit(model.node_alltoall)(x)
        np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))

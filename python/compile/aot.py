"""AOT export: lower the L2 node-phase graphs to HLO text artifacts.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` rust crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/gen_hlo.py.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``

Emits one ``<name>__n<n>_c<c>.hlo.txt`` per (phase, shape) combination plus
``manifest.txt`` (tab-separated: name, n, c, dtype, input shapes, file)
that the rust runtime uses to locate executables.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Shapes the rust exec runtime requests. Node sizes are small because the
# exec backend runs p = N*n OS threads; counts cover the eager/rendezvous
# range the examples use. Element type is int32 (the paper uses MPI_INT).
NODE_SIZES = (4, 8)
COUNTS = (16, 256, 1024)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def phases(n: int, c: int):
    """(name, jitted fn, example args) for every node phase at (n, c)."""
    return [
        ("node_alltoall", jax.jit(model.node_alltoall), (spec(n, n, c),)),
        ("node_allgather", jax.jit(model.node_allgather), (spec(n, c),)),
        (
            "node_scatter",
            jax.jit(lambda x: model.node_scatter(x, n)),
            (spec(n * c),),
        ),
        ("node_bcast", jax.jit(lambda x: model.node_bcast(x, n)), (spec(c),)),
        ("shuffle_step", jax.jit(model.shuffle_step), (spec(n, n, c),)),
        ("checksum", jax.jit(model.payload_checksum), (spec(n * c),)),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--node-sizes", type=int, nargs="*", default=list(NODE_SIZES))
    ap.add_argument("--counts", type=int, nargs="*", default=list(COUNTS))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for n in args.node_sizes:
        for c in args.counts:
            for name, fn, specs in phases(n, c):
                fname = f"{name}__n{n}_c{c}.hlo.txt"
                path = os.path.join(args.out_dir, fname)
                text = to_hlo_text(fn.lower(*specs))
                with open(path, "w") as f:
                    f.write(text)
                shapes = ";".join(
                    "x".join(map(str, s.shape)) or "scalar" for s in specs
                )
                manifest.append(f"{name}\t{n}\t{c}\tint32\t{shapes}\t{fname}")
                print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()

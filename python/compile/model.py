"""L2: node-phase compute graphs composed from the L1 Pallas kernels.

Each function here is a complete node-local phase of one of the paper's
k-lane / full-lane algorithms (§2.2–2.3), expressed as a jax computation
that calls the Pallas kernels in ``kernels/node_phases.py``. ``aot.py``
lowers these once, at the shapes the rust exec runtime requests, to HLO
text under ``artifacts/`` — python never runs on the request path.
"""

import jax.numpy as jnp

from compile.kernels import node_phases as k


def node_alltoall(x):
    """Node-local alltoall phase: (n, n, c) block matrix transpose."""
    return k.alltoall_pack(x)


def node_allgather(x):
    """Node-local allgather phase: (n, c) -> (n, n, c)."""
    return k.allgather_concat(x)


def node_scatter(x, n):
    """Node-local scatter phase: flat (n*c,) root buffer -> (n, c)."""
    return k.scatter_slice(x, n)


def node_bcast(x, n):
    """Node-local broadcast phase: (c,) root block -> (n, c)."""
    return k.bcast_tile(x, n)


def payload_checksum(x):
    """Validation checksum over a flat int32 payload -> (1,)."""
    return k.checksum(x)


def shuffle_step(x):
    """One full-lane alltoall node step (paper §2.2), fused.

    x: (n, n, c) — on-node send blocks. Combines the node-local alltoall
    (combine blocks headed to the same destination node) with a payload
    checksum of the packed result, so the exec runtime gets both the
    packed buffer and an integrity witness from a single executable.
    """
    packed = k.alltoall_pack(x)
    csum = k.checksum(packed.reshape(-1))
    return packed, csum

"""Pure-jnp oracles for the Pallas node-phase kernels.

These are the *semantic definitions* of the node-local data-redistribution
phases used by the k-lane / full-lane algorithms (paper §2.2–2.3):

- ``alltoall_pack``  — node-local alltoall: block transpose.
- ``allgather_concat`` — node-local allgather: every on-node rank ends up
  with every rank's block (full-lane bcast completion phase).
- ``scatter_slice``  — node-local scatter: root's flat buffer split into
  per-rank blocks (full-lane bcast/scatter entry phase).
- ``bcast_tile``     — node-local broadcast: root's block replicated to all
  on-node ranks (k-lane adapted algorithms, §2.3).
- ``checksum``       — wrap-around int32 payload checksum used by the exec
  runtime to validate delivered data.

Every kernel in ``kernels/`` must match its oracle exactly (integer data,
bitwise equality).
"""

import jax.numpy as jnp


def alltoall_pack(x):
    """x: (n, n, c); x[i, j] = block rank i sends to rank j.

    Returns y with y[i, j] = x[j, i] — i.e. y[i] is the receive buffer of
    rank i (block j arrived from rank j).
    """
    return jnp.swapaxes(x, 0, 1)


def allgather_concat(x):
    """x: (n, c) per-rank blocks. Returns y: (n, n, c), y[i, j] = x[j]."""
    n = x.shape[0]
    return jnp.broadcast_to(x[None, :, :], (n, n, x.shape[1]))


def scatter_slice(x, n):
    """x: (n*c,) root buffer. Returns y: (n, c), y[i] = x[i*c:(i+1)*c]."""
    return x.reshape(n, -1)


def bcast_tile(x, n):
    """x: (c,) root block. Returns y: (n, c), y[i] = x."""
    return jnp.broadcast_to(x[None, :], (n, x.shape[0]))


def checksum(x):
    """Wrap-around int32 sum of a flat buffer. Returns shape (1,) int32."""
    return jnp.sum(x, dtype=jnp.int32).reshape(1)

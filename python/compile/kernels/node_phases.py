"""L1 Pallas kernels: node-local data-redistribution phases.

The k-lane and full-lane algorithms (paper §2.2–2.3) interleave off-node
point-to-point communication with node-local collective phases performed
over shared memory. In this reproduction the node-local phases are real
compute kernels: tiled block permutations written in Pallas.

TPU adaptation (DESIGN.md §Hardware-Adaptation): each grid cell moves one
(rank, block) tile through VMEM via a ``BlockSpec`` index map — the
HBM↔VMEM schedule plays the role of the shared-memory bus in the paper's
§2.4 model. The kernels are copy-bound: no MXU work, roofline = memory
bandwidth.

All kernels use ``interpret=True``: CPU-PJRT cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust runtime
(PJRT CPU client) can run after AOT export.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # see module docstring


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def alltoall_pack(x):
    """Node-local alltoall (block transpose): y[i, j] = x[j, i].

    x: (n, n, c). Grid (n, n); each cell moves one block of c elements.
    The output tile (i, j) reads input tile (j, i) — the permutation lives
    entirely in the BlockSpec index maps, the kernel body is a straight
    VMEM-resident copy.
    """
    n, n2, c = x.shape
    assert n == n2, f"alltoall_pack needs a square block matrix, got {x.shape}"
    return pl.pallas_call(
        _copy_kernel,
        grid=(n, n),
        in_specs=[pl.BlockSpec((1, 1, c), lambda i, j: (j, i, 0))],
        out_specs=pl.BlockSpec((1, 1, c), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n, c), x.dtype),
        interpret=INTERPRET,
    )(x)


def allgather_concat(x):
    """Node-local allgather: y[i, j] = x[j] for all on-node ranks i.

    x: (n, c) -> y: (n, n, c). Completion phase of the full-lane broadcast
    (paper §2.2): each rank's c/n-block is collected by everyone.
    """
    n, c = x.shape

    def _gather_kernel(x_ref, o_ref):
        o_ref[0, 0, :] = x_ref[0, :]

    return pl.pallas_call(
        _gather_kernel,
        grid=(n, n),
        in_specs=[pl.BlockSpec((1, c), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((1, 1, c), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n, c), x.dtype),
        interpret=INTERPRET,
    )(x)


def scatter_slice(x, n):
    """Node-local scatter: split the root's flat buffer into n blocks.

    x: (n*c,) -> y: (n, c), y[i] = x[i*c:(i+1)*c]. Entry phase of the
    full-lane algorithms on the root node (paper §2.2).
    """
    (m,) = x.shape
    assert m % n == 0, f"buffer of {m} elements not divisible into {n} blocks"
    c = m // n

    def _slice_kernel(x_ref, o_ref):
        o_ref[0, :] = x_ref[...]

    return pl.pallas_call(
        _slice_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((c,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=INTERPRET,
    )(x)


def bcast_tile(x, n):
    """Node-local broadcast: replicate the root's block to n ranks.

    x: (c,) -> y: (n, c), y[i] = x. Used by the adapted k-lane algorithms
    (paper §2.3) when a local root hands a received block to the k lane
    processors (and finally to all n on-node ranks).
    """
    (c,) = x.shape

    def _tile_kernel(x_ref, o_ref):
        o_ref[0, :] = x_ref[...]

    return pl.pallas_call(
        _tile_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((c,), lambda i: (0,))],
        out_specs=pl.BlockSpec((1, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=INTERPRET,
    )(x)


def checksum(x, tile=1024):
    """Wrap-around int32 sum of a flat int32 buffer -> shape (1,).

    Tiled accumulating reduction: grid cell i adds the sum of tile i into
    the single output element (sequential grid => no race in interpret or
    TPU semantics). Used by the exec runtime to validate payloads.
    """
    (m,) = x.shape
    t = min(tile, m)
    if m % t != 0:  # pad to a whole number of tiles; zeros don't change the sum
        pad = t - m % t
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
        m += pad

    def _kernel(x_ref, o_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.sum(x_ref[...]).reshape(1)

    return pl.pallas_call(
        _kernel,
        grid=(m // t,),
        in_specs=[pl.BlockSpec((t,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        interpret=INTERPRET,
    )(x)

//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored
//! path dependency provides exactly the API subset `mlane` uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros,
//! and the [`Context`] extension trait for `Result` and `Option`.
//!
//! Error values are flattened to strings at construction time — no
//! backtraces, no downcasting. Like the real crate, [`Error`] does not
//! implement `std::error::Error` so that the blanket `From` impl for
//! every standard error type can exist.

use std::fmt;

/// A string-backed error with an optional chain of context frames.
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Construct an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), context: Vec::new() }
    }

    fn push_context(mut self, c: String) -> Error {
        self.context.push(c);
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Most-recent context first, like anyhow's `{:#}` rendering.
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error as it propagates.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).push_context(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse().context("not a number")?;
        ensure!(n > 0, "want positive, got {n}");
        Ok(n)
    }

    #[test]
    fn from_std_error_and_context() {
        let e = parse("abc").unwrap_err();
        assert!(e.to_string().starts_with("not a number: "), "{e}");
        assert_eq!(parse("7").unwrap(), 7);
    }

    #[test]
    fn ensure_formats() {
        let e = parse("0").unwrap_err();
        assert_eq!(e.to_string(), "want positive, got 0");
    }

    #[test]
    fn bail_and_bare_ensure() {
        fn f(flag: bool) -> Result<()> {
            ensure!(flag);
            bail!("reached the end");
        }
        assert!(f(false).unwrap_err().to_string().contains("condition failed"));
        assert_eq!(f(true).unwrap_err().to_string(), "reached the end");
    }
}

use mlane::{algorithms::alltoall, model::CostModel, sim::Simulator, topology::Cluster};
fn main() {
    let cl = Cluster::hydra(2);
    let s = alltoall::build(cl, 869, alltoall::AlltoallAlg::KLane);
    let m = CostModel::hydra_baseline();
    let sim = Simulator::new(&s, &m);
    let mut st = sim.new_state();
    for rep in 0..6 { sim.run_into(&mut st, rep); }
}

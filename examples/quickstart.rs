//! Quickstart: the public API in ~40 lines.
//!
//! Builds a 4-node × 4-core cluster with 2 lanes, broadcasts 1000
//! MPI_INTs with three different algorithms, and shows both backends:
//! the discrete-event simulator (paper-style avg/min µs) and the
//! threaded exec runtime (real data movement, verified).
//!
//! Run: `cargo run --release --example quickstart`

use mlane::algorithms::registry::{self, OpKind};
use mlane::coordinator::{Collectives, Op};
use mlane::exec::ExecRuntime;
use mlane::harness::{run_plan, Grid, Plan, RunConfig};
use mlane::model::PersonaName;
use mlane::topology::Cluster;

fn main() -> anyhow::Result<()> {
    // A small multi-lane cluster: N=4 nodes, n=4 cores, k=2 lanes/node.
    let cluster = Cluster::new(4, 4, 2);
    let coll = Collectives::new(cluster, PersonaName::OpenMpi);

    let op = Op::Bcast { root: 0, c: 1000 };
    println!("bcast of 1000 ints on {}x{} (k={} lanes)\n", cluster.nodes, cluster.cores, cluster.lanes);

    // 1. Simulated timing under the Open MPI persona cost model.
    println!("simulated (persona {:?}):", coll.persona.name);
    for alg in [
        registry::kported(2),
        registry::klane(2),
        registry::fulllane(),
        registry::native(),
    ] {
        let m = coll.run(op, &alg)?;
        println!("  {:24} avg={:8.2}us  min={:8.2}us", m.algorithm, m.summary.avg, m.summary.min);
    }

    // 2. Real execution: 16 threads move real bytes; payloads verified.
    let rt = ExecRuntime::channels();
    let rep = coll.execute(op, &registry::fulllane(), &rt)?;
    println!(
        "\nexecuted full-lane for real: avg={:.1}us min={:.1}us ({} blocks verified)",
        rep.summary.avg, rep.summary.min, rep.blocks_verified
    );

    // 3. The coordinator's algorithm selection.
    let (best, m) = coll.autotune(op, &coll.default_candidates(op))?;
    println!("\nautotuner picks: {} ({:.2}us simulated)", best.label(), m.summary.avg);

    // 4. The experiment-plan API: declare a scenario grid, run it as a
    //    plan (all sections scheduled over one worker pool + the shared
    //    schedule cache), and render through the Text sink.
    let grid = Grid::new()
        .cluster(cluster)
        .op(OpKind::Bcast)
        .algs([registry::kported(2), registry::fulllane()])
        .counts(&[1, 1000]);
    let plan = Plan::new().table(1, "quickstart bcast grid", coll.persona.name, &grid);
    let report = run_plan(&plan, &RunConfig::default().reps(5))?;
    print!("\n{}", report.text());
    Ok(())
}

//! Algorithm-selection sweep: the coordinator as an "improved MPI".
//!
//! The paper's conclusion observes that native MPI collective selection
//! "can easily be improved, and sometimes quite considerably". This
//! example sweeps the paper's count grids on the simulated Hydra system
//! and prints, for every (operation, count), which algorithm the
//! autotuner picks, what the native library would have delivered, and
//! the speed-up — i.e. the selection table a better library would ship.
//!
//! Run: `MLANE_REPS=5 cargo run --release --example autotune`

use mlane::algorithms::registry;
use mlane::coordinator::{Collectives, Op};
use mlane::harness::{RunConfig, ALLTOALL_COUNTS, BCAST_COUNTS, SCATTER_COUNTS};
use mlane::model::PersonaName;
use mlane::topology::Cluster;

fn sweep(coll: &Collectives, name: &str, counts: &[u64], mk: impl Fn(u64) -> Op) {
    println!("--- {name} ---");
    println!(
        "{:>9} {:<24} {:>12} {:>12} {:>8}",
        "c", "winner", "winner(us)", "native(us)", "speedup"
    );
    for &c in counts {
        let op = mk(c);
        let native = coll.run(op, &registry::native()).expect("native supports every op");
        let (best, m) =
            coll.autotune(op, &coll.default_candidates(op)).expect("default candidates");
        println!(
            "{:>9} {:<24} {:>12.2} {:>12.2} {:>8.2}",
            c,
            format!("{} ({})", m.algorithm, best.label()),
            m.summary.avg,
            native.summary.avg,
            native.summary.avg / m.summary.avg
        );
    }
    println!();
}

fn main() {
    let cluster = Cluster::hydra(2);
    // CLI edge: MLANE_REPS etc. are parsed here, not inside the library.
    let cfg = RunConfig::from_env();
    for persona in [PersonaName::OpenMpi, PersonaName::IntelMpi, PersonaName::Mpich] {
        let mut coll = Collectives::new(cluster, persona);
        coll.reps = cfg.reps;
        println!("=== persona: {} ===\n", persona.label());
        sweep(&coll, "bcast", BCAST_COUNTS, |c| Op::Bcast { root: 0, c });
        sweep(&coll, "scatter", SCATTER_COUNTS, |c| Op::Scatter { root: 0, c });
        sweep(&coll, "alltoall", ALLTOALL_COUNTS, |c| Op::Alltoall { c });
    }
}

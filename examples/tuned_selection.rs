//! Per-size tuned selection end to end: build decision tables for every
//! operation, print their breakpoints, persist them as a `TuningBook`
//! JSON artifact, and show the `tuned` meta-algorithm dispatching to
//! the per-count winner — beating (or matching) the native library at
//! every sampled size, which no fixed algorithm manages.
//!
//! Run: `cargo run --release --example tuned_selection`

use std::sync::Arc;

use mlane::algorithms::registry::{registry, tuned, OpKind};
use mlane::coordinator::Collectives;
use mlane::harness;
use mlane::model::PersonaName;
use mlane::tuning::{self, Scenario, TuneConfig};

fn main() {
    let cluster = mlane::topology::Cluster::new(4, 8, 2);
    let persona = PersonaName::OpenMpi;
    let engine = harness::shared_engine();

    // One tuning scenario per operation: registry default candidates
    // over the paper's count grid, swept through the shared engine.
    let scenarios: Vec<Scenario> = OpKind::ALL
        .into_iter()
        .map(|op| Scenario::default_for(cluster, op, persona))
        .collect();
    let book = tuning::tune_all(&engine, &scenarios, &TuneConfig::default(), 4)
        .expect("default scenarios tune");
    print!("{}", book.text());

    let path = std::env::temp_dir().join("mlane_tuned_selection.json");
    book.save(&path).expect("persist the book");
    println!("\npersisted: {} ({} tables)\n", path.display(), book.tables.len());

    // The payoff: `tuned` vs the native library at every bcast count.
    let mut coll = Collectives::with_engine(cluster, persona, Arc::clone(&engine));
    coll.reps = 5;
    coll.warmup = 1;
    let meta = tuned();
    let native = registry().resolve("native", 0).expect("native");
    println!("bcast: tuned dispatch vs native MPI_Bcast");
    println!("{:>9} {:<26} {:>12} {:>12} {:>8}", "c", "dispatched", "tuned(us)", "native(us)", "speedup");
    for &c in harness::default_counts(OpKind::Bcast) {
        let op = OpKind::Bcast.op(c);
        let t = coll.run(op, &meta).expect("tuned runs everywhere");
        let n = coll.run(op, &native).expect("native runs everywhere");
        println!(
            "{:>9} {:<26} {:>12.2} {:>12.2} {:>8.2}",
            c,
            t.algorithm,
            t.summary.avg,
            n.summary.avg,
            n.summary.avg / t.summary.avg
        );
    }
}

//! End-to-end driver: a distributed data-shuffle pipeline over the full
//! three-layer stack — the workload the paper's alltoall section
//! motivates (bulk data redistribution across a multi-lane cluster).
//!
//! Pipeline per step, on a 4-node × 4-core cluster (16 worker threads):
//!   1. **broadcast** the pipeline configuration (full-lane bcast);
//!   2. **scatter**  per-worker partitions from the leader (k-lane
//!      scatter);
//!   3. **alltoall shuffle** of a synthetic keyed dataset — every worker
//!      re-partitions its records to their destination workers
//!      (full-lane alltoall: node-local combine through the *Pallas
//!      `alltoall_pack` kernel via the AOT XLA artifact*, then
//!      inter-node rotation);
//!   4. **checksum validation** of the shuffled payload through the
//!      `checksum` artifact (L1 kernel), cross-checked in rust.
//!
//! Every byte moves through the threaded exec runtime's mailboxes or the
//! PJRT-executed node phases; the pipeline reports per-stage latency and
//! end-to-end shuffle throughput, and verifies every delivered block.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example shuffle_pipeline`

use std::time::Instant;

use mlane::algorithms::registry;
use mlane::coordinator::{Collectives, Op};
use mlane::exec::{block_elem, ExecRuntime, PhaseMode};
use mlane::model::PersonaName;
use mlane::runtime::XlaService;
use mlane::topology::Cluster;

const NODES: u32 = 4;
const CORES: u32 = 4;
const LANES: u32 = 2;
/// Records per (worker, worker) shuffle block. With i32 records and
/// p = 16 workers this is 256 × 16 × 16 × 4 B = 256 KiB per step; the
/// full-lane combine phase then moves N·c = 1024-element pair payloads,
/// matching the (n=4, c=1024) AOT artifact.
const C: u64 = 256;
const STEPS: usize = 10;
const WARMUP: usize = 2;

fn main() -> anyhow::Result<()> {
    let cluster = Cluster::new(NODES, CORES, LANES);
    let p = cluster.p();
    println!(
        "shuffle pipeline on {NODES}x{CORES} (k={LANES} lanes), p={p} workers, \
         {C} records/block, {STEPS} steps\n"
    );

    let artifacts = std::path::Path::new("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let xla = XlaService::start(artifacts)?;
    let rt = ExecRuntime::with_xla(xla.clone());
    anyhow::ensure!(rt.mode == PhaseMode::Xla);

    let mut coll = Collectives::new(cluster, PersonaName::OpenMpi);
    coll.reps = STEPS;
    coll.warmup = WARMUP;

    // --- stage 1: broadcast the "configuration" (full-lane bcast) ---
    let t0 = Instant::now();
    let bcast = coll.execute(Op::Bcast { root: 0, c: 1024 }, &registry::fulllane(), &rt)?;
    println!(
        "stage 1  bcast config      avg={:>8.1}us min={:>8.1}us  ({} blocks, xla_phases={})",
        bcast.summary.avg, bcast.summary.min, bcast.blocks_verified, bcast.xla_phases
    );

    // --- stage 2: scatter partitions (k-lane scatter) ---
    let scatter =
        coll.execute(Op::Scatter { root: 0, c: 1024 }, &registry::klane(LANES), &rt)?;
    println!(
        "stage 2  scatter inputs    avg={:>8.1}us min={:>8.1}us  ({} blocks)",
        scatter.summary.avg, scatter.summary.min, scatter.blocks_verified
    );

    // --- stage 3: the shuffle (full-lane alltoall, XLA node phases) ---
    let shuffle = coll.execute(Op::Alltoall { c: C }, &registry::fulllane(), &rt)?;
    let shuffled_bytes = (p as u64) * (p as u64) * C * 4;
    println!(
        "stage 3  alltoall shuffle  avg={:>8.1}us min={:>8.1}us  ({} blocks, xla_phases={})",
        shuffle.summary.avg, shuffle.summary.min, shuffle.blocks_verified, shuffle.xla_phases
    );
    anyhow::ensure!(shuffle.xla_phases > 0, "expected Pallas-kernel node phases");

    // --- stage 4: checksum validation through the L1 checksum kernel ---
    // Every worker's received row (p blocks of C records) is checksummed
    // by the AOT `checksum` artifact and cross-checked in rust.
    let t_csum = Instant::now();
    let mut validated = 0u64;
    for dst in 0..p {
        // Reconstruct the received row from the payload generator (block
        // (src → dst) has id src·p + dst) and wrap-sum it in rust.
        let mut row = Vec::with_capacity((p as u64 * C) as usize);
        let mut expect = 0i32;
        for src in 0..p {
            let b = src as u64 * p as u64 + dst as u64;
            for e in 0..C {
                let v = block_elem(b, e);
                row.push(v);
                expect = expect.wrapping_add(v);
            }
        }
        // The checksum artifact is lowered for (n·c,) inputs; the row is
        // p·C = (CORES·NODES)·C — use the n=CORES, c=NODES·C shape? The
        // aot sweep lowers (n, c) grids, so feed per-node slices of
        // CORES·C elements and combine.
        let mut xla_sum = 0i32;
        for chunk in row.chunks((CORES as u64 * C) as usize) {
            let got = xla.run("checksum", CORES, C, chunk.to_vec())?;
            xla_sum = xla_sum.wrapping_add(got[0]);
        }
        anyhow::ensure!(
            xla_sum == expect,
            "checksum mismatch for worker {dst}: xla={xla_sum} rust={expect}"
        );
        validated += 1;
    }
    println!(
        "stage 4  checksum (L1)     {:>8.1}us total  ({validated}/{p} workers validated)",
        t_csum.elapsed().as_secs_f64() * 1e6
    );

    // --- headline metrics ---
    let pipeline_avg = bcast.summary.avg + scatter.summary.avg + shuffle.summary.avg;
    let tput = shuffled_bytes as f64 / shuffle.summary.avg; // B/us = MB/s
    println!("\n=== end-to-end ===");
    println!("pipeline latency (avg/step): {pipeline_avg:>10.1} us");
    println!("shuffle payload            : {:>10.2} MiB/step", shuffled_bytes as f64 / (1 << 20) as f64);
    println!("shuffle throughput         : {tput:>10.1} MB/s");
    println!("total wallclock            : {:>10.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    println!("\nall blocks verified; record in EXPERIMENTS.md §End-to-end");
    Ok(())
}

//! Multi-process plan sharding end to end, inside one process for
//! demonstration: split a scenario plan into 3 shards, run each shard
//! against its own engine (exactly what separate machines would do),
//! write the self-describing shard artifacts, merge them back, and
//! verify the merged report is byte-identical to a single-process run.
//!
//! In production the three `run + write` steps below are three
//! `mlane sweep … --shards 3 --shard-index I --out shard_I.json`
//! processes on three machines, and the merge is
//! `mlane merge report.txt shard_dir/` on the coordinator.
//!
//! Run: `cargo run --release --example plan_sharding`

use std::sync::Arc;

use mlane::algorithms::registry::{self, OpKind};
use mlane::harness::{
    merge_dir, plan_fingerprint, run_plan_with, write_shard, Grid, Merged, Plan, RunConfig,
};
use mlane::model::PersonaName;
use mlane::sim::SweepEngine;
use mlane::topology::Cluster;

fn main() -> anyhow::Result<()> {
    let grid = Grid::new()
        .cluster(Cluster::new(4, 8, 2))
        .op(OpKind::Bcast)
        .algs((1..=2).map(registry::klane).chain([registry::fulllane(), registry::native()]))
        .counts(&[1, 1000, 100_000]);
    let plan = Plan::new().table(1, "sharding demo: bcast grid", PersonaName::OpenMpi, &grid);
    let cfg = RunConfig::default().reps(5);
    let shards = 3u32;

    println!(
        "plan: {} sections, {} cells; fingerprint {:016x}\n",
        plan.num_sections(),
        plan.num_cells(),
        plan_fingerprint(&plan, &cfg)
    );

    let dir = std::env::temp_dir().join("mlane_plan_sharding_example");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    // Each "process": run the owned sections, emit the shard artifact.
    for i in 0..shards {
        let sub = plan.shard(shards, i);
        let engine = Arc::new(SweepEngine::new()); // per-process cache
        let report = run_plan_with(&engine, &sub, &cfg)?;
        let path = dir.join(format!("shard_{i}.json"));
        write_shard(&path, &plan, &cfg, shards, i, &report)?;
        println!(
            "shard {i}: {} sections -> {}",
            sub.num_sections(),
            path.display()
        );
    }

    // The coordinator: merge and compare against a single-process run.
    let merged = match merge_dir(&dir)? {
        Merged::Report(r) => r,
        Merged::Book(_) => unreachable!("plan shards"),
    };
    let single = run_plan_with(&Arc::new(SweepEngine::new()), &plan, &cfg)?;
    assert_eq!(merged.text(), single.text(), "distributed run must equal serial");
    assert_eq!(merged.json(), single.json());
    println!("\nmerged report (byte-identical to a single-process run):\n");
    print!("{}", merged.text());
    Ok(())
}

//! Reproduce the paper's headline tables on the simulated Hydra system
//! and compare against the transcribed paper anchors.
//!
//! Regenerates Table 12 (full-lane Bcast vs native MPI_Bcast, Open MPI)
//! and Table 41 (full-lane Alltoall vs native MPI_Alltoall, Open MPI) —
//! the two tables where the paper's most quotable results live (the ~5×
//! full-lane broadcast win; the native alltoall mid-size collapse) —
//! then prints simulated-vs-paper ratios for every anchor cell.
//!
//! Both tables run as ONE experiment plan over the shared engine, and
//! the output flows through the Text sink.
//!
//! Run: `MLANE_REPS=10 cargo run --release --example hydra_tables`

use mlane::harness::{anchors, run_plan, Plan, RunConfig, TextSink};

fn main() -> anyhow::Result<()> {
    // CLI edge: env (MLANE_REPS/MLANE_THREADS/...) parsed here, once.
    let cfg = RunConfig::from_env();

    let mut plan = Plan::paper();
    plan.tables.retain(|t| [12u32, 41].contains(&t.number));
    let report = run_plan(&plan, &cfg)?;
    let stdout = std::io::stdout();
    report.emit(&mut TextSink::new(stdout.lock()))?;
    println!();

    println!("--- anchor comparison (shape check; see EXPERIMENTS.md) ---");
    println!(
        "{:>6} {:<28} {:>9} {:>12} {:>12} {:>7}",
        "table", "section", "c", "paper(us)", "sim(us)", "ratio"
    );
    for c in anchors::compare_all(&cfg)? {
        println!(
            "{:>6} {:<28} {:>9} {:>12.2} {:>12.2} {:>7.2}",
            c.anchor.table, c.anchor.section, c.anchor.c, c.anchor.paper_avg_us,
            c.simulated_avg_us, c.ratio
        );
    }
    Ok(())
}

//! Contention: the same collective under a loaded network.
//!
//! The analytic backend prices a schedule on an idle cluster. The
//! event-driven network backend replays it through per-node lane ports
//! with FIFO serialization, so it can also answer "what if the network
//! is busy?": background tenant flows, straggling nodes, bounded
//! drop-tail queues. This example runs one k-lane broadcast across the
//! scenario ladder and shows the slowdown each effect adds.
//!
//! Run: `cargo run --release --example contention`

use mlane::algorithms::registry;
use mlane::coordinator::{Collectives, Op};
use mlane::model::PersonaName;
use mlane::netsim::{Backend, Scenario};
use mlane::topology::Cluster;

fn main() -> anyhow::Result<()> {
    let cluster = Cluster::new(8, 8, 2);
    let op = Op::Bcast { root: 0, c: 100_000 };
    let alg = registry::klane(2);
    println!(
        "k-lane bcast of 100000 ints on {}x{} (k={} lanes), event backend\n",
        cluster.nodes, cluster.cores, cluster.lanes
    );

    // The scenario ladder: idle wire -> tenant traffic -> stragglers on
    // top. Each rung reuses the same schedule; only the network differs.
    let mut tenants = Scenario::contention_free();
    tenants.tenant_flows = 4;
    tenants.tenant_gap_us = 50.0;
    tenants.tenant_bytes = 16_384.0;
    let mut loaded = tenants;
    loaded.straggler_nodes = 2;
    loaded.straggler_factor = 1.5;

    let mut baseline = 0.0;
    for (label, scenario) in [
        ("contention-free", Scenario::contention_free()),
        ("4 tenant flows/node", tenants),
        ("tenants + 2 stragglers x1.5", loaded),
    ] {
        let mut coll = Collectives::new(cluster, PersonaName::OpenMpi);
        coll.backend = Backend::Event(scenario);
        let m = coll.run(op, &alg)?;
        if baseline == 0.0 {
            baseline = m.summary.avg;
        }
        println!(
            "  {:28} avg={:10.2}us  min={:10.2}us  ({:4.2}x idle)",
            label,
            m.summary.avg,
            m.summary.min,
            m.summary.avg / baseline
        );
    }

    // A bounded queue turns overload into a typed error instead of an
    // unbounded backlog — the same NetError the CLI reports on exit 1.
    let mut choked = loaded;
    choked.queue_capacity = Some(0);
    let mut coll = Collectives::new(cluster, PersonaName::OpenMpi);
    coll.backend = Backend::Event(choked);
    match coll.run(Op::Alltoall { c: 10_000 }, &registry::fulllane()) {
        Ok(m) => println!("\nzero-capacity alltoall unexpectedly fit: {:.2}us", m.summary.avg),
        Err(e) => println!("\nzero-capacity alltoall refused, as designed:\n  {e}"),
    }
    Ok(())
}

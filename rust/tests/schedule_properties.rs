//! Property tests over the schedule invariants (DESIGN.md §Perf / §5):
//! for randomly drawn cluster shapes, roots, counts, and k, every
//! algorithm must produce schedules that are causal, port-legal, and
//! complete. Uses the in-repo deterministic property harness
//! (`mlane::util::prop`) — failures print a replayable seed.

use mlane::algorithms::{alltoall, bcast, scatter};
use mlane::schedule::validate::{validate, validate_ports};
use mlane::schedule::Schedule;
use mlane::topology::Cluster;
use mlane::util::prop::{check, Gen};

const CASES: u64 = 60;

fn random_cluster(g: &mut Gen) -> Cluster {
    let nodes = g.usize_in(1, 6) as u32;
    let cores = g.usize_in(1, 8) as u32;
    let lanes = g.usize_in(1, cores as usize) as u32;
    Cluster::new(nodes, cores, lanes)
}

fn assert_valid(s: &Schedule, ports: u32, ctx: &str) {
    if let Err(v) = validate(s) {
        panic!("{ctx}: {} invalid: {v}", s.algorithm);
    }
    if let Err(v) = validate_ports(s, ports) {
        panic!("{ctx}: {} port violation: {v}", s.algorithm);
    }
}

#[test]
fn prop_bcast_kported() {
    check("bcast k-ported", CASES, |g| {
        let cl = random_cluster(g);
        let root = g.usize_in(0, cl.p() as usize - 1) as u32;
        let k = g.usize_in(1, 6) as u32;
        let c = g.u64_in(1, 5000);
        let s = bcast::build(cl, root, c, bcast::BcastAlg::KPorted { k });
        assert_valid(&s, k, &format!("cl={cl:?} root={root} k={k} c={c}"));
    });
}

#[test]
fn prop_bcast_klane_both_variants() {
    check("bcast k-lane", CASES, |g| {
        let cl = random_cluster(g);
        let root = g.usize_in(0, cl.p() as usize - 1) as u32;
        let k = g.usize_in(1, cl.cores as usize) as u32;
        let c = g.u64_in(1, 5000);
        let two_phase = g.bool();
        let s = bcast::build(cl, root, c, bcast::BcastAlg::KLane { k, two_phase });
        assert_valid(&s, 1, &format!("cl={cl:?} root={root} k={k} two_phase={two_phase}"));
    });
}

#[test]
fn prop_bcast_fulllane_and_natives() {
    check("bcast full-lane/native", CASES, |g| {
        let cl = random_cluster(g);
        let root = g.usize_in(0, cl.p() as usize - 1) as u32;
        let c = g.u64_in(1, 5000);
        for alg in [
            bcast::BcastAlg::FullLane,
            bcast::BcastAlg::Binomial,
            bcast::BcastAlg::ScatterAllgather,
        ] {
            let s = bcast::build(cl, root, c, alg);
            assert_valid(&s, 1, &format!("cl={cl:?} root={root} c={c}"));
        }
    });
}

#[test]
fn prop_scatter_all_algorithms() {
    check("scatter", CASES, |g| {
        let cl = random_cluster(g);
        let root = g.usize_in(0, cl.p() as usize - 1) as u32;
        let k = g.usize_in(1, cl.cores as usize) as u32;
        let c = g.u64_in(1, 1000);
        let ctx = format!("cl={cl:?} root={root} k={k} c={c}");
        assert_valid(&scatter::build(cl, root, c, scatter::ScatterAlg::KPorted { k }), k, &ctx);
        assert_valid(&scatter::build(cl, root, c, scatter::ScatterAlg::KLane { k }), 1, &ctx);
        assert_valid(&scatter::build(cl, root, c, scatter::ScatterAlg::FullLane), 1, &ctx);
        assert_valid(&scatter::build(cl, root, c, scatter::ScatterAlg::Binomial), 1, &ctx);
        assert_valid(&scatter::build(cl, root, c, scatter::ScatterAlg::Linear), 1, &ctx);
    });
}

#[test]
fn prop_alltoall_all_algorithms() {
    check("alltoall", CASES / 2, |g| {
        let cl = random_cluster(g);
        let k = g.usize_in(1, 6) as u32;
        let c = g.u64_in(1, 100);
        let ctx = format!("cl={cl:?} k={k} c={c}");
        assert_valid(&alltoall::build(cl, c, alltoall::AlltoallAlg::KPorted { k }), k, &ctx);
        assert_valid(&alltoall::build(cl, c, alltoall::AlltoallAlg::Bruck { k }), k, &ctx);
        assert_valid(&alltoall::build(cl, c, alltoall::AlltoallAlg::KLane), cl.cores, &ctx);
        assert_valid(&alltoall::build(cl, c, alltoall::AlltoallAlg::FullLane), 1, &ctx);
        assert_valid(&alltoall::build(cl, c, alltoall::AlltoallAlg::Pairwise), 1, &ctx);
    });
}

#[test]
fn prop_offnode_bytes_never_below_collective_lower_bound() {
    // Any correct bcast must move ≥ (N-1) payloads off the root node…
    // actually ≥ c elements into each of the other N-1 nodes.
    check("bcast off-node lower bound", CASES / 2, |g| {
        let mut cl = random_cluster(g);
        while cl.nodes < 2 {
            cl = random_cluster(g);
        }
        let c = g.u64_in(1, 2000);
        let root = 0;
        for alg in [
            bcast::BcastAlg::KPorted { k: 2 },
            bcast::BcastAlg::KLane { k: cl.lanes, two_phase: false },
            bcast::BcastAlg::FullLane,
            bcast::BcastAlg::Binomial,
        ] {
            let s = bcast::build(cl, root, c, alg);
            let lower = (cl.nodes as u64 - 1) * c * 4;
            assert!(
                s.offnode_bytes() >= lower,
                "{}: off-node {} < lower bound {lower} (cl={cl:?} c={c})",
                s.algorithm,
                s.offnode_bytes()
            );
        }
    });
}

#[test]
fn prop_kported_scatter_root_egress_exact() {
    // §2.1: the k-ported scatter is message-size optimal — the root sends
    // each block exactly once, i.e. (p-1)·c elements leave the root.
    check("scatter root egress", CASES, |g| {
        let cl = random_cluster(g);
        let root = g.usize_in(0, cl.p() as usize - 1) as u32;
        let k = g.usize_in(1, 6) as u32;
        let c = g.u64_in(1, 500);
        let s = scatter::build(cl, root, c, scatter::ScatterAlg::KPorted { k });
        let egress: u64 = s
            .rounds
            .iter()
            .flat_map(|r| &r.transfers)
            .filter(|t| t.src == root)
            .map(|t| t.bytes)
            .sum();
        assert_eq!(egress, (cl.p() as u64 - 1) * c * 4, "cl={cl:?} root={root} k={k}");
    });
}

#[test]
fn prop_round_counts_match_paper_bounds() {
    check("round bounds", CASES, |g| {
        let cl = random_cluster(g);
        let p = cl.p();
        let k = g.usize_in(1, 6) as u32;
        let c = 10;
        // §2.1: ⌈log_{k+1} p⌉ rounds for k-ported bcast/scatter.
        let want = mlane::algorithms::common::ceil_log(p, k + 1) as usize;
        assert_eq!(bcast::build(cl, 0, c, bcast::BcastAlg::KPorted { k }).rounds.len(), want);
        assert_eq!(
            scatter::build(cl, 0, c, scatter::ScatterAlg::KPorted { k }).rounds.len(),
            want
        );
        // §2.1: ⌈(p-1)/k⌉ rounds for the round-robin alltoall.
        let a2a = alltoall::build(cl, c, alltoall::AlltoallAlg::KPorted { k });
        assert_eq!(a2a.rounds.len() as u32, (p - 1).div_ceil(k), "cl={cl:?} k={k}");
    });
}

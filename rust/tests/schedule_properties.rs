//! Property tests over the schedule invariants (DESIGN.md §Perf / §5):
//! for randomly drawn cluster shapes, roots, counts, and k, every
//! algorithm must produce schedules that are causal, port-legal, and
//! complete. Uses the in-repo deterministic property harness
//! (`mlane::util::prop`) — failures print a replayable seed.
//!
//! The second half hand-builds schedules that trip each lint of the
//! static-analysis driver (lane oversubscription, rendezvous deadlock,
//! redundant sends, dead data, mergeable rounds, per-code truncation)
//! and pins the exhaustive diagnostic lists — including a golden
//! text/JSON snapshot re-parsed with the independent mini JSON parser
//! in `common/`.

mod common;

use mlane::algorithms::{alltoall, bcast, scatter};
use mlane::analysis::{analyze, codes, Analysis, LintConfig, Severity};
use mlane::schedule::validate::{validate, validate_ports};
use mlane::schedule::{BlockSet, Collective, Round, Schedule};
use mlane::topology::Cluster;
use mlane::util::prop::{check, Gen};

const CASES: u64 = 60;

fn random_cluster(g: &mut Gen) -> Cluster {
    let nodes = g.usize_in(1, 6) as u32;
    let cores = g.usize_in(1, 8) as u32;
    let lanes = g.usize_in(1, cores as usize) as u32;
    Cluster::new(nodes, cores, lanes)
}

fn assert_valid(s: &Schedule, ports: u32, ctx: &str) {
    if let Err(v) = validate(s) {
        panic!("{ctx}: {} invalid: {v}", s.algorithm);
    }
    if let Err(v) = validate_ports(s, ports) {
        panic!("{ctx}: {} port violation: {v}", s.algorithm);
    }
    // The exhaustive driver must agree with the first-error wrappers:
    // a schedule both wrappers accept has zero error diagnostics.
    let a = analyze(s, &LintConfig::new(ports));
    assert!(a.is_clean(), "{ctx}: {} lint errors:\n{}", s.algorithm, a.text());
}

#[test]
fn prop_bcast_kported() {
    check("bcast k-ported", CASES, |g| {
        let cl = random_cluster(g);
        let root = g.usize_in(0, cl.p() as usize - 1) as u32;
        let k = g.usize_in(1, 6) as u32;
        let c = g.u64_in(1, 5000);
        let s = bcast::build(cl, root, c, bcast::BcastAlg::KPorted { k });
        assert_valid(&s, k, &format!("cl={cl:?} root={root} k={k} c={c}"));
    });
}

#[test]
fn prop_bcast_klane_both_variants() {
    check("bcast k-lane", CASES, |g| {
        let cl = random_cluster(g);
        let root = g.usize_in(0, cl.p() as usize - 1) as u32;
        let k = g.usize_in(1, cl.cores as usize) as u32;
        let c = g.u64_in(1, 5000);
        let two_phase = g.bool();
        let s = bcast::build(cl, root, c, bcast::BcastAlg::KLane { k, two_phase });
        assert_valid(&s, 1, &format!("cl={cl:?} root={root} k={k} two_phase={two_phase}"));
    });
}

#[test]
fn prop_bcast_fulllane_and_natives() {
    check("bcast full-lane/native", CASES, |g| {
        let cl = random_cluster(g);
        let root = g.usize_in(0, cl.p() as usize - 1) as u32;
        let c = g.u64_in(1, 5000);
        for alg in [
            bcast::BcastAlg::FullLane,
            bcast::BcastAlg::Binomial,
            bcast::BcastAlg::ScatterAllgather,
        ] {
            let s = bcast::build(cl, root, c, alg);
            assert_valid(&s, 1, &format!("cl={cl:?} root={root} c={c}"));
        }
    });
}

#[test]
fn prop_scatter_all_algorithms() {
    check("scatter", CASES, |g| {
        let cl = random_cluster(g);
        let root = g.usize_in(0, cl.p() as usize - 1) as u32;
        let k = g.usize_in(1, cl.cores as usize) as u32;
        let c = g.u64_in(1, 1000);
        let ctx = format!("cl={cl:?} root={root} k={k} c={c}");
        assert_valid(&scatter::build(cl, root, c, scatter::ScatterAlg::KPorted { k }), k, &ctx);
        assert_valid(&scatter::build(cl, root, c, scatter::ScatterAlg::KLane { k }), 1, &ctx);
        assert_valid(&scatter::build(cl, root, c, scatter::ScatterAlg::FullLane), 1, &ctx);
        assert_valid(&scatter::build(cl, root, c, scatter::ScatterAlg::Binomial), 1, &ctx);
        assert_valid(&scatter::build(cl, root, c, scatter::ScatterAlg::Linear), 1, &ctx);
    });
}

#[test]
fn prop_alltoall_all_algorithms() {
    check("alltoall", CASES / 2, |g| {
        let cl = random_cluster(g);
        let k = g.usize_in(1, 6) as u32;
        let c = g.u64_in(1, 100);
        let ctx = format!("cl={cl:?} k={k} c={c}");
        assert_valid(&alltoall::build(cl, c, alltoall::AlltoallAlg::KPorted { k }), k, &ctx);
        assert_valid(&alltoall::build(cl, c, alltoall::AlltoallAlg::Bruck { k }), k, &ctx);
        assert_valid(&alltoall::build(cl, c, alltoall::AlltoallAlg::KLane), cl.cores, &ctx);
        assert_valid(&alltoall::build(cl, c, alltoall::AlltoallAlg::FullLane), 1, &ctx);
        assert_valid(&alltoall::build(cl, c, alltoall::AlltoallAlg::Pairwise), 1, &ctx);
    });
}

#[test]
fn prop_offnode_bytes_never_below_collective_lower_bound() {
    // Any correct bcast must move ≥ (N-1) payloads off the root node…
    // actually ≥ c elements into each of the other N-1 nodes.
    check("bcast off-node lower bound", CASES / 2, |g| {
        let mut cl = random_cluster(g);
        while cl.nodes < 2 {
            cl = random_cluster(g);
        }
        let c = g.u64_in(1, 2000);
        let root = 0;
        for alg in [
            bcast::BcastAlg::KPorted { k: 2 },
            bcast::BcastAlg::KLane { k: cl.lanes, two_phase: false },
            bcast::BcastAlg::FullLane,
            bcast::BcastAlg::Binomial,
        ] {
            let s = bcast::build(cl, root, c, alg);
            let lower = (cl.nodes as u64 - 1) * c * 4;
            assert!(
                s.offnode_bytes() >= lower,
                "{}: off-node {} < lower bound {lower} (cl={cl:?} c={c})",
                s.algorithm,
                s.offnode_bytes()
            );
        }
    });
}

#[test]
fn prop_kported_scatter_root_egress_exact() {
    // §2.1: the k-ported scatter is message-size optimal — the root sends
    // each block exactly once, i.e. (p-1)·c elements leave the root.
    check("scatter root egress", CASES, |g| {
        let cl = random_cluster(g);
        let root = g.usize_in(0, cl.p() as usize - 1) as u32;
        let k = g.usize_in(1, 6) as u32;
        let c = g.u64_in(1, 500);
        let s = scatter::build(cl, root, c, scatter::ScatterAlg::KPorted { k });
        let egress: u64 = s
            .rounds
            .iter()
            .flat_map(|r| &r.transfers)
            .filter(|t| t.src == root)
            .map(|t| t.bytes)
            .sum();
        assert_eq!(egress, (cl.p() as u64 - 1) * c * 4, "cl={cl:?} root={root} k={k}");
    });
}

#[test]
fn prop_round_counts_match_paper_bounds() {
    check("round bounds", CASES, |g| {
        let cl = random_cluster(g);
        let p = cl.p();
        let k = g.usize_in(1, 6) as u32;
        let c = 10;
        // §2.1: ⌈log_{k+1} p⌉ rounds for k-ported bcast/scatter.
        let want = mlane::algorithms::common::ceil_log(p, k + 1) as usize;
        assert_eq!(bcast::build(cl, 0, c, bcast::BcastAlg::KPorted { k }).rounds.len(), want);
        assert_eq!(
            scatter::build(cl, 0, c, scatter::ScatterAlg::KPorted { k }).rounds.len(),
            want
        );
        // §2.1: ⌈(p-1)/k⌉ rounds for the round-robin alltoall.
        let a2a = alltoall::build(cl, c, alltoall::AlltoallAlg::KPorted { k });
        assert_eq!(a2a.rounds.len() as u32, (p - 1).div_ceil(k), "cl={cl:?} k={k}");
    });
}

// ---------------------------------------------------------------------------
// Hand-built schedules tripping each static-analysis lint.
// ---------------------------------------------------------------------------

/// The diagnostic codes of an analysis, in emission order.
fn codes_of(a: &Analysis) -> Vec<&'static str> {
    a.diagnostics.iter().map(|d| d.code).collect()
}

/// A 2-node × 2-core, 1-lane scatter from rank 0 whose first round
/// drives two off-node sends (lane oversubscription) and whose second
/// relays block 2 to rank 3, which neither requires nor forwards it
/// (dead data). Correct — zero errors — but two warn lints fire.
fn oversubscribed_scatter() -> Schedule {
    let cl = Cluster::new(2, 2, 1);
    let mut s = Schedule::new(cl, Collective::Scatter { root: 0, c: 4 }, "test");
    let t1 = s.transfer(0, 2, BlockSet::single(2));
    let t2 = s.transfer(0, 3, BlockSet::single(3));
    s.push_round(Round::of(vec![t1, t2]));
    let t3 = s.transfer(0, 1, BlockSet::single(1));
    let t4 = s.transfer(2, 3, BlockSet::single(2));
    s.push_round(Round::of(vec![t3, t4]));
    s
}

#[test]
fn lane_oversubscription_and_dead_data_are_linted() {
    let s = oversubscribed_scatter();
    let a = analyze(&s, &LintConfig::new(2));
    assert!(a.is_clean(), "unexpected errors:\n{}", a.text());
    assert_eq!(
        codes_of(&a),
        [
            codes::LANE_CONTENTION,  // round 0, node 0: 2 sends over 1 lane
            codes::LANE_CONTENTION,  // round 0, node 1: 2 recvs over 1 lane
            codes::LANE_SERIALIZATION,
            codes::DEAD_DATA, // rank 3 receives block 2 for nothing
        ],
        "\n{}",
        a.text()
    );
    let node0 = &a.diagnostics[0];
    assert_eq!(node0.severity, Severity::Warn);
    assert_eq!(node0.span.round, Some(0));
    assert_eq!(node0.u64_field("node"), Some(0));
    assert_eq!(node0.u64_field("sends"), Some(2));
    assert_eq!(node0.u64_field("recvs"), Some(0));
    assert_eq!(node0.u64_field("factor"), Some(2));
    let dead = &a.diagnostics[3];
    assert_eq!(dead.severity, Severity::Warn);
    assert_eq!(dead.u64_field("rank"), Some(3));
    assert_eq!(dead.u64_field("block"), Some(2));
}

#[test]
fn lint_text_snapshot_is_stable() {
    // Golden text output: the full rendering, not just codes — CI tools
    // grep these lines, so format drift must be deliberate.
    let a = analyze(&oversubscribed_scatter(), &LintConfig::new(2));
    assert_eq!(
        a.text(),
        "warn[lane-contention] round 0: node 0 drives 2 off-node sends / 0 recvs over 1 lane(s): ~2x serialized\n\
         warn[lane-contention] round 0: node 1 drives 0 off-node sends / 2 recvs over 1 lane(s): ~2x serialized\n\
         info[lane-serialization] schedule: 1 of 2 round(s) oversubscribe the node lanes (worst factor 2)\n\
         warn[dead-data] schedule: rank 3 receives 1 block(s) it neither requires nor forwards (e.g. block 2)\n"
    );
}

#[test]
fn lint_json_snapshot_parses_and_round_trips() {
    // The JSON emission, re-parsed with the independent strict parser:
    // schema (severity/code/round/transfer/message/payload) and values.
    let a = analyze(&oversubscribed_scatter(), &LintConfig::new(2));
    let doc = common::parse_json(&a.to_json()).expect("diagnostics JSON parses");
    let diags = doc.arr();
    assert_eq!(diags.len(), 4);
    let first = &diags[0];
    assert_eq!(first.get("severity").unwrap().string(), "warn");
    assert_eq!(first.get("code").unwrap().string(), "lane-contention");
    assert_eq!(first.get("round").unwrap().num(), 0.0);
    assert!(matches!(first.get("transfer"), Some(common::Json::Null)));
    let payload = first.get("payload").unwrap();
    assert_eq!(payload.get("node").unwrap().num(), 0.0);
    assert_eq!(payload.get("sends").unwrap().num(), 2.0);
    assert_eq!(payload.get("lanes").unwrap().num(), 1.0);
    let last = &diags[3];
    assert_eq!(last.get("code").unwrap().string(), "dead-data");
    assert!(matches!(last.get("round"), Some(common::Json::Null)));
    assert_eq!(last.get("payload").unwrap().get("block").unwrap().num(), 2.0);
}

#[test]
fn rendezvous_cycle_is_a_deadlock_error() {
    // Mutual exchange in one round: fine on a buffered backend (the
    // default lint config stays silent), a deadlock under rendezvous
    // semantics (both senders block, neither posts its receive).
    let cl = Cluster::new(1, 2, 1);
    let mut s = Schedule::new(cl, Collective::Allgather { c: 1 }, "test");
    let t1 = s.transfer(0, 1, BlockSet::single(0));
    let t2 = s.transfer(1, 0, BlockSet::single(1));
    s.push_round(Round::of(vec![t1, t2]));

    let buffered = analyze(&s, &LintConfig::new(1));
    assert!(buffered.is_clean(), "{}", buffered.text());
    assert!(buffered.diagnostics.is_empty(), "\n{}", buffered.text());

    let sync = analyze(&s, &LintConfig::new(1).with_rendezvous(0, 0));
    assert_eq!(codes_of(&sync), [codes::DEADLOCK], "\n{}", sync.text());
    let d = &sync.diagnostics[0];
    assert_eq!(d.severity, Severity::Error);
    assert_eq!(d.span.round, Some(0));
    assert_eq!(d.u64_field("ranks"), Some(2));
    assert_eq!(d.u64_field("cycle_len"), Some(2));
}

#[test]
fn redundant_transfer_and_round_slack_are_linted() {
    // Sending block 0 to rank 1 twice: the second delivery is redundant,
    // and the two rounds exceed the 1-ported lower bound for p = 2.
    let cl = Cluster::new(1, 2, 1);
    let mut s = Schedule::new(cl, Collective::Bcast { root: 0, c: 8, segments: 1 }, "test");
    for _ in 0..2 {
        let t = s.transfer(0, 1, BlockSet::single(0));
        s.push_round(Round::of(vec![t]));
    }
    let a = analyze(&s, &LintConfig::new(1));
    assert!(a.is_clean(), "{}", a.text());
    assert_eq!(codes_of(&a), [codes::REDUNDANT_TRANSFER, codes::ROUND_BOUND], "\n{}", a.text());
    let dup = &a.diagnostics[0];
    assert_eq!(dup.span, mlane::analysis::Span { round: Some(1), transfer: Some(0) });
    assert_eq!(dup.u64_field("count"), Some(1));
    assert_eq!(dup.u64_field("block"), Some(0));
    let slack = &a.diagnostics[1];
    assert_eq!(slack.u64_field("rounds"), Some(2));
    assert_eq!(slack.u64_field("lower"), Some(1));
    assert_eq!(slack.u64_field("slack"), Some(1));
}

#[test]
fn independent_rounds_are_flagged_mergeable() {
    // A serialized linear scatter under a 2-port budget: adjacent rounds
    // are independent and would fit merged — exactly what the lint is
    // for. The round-bound info rides along (3 rounds vs. lower bound 2).
    let cl = Cluster::new(1, 4, 1);
    let mut s = Schedule::new(cl, Collective::Scatter { root: 0, c: 4 }, "test");
    for dst in 1..4u32 {
        let t = s.transfer(0, dst, BlockSet::single(dst as u64));
        s.push_round(Round::of(vec![t]));
    }
    let a = analyze(&s, &LintConfig::new(2));
    assert!(a.is_clean(), "{}", a.text());
    assert_eq!(
        codes_of(&a),
        [codes::ROUND_BOUND, codes::MERGEABLE_ROUNDS, codes::MERGEABLE_ROUNDS],
        "\n{}",
        a.text()
    );
    assert_eq!(a.diagnostics[1].u64_field("round"), Some(0));
    assert_eq!(a.diagnostics[1].u64_field("next"), Some(1));
    assert_eq!(a.diagnostics[2].u64_field("round"), Some(1));
    assert_eq!(a.diagnostics[2].u64_field("next"), Some(2));
}

#[test]
fn per_lint_cap_truncates_loudly() {
    // 60 rounds re-delivering the same block: 59 redundant-transfer
    // warnings hit the per-code cap; the overflow surfaces as one
    // truncation info (never silently).
    let cl = Cluster::new(1, 2, 1);
    let mut s = Schedule::new(cl, Collective::Bcast { root: 0, c: 8, segments: 1 }, "test");
    for _ in 0..60 {
        let t = s.transfer(0, 1, BlockSet::single(0));
        s.push_round(Round::of(vec![t]));
    }
    let a = analyze(&s, &LintConfig::new(1));
    assert_eq!(a.warnings(), 50, "\n{}", a.text());
    let trunc = a.diagnostics.last().unwrap();
    assert_eq!(trunc.code, codes::TRUNCATED);
    assert_eq!(trunc.severity, Severity::Info);
    assert_eq!(trunc.u64_field("dropped"), Some(9));
    assert_eq!(trunc.u64_field("cap"), Some(50));

    // A tighter cap keeps the cut proportional.
    let mut cfg = LintConfig::new(1);
    cfg.max_per_lint = 5;
    let tight = analyze(&s, &cfg);
    assert_eq!(tight.warnings(), 5);
    assert_eq!(tight.diagnostics.last().unwrap().u64_field("dropped"), Some(54));
}

#[test]
fn analysis_is_exhaustive_not_first_error() {
    // One round carrying four distinct defects: the legacy validator
    // stopped at the first; the driver must report every one of them,
    // plus the downstream delivery and port-budget consequences.
    let cl = Cluster::new(1, 4, 1);
    let mut s = Schedule::new(cl, Collective::Bcast { root: 0, c: 8, segments: 1 }, "test");
    let t0 = s.transfer(1, 2, BlockSet::single(0)); // causality: rank 1 holds nothing
    let t1 = s.transfer(0, 1, BlockSet::single(0)); // fine
    let t2 = mlane::schedule::Transfer { src: 0, dst: 3, blocks: BlockSet::single(5), bytes: 4 };
    let t3 = mlane::schedule::Transfer { src: 3, dst: 3, blocks: BlockSet::single(0), bytes: 4 };
    s.push_round(Round::of(vec![t0, t1, t2, t3]));
    let a = analyze(&s, &LintConfig::new(1));
    assert_eq!(
        codes_of(&a),
        [
            codes::CAUSALITY,     // round 0/t0
            codes::UNKNOWN_BLOCK, // round 0/t2: block 5 of 1
            codes::BAD_ENDPOINTS, // round 0/t3: self-message
            codes::DELIVERY,      // rank 3 never gets block 0
            codes::PORT_BUDGET,   // rank 0 sends twice under limit 1
            codes::DEAD_DATA,     // rank 3 sits on useless block 5
        ],
        "\n{}",
        a.text()
    );
    assert_eq!(a.errors(), 5);
    assert_eq!(a.diagnostics[0].span.transfer, Some(0));
    assert_eq!(a.diagnostics[1].span.transfer, Some(2));
    assert_eq!(a.diagnostics[2].span.transfer, Some(3));
    assert_eq!(a.diagnostics[4].u64_field("rank"), Some(0));
    assert_eq!(a.diagnostics[4].u64_field("sends"), Some(2));
}

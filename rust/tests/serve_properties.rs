//! `mlane serve` correctness properties.
//!
//! The tentpole claim: the selection service is *semantics-preserving*
//! — for every table in a generated book, a serve answer names exactly
//! the algorithm the `tuned` registry dispatch would build, including
//! at breakpoint boundaries (`from`, `from±1`), below the first entry
//! (saturating) and past the last (open-ended). The rest of the file
//! pins the failure envelope: malformed requests and uncovered
//! scenarios become `{"ok":false,...}` responses (never a panic),
//! malformed books are typed load errors, and hot reload is torn-free
//! under concurrency.
//!
//! Responses are re-parsed with the *independent* strict JSON parser
//! from `tests/common`, not the library's own reader.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use mlane::algorithms::registry::{registry, OpKind};
use mlane::model::PersonaName;
use mlane::serve::{Flow, ServeError, Service};
use mlane::sim::SweepEngine;
use mlane::topology::Cluster;
use mlane::tuning::{self, Scenario, TuneConfig, TuneError, TuningBook};

use common::{parse_json, Json};

fn cfg() -> TuneConfig {
    TuneConfig { reps: 1, warmup: 0, seed: 7, ..TuneConfig::default() }
}

fn cl() -> Cluster {
    Cluster::new(2, 4, 2)
}

fn tune_one(op: OpKind, persona: PersonaName, counts: &[u64]) -> tuning::DecisionTable {
    let engine = Arc::new(SweepEngine::new());
    let sc = Scenario {
        cluster: cl(),
        op,
        persona,
        counts: counts.to_vec(),
        candidates: registry().candidates(cl(), op),
    };
    tuning::tune_scenario(&engine, &sc, &cfg()).expect("tiny scenario tunes")
}

/// Every op x every persona on the tiny cluster — the "full generated
/// book" the equivalence property quantifies over.
fn full_book() -> TuningBook {
    let mut tables = Vec::new();
    for op in OpKind::ALL {
        for persona in PersonaName::all() {
            tables.push(tune_one(op, persona, &[1, 600, 6000, 60_000]));
        }
    }
    TuningBook { tune: cfg(), tables }
}

fn small_book() -> TuningBook {
    TuningBook {
        tune: cfg(),
        tables: vec![tune_one(OpKind::Bcast, PersonaName::OpenMpi, &[1, 600])],
    }
}

fn query_line(op: OpKind, persona: PersonaName, c: Cluster, count: u64) -> String {
    format!(
        "{{\"op\":\"{}\",\"persona\":\"{}\",\"nodes\":{},\"cores\":{},\
         \"lanes\":{},\"count\":{}}}",
        op.name(),
        persona.key(),
        c.nodes,
        c.cores,
        c.lanes,
        count
    )
}

fn respond(svc: &Service, line: &str) -> String {
    let mut out = String::new();
    assert_eq!(svc.respond(line, &mut out), Flow::Continue, "{line}");
    out
}

fn ok_true(v: &Json) -> bool {
    matches!(v.get("ok"), Some(Json::Bool(true)))
}

/// The acceptance-criteria property: serve answers are identical to
/// what the `tuned` registry path dispatches, for every table in a
/// full generated book and for boundary-hugging counts. The installed
/// book is process-global state, so this is the ONE test that touches
/// `tuning::install`.
#[test]
fn serve_answers_match_tuned_dispatch_across_a_full_book() {
    let book = full_book();
    let svc = Service::from_book(&book).expect("full book compiles");
    tuning::install(book.clone()).expect("full book installs");

    for t in &book.tables {
        let mut counts = vec![0u64, 1, u64::MAX];
        for b in &t.entries {
            counts.push(b.from.saturating_sub(1));
            counts.push(b.from);
            counts.push(b.from.saturating_add(1));
        }
        for &c in &counts {
            let line = query_line(t.op, t.persona, t.cluster, c);
            let out = respond(&svc, &line);
            let v = parse_json(out.trim_end())
                .unwrap_or_else(|e| panic!("unparseable response {out:?}: {e}"));
            assert!(ok_true(&v), "covered query must answer ok: {out}");

            // The same scenario through the registry's `tuned` path.
            let alg = tuning::dispatch(t.cluster, t.persona, t.op, c)
                .expect("installed book covers the scenario");
            assert_eq!(v.get("alg").expect("alg").string(), alg.name(), "{line}");
            assert_eq!(v.get("label").expect("label").string(), alg.label(), "{line}");

            // And the book's own pick governs k / from / avg_us.
            let b = t.try_pick(c).expect("tuned tables are never empty");
            assert_eq!(v.get("k").expect("k").num() as u32, b.k, "{line}");
            assert_eq!(v.get("from").expect("from").num() as u64, b.from, "{line}");
            assert_eq!(v.get("avg_us").expect("avg_us").num(), b.avg_us, "{line}");
            assert_eq!(v.get("op").expect("op").string(), t.op.name(), "{line}");
            assert_eq!(v.get("persona").expect("persona").string(), t.persona.key(), "{line}");
        }
    }
    tuning::clear_installed();
}

/// A batch answers element-for-element what the single-query path
/// answers, in order.
#[test]
fn batch_answers_equal_single_answers() {
    let book = full_book();
    let svc = Service::from_book(&book).expect("full book compiles");
    let t = &book.tables[0];
    let counts: Vec<u64> = t.entries.iter().map(|b| b.from).chain([0, u64::MAX]).collect();
    let singles: Vec<String> = counts
        .iter()
        .map(|&c| respond(&svc, &query_line(t.op, t.persona, t.cluster, c)))
        .collect();
    let items: Vec<String> = counts
        .iter()
        .map(|&c| query_line(t.op, t.persona, t.cluster, c))
        .collect();
    let batch = respond(&svc, &format!("{{\"batch\":[{}]}}", items.join(",")));
    let v = parse_json(batch.trim_end()).expect("batch response parses");
    assert!(ok_true(&v), "{batch}");
    let answers = v.get("answers").expect("answers").arr();
    assert_eq!(answers.len(), singles.len());
    for (a, s) in answers.iter().zip(&singles) {
        let sv = parse_json(s.trim_end()).expect("single response parses");
        for key in ["op", "persona", "alg", "label"] {
            assert_eq!(
                a.get(key).expect(key).string(),
                sv.get(key).expect(key).string(),
                "batch and single disagree on {key}"
            );
        }
        for key in ["k", "from", "avg_us"] {
            assert_eq!(a.get(key).expect(key).num(), sv.get(key).expect(key).num(), "{key}");
        }
    }
}

/// Every malformed line in the fuzz corpus gets a parseable
/// `{"ok":false,...}` response, and the service keeps answering
/// well-formed queries afterwards — the daemon-survival contract.
#[test]
fn malformed_requests_are_error_responses_never_panics() {
    let svc = Service::from_book(&small_book()).expect("small book compiles");
    let good = query_line(OpKind::Bcast, PersonaName::OpenMpi, cl(), 600);
    let corpus: Vec<String> = vec![
        // not JSON at all
        "hello".into(),
        "{".into(),
        "[]".into(),
        "null".into(),
        // unknown vocabulary
        good.replace("bcast", "noop"),
        good.replace("openmpi", "nobody"),
        // zero dims would panic Cluster::new if they got that far
        good.replace("\"nodes\":2", "\"nodes\":0"),
        good.replace("\"lanes\":2", "\"lanes\":0"),
        // count: negative, float, overflow
        good.replace("\"count\":600", "\"count\":-1"),
        good.replace("\"count\":600", "\"count\":1.5"),
        good.replace("\"count\":600", "\"count\":18446744073709551616"),
        // missing / duplicate / unknown keys, trailing data
        good.replace(",\"count\":600", ""),
        good.replace("\"count\":600", "\"count\":600,\"count\":601"),
        good.replace("\"count\":600", "\"count\":600,\"extra\":1"),
        format!("{good} trailing"),
        // batch malformations
        format!("{{\"batch\":[{good},]}}"),
        format!("{{\"batch\":[{good}"),
        "{\"batch\":\"x\"}".into(),
        format!("{{\"batch\":[\"x\",{good}]}}"),
        // unknown command
        "{\"cmd\":\"nope\"}".into(),
        // valid shape, uncovered scenario
        query_line(OpKind::Scatter, PersonaName::OpenMpi, cl(), 600),
        query_line(OpKind::Bcast, PersonaName::OpenMpi, Cluster::new(9, 9, 1), 600),
        format!(
            "{{\"batch\":[{good},{}]}}",
            query_line(OpKind::Bcast, PersonaName::Mpich, cl(), 600)
        ),
        // reload with no backing path (in-memory book)
        "{\"cmd\":\"reload\"}".into(),
    ];
    for line in &corpus {
        let out = respond(&svc, line);
        assert!(
            out.starts_with("{\"ok\":false,\"error\":\""),
            "expected an error response for {line:?}, got {out:?}"
        );
        let v = parse_json(out.trim_end())
            .unwrap_or_else(|e| panic!("error response must be JSON ({line:?}): {e}"));
        assert!(!ok_true(&v));
        // Survival: the very next well-formed query still answers.
        let ok = respond(&svc, &good);
        assert!(ok.starts_with("{\"ok\":true,"), "service died after {line:?}: {ok}");
    }
    // Blank lines are keep-alives: no output at all.
    assert_eq!(respond(&svc, "\n"), "");
    assert_eq!(respond(&svc, "   "), "");
}

#[test]
fn stats_and_quit_follow_the_protocol() {
    let svc = Service::from_book(&small_book()).expect("small book compiles");
    let good = query_line(OpKind::Bcast, PersonaName::OpenMpi, cl(), 1);
    respond(&svc, &good);
    respond(&svc, "garbage");
    let stats = respond(&svc, "{\"cmd\":\"stats\"}");
    let v = parse_json(stats.trim_end()).expect("stats parses");
    assert!(ok_true(&v), "{stats}");
    assert_eq!(v.get("queries").expect("queries").num() as u64, 1);
    assert_eq!(v.get("errors").expect("errors").num() as u64, 1);
    assert_eq!(v.get("reloads").expect("reloads").num() as u64, 0);
    assert_eq!(v.get("tables").expect("tables").num() as u64, 1);
    assert_eq!(v.get("generation").expect("generation").num() as u64, 1);

    let mut out = String::new();
    assert_eq!(svc.respond("{\"cmd\":\"quit\"}", &mut out), Flow::Quit);
    assert_eq!(out, "{\"ok\":true,\"bye\":true}\n");
}

/// Book-shaped failures are typed `ServeError::Book` values at load
/// time — duplicate tables, empty tables, missing files — never
/// assertion failures inside the query path.
#[test]
fn malformed_books_fail_load_with_typed_errors() {
    let base = small_book();

    let dup = TuningBook {
        tune: cfg(),
        tables: vec![base.tables[0].clone(), base.tables[0].clone()],
    };
    let err = Service::from_book(&dup).expect_err("duplicate tables must not compile");
    assert!(matches!(&err, ServeError::Book(TuneError::DuplicateTable { .. })), "{err:?}");
    assert!(err.to_string().contains("duplicate table"), "{err}");

    let mut empty = base.clone();
    empty.tables[0].entries.clear();
    let err = Service::from_book(&empty).expect_err("empty tables must not compile");
    assert!(matches!(&err, ServeError::Book(TuneError::Parse(_))), "{err:?}");
    assert!(err.to_string().contains("no entries"), "{err}");

    let err = Service::load("/nonexistent/mlane/book.json")
        .expect_err("missing book file must not load");
    assert!(matches!(&err, ServeError::Book(TuneError::Io(_))), "{err:?}");
}

/// Hot reload: generation bumps and answers change after a successful
/// reload; a corrupt book keeps the old snapshot serving.
#[test]
fn reload_swaps_answers_and_keeps_old_snapshot_on_error() {
    let dir = std::env::temp_dir().join(format!("mlane_serve_reload_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("book.json");

    let mut book_a = small_book();
    for b in &mut book_a.tables[0].entries {
        b.avg_us = 1.0;
    }
    book_a.save(&path).expect("save book a");
    let svc = Service::load(&path).expect("book a loads");
    assert_eq!(svc.snapshot().generation(), 1);

    let good = query_line(OpKind::Bcast, PersonaName::OpenMpi, cl(), 600);
    let before = respond(&svc, &good);
    assert!(before.contains("\"avg_us\":1}"), "{before}");

    let mut book_b = book_a.clone();
    for b in &mut book_b.tables[0].entries {
        b.avg_us = 2.0;
    }
    book_b.save(&path).expect("save book b");
    let out = respond(&svc, "{\"cmd\":\"reload\"}");
    assert!(out.contains("\"reloaded\":true"), "{out}");
    assert!(out.contains("\"generation\":2"), "{out}");
    let after = respond(&svc, &good);
    assert!(after.contains("\"avg_us\":2}"), "{after}");

    // A corrupt book is an error response; the old snapshot survives.
    std::fs::write(&path, "{not json").expect("corrupt book");
    let out = respond(&svc, "{\"cmd\":\"reload\"}");
    assert!(out.starts_with("{\"ok\":false,"), "{out}");
    assert_eq!(svc.snapshot().generation(), 2);
    assert_eq!(respond(&svc, &good), after);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Torn-free reload under fire: a reader hammering batch queries must
/// never observe a response mixing two book versions — every answer in
/// one batch carries the same `avg_us` tag.
#[test]
fn concurrent_reloads_never_tear_a_batch() {
    let dir = std::env::temp_dir().join(format!("mlane_serve_torn_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("book.json");

    let mut book = small_book();
    // Two breakpoints so a batch can straddle table entries.
    assert!(!book.tables[0].entries.is_empty());
    for b in &mut book.tables[0].entries {
        b.avg_us = 1.0;
    }
    book.save(&path).expect("save");
    let svc = Arc::new(Service::load(&path).expect("loads"));

    let froms: Vec<u64> = book.tables[0].entries.iter().map(|b| b.from).collect();
    let items: Vec<String> = froms
        .iter()
        .chain(&[0, u64::MAX])
        .map(|&c| query_line(OpKind::Bcast, PersonaName::OpenMpi, cl(), c))
        .collect();
    let batch = format!("{{\"batch\":[{}]}}", items.join(","));

    let stop = Arc::new(AtomicBool::new(false));
    let reader = {
        let svc = Arc::clone(&svc);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut out = String::new();
            while !stop.load(Ordering::Relaxed) {
                out.clear();
                svc.respond(&batch, &mut out);
                let v = parse_json(out.trim_end()).expect("batch parses");
                let answers = v.get("answers").expect("answers").arr();
                let first = answers[0].get("avg_us").expect("avg_us").num();
                for a in answers {
                    assert_eq!(
                        a.get("avg_us").expect("avg_us").num(),
                        first,
                        "torn batch: {out}"
                    );
                }
            }
        })
    };

    for i in 0..50u64 {
        for b in &mut book.tables[0].entries {
            b.avg_us = (i % 2 + 1) as f64;
        }
        book.save(&path).expect("save");
        svc.reload().expect("reload");
    }
    stop.store(true, Ordering::Relaxed);
    reader.join().expect("reader thread clean");
    assert_eq!(svc.snapshot().generation(), 51);

    let _ = std::fs::remove_dir_all(&dir);
}

//! Shard-partition contract for `Plan::shard(n, i)`:
//!
//! 1. **Exhaustive + disjoint** — over `i ∈ 0..n` the shards' sections
//!    are exactly the full plan's sections, each appearing exactly once
//!    (checked as multisets for n ∈ {1, 2, 3, 7} on the full paper
//!    plan and the presets);
//! 2. **Stable** — the assignment is a pure function of the plan (no
//!    environment, no randomness): recomputing from a freshly built
//!    plan yields the identical partition;
//! 3. **Order-preserving** — a shard keeps tables and sections in full
//!    plan order, so shard artifacts map positionally;
//! 4. `shard(1, 0)` is the identity partition.

use mlane::algorithms::registry;
use mlane::algorithms::registry::OpKind;
use mlane::harness::{Grid, Plan};
use mlane::model::PersonaName;
use mlane::topology::Cluster;

/// One section's identity, rich enough to distinguish any two sections
/// of the paper plan (and to survive duplicate headings).
fn section_ids(plan: &Plan) -> Vec<String> {
    plan.tables
        .iter()
        .flat_map(|t| {
            t.sections.iter().map(move |s| {
                format!(
                    "{}|{}|{:?}|{}|{}|{:?}|{:?}",
                    t.number,
                    t.persona.key(),
                    s.cluster,
                    s.op,
                    s.alg.label(),
                    s.heading,
                    s.counts
                )
            })
        })
        .collect()
}

fn plans_under_test() -> Vec<(&'static str, Plan)> {
    let user = Plan::new().table(
        1,
        "user grid",
        PersonaName::Mpich,
        &Grid::new()
            .clusters([Cluster::new(2, 4, 2), Cluster::new(3, 4, 2)])
            .ops([OpKind::Bcast, OpKind::Scatter])
            .algs([registry::klane(1), registry::klane(2), registry::fulllane()])
            .counts(&[1, 64]),
    );
    vec![
        ("paper", Plan::paper()),
        ("appendix", Plan::appendix()),
        ("tuned", Plan::tuned()),
        ("user", user),
    ]
}

#[test]
fn shards_partition_exhaustively_and_disjointly() {
    for (name, plan) in plans_under_test() {
        let mut full = section_ids(&plan);
        full.sort();
        for n in [1u32, 2, 3, 7] {
            let mut union: Vec<String> = Vec::new();
            for i in 0..n {
                union.extend(section_ids(&plan.shard(n, i)));
            }
            // Same multiset: every section in exactly one shard.
            assert_eq!(union.len(), full.len(), "{name}, n={n}: lost or duplicated sections");
            union.sort();
            assert_eq!(union, full, "{name}, n={n}: partition is not the full plan");
        }
    }
}

#[test]
fn sharding_is_deterministic_across_plan_rebuilds() {
    for n in [2u32, 3, 7] {
        for i in 0..n {
            // Two *independently built* paper plans — the partition must
            // agree, because distributed processes each compute their own.
            let a = section_ids(&Plan::paper().shard(n, i));
            let b = section_ids(&Plan::paper().shard(n, i));
            assert_eq!(a, b, "n={n}, i={i}");
        }
    }
}

#[test]
fn shard_one_is_the_identity() {
    let plan = Plan::paper();
    let sharded = plan.shard(1, 0);
    assert_eq!(section_ids(&sharded), section_ids(&plan));
    assert_eq!(sharded.tables.len(), plan.tables.len());
    let numbers: Vec<u32> = sharded.tables.iter().map(|t| t.number).collect();
    let want: Vec<u32> = plan.tables.iter().map(|t| t.number).collect();
    assert_eq!(numbers, want, "table order preserved");
}

#[test]
fn shards_preserve_plan_order() {
    let plan = Plan::paper();
    let full = section_ids(&plan);
    for i in 0..3u32 {
        let ids = section_ids(&plan.shard(3, i));
        // Each shard's sections appear in the same relative order as in
        // the full plan (a subsequence), so positional row mapping in
        // the shard artifacts is well-defined.
        let mut cursor = 0usize;
        for id in &ids {
            let pos = full[cursor..]
                .iter()
                .position(|f| f == id)
                .unwrap_or_else(|| panic!("shard {i}: section out of order: {id}"));
            cursor += pos + 1;
        }
    }
}

#[test]
fn small_plans_leave_some_shards_empty_but_none_lost() {
    let plan = Plan::new().table(
        42,
        "single section",
        PersonaName::OpenMpi,
        &Grid::new()
            .cluster(Cluster::new(2, 2, 1))
            .op(OpKind::Bcast)
            .alg(registry::fulllane())
            .counts(&[1]),
    );
    let n = 7u32;
    let non_empty: Vec<u32> =
        (0..n).filter(|&i| !plan.shard(n, i).tables.is_empty()).collect();
    assert_eq!(non_empty.len(), 1, "one section lives in exactly one shard");
    let owned = plan.shard(n, non_empty[0]);
    assert_eq!(section_ids(&owned), section_ids(&plan));
    // Empty shards drop the table entirely rather than keeping a
    // sectionless spec (which run_plan would reject as an EmptySpec).
    for i in (0..n).filter(|i| *i != non_empty[0]) {
        assert!(plan.shard(n, i).tables.is_empty(), "shard {i}");
    }
}

#[test]
fn paper_plan_shards_are_roughly_balanced() {
    // Not a strict guarantee — just a regression guard that the hash
    // spreads the 100+ paper sections instead of clumping them (which
    // would silently serialize a "distributed" run).
    let plan = Plan::paper();
    let total = plan.num_sections();
    for n in [2usize, 3] {
        for i in 0..n {
            let got = plan.shard(n as u32, i as u32).num_sections();
            let fair = total / n;
            assert!(
                got >= fair / 2 && got <= fair * 2,
                "n={n}, shard {i}: {got} sections of {total} (fair ≈ {fair})"
            );
        }
    }
}

//! The serve hot-path allocation contract, enforced with the counting
//! allocator (`util::allocs`): once the service is warm, answering a
//! covered single query — wire scan, snapshot lookup, response
//! `push_str` — performs **zero** heap allocations, and so does a warm
//! batch line. `benches/engine_perf.rs` measures the same loop at
//! scale and CI gates `serve_steady_allocs == 0`.

use std::sync::Arc;

use mlane::algorithms::registry::{registry, OpKind};
use mlane::model::PersonaName;
use mlane::serve::{Flow, Service};
use mlane::sim::SweepEngine;
use mlane::topology::Cluster;
use mlane::tuning::{self, Scenario, TuneConfig, TuningBook};
use mlane::util::allocs::thread_allocations;

fn two_table_service() -> Service {
    let cl = Cluster::new(2, 4, 2);
    let cfg = TuneConfig { reps: 1, warmup: 0, seed: 7, ..TuneConfig::default() };
    let engine = Arc::new(SweepEngine::new());
    let tables = [OpKind::Bcast, OpKind::Scatter]
        .into_iter()
        .map(|op| {
            let sc = Scenario {
                cluster: cl,
                op,
                persona: PersonaName::OpenMpi,
                counts: vec![1, 600, 6000],
                candidates: registry().candidates(cl, op),
            };
            tuning::tune_scenario(&engine, &sc, &cfg).expect("tiny scenario tunes")
        })
        .collect();
    Service::from_book(&TuningBook { tune: cfg, tables }).expect("book compiles")
}

fn query(op: &str, count: u64) -> String {
    format!(
        "{{\"op\":\"{op}\",\"persona\":\"openmpi\",\"nodes\":2,\"cores\":4,\
         \"lanes\":2,\"count\":{count}}}"
    )
}

#[test]
fn warm_single_queries_allocate_nothing() {
    let svc = two_table_service();
    let reqs = [
        query("bcast", 0),
        query("bcast", 600),
        query("scatter", 6000),
        query("scatter", u64::MAX),
    ];
    let mut out = String::new();
    // Warm pass: size the buffer and fault in every code path.
    for line in &reqs {
        out.clear();
        assert_eq!(svc.respond(line, &mut out), Flow::Continue);
        assert!(out.starts_with("{\"ok\":true,"), "warm query must be covered: {out}");
    }

    let a0 = thread_allocations();
    for _ in 0..1000 {
        for line in &reqs {
            out.clear();
            svc.respond(line, &mut out);
            std::hint::black_box(out.len());
        }
    }
    let allocs = thread_allocations() - a0;
    assert_eq!(allocs, 0, "warm single-query serve path must not touch the heap");
    // The loop really did answer (paranoia against an optimized-out body).
    assert!(out.starts_with("{\"ok\":true,"), "{out}");
}

#[test]
fn warm_batches_allocate_nothing() {
    let svc = two_table_service();
    let items: Vec<String> = (0..64)
        .map(|i| query(if i % 2 == 0 { "bcast" } else { "scatter" }, 600 + i as u64))
        .collect();
    let batch = format!("{{\"batch\":[{}]}}", items.join(","));
    let mut out = String::new();
    assert_eq!(svc.respond(&batch, &mut out), Flow::Continue);
    assert!(out.starts_with("{\"ok\":true,\"answers\":["), "warm batch must be covered: {out}");

    let a0 = thread_allocations();
    for _ in 0..200 {
        out.clear();
        svc.respond(&batch, &mut out);
        std::hint::black_box(out.len());
    }
    let allocs = thread_allocations() - a0;
    assert_eq!(allocs, 0, "warm batch serve path must not touch the heap");
}

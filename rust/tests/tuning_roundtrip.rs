//! Decision-table persistence round trip: serialize a `TuningBook` to
//! JSON, re-parse it with the strict mini-parser promoted out of
//! `plan_report.rs` (`tests/common` — an implementation *independent*
//! of the library's reader), rebuild the book from the parsed document,
//! and assert the re-serialization is byte-identical. The library's own
//! strict parser must agree, and malformed artifacts must fail with
//! typed errors.

use std::sync::Arc;

use mlane::algorithms::registry::{registry, OpKind};
use mlane::model::PersonaName;
use mlane::sim::SweepEngine;
use mlane::topology::Cluster;
use mlane::tuning::{
    self, Breakpoint, DecisionTable, Scenario, TuneConfig, TuningBook,
};

mod common;
use common::{parse_json, Json};

fn tiny() -> Cluster {
    Cluster::new(2, 4, 2)
}

fn fast() -> TuneConfig {
    TuneConfig { reps: 2, warmup: 0, seed: 11, ..TuneConfig::default() }
}

fn sample_book() -> TuningBook {
    let engine = Arc::new(SweepEngine::new());
    let scenarios: Vec<Scenario> = [OpKind::Bcast, OpKind::Scatter, OpKind::Alltoall]
        .into_iter()
        .map(|op| Scenario {
            cluster: tiny(),
            op,
            persona: PersonaName::OpenMpi,
            counts: vec![1, 64, 869, 6000, 600_000],
            candidates: registry().candidates(tiny(), op),
        })
        .collect();
    tuning::tune_all(&engine, &scenarios, &fast(), 2).expect("tiny scenarios tune")
}

/// Rebuild a book from the *independently parsed* document — the
/// inverse mapping written against the parsed JSON, not the library
/// structs, so a writer/reader disagreement cannot cancel out.
fn book_from_json(doc: &Json) -> TuningBook {
    assert_eq!(doc.get("version").unwrap().num() as u32, 1);
    let tune_v = doc.get("tune").unwrap();
    let tune = TuneConfig {
        reps: tune_v.get("reps").unwrap().num() as usize,
        warmup: tune_v.get("warmup").unwrap().num() as usize,
        seed: tune_v.get("seed").unwrap().num() as u64,
        backend: mlane::netsim::BackendKind::parse(tune_v.get("backend").unwrap().string())
            .expect("known backend tag"),
    };
    let tables = doc
        .get("tables")
        .unwrap()
        .arr()
        .iter()
        .map(|t| DecisionTable {
            cluster: Cluster::new(
                t.get("nodes").unwrap().num() as u32,
                t.get("cores").unwrap().num() as u32,
                t.get("lanes").unwrap().num() as u32,
            ),
            op: OpKind::parse(t.get("op").unwrap().string()).expect("known op"),
            persona: PersonaName::parse(t.get("persona").unwrap().string())
                .expect("known persona"),
            entries: t
                .get("entries")
                .unwrap()
                .arr()
                .iter()
                .map(|e| Breakpoint {
                    from: e.get("from").unwrap().num() as u64,
                    alg: e.get("alg").unwrap().string().to_string(),
                    k: e.get("k").unwrap().num() as u32,
                    avg_us: e.get("avg_us").unwrap().num(),
                })
                .collect(),
        })
        .collect();
    TuningBook { tune, tables }
}

#[test]
fn reserialization_is_byte_identical() {
    let book = sample_book();
    let json = book.to_json();

    // Independent parse (the promoted mini-parser) -> rebuild -> emit.
    let doc = parse_json(&json).unwrap_or_else(|e| panic!("invalid JSON ({e}):\n{json}"));
    let rebuilt = book_from_json(&doc);
    assert_eq!(rebuilt, book);
    assert_eq!(rebuilt.to_json(), json, "re-serialization must be byte-identical");

    // The library's strict parser agrees byte-for-byte too.
    let lib = TuningBook::parse(&json).expect("library parser accepts its own writer");
    assert_eq!(lib, book);
    assert_eq!(lib.to_json(), json);
}

#[test]
fn save_load_round_trips_through_disk() {
    let book = sample_book();
    let path = std::env::temp_dir().join("mlane_tuning_roundtrip.json");
    book.save(&path).unwrap();
    let loaded = TuningBook::load(&path).unwrap();
    assert_eq!(loaded, book);
    assert_eq!(loaded.to_json(), book.to_json());
}

#[test]
fn breakpoint_semantics_survive_the_round_trip() {
    let book = sample_book();
    let loaded = TuningBook::parse(&book.to_json()).unwrap();
    for (orig, re) in book.tables.iter().zip(&loaded.tables) {
        // Dispatch decisions are identical at, between, and beyond the
        // sampled breakpoints.
        for c in [0u64, 1, 2, 64, 500, 869, 6000, 1_000_000, u64::MAX] {
            assert_eq!(orig.pick(c), re.pick(c), "{} c={c}", orig.label());
            assert_eq!(
                orig.resolve(c).unwrap().label(),
                re.resolve(c).unwrap().label(),
                "{} c={c}",
                orig.label()
            );
        }
    }
}

#[test]
fn malformed_artifacts_fail_typed() {
    let book = sample_book();
    let json = book.to_json();

    // Truncation, trailing garbage, and a corrupted alg name must all
    // be errors — never panics, never silently-empty books.
    assert!(TuningBook::parse(&json[..json.len() / 2]).is_err());
    assert!(TuningBook::parse(&format!("{json}garbage")).is_err());
    let corrupted = json.replace("\"alg\":\"", "\"alg\":\"zz");
    assert!(TuningBook::parse(&corrupted).is_err(), "unknown algorithm must be rejected");

    let missing = TuningBook::load(std::env::temp_dir().join("mlane_nonexistent_book.json"));
    let err = missing.unwrap_err();
    assert!(err.to_string().contains("read "), "{err}");
}

//! Registry-exhaustive validation, rewritten on **certificates**: every
//! registered algorithm × every operation it supports × a grid of
//! cluster shapes is certified clean of errors over the *entire* count
//! domain `[1, max]` — not a sampled handful of counts. The symbolic
//! driver partitions the domain at structure breaks and exact
//! eager/rendezvous byte crossovers and proves one verdict per
//! interval, so a clean report here covers every count a user could
//! pass.
//!
//! This replaces the old per-count spot checks: a newly registered
//! algorithm (e.g. the two-phase k-lane broadcast variant, `klane2p`)
//! is covered here with **no edits to this test**.

use mlane::algorithms::registry::{registry, OpKind};
use mlane::analysis::{analyze, certify, certify_registry, codes, CertifyOptions, LintConfig};
use mlane::model::{Persona, PersonaName};
use mlane::topology::Cluster;
use mlane::tuning;

/// Small, structure-exercising counts (uneven splits included via the
/// 3×5 cluster below) for the concrete spot checks that remain.
fn count_for(op: OpKind) -> u64 {
    match op {
        OpKind::Bcast => 64,
        OpKind::Scatter | OpKind::Gather => 16,
        OpKind::Allgather | OpKind::Alltoall => 8,
    }
}

/// Power-of-two, even, and uneven layouts.
fn clusters() -> [Cluster; 3] {
    [Cluster::new(2, 2, 1), Cluster::new(4, 4, 2), Cluster::new(3, 5, 2)]
}

#[test]
fn every_registered_algorithm_certifies_clean_on_every_supported_op() {
    let persona = Persona::get(PersonaName::OpenMpi);
    let opts = CertifyOptions::default();
    let mut certified = 0usize;
    for cl in clusters() {
        let report = certify_registry(cl, &persona, &OpKind::ALL, &opts)
            .unwrap_or_else(|e| panic!("certify_registry on {cl:?}: {e}"));
        assert_eq!(report.errors(), 0, "{cl:?} has error intervals:\n{}", report.text());
        for cert in &report.certificates {
            let ctx = format!("{} {} on {cl:?}", cert.algorithm, cert.op.name());
            // The intervals must tile [1, max_count] gap-free, in order.
            assert!(!cert.intervals.is_empty(), "{ctx}: empty certificate");
            let mut next = 1u64;
            for iv in &cert.intervals {
                assert_eq!(iv.lo, next, "{ctx}: gap before [{}, {}]", iv.lo, iv.hi);
                assert!(iv.hi >= iv.lo, "{ctx}: inverted interval");
                next = iv.hi.saturating_add(1);
            }
            assert_eq!(
                cert.intervals.last().unwrap().hi,
                cert.max_count,
                "{ctx}: domain ceiling mismatch"
            );
            certified += cert.intervals.len();
        }
        // Unsupported pairs must be typed errors, not panics — and must
        // stay *out* of the report.
        for alg in registry().validation_instances(cl) {
            for op in OpKind::ALL {
                if !alg.supports(op) {
                    assert!(
                        alg.build(cl, &persona, op.op(count_for(op))).is_err(),
                        "{} should reject {op}",
                        alg.label()
                    );
                }
            }
        }
    }
    // Sanity: the sweep really covered a substantial grid (10 families,
    // parameterized ones over k ranges, up to 5 ops each, multiple
    // intervals per entry).
    assert!(certified >= 100, "only {certified} intervals certified");
}

#[test]
fn native_certifies_clean_for_every_persona() {
    // Native selection depends on the persona; certify all three over
    // the full count domain so every structure break is covered from
    // both sides, not just at spot counts.
    let cl = Cluster::new(3, 4, 2);
    let native = registry().resolve("native", 0).unwrap();
    let opts = CertifyOptions::default();
    for name in PersonaName::all() {
        let persona = Persona::get(name);
        for op in OpKind::ALL {
            let cert = certify(&native, cl, &persona, op, &opts)
                .unwrap_or_else(|e| panic!("native {op} [{name:?}]: {e}"));
            assert_eq!(cert.errors(), 0, "{name:?} native {op} has error intervals");
            // Every persona switches native structure at least once for
            // bcast/allgather/alltoall — the certificate must see it.
            let distinct: std::collections::BTreeSet<&str> =
                cert.intervals.iter().map(|iv| iv.structure).collect();
            match op {
                OpKind::Bcast | OpKind::Allgather | OpKind::Alltoall => {
                    assert!(
                        distinct.len() >= 2,
                        "{name:?} native {op}: expected a structure switch, got {distinct:?}"
                    );
                }
                OpKind::Scatter | OpKind::Gather => {
                    assert_eq!(
                        distinct.len(),
                        1,
                        "{name:?} native {op}: unexpected structure switch {distinct:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn tuned_dispatch_lints_clean_for_every_persona() {
    // The dispatched schedule (not the meta-entry) must hold the full
    // invariants under every persona — native winners included, whose
    // selection varies by persona and count.
    let cl = Cluster::new(3, 4, 2);
    let tuned = registry().resolve("tuned", 0).unwrap();
    for name in PersonaName::all() {
        let persona = Persona::get(name);
        for op in OpKind::ALL {
            for c in [1u64, 64, 100_000] {
                let built = tuned
                    .build(cl, &persona, op.op(c))
                    .unwrap_or_else(|e| panic!("tuned {op} c={c} [{name:?}]: {e}"));
                let d = tuning::dispatch(cl, name, op, c)
                    .unwrap_or_else(|e| panic!("dispatch {op} c={c} [{name:?}]: {e}"));
                // What tuned built really is the dispatched algorithm's
                // schedule (same deterministic table on both paths).
                let direct = d
                    .build(cl, &persona, op.op(c))
                    .unwrap_or_else(|e| panic!("{} {op} c={c}: {e}", d.label()));
                assert_eq!(
                    built.schedule.algorithm, direct.schedule.algorithm,
                    "{name:?} {op} c={c}"
                );
                let a = analyze(&built.schedule, &LintConfig::new(d.ports_required(cl, op)));
                assert!(a.is_clean(), "{name:?} tuned {op} c={c}:\n{}", a.text());
            }
        }
    }
}

#[test]
fn ports_required_is_tight_enough_to_matter() {
    // The declared port budgets must really be the limit: k-ported with
    // k=2 must produce port-budget errors under a 1-port lint (otherwise
    // ports_required would be vacuous and the exhaustive test above
    // toothless) and lint clean under its own budget.
    let cl = Cluster::new(4, 4, 2);
    let persona = Persona::get(PersonaName::OpenMpi);
    let alg = registry().resolve("kported", 2).unwrap();
    let built = alg.build(cl, &persona, OpKind::Bcast.op(64)).unwrap();
    let tight = analyze(&built.schedule, &LintConfig::new(1));
    assert!(
        tight.diagnostics.iter().any(|d| d.code == codes::PORT_BUDGET),
        "2-ported fits 1 port?\n{}",
        tight.text()
    );
    let own = analyze(&built.schedule, &LintConfig::new(2));
    assert!(
        own.diagnostics.iter().all(|d| d.code != codes::PORT_BUDGET),
        "2-ported violates its own budget:\n{}",
        own.text()
    );
    assert!(own.is_clean(), "{}", own.text());
}

//! Registry-exhaustive validation, rewritten on the static-analysis
//! driver: every registered algorithm × every operation it supports ×
//! a grid of cluster shapes must lint **clean of errors** under the
//! algorithm's own `ports_required` — causality, port budget, delivery,
//! and endpoint/block sanity all come from one `analyze` call, and the
//! exhaustive driver reports *every* finding, not just the first.
//!
//! This replaces the old hand-maintained checklist in `cmd_validate`:
//! a newly registered algorithm (e.g. the two-phase k-lane broadcast
//! variant, `klane2p`) is covered here with **no edits to this test**.

use mlane::algorithms::registry::{registry, OpKind};
use mlane::analysis::{analyze, codes, LintConfig};
use mlane::model::{Persona, PersonaName};
use mlane::schedule::Schedule;
use mlane::topology::Cluster;
use mlane::tuning;

/// Small, structure-exercising counts (uneven splits included via the
/// 3×5 cluster below).
fn count_for(op: OpKind) -> u64 {
    match op {
        OpKind::Bcast => 64,
        OpKind::Scatter | OpKind::Gather => 16,
        OpKind::Allgather | OpKind::Alltoall => 8,
    }
}

/// Power-of-two, even, and uneven layouts.
fn clusters() -> [Cluster; 3] {
    [Cluster::new(2, 2, 1), Cluster::new(4, 4, 2), Cluster::new(3, 5, 2)]
}

/// Lint `s` under `ports` and panic with the full diagnostic list if
/// any error-severity finding survives.
fn assert_lints_clean(s: &Schedule, ports: u32, ctx: &str) {
    let a = analyze(s, &LintConfig::new(ports));
    assert!(
        a.is_clean(),
        "{ctx}: {} has {} error diagnostic(s):\n{}",
        s.algorithm,
        a.errors(),
        a.text()
    );
}

#[test]
fn every_registered_algorithm_lints_clean_on_every_supported_op() {
    let persona = Persona::get(PersonaName::OpenMpi);
    let mut checked = 0usize;
    for cl in clusters() {
        for alg in registry().validation_instances(cl) {
            for op in OpKind::ALL {
                if !alg.supports(op) {
                    // Unsupported pairs must be typed errors, not panics.
                    assert!(
                        alg.build(cl, &persona, op.op(count_for(op))).is_err(),
                        "{} should reject {op}",
                        alg.label()
                    );
                    continue;
                }
                let c = count_for(op);
                let built = alg
                    .build(cl, &persona, op.op(c))
                    .unwrap_or_else(|e| panic!("{} {op} on {cl:?}: {e}", alg.label()));
                // `tuned` is a meta-entry: what it built is the schedule
                // of whatever its decision table dispatched to, so the
                // port budget to verify is the *dispatched* algorithm's
                // own, not the meta budget (max over candidates) — a
                // 1-ported winner must still fit 1 port.
                let ports = if alg.name() == "tuned" {
                    let d = tuning::dispatch(cl, PersonaName::OpenMpi, op, c)
                        .unwrap_or_else(|e| panic!("tuned {op} on {cl:?}: {e}"));
                    assert_ne!(d.name(), "tuned", "self-dispatch");
                    d.ports_required(cl, op)
                } else {
                    alg.ports_required(cl, op)
                };
                assert_lints_clean(&built.schedule, ports, &format!("{op} on {cl:?}"));
                checked += 1;
            }
        }
    }
    // Sanity: the sweep actually covered a substantial grid (9 families,
    // parameterized ones over k ranges, up to 5 ops each).
    assert!(checked >= 60, "only {checked} combinations checked");
}

#[test]
fn native_schedules_lint_clean_for_every_persona() {
    // Native selection depends on the persona; exercise all three.
    let cl = Cluster::new(3, 4, 2);
    let native = registry().resolve("native", 0).unwrap();
    for name in PersonaName::all() {
        let persona = Persona::get(name);
        for op in OpKind::ALL {
            for c in [1u64, 64, 100_000] {
                let built = native
                    .build(cl, &persona, op.op(c))
                    .unwrap_or_else(|e| panic!("native {op} c={c}: {e}"));
                let ports = native.ports_required(cl, op);
                assert_lints_clean(&built.schedule, ports, &format!("{name:?} native {op} c={c}"));
            }
        }
    }
}

#[test]
fn tuned_dispatch_lints_clean_for_every_persona() {
    // The dispatched schedule (not the meta-entry) must hold the full
    // invariants under every persona — native winners included, whose
    // selection varies by persona and count.
    let cl = Cluster::new(3, 4, 2);
    let tuned = registry().resolve("tuned", 0).unwrap();
    for name in PersonaName::all() {
        let persona = Persona::get(name);
        for op in OpKind::ALL {
            for c in [1u64, 64, 100_000] {
                let built = tuned
                    .build(cl, &persona, op.op(c))
                    .unwrap_or_else(|e| panic!("tuned {op} c={c} [{name:?}]: {e}"));
                let d = tuning::dispatch(cl, name, op, c)
                    .unwrap_or_else(|e| panic!("dispatch {op} c={c} [{name:?}]: {e}"));
                // What tuned built really is the dispatched algorithm's
                // schedule (same deterministic table on both paths).
                let direct = d
                    .build(cl, &persona, op.op(c))
                    .unwrap_or_else(|e| panic!("{} {op} c={c}: {e}", d.label()));
                assert_eq!(
                    built.schedule.algorithm, direct.schedule.algorithm,
                    "{name:?} {op} c={c}"
                );
                assert_lints_clean(
                    &built.schedule,
                    d.ports_required(cl, op),
                    &format!("{name:?} tuned {op} c={c}"),
                );
            }
        }
    }
}

#[test]
fn ports_required_is_tight_enough_to_matter() {
    // The declared port budgets must really be the limit: k-ported with
    // k=2 must produce port-budget errors under a 1-port lint (otherwise
    // ports_required would be vacuous and the exhaustive test above
    // toothless) and lint clean under its own budget.
    let cl = Cluster::new(4, 4, 2);
    let persona = Persona::get(PersonaName::OpenMpi);
    let alg = registry().resolve("kported", 2).unwrap();
    let built = alg.build(cl, &persona, OpKind::Bcast.op(64)).unwrap();
    let tight = analyze(&built.schedule, &LintConfig::new(1));
    assert!(
        tight.diagnostics.iter().any(|d| d.code == codes::PORT_BUDGET),
        "2-ported fits 1 port?\n{}",
        tight.text()
    );
    let own = analyze(&built.schedule, &LintConfig::new(2));
    assert!(
        own.diagnostics.iter().all(|d| d.code != codes::PORT_BUDGET),
        "2-ported violates its own budget:\n{}",
        own.text()
    );
    assert!(own.is_clean(), "{}", own.text());
}

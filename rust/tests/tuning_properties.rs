//! Property tests for the tuning subsystem (via `util::prop::check` —
//! proptest is unavailable offline):
//!
//! 1. Every `DecisionTable` built from random clusters/ops/personas
//!    covers the full count domain through `pick` with sorted,
//!    deduplicated breakpoints, each anchored at a sampled count;
//! 2. `tuned` dispatch equals the argmin of its candidates' modeled
//!    cost (simulated average under the fixed `TuneConfig`) at every
//!    sampled count — and therefore costs no more than *any* fixed
//!    registry candidate there.

use std::sync::Arc;

use mlane::algorithms::registry::{registry, OpKind};
use mlane::coordinator::Collectives;
use mlane::harness;
use mlane::model::PersonaName;
use mlane::sim::SweepEngine;
use mlane::tuning::{self, Scenario, TuneConfig};
use mlane::util::prop::{check, Gen};

/// Count pool spanning the paper's grids (eager/rendezvous boundaries,
/// uneven splits).
const COUNT_POOL: &[u64] = &[1, 2, 6, 9, 53, 64, 87, 521, 869, 1000, 6000, 60_000];

fn fast() -> TuneConfig {
    TuneConfig { reps: 2, warmup: 0, seed: 0xC0FFEE, ..TuneConfig::default() }
}

fn random_scenario(g: &mut Gen) -> Scenario {
    // Same cluster envelope the exhaustive validation sweeps (multi-node,
    // multi-core, 1–2 lanes), including uneven core counts.
    let cluster = mlane::topology::Cluster::new(
        g.usize_in(2, 3) as u32,
        g.usize_in(2, 5) as u32,
        g.usize_in(1, 2) as u32,
    );
    let op = *g.choose(&OpKind::ALL);
    let persona = *g.choose(&PersonaName::all());
    let counts: Vec<u64> = (0..g.usize_in(1, 6)).map(|_| *g.choose(COUNT_POOL)).collect();
    Scenario {
        cluster,
        op,
        persona,
        counts,
        candidates: registry().candidates(cluster, op),
    }
}

#[test]
fn decision_tables_cover_the_domain_with_sorted_dedup_breakpoints() {
    let engine = Arc::new(SweepEngine::new());
    check("decision-table structure", 12, |g| {
        let sc = random_scenario(g);
        let mut sampled = sc.counts.clone();
        sampled.sort_unstable();
        sampled.dedup();
        let t = tuning::tune_scenario(&engine, &sc, &fast())
            .unwrap_or_else(|e| panic!("{}: {e}", sc.label()));
        t.validate().unwrap_or_else(|e| panic!("{}: {e}", sc.label()));
        assert!(!t.entries.is_empty());
        // Sorted strictly ascending, adjacent entries dispatch
        // differently (deduplicated compression).
        for w in t.entries.windows(2) {
            assert!(w[0].from < w[1].from, "unsorted: {} then {}", w[0].from, w[1].from);
            assert!(
                w[0].alg != w[1].alg || w[0].k != w[1].k,
                "adjacent duplicate {} at {} and {}",
                w[0].alg,
                w[0].from,
                w[1].from
            );
        }
        // Anchored at the smallest sampled count; every breakpoint
        // opens at a sampled count.
        assert_eq!(t.entries[0].from, sampled[0]);
        for b in &t.entries {
            assert!(sampled.contains(&b.from), "breakpoint {} not sampled", b.from);
        }
        // Full-domain coverage: pick/resolve are total (below the first
        // breakpoint, between samples, and beyond the last).
        for c in [0u64, 1, 5, sampled[0], 1_000_000, u64::MAX] {
            let b = t.pick(c);
            assert!(b.from <= c.max(t.entries[0].from), "pick({c}) -> from={}", b.from);
            t.resolve(c).unwrap_or_else(|e| panic!("resolve({c}): {e}"));
        }
    });
}

#[test]
fn tuned_dispatch_is_the_argmin_of_modeled_cost() {
    // A fixed small cluster so the auto tables (built once per
    // (cluster, op, persona) under TuneConfig::default) are shared
    // across cases; ops, personas and sampled counts vary randomly.
    let cluster = mlane::topology::Cluster::new(2, 4, 2);
    let cfg = TuneConfig::default();
    check("tuned dispatch argmin", 8, |g| {
        let op = *g.choose(&OpKind::ALL);
        let persona = *g.choose(&PersonaName::all());
        let c = *g.choose(harness::default_counts(op));
        let picked = tuning::dispatch(cluster, persona, op, c).unwrap();
        assert_ne!(picked.name(), "tuned", "self-dispatch");

        // Recompute the winner independently under the same TuneConfig.
        let mut coll = Collectives::new(cluster, persona);
        coll.reps = cfg.reps;
        coll.warmup = cfg.warmup;
        coll.seed = cfg.seed;
        let cands = registry().candidates(cluster, op);
        let costs: Vec<f64> = cands
            .iter()
            .map(|a| coll.run(op.op(c), a).unwrap().summary.avg)
            .collect();
        let tuned_cost = coll.run(op.op(c), &picked).unwrap().summary.avg;
        // tuned's modeled cost <= every fixed candidate's cost at c.
        for (a, &cost) in cands.iter().zip(&costs) {
            assert!(
                tuned_cost <= cost,
                "{op} c={c} [{persona:?}]: tuned picked {} ({tuned_cost}us) but {} costs {cost}us",
                picked.label(),
                a.label()
            );
        }
        // And it is exactly the first argmin (ties keep candidate order).
        let best = costs.iter().copied().fold(f64::INFINITY, f64::min);
        let first = cands
            .iter()
            .zip(&costs)
            .find(|(_, &cost)| cost == best)
            .expect("non-empty candidate set")
            .0;
        assert_eq!(
            (picked.name(), picked.k()),
            (first.name(), first.k()),
            "{op} c={c} [{persona:?}]"
        );
    });
}

#[test]
fn every_breakpoint_is_optimal_at_its_own_count() {
    // The acceptance property, stated directly on the auto tables: at
    // every breakpoint count, the table's winner costs no more than any
    // fixed registry candidate under the same TuneConfig.
    let cluster = mlane::topology::Cluster::new(2, 4, 2);
    let cfg = TuneConfig::default();
    for op in OpKind::ALL {
        let table = tuning::auto_table(cluster, PersonaName::OpenMpi, op).unwrap();
        let mut coll = Collectives::new(cluster, PersonaName::OpenMpi);
        coll.reps = cfg.reps;
        coll.warmup = cfg.warmup;
        coll.seed = cfg.seed;
        for b in &table.entries {
            let winner = table.resolve(b.from).unwrap();
            let winner_cost = coll.run(op.op(b.from), &winner).unwrap().summary.avg;
            for cand in registry().candidates(cluster, op) {
                let cost = coll.run(op.op(b.from), &cand).unwrap().summary.avg;
                assert!(
                    winner_cost <= cost,
                    "{op} breakpoint {}: {} ({winner_cost}us) beaten by {} ({cost}us)",
                    b.from,
                    winner.label(),
                    cand.label()
                );
            }
        }
    }
}

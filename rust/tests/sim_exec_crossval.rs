//! Cross-validation between the two backends and failure injection.
//!
//! The simulator and the exec runtime consume the same IR; these tests
//! pin down that (a) what the simulator times, the executor can really
//! do, (b) the simulator's cost ordering is sane against analytic
//! expectations, and (c) corrupted schedules are *caught*, not silently
//! mis-executed.

use mlane::algorithms::{alltoall, bcast, scatter};
use mlane::exec::ExecRuntime;
use mlane::model::CostModel;
use mlane::schedule::{BlockSet, Round, Schedule};
use mlane::sim;
use mlane::topology::Cluster;

fn quiet() -> CostModel {
    let mut m = CostModel::hydra_baseline();
    m.jitter_mean = 0.0;
    m
}

#[test]
fn every_simulated_schedule_is_executable() {
    // The exact schedules the simulator times must execute and verify on
    // the threaded backend — the strongest "sim isn't lying about the
    // communication structure" check we can run in-process.
    let cl = Cluster::new(3, 4, 2);
    let rt = ExecRuntime::channels();
    let mut schedules: Vec<Schedule> = Vec::new();
    for k in 1..=3 {
        schedules.push(bcast::build(cl, 5, 97, bcast::BcastAlg::KPorted { k }));
        schedules.push(bcast::build(cl, 5, 97, bcast::BcastAlg::KLane { k, two_phase: false }));
        schedules.push(scatter::build(cl, 5, 33, scatter::ScatterAlg::KPorted { k }));
        schedules.push(scatter::build(cl, 5, 33, scatter::ScatterAlg::KLane { k }));
        schedules.push(alltoall::build(cl, 9, alltoall::AlltoallAlg::Bruck { k }));
    }
    schedules.push(bcast::build(cl, 5, 97, bcast::BcastAlg::FullLane));
    schedules.push(scatter::build(cl, 5, 33, scatter::ScatterAlg::FullLane));
    schedules.push(alltoall::build(cl, 9, alltoall::AlltoallAlg::KLane));
    schedules.push(alltoall::build(cl, 9, alltoall::AlltoallAlg::FullLane));

    let m = quiet();
    for s in schedules {
        let t = sim::measure(&s, &m, 2, 0, 1);
        assert!(t.avg > 0.0, "{}", s.algorithm);
        let rep = rt.run(&s, 1, 0).unwrap_or_else(|e| panic!("{}: {e}", s.algorithm));
        assert!(rep.blocks_verified > 0, "{}", s.algorithm);
    }
}

#[test]
fn sim_ordering_matches_analytic_expectations() {
    let cl = Cluster::hydra(2);
    let m = quiet();
    let t = |s: &Schedule| sim::measure(s, &m, 2, 0, 1).avg;

    // Large bcast: scatter-allgather < binomial (2c vs log(p)·c).
    let sag = t(&bcast::build(cl, 0, 1_000_000, bcast::BcastAlg::ScatterAllgather));
    let bin = t(&bcast::build(cl, 0, 1_000_000, bcast::BcastAlg::Binomial));
    assert!(sag < bin, "sag {sag} >= binomial {bin}");

    // Small alltoall: Bruck (log rounds) < round-robin (p-1 rounds).
    let br = t(&alltoall::build(cl, 1, alltoall::AlltoallAlg::Bruck { k: 1 }));
    let rr = t(&alltoall::build(cl, 1, alltoall::AlltoallAlg::Pairwise));
    assert!(br < rr, "bruck {br} >= pairwise {rr}");

    // Large alltoall: the order flips (Bruck sends log-times the data).
    let br = t(&alltoall::build(cl, 869, alltoall::AlltoallAlg::Bruck { k: 1 }));
    let rr = t(&alltoall::build(cl, 869, alltoall::AlltoallAlg::Pairwise));
    assert!(rr < br, "pairwise {rr} >= bruck {br} at large c");

    // Scatter: k-ported k=6 ≤ k=1 (more ports can't hurt under the model).
    let k6 = t(&scatter::build(cl, 0, 869, scatter::ScatterAlg::KPorted { k: 6 }));
    let k1 = t(&scatter::build(cl, 0, 869, scatter::ScatterAlg::KPorted { k: 1 }));
    assert!(k6 <= k1 * 1.05, "k=6 {k6} much worse than k=1 {k1}");
}

#[test]
fn node_vs_net_shape_holds() {
    // §4.1: on-node alltoall is much slower than across-nodes at large
    // counts (Table 2: ~10× for Open MPI) — the shared-memory bus cannot
    // match 32 nodes' aggregate lanes.
    let m = mlane::model::Persona::openmpi().model;
    let onnode = alltoall::build(Cluster::new(1, 32, 2), 31250, alltoall::AlltoallAlg::KPorted { k: 31 });
    let offnode = alltoall::build(Cluster::new(32, 1, 1), 31250, alltoall::AlltoallAlg::KPorted { k: 31 });
    let t_on = sim::measure(&onnode, &m, 3, 1, 1).avg;
    let t_off = sim::measure(&offnode, &m, 3, 1, 1).avg;
    assert!(
        t_on > 3.0 * t_off,
        "on-node {t_on} not ≫ off-node {t_off} (paper shape: ~10x)"
    );
}

// ---- failure injection ----

#[test]
fn exec_catches_missing_delivery() {
    // Drop the last round of a binomial bcast: some rank never receives.
    let cl = Cluster::new(2, 2, 1);
    let mut s = bcast::build(cl, 0, 16, bcast::BcastAlg::Binomial);
    s.rounds.pop();
    let err = ExecRuntime::channels().run(&s, 1, 0).unwrap_err();
    assert!(err.to_string().contains("missing block"), "{err}");
}

#[test]
fn exec_catches_corrupted_block_ids() {
    // Rewrite a transfer to carry the wrong block: the receiver ends up
    // with a block whose content does not match its id's generator.
    let cl = Cluster::new(2, 2, 1);
    let mut s = scatter::build(cl, 0, 16, scatter::ScatterAlg::Linear);
    // Find a transfer and swap its block for another rank's block.
    let t = &mut s.rounds[0].transfers[0];
    let wrong = if t.blocks.contains(1) { 2 } else { 1 };
    t.blocks = BlockSet::single(wrong);
    let err = ExecRuntime::channels().run(&s, 1, 0).unwrap_err();
    assert!(err.to_string().contains("missing block"), "{err}");
}

#[test]
fn validate_catches_what_exec_would_deadlock_on() {
    // A transfer whose source never holds the data: validation must
    // reject it so the exec backend is never handed the schedule.
    let cl = Cluster::new(2, 2, 1);
    let mut s = bcast::build(cl, 0, 16, bcast::BcastAlg::Binomial);
    let bogus = s.transfer(2, 3, BlockSet::single(0));
    let mut round = Round::default();
    round.transfers.push(bogus);
    s.rounds.insert(0, round);
    assert!(mlane::schedule::validate::validate(&s).is_err());
}

#[test]
fn empty_count_still_works() {
    // c = 1 (single element) everywhere; boundary for Split sizing.
    let cl = Cluster::new(2, 4, 2);
    let rt = ExecRuntime::channels();
    for alg in [bcast::BcastAlg::FullLane, bcast::BcastAlg::KPorted { k: 2 }] {
        let s = bcast::build(cl, 0, 1, alg);
        rt.run(&s, 1, 0).unwrap_or_else(|e| panic!("{}: {e}", s.algorithm));
    }
}

//! Correctness gate for the batched series path: for every registry
//! algorithm family, every operation it supports, and both cached and
//! uncached (native-persona / count-dependent) code paths,
//! `Collectives::run_series` over a count grid must be bitwise
//! identical — cell for cell — to a per-count `Collectives::run` loop.
//!
//! The grid deliberately repeats counts (cache hits) and revisits
//! earlier counts (recost back down) so every branch of the series
//! loop is exercised, and the engine-level sweep stats are checked to
//! add up identically whether the counters are updated per cell or
//! batched once per series.

use mlane::algorithms::registry::{registry, OpKind};
use mlane::coordinator::{Collectives, Op};
use mlane::model::PersonaName;
use mlane::topology::Cluster;

/// Repeats and revisits on purpose: build, recost, hit, recost-back.
const COUNTS: &[u64] = &[1, 7, 64, 7, 869, 64, 60_000, 1];

fn coll(persona: PersonaName) -> Collectives {
    let mut c = Collectives::new(Cluster::new(2, 4, 2), persona);
    c.reps = 3;
    c.warmup = 1;
    c
}

#[test]
fn run_series_matches_per_count_run_for_every_registry_algorithm() {
    for persona in [PersonaName::OpenMpi, PersonaName::IntelMpi] {
        for entry in registry().entries() {
            let alg = entry.instantiate(2);
            for kind in OpKind::ALL {
                if !entry.supports(kind) {
                    continue;
                }
                let op = kind.op(1);
                // Fresh Collectives per mode: both sweeps start from a
                // cold cache, so equality covers the build cell too.
                let per = coll(persona);
                let cell_by_cell: Vec<_> = COUNTS
                    .iter()
                    .map(|&c| {
                        per.run(op.with_count(c), &alg)
                            .unwrap_or_else(|e| panic!("{kind} {alg:?} c={c}: {e}"))
                    })
                    .collect();
                let ser = coll(persona);
                let series = ser
                    .run_series(op, COUNTS, &alg)
                    .unwrap_or_else(|e| panic!("{kind} {alg:?}: {e}"));
                assert_eq!(cell_by_cell.len(), series.len());
                for (a, b) in cell_by_cell.iter().zip(&series) {
                    let ctx = format!("{persona:?} {kind} {alg:?} c={}", a.c);
                    assert_eq!(a.summary, b.summary, "{ctx}");
                    assert_eq!(a.algorithm, b.algorithm, "{ctx}");
                    assert_eq!(a.k, b.k, "{ctx}");
                    assert_eq!(a.c, b.c, "{ctx}");
                }
                assert_eq!(
                    per.sweep_stats(),
                    ser.sweep_stats(),
                    "{persona:?} {kind} {alg:?}: batched stats must add up identically"
                );
            }
        }
    }
}

#[test]
fn autotune_counts_is_stable_under_the_series_path() {
    // The candidate-major autotune sweep rides run_series; its winners
    // must match per-count autotune on a grid with repeated counts.
    let c = coll(PersonaName::OpenMpi);
    let counts = [64u64, 100_000, 64];
    let op = OpKind::Scatter.op(1);
    let cands = c.default_candidates(op);
    let winners = c.autotune_counts(op, &counts, &cands).unwrap();
    assert_eq!(winners.len(), counts.len());
    for (w, &count) in winners.iter().zip(&counts) {
        assert_eq!(w.c, count);
        let (alg, m) = c.autotune(op.with_count(count), &cands).unwrap();
        assert_eq!((w.alg.name(), w.alg.k()), (alg.name(), alg.k()), "c={count}");
        assert_eq!(w.measurement.summary, m.summary, "c={count}");
    }
    // Repeated count, same candidate set: identical winner bitwise.
    assert_eq!(winners[0].measurement.summary, winners[2].measurement.summary);
    assert_eq!(winners[0].alg.name(), winners[2].alg.name());
}

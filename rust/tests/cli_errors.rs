//! CLI error-path contract: invalid (op, algorithm) combinations must
//! exit 1 with a clean registry-driven message — no panics — and newly
//! registered algorithms must be reachable through `--alg` with no CLI
//! edits (the two-phase k-lane variant is the canary).

use std::process::{Command, Output};

fn mlane(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_mlane"))
        .args(args)
        .env("MLANE_REPS", "2")
        .output()
        .expect("spawn mlane")
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn unsupported_op_alg_pair_exits_cleanly() {
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "bruck", "--nodes", "2", "--cores", "2",
    ]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(
        err.contains("error: bruck does not support bcast; supported:"),
        "stderr: {err}"
    );
    // The supported list is registry-driven and includes the
    // registered-only two-phase variant.
    assert!(err.contains("klane2p"), "stderr: {err}");
    assert!(!err.contains("panicked"), "panic leaked to the user: {err}");
}

#[test]
fn more_unsupported_pairs_never_panic() {
    for (op, alg) in
        [("scatter", "bruck"), ("gather", "bruck"), ("bcast", "ring"), ("allgather", "kported")]
    {
        let out =
            mlane(&["run", "--op", op, "--alg", alg, "--nodes", "2", "--cores", "2"]);
        assert_eq!(out.status.code(), Some(1), "{op}/{alg}: {}", stderr(&out));
        let err = stderr(&out);
        assert!(err.contains("does not support"), "{op}/{alg}: {err}");
        assert!(!err.contains("panicked"), "{op}/{alg} panicked: {err}");
    }
}

#[test]
fn unknown_algorithm_lists_the_catalog() {
    let out = mlane(&["run", "--op", "bcast", "--alg", "nosuch"]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr(&out);
    assert!(err.contains("unknown algorithm nosuch; known:"), "{err}");
    assert!(err.contains("kported") && err.contains("klane2p"), "{err}");
}

#[test]
fn invalid_k_is_a_clean_error() {
    // k = 0 is rejected at resolve time; k > cores at build time.
    let out = mlane(&["run", "--op", "bcast", "--alg", "kported", "--k", "0"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("k = 0 is invalid"), "{}", stderr(&out));

    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "klane", "--k", "9", "--nodes", "2", "--cores",
        "4",
    ]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr(&out);
    assert!(err.contains("k = 9 is invalid"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn two_phase_klane_reachable_from_cli() {
    // Registered purely through the catalog, runnable with no main.rs
    // edits.
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "klane2p", "--k", "2", "--nodes", "2",
        "--cores", "4", "--c", "64",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("bcast/k-lane-2phase"), "stdout: {}", stdout(&out));
}

#[test]
fn help_and_algs_are_registry_driven() {
    let help = mlane(&["help"]);
    assert_eq!(help.status.code(), Some(0));
    let text = stdout(&help);
    // Doc-drift guards: all five ops, the trace command, the catalog.
    for needle in ["gather", "allgather", "trace", "klane2p", "all 48 tables (2..49)"] {
        assert!(text.contains(needle), "help missing {needle:?}: {text}");
    }

    let algs = mlane(&["algs"]);
    assert_eq!(algs.status.code(), Some(0));
    assert!(stdout(&algs).contains("klane2p"), "{}", stdout(&algs));
}

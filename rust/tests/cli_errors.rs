//! CLI error-path contract: invalid (op, algorithm) combinations must
//! exit 1 with a clean registry-driven message — no panics — and newly
//! registered algorithms must be reachable through `--alg` with no CLI
//! edits (the two-phase k-lane variant is the canary).

use std::process::{Command, Output};

fn mlane(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_mlane"))
        .args(args)
        .env("MLANE_REPS", "2")
        .output()
        .expect("spawn mlane")
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn unsupported_op_alg_pair_exits_cleanly() {
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "bruck", "--nodes", "2", "--cores", "2",
    ]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(
        err.contains("error: bruck does not support bcast; supported:"),
        "stderr: {err}"
    );
    // The supported list is registry-driven and includes the
    // registered-only two-phase variant.
    assert!(err.contains("klane2p"), "stderr: {err}");
    assert!(!err.contains("panicked"), "panic leaked to the user: {err}");
}

#[test]
fn more_unsupported_pairs_never_panic() {
    for (op, alg) in
        [("scatter", "bruck"), ("gather", "bruck"), ("bcast", "ring"), ("allgather", "kported")]
    {
        let out =
            mlane(&["run", "--op", op, "--alg", alg, "--nodes", "2", "--cores", "2"]);
        assert_eq!(out.status.code(), Some(1), "{op}/{alg}: {}", stderr(&out));
        let err = stderr(&out);
        assert!(err.contains("does not support"), "{op}/{alg}: {err}");
        assert!(!err.contains("panicked"), "{op}/{alg} panicked: {err}");
    }
}

#[test]
fn unknown_algorithm_lists_the_catalog() {
    let out = mlane(&["run", "--op", "bcast", "--alg", "nosuch"]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr(&out);
    assert!(err.contains("unknown algorithm nosuch; known:"), "{err}");
    assert!(err.contains("kported") && err.contains("klane2p"), "{err}");
}

#[test]
fn invalid_k_is_a_clean_error() {
    // k = 0 is rejected at resolve time; k > cores at build time.
    let out = mlane(&["run", "--op", "bcast", "--alg", "kported", "--k", "0"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("k = 0 is invalid"), "{}", stderr(&out));

    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "klane", "--k", "9", "--nodes", "2", "--cores",
        "4",
    ]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr(&out);
    assert!(err.contains("k = 9 is invalid"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn two_phase_klane_reachable_from_cli() {
    // Registered purely through the catalog, runnable with no main.rs
    // edits.
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "klane2p", "--k", "2", "--nodes", "2",
        "--cores", "4", "--c", "64",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("bcast/k-lane-2phase"), "stdout: {}", stdout(&out));
}

#[test]
fn help_and_algs_are_registry_driven() {
    let help = mlane(&["help"]);
    assert_eq!(help.status.code(), Some(0));
    let text = stdout(&help);
    // Doc-drift guards: all five ops, the trace command, the catalog,
    // the sweep command and its presets.
    for needle in [
        "gather",
        "allgather",
        "trace",
        "klane2p",
        "all 48 tables (2..49)",
        "sweep",
        "appendix",
        "tune",
        "decision tables",
        "tuned",
        "lint",
        "--eager-limit",
        "--max-per-lint",
        "certify",
        "--max-count",
        "crossovers",
        "serve",
        "zero-alloc",
        "--once",
    ] {
        assert!(text.contains(needle), "help missing {needle:?}: {text}");
    }

    let algs = mlane(&["algs"]);
    assert_eq!(algs.status.code(), Some(0));
    assert!(stdout(&algs).contains("klane2p"), "{}", stdout(&algs));
    assert!(stdout(&algs).contains("tuned"), "{}", stdout(&algs));
}

#[test]
fn lint_smoke_full_registry_exits_clean() {
    // The static-analysis acceptance path through a real process: the
    // whole registry on a small cluster lints with zero error-severity
    // diagnostics and a summary line; JSON is the same data, strict.
    let out = mlane(&["lint", "--nodes", "2", "--cores", "2", "--lanes", "2"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("linted "), "no summary line: {s}");
    assert!(s.contains(" 0 error(s)"), "errors on a clean registry: {s}");

    let out = mlane(&[
        "lint", "--nodes", "2", "--cores", "2", "--lanes", "2", "--format", "json",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.trim_start().starts_with('{'), "{s}");
    assert!(s.contains("\"schedules\": "), "{s}");
    assert!(s.contains("\"errors\": 0"), "{s}");

    // Rendezvous modeling on the tree ops (the CI configuration): no
    // cycles in any registered tree schedule.
    let out = mlane(&[
        "lint", "--nodes", "2", "--cores", "2", "--lanes", "2", "--op",
        "bcast,scatter,gather", "--eager-limit", "8192",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
}

#[test]
fn lint_truncated_info_notices_never_flip_exit() {
    // Regression guard on the exit-code contract: only error-severity
    // findings flip `lint` (and `certify`) to exit 1. A lanes-starved
    // alltoall floods lane-contention warnings; with --max-per-lint 1
    // everything past the first is dropped and surfaced as
    // info-severity `truncated` notices — warnings and notices alike
    // must leave the exit at 0.
    let fixture = [
        "lint", "--nodes", "2", "--cores", "4", "--lanes", "1", "--alg", "kported:4",
        "--op", "alltoall", "--max-per-lint", "1",
    ];
    let out = mlane(&fixture);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("[truncated]"), "no truncation notice in: {s}");
    assert!(s.contains(" 0 error(s)"), "{s}");

    // Same through JSON: the notices really carry info severity.
    let mut json_args = fixture.to_vec();
    json_args.extend_from_slice(&["--format", "json"]);
    let out = mlane(&json_args);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("\"code\":\"truncated\""), "{s}");
    assert!(s.contains("\"severity\":\"info\",\"code\":\"truncated\""), "{s}");
}

#[test]
fn lint_counts_series_replays_one_arena() {
    // --counts on a cache-id algorithm takes the series path (one build,
    // one flow replay across the whole list); the report must still be
    // one entry per count, in order.
    let out = mlane(&[
        "lint", "--nodes", "2", "--cores", "4", "--lanes", "2", "--alg", "kported:2",
        "--op", "bcast", "--counts", "1,64,4096", "--format", "json",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("\"schedules\": 3"), "{s}");
    for needle in ["\"count\":1,", "\"count\":64,", "\"count\":4096,"] {
        assert!(s.contains(needle), "series entry missing {needle}: {s}");
    }

    // A count whose byte sizes overflow u64 is a clean error, not a
    // wrapped size or a panic.
    let out = mlane(&[
        "lint", "--nodes", "2", "--cores", "4", "--lanes", "2", "--alg", "kported:2",
        "--op", "bcast", "--counts", "1,18446744073709551615",
    ]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("overflows byte sizes"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn lint_flag_errors_are_clean() {
    let out = mlane(&["lint", "--nodes", "2", "--cores", "2", "--format", "nosuch"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("unknown format nosuch"), "{}", stderr(&out));

    let out = mlane(&["lint", "--nodes", "2", "--cores", "2", "--eager-limit", "soon"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("bad --eager-limit value"), "{}", stderr(&out));

    // lint takes grid flags, not run flags; typos are rejected loudly.
    let out = mlane(&["lint", "--reps", "3"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("unknown flag --reps"), "{}", stderr(&out));

    // An op/alg narrowing with an empty intersection is an error, not a
    // vacuously green lint.
    let out = mlane(&[
        "lint", "--nodes", "2", "--cores", "2", "--op", "bcast", "--alg", "ring",
    ]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("nothing to lint"), "{}", stderr(&out));
}

#[test]
fn certify_smoke_full_registry_exits_clean() {
    // The certification acceptance path through a real process: the
    // whole registry on a small cluster certifies every count in
    // [1, max] with zero error-severity intervals.
    let out = mlane(&["certify", "--nodes", "2", "--cores", "2", "--lanes", "2"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("certified "), "no summary line: {s}");
    assert!(s.contains(" 0 error(s)"), "errors on a clean registry: {s}");
    assert!(s.contains("[fingerprint "), "no fingerprint: {s}");

    // JSON is the machine-readable certificate set: strict, with the
    // spec fingerprint and per-interval verdicts.
    let out = mlane(&[
        "certify", "--nodes", "2", "--cores", "2", "--lanes", "2", "--format", "json",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.trim_start().starts_with('{'), "{s}");
    assert!(s.contains("\"fingerprint\": \""), "{s}");
    assert!(s.contains("\"certificates\": ["), "{s}");
    assert!(s.contains("\"intervals\":["), "{s}");
    assert!(s.contains("\"crossovers\":["), "{s}");
    assert!(s.contains("\"errors\": 0"), "{s}");

    // --max-count bounds the domain (and changes the fingerprint, but
    // the verdicts must stay clean).
    let out = mlane(&[
        "certify", "--nodes", "2", "--cores", "2", "--lanes", "2", "--alg", "kported:2",
        "--op", "bcast", "--max-count", "1024",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("[1, 1024]"), "{}", stdout(&out));
}

#[test]
fn certify_flag_errors_are_clean() {
    let out = mlane(&["certify", "--nodes", "2", "--cores", "2", "--format", "nosuch"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("unknown format nosuch"), "{}", stderr(&out));

    let out = mlane(&["certify", "--nodes", "2", "--cores", "2", "--max-count", "0"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("bad --max-count value"), "{}", stderr(&out));

    // certify is a symbolic sweep over *all* counts: --counts is a lint
    // flag and must be rejected, not silently ignored.
    let out = mlane(&["certify", "--nodes", "2", "--cores", "2", "--counts", "1,64"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("unknown flag --counts"), "{}", stderr(&out));

    // An op/alg narrowing with an empty intersection is an error, not a
    // vacuously green certificate set.
    let out = mlane(&[
        "certify", "--nodes", "2", "--cores", "2", "--op", "bcast", "--alg", "ring",
    ]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("nothing to certify"), "{}", stderr(&out));
}

#[test]
fn sweep_broken_spec_exits_one_with_the_typed_error() {
    // bruck does not implement bcast: the grid builds, the plan run
    // fails — exit 1 with the PlanError naming table + section and the
    // underlying AlgError, no panic.
    let out = mlane(&[
        "sweep", "--nodes", "2", "--cores", "2", "--op", "bcast", "--alg", "bruck:2",
        "--counts", "1",
    ]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("table 1, section "), "stderr: {err}");
    assert!(err.contains("bruck does not support bcast; supported:"), "stderr: {err}");
    assert!(!err.contains("panicked"), "panic leaked to the user: {err}");
}

#[test]
fn sweep_unknown_alg_and_preset_are_clean_errors() {
    let out = mlane(&["sweep", "--alg", "nosuch", "--counts", "1"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("unknown algorithm nosuch"), "{}", stderr(&out));

    let out = mlane(&["sweep", "--preset", "nosuch"]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr(&out);
    assert!(err.contains("unknown preset nosuch"), "{err}");
    assert!(err.contains("appendix"), "{err}");

    // A preset IS the grid: combining it with grid flags is an error,
    // not a silent ignore.
    let out = mlane(&["sweep", "--preset", "appendix", "--counts", "1,64"]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr(&out);
    assert!(err.contains("--preset defines the whole grid"), "{err}");
    assert!(err.contains("drop --counts"), "{err}");
}

#[test]
fn misspelled_flags_are_rejected_not_ignored() {
    // A typo like --count (vs --counts) must not silently fall back to
    // the full default grid on a Hydra-scale cluster.
    let out = mlane(&["sweep", "--count", "1,64", "--alg", "klane:2"]);
    assert_eq!(out.status.code(), Some(1), "stdout: {}", stdout(&out));
    let err = stderr(&out);
    assert!(err.contains("unknown flag --count"), "{err}");
    assert!(err.contains("--counts"), "should list the valid flags: {err}");

    let out = mlane(&["run", "--op", "bcast", "--algs", "klane"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("unknown flag --algs"), "{}", stderr(&out));

    // Degenerate comma lists are an error, never a silent empty plan.
    let out = mlane(&["sweep", "--alg", "klane:2", "--counts", ","]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("--counts needs at least one value"), "{}", stderr(&out));
}

#[test]
fn sweep_emits_valid_json_for_a_user_grid() {
    // A tiny user-defined grid through the plan API; klane2p is in the
    // grid purely via the registry (scenario growth without CLI edits).
    let out = mlane(&[
        "sweep", "--nodes", "2", "--cores", "4", "--lanes", "2", "--op", "bcast",
        "--alg", "klane:2,klane2p:2", "--counts", "1,64", "--format", "json",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.trim_start().starts_with('['), "{s}");
    assert!(s.trim_end().ends_with(']'), "{s}");
    assert!(s.contains("\"alg\":\"klane2p\""), "{s}");
    assert!(s.contains("\"counts\":[1,64]"), "{s}");
    assert!(s.contains("\"rows\":["), "{s}");
}

#[test]
fn tune_preset_conflicts_and_unknowns_are_clean_errors() {
    // A preset IS the grid, for tune exactly as for sweep.
    let out = mlane(&["tune", "--preset", "appendix", "--counts", "1,64"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("--preset defines the whole grid"), "{err}");
    assert!(err.contains("drop --counts"), "{err}");
    assert!(!err.contains("panicked"), "{err}");

    let out = mlane(&["tune", "--preset", "nosuch"]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr(&out);
    assert!(err.contains("unknown preset nosuch"), "{err}");
    assert!(err.contains("tuned"), "should list the tuned preset: {err}");

    let out = mlane(&[
        "tune", "--persona", "nosuch", "--op", "bcast", "--counts", "1", "--nodes", "2",
        "--cores", "2",
    ]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("unknown persona nosuch"), "{}", stderr(&out));
}

#[test]
fn tune_empty_candidate_set_is_a_typed_error() {
    // ring implements only allgather: tuning bcast over it leaves zero
    // candidates — a typed message, not a panic or an empty table.
    let out = mlane(&[
        "tune", "--op", "bcast", "--alg", "ring", "--counts", "1,64", "--nodes", "2",
        "--cores", "2", "--reps", "1",
    ]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("no tuning candidates support bcast"), "{err}");
    assert!(err.contains("kported"), "should list registry supporters: {err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn tune_unwritable_out_is_a_clean_error() {
    let out = mlane(&[
        "tune", "--op", "bcast", "--counts", "1", "--nodes", "2", "--cores", "2",
        "--reps", "1", "--out", "/nonexistent-mlane-dir/sub/tables.json",
    ]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("write decision tables"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn tune_emits_a_decision_table_and_tuned_runs_from_it() {
    // The acceptance path end to end: `mlane tune --op bcast` writes a
    // JSON decision-table book; `mlane run --alg tuned --table FILE`
    // dispatches from it.
    let path = std::env::temp_dir().join("mlane_cli_tune_book.json");
    let path = path.to_str().unwrap();
    let out = mlane(&[
        "tune", "--op", "bcast", "--nodes", "2", "--cores", "4", "--lanes", "2",
        "--counts", "1,64,6000,600000", "--reps", "2", "--format", "json", "--out", path,
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.starts_with("{\"version\":1,"), "{s}");
    assert!(s.contains("\"tables\":["), "{s}");
    assert!(s.contains("\"op\":\"bcast\""), "{s}");
    assert!(s.contains("\"entries\":[{\"from\":1,"), "{s}");

    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "tuned", "--nodes", "2", "--cores", "4",
        "--lanes", "2", "--c", "64", "--table", path,
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    // The dispatched (concrete) schedule ran, not a meta artifact.
    assert!(stdout(&out).contains("bcast "), "stdout: {}", stdout(&out));

    // A book that does not cover the requested scenario must be an
    // error, not a silent fall-back to an auto-built table.
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "tuned", "--nodes", "3", "--cores", "4",
        "--lanes", "2", "--c", "64", "--table", path,
    ]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("no decision table for bcast on 3x4"), "{err}");
    assert!(err.contains("tables cover:"), "{err}");
    assert!(!err.contains("panicked"), "{err}");

    // A corrupt artifact is a typed load error.
    let bad = std::env::temp_dir().join("mlane_cli_tune_bad.json");
    std::fs::write(&bad, "{\"version\":1").unwrap();
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "tuned", "--table", bad.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(1));
    assert!(!stderr(&out).contains("panicked"), "{}", stderr(&out));
}

#[test]
fn tuned_reachable_from_cli_without_a_table_file() {
    // Auto-built decision tables: `--alg tuned` needs no artifact.
    let out = mlane(&[
        "run", "--op", "scatter", "--alg", "tuned", "--nodes", "2", "--cores", "4",
        "--c", "16",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("scatter "), "stdout: {}", stdout(&out));

    // And the tuned sweep preset resolves and lists (not run: Hydra).
    let out = mlane(&["sweep", "--preset", "tuned", "--list"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("table 53"), "{s}");
    assert!(s.contains("tuned selection"), "{s}");
    assert!(s.contains("MPI_Bcast"), "{s}");
}

#[test]
fn shard_flag_validation_is_clean() {
    let grid = ["--nodes", "2", "--cores", "4", "--op", "bcast", "--alg", "klane:2",
        "--counts", "1"];
    let with = |extra: &[&str]| {
        let mut args = vec!["sweep"];
        args.extend_from_slice(&grid);
        args.extend_from_slice(extra);
        mlane(&args)
    };

    // Half a shard spec is an error, not a silent full run.
    let out = with(&["--shards", "2"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("needs --shard-index"), "{}", stderr(&out));
    let out = with(&["--shard-index", "0"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("needs --shards"), "{}", stderr(&out));

    // Out-of-range / zero shard counts.
    let out = with(&["--shards", "2", "--shard-index", "2"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("out of range"), "{}", stderr(&out));
    let out = with(&["--shards", "0", "--shard-index", "0"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("bad --shards"), "{}", stderr(&out));

    // A shard run emits an artifact; --format belongs to merge.
    let out = with(&["--shards", "2", "--shard-index", "0", "--format", "json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("shard artifact"), "{}", stderr(&out));

    // merge usage and a missing directory are clean errors.
    let out = mlane(&["merge"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("usage: mlane merge"), "{}", stderr(&out));
    let out = mlane(&["merge", "/tmp/mlane_nope.txt", "/nonexistent-mlane-shards"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(!stderr(&out).contains("panicked"), "{}", stderr(&out));
}

#[test]
fn cli_shard_merge_round_trip_is_byte_identical() {
    // The acceptance criterion end to end through real processes: a
    // 2-shard `mlane sweep` merged back equals the single-process
    // report byte for byte, for both the text and json sinks.
    let grid = ["--nodes", "2", "--cores", "4", "--lanes", "2", "--op", "bcast",
        "--alg", "klane:2,native", "--counts", "1,600"];
    let dir = std::env::temp_dir().join("mlane_cli_shard_merge");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let single = {
        let mut args = vec!["sweep"];
        args.extend_from_slice(&grid);
        let out = mlane(&args);
        assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
        stdout(&out)
    };
    let single_json = {
        let mut args = vec!["sweep"];
        args.extend_from_slice(&grid);
        args.extend_from_slice(&["--format", "json"]);
        stdout(&mlane(&args))
    };

    let shard_dir = dir.join("shards");
    std::fs::create_dir_all(&shard_dir).unwrap();
    for i in 0..2 {
        let path = shard_dir.join(format!("shard_{i}.json"));
        let idx = i.to_string();
        let mut args = vec!["sweep"];
        args.extend_from_slice(&grid);
        args.extend_from_slice(&[
            "--shards", "2", "--shard-index", idx.as_str(), "--out",
            path.to_str().unwrap(),
        ]);
        let out = mlane(&args);
        assert_eq!(out.status.code(), Some(0), "shard {i} stderr: {}", stderr(&out));
        let artifact = std::fs::read_to_string(&path).unwrap();
        assert!(artifact.contains("\"kind\":\"plan-shard\""), "{artifact}");
        assert!(artifact.contains("\"fingerprint\":"), "{artifact}");
    }

    let merged_txt = dir.join("merged.txt");
    let out = mlane(&["merge", merged_txt.to_str().unwrap(), shard_dir.to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert_eq!(std::fs::read_to_string(&merged_txt).unwrap(), single, "text diverged");

    let merged_json = dir.join("merged.json");
    let out = mlane(&[
        "merge", merged_json.to_str().unwrap(), shard_dir.to_str().unwrap(),
        "--format", "json",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert_eq!(std::fs::read_to_string(&merged_json).unwrap(), single_json, "json diverged");

    // An incomplete shard set must refuse to merge, exit 1.
    std::fs::remove_file(shard_dir.join("shard_1.json")).unwrap();
    let out = mlane(&["merge", merged_txt.to_str().unwrap(), shard_dir.to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("missing shard"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn cli_tune_shards_merge_into_the_single_book() {
    let grid = ["--nodes", "2", "--cores", "4", "--lanes", "2", "--op",
        "bcast,scatter", "--counts", "1,64", "--reps", "1"];
    let dir = std::env::temp_dir().join("mlane_cli_tune_shard");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let single = {
        let mut args = vec!["tune"];
        args.extend_from_slice(&grid);
        args.extend_from_slice(&["--format", "json"]);
        let out = mlane(&args);
        assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
        stdout(&out)
    };

    let shard_dir = dir.join("shards");
    std::fs::create_dir_all(&shard_dir).unwrap();
    for i in 0..2 {
        let path = shard_dir.join(format!("tune_{i}.json"));
        let idx = i.to_string();
        let mut args = vec!["tune"];
        args.extend_from_slice(&grid);
        args.extend_from_slice(&[
            "--shards", "2", "--shard-index", idx.as_str(), "--out",
            path.to_str().unwrap(),
        ]);
        let out = mlane(&args);
        assert_eq!(out.status.code(), Some(0), "shard {i} stderr: {}", stderr(&out));
        assert!(
            std::fs::read_to_string(&path).unwrap().contains("\"kind\":\"tune-shard\""),
            "not a tune-shard artifact"
        );
    }

    let merged = dir.join("book.json");
    let out = mlane(&["merge", merged.to_str().unwrap(), shard_dir.to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert_eq!(std::fs::read_to_string(&merged).unwrap(), single, "book diverged");

    // The merged artifact is a loadable decision-table book.
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "tuned", "--nodes", "2", "--cores", "4",
        "--lanes", "2", "--c", "64", "--table", merged.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
}

#[test]
fn event_backend_runs_and_traces_from_the_cli() {
    // The event backend is a first-class `--backend` value on run …
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "klane", "--k", "2", "--nodes", "2", "--cores",
        "4", "--c", "64", "--backend", "event",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("avg="), "stdout: {}", stdout(&out));

    // … including with contention knobs …
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "klane", "--k", "2", "--nodes", "2", "--cores",
        "4", "--c", "64", "--backend", "event", "--tenants", "2", "--stragglers", "1",
        "--straggler-factor", "1.5",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("avg="), "stdout: {}", stdout(&out));

    // … and for uncacheable personas (native quirks bypass the cache).
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "native", "--nodes", "2", "--cores", "4",
        "--c", "64", "--backend", "event", "--persona", "intelmpi",
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("avg="), "stdout: {}", stdout(&out));

    // trace --backend event emits the per-event chrome trace.
    let trace = std::env::temp_dir().join("mlane_cli_event_trace.json");
    let out = mlane(&[
        "trace", "--op", "bcast", "--alg", "klane", "--k", "2", "--nodes", "2",
        "--cores", "4", "--c", "64", "--backend", "event", "--out",
        trace.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("wrote "), "{s}");
    assert!(s.contains(" events,"), "{s}");
    let json = std::fs::read_to_string(&trace).unwrap();
    assert!(json.contains("\"ph\":\"i\""), "no instant events in {json}");
    assert!(json.contains("\"depth\":"), "no queue depth in {json}");
}

#[test]
fn event_backend_errors_are_typed_and_clean() {
    // Unknown backend still lists cleanly.
    let out = mlane(&["run", "--op", "bcast", "--alg", "klane", "--backend", "nosuch"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("unknown backend"), "{}", stderr(&out));

    // Scenario knobs without the event backend: refused, not ignored.
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "klane", "--nodes", "2", "--cores", "4",
        "--tenants", "2",
    ]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr(&out);
    assert!(err.contains("--tenants applies to the event backend"), "{err}");
    assert!(err.contains("add --backend event"), "{err}");

    // Invalid scenario values fail at the CLI edge.
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "klane", "--nodes", "2", "--cores", "4",
        "--backend", "event", "--straggler-factor", "0.5",
    ]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr(&out);
    assert!(err.contains("invalid scenario"), "{err}");
    assert!(!err.contains("panicked"), "{err}");

    // Drop-tail overflow is a typed exit-1 NetError, not a panic: a
    // zero-capacity queue cannot hold an alltoall fan-in.
    let out = mlane(&[
        "run", "--op", "alltoall", "--alg", "fulllane", "--nodes", "3", "--cores", "4",
        "--c", "1000", "--backend", "event", "--queue-capacity", "0",
    ]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("queue overflow"), "{err}");
    assert!(!err.contains("panicked"), "{err}");

    // Tenants need off-node links: a single-node cluster is unsupported.
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "fulllane", "--nodes", "1", "--cores", "4",
        "--c", "64", "--backend", "event", "--tenants", "2",
    ]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("does not support"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn contention_preset_and_backend_help_are_wired() {
    // The contention preset resolves and lists without running (Hydra).
    let out = mlane(&["sweep", "--preset", "contention", "--list"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("table 56"), "{s}");
    assert!(s.contains("contention"), "{s}");

    let help = mlane(&["help"]);
    let text = stdout(&help);
    for needle in ["--backend", "contention", "--tenants", "--straggler-factor", "--queue-capacity"]
    {
        assert!(text.contains(needle), "help missing {needle:?}: {text}");
    }
}

/// Spawn `mlane` with `input` piped to stdin (the `serve` transport).
/// Dropping the pipe after the write is the EOF that ends `--once`.
fn mlane_piped(args: &[&str], input: &str) -> Output {
    use std::io::Write as _;
    use std::process::Stdio;
    let mut child = Command::new(env!("CARGO_BIN_EXE_mlane"))
        .args(args)
        .env("MLANE_REPS", "2")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn mlane");
    child
        .stdin
        .take()
        .expect("piped stdin")
        .write_all(input.as_bytes())
        .expect("write stdin");
    child.wait_with_output().expect("wait mlane")
}

#[test]
fn serve_flag_and_book_errors_are_clean() {
    let out = mlane(&["serve"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("serve needs --book"), "{}", stderr(&out));

    // A missing book file is a typed load error, not a panic.
    let out = mlane(&["serve", "--book", "/nonexistent-mlane/book.json"]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr(&out);
    assert!(err.contains("serve book:"), "{err}");
    assert!(!err.contains("panicked"), "{err}");

    // So is a corrupt one.
    let bad = std::env::temp_dir().join("mlane_cli_serve_bad.json");
    std::fs::write(&bad, "{\"version\":1").unwrap();
    let out = mlane(&["serve", "--book", bad.to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(1));
    assert!(!stderr(&out).contains("panicked"), "{}", stderr(&out));

    let out = mlane(&["serve", "--book", "x", "--nope", "1"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("unknown flag --nope"), "{}", stderr(&out));

    // --once is a drain-and-exit batch: daemon-only flags conflict, and
    // the conflict is caught before any book i/o.
    let out = mlane(&[
        "serve", "--book", "/nonexistent-mlane/book.json", "--once", "--socket",
        "/tmp/mlane_conflict.sock",
    ]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("drop --socket"), "{}", stderr(&out));
}

#[test]
fn serve_duplicate_table_book_is_a_typed_error() {
    // Two tables covering the same (cluster, op, persona): before the
    // duplicate check, dispatch silently depended on table order.
    let table = concat!(
        "{\"op\":\"bcast\",\"persona\":\"openmpi\",\"nodes\":2,\"cores\":4,",
        "\"lanes\":2,\"entries\":[{\"from\":1,\"alg\":\"kported\",\"k\":2,",
        "\"avg_us\":1}]}"
    );
    let book = format!(
        "{{\"version\":1,\"tune\":{{\"reps\":1,\"warmup\":0,\"seed\":1}},\
         \"tables\":[{table},{table}]}}"
    );
    let path = std::env::temp_dir().join("mlane_cli_serve_dup.json");
    std::fs::write(&path, book).unwrap();
    let out = mlane(&["serve", "--book", path.to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("duplicate table"), "{err}");
    assert!(!err.contains("panicked"), "{err}");

    // The same book through `run --alg tuned --table` — the dispatch
    // path rejects it at install, same typed error.
    let out = mlane(&[
        "run", "--op", "bcast", "--alg", "tuned", "--nodes", "2", "--cores", "4",
        "--lanes", "2", "--c", "64", "--table", path.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("duplicate table"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn serve_once_answers_batches_and_survives_garbage() {
    // End to end through real processes: tune a book, serve it --once,
    // mix well-formed queries, garbage, a batch and a stats command on
    // one stdin; every line gets a response and the exit is clean.
    let path = std::env::temp_dir().join("mlane_cli_serve_book.json");
    let path = path.to_str().unwrap();
    let out = mlane(&[
        "tune", "--op", "bcast", "--nodes", "2", "--cores", "4", "--lanes", "2",
        "--counts", "1,600", "--reps", "1", "--format", "json", "--out", path,
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));

    let good = concat!(
        "{\"op\":\"bcast\",\"persona\":\"openmpi\",\"nodes\":2,\"cores\":4,",
        "\"lanes\":2,\"count\":600}"
    );
    let input = format!("{good}\ngarbage\n{{\"batch\":[{good},{good}]}}\n{{\"cmd\":\"stats\"}}\n");
    let out = mlane_piped(&["serve", "--book", path, "--once"], &input);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    let lines: Vec<&str> = s.lines().collect();
    assert_eq!(lines.len(), 4, "one response per request line: {s}");
    assert!(lines[0].starts_with("{\"ok\":true,\"op\":\"bcast\""), "{s}");
    assert!(lines[1].starts_with("{\"ok\":false,\"error\":\"bad request"), "{s}");
    assert!(lines[2].starts_with("{\"ok\":true,\"answers\":[{\"ok\":true"), "{s}");
    assert!(lines[3].contains("\"queries\":3"), "{s}");
    assert!(lines[3].contains("\"errors\":1"), "{s}");
    // The --once summary lands on stderr, never polluting the protocol.
    assert!(
        stderr(&out).contains("served 3 queries (1 errors, 0 reloads)"),
        "stderr: {}",
        stderr(&out)
    );

    // quit ends the stream early: later lines are never answered.
    let input = format!("{{\"cmd\":\"quit\"}}\n{good}\n");
    let out = mlane_piped(&["serve", "--book", path, "--once"], &input);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert_eq!(stdout(&out), "{\"ok\":true,\"bye\":true}\n");
}

#[test]
fn sweep_preset_lists_and_env_is_parsed_at_the_edge() {
    // --list prints the plan without running it, so the Hydra-scale
    // appendix preset stays cheap here; MLANE_REPS=2 (set by the test
    // driver) must surface in the printed config — the env is parsed
    // once at the CLI edge into RunConfig, never inside the library.
    let out = mlane(&["sweep", "--preset", "appendix", "--list"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("reps=2"), "MLANE_REPS not mapped into RunConfig: {s}");
    assert!(s.contains("table 50"), "{s}");
    assert!(s.contains("two-phase"), "{s}");
    assert!(s.contains("klane2p"), "{s}");
}

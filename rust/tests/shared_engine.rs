//! Cross-table schedule-cache contract: `harness::run_table` calls that
//! share a `SweepEngine` must reuse cached shapes — the second run of a
//! table builds **zero** new schedules — while personas with different
//! cost models stay isolated within the same engine.
//!
//! Run parameters come from an explicit `RunConfig` (no environment
//! mutation: these tests are safe under parallel test runs).

use std::sync::Arc;

use mlane::harness::{self, run_table_with, RunConfig};
use mlane::sim::SweepEngine;
use mlane::topology::Cluster;

/// A paper table shrunk to a fast grid. Tables 8/13 (k-lane bcast
/// k=1,2,3; Open MPI / Intel MPI) are all-cacheable: no count-dependent
/// native selection.
fn small_table(number: u32) -> harness::TableSpec {
    harness::table(number).unwrap().with_grid(Cluster::new(3, 4, 2), &[1, 600])
}

#[test]
fn shared_engine_reuses_shapes_across_tables_and_isolates_personas() {
    let cfg = RunConfig::default().reps(2);
    let engine = Arc::new(SweepEngine::new());
    let t = small_table(8);

    // First run: one schedule per k-lane section.
    let first = run_table_with(&engine, &t, &cfg).unwrap();
    let built_after_first = engine.stats().schedules_built;
    assert_eq!(built_after_first, 3, "one shape per section: {:?}", engine.stats());

    // Second run of the same table/persona: served entirely from cache.
    let second = run_table_with(&engine, &t, &cfg).unwrap();
    let st = engine.stats();
    assert_eq!(
        st.schedules_built, built_after_first,
        "second table run must build no schedules: {st:?}"
    );
    assert_eq!(st.cells, 12, "{st:?}");
    assert!(st.recosts + st.cache_hits >= 6, "{st:?}");
    // Shared-cache runs are bitwise identical to the first pass.
    assert_eq!(first.text(), second.text());

    // Same sections under a different persona (= different cost model):
    // shapes must NOT be shared — timings under the wrong model would be
    // silent corruption — so the build counter grows by one per section.
    let intel = small_table(13);
    let third = run_table_with(&engine, &intel, &cfg).unwrap();
    assert_eq!(
        engine.stats().schedules_built,
        built_after_first + 3,
        "per-persona shapes: {:?}",
        engine.stats()
    );
    // And the models genuinely differ in outcome.
    assert_ne!(first.rows[0].avg, third.rows[0].avg, "personas identical?");
}

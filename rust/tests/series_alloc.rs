//! The zero-steady-state-allocation gate for the batched series path.
//!
//! The crate's global allocator (`mlane::util::allocs`) counts every
//! heap allocation made by the current thread. A warm
//! `SweepEngine::measure_series_into` pass — cached shape, reused
//! `RepState`, pre-sized output buffer, identical count trajectory —
//! must allocate nothing at all: the count grid is walked entirely
//! over the simulator's flat arrays and the caller's arena.

use mlane::algorithms::bcast::{self, BcastAlg};
use mlane::model::CostModel;
use mlane::schedule::Schedule;
use mlane::sim::{AlgId, OpShape, SweepEngine, SweepKey};
use mlane::topology::Cluster;
use mlane::util::allocs::thread_allocations;

#[test]
fn warm_series_performs_zero_allocations() {
    let cl = Cluster::new(3, 4, 2);
    let m = CostModel::hydra_baseline();
    let counts = [1u64, 7, 64, 869, 60_000, 7, 1];
    let key = SweepKey {
        cluster: cl,
        op: OpShape::Bcast { root: 0 },
        alg: AlgId { family: "klane", k: 2 },
    };
    let alg = BcastAlg::KLane { k: 2, two_phase: false };
    let build = |c| Ok::<Schedule, std::convert::Infallible>(bcast::build(cl, 0, c, alg));
    let eng = SweepEngine::new();
    let mut st = None;
    let mut out = Vec::new();

    // Cold pass: builds the shape, sizes the rep state and the output
    // buffer to their high-water marks for this trajectory.
    eng.measure_series_into(key, &counts, &m, 3, 1, 7, &mut st, &mut out, build).unwrap();
    let cold = out.clone();
    out.clear();

    // Warm pass: identical trajectory, everything reused.
    let before = thread_allocations();
    eng.measure_series_into(key, &counts, &m, 3, 1, 7, &mut st, &mut out, build).unwrap();
    let after = thread_allocations();

    assert_eq!(after - before, 0, "warm series must not touch the heap");
    assert_eq!(out.len(), counts.len());
    for (i, (a, b)) in cold.iter().zip(&out).enumerate() {
        assert_eq!(a.summary, b.summary, "cell {i} (c={})", counts[i]);
        assert_eq!(a.algorithm, b.algorithm, "cell {i}");
    }
}

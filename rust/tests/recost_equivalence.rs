//! Correctness gate for the sweep engine's caching layer: for every
//! algorithm and a grid of element counts, a recost-ed simulator
//! (schedule built at one count, then `Schedule::resize_count` +
//! `Simulator::recost`) must produce bitwise-identical `SimResult`s
//! (makespan and event count) to a fresh `Simulator::new` on a freshly
//! built schedule — and the resized schedule itself must equal the
//! fresh build structurally. The schedule-free `Simulator::recost_count`
//! path (used by `SweepEngine::measure_series`) is held to the same
//! bitwise standard in the same sweep.
//!
//! This is exactly the lane-decomposition property the cache relies on
//! (arXiv:1910.13373: structure fixed, block sizes vary); any algorithm
//! whose round structure starts depending on count will fail here and
//! must be routed through `SweepEngine::measure_uncached` instead.

use mlane::algorithms::{allgather, alltoall, bcast, gather, scatter};
use mlane::model::CostModel;
use mlane::schedule::Schedule;
use mlane::sim::Simulator;
use mlane::topology::Cluster;

/// Count grid: spans eager/rendezvous boundaries on both channels and
/// uneven block splits (869 over 4-core nodes).
const COUNTS: &[u64] = &[1, 7, 64, 869, 60_000];

/// Jitter left on so the rng stream is exercised: identical structure
/// must consume identical jitter draws in identical order.
fn model() -> CostModel {
    CostModel::hydra_baseline()
}

fn check(name: &str, build: impl Fn(u64) -> Schedule) {
    let m = model();
    let mut s = build(COUNTS[0]);
    let mut sim = Simulator::new(&s, &m);
    // The schedule-free series path (`Simulator::recost_count`, flat
    // sizing arrays) must agree bitwise with both the schedule-driven
    // recost and the fresh build.
    let mut flat = Simulator::new(&s, &m);
    let mut st = sim.new_state();
    let mut flat_st = flat.new_state();
    for &c in &COUNTS[1..] {
        s.resize_count(c);
        sim.recost(&s).expect("same structure");
        flat.recost_count(c);
        let fresh_sched = build(c);
        assert_eq!(
            s.rounds, fresh_sched.rounds,
            "{name} c={c}: resized schedule structurally diverged from fresh build"
        );
        let fresh = Simulator::new(&fresh_sched, &m);
        let mut fresh_st = fresh.new_state();
        for seed in [0u64, 1, 0xC0FFEE] {
            let a = sim.run_into(&mut st, seed);
            let f = flat.run_into(&mut flat_st, seed);
            let b = fresh.run_into(&mut fresh_st, seed);
            assert_eq!(a, b, "{name} c={c} seed={seed}: recost != fresh");
            assert_eq!(f, b, "{name} c={c} seed={seed}: recost_count != fresh");
        }
    }
}

fn clusters() -> [Cluster; 2] {
    // Power-of-two cores and an uneven 5-core layout (ring allgather,
    // remainder block splits).
    [Cluster::new(3, 4, 2), Cluster::new(2, 5, 2)]
}

#[test]
fn bcast_all_algorithms() {
    for cl in clusters() {
        for root in [0, cl.p() - 1] {
            for (label, alg) in [
                ("kported1", bcast::BcastAlg::KPorted { k: 1 }),
                ("kported2", bcast::BcastAlg::KPorted { k: 2 }),
                ("kported3", bcast::BcastAlg::KPorted { k: 3 }),
                ("klane1", bcast::BcastAlg::KLane { k: 1, two_phase: false }),
                ("klane2", bcast::BcastAlg::KLane { k: 2, two_phase: false }),
                ("klane2p", bcast::BcastAlg::KLane { k: 2, two_phase: true }),
                ("fulllane", bcast::BcastAlg::FullLane),
                ("binomial", bcast::BcastAlg::Binomial),
                ("scatter-allgather", bcast::BcastAlg::ScatterAllgather),
            ] {
                check(
                    &format!("bcast/{label} root={root} {cl:?}"),
                    |c| bcast::build(cl, root, c, alg),
                );
            }
        }
    }
}

#[test]
fn scatter_all_algorithms() {
    for cl in clusters() {
        for (label, alg) in [
            ("kported2", scatter::ScatterAlg::KPorted { k: 2 }),
            ("klane2", scatter::ScatterAlg::KLane { k: 2 }),
            ("fulllane", scatter::ScatterAlg::FullLane),
            ("binomial", scatter::ScatterAlg::Binomial),
            ("linear", scatter::ScatterAlg::Linear),
        ] {
            check(&format!("scatter/{label} {cl:?}"), |c| scatter::build(cl, 0, c, alg));
        }
    }
}

#[test]
fn gather_all_algorithms() {
    for cl in clusters() {
        for (label, alg) in [
            ("kported2", gather::GatherAlg::KPorted { k: 2 }),
            ("klane2", gather::GatherAlg::KLane { k: 2 }),
            ("fulllane", gather::GatherAlg::FullLane),
            ("binomial", gather::GatherAlg::Binomial),
            ("linear", gather::GatherAlg::Linear),
        ] {
            check(&format!("gather/{label} {cl:?}"), |c| gather::build(cl, 0, c, alg));
        }
    }
}

#[test]
fn allgather_all_algorithms() {
    for cl in clusters() {
        for (label, alg) in [
            ("ring", allgather::AllgatherAlg::Ring),
            ("bruck1", allgather::AllgatherAlg::Bruck { k: 1 }),
            ("bruck2", allgather::AllgatherAlg::Bruck { k: 2 }),
            ("fulllane", allgather::AllgatherAlg::FullLane),
        ] {
            check(&format!("allgather/{label} {cl:?}"), |c| allgather::build(cl, c, alg));
        }
    }
    // Recursive doubling requires p = 2^m.
    for cl in [Cluster::new(4, 4, 2), Cluster::new(2, 8, 2)] {
        check(&format!("allgather/rd {cl:?}"), |c| {
            allgather::build(cl, c, allgather::AllgatherAlg::RecursiveDoubling)
        });
    }
}

#[test]
fn alltoall_all_algorithms() {
    for cl in clusters() {
        for (label, alg) in [
            ("kported1", alltoall::AlltoallAlg::KPorted { k: 1 }),
            ("kported3", alltoall::AlltoallAlg::KPorted { k: 3 }),
            ("bruck1", alltoall::AlltoallAlg::Bruck { k: 1 }),
            ("bruck2", alltoall::AlltoallAlg::Bruck { k: 2 }),
            ("klane", alltoall::AlltoallAlg::KLane),
            ("fulllane", alltoall::AlltoallAlg::FullLane),
            ("pairwise", alltoall::AlltoallAlg::Pairwise),
        ] {
            check(&format!("alltoall/{label} {cl:?}"), |c| alltoall::build(cl, c, alg));
        }
    }
}

#[test]
fn hydra_scale_spot_check() {
    // One full-size shape: the acceptance workload (Hydra k-lane bcast).
    let cl = Cluster::hydra(2);
    let m = model();
    let alg = bcast::BcastAlg::KLane { k: 2, two_phase: false };
    let mut s = bcast::build(cl, 0, 1, alg);
    let mut sim = Simulator::new(&s, &m);
    let mut st = sim.new_state();
    for c in [1_000u64, 1_000_000] {
        s.resize_count(c);
        sim.recost(&s).expect("same structure");
        let fresh = Simulator::new(&bcast::build(cl, 0, c, alg), &m);
        assert_eq!(sim.run_into(&mut st, 3), fresh.run(3), "hydra klane bcast c={c}");
    }
}

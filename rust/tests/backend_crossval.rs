//! Cross-validation between the analytic closed-form simulator and the
//! event-driven network backend, the same way `sim_exec_crossval.rs`
//! gates sim-vs-exec.
//!
//! On a contention-free scenario the event backend models the *same*
//! physics as the analytic backend — per-lane serialization, alpha/beta
//! link costs, store-and-forward with cut-through readiness — so its
//! cost must land within a small tolerance of the closed form for every
//! registry algorithm, at full Hydra scale. The residual comes from
//! queueing discipline: the event backend serves a port FIFO in event
//! order while the analytic model packs transfers earliest-free; both
//! are work-conserving, so totals agree to within a couple of
//! microseconds plus a small relative slack, never by orders of
//! magnitude.
//!
//! The second half pins determinism: same seed, same events, bitwise —
//! across repeated runs and across plan-runner thread counts.

use mlane::algorithms::registry::{registry, OpKind};
use mlane::harness::{self, Grid, Plan, RunConfig, TableSpec};
use mlane::model::{CostModel, Persona, PersonaName};
use mlane::netsim::{Backend, NetSim, Scenario};
use mlane::sim;
use mlane::topology::Cluster;

fn quiet() -> CostModel {
    let mut m = CostModel::hydra_baseline();
    m.jitter_mean = 0.0;
    m
}

/// Validation element count per operation (mirrors `mlane validate`:
/// structure and cost shape, not big-payload timing, are under test).
fn crossval_count(op: OpKind) -> u64 {
    match op {
        OpKind::Bcast => 64,
        OpKind::Scatter | OpKind::Gather => 16,
        OpKind::Allgather | OpKind::Alltoall => 8,
    }
}

#[test]
fn event_backend_matches_analytic_when_contention_free() {
    // Every registry instance x every op it supports, at the paper's
    // 36x32 Hydra scale. `tuned` is skipped: it is dispatch, not a
    // schedule, and its concrete picks are already in the instance list.
    let cl = Cluster::hydra(2);
    let persona = Persona::get(PersonaName::OpenMpi);
    let m = quiet();
    let scenario = Scenario::contention_free();
    let mut checked = 0;
    for alg in registry().validation_instances(cl) {
        if alg.name() == "tuned" {
            continue;
        }
        for kind in OpKind::ALL {
            if !alg.supports(kind) {
                continue;
            }
            let c = crossval_count(kind);
            let built = alg
                .build(cl, &persona, kind.op(c))
                .unwrap_or_else(|e| panic!("{} {kind}: {e}", alg.label()));
            let an = sim::measure(&built.schedule, &m, 1, 0, 1).avg;
            let net = NetSim::new(&built.schedule, &m, &scenario)
                .unwrap_or_else(|e| panic!("{} {kind}: {e}", alg.label()));
            let mut st = net.new_state();
            let ev = sim::measure_backend(&net, &mut st, 1, 0, 1)
                .unwrap_or_else(|e| panic!("{} {kind}: {e}", alg.label()))
                .avg;
            // Tolerance: 2us absolute (rounding + cut-through edges on
            // short chains) + 10% relative (FIFO-by-ready vs
            // earliest-free port packing on long chains). See the module
            // doc for why this is tight enough to catch a physics bug.
            assert!(
                (ev - an).abs() <= 2.0 + 0.10 * an,
                "{} {kind}: event {ev:.3}us vs analytic {an:.3}us",
                alg.label()
            );
            checked += 1;
        }
    }
    // Coverage guard: the registry currently yields dozens of
    // (instance, op) pairs; a refactor that silently empties the loop
    // must fail here, not pass vacuously.
    assert!(checked >= 20, "only {checked} (alg, op) pairs cross-validated");
}

#[test]
fn event_backend_is_bitwise_deterministic_per_seed() {
    let cl = Cluster::hydra(2);
    let persona = Persona::get(PersonaName::OpenMpi);
    let m = Persona::get(PersonaName::OpenMpi).model;
    let built = registry()
        .resolve("klane", 2)
        .unwrap()
        .build(cl, &persona, OpKind::Bcast.op(64))
        .unwrap();
    // Contended scenario: tenants + stragglers exercise the Prng and
    // every queue path, the hardest case for determinism.
    let net = NetSim::new(&built.schedule, &m, &Scenario::contended()).unwrap();
    let a = net.run(42).unwrap();
    let b = net.run(42).unwrap();
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "same seed must replay bitwise");
    assert_eq!(a.events, b.events, "same seed must process the same events");
    let other = net.run(43).unwrap();
    assert_ne!(a.makespan.to_bits(), other.makespan.to_bits(), "seeds must differ");
    // And independent state replays identically too.
    let mut st = net.new_state();
    let c = net.run_into(&mut st, 42).unwrap();
    assert_eq!(a.makespan.to_bits(), c.makespan.to_bits());
}

#[test]
fn event_backend_reports_are_byte_identical_across_thread_counts() {
    // The acceptance determinism bar at the plan level: the same event
    // sweep under 1 and 4 worker threads renders the same report, byte
    // for byte — thread scheduling must never leak into event order.
    let cl = Cluster::new(3, 4, 2);
    let sections = [
        Grid::new()
            .cluster(cl)
            .op(OpKind::Bcast)
            .algs([
                registry().resolve("klane", 2).unwrap(),
                registry().resolve("fulllane", 0).unwrap(),
            ])
            .counts(&[1, 64, 6000])
            .sections(),
        Grid::new()
            .cluster(cl)
            .op(OpKind::Alltoall)
            .algs([registry().resolve("bruck", 2).unwrap()])
            .counts(&[1, 87])
            .sections(),
    ]
    .concat();
    let mut plan = Plan::new();
    plan.tables.push(TableSpec {
        number: 1,
        caption: "event determinism".into(),
        persona: PersonaName::OpenMpi,
        sections,
    });
    let render = |threads: usize| {
        let mut cfg = RunConfig::default();
        cfg.reps = 2;
        cfg.warmup = 1;
        cfg.threads = threads;
        cfg.backend = Backend::Event(Scenario::contended());
        harness::run_plan(&plan, &cfg).unwrap().text()
    };
    let serial = render(1);
    assert_eq!(serial, render(4), "thread count leaked into event results");
    assert_eq!(serial, render(1), "repeat run diverged");
}

//! Plan/report contract tests:
//!
//! 1. **Golden rendering** — the Text and Csv sinks must be
//!    byte-identical to the pre-redesign `TableOut::render`/`write_csv`
//!    (verbatim copies of that code live below as the oracle);
//! 2. **JSON sink schema** — the emitted JSON must parse (the strict
//!    mini-parser in `tests/common`) and carry the full spec (cluster
//!    dims, op, algorithm, count series) plus one row per
//!    (section, count);
//! 3. **Plan-level determinism** — `run_plan` output is identical for
//!    `threads ∈ {1, 4}`.
//!
//! No environment mutation: all parameters flow through `RunConfig`.

use std::sync::Arc;

use mlane::harness::{self, run_plan_with, CsvSink, Plan, Report, RunConfig, TableOut, TextSink};
use mlane::sim::SweepEngine;
use mlane::topology::Cluster;

mod common;
use common::{parse_json, Json};

// ---- the pre-redesign renderer, verbatim (the golden oracle) ----------

fn legacy_render(out: &TableOut) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "Table {}: {} [{}]",
        out.spec.number,
        out.spec.caption,
        out.spec.persona.label()
    );
    let mut current = String::new();
    for r in &out.rows {
        if r.section != current {
            current = r.section.clone();
            let _ = writeln!(s, "  -- {current} --");
            let _ = writeln!(
                s,
                "  {:>2} {:>4} {:>4} {:>5} {:>9} {:>12} {:>12}",
                "k", "n", "N", "p", "c", "avg(us)", "min(us)"
            );
        }
        let _ = writeln!(
            s,
            "  {:>2} {:>4} {:>4} {:>5} {:>9} {:>12.2} {:>12.2}",
            r.k, r.n, r.nodes, r.p, r.c, r.avg, r.min
        );
    }
    s
}

fn legacy_csv(out: &TableOut) -> String {
    use std::fmt::Write as _;
    let mut s = String::from("table,persona,section,k,n,N,p,c,avg_us,min_us\n");
    for r in &out.rows {
        let _ = writeln!(
            s,
            "{},{},{},{},{},{},{},{},{:.2},{:.2}",
            out.spec.number,
            out.spec.persona.label(),
            r.section,
            r.k,
            r.n,
            r.nodes,
            r.p,
            r.c,
            r.avg,
            r.min
        );
    }
    s
}

// ---- fixtures ---------------------------------------------------------

/// Table 12 (full-lane Bcast + native MPI_Bcast — exercises both the
/// cached and the uncached engine path) shrunk to a fast grid.
fn small12() -> harness::TableSpec {
    harness::table(12).unwrap().with_grid(Cluster::new(3, 4, 2), &[1, 600, 6000])
}

/// Table 8 (k-lane bcast k=1,2,3 — three cacheable sections) shrunk.
fn small8() -> harness::TableSpec {
    harness::table(8).unwrap().with_grid(Cluster::new(3, 4, 2), &[1, 600])
}

fn cfg() -> RunConfig {
    RunConfig::default().reps(3).warmup(1)
}

fn run(plan: &Plan, cfg: &RunConfig) -> Report {
    run_plan_with(&Arc::new(SweepEngine::new()), plan, cfg).expect("paper specs are valid")
}

// ---- the tests --------------------------------------------------------

#[test]
fn text_sink_is_byte_identical_to_the_pre_redesign_renderer() {
    let plan = Plan { tables: vec![small12()] };
    let report = run(&plan, &cfg());
    let golden: String = report.tables.iter().map(legacy_render).collect();
    assert!(!golden.is_empty() && golden.contains("MPI_Bcast"), "{golden}");

    // Report::text and a streamed TextSink must both match.
    assert_eq!(report.text(), golden);
    let mut buf = Vec::new();
    report.emit(&mut TextSink::new(&mut buf)).unwrap();
    assert_eq!(String::from_utf8(buf).unwrap(), golden);
}

#[test]
fn csv_sink_is_byte_identical_to_the_pre_redesign_writer() {
    let report = run(&Plan { tables: vec![small12()] }, &cfg());
    let dir = std::env::temp_dir().join("mlane_plan_report_csv");
    let mut sink = CsvSink::new(&dir);
    report.emit(&mut sink).unwrap();
    assert_eq!(sink.written().len(), 1);
    let got = std::fs::read_to_string(&sink.written()[0]).unwrap();
    assert_eq!(got, legacy_csv(&report.tables[0]));
    assert!(sink.written()[0].ends_with("table_12.csv"), "{:?}", sink.written());
}

#[test]
fn json_sink_parses_and_carries_the_full_spec() {
    let plan = Plan { tables: vec![small8(), small12()] };
    let report = run(&plan, &cfg());
    let json = report.json();
    let doc = parse_json(&json).unwrap_or_else(|e| panic!("invalid JSON ({e}):\n{json}"));

    let tables = doc.arr();
    assert_eq!(tables.len(), 2);
    for (t, spec) in tables.iter().zip(&plan.tables) {
        assert_eq!(t.get("table").unwrap().num() as u32, spec.number);
        assert_eq!(t.get("caption").unwrap().string(), spec.caption);
        assert_eq!(t.get("persona").unwrap().string(), spec.persona.key());
        let sections = t.get("sections").unwrap().arr();
        assert_eq!(sections.len(), spec.sections.len());
        for (js, s) in sections.iter().zip(&spec.sections) {
            assert_eq!(js.get("heading").unwrap().string(), s.heading);
            assert_eq!(js.get("nodes").unwrap().num() as u32, s.cluster.nodes);
            assert_eq!(js.get("cores").unwrap().num() as u32, s.cluster.cores);
            assert_eq!(js.get("lanes").unwrap().num() as u32, s.cluster.lanes);
            assert_eq!(js.get("op").unwrap().string(), s.op.name());
            assert_eq!(js.get("alg").unwrap().string(), s.alg.name());
            let counts: Vec<u64> =
                js.get("counts").unwrap().arr().iter().map(|c| c.num() as u64).collect();
            assert_eq!(counts[..], s.counts[..]);
            match s.alg.k() {
                Some(k) => assert_eq!(js.get("k").unwrap().num() as u32, k),
                None => assert!(matches!(js.get("k").unwrap(), Json::Null)),
            }
        }
        // One row per (section, count), section order preserved.
        let rows = t.get("rows").unwrap().arr();
        let want: usize = spec.sections.iter().map(|s| s.counts.len()).sum();
        assert_eq!(rows.len(), want);
        for r in rows {
            assert!(r.get("avg_us").unwrap().num() >= r.get("min_us").unwrap().num());
            assert!(r.get("c").unwrap().num() >= 1.0);
        }
    }
}

#[test]
fn run_plan_is_deterministic_across_thread_counts() {
    let plan = Plan { tables: vec![small8(), small12()] };
    let serial = run(&plan, &cfg().threads(1));
    let parallel = run(&plan, &cfg().threads(4));
    assert_eq!(serial.text(), parallel.text(), "threads must not change output");
    assert_eq!(serial.json(), parallel.json(), "threads must not change output");
}

//! The distributed determinism contract, end to end in-library:
//! running a plan as `N` shards (each through its own engine, as
//! separate processes would) and merging the shard artifacts must
//! reproduce the single-process report **byte for byte** through every
//! sink — text, csv and json — for N ∈ {1, 3}. Sharded `mlane tune`
//! books merge byte-identically too. Broken shard sets (fingerprint
//! mismatch, missing/duplicate shards, corrupt files) fail with typed
//! `PlanError`s, never panics.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use mlane::algorithms::registry::{self, registry, OpKind};
use mlane::harness::{
    merge_dir, run_plan_with, write_shard, CsvSink, Grid, Merged, Plan, PlanError, Report,
    RunConfig,
};
use mlane::model::PersonaName;
use mlane::sim::SweepEngine;
use mlane::topology::Cluster;
use mlane::tuning::{self, Scenario, TuneConfig};

/// Two tables mixing cacheable (k-lane, full-lane) and uncacheable
/// (native — count-dependent selection plus quirks) sections, on small
/// clusters so the whole suite stays fast.
fn tiny_plan() -> Plan {
    let bcast = Grid::new()
        .cluster(Cluster::new(3, 4, 2))
        .op(OpKind::Bcast)
        .algs([registry::klane(1), registry::klane(2), registry::native()])
        .counts(&[1, 600, 6000]);
    let alltoall = Grid::new()
        .cluster(Cluster::new(2, 4, 2))
        .op(OpKind::Alltoall)
        .algs([registry::fulllane(), registry::native()])
        .counts(&[1, 64]);
    Plan::new()
        .table(3, "shard golden: bcast", PersonaName::OpenMpi, &bcast)
        .table(7, "shard golden: alltoall", PersonaName::IntelMpi, &alltoall)
}

fn cfg() -> RunConfig {
    RunConfig::default().reps(3).warmup(1).threads(2)
}

fn run(plan: &Plan, cfg: &RunConfig) -> Report {
    // A fresh engine per invocation — exactly what separate shard
    // *processes* have. Byte-identity must not depend on cache sharing.
    run_plan_with(&Arc::new(SweepEngine::new()), plan, cfg).expect("plan runs")
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn csv_bytes(report: &Report, dir: &Path) -> Vec<(String, String)> {
    let mut sink = CsvSink::new(dir);
    report.emit(&mut sink).unwrap();
    sink.written()
        .iter()
        .map(|p| {
            (
                p.file_name().unwrap().to_string_lossy().into_owned(),
                std::fs::read_to_string(p).unwrap(),
            )
        })
        .collect()
}

fn write_all_shards(dir: &Path, plan: &Plan, cfg: &RunConfig, n: u32) {
    for i in 0..n {
        let report = run(&plan.shard(n, i), cfg);
        write_shard(dir.join(format!("shard_{i}.json")), plan, cfg, n, i, &report)
            .unwrap_or_else(|e| panic!("shard {i}: {e}"));
    }
}

fn merged_report(dir: &Path) -> Report {
    match merge_dir(dir).expect("merge succeeds") {
        Merged::Report(r) => r,
        Merged::Book(_) => panic!("plan shards merged into a book"),
    }
}

// ---- the golden byte-identity test ------------------------------------

#[test]
fn merge_is_byte_identical_to_single_process_for_1_and_3_shards() {
    let plan = tiny_plan();
    let cfg = cfg();
    let single = run(&plan, &cfg);
    let golden_text = single.text();
    let golden_json = single.json();
    let golden_csv = csv_bytes(&single, &fresh_dir("mlane_shard_golden_csv_single"));
    assert!(golden_text.contains("Table 3"), "{golden_text}");
    assert!(golden_json.contains("\"alg\":\"native\""), "{golden_json}");

    for n in [1u32, 3] {
        let dir = fresh_dir(&format!("mlane_shard_golden_{n}"));
        write_all_shards(&dir, &plan, &cfg, n);
        let merged = merged_report(&dir);
        assert_eq!(merged.text(), golden_text, "text diverged at n={n}");
        assert_eq!(merged.json(), golden_json, "json diverged at n={n}");
        let merged_csv =
            csv_bytes(&merged, &fresh_dir(&format!("mlane_shard_golden_csv_{n}")));
        assert_eq!(merged_csv, golden_csv, "csv diverged at n={n}");
    }
}

#[test]
fn shard_runs_do_not_depend_on_sibling_sections() {
    // The property the merge contract stands on, pinned directly: a
    // section's rows are the same whether it runs alone or with the
    // whole plan.
    let plan = tiny_plan();
    let full = run(&plan, &cfg());
    let sub = run(&plan.shard(3, 0), &cfg());
    for table in &sub.tables {
        let counterpart = full
            .tables
            .iter()
            .find(|t| t.spec.number == table.spec.number)
            .expect("shard tables exist in the full plan");
        for row in &table.rows {
            assert!(
                counterpart.rows.iter().any(|r| {
                    r.section == row.section
                        && r.c == row.c
                        && r.avg == row.avg
                        && r.min == row.min
                }),
                "row {} c={} differs between shard and full run",
                row.section,
                row.c
            );
        }
    }
}

// ---- typed failure paths ----------------------------------------------

#[test]
fn missing_shards_are_a_typed_error() {
    let plan = tiny_plan();
    let cfg = cfg();
    let dir = fresh_dir("mlane_shard_missing");
    let report = run(&plan.shard(3, 1), &cfg);
    write_shard(dir.join("shard_1.json"), &plan, &cfg, 3, 1, &report).unwrap();
    match merge_dir(&dir) {
        Err(PlanError::ShardIncomplete { missing, shards: 3 }) => {
            assert_eq!(missing, vec![0, 2]);
        }
        other => panic!("wanted ShardIncomplete, got {other:?}"),
    }
    let msg = merge_dir(&dir).unwrap_err().to_string();
    assert!(msg.contains("missing shards 0, 2 of 3"), "{msg}");
}

#[test]
fn fingerprint_mismatch_is_a_typed_error() {
    // Same plan, different measurement config: the artifacts must
    // refuse to merge — the rows would not belong to one run.
    let plan = tiny_plan();
    let (cfg_a, cfg_b) = (cfg(), cfg().reps(5));
    let dir = fresh_dir("mlane_shard_fpmismatch");
    write_shard(dir.join("shard_0.json"), &plan, &cfg_a, 2, 0, &run(&plan.shard(2, 0), &cfg_a))
        .unwrap();
    write_shard(dir.join("shard_1.json"), &plan, &cfg_b, 2, 1, &run(&plan.shard(2, 1), &cfg_b))
        .unwrap();
    match merge_dir(&dir) {
        Err(PlanError::ShardMismatch { detail }) => {
            assert!(detail.contains("fingerprint"), "{detail}");
        }
        other => panic!("wanted ShardMismatch, got {other:?}"),
    }
}

#[test]
fn duplicate_shards_and_corrupt_files_are_typed_errors() {
    let plan = tiny_plan();
    let cfg = cfg();
    let dir = fresh_dir("mlane_shard_dup");
    let report = run(&plan.shard(2, 0), &cfg);
    write_shard(dir.join("a.json"), &plan, &cfg, 2, 0, &report).unwrap();
    write_shard(dir.join("b.json"), &plan, &cfg, 2, 0, &report).unwrap();
    match merge_dir(&dir) {
        Err(PlanError::ShardMismatch { detail }) => {
            assert!(detail.contains("shard 0 appears in both"), "{detail}");
        }
        other => panic!("wanted ShardMismatch, got {other:?}"),
    }

    let dir = fresh_dir("mlane_shard_corrupt");
    std::fs::write(dir.join("bad.json"), "{\"version\":1,").unwrap();
    assert!(
        matches!(merge_dir(&dir), Err(PlanError::ShardParse { .. })),
        "corrupt artifact must be a parse error"
    );

    let dir = fresh_dir("mlane_shard_empty");
    match merge_dir(&dir) {
        Err(PlanError::ShardIo { detail, .. }) => {
            assert!(detail.contains("no shard artifacts"), "{detail}");
        }
        other => panic!("wanted ShardIo, got {other:?}"),
    }
}

#[test]
fn truncated_shard_rows_are_a_typed_error() {
    // Hand-corrupt one artifact by dropping its last row: merge must
    // detect the incomplete count coverage, not emit a short report.
    let plan = tiny_plan();
    let cfg = cfg();
    let dir = fresh_dir("mlane_shard_truncated");
    write_all_shards(&dir, &plan, &cfg, 2);
    let victim = dir.join("shard_0.json");
    let text = std::fs::read_to_string(&victim).unwrap();
    // Remove the penultimate line (the last row object), keeping valid
    // JSON: `...},\n{last}\n]}` -> `...{last}\n]}` with the previous
    // line's trailing comma dropped.
    let without_row = {
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() > 3, "artifact unexpectedly small");
        let mut kept: Vec<String> = lines[..lines.len() - 3]
            .iter()
            .map(|s| s.to_string())
            .collect();
        kept.push(lines[lines.len() - 2].trim_end_matches(',').to_string());
        kept.push(lines[lines.len() - 1].to_string());
        kept.join("\n") + "\n"
    };
    std::fs::write(&victim, without_row).unwrap();
    match merge_dir(&dir) {
        Err(PlanError::ShardMismatch { detail }) => {
            assert!(detail.contains("merged rows cover counts"), "{detail}");
        }
        Err(PlanError::ShardParse { .. }) => {} // also acceptable: strictness caught it
        other => panic!("wanted a typed merge error, got {other:?}"),
    }
}

// ---- tune shards -------------------------------------------------------

#[test]
fn tune_shards_merge_into_the_single_process_book() {
    let cl = Cluster::new(2, 4, 2);
    let scenarios: Vec<Scenario> = [OpKind::Bcast, OpKind::Scatter, OpKind::Alltoall]
        .into_iter()
        .map(|op| Scenario {
            cluster: cl,
            op,
            persona: PersonaName::OpenMpi,
            counts: vec![1, 64, 6000],
            candidates: registry().candidates(cl, op),
        })
        .collect();
    let tcfg = TuneConfig { reps: 2, warmup: 0, seed: 11, ..TuneConfig::default() };

    let full =
        tuning::tune_all(&Arc::new(SweepEngine::new()), &scenarios, &tcfg, 2).unwrap();
    let golden = full.to_json();

    let n = 2u32;
    let dir = fresh_dir("mlane_tune_shards");
    let mut owned_total = 0usize;
    for i in 0..n {
        let indices = tuning::shard_scenarios(scenarios.len(), n, i);
        owned_total += indices.len();
        let owned: Vec<Scenario> = indices.iter().map(|&s| scenarios[s].clone()).collect();
        let book =
            tuning::tune_all(&Arc::new(SweepEngine::new()), &owned, &tcfg, 1).unwrap();
        let artifact = tuning::tune_shard_json(&scenarios, &tcfg, n, i, &indices, &book);
        std::fs::write(dir.join(format!("tune_{i}.json")), artifact).unwrap();
    }
    assert_eq!(owned_total, scenarios.len(), "tune sharding is exhaustive");

    match merge_dir(&dir).expect("tune merge succeeds") {
        Merged::Book(book) => {
            assert_eq!(book.to_json(), golden, "merged book must be byte-identical");
            assert_eq!(book, full);
        }
        Merged::Report(_) => panic!("tune shards merged into a plan report"),
    }
}

#[test]
fn mixing_plan_and_tune_shards_is_a_typed_error() {
    let plan = tiny_plan();
    let cfg = cfg();
    let dir = fresh_dir("mlane_shard_mixed");
    write_shard(dir.join("a.json"), &plan, &cfg, 1, 0, &run(&plan, &cfg)).unwrap();
    let sc = Scenario {
        cluster: Cluster::new(2, 4, 2),
        op: OpKind::Bcast,
        persona: PersonaName::OpenMpi,
        counts: vec![1, 64],
        candidates: registry().candidates(Cluster::new(2, 4, 2), OpKind::Bcast),
    };
    let tcfg = TuneConfig { reps: 1, warmup: 0, seed: 1, ..TuneConfig::default() };
    let book = tuning::tune_all(&Arc::new(SweepEngine::new()), &[sc.clone()], &tcfg, 1).unwrap();
    let artifact = tuning::tune_shard_json(&[sc], &tcfg, 1, 0, &[0], &book);
    std::fs::write(dir.join("b.json"), artifact).unwrap();
    match merge_dir(&dir) {
        Err(PlanError::ShardMismatch { detail }) => {
            assert!(detail.contains("artifact"), "{detail}");
        }
        other => panic!("wanted ShardMismatch, got {other:?}"),
    }
}

//! Differential property test for the symbolic certifier: a
//! certificate's verdict for an interval must be **bitwise-identical**
//! (serialized JSON, so ordering and truncation notices included) to
//! what the concrete `analyze` driver produces on a freshly built
//! schedule at any count inside that interval.
//!
//! Sampling at each interval endpoint, one step inside each endpoint,
//! and one interior point exercises exactly the places an off-by-one in
//! the crossover arithmetic would show up: a threshold computed one
//! count too early or late moves a boundary, and the fresh concrete
//! build at the stale boundary then disagrees with the certificate.

use mlane::algorithms::registry::{registry, Alg, OpKind};
use mlane::analysis::{analyze, certify, CertifyOptions, LintConfig};
use mlane::model::{Persona, PersonaName};
use mlane::topology::Cluster;
use mlane::tuning;

/// Sample points for `[lo, hi]`: endpoints, endpoint±1, interior.
fn samples(lo: u64, hi: u64) -> Vec<u64> {
    let mut out = vec![lo, hi, lo.saturating_add(1).min(hi), hi.saturating_sub(1).max(lo)];
    out.push(lo + (hi - lo) / 2);
    out.sort_unstable();
    out.dedup();
    out
}

/// The port budget the certificate claims for this interval must match
/// what a concrete lint at count `c` would use (`cmd_lint` semantics:
/// `tuned` verifies the *dispatched* algorithm's budget).
fn concrete_ports(alg: &Alg, cl: Cluster, persona: &Persona, op: OpKind, c: u64) -> u32 {
    if alg.name() == "tuned" {
        let d = tuning::dispatch(cl, persona.name, op, c)
            .unwrap_or_else(|e| panic!("dispatch {op} c={c}: {e}"));
        d.ports_required(cl, op)
    } else {
        alg.ports_required(cl, op)
    }
}

fn crossval(cl: Cluster, opts: &CertifyOptions, rendezvous: Option<(u64, u64)>) {
    let persona = Persona::get(PersonaName::OpenMpi);
    for alg in registry().validation_instances(cl) {
        for op in OpKind::ALL {
            if !alg.supports(op) {
                continue;
            }
            let cert = certify(&alg, cl, &persona, op, opts)
                .unwrap_or_else(|e| panic!("certify {} {op} on {cl:?}: {e}", alg.label()));
            for iv in &cert.intervals {
                for c in samples(iv.lo, iv.hi) {
                    let ctx = format!("{} {op} on {cl:?} c={c}", alg.label());
                    assert_eq!(
                        iv.port_limit,
                        concrete_ports(&alg, cl, &persona, op, c),
                        "{ctx}: port budget drifts inside [{}, {}]",
                        iv.lo,
                        iv.hi
                    );
                    let built = alg
                        .build(cl, &persona, op.op(c))
                        .unwrap_or_else(|e| panic!("{ctx}: {e}"));
                    assert_eq!(
                        built.schedule.algorithm, iv.structure,
                        "{ctx}: structure drifts inside [{}, {}]",
                        iv.lo, iv.hi
                    );
                    let mut cfg = LintConfig::new(iv.port_limit);
                    if let Some((net, shm)) = rendezvous {
                        cfg = cfg.with_rendezvous(net, shm);
                    }
                    cfg.max_per_lint = opts.max_per_lint;
                    let concrete = analyze(&built.schedule, &cfg);
                    assert_eq!(
                        iv.analysis.to_json(),
                        concrete.to_json(),
                        "{ctx}: certificate verdict differs from concrete analyze"
                    );
                }
            }
        }
    }
}

#[test]
fn certificates_match_concrete_analyze_buffered() {
    // Default options: buffered MPI (no rendezvous), intervals cut only
    // at structure breaks and eager-mode crossovers.
    for cl in [Cluster::new(2, 2, 1), Cluster::new(3, 5, 2)] {
        crossval(cl, &CertifyOptions::default(), None);
    }
}

#[test]
fn certificates_match_concrete_analyze_rendezvous() {
    // A finite rendezvous limit arms the deadlock pass and adds byte
    // crossovers at the limit itself — the interval boundaries most
    // likely to be off by one.
    let opts = CertifyOptions {
        rendezvous_net: 4096,
        rendezvous_shm: 4096,
        ..CertifyOptions::default()
    };
    crossval(Cluster::new(3, 5, 2), &opts, Some((4096, 4096)));
}

//! Golden-snapshot contract for `mlane trace --backend event`: the
//! per-event span stream (enqueue/dequeue/deliver with queue depth) for
//! the simplest possible network interaction — one off-node transfer —
//! is pinned exactly, and the rendering is byte-deterministic.
//!
//! The golden sequence is the store-and-forward life of a message
//! through the two serialization points: the source node's egress port,
//! then (one wire latency later, cut-through) the destination node's
//! ingress port, where delivery happens at ingress service end.

use mlane::algorithms::bcast;
use mlane::model::CostModel;
use mlane::netsim::Scenario;
use mlane::sim::trace::trace_run_event;
use mlane::topology::Cluster;

fn quiet() -> CostModel {
    let mut m = CostModel::hydra_baseline();
    m.jitter_mean = 0.0;
    m
}

#[test]
fn single_offnode_transfer_emits_the_golden_event_sequence() {
    // Two single-core nodes, binomial bcast: exactly one transfer,
    // rank 0 -> rank 1, off-node.
    let cl = Cluster::new(2, 1, 1);
    let s = bcast::build(cl, 0, 4, bcast::BcastAlg::Binomial);
    assert_eq!(s.num_transfers(), 1, "golden assumes a single transfer");
    let bytes = s.rounds[0].transfers[0].bytes;

    let et = trace_run_event(&s, &quiet(), &Scenario::contention_free(), 1).unwrap();
    let got: Vec<String> = et
        .events
        .iter()
        .map(|e| {
            format!(
                "{} {} node={} {}->{} {}B depth={}",
                e.kind.label(),
                e.port,
                e.node,
                e.src,
                e.dst,
                e.bytes,
                e.depth
            )
        })
        .collect();
    let golden = [
        format!("enqueue net-out node=0 0->1 {bytes}B depth=0"),
        format!("dequeue net-out node=0 0->1 {bytes}B depth=0"),
        format!("enqueue net-in node=1 0->1 {bytes}B depth=0"),
        format!("dequeue net-in node=1 0->1 {bytes}B depth=0"),
        format!("deliver net-in node=1 0->1 {bytes}B depth=0"),
    ];
    assert_eq!(got, golden, "event sequence drifted from the golden snapshot");

    // The text rendering carries the same sequence after stripping the
    // leading timestamp, and timestamps are monotonically non-decreasing.
    let text = et.text();
    let mut last = 0.0f64;
    for (line, want) in text.lines().zip(&golden) {
        let (t, rest) = line.split_once(' ').expect("timestamp prefix");
        let t: f64 = t.parse().expect("parseable timestamp");
        assert!(t >= last, "timestamps went backwards: {text}");
        last = t;
        assert_eq!(rest, want);
    }
    assert_eq!(text.lines().count(), golden.len());

    // One wire span per transfer rides along with the events.
    assert_eq!(et.trace.spans.len(), 1);
}

#[test]
fn event_trace_rendering_is_byte_deterministic_and_wellformed() {
    let cl = Cluster::new(3, 4, 2);
    let s = bcast::build(cl, 0, 64, bcast::BcastAlg::KLane { k: 2, two_phase: false });
    let m = quiet();
    // A contended scenario exercises queue depths > 0 and tenant events.
    let sc = Scenario::contended();
    let a = trace_run_event(&s, &m, &sc, 7).unwrap();
    let b = trace_run_event(&s, &m, &sc, 7).unwrap();
    assert_eq!(a.text(), b.text(), "text rendering must replay bitwise");
    assert_eq!(a.to_chrome_json(), b.to_chrome_json(), "json must replay bitwise");

    // Chrome-trace shape: a JSON array whose instant-event count equals
    // the recorded event count (spans render as "X" duration events).
    let json = a.to_chrome_json();
    assert!(json.trim_start().starts_with('['), "{json}");
    assert!(json.trim_end().ends_with(']'), "{json}");
    assert_eq!(json.matches("\"ph\":\"i\"").count(), a.events.len(), "{json}");
    assert!(json.contains("\"depth\":"), "{json}");
    // A different seed reorders tenant arrivals — the trace must follow.
    let c = trace_run_event(&s, &m, &sc, 8).unwrap();
    assert_ne!(a.text(), c.text(), "seed must matter under tenant traffic");
}

//! Shared integration-test helpers.
//!
//! The strict mini JSON parser started life inside `plan_report.rs` as
//! the schema oracle for the `JsonSink`; it is promoted here so the
//! decision-table round-trip test (`tuning_roundtrip.rs`) can re-parse
//! `mlane tune` artifacts with an implementation *independent* of the
//! library's own reader. Strictness is the point: the whole document
//! must parse and trailing bytes are an error.

// Each integration-test binary compiles its own copy and uses a
// different subset of the helpers.
#![allow(dead_code)]

#[derive(Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(items) => items.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn arr(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            other => panic!("not an array: {other:?}"),
        }
    }

    pub fn num(&self) -> f64 {
        match self {
            Json::Num(n) => *n,
            other => panic!("not a number: {other:?}"),
        }
    }

    pub fn string(&self) -> &str {
        match self {
            Json::Str(s) => s,
            other => panic!("not a string: {other:?}"),
        }
    }
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Parser { s: s.as_bytes(), i: 0 }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn ws(&mut self) {
        while self.peek().is_some_and(|b| b.is_ascii_whitespace()) {
            self.i += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek().ok_or("unexpected eof")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.quoted()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.s[start..self.i])
            .map_err(|_| "bad utf-8 in number".to_string())?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at byte {start}: {e}"))
    }

    fn quoted(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or("eof inside string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or("eof after escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .s
                                .get(self.i..self.i + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("truncated \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.i += 4;
                            out.push(char::from_u32(cp).ok_or("bad codepoint")?);
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                _ => {
                    let rest = std::str::from_utf8(&self.s[self.i..])
                        .map_err(|_| "bad utf-8 in string".to_string())?;
                    let ch = rest.chars().next().expect("non-empty");
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(items));
        }
        loop {
            self.ws();
            let key = self.quoted()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            items.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(items));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

pub fn parse_json(s: &str) -> Result<Json, String> {
    let mut p = Parser::new(s);
    let v = p.value()?;
    p.ws();
    if p.i != p.s.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

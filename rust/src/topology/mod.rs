//! Cluster topology: `N` compute nodes × `n` processor-cores, `k` network
//! lanes per node (paper §2: p = N·n, ranks consecutive per node).

/// A process rank, 0 ≤ rank < p.
pub type Rank = u32;

/// Hierarchical cluster description.
///
/// Placement follows the paper's experiments (§4): ranks are consecutive
/// on nodes (rank `i` lives on node `i / n`, core `i % n`), and cores are
/// assumed to alternate over the sockets so that cores `0..k` can each
/// drive one of the `k` lanes at full bandwidth.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Cluster {
    /// Number of compute nodes (paper: N).
    pub nodes: u32,
    /// Processor-cores per node (paper: n).
    pub cores: u32,
    /// Network lanes per node (paper: k); the Hydra system has k = 2
    /// physical lanes (dual OmniPath), experiments use k = 1..6 virtual.
    pub lanes: u32,
}

impl Cluster {
    pub fn new(nodes: u32, cores: u32, lanes: u32) -> Self {
        assert!(nodes >= 1 && cores >= 1 && lanes >= 1, "degenerate cluster");
        // lanes may exceed cores: lanes are node hardware (e.g. a
        // single-process-per-node placement on a dual-rail system still
        // has 2 lanes, §4.1); algorithms that *drive* k lanes from k
        // cores assert k <= n themselves.
        Self { nodes, cores, lanes }
    }

    /// The paper's evaluation system: 36 nodes × 32 cores, dual OmniPath.
    pub fn hydra(lanes: u32) -> Self {
        Self::new(36, 32, lanes)
    }

    /// Total number of processes p = N·n.
    #[inline]
    pub fn p(&self) -> u32 {
        self.nodes * self.cores
    }

    #[inline]
    pub fn node_of(&self, rank: Rank) -> u32 {
        debug_assert!(rank < self.p());
        rank / self.cores
    }

    #[inline]
    pub fn core_of(&self, rank: Rank) -> u32 {
        debug_assert!(rank < self.p());
        rank % self.cores
    }

    #[inline]
    pub fn rank_of(&self, node: u32, core: u32) -> Rank {
        debug_assert!(node < self.nodes && core < self.cores);
        node * self.cores + core
    }

    /// All ranks on `node`, in core order.
    pub fn ranks_on(&self, node: u32) -> impl Iterator<Item = Rank> + '_ {
        let base = node * self.cores;
        base..base + self.cores
    }

    #[inline]
    pub fn same_node(&self, a: Rank, b: Rank) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Lane a given core drives for off-node traffic (core `c` maps to
    /// lane `c mod k`; with socket-alternating placement consecutive
    /// cores hit distinct lanes, matching the paper's placement note).
    #[inline]
    pub fn lane_of_core(&self, core: u32) -> u32 {
        core % self.lanes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hydra_dimensions() {
        let cl = Cluster::hydra(2);
        assert_eq!(cl.p(), 1152);
        assert_eq!(cl.nodes, 36);
        assert_eq!(cl.cores, 32);
    }

    #[test]
    fn rank_mapping_roundtrip() {
        let cl = Cluster::new(4, 8, 2);
        for r in 0..cl.p() {
            let (nd, co) = (cl.node_of(r), cl.core_of(r));
            assert_eq!(cl.rank_of(nd, co), r);
        }
    }

    #[test]
    fn ranks_on_node() {
        let cl = Cluster::new(3, 4, 1);
        let v: Vec<_> = cl.ranks_on(1).collect();
        assert_eq!(v, vec![4, 5, 6, 7]);
    }

    #[test]
    fn same_node_detection() {
        let cl = Cluster::new(2, 3, 1);
        assert!(cl.same_node(0, 2));
        assert!(!cl.same_node(2, 3));
    }

    #[test]
    fn lane_assignment_cycles() {
        let cl = Cluster::new(2, 8, 2);
        assert_eq!(cl.lane_of_core(0), 0);
        assert_eq!(cl.lane_of_core(1), 1);
        assert_eq!(cl.lane_of_core(2), 0);
    }

    #[test]
    #[should_panic(expected = "degenerate")]
    fn rejects_zero_nodes() {
        Cluster::new(0, 4, 1);
    }

    #[test]
    fn lanes_may_exceed_cores() {
        // single-process-per-node placement on dual-rail hardware (§4.1)
        let cl = Cluster::new(32, 1, 2);
        assert_eq!(cl.p(), 32);
    }
}

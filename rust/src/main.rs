//! `mlane` CLI — leader entrypoint for the k-ported / k-lane collective
//! library.
//!
//! ```text
//! mlane table <N> [--persona openmpi|intelmpi|mpich] [--csv DIR]
//! mlane tables [--csv DIR] [--threads T]  # all 48 tables (2..49), plan-parallel
//!              [--shards N --shard-index I --out FILE]  # one shard of a multi-process run
//! mlane sweep  [--preset paper|appendix|tuned|contention]
//!              [--nodes N --cores n --lanes L] [--op OP[,OP...]]
//!              [--alg NAME[:K][,NAME[:K]...]] [--k K] [--counts C[,C...]]
//!              [--persona P[,P...]] [--format text|csv|json] [--out DIR]
//!              [--reps R] [--threads T] [--list]
//!              [--backend sim|event] [event scenario knobs, see below]
//!              [--shards N --shard-index I]  # emit a shard artifact instead of a report
//! mlane tune   [--preset paper|appendix|tuned|contention] [grid flags as sweep]
//!              [--backend sim|event]  # event books are tagged; shards never mix backends
//!              [--format text|json] [--out FILE]  # per-size decision tables
//!              [--shards N --shard-index I]  # emit a tune-shard artifact
//! mlane merge  OUT DIR [--format text|csv|json]  # reassemble shard artifacts;
//!              byte-identical to the single-process report (tune shards -> book json)
//! mlane serve  --book FILE [--once] [--socket PATH] [--watch-ms MS]
//!              # algorithm-selection daemon over a tuned book: line-JSON queries
//!              # on stdin/stdout (or a unix socket), zero-alloc dispatch,
//!              # torn-free hot reload ({"cmd":"reload"} or --watch-ms polling)
//! mlane run --op bcast|scatter|gather|allgather|alltoall
//!           --alg <registry name: kported|klane|klane2p|fulllane|bruck|tuned|...>
//!           [--k K] [--c C] [--nodes N] [--cores n] [--lanes L]
//!           [--backend sim|event|exec|xla] [--persona P] [--table FILE]
//! mlane autotune --op <op> [--c C] [--nodes N] [--cores n] [--lanes L]
//! mlane compare                       # simulated vs paper anchors
//! mlane trace --op <op> --alg <alg> [--out FILE] [--backend sim|event]  # Chrome trace
//! # event scenario knobs (with --backend event; the contention preset defaults to it):
//! #   --tenants N --tenant-gap US --tenant-bytes B   background tenant flows per node
//! #   --stragglers N --straggler-factor F            slow nodes (factor >= 1)
//! #   --queue-capacity SLOTS                         drop-tail bound (overflow = typed error)
//! mlane lint   [--nodes N --cores n --lanes L] [--op OP[,OP...]]
//!              [--alg NAME[:K][,NAME[:K]...]] [--k K] [--counts C[,C...]]
//!              [--persona P] [--format text|json] [--out FILE]
//!              [--eager-limit BYTES] [--max-per-lint N]  # exhaustive diagnostics
//!              # --counts on a cache-id algorithm replays one flow arena
//!              # across the whole series instead of rebuilding per count
//! mlane certify [--nodes N --cores n --lanes L] [--op OP[,OP...]]
//!              [--alg NAME[:K][,NAME[:K]...]] [--k K] [--persona P]
//!              [--format text|json] [--out FILE] [--max-count C]
//!              [--eager-limit BYTES] [--max-per-lint N]
//!              # symbolic lint over count *intervals*: every count in
//!              # [1, max] receives a verdict; exit 1 on any error
//! mlane validate [--nodes N] [--cores n]  # registry-exhaustive invariants
//! mlane algs                          # list the algorithm catalog
//! ```
//!
//! Algorithm names are resolved against `algorithms::registry` — the
//! catalog, candidate sets, validation coverage and this help text all
//! follow a new registration automatically.
//!
//! This binary is the **only** place environment variables are read:
//! `MLANE_REPS`/`MLANE_THREADS`/`MLANE_CACHE_SHAPES` are parsed here
//! into a `harness::RunConfig` (flags override env) and passed down —
//! the library itself is environment-free.
#![deny(unsafe_code)]

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use mlane::algorithms::registry::{registry, Alg, OpKind};
use mlane::analysis::{
    analyze, analyze_series, certify_into, CertArena, CertReport, CertifyOptions, LintConfig,
    LintEntry, LintReport,
};
use mlane::coordinator::{Collectives, Op};
use mlane::exec::ExecRuntime;
use mlane::harness::{
    self, anchors, CsvSink, Grid, JsonSink, Merged, Plan, Report, RunConfig, ShardSink,
    TextSink,
};
use mlane::model::{Persona, PersonaName};
use mlane::netsim::{Backend, BackendKind, Scenario as NetScenario};
use mlane::runtime::XlaService;
use mlane::sim::SweepEngine;
use mlane::topology::Cluster;
use mlane::tuning::{self, Scenario, TuneConfig, TuningBook};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal argument parser: positional command + `--key value` flags,
/// plus a known set of value-less boolean switches.
struct Args {
    cmd: String,
    pos: Vec<String>,
    flags: HashMap<String, String>,
}

/// Switches that take no value; everything else still requires one
/// (`--csv --threads 4` stays a hard error, not a directory named
/// "true").
const BOOL_FLAGS: &[&str] = &["list", "once"];

fn parse_args() -> Result<Args> {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().unwrap_or_else(|| "help".into());
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    while let Some(a) = argv.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = if BOOL_FLAGS.contains(&key) {
                "true".to_string()
            } else {
                argv.next().ok_or_else(|| anyhow!("--{key} needs a value"))?
            };
            flags.insert(key.to_string(), val);
        } else {
            pos.push(a);
        }
    }
    Ok(Args { cmd, pos, flags })
}

impl Args {
    fn flag<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("bad --{key} value: {v}")),
        }
    }

    fn bool_flag(&self, key: &str) -> bool {
        self.flags.get(key).is_some_and(|v| v != "false")
    }

    fn persona(&self) -> Result<PersonaName> {
        match self.flags.get("persona") {
            None => Ok(PersonaName::OpenMpi),
            Some(v) => parse_persona(v),
        }
    }

    fn cluster(&self) -> Result<Cluster> {
        let nodes = self.flag("nodes", 36u32)?;
        let cores = self.flag("cores", 32u32)?;
        let lanes = self.flag("lanes", 2u32)?;
        Ok(Cluster::new(nodes, cores, lanes))
    }

    fn op(&self) -> Result<Op> {
        let c = self.flag("c", 1000u64)?;
        match self.flags.get("op").map(String::as_str) {
            None => Ok(OpKind::Bcast.op(c)),
            Some(name) => match OpKind::parse(name) {
                Some(kind) => Ok(kind.op(c)),
                None => bail!("unknown op {name} (ops: {})", op_names().join("|")),
            },
        }
    }

    /// `--alg`/`--k` resolved against the registry; unknown names and
    /// invalid k come back as typed errors, never panics.
    fn algorithm(&self) -> Result<Alg> {
        let k = self.flag("k", 2u32)?;
        let name = self.flags.get("alg").map(String::as_str).unwrap_or("kported");
        Ok(registry().resolve(name, k)?)
    }
}

fn parse_persona(v: &str) -> Result<PersonaName> {
    PersonaName::parse(v)
        .ok_or_else(|| anyhow!("unknown persona {v} (personas: openmpi|intelmpi|mpich)"))
}

fn op_names() -> Vec<&'static str> {
    OpKind::ALL.iter().map(|k| k.name()).collect()
}

/// The run configuration for this invocation: environment first
/// (`RunConfig::from_env` — the CLI edge), explicit flags override.
fn run_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig::from_env();
    if let Some(v) = args.flags.get("reps") {
        cfg.reps = parse_positive(v, "reps")?;
    }
    if let Some(v) = args.flags.get("threads") {
        cfg.threads = parse_positive(v, "threads")?;
    }
    if let Some(v) = args.flags.get("cache-shapes") {
        cfg.cache_shapes = parse_positive(v, "cache-shapes")?;
    }
    if let Some(v) = args.flags.get("out") {
        cfg.out_dir = std::path::PathBuf::from(v);
    }
    Ok(cfg)
}

fn parse_positive(v: &str, what: &str) -> Result<usize> {
    v.parse::<usize>()
        .ok()
        .filter(|&n| n > 0)
        .ok_or_else(|| anyhow!("bad --{what} value: {v} (want a positive integer)"))
}

/// The measurement-config flags (`RunConfig`) a measuring command
/// accepts; `--out` is listed separately, only where it is consumed.
const MEASURE_FLAGS: &[&str] = &["reps", "threads", "cache-shapes"];
const CLUSTER_FLAGS: &[&str] = &["nodes", "cores", "lanes"];
/// Event-backend scenario knobs. Meaningless on the analytic backend —
/// using one without `--backend event` is an error, not a silent no-op.
const SCENARIO_FLAGS: &[&str] = &[
    "tenants",
    "tenant-gap",
    "tenant-bytes",
    "stragglers",
    "straggler-factor",
    "queue-capacity",
];

/// `--backend sim|event` plus the scenario knobs, resolved to a
/// `netsim::Backend`. `contended` seeds the event scenario with
/// `Scenario::contended()` (the `contention` preset's default — which
/// also defaults the backend itself to event) instead of
/// contention-free; explicit knob flags override the base either way.
/// A scenario the backend would reject (`--straggler-factor 0.5`) fails
/// here, at the CLI edge, not mid-sweep.
fn parse_backend(args: &Args, contended: bool) -> Result<Backend> {
    let event = match args.flags.get("backend").map(String::as_str) {
        None => contended,
        Some("sim") => false,
        Some("event") => true,
        Some(other) => bail!("unknown backend {other} (backends: sim|event)"),
    };
    if !event {
        if let Some(f) = SCENARIO_FLAGS.iter().find(|f| args.flags.contains_key(**f)) {
            bail!("--{f} applies to the event backend; add --backend event");
        }
        return Ok(Backend::Analytic);
    }
    let mut sc =
        if contended { NetScenario::contended() } else { NetScenario::contention_free() };
    if let Some(v) = args.flags.get("queue-capacity") {
        let cap: u32 =
            v.parse().map_err(|_| anyhow!("bad --queue-capacity value: {v} (want slots)"))?;
        sc.queue_capacity = Some(cap);
    }
    if let Some(v) = args.flags.get("tenants") {
        sc.tenant_flows =
            v.parse().map_err(|_| anyhow!("bad --tenants value: {v} (want a flow count)"))?;
    }
    if let Some(v) = args.flags.get("tenant-gap") {
        sc.tenant_gap_us =
            v.parse().map_err(|_| anyhow!("bad --tenant-gap value: {v} (want microseconds)"))?;
    }
    if let Some(v) = args.flags.get("tenant-bytes") {
        sc.tenant_bytes =
            v.parse().map_err(|_| anyhow!("bad --tenant-bytes value: {v} (want bytes)"))?;
    }
    if let Some(v) = args.flags.get("stragglers") {
        sc.straggler_nodes =
            v.parse().map_err(|_| anyhow!("bad --stragglers value: {v} (want a node count)"))?;
    }
    if let Some(v) = args.flags.get("straggler-factor") {
        sc.straggler_factor = v
            .parse()
            .map_err(|_| anyhow!("bad --straggler-factor value: {v} (want a slowdown >= 1)"))?;
    }
    sc.validate()?;
    Ok(Backend::Event(sc))
}
/// Multi-process sharding flags (`mlane sweep`/`tables`/`tune`).
const SHARD_FLAGS: &[&str] = &["shards", "shard-index"];

/// `--shards N --shard-index I`, validated as a pair: both or neither,
/// N ≥ 1, I < N.
fn shard_params(args: &Args) -> Result<Option<(u32, u32)>> {
    match (args.flags.get("shards"), args.flags.get("shard-index")) {
        (None, None) => Ok(None),
        (Some(n), Some(i)) => {
            let shards: u32 = n
                .parse()
                .ok()
                .filter(|&v: &u32| v > 0)
                .ok_or_else(|| anyhow!("bad --shards value: {n} (want a positive integer)"))?;
            let index: u32 = i
                .parse()
                .map_err(|_| anyhow!("bad --shard-index value: {i}"))?;
            if index >= shards {
                bail!(
                    "--shard-index {index} out of range for --shards {shards} (valid: 0..={})",
                    shards - 1
                );
            }
            if shards > harness::shard::MAX_SHARDS {
                bail!(
                    "--shards {shards} exceeds the supported {} (merge bookkeeping is \
                     per-shard)",
                    harness::shard::MAX_SHARDS
                );
            }
            Ok(Some((shards, index)))
        }
        (Some(_), None) => bail!("--shards needs --shard-index (which shard this process runs)"),
        (None, Some(_)) => bail!("--shard-index needs --shards (the total shard count)"),
    }
}

/// Reject flags the command does not actually consume — both typos
/// (`--count` must not fall back to a full default grid) and real
/// flags in the wrong place (`mlane algs --reps 5` would be silently
/// ignored otherwise).
fn check_flags(args: &Args, groups: &[&[&str]]) -> Result<()> {
    for key in args.flags.keys() {
        if !groups.iter().any(|g| g.contains(&key.as_str())) {
            bail!(
                "unknown flag --{key} for `{}` (flags: {})",
                args.cmd,
                groups
                    .iter()
                    .flat_map(|g| g.iter())
                    .map(|f| format!("--{f}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
        }
    }
    Ok(())
}

fn run() -> Result<()> {
    let args = parse_args()?;
    match args.cmd.as_str() {
        "table" => {
            check_flags(&args, &[&["persona", "csv"], MEASURE_FLAGS])?;
            cmd_table(&args)
        }
        "tables" => {
            check_flags(&args, &[&["csv", "out"], SHARD_FLAGS, MEASURE_FLAGS])?;
            cmd_tables(&args)
        }
        "sweep" => {
            check_flags(
                &args,
                &[
                    &[
                        "preset", "op", "alg", "k", "counts", "persona", "format", "list",
                        "out", "backend",
                    ],
                    SCENARIO_FLAGS,
                    SHARD_FLAGS,
                    CLUSTER_FLAGS,
                    MEASURE_FLAGS,
                ],
            )?;
            cmd_sweep(&args)
        }
        "tune" => {
            check_flags(
                &args,
                &[
                    &["preset", "op", "alg", "k", "counts", "persona", "format", "out", "backend"],
                    SHARD_FLAGS,
                    CLUSTER_FLAGS,
                    MEASURE_FLAGS,
                ],
            )?;
            cmd_tune(&args)
        }
        "merge" => {
            check_flags(&args, &[&["format"]])?;
            cmd_merge(&args)
        }
        "serve" => {
            check_flags(&args, &[&["book", "once", "socket", "watch-ms"]])?;
            cmd_serve(&args)
        }
        "run" => {
            check_flags(
                &args,
                &[
                    &["op", "alg", "k", "c", "backend", "persona", "table"],
                    SCENARIO_FLAGS,
                    CLUSTER_FLAGS,
                    MEASURE_FLAGS,
                ],
            )?;
            cmd_run(&args)
        }
        "autotune" => {
            check_flags(&args, &[&["op", "c", "persona"], CLUSTER_FLAGS, MEASURE_FLAGS])?;
            cmd_autotune(&args)
        }
        "compare" => {
            check_flags(&args, &[MEASURE_FLAGS])?;
            cmd_compare(&args)
        }
        "trace" => {
            check_flags(
                &args,
                &[
                    &["op", "alg", "k", "c", "persona", "out", "cache-shapes", "backend"],
                    SCENARIO_FLAGS,
                    CLUSTER_FLAGS,
                ],
            )?;
            cmd_trace(&args)
        }
        "lint" => {
            check_flags(
                &args,
                &[
                    &[
                        "op",
                        "alg",
                        "k",
                        "counts",
                        "persona",
                        "format",
                        "out",
                        "eager-limit",
                        "max-per-lint",
                    ],
                    CLUSTER_FLAGS,
                ],
            )?;
            cmd_lint(&args)
        }
        "certify" => {
            check_flags(
                &args,
                &[
                    &[
                        "op",
                        "alg",
                        "k",
                        "persona",
                        "format",
                        "out",
                        "eager-limit",
                        "max-per-lint",
                        "max-count",
                    ],
                    CLUSTER_FLAGS,
                ],
            )?;
            cmd_certify(&args)
        }
        "validate" => {
            check_flags(&args, &[&["persona"], CLUSTER_FLAGS])?;
            cmd_validate(&args)
        }
        "algs" => {
            check_flags(&args, &[])?;
            cmd_algs()
        }
        "help" | "--help" | "-h" => {
            println!("{}", help());
            Ok(())
        }
        other => bail!("unknown command {other} (try `mlane help`)"),
    }
}

/// Help text; the op and algorithm lists are registry-driven so a new
/// registration shows up here without edits.
fn help() -> String {
    format!(
        "mlane — k-ported vs. k-lane collective algorithms (Träff 2020 reproduction)

commands:
  table <N>   regenerate paper table N (2..49)   [--persona P --csv DIR]
  tables      regenerate all 48 tables (2..49), plan-parallel over one worker pool  [--csv DIR --threads T]
                [--shards N --shard-index I --out FILE]  (one shard of a multi-process run)
  sweep       run a user-defined scenario grid through the experiment-plan API
                [--preset {presets}]
                [--nodes --cores --lanes --op OP[,OP] --alg NAME[:K][,NAME[:K]] --k K]
                [--counts C[,C] --persona P[,P] --format text|csv|json --out DIR]
                [--reps R --threads T --list] [--backend sim|event + scenario knobs]
                [--shards N --shard-index I]  (emit a shard artifact instead of a report)
  tune        build per-size decision tables (count breakpoints -> fastest algorithm);
              the `tuned` meta-algorithm dispatches from them
                [--preset {presets}] [grid flags as sweep]
                [--backend sim|event  (event books are tagged; backends never merge)]
                [--format text|json --out FILE --reps R --threads T]
                [--shards N --shard-index I]  (emit a tune-shard artifact)
  merge       reassemble shard artifacts from DIR into OUT — byte-identical to the
              single-process report  [--format text|csv|json]  (tune shards: book json)
                usage: mlane merge OUT DIR
  serve       algorithm-selection daemon over a tuned book: newline-JSON queries
              (single, batch, reload/stats/quit commands) answered from a compiled
              snapshot with a zero-alloc hot path and torn-free hot reload
                usage: mlane serve --book FILE [--once] [--socket PATH] [--watch-ms MS]
  run         run one collective                 [--op --alg --k --c --nodes --cores --lanes --backend sim|event|exec|xla --persona --table FILE]
  autotune    pick the fastest algorithm         [--op --c --nodes --cores --lanes --persona]
  compare     simulated vs paper anchor cells
  trace       emit a Chrome-trace of one run     [--op --alg ... --out FILE --backend sim|event]
              (--backend event adds per-event enqueue/dequeue/deliver instants with queue depth)
  lint        run every static-analysis pass (invariants, lane contention,
              rendezvous deadlock, redundancy, round optimality) over catalog
              schedules; exhaustive diagnostics, exit 1 on any error finding
                [--nodes --cores --lanes --op OP[,OP] --alg NAME[:K][,NAME[:K]] --k K]
                [--counts C[,C] --persona P --format text|json --out FILE]
                [--eager-limit BYTES  (model a rendezvous MPI; default: buffered)]
                [--max-per-lint N  (per-code diagnostic cap, default 50)]
              --counts on a cache-id algorithm runs as a series: one flow-replay
              arena across all counts, structural passes run once
  certify     symbolic lint over count *intervals*: partition [1, max] at exact
              structure breaks and eager/rendezvous byte crossovers, then prove
              a verdict for every count in each interval; machine-readable
              fingerprinted certificates, exit 1 on any error-severity interval
                [--nodes --cores --lanes --op OP[,OP] --alg NAME[:K][,NAME[:K]] --k K]
                [--persona P --format text|json --out FILE]
                [--max-count C  (certification domain ceiling, default u64 max)]
                [--eager-limit BYTES] [--max-per-lint N]
  validate    check schedule invariants for the whole catalog  [--nodes --cores --lanes --persona]
  algs        list the algorithm catalog

flags:      --op  {}
            --alg {}

network backend (sweep/run/trace: --backend sim|event; `--preset contention`
defaults to event with the contended scenario):
            --tenants N           background tenant flows injected per node
            --tenant-gap US       mean gap between tenant flow arrivals (microseconds)
            --tenant-bytes B      tenant flow size in bytes
            --stragglers N        nodes slowed by --straggler-factor
            --straggler-factor F  per-node slowdown multiplier (>= 1)
            --queue-capacity S    drop-tail port queue bound; overflow is a typed error

environment (parsed once, at this CLI edge, into harness::RunConfig;
flags override):
             MLANE_REPS         (simulated repetitions, default 20)
             MLANE_THREADS      (plan worker threads, default: available parallelism)
             MLANE_CACHE_SHAPES (shared schedule-cache bound, default 8)",
        op_names().join("|"),
        registry().names().join("|"),
        presets = Plan::PRESETS.join("|"),
    )
}

fn cmd_algs() -> Result<()> {
    println!("algorithm catalog ({} families):", registry().entries().len());
    for e in registry().entries() {
        let ops: Vec<&str> = OpKind::ALL
            .iter()
            .filter(|&&k| e.supports(k))
            .map(|k| k.name())
            .collect();
        println!(
            "  {:<9} {} [{}]{}",
            e.name(),
            e.about(),
            ops.join(", "),
            if e.parameterized() { "  (--k)" } else { "" }
        );
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let n: u32 = args
        .pos
        .first()
        .ok_or_else(|| anyhow!("usage: mlane table <N>"))?
        .parse()
        .context("table number")?;
    let cfg = run_config(args)?;
    let mut spec = harness::table(n).ok_or_else(|| anyhow!("no table {n} (range 2..49)"))?;
    // Re-run the paper grid under a different library persona on request.
    if args.flags.contains_key("persona") {
        spec.persona = args.persona()?;
    }
    let out = harness::run_table(&spec, &cfg)?;
    let report = Report { tables: vec![out] };
    emit_text(&report)?;
    if let Some(dir) = args.flags.get("csv") {
        emit_csv(&report, dir)?;
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    let plan = Plan::paper();
    // One shard of a multi-process table regeneration: run the owned
    // sections, emit the shard artifact, and let `mlane merge`
    // reassemble the full report on the coordinator. The shard-mode
    // flags and the report-mode flags are mutually exclusive — a
    // silently ignored flag would hide a misconfigured distributed run.
    if let Some((shards, index)) = shard_params(args)? {
        if args.flags.contains_key("csv") {
            bail!("--csv applies to the merged report; a shard run emits an artifact (--out)");
        }
        return run_shard(args, &plan, &cfg, shards, index);
    }
    if args.flags.contains_key("out") {
        bail!("--out names the shard artifact (with --shards); use --csv DIR for reports");
    }
    // The outer table loop is plan-parallel: all sections of all 48
    // tables drain through one work-stealing pool over the shared
    // engine. Emission below is in table order — byte-identical to a
    // serial run for any thread count.
    let report = harness::run_plan(&plan, &cfg)?;
    emit_text(&report)?;
    let dir = args.flags.get("csv").cloned().unwrap_or_else(|| "bench_out".into());
    emit_csv(&report, &dir)?;
    Ok(())
}

/// Run one shard of `plan` and emit its artifact to `--out` (a file
/// path in shard mode) or stdout.
fn run_shard(args: &Args, plan: &Plan, cfg: &RunConfig, shards: u32, index: u32) -> Result<()> {
    let sub = plan.shard(shards, index);
    let report = harness::run_plan(&sub, cfg)?;
    match args.flags.get("out") {
        Some(path) => {
            harness::write_shard(path, plan, cfg, shards, index, &report)?;
            eprintln!(
                "shard {index} of {shards} ({} of {} sections): {path}",
                sub.num_sections(),
                plan.num_sections()
            );
        }
        None => {
            let stdout = std::io::stdout();
            let mut sink = ShardSink::new(stdout.lock(), plan, cfg, shards, index);
            report.emit(&mut sink)?;
        }
    }
    Ok(())
}

fn emit_text(report: &Report) -> Result<()> {
    let stdout = std::io::stdout();
    report.emit(&mut TextSink::new(stdout.lock()))?;
    Ok(())
}

fn emit_csv(report: &Report, dir: impl Into<std::path::PathBuf>) -> Result<()> {
    let mut sink = CsvSink::new(dir);
    report.emit(&mut sink)?;
    for p in sink.written() {
        eprintln!("csv: {}", p.display());
    }
    Ok(())
}

/// Split a comma list, trimming items; empty lists (e.g. `--counts ","`)
/// are an error, never a silent empty plan.
fn parse_list<'a>(raw: &'a str, what: &str) -> Result<Vec<&'a str>> {
    let items: Vec<&str> =
        raw.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if items.is_empty() {
        bail!("--{what} needs at least one value");
    }
    Ok(items)
}

/// `--op OP[,OP]` (default: bcast). Shared by `sweep` and `tune`.
fn parse_ops(args: &Args) -> Result<Vec<OpKind>> {
    match args.flags.get("op") {
        None => Ok(vec![OpKind::Bcast]),
        Some(list) => parse_list(list, "op")?
            .into_iter()
            .map(|s| {
                OpKind::parse(s)
                    .ok_or_else(|| anyhow!("unknown op {s} (ops: {})", op_names().join("|")))
            })
            .collect(),
    }
}

/// `--alg NAME[:K][,NAME[:K]]` resolved against the registry (`None`
/// when the flag is absent, so each command picks its own default).
fn parse_algs(args: &Args, default_k: u32) -> Result<Option<Vec<Alg>>> {
    match args.flags.get("alg") {
        None => Ok(None),
        Some(list) => parse_list(list, "alg")?
            .into_iter()
            .map(|item| {
                let (name, k) = match item.split_once(':') {
                    Some((n, ks)) => (
                        n,
                        ks.parse::<u32>().map_err(|_| anyhow!("bad k in --alg {item}"))?,
                    ),
                    None => (item, default_k),
                };
                Ok(registry().resolve(name, k)?)
            })
            .collect::<Result<_>>()
            .map(Some),
    }
}

/// `--persona P[,P]` (default: openmpi).
fn parse_personas(args: &Args) -> Result<Vec<PersonaName>> {
    match args.flags.get("persona") {
        None => Ok(vec![PersonaName::OpenMpi]),
        Some(list) => {
            parse_list(list, "persona")?.into_iter().map(parse_persona).collect()
        }
    }
}

/// `--counts C[,C]` (`None` falls back to the per-op paper grid).
fn parse_counts(args: &Args) -> Result<Option<Vec<u64>>> {
    match args.flags.get("counts") {
        None => Ok(None),
        Some(list) => parse_list(list, "counts")?
            .into_iter()
            .map(|s| s.parse::<u64>().map_err(|_| anyhow!("bad --counts value {s}")))
            .collect::<Result<Vec<u64>>>()
            .map(Some),
    }
}

/// Build a plan from the sweep flags: one table per persona, sections =
/// (algorithms × ops) on the given cluster.
fn sweep_plan(args: &Args) -> Result<Plan> {
    let cl = args.cluster()?;
    let default_k = args.flag("k", cl.lanes)?;

    let ops = parse_ops(args)?;
    let algs: Vec<Alg> = match parse_algs(args, default_k)? {
        // fulllane + native support every operation — a safe default grid.
        None => vec![registry().resolve("fulllane", 0)?, registry().resolve("native", 0)?],
        Some(list) => list,
    };
    let personas = parse_personas(args)?;
    let counts = parse_counts(args)?;

    let caption = format!(
        "sweep: {} x {} on {}x{} (lanes={})",
        ops.iter().map(|o| o.name()).collect::<Vec<_>>().join(","),
        algs.iter().map(|a| a.label()).collect::<Vec<_>>().join(","),
        cl.nodes,
        cl.cores,
        cl.lanes
    );
    let mut plan = Plan::new();
    for (pi, &persona) in personas.iter().enumerate() {
        let mut sections = Vec::new();
        for &op in &ops {
            let cts: &[u64] = match &counts {
                Some(v) => v,
                None => harness::default_counts(op),
            };
            sections.extend(
                Grid::new()
                    .cluster(cl)
                    .op(op)
                    .algs(algs.iter().cloned())
                    .counts(cts)
                    .sections(),
            );
        }
        plan.tables.push(harness::TableSpec {
            number: pi as u32 + 1,
            caption: caption.clone(),
            persona,
            sections,
        });
    }
    Ok(plan)
}

fn print_plan(plan: &Plan, cfg: &RunConfig) {
    println!(
        "plan: {} tables, {} sections, {} cells (reps={}, threads={})",
        plan.tables.len(),
        plan.num_sections(),
        plan.num_cells(),
        cfg.reps,
        cfg.threads
    );
    for t in &plan.tables {
        println!("table {}: {} [{}]", t.number, t.caption, t.persona.label());
        for s in &t.sections {
            let k = s.alg.k().map(|k| k.to_string()).unwrap_or_else(|| "-".into());
            println!(
                "    {:<44} {} {}:{} on {}x{} (lanes={}), {} counts",
                s.heading,
                s.op,
                s.alg.name(),
                k,
                s.cluster.nodes,
                s.cluster.cores,
                s.cluster.lanes,
                s.counts.len()
            );
        }
    }
}

/// Grid-shaping flags that conflict with `--preset` (a preset IS the
/// grid; silently ignoring these would run something the user didn't
/// ask for).
const GRID_FLAGS: &[&str] =
    &["op", "alg", "counts", "persona", "k", "nodes", "cores", "lanes"];

fn cmd_sweep(args: &Args) -> Result<()> {
    let mut cfg = run_config(args)?;
    // The contention preset exists to exercise the event backend: it
    // defaults to `--backend event` with the contended scenario. Any
    // other grid stays analytic unless `--backend event` asks.
    let contended = args.flags.get("preset").map(String::as_str) == Some("contention");
    cfg.backend = parse_backend(args, contended)?;
    let plan = match args.flags.get("preset") {
        Some(name) => {
            if let Some(conflict) = GRID_FLAGS.iter().find(|f| args.flags.contains_key(**f)) {
                bail!("--preset defines the whole grid; drop --{conflict} (grid flags: {})",
                    GRID_FLAGS.iter().map(|f| format!("--{f}")).collect::<Vec<_>>().join(" "));
            }
            Plan::preset(name).ok_or_else(|| {
                anyhow!("unknown preset {name} (presets: {})", Plan::PRESETS.join(", "))
            })?
        }
        None => sweep_plan(args)?,
    };
    if let Some((shards, index)) = shard_params(args)? {
        if args.flags.contains_key("format") {
            bail!(
                "--shards emits a shard artifact, not a report; \
                 --format belongs to `mlane merge`"
            );
        }
        if args.bool_flag("list") {
            print_plan(&plan.shard(shards, index), &cfg);
            return Ok(());
        }
        return run_shard(args, &plan, &cfg, shards, index);
    }
    if args.bool_flag("list") {
        print_plan(&plan, &cfg);
        return Ok(());
    }
    let report = harness::run_plan(&plan, &cfg)?;
    match args.flags.get("format").map(String::as_str) {
        None | Some("text") => emit_text(&report)?,
        Some("json") => {
            let stdout = std::io::stdout();
            report.emit(&mut JsonSink::new(stdout.lock()))?;
        }
        Some("csv") => emit_csv(&report, &cfg.out_dir)?,
        Some(other) => bail!("unknown format {other} (formats: text|csv|json)"),
    }
    Ok(())
}

/// `mlane merge OUT DIR`: reassemble a directory of shard artifacts
/// into the single-process result. Plan shards merge into a report
/// (`--format text|csv|json`; text/json write OUT as a file, csv fills
/// OUT as a directory); tune shards merge into the decision-table book
/// (always JSON). Every shard-set inconsistency — fingerprint mismatch,
/// missing/duplicate shards, truncated rows — is a typed error, exit 1.
fn cmd_merge(args: &Args) -> Result<()> {
    let (out, dir) = match &args.pos[..] {
        [out, dir] => (out.as_str(), dir.as_str()),
        _ => bail!(
            "usage: mlane merge OUT DIR [--format text|csv|json] (got {} positional \
             argument{})",
            args.pos.len(),
            if args.pos.len() == 1 { "" } else { "s" }
        ),
    };
    // Refuse to write the merged output into the shard directory
    // itself: merge_dir globs every direct-child *.json, so a later
    // merge of the same directory would read OUT as a shard artifact.
    if let Ok(d) = std::fs::canonicalize(dir) {
        let parent = match std::path::Path::new(out).parent() {
            Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
            _ => std::path::PathBuf::from("."),
        };
        if std::fs::canonicalize(&parent).is_ok_and(|p| p == d) {
            bail!(
                "OUT {out} lands inside the shard directory {dir}; a later merge would \
                 read it as a shard artifact — write it elsewhere"
            );
        }
    }
    let format = args.flags.get("format").map(String::as_str);
    match harness::merge_dir(dir)? {
        Merged::Report(report) => match format {
            None | Some("text") => write_out(out, &report.text())?,
            Some("json") => write_out(out, &report.json())?,
            Some("csv") => {
                let mut sink = CsvSink::new(out);
                report.emit(&mut sink).with_context(|| format!("write csv under {out}"))?;
                for p in sink.written() {
                    eprintln!("csv: {}", p.display());
                }
            }
            Some(other) => bail!("unknown format {other} (formats: text|csv|json)"),
        },
        Merged::Book(book) => match format {
            None | Some("json") => write_out(out, &book.to_json())?,
            Some(other) => {
                bail!("tune-shard merges emit the decision-table book as json, not {other}")
            }
        },
    }
    eprintln!("merged {dir} -> {out}");
    Ok(())
}

fn write_out(path: &str, contents: &str) -> Result<()> {
    std::fs::write(path, contents).with_context(|| format!("write {path}"))
}

/// `mlane serve`: load + compile the book, then hand the transport
/// loop to `mlane::serve`. `--once` drains stdin and exits (the batch
/// mode CI scripts use), `--socket` accepts Unix-socket connections,
/// `--watch-ms` polls the book file and hot-reloads on change.
fn cmd_serve(args: &Args) -> Result<()> {
    let path = args
        .flags
        .get("book")
        .ok_or_else(|| anyhow!("serve needs --book FILE (a `mlane tune --format json` book)"))?;
    let once = args.bool_flag("once");
    // Flag conflicts are cheaper than a book load: check them first.
    if once && (args.flags.contains_key("socket") || args.flags.contains_key("watch-ms")) {
        bail!("--once drains stdin and exits; drop --socket/--watch-ms");
    }
    let svc = Arc::new(mlane::serve::Service::load(path)?);
    if let Some(v) = args.flags.get("watch-ms") {
        let ms = parse_positive(v, "watch-ms")? as u64;
        mlane::serve::watch_book(Arc::clone(&svc), std::time::Duration::from_millis(ms));
    }
    if let Some(sock) = args.flags.get("socket") {
        return serve_socket_cli(sock, &svc);
    }
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    mlane::serve::serve_lines(&svc, stdin.lock(), stdout.lock())?;
    if once {
        eprintln!("{}", svc.summary());
    }
    Ok(())
}

#[cfg(unix)]
fn serve_socket_cli(sock: &str, svc: &Arc<mlane::serve::Service>) -> Result<()> {
    eprintln!("mlane serve: listening on {sock}");
    mlane::serve::serve_socket(svc, std::path::Path::new(sock))?;
    Ok(())
}

#[cfg(not(unix))]
fn serve_socket_cli(_sock: &str, _svc: &Arc<mlane::serve::Service>) -> Result<()> {
    bail!("--socket needs Unix domain sockets; serve over stdin/stdout instead")
}

/// Tuning scenarios from the grid flags: (personas × ops) on the given
/// cluster. Explicit `--alg` names the candidate set (filtered per op;
/// an op left with no supporting candidate is a typed error downstream);
/// otherwise each op tunes over its registry default candidates.
fn tune_scenarios(args: &Args) -> Result<Vec<Scenario>> {
    let cl = args.cluster()?;
    let default_k = args.flag("k", cl.lanes)?;
    let ops = parse_ops(args)?;
    let explicit = parse_algs(args, default_k)?;
    let personas = parse_personas(args)?;
    let counts = parse_counts(args)?;
    let mut out = Vec::new();
    for &persona in &personas {
        for &op in &ops {
            out.push(Scenario {
                cluster: cl,
                op,
                persona,
                counts: counts
                    .clone()
                    .unwrap_or_else(|| harness::default_counts(op).to_vec()),
                candidates: match &explicit {
                    Some(list) => list.clone(),
                    None => registry().candidates(cl, op),
                },
            });
        }
    }
    Ok(out)
}

/// Tuning scenarios covering a preset plan: one scenario per distinct
/// (cluster, op, persona) its tables sweep, counts = the union of the
/// sections' grids, candidates = the registry defaults.
fn scenarios_from_plan(plan: &Plan) -> Vec<Scenario> {
    let mut out: Vec<Scenario> = Vec::new();
    for t in &plan.tables {
        for s in &t.sections {
            match out
                .iter_mut()
                .find(|sc| sc.cluster == s.cluster && sc.op == s.op && sc.persona == t.persona)
            {
                Some(sc) => sc.counts.extend(s.counts.iter().copied()),
                None => out.push(Scenario {
                    cluster: s.cluster,
                    op: s.op,
                    persona: t.persona,
                    counts: s.counts.to_vec(),
                    candidates: registry().candidates(s.cluster, s.op),
                }),
            }
        }
    }
    for sc in &mut out {
        sc.counts.sort_unstable();
        sc.counts.dedup();
    }
    out
}

fn cmd_tune(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    // Decision tables are reproducible artifacts: tuning runs under the
    // fixed TuneConfig defaults (the same parameters the `tuned`
    // meta-algorithm auto-builds with), not the measurement env —
    // explicit --reps overrides for quick experiments.
    let mut tune_cfg = TuneConfig::default();
    if let Some(v) = args.flags.get("reps") {
        tune_cfg.reps = parse_positive(v, "reps")?;
    }
    // `--backend event` tunes on the event backend (contention-free
    // scenario only — winners ranked under one tenant load would be
    // wrong under another) and tags the book, so analytic and event
    // artifacts never merge or install interchangeably.
    tune_cfg.backend = match args.flags.get("backend").map(String::as_str) {
        None | Some("sim") => BackendKind::Analytic,
        Some("event") => BackendKind::Event,
        Some(other) => bail!("unknown backend {other} (backends: sim|event)"),
    };
    let scenarios = match args.flags.get("preset") {
        Some(name) => {
            if let Some(conflict) = GRID_FLAGS.iter().find(|f| args.flags.contains_key(**f)) {
                bail!(
                    "--preset defines the whole grid; drop --{conflict} (grid flags: {})",
                    GRID_FLAGS.iter().map(|f| format!("--{f}")).collect::<Vec<_>>().join(" ")
                );
            }
            let plan = Plan::preset(name).ok_or_else(|| {
                anyhow!("unknown preset {name} (presets: {})", Plan::PRESETS.join(", "))
            })?;
            scenarios_from_plan(&plan)
        }
        None => tune_scenarios(args)?,
    };
    // One shard of a multi-process tune: sweep only the owned scenarios
    // and emit a tune-shard artifact carrying the whole job's
    // fingerprint, for `mlane merge` to reassemble into one book.
    if let Some((shards, index)) = shard_params(args)? {
        if args.flags.contains_key("format") {
            bail!(
                "--shards emits a tune-shard artifact, not a report; \
                 the merged book is always json"
            );
        }
        let indices = tuning::shard_scenarios(scenarios.len(), shards, index);
        let owned: Vec<Scenario> = indices.iter().map(|&i| scenarios[i].clone()).collect();
        let engine = Arc::new(SweepEngine::with_capacity(cfg.cache_shapes));
        let book = tuning::tune_all(&engine, &owned, &tune_cfg, cfg.threads)?;
        let json = tuning::tune_shard_json(&scenarios, &tune_cfg, shards, index, &indices, &book);
        match args.flags.get("out") {
            Some(path) => {
                write_out(path, &json)?;
                eprintln!(
                    "tune shard {index} of {shards} ({} of {} scenarios): {path}",
                    owned.len(),
                    scenarios.len()
                );
            }
            None => print!("{json}"),
        }
        return Ok(());
    }
    // A command-local engine sized by --cache-shapes / MLANE_CACHE_SHAPES
    // (the process singleton ignores later capacity requests); it is
    // still shared across all scenarios and tune workers.
    let engine = Arc::new(SweepEngine::with_capacity(cfg.cache_shapes));
    let book = tuning::tune_all(&engine, &scenarios, &tune_cfg, cfg.threads)?;
    match args.flags.get("format").map(String::as_str) {
        None | Some("text") => print!("{}", book.text()),
        Some("json") => print!("{}", book.to_json()),
        Some(other) => bail!("unknown format {other} (formats: text|json)"),
    }
    if let Some(path) = args.flags.get("out") {
        book.save(path).with_context(|| format!("write decision tables to {path}"))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// A `Collectives` configured from the invocation's `RunConfig` —
/// including the schedule-cache bound (`--cache-shapes` /
/// `MLANE_CACHE_SHAPES`), which applies to every command, not just the
/// plan runners.
fn collectives(cl: Cluster, persona: PersonaName, cfg: &RunConfig) -> Collectives {
    let engine = Arc::new(SweepEngine::with_capacity(cfg.cache_shapes));
    let mut coll = Collectives::with_engine(cl, persona, engine);
    coll.reps = cfg.reps;
    coll.warmup = cfg.warmup;
    coll.seed = cfg.seed;
    coll
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    let cl = args.cluster()?;
    let op = args.op()?;
    let alg = args.algorithm()?;
    let persona = args.persona()?;
    // `--table FILE`: load persisted decision tables so `--alg tuned`
    // dispatches from the artifact instead of auto-building one. A book
    // that does not cover the requested scenario is an error — silently
    // falling back to an auto-built table would report results that
    // have nothing to do with the supplied artifact.
    if let Some(path) = args.flags.get("table") {
        let book = TuningBook::load(path)?;
        if alg.name() == "tuned" && book.get(cl, op.kind(), persona).is_none() {
            let covered: Vec<String> = book.tables.iter().map(|t| t.label()).collect();
            bail!(
                "{path}: no decision table for {} on {}x{} (lanes={}) [{}]; tables cover: {}",
                op.kind(),
                cl.nodes,
                cl.cores,
                cl.lanes,
                persona.key(),
                if covered.is_empty() { "<none>".to_string() } else { covered.join("; ") }
            );
        }
        tuning::install(book)?;
    }
    match args.flags.get("backend").map(String::as_str) {
        None | Some("sim") | Some("event") => {
            let mut coll = collectives(cl, persona, &cfg);
            coll.backend = parse_backend(args, false)?;
            let m = coll.run(op, &alg)?;
            println!(
                "{} {} p={} c={}  avg={:.2}us min={:.2}us  ({} reps)",
                op.kind(),
                m.algorithm,
                cl.p(),
                m.c,
                m.summary.avg,
                m.summary.min,
                m.summary.reps
            );
        }
        Some(backend @ ("exec" | "xla")) => {
            if let Some(f) = SCENARIO_FLAGS.iter().find(|f| args.flags.contains_key(**f)) {
                bail!("--{f} applies to the event backend; add --backend event");
            }
            let coll = collectives(cl, persona, &cfg);
            let rt = if backend == "xla" {
                ExecRuntime::with_xla(XlaService::start(std::path::Path::new("artifacts"))?)
            } else {
                ExecRuntime::channels()
            };
            let rep = coll.execute(op, &alg, &rt)?;
            println!(
                "{} p={} c={}  wallclock avg={:.2}us min={:.2}us  blocks={} xla_phases={}",
                op.kind(),
                cl.p(),
                op.count(),
                rep.summary.avg,
                rep.summary.min,
                rep.blocks_verified,
                rep.xla_phases
            );
        }
        Some(other) => bail!("unknown backend {other}"),
    }
    Ok(())
}

fn cmd_autotune(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    let cl = args.cluster()?;
    let op = args.op()?;
    let coll = collectives(cl, args.persona()?, &cfg);
    let candidates = coll.default_candidates(op);
    println!(
        "autotune {} c={} on {}x{} (k={} lanes):",
        op.kind(),
        op.count(),
        cl.nodes,
        cl.cores,
        cl.lanes
    );
    for alg in &candidates {
        let m = coll.run(op, alg)?;
        println!("  {:24} avg={:.2}us min={:.2}us", m.algorithm, m.summary.avg, m.summary.min);
    }
    let (best, m) = coll.autotune(op, &candidates)?;
    println!("winner: {} ({:.2}us)", best.label(), m.summary.avg);
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    println!("simulated vs paper anchors (ratio = simulated / paper):");
    println!(
        "{:>6} {:<28} {:>9} {:>12} {:>12} {:>7}",
        "table", "section", "c", "paper(us)", "sim(us)", "ratio"
    );
    for c in anchors::compare_all(&cfg)? {
        println!(
            "{:>6} {:<28} {:>9} {:>12.2} {:>12.2} {:>7.2}",
            c.anchor.table,
            c.anchor.section,
            c.anchor.c,
            c.anchor.paper_avg_us,
            c.simulated_avg_us,
            c.ratio
        );
    }
    Ok(())
}

/// Validation element count per operation (kept small: structure, not
/// timing, is under test).
fn validation_count(op: OpKind) -> u64 {
    match op {
        OpKind::Bcast => 64,
        OpKind::Scatter | OpKind::Gather => 16,
        OpKind::Allgather | OpKind::Alltoall => 8,
    }
}

/// The k-ported budget to lint/validate an instance against: the tuned
/// meta-entry builds whatever its decision table picked, so verify the
/// *dispatched* algorithm's port budget, not the meta budget (which is
/// the max over candidates).
fn port_budget(alg: &Alg, cl: Cluster, persona: PersonaName, kind: OpKind, c: u64) -> Result<u32> {
    if alg.name() == "tuned" {
        Ok(tuning::dispatch(cl, persona, kind, c)?.ports_required(cl, kind))
    } else {
        Ok(alg.ports_required(cl, kind))
    }
}

/// `mlane lint`: every static-analysis pass over a grid of catalog
/// schedules, all findings reported. Defaults to the full registry ×
/// every supported operation at the full-scale 36x32 cluster — the CI
/// gate runs exactly this and fails on any error-severity finding.
fn cmd_lint(args: &Args) -> Result<()> {
    let cl = args.cluster()?;
    let default_k = args.flag("k", cl.lanes)?;
    let persona = Persona::get(args.persona()?);
    // `parse_ops` defaults to bcast (the sweep default); lint wants the
    // whole catalog unless the user narrows it.
    let ops = match args.flags.get("op") {
        None => OpKind::ALL.to_vec(),
        Some(_) => parse_ops(args)?,
    };
    let algs = match parse_algs(args, default_k)? {
        Some(list) => list,
        None => registry().validation_instances(cl),
    };
    let counts = parse_counts(args)?;
    let eager = match args.flags.get("eager-limit") {
        None => None,
        Some(v) => Some(
            v.parse::<u64>().map_err(|_| anyhow!("bad --eager-limit value: {v} (want bytes)"))?,
        ),
    };
    let max_per_lint = match args.flags.get("max-per-lint") {
        None => None,
        Some(v) => Some(parse_positive(v, "max-per-lint")?),
    };
    let mut report = LintReport::default();
    for alg in &algs {
        for &kind in &OpKind::ALL {
            if !ops.contains(&kind) || !alg.supports(kind) {
                continue;
            }
            let cts: &[u64] = match &counts {
                Some(v) => v,
                None => &[validation_count(kind)],
            };
            // Cache-id algorithms have count-invariant structure and
            // port budgets, so a `--counts` series is replayed through
            // one flow arena (`analyze_series`) instead of rebuilding
            // the schedule and re-running structural passes per count.
            if cts.len() > 1 && alg.cache_id().is_some() {
                let built = alg
                    .build(cl, &persona, kind.op(cts[0]))
                    .map_err(|e| anyhow!("{} {kind}: {e}", alg.label()))?;
                let ports = port_budget(alg, cl, persona.name, kind, cts[0])?;
                let mut cfg = LintConfig::new(ports);
                if let Some(limit) = eager {
                    cfg = cfg.with_rendezvous(limit, limit);
                }
                if let Some(cap) = max_per_lint {
                    cfg.max_per_lint = cap;
                }
                let safe = built.schedule.count_sizer().max_safe_count();
                if let Some(&c) = cts.iter().find(|&&c| c > safe) {
                    bail!(
                        "count {c} overflows byte sizes for {} {kind} (max safe count {safe})",
                        alg.label()
                    );
                }
                let series = analyze_series(&built.schedule, &cfg, cts);
                for (&c, analysis) in cts.iter().zip(series) {
                    report.entries.push(LintEntry {
                        algorithm: alg.label(),
                        op: kind.name(),
                        count: c,
                        persona: persona.name.key(),
                        cluster: cl,
                        port_limit: ports,
                        analysis,
                    });
                }
                continue;
            }
            for &c in cts {
                let built = alg
                    .build(cl, &persona, kind.op(c))
                    .map_err(|e| anyhow!("{} {kind}: {e}", alg.label()))?;
                let ports = port_budget(alg, cl, persona.name, kind, c)?;
                let mut cfg = LintConfig::new(ports);
                if let Some(limit) = eager {
                    cfg = cfg.with_rendezvous(limit, limit);
                }
                if let Some(cap) = max_per_lint {
                    cfg.max_per_lint = cap;
                }
                report.entries.push(LintEntry {
                    algorithm: alg.label(),
                    op: kind.name(),
                    count: c,
                    persona: persona.name.key(),
                    cluster: cl,
                    port_limit: ports,
                    analysis: analyze(&built.schedule, &cfg),
                });
            }
        }
    }
    if report.entries.is_empty() {
        bail!("nothing to lint: no requested algorithm supports a requested op");
    }
    let rendered = match args.flags.get("format").map(String::as_str) {
        None | Some("text") => report.text(),
        Some("json") => report.to_json(),
        Some(other) => bail!("unknown format {other} (formats: text|json)"),
    };
    match args.flags.get("out") {
        Some(path) => {
            write_out(path, &rendered)?;
            eprintln!("wrote {path}");
        }
        None => print!("{rendered}"),
    }
    if report.errors() > 0 {
        bail!("lint found {} error-severity diagnostic(s)", report.errors());
    }
    Ok(())
}

/// `mlane certify`: symbolic count-range analysis over the same grid
/// as `lint`, but covering *every* count in `[1, max]` rather than a
/// sampled handful. The domain is partitioned into finitely many
/// intervals (structure breaks, then exact eager/rendezvous byte
/// crossovers) and each interval carries a verdict proven identical to
/// concrete `analyze` at any count inside it.
fn cmd_certify(args: &Args) -> Result<()> {
    let cl = args.cluster()?;
    let default_k = args.flag("k", cl.lanes)?;
    let persona = Persona::get(args.persona()?);
    let ops = match args.flags.get("op") {
        None => OpKind::ALL.to_vec(),
        Some(_) => parse_ops(args)?,
    };
    let algs = match parse_algs(args, default_k)? {
        Some(list) => list,
        None => registry().validation_instances(cl),
    };
    let mut opts = CertifyOptions::default();
    if let Some(v) = args.flags.get("eager-limit") {
        let limit =
            v.parse::<u64>().map_err(|_| anyhow!("bad --eager-limit value: {v} (want bytes)"))?;
        opts.rendezvous_net = limit;
        opts.rendezvous_shm = limit;
    }
    if let Some(v) = args.flags.get("max-per-lint") {
        opts.max_per_lint = parse_positive(v, "max-per-lint")?;
    }
    if let Some(v) = args.flags.get("max-count") {
        let max = v
            .parse::<u64>()
            .ok()
            .filter(|&m| m > 0)
            .ok_or_else(|| anyhow!("bad --max-count value: {v} (want a positive count)"))?;
        opts.max_count = Some(max);
    }
    let mut arena = CertArena::new();
    let mut certificates = Vec::new();
    for alg in &algs {
        for &kind in &OpKind::ALL {
            if !ops.contains(&kind) || !alg.supports(kind) {
                continue;
            }
            let cert = certify_into(alg, cl, &persona, kind, &opts, &mut arena)
                .map_err(|e| anyhow!("{} {kind}: {e}", alg.label()))?;
            certificates.push(cert);
        }
    }
    if certificates.is_empty() {
        bail!("nothing to certify: no requested algorithm supports a requested op");
    }
    let report = CertReport::new(cl, persona.name, &opts, certificates);
    let rendered = match args.flags.get("format").map(String::as_str) {
        None | Some("text") => report.text(),
        Some("json") => report.to_json(),
        Some(other) => bail!("unknown format {other} (formats: text|json)"),
    };
    match args.flags.get("out") {
        Some(path) => {
            write_out(path, &rendered)?;
            eprintln!("wrote {path}");
        }
        None => print!("{rendered}"),
    }
    if report.errors() > 0 {
        bail!("certification found {} error-severity diagnostic(s)", report.errors());
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let nodes = args.flag("nodes", 4u32)?;
    let cores = args.flag("cores", 4u32)?;
    let lanes = args.flag("lanes", 2u32)?;
    let cl = Cluster::new(nodes, cores, lanes);
    let persona = Persona::get(args.persona()?);
    let mut count = 0;
    let (mut warnings, mut infos) = (0, 0);
    // Registry-exhaustive: every registered instance × every operation
    // it supports — a new registration is covered with no edits here.
    for alg in registry().validation_instances(cl) {
        for kind in OpKind::ALL {
            if !alg.supports(kind) {
                continue;
            }
            let c = validation_count(kind);
            let built = alg
                .build(cl, &persona, kind.op(c))
                .map_err(|e| anyhow!("{} {kind}: {e}", alg.label()))?;
            let s = &built.schedule;
            let ports = port_budget(&alg, cl, persona.name, kind, c)?;
            let analysis = analyze(s, &LintConfig::new(ports));
            if let Some(d) = analysis.first_error() {
                bail!("{} {kind}: {}", s.algorithm, d.text_line());
            }
            warnings += analysis.warnings();
            infos += analysis.infos();
            count += 1;
        }
    }
    println!(
        "validated {count} schedules on {nodes}x{cores} (lanes={lanes}): all invariants hold \
         ({warnings} warnings, {infos} infos — `mlane lint` lists them)"
    );
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    let cl = args.cluster()?;
    let op = args.op()?;
    let alg = args.algorithm()?;
    let coll = collectives(cl, args.persona()?, &cfg);
    let built = coll.schedule(op, &alg)?;
    let out = args.flags.get("out").cloned().unwrap_or_else(|| "trace.json".into());
    match parse_backend(args, false)? {
        Backend::Analytic => {
            let trace = mlane::sim::trace::trace_run(&built.schedule, &coll.persona.model, 1);
            std::fs::write(&out, trace.to_chrome_json())?;
            println!(
                "wrote {} ({} spans, makespan {:.2}us) — open in chrome://tracing or Perfetto",
                out,
                trace.spans.len(),
                trace.makespan
            );
        }
        Backend::Event(sc) => {
            let et = mlane::sim::trace::trace_run_event(
                &built.schedule,
                &coll.persona.model,
                &sc,
                1,
            )?;
            std::fs::write(&out, et.to_chrome_json())?;
            println!(
                "wrote {} ({} spans, {} events, makespan {:.2}us) — open in chrome://tracing \
                 or Perfetto",
                out,
                et.trace.spans.len(),
                et.events.len(),
                et.trace.makespan
            );
        }
    }
    Ok(())
}

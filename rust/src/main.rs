//! `mlane` CLI — leader entrypoint for the k-ported / k-lane collective
//! library.
//!
//! ```text
//! mlane table <N> [--persona openmpi|intelmpi|mpich] [--csv DIR]
//! mlane tables [--csv DIR]            # regenerate all 48 tables (2..49)
//! mlane run --op bcast|scatter|gather|allgather|alltoall
//!           --alg <registry name: kported|klane|klane2p|fulllane|bruck|...>
//!           [--k K] [--c C] [--nodes N] [--cores n] [--lanes L]
//!           [--backend sim|exec|xla] [--persona P]
//! mlane autotune --op <op> [--c C] [--nodes N] [--cores n] [--lanes L]
//! mlane compare                       # simulated vs paper anchors
//! mlane trace --op <op> --alg <alg> [--out FILE]  # Chrome trace of one run
//! mlane validate [--nodes N] [--cores n]  # registry-exhaustive invariants
//! mlane algs                          # list the algorithm catalog
//! ```
//!
//! Algorithm names are resolved against `algorithms::registry` — the
//! catalog, candidate sets, validation coverage and this help text all
//! follow a new registration automatically.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use mlane::algorithms::registry::{registry, Alg, OpKind};
use mlane::coordinator::{Collectives, Op};
use mlane::exec::ExecRuntime;
use mlane::harness::{self, anchors};
use mlane::model::{Persona, PersonaName};
use mlane::runtime::XlaService;
use mlane::schedule::validate::{validate, validate_ports};
use mlane::topology::Cluster;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal argument parser: positional command + `--key value` flags.
struct Args {
    cmd: String,
    pos: Vec<String>,
    flags: HashMap<String, String>,
}

fn parse_args() -> Result<Args> {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().unwrap_or_else(|| "help".into());
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    while let Some(a) = argv.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = argv.next().ok_or_else(|| anyhow!("--{key} needs a value"))?;
            flags.insert(key.to_string(), val);
        } else {
            pos.push(a);
        }
    }
    Ok(Args { cmd, pos, flags })
}

impl Args {
    fn flag<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("bad --{key} value: {v}")),
        }
    }

    fn persona(&self) -> Result<PersonaName> {
        Ok(match self.flags.get("persona").map(String::as_str) {
            None | Some("openmpi") => PersonaName::OpenMpi,
            Some("intelmpi") => PersonaName::IntelMpi,
            Some("mpich") => PersonaName::Mpich,
            Some(other) => bail!("unknown persona {other}"),
        })
    }

    fn cluster(&self) -> Result<Cluster> {
        let nodes = self.flag("nodes", 36u32)?;
        let cores = self.flag("cores", 32u32)?;
        let lanes = self.flag("lanes", 2u32)?;
        Ok(Cluster::new(nodes, cores, lanes))
    }

    fn op(&self) -> Result<Op> {
        let c = self.flag("c", 1000u64)?;
        match self.flags.get("op").map(String::as_str) {
            None => Ok(OpKind::Bcast.op(c)),
            Some(name) => match OpKind::parse(name) {
                Some(kind) => Ok(kind.op(c)),
                None => bail!("unknown op {name} (ops: {})", op_names().join("|")),
            },
        }
    }

    /// `--alg`/`--k` resolved against the registry; unknown names and
    /// invalid k come back as typed errors, never panics.
    fn algorithm(&self) -> Result<Alg> {
        let k = self.flag("k", 2u32)?;
        let name = self.flags.get("alg").map(String::as_str).unwrap_or("kported");
        Ok(registry().resolve(name, k)?)
    }
}

fn op_names() -> Vec<&'static str> {
    OpKind::ALL.iter().map(|k| k.name()).collect()
}

fn run() -> Result<()> {
    let args = parse_args()?;
    match args.cmd.as_str() {
        "table" => cmd_table(&args),
        "tables" => cmd_tables(&args),
        "run" => cmd_run(&args),
        "autotune" => cmd_autotune(&args),
        "compare" => cmd_compare(),
        "trace" => cmd_trace(&args),
        "validate" => cmd_validate(&args),
        "algs" => cmd_algs(),
        "help" | "--help" | "-h" => {
            println!("{}", help());
            Ok(())
        }
        other => bail!("unknown command {other} (try `mlane help`)"),
    }
}

/// Help text; the op and algorithm lists are registry-driven so a new
/// registration shows up here without edits.
fn help() -> String {
    format!(
        "mlane — k-ported vs. k-lane collective algorithms (Träff 2020 reproduction)

commands:
  table <N>   regenerate paper table N (2..49)   [--csv DIR]
  tables      regenerate all 48 tables (2..49)   [--csv DIR]
  run         run one collective                 [--op --alg --k --c --nodes --cores --lanes --backend --persona]
  autotune    pick the fastest algorithm         [--op --c --nodes --cores --lanes --persona]
  compare     simulated vs paper anchor cells
  trace       emit a Chrome-trace of one simulated run  [--op --alg ... --out FILE]
  validate    check schedule invariants for the whole catalog  [--nodes --cores --lanes --persona]
  algs        list the algorithm catalog

flags:      --op  {}
            --alg {}

environment: MLANE_REPS         (simulated repetitions, default 20)
             MLANE_THREADS      (table-generation workers, default: available parallelism)
             MLANE_CACHE_SHAPES (shared schedule-cache bound, default 8)",
        op_names().join("|"),
        registry().names().join("|")
    )
}

fn cmd_algs() -> Result<()> {
    println!("algorithm catalog ({} families):", registry().entries().len());
    for e in registry().entries() {
        let ops: Vec<&str> = OpKind::ALL
            .iter()
            .filter(|&&k| e.supports(k))
            .map(|k| k.name())
            .collect();
        println!(
            "  {:<9} {} [{}]{}",
            e.name(),
            e.about(),
            ops.join(", "),
            if e.parameterized() { "  (--k)" } else { "" }
        );
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let n: u32 = args
        .pos
        .first()
        .ok_or_else(|| anyhow!("usage: mlane table <N>"))?
        .parse()
        .context("table number")?;
    let spec = harness::table(n).ok_or_else(|| anyhow!("no table {n} (range 2..49)"))?;
    let out = harness::run_table(&spec);
    print!("{}", out.render());
    if let Some(dir) = args.flags.get("csv") {
        let p = out.write_csv(std::path::Path::new(dir))?;
        eprintln!("csv: {}", p.display());
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let dir = args.flags.get("csv").cloned().unwrap_or_else(|| "bench_out".into());
    // All tables share the harness engine: overlapping sections across
    // tables are served from one cross-table schedule cache.
    for spec in harness::registry() {
        let out = harness::run_table(&spec);
        print!("{}", out.render());
        let p = out.write_csv(std::path::Path::new(&dir))?;
        eprintln!("csv: {}", p.display());
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let cl = args.cluster()?;
    let op = args.op()?;
    let alg = args.algorithm()?;
    let coll = Collectives::new(cl, args.persona()?);
    match args.flags.get("backend").map(String::as_str) {
        Some("sim") | None => {
            let m = coll.run(op, &alg)?;
            println!(
                "{} {} p={} c={}  avg={:.2}us min={:.2}us  ({} reps)",
                op.kind(),
                m.algorithm,
                cl.p(),
                m.c,
                m.summary.avg,
                m.summary.min,
                m.summary.reps
            );
        }
        Some(backend @ ("exec" | "xla")) => {
            let rt = if backend == "xla" {
                ExecRuntime::with_xla(XlaService::start(std::path::Path::new("artifacts"))?)
            } else {
                ExecRuntime::channels()
            };
            let rep = coll.execute(op, &alg, &rt)?;
            println!(
                "{} p={} c={}  wallclock avg={:.2}us min={:.2}us  blocks={} xla_phases={}",
                op.kind(),
                cl.p(),
                op.count(),
                rep.summary.avg,
                rep.summary.min,
                rep.blocks_verified,
                rep.xla_phases
            );
        }
        Some(other) => bail!("unknown backend {other}"),
    }
    Ok(())
}

fn cmd_autotune(args: &Args) -> Result<()> {
    let cl = args.cluster()?;
    let op = args.op()?;
    let coll = Collectives::new(cl, args.persona()?);
    let candidates = coll.default_candidates(op);
    println!(
        "autotune {} c={} on {}x{} (k={} lanes):",
        op.kind(),
        op.count(),
        cl.nodes,
        cl.cores,
        cl.lanes
    );
    for alg in &candidates {
        let m = coll.run(op, alg)?;
        println!("  {:24} avg={:.2}us min={:.2}us", m.algorithm, m.summary.avg, m.summary.min);
    }
    let (best, m) = coll.autotune(op, &candidates)?;
    println!("winner: {} ({:.2}us)", best.label(), m.summary.avg);
    Ok(())
}

fn cmd_compare() -> Result<()> {
    println!("simulated vs paper anchors (ratio = simulated / paper):");
    println!(
        "{:>6} {:<28} {:>9} {:>12} {:>12} {:>7}",
        "table", "section", "c", "paper(us)", "sim(us)", "ratio"
    );
    for c in anchors::compare_all() {
        println!(
            "{:>6} {:<28} {:>9} {:>12.2} {:>12.2} {:>7.2}",
            c.anchor.table,
            c.anchor.section,
            c.anchor.c,
            c.anchor.paper_avg_us,
            c.simulated_avg_us,
            c.ratio
        );
    }
    Ok(())
}

/// Validation element count per operation (kept small: structure, not
/// timing, is under test).
fn validation_count(op: OpKind) -> u64 {
    match op {
        OpKind::Bcast => 64,
        OpKind::Scatter | OpKind::Gather => 16,
        OpKind::Allgather | OpKind::Alltoall => 8,
    }
}

fn cmd_validate(args: &Args) -> Result<()> {
    let nodes = args.flag("nodes", 4u32)?;
    let cores = args.flag("cores", 4u32)?;
    let lanes = args.flag("lanes", 2u32)?;
    let cl = Cluster::new(nodes, cores, lanes);
    let persona = Persona::get(args.persona()?);
    let mut count = 0;
    // Registry-exhaustive: every registered instance × every operation
    // it supports — a new registration is covered with no edits here.
    for alg in registry().validation_instances(cl) {
        for kind in OpKind::ALL {
            if !alg.supports(kind) {
                continue;
            }
            let built = alg
                .build(cl, &persona, kind.op(validation_count(kind)))
                .map_err(|e| anyhow!("{} {kind}: {e}", alg.label()))?;
            let s = &built.schedule;
            validate(s).map_err(|v| anyhow!("{}: {v}", s.algorithm))?;
            validate_ports(s, alg.ports_required(cl, kind))
                .map_err(|v| anyhow!("{} ports: {v}", s.algorithm))?;
            count += 1;
        }
    }
    println!(
        "validated {count} schedules on {nodes}x{cores} (lanes={lanes}): all invariants hold"
    );
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let cl = args.cluster()?;
    let op = args.op()?;
    let alg = args.algorithm()?;
    let coll = Collectives::new(cl, args.persona()?);
    let built = coll.schedule(op, &alg)?;
    let out = args.flags.get("out").cloned().unwrap_or_else(|| "trace.json".into());
    let trace = mlane::sim::trace::trace_run(&built.schedule, &coll.persona.model, 1);
    std::fs::write(&out, trace.to_chrome_json())?;
    println!(
        "wrote {} ({} spans, makespan {:.2}us) — open in chrome://tracing or Perfetto",
        out,
        trace.spans.len(),
        trace.makespan
    );
    Ok(())
}

//! Multi-process plan sharding: shard artifacts and their merge.
//!
//! [`Plan::shard`] splits a plan into `N` disjoint sub-plans; each
//! process runs one sub-plan through the ordinary [`run_plan`] pool and
//! emits a **shard artifact** — a self-describing JSON file carrying
//!
//! * the *full* plan spec (every table, every section — identical in
//!   every shard, so any single artifact documents the whole run),
//! * a **fingerprint** binding the spec *and* the measurement config
//!   (reps/warmup/seed) — shards of different plans or configs can
//!   never be merged into a frankenreport,
//! * the shard coordinates (`shards`, `shard`), and
//! * the measured rows of the sections this shard owns, tagged with
//!   their (table, section) position in the full spec.
//!
//! [`merge_dir`] reassembles a directory of shard artifacts into the
//! [`Report`] a single-process run would have produced — **byte
//! identical** through every sink (text, csv, json;
//! `rust/tests/shard_merge.rs` pins this). That works because cell
//! values depend only on (section spec, model, config) — never on
//! sibling sections, thread count, or process boundaries — and because
//! row numbers round-trip exactly (shortest-round-trip `f64` display,
//! raw-text `u64` parsing, the `tuning::json` reader).
//!
//! Failure is typed, never a panic: fingerprint mismatches, missing or
//! duplicated shards, truncated row sets and malformed files all
//! surface as [`PlanError`] variants (exit 1 at the CLI).
//!
//! `mlane tune` shards ride the same merge entry point: a directory of
//! tune-shard artifacts (written via `tuning::tune_shard_json`) merges
//! into one `TuningBook`, dispatched by the artifact's `kind` field.

use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::algorithms::registry::{self, OpKind};
use crate::model::PersonaName;
use crate::netsim::BackendKind;
use crate::topology::Cluster;
use crate::tuning::{self, json, json::Value};

use super::plan::fnv1a;
use super::report::{table_spec_fields, Report, Sink};
use super::{Plan, PlanError, Row, RunConfig, Section, TableOut, TableSpec};

/// Artifact schema version; bumped on breaking format changes.
const SHARD_VERSION: u64 = 1;

/// Upper bound on the shard count an artifact may declare. Merge-time
/// bookkeeping allocates per declared shard, so a corrupt or forged
/// artifact claiming billions of shards must fail *typed* here rather
/// than abort in the allocator. 64Ki processes is far beyond any real
/// deployment of this tool.
pub const MAX_SHARDS: u32 = 65_536;

/// Same guard for a tune artifact's declared scenario count (merge
/// allocates one slot per scenario).
const MAX_SCENARIOS: usize = 100_000;

/// The `kind` tag of a plan-shard artifact ([`ShardSink`]); tune shards
/// use `tuning::TUNE_SHARD_KIND`.
pub const PLAN_SHARD_KIND: &str = "plan-shard";

/// The full-plan spec as a JSON array (one table object per line — the
/// `JsonSink` layout idiom). This exact text is embedded in every shard
/// artifact and hashed into the fingerprint; at merge time the parsed
/// specs are re-serialized through the same function, so spec equality
/// across artifacts is checked on canonical bytes, not just the hash.
fn spec_array(tables: &[TableSpec]) -> String {
    let mut out = String::from("[");
    for (i, spec) in tables.iter().enumerate() {
        out.push_str(if i == 0 { "\n{" } else { ",\n{" });
        out.push_str(&table_spec_fields(spec));
        out.push('}');
    }
    out.push_str(if tables.is_empty() { "]" } else { "\n]" });
    out
}

/// Fingerprint of (plan spec, measurement config): equal fingerprints
/// are the merge-time proof that two artifacts are shards of the same
/// run. FNV-1a over the spec text plus the config fields that influence
/// cell values (`reps`/`warmup`/`seed`/`backend` with every scenario
/// knob; threads and cache bounds do not change output, by the
/// determinism contract).
pub fn plan_fingerprint(plan: &Plan, cfg: &RunConfig) -> u64 {
    spec_fingerprint(&spec_array(&plan.tables), cfg)
}

/// [`plan_fingerprint`] over already-serialized spec text, so callers
/// that also embed the spec (the sink) serialize it exactly once — the
/// fingerprinted bytes and the embedded bytes cannot drift apart.
fn spec_fingerprint(spec_text: &str, cfg: &RunConfig) -> u64 {
    let mut text = spec_text.to_string();
    text.push_str(&format!(
        "|reps={},warmup={},seed={}|backend={}",
        cfg.reps,
        cfg.warmup,
        cfg.seed,
        cfg.backend.fingerprint_text()
    ));
    fnv1a(text.as_bytes())
}

/// One owned table of a shard: its position in the full plan, its
/// number (cross-checked against incoming `TableOut`s), and the owned
/// sections as (full section index, expected row count).
struct OwnedTable {
    position: usize,
    number: u32,
    sections: Vec<(usize, usize)>,
}

/// A [`Sink`] that emits the shard artifact for one `Plan::shard(n, i)`
/// run. Construct it from the **full** plan plus the shard coordinates,
/// then drive the shard's `Report` through it; `finish` writes the
/// artifact in one piece.
pub struct ShardSink<W: Write> {
    w: W,
    header: String,
    spec: String,
    /// Owned tables not yet received, in plan order.
    expected: Vec<OwnedTable>,
    /// How many of `expected` have been consumed.
    next: usize,
    rows: Vec<String>,
}

impl<W: Write> ShardSink<W> {
    pub fn new(w: W, plan: &Plan, cfg: &RunConfig, shards: u32, index: u32) -> Self {
        assert!(shards >= 1 && index < shards, "invalid shard coordinates");
        let mut expected = Vec::new();
        for (position, spec) in plan.tables.iter().enumerate() {
            let sections: Vec<(usize, usize)> = spec
                .owned_sections(shards, index)
                .into_iter()
                .map(|s| (s, spec.sections[s].counts.len()))
                .collect();
            if !sections.is_empty() {
                expected.push(OwnedTable { position, number: spec.number, sections });
            }
        }
        let spec = spec_array(&plan.tables);
        let header = format!(
            "{{\"version\":{SHARD_VERSION},\"kind\":\"{PLAN_SHARD_KIND}\",\
             \"fingerprint\":\"{:016x}\",\"shards\":{shards},\"shard\":{index},\"spec\":",
            spec_fingerprint(&spec, cfg)
        );
        ShardSink { w, header, spec, expected, next: 0, rows: Vec::new() }
    }

    fn bad(msg: String) -> io::Error {
        io::Error::new(io::ErrorKind::InvalidData, msg)
    }
}

impl<W: Write> Sink for ShardSink<W> {
    fn table(&mut self, t: &TableOut) -> io::Result<()> {
        let owned = self.expected.get(self.next).ok_or_else(|| {
            Self::bad(format!(
                "unexpected table {} — this shard owns {} table(s)",
                t.spec.number,
                self.expected.len()
            ))
        })?;
        if owned.number != t.spec.number {
            return Err(Self::bad(format!(
                "table {} arrived where the shard assignment expects table {}",
                t.spec.number, owned.number
            )));
        }
        let want: usize = owned.sections.iter().map(|(_, n)| n).sum();
        if t.rows.len() != want {
            return Err(Self::bad(format!(
                "table {}: {} rows for {} owned cells",
                t.spec.number,
                t.rows.len(),
                want
            )));
        }
        let mut rows = t.rows.iter();
        for &(section, len) in &owned.sections {
            for _ in 0..len {
                let r = rows.next().expect("length checked above");
                self.rows.push(format!(
                    "{{\"table_index\":{},\"section_index\":{section},\"k\":{},\"n\":{},\
                     \"N\":{},\"p\":{},\"c\":{},\"avg_us\":{},\"min_us\":{}}}",
                    owned.position, r.k, r.n, r.nodes, r.p, r.c, r.avg, r.min
                ));
            }
        }
        self.next += 1;
        Ok(())
    }

    fn finish(&mut self) -> io::Result<()> {
        if self.next != self.expected.len() {
            return Err(Self::bad(format!(
                "shard run incomplete: {} of {} owned tables emitted",
                self.next,
                self.expected.len()
            )));
        }
        self.w.write_all(self.header.as_bytes())?;
        self.w.write_all(self.spec.as_bytes())?;
        self.w.write_all(b",\"rows\":[")?;
        for (i, r) in self.rows.iter().enumerate() {
            self.w.write_all(if i == 0 { b"\n" } else { b",\n" })?;
            self.w.write_all(r.as_bytes())?;
        }
        self.w.write_all(if self.rows.is_empty() { b"]}\n" } else { b"\n]}\n" })?;
        self.w.flush()
    }
}

/// Run-and-write convenience: emit `report` (the result of running
/// `plan.shard(shards, index)`) as a shard artifact at `path`.
pub fn write_shard(
    path: impl AsRef<Path>,
    plan: &Plan,
    cfg: &RunConfig,
    shards: u32,
    index: u32,
    report: &Report,
) -> Result<(), PlanError> {
    let path = path.as_ref();
    let io_err = |e: io::Error| PlanError::ShardIo {
        path: path.to_path_buf(),
        detail: e.to_string(),
    };
    let file = std::fs::File::create(path).map_err(io_err)?;
    let mut sink = ShardSink::new(io::BufWriter::new(file), plan, cfg, shards, index);
    report.emit(&mut sink).map_err(|e| {
        // The sink reports assignment violations (report does not match
        // plan.shard(shards, index)) as InvalidData — surface those as
        // the mismatch they are, not as file I/O trouble.
        if e.kind() == io::ErrorKind::InvalidData {
            PlanError::ShardMismatch { detail: format!("{}: {e}", path.display()) }
        } else {
            io_err(e)
        }
    })
}

// ---- merge ------------------------------------------------------------

/// What a directory of shard artifacts merges into, dispatched by the
/// artifacts' `kind` field.
#[derive(Debug)]
pub enum Merged {
    /// `plan-shard` artifacts: the reassembled plan report.
    Report(Report),
    /// `tune-shard` artifacts: the reassembled decision-table book.
    Book(tuning::TuningBook),
}

/// Strict field access over the mini-parser's [`Value`], with
/// [`PlanError::ShardParse`] errors naming the offending file.
struct Doc<'v> {
    path: &'v Path,
    v: &'v Value,
}

impl<'v> Doc<'v> {
    fn err(&self, detail: String) -> PlanError {
        PlanError::ShardParse { path: self.path.to_path_buf(), detail }
    }

    fn get(&self, key: &str) -> Result<&'v Value, PlanError> {
        self.v.get(key).ok_or_else(|| self.err(format!("missing key {key:?}")))
    }

    fn str(&self, key: &str) -> Result<&'v str, PlanError> {
        self.get(key)?
            .as_str()
            .ok_or_else(|| self.err(format!("{key} must be a string")))
    }

    fn u64(&self, key: &str) -> Result<u64, PlanError> {
        self.get(key)?
            .as_u64()
            .ok_or_else(|| self.err(format!("{key} must be an unsigned integer")))
    }

    fn u32(&self, key: &str) -> Result<u32, PlanError> {
        self.u64(key)?
            .try_into()
            .map_err(|_| self.err(format!("{key} out of u32 range")))
    }

    fn f64(&self, key: &str) -> Result<f64, PlanError> {
        self.get(key)?
            .as_f64()
            .ok_or_else(|| self.err(format!("{key} must be a number")))
    }

    fn arr(&self, key: &str) -> Result<&'v [Value], PlanError> {
        self.get(key)?
            .as_arr()
            .ok_or_else(|| self.err(format!("{key} must be an array")))
    }

    fn sub(&self, v: &'v Value) -> Doc<'v> {
        Doc { path: self.path, v }
    }
}

/// One parsed plan-shard artifact.
struct PlanShard {
    path: PathBuf,
    fingerprint: String,
    /// The embedded spec re-serialized to canonical bytes — compared
    /// *literally* across shards at merge time, so even a colliding or
    /// forged fingerprint cannot splice rows into a different spec.
    spec_text: String,
    shards: u32,
    shard: u32,
    tables: Vec<TableOut>,
    /// (table_index, section_index, row) triples in file order.
    rows: Vec<(usize, usize, Row)>,
}

/// The shard coordinates every artifact kind carries, strictly read
/// and range-checked.
fn shard_coords(doc: &Doc) -> Result<(u32, u32), PlanError> {
    let shards = doc.u32("shards")?;
    let shard = doc.u32("shard")?;
    if shards == 0 || shard >= shards {
        return Err(doc.err(format!("shard {shard} out of range for {shards} shards")));
    }
    if shards > MAX_SHARDS {
        return Err(doc.err(format!("{shards} shards exceeds the supported {MAX_SHARDS}")));
    }
    Ok((shards, shard))
}

/// The shard-set invariants shared by every artifact kind: equal
/// fingerprints and shard counts, no duplicated index, and full
/// coverage of `0..shards`. `metas` is (path, fingerprint, shards,
/// shard) per artifact; callers layer kind-specific checks on top.
fn check_shard_set(metas: &[(&Path, &str, u32, u32)]) -> Result<(), PlanError> {
    let (first_path, first_fp, total, _) = metas[0];
    for &(path, fp, shards, _) in &metas[1..] {
        if fp != first_fp {
            return Err(PlanError::ShardMismatch {
                detail: format!(
                    "{} has fingerprint {} but {} has {} — shards of different runs",
                    first_path.display(),
                    first_fp,
                    path.display(),
                    fp
                ),
            });
        }
        if shards != total {
            return Err(PlanError::ShardMismatch {
                detail: format!(
                    "{} says {total} shards but {} says {shards}",
                    first_path.display(),
                    path.display()
                ),
            });
        }
    }
    // total <= MAX_SHARDS by shard_coords, so this allocation is bounded.
    let mut seen: Vec<Option<&Path>> = vec![None; total as usize];
    for &(path, _, _, shard) in metas {
        if let Some(prev) = seen[shard as usize] {
            return Err(PlanError::ShardMismatch {
                detail: format!(
                    "shard {shard} appears in both {} and {}",
                    prev.display(),
                    path.display()
                ),
            });
        }
        seen[shard as usize] = Some(path);
    }
    let missing: Vec<u32> = (0..total).filter(|&i| seen[i as usize].is_none()).collect();
    if !missing.is_empty() {
        return Err(PlanError::ShardIncomplete { missing, shards: total });
    }
    Ok(())
}

fn parse_plan_shard(path: &Path, v: &Value) -> Result<PlanShard, PlanError> {
    let doc = Doc { path, v };
    let fingerprint = doc.str("fingerprint")?.to_string();
    let (shards, shard) = shard_coords(&doc)?;

    let mut specs: Vec<TableSpec> = Vec::new();
    for tv in doc.arr("spec")? {
        let td = doc.sub(tv);
        let number = td.u32("table")?;
        let caption = td.str("caption")?.to_string();
        let persona_key = td.str("persona")?;
        let persona = PersonaName::parse(persona_key)
            .ok_or_else(|| doc.err(format!("unknown persona {persona_key:?}")))?;
        let mut sections = Vec::new();
        for sv in td.arr("sections")? {
            let sd = doc.sub(sv);
            let heading = sd.str("heading")?.to_string();
            let (nodes, cores, lanes) = (sd.u32("nodes")?, sd.u32("cores")?, sd.u32("lanes")?);
            if nodes == 0 || cores == 0 || lanes == 0 {
                return Err(doc.err(format!("table {number}: degenerate cluster dimensions")));
            }
            let op_name = sd.str("op")?;
            let op = OpKind::parse(op_name)
                .ok_or_else(|| doc.err(format!("unknown op {op_name:?}")))?;
            let alg_name = sd.str("alg")?;
            let k = match sd.get("k")? {
                Value::Null => 0,
                _ => sd.u32("k")?,
            };
            let alg = registry::registry()
                .resolve(alg_name, k)
                .map_err(|e| doc.err(format!("table {number}: {e}")))?;
            let counts: Vec<u64> = sd
                .arr("counts")?
                .iter()
                .map(|c| c.as_u64())
                .collect::<Option<_>>()
                .ok_or_else(|| doc.err(format!("table {number}: counts must be u64s")))?;
            sections.push(Section {
                heading,
                cluster: Cluster::new(nodes, cores, lanes),
                op,
                alg,
                counts: Arc::from(&counts[..]),
            });
        }
        specs.push(TableSpec { number, caption, persona, sections });
    }
    let spec_text = spec_array(&specs);
    let tables: Vec<TableOut> =
        specs.into_iter().map(|spec| TableOut { spec, rows: Vec::new() }).collect();

    let mut rows = Vec::new();
    for rv in doc.arr("rows")? {
        let rd = doc.sub(rv);
        let t = rd.u64("table_index")? as usize;
        let s = rd.u64("section_index")? as usize;
        let sec = tables
            .get(t)
            .and_then(|tab| tab.spec.sections.get(s))
            .ok_or_else(|| doc.err(format!("row references unknown section ({t}, {s})")))?;
        rows.push((
            t,
            s,
            Row {
                section: sec.heading.clone(),
                k: rd.u32("k")?,
                n: rd.u32("n")?,
                nodes: rd.u32("N")?,
                p: rd.u32("p")?,
                c: rd.u64("c")?,
                avg: rd.f64("avg_us")?,
                min: rd.f64("min_us")?,
            },
        ));
    }

    Ok(PlanShard {
        path: path.to_path_buf(),
        fingerprint,
        spec_text,
        shards,
        shard,
        tables,
        rows,
    })
}

fn merge_plan_shards(mut shards: Vec<PlanShard>) -> Result<Report, PlanError> {
    let metas: Vec<(&Path, &str, u32, u32)> = shards
        .iter()
        .map(|s| (s.path.as_path(), s.fingerprint.as_str(), s.shards, s.shard))
        .collect();
    check_shard_set(&metas)?;
    drop(metas);
    // Stronger than the (non-cryptographic) fingerprint: the embedded
    // specs must agree byte for byte before any rows are spliced.
    if let Some(s) = shards[1..].iter().find(|s| s.spec_text != shards[0].spec_text) {
        return Err(PlanError::ShardMismatch {
            detail: format!(
                "{} embeds a different plan spec than {} despite equal fingerprints",
                s.path.display(),
                shards[0].path.display()
            ),
        });
    }

    // Reassemble: bucket rows by (table, section) across all shards,
    // then validate each bucket against its count series — exactly one
    // row per (section, count), in count order.
    let mut tables: Vec<TableOut> = std::mem::take(&mut shards[0].tables);
    let mut buckets: Vec<Vec<Vec<Row>>> = tables
        .iter()
        .map(|t| t.spec.sections.iter().map(|_| Vec::new()).collect())
        .collect();
    for s in &mut shards {
        let path = s.path.clone();
        for (t, sec, row) in s.rows.drain(..) {
            // Indices were validated against each shard's own spec, but
            // only fingerprint equality ties the specs together — a
            // forged fingerprint must fail typed, not out-of-bounds.
            let bucket = buckets.get_mut(t).and_then(|b| b.get_mut(sec)).ok_or_else(
                || PlanError::ShardParse {
                    path: path.clone(),
                    detail: format!("row references section ({t}, {sec}) absent from the spec"),
                },
            )?;
            bucket.push(row);
        }
    }
    for (t, table) in tables.iter_mut().enumerate() {
        for (si, sec) in table.spec.sections.iter().enumerate() {
            let got = &buckets[t][si];
            let want: Vec<u64> = sec.counts.to_vec();
            let got_counts: Vec<u64> = got.iter().map(|r| r.c).collect();
            if got_counts != want {
                return Err(PlanError::ShardMismatch {
                    detail: format!(
                        "table {}, section {:?}: merged rows cover counts {:?} but the \
                         spec sweeps {:?} (truncated or duplicated shard run?)",
                        table.spec.number, sec.heading, got_counts, want
                    ),
                });
            }
        }
        for bucket in std::mem::take(&mut buckets[t]) {
            table.rows.extend(bucket);
        }
    }
    Ok(Report { tables })
}

/// One parsed tune-shard artifact (`mlane tune --shards N`).
struct TuneShard {
    path: PathBuf,
    fingerprint: String,
    shards: u32,
    shard: u32,
    scenario_count: usize,
    /// (global scenario index, its decision table) pairs, ascending.
    tables: Vec<(usize, tuning::DecisionTable)>,
    tune: tuning::TuneConfig,
}

fn parse_tune_shard(path: &Path, v: &Value) -> Result<TuneShard, PlanError> {
    let doc = Doc { path, v };
    let fingerprint = doc.str("fingerprint")?.to_string();
    let (shards, shard) = shard_coords(&doc)?;
    let scenario_count = doc.u64("scenario_count")? as usize;
    if scenario_count > MAX_SCENARIOS {
        return Err(doc.err(format!(
            "scenario_count {scenario_count} exceeds the supported {MAX_SCENARIOS}"
        )));
    }
    let tune_v = doc.get("tune")?;
    let td = doc.sub(tune_v);
    // Older artifacts predate the backend tag; absent means analytic.
    let backend = match tune_v.get("backend") {
        None => BackendKind::Analytic,
        Some(b) => {
            let s = b
                .as_str()
                .ok_or_else(|| doc.err("tune.backend must be a string".into()))?;
            BackendKind::parse(s)
                .ok_or_else(|| doc.err(format!("unknown tune backend {s:?}")))?
        }
    };
    let tune = tuning::TuneConfig {
        reps: td.u64("reps")? as usize,
        warmup: td.u64("warmup")? as usize,
        seed: td.u64("seed")?,
        backend,
    };
    let indices: Vec<usize> = doc
        .arr("indices")?
        .iter()
        .map(|i| i.as_u64().map(|n| n as usize))
        .collect::<Option<_>>()
        .ok_or_else(|| doc.err("indices must be unsigned integers".into()))?;
    let tables_v = doc.arr("tables")?;
    if indices.len() != tables_v.len() {
        return Err(doc.err(format!(
            "{} indices for {} tables",
            indices.len(),
            tables_v.len()
        )));
    }
    if indices.windows(2).any(|w| w[0] >= w[1]) {
        return Err(doc.err("indices must be strictly ascending".into()));
    }
    if indices.last().is_some_and(|&i| i >= scenario_count) {
        return Err(doc.err(format!("index beyond scenario_count {scenario_count}")));
    }
    let mut tables = Vec::with_capacity(tables_v.len());
    for (&i, tv) in indices.iter().zip(tables_v) {
        let table = tuning::DecisionTable::from_value(tv)
            .map_err(|e| doc.err(e.to_string()))?;
        tables.push((i, table));
    }
    Ok(TuneShard {
        path: path.to_path_buf(),
        fingerprint,
        shards,
        shard,
        scenario_count,
        tables,
        tune,
    })
}

fn merge_tune_shards(shards: Vec<TuneShard>) -> Result<tuning::TuningBook, PlanError> {
    let metas: Vec<(&Path, &str, u32, u32)> = shards
        .iter()
        .map(|s| (s.path.as_path(), s.fingerprint.as_str(), s.shards, s.shard))
        .collect();
    check_shard_set(&metas)?;
    drop(metas);
    let first = &shards[0];
    for s in &shards[1..] {
        // Belt-and-braces beyond the fingerprint: the tune parameters
        // and scenario universe must agree literally.
        if s.scenario_count != first.scenario_count || s.tune != first.tune {
            return Err(PlanError::ShardMismatch {
                detail: format!(
                    "{} and {} are shards of different tune runs",
                    first.path.display(),
                    s.path.display()
                ),
            });
        }
    }
    let scenario_count = first.scenario_count;
    let tune = first.tune;
    let mut slots: Vec<Option<tuning::DecisionTable>> = (0..scenario_count).map(|_| None).collect();
    for s in shards {
        for (i, table) in s.tables {
            if slots[i].replace(table).is_some() {
                return Err(PlanError::ShardMismatch {
                    detail: format!("scenario {i} tuned by more than one shard"),
                });
            }
        }
    }
    let holes: Vec<String> = slots
        .iter()
        .enumerate()
        .filter(|(_, t)| t.is_none())
        .map(|(i, _)| i.to_string())
        .collect();
    if !holes.is_empty() {
        return Err(PlanError::ShardMismatch {
            detail: format!(
                "scenario{} {} not covered by any shard (truncated run?)",
                if holes.len() == 1 { "" } else { "s" },
                holes.join(", ")
            ),
        });
    }
    let book = tuning::TuningBook {
        tune,
        tables: slots.into_iter().map(|t| t.expect("holes checked")).collect(),
    };
    book.validate().map_err(|e| PlanError::ShardMismatch { detail: e.to_string() })?;
    Ok(book)
}

/// Merge every shard artifact (`*.json`) under `dir` back into the
/// single-process result: a plan [`Report`] or a tune
/// [`tuning::TuningBook`], depending on the artifacts' `kind`. All the
/// artifact cross-checks (same fingerprint, complete disjoint shard
/// set, full row coverage) are typed [`PlanError`]s.
pub fn merge_dir(dir: impl AsRef<Path>) -> Result<Merged, PlanError> {
    let dir = dir.as_ref();
    let io_err = |e: io::Error| PlanError::ShardIo {
        path: dir.to_path_buf(),
        detail: e.to_string(),
    };
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(io_err)?
        .collect::<Result<Vec<_>, _>>()
        .map_err(io_err)?
        .into_iter()
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "json") && p.is_file())
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(PlanError::ShardIo {
            path: dir.to_path_buf(),
            detail: "no shard artifacts (*.json) found".into(),
        });
    }

    let mut docs = Vec::with_capacity(paths.len());
    for path in paths {
        let text = std::fs::read_to_string(&path).map_err(|e| PlanError::ShardIo {
            path: path.clone(),
            detail: e.to_string(),
        })?;
        let v = json::parse(&text)
            .map_err(|e| PlanError::ShardParse { path: path.clone(), detail: e })?;
        let doc = Doc { path: &path, v: &v };
        let version = doc.u64("version")?;
        if version != SHARD_VERSION {
            return Err(PlanError::ShardParse {
                path,
                detail: format!("unsupported shard version {version}"),
            });
        }
        let kind = doc.str("kind")?.to_string();
        docs.push((path, v, kind));
    }
    let kind = docs[0].2.clone();
    if let Some((path, _, other)) = docs.iter().find(|(_, _, k)| *k != kind) {
        return Err(PlanError::ShardMismatch {
            detail: format!(
                "{} is a {} artifact among {} artifacts",
                path.display(),
                other,
                kind
            ),
        });
    }
    match kind.as_str() {
        PLAN_SHARD_KIND => {
            let shards = docs
                .iter()
                .map(|(p, v, _)| parse_plan_shard(p, v))
                .collect::<Result<Vec<_>, _>>()?;
            merge_plan_shards(shards).map(Merged::Report)
        }
        tuning::TUNE_SHARD_KIND => {
            let shards = docs
                .iter()
                .map(|(p, v, _)| parse_tune_shard(p, v))
                .collect::<Result<Vec<_>, _>>()?;
            merge_tune_shards(shards).map(Merged::Book)
        }
        other => Err(PlanError::ShardParse {
            path: docs[0].0.clone(),
            detail: format!("unknown artifact kind {other:?}"),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::super::{run_plan_with, Grid};
    use super::*;
    use crate::sim::SweepEngine;

    fn tiny_plan() -> Plan {
        let grid = Grid::new()
            .cluster(Cluster::new(2, 4, 2))
            .op(OpKind::Bcast)
            .algs([registry::klane(1), registry::klane(2), registry::fulllane()])
            .counts(&[1, 600]);
        Plan::new().table(1, "shard unit-test grid", PersonaName::OpenMpi, &grid)
    }

    fn cfg() -> RunConfig {
        RunConfig::default().reps(2).warmup(0)
    }

    #[test]
    fn fingerprint_binds_spec_and_config() {
        let plan = tiny_plan();
        let a = plan_fingerprint(&plan, &cfg());
        assert_eq!(a, plan_fingerprint(&plan, &cfg()), "deterministic");
        assert_ne!(a, plan_fingerprint(&plan, &cfg().reps(3)), "reps in fingerprint");
        assert_ne!(a, plan_fingerprint(&plan, &cfg().seed(1)), "seed in fingerprint");
        let other = Plan::new().table(
            2,
            "different",
            PersonaName::OpenMpi,
            &Grid::new()
                .cluster(Cluster::new(2, 4, 2))
                .op(OpKind::Bcast)
                .alg(registry::klane(1))
                .counts(&[1]),
        );
        assert_ne!(a, plan_fingerprint(&other, &cfg()), "spec in fingerprint");
        // Thread count must NOT shard the fingerprint: output is
        // thread-independent, so shards may use different pool sizes.
        assert_eq!(a, plan_fingerprint(&plan, &cfg().threads(7)));
        // The backend (and each scenario knob) measures different
        // numbers, so it must shard the fingerprint.
        use crate::netsim::{Backend, Scenario};
        let ev = plan_fingerprint(&plan, &cfg().backend(Backend::Event(Scenario::contended())));
        assert_ne!(a, ev, "backend in fingerprint");
        let mut sc = Scenario::contended();
        sc.tenant_flows += 1;
        assert_ne!(
            ev,
            plan_fingerprint(&plan, &cfg().backend(Backend::Event(sc))),
            "scenario knobs in fingerprint"
        );
    }

    #[test]
    fn shard_sink_rejects_tables_outside_its_assignment() {
        let plan = tiny_plan();
        let engine = Arc::new(SweepEngine::new());
        let report = run_plan_with(&engine, &plan, &cfg()).unwrap();
        // A full-plan report fed to a 2-shard sink has too many rows.
        let mut buf = Vec::new();
        let mut sink = ShardSink::new(&mut buf, &plan, &cfg(), 2, 0);
        let err = report.emit(&mut sink).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{err}");
    }

    #[test]
    fn empty_shard_artifact_round_trips() {
        // A plan with fewer sections than shards leaves some shard
        // empty; its artifact must still write and merge-parse.
        let plan = Plan::new().table(
            9,
            "one section",
            PersonaName::Mpich,
            &Grid::new()
                .cluster(Cluster::new(2, 2, 1))
                .op(OpKind::Bcast)
                .alg(registry::fulllane())
                .counts(&[1]),
        );
        let shards = 4u32;
        let empties: Vec<u32> = (0..shards)
            .filter(|&i| plan.shard(shards, i).tables.is_empty())
            .collect();
        assert!(!empties.is_empty(), "expected at least one empty shard");
        let i = empties[0];
        let empty = plan.shard(shards, i);
        let report =
            run_plan_with(&Arc::new(SweepEngine::new()), &empty, &cfg()).unwrap();
        let mut buf = Vec::new();
        report.emit(&mut ShardSink::new(&mut buf, &plan, &cfg(), shards, i)).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("\"rows\":[]"), "{text}");
        let v = json::parse(&text).unwrap();
        let parsed = parse_plan_shard(Path::new("mem"), &v).unwrap();
        assert_eq!(parsed.rows.len(), 0);
        assert_eq!(parsed.tables.len(), 1, "spec still carries the full plan");
    }
}

//! Table-regeneration harness: every table in the paper's evaluation
//! (Tables 2–49) has a [`TableSpec`] here; running it prints the same
//! rows (k, n, N, p, c, avg µs, min µs) the paper reports and writes a
//! CSV under `bench_out/`.
//!
//! Table numbering follows the paper exactly:
//! * 2–7 — §4.1 node-vs-network alltoall at p = 32 (k-ported / native,
//!   per library);
//! * 8–22 — §4.2 broadcast (k-lane k=1..6, k-ported k=1..6, full-lane +
//!   native; × three libraries);
//! * 23–37 — §4.3 scatter (same grid);
//! * 38–49 — §4.4 alltoall (k-lane, k-ported k=1..6, full-lane + native;
//!   × three libraries).
//!
//! Sections name their algorithm as a registry handle
//! (`algorithms::registry::Alg`), so the specs track the catalog — a
//! newly registered algorithm needs no harness changes to be swept.
//!
//! ## Environment
//!
//! * `MLANE_REPS` — simulated repetitions per cell (default 20; the
//!   paper uses 100, see `sim::PAPER_REPS`).
//! * `MLANE_THREADS` — worker threads for table generation (default:
//!   available parallelism). Workers process whole sections, so every
//!   count sweep stays on one warm shape; output row order is
//!   deterministic regardless of the thread count.
//! * `MLANE_CACHE_SHAPES` — bound on the shared schedule cache (see
//!   `sim::sweep`).
//!
//! All tables run against one process-wide [`SweepEngine`]
//! ([`shared_engine`]): sections of one table and repeated/overlapping
//! tables (`mlane tables`, any persona mix) share cached schedules.
//! Pass an explicit engine with [`run_table_with`] for isolated runs.

pub mod anchors;

use std::fmt::Write as _;
use std::io::Write as _;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::algorithms::registry::{self, Alg, OpKind};
use crate::coordinator::Collectives;
use crate::model::PersonaName;
use crate::sim::SweepEngine;
use crate::topology::Cluster;

/// Count sweeps used by the paper (§4.2–4.4; MPI_INT elements).
pub const BCAST_COUNTS: &[u64] =
    &[1, 6, 10, 60, 100, 600, 1000, 6000, 10000, 60000, 100000, 600000, 1000000];
pub const SCATTER_COUNTS: &[u64] = &[1, 6, 9, 53, 87, 521, 869];
pub const ALLTOALL_COUNTS: &[u64] = &[1, 6, 9, 53, 87, 521, 869];
/// §4.1 sweep (p = 32).
pub const NODE_VS_NET_COUNTS: &[u64] =
    &[1, 2, 4, 19, 32, 188, 313, 1875, 3125, 18750, 31250];

/// One series within a table (the paper's tables stack 1–3 of these).
#[derive(Clone, Debug)]
pub struct Section {
    pub heading: String,
    pub cluster: Cluster,
    pub op: OpKind,
    pub alg: Alg,
    pub counts: &'static [u64],
}

#[derive(Clone, Debug)]
pub struct TableSpec {
    /// Paper table number (2–49).
    pub number: u32,
    pub caption: String,
    pub persona: PersonaName,
    pub sections: Vec<Section>,
}

/// One output row, matching the paper's columns.
#[derive(Clone, Debug)]
pub struct Row {
    pub section: String,
    pub k: u32,
    pub n: u32,
    pub nodes: u32,
    pub p: u32,
    pub c: u64,
    pub avg: f64,
    pub min: f64,
}

pub struct TableOut {
    pub spec: TableSpec,
    pub rows: Vec<Row>,
}

/// The process-wide sweep engine behind `run_table`: the cross-table
/// schedule cache. Personas are isolated by the engine's
/// model-fingerprinted keys; size is bounded by `MLANE_CACHE_SHAPES`.
pub fn shared_engine() -> Arc<SweepEngine> {
    static ENGINE: OnceLock<Arc<SweepEngine>> = OnceLock::new();
    ENGINE.get_or_init(|| Arc::new(SweepEngine::new())).clone()
}

/// Worker threads for table generation: `MLANE_THREADS` if set (> 0),
/// else the machine's available parallelism.
pub fn sweep_threads() -> usize {
    std::env::var("MLANE_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

/// One section's count sweep. The `Collectives` shares the engine (so
/// shapes persist across sections and tables) but owns its rep state —
/// no allocation inside the sweep, no cross-thread contention except on
/// a shared shape.
fn run_section(engine: &Arc<SweepEngine>, persona: PersonaName, sec: &Section) -> Vec<Row> {
    let coll = Collectives::with_engine(sec.cluster, persona, engine.clone());
    sec.counts
        .iter()
        .map(|&c| {
            // Spec sections come from the registry, so a build failure
            // here is a broken spec, not user input — fail loudly.
            let m = coll
                .run(sec.op.op(c), &sec.alg)
                .unwrap_or_else(|e| panic!("section {}: {e}", sec.heading));
            Row {
                section: sec.heading.clone(),
                k: m.k,
                n: sec.cluster.cores,
                nodes: sec.cluster.nodes,
                p: sec.cluster.p(),
                c,
                avg: m.summary.avg,
                min: m.summary.min,
            }
        })
        .collect()
}

/// Run every section of a table on the simulator, against the shared
/// cross-table engine. Sections run across scoped worker threads (see
/// [`sweep_threads`]); rows come back in section order, identical to a
/// serial run.
pub fn run_table(spec: &TableSpec) -> TableOut {
    run_table_with(&shared_engine(), spec)
}

/// [`run_table`] against a caller-provided engine (isolated caches for
/// tests and benchmarks).
pub fn run_table_with(engine: &Arc<SweepEngine>, spec: &TableSpec) -> TableOut {
    let sections = &spec.sections;
    let workers = sweep_threads().min(sections.len()).max(1);

    let rows: Vec<Vec<Row>> = if workers <= 1 {
        sections.iter().map(|sec| run_section(engine, spec.persona, sec)).collect()
    } else {
        // Work-stealing over section indices; each worker returns
        // (index, rows) pairs so ordering is reassembled exactly.
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut done = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= sections.len() {
                                break;
                            }
                            done.push((i, run_section(engine, spec.persona, &sections[i])));
                        }
                        done
                    })
                })
                .collect();
            let mut slots: Vec<Option<Vec<Row>>> =
                (0..sections.len()).map(|_| None).collect();
            for h in handles {
                for (i, rows) in h.join().expect("table worker panicked") {
                    slots[i] = Some(rows);
                }
            }
            slots.into_iter().map(|s| s.expect("section not processed")).collect()
        })
    };

    TableOut { spec: spec.clone(), rows: rows.into_iter().flatten().collect() }
}

impl TableOut {
    /// Paper-style text rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "Table {}: {} [{}]",
            self.spec.number,
            self.spec.caption,
            self.spec.persona.label()
        );
        let mut current = String::new();
        for r in &self.rows {
            if r.section != current {
                current = r.section.clone();
                let _ = writeln!(out, "  -- {current} --");
                let _ = writeln!(
                    out,
                    "  {:>2} {:>4} {:>4} {:>5} {:>9} {:>12} {:>12}",
                    "k", "n", "N", "p", "c", "avg(us)", "min(us)"
                );
            }
            let _ = writeln!(
                out,
                "  {:>2} {:>4} {:>4} {:>5} {:>9} {:>12.2} {:>12.2}",
                r.k, r.n, r.nodes, r.p, r.c, r.avg, r.min
            );
        }
        out
    }

    /// Write CSV to `bench_out/table_<nn>.csv`.
    pub fn write_csv(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("table_{:02}.csv", self.spec.number));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "table,persona,section,k,n,N,p,c,avg_us,min_us")?;
        for r in &self.rows {
            writeln!(
                f,
                "{},{},{},{},{},{},{},{},{:.2},{:.2}",
                self.spec.number,
                self.spec.persona.label(),
                r.section,
                r.k,
                r.n,
                r.nodes,
                r.p,
                r.c,
                r.avg,
                r.min
            )?;
        }
        Ok(path)
    }
}

fn hydra() -> Cluster {
    Cluster::hydra(2)
}

fn persona_ord(i: usize) -> PersonaName {
    [PersonaName::OpenMpi, PersonaName::IntelMpi, PersonaName::Mpich][i]
}

/// The full registry: every table of the paper. Algorithms are looked
/// up in `algorithms::registry` by name — the specs carry no algorithm
/// enumeration of their own.
pub fn registry() -> Vec<TableSpec> {
    let mut tables = Vec::new();

    // ---- §4.1: Tables 2–7 (node vs network, p = 32) ----
    let net32 = Cluster::new(32, 1, 2); // N=32, n=1 (both rails usable, §4.1)
    let node32 = Cluster::new(1, 32, 2); // N=1, n=32
    for &(kported, base) in &[(true, 2u32), (false, 3u32)] {
        for pi in 0..3 {
            let number = base + (pi as u32) * 2;
            let (label, alg) = if kported {
                ("k-ported alltoall", registry::kported(31))
            } else {
                ("MPI_Alltoall", registry::native())
            };
            tables.push(TableSpec {
                number,
                caption: format!("{label}, N=32/n=1 vs N=1/n=32, p=32"),
                persona: persona_ord(pi),
                sections: vec![
                    Section {
                        heading: format!("{label} N=32"),
                        cluster: net32,
                        op: OpKind::Alltoall,
                        alg: alg.clone(),
                        counts: NODE_VS_NET_COUNTS,
                    },
                    Section {
                        heading: format!("{label} N=1"),
                        cluster: node32,
                        op: OpKind::Alltoall,
                        alg,
                        counts: NODE_VS_NET_COUNTS,
                    },
                ],
            });
        }
    }

    // ---- §4.2: Tables 8–22 (bcast) ----
    for pi in 0..3u32 {
        let base = 8 + pi * 5;
        let persona = persona_ord(pi as usize);
        let klane_sec = |ks: std::ops::RangeInclusive<u32>| -> Vec<Section> {
            ks.map(|k| Section {
                heading: format!("Bcast, k = {k} lanes"),
                cluster: hydra(),
                op: OpKind::Bcast,
                alg: registry::klane(k),
                counts: BCAST_COUNTS,
            })
            .collect()
        };
        let kported_sec = |ks: std::ops::RangeInclusive<u32>| -> Vec<Section> {
            ks.map(|k| Section {
                heading: format!("Bcast, {k}-ported"),
                cluster: hydra(),
                op: OpKind::Bcast,
                alg: registry::kported(k),
                counts: BCAST_COUNTS,
            })
            .collect()
        };
        tables.push(TableSpec {
            number: base,
            caption: "k-lane Bcast for k=1,2,3 on Hydra".into(),
            persona,
            sections: klane_sec(1..=3),
        });
        tables.push(TableSpec {
            number: base + 1,
            caption: "k-lane Bcast for k=4,5,6 on Hydra".into(),
            persona,
            sections: klane_sec(4..=6),
        });
        tables.push(TableSpec {
            number: base + 2,
            caption: "k-ported Bcast for k=1,2,3 on Hydra".into(),
            persona,
            sections: kported_sec(1..=3),
        });
        tables.push(TableSpec {
            number: base + 3,
            caption: "k-ported Bcast for k=4,5,6 on Hydra".into(),
            persona,
            sections: kported_sec(4..=6),
        });
        tables.push(TableSpec {
            number: base + 4,
            caption: "full-lane Bcast and native MPI_Bcast on Hydra".into(),
            persona,
            sections: vec![
                Section {
                    heading: "Full-lane Bcast".into(),
                    cluster: hydra(),
                    op: OpKind::Bcast,
                    alg: registry::fulllane(),
                    counts: BCAST_COUNTS,
                },
                Section {
                    heading: "MPI_Bcast".into(),
                    cluster: hydra(),
                    op: OpKind::Bcast,
                    alg: registry::native(),
                    counts: BCAST_COUNTS,
                },
            ],
        });
    }

    // ---- §4.3: Tables 23–37 (scatter) ----
    for pi in 0..3u32 {
        let base = 23 + pi * 5;
        let persona = persona_ord(pi as usize);
        let klane_sec = |ks: std::ops::RangeInclusive<u32>| -> Vec<Section> {
            ks.map(|k| Section {
                heading: format!("Scatter, {k} lane{}", if k == 1 { "" } else { "s" }),
                cluster: hydra(),
                op: OpKind::Scatter,
                alg: registry::klane(k),
                counts: SCATTER_COUNTS,
            })
            .collect()
        };
        let kported_sec = |ks: std::ops::RangeInclusive<u32>| -> Vec<Section> {
            ks.map(|k| Section {
                heading: format!("Scatter, {k}-ported"),
                cluster: hydra(),
                op: OpKind::Scatter,
                alg: registry::kported(k),
                counts: SCATTER_COUNTS,
            })
            .collect()
        };
        tables.push(TableSpec {
            number: base,
            caption: "k-lane Scatter for k=1,2,3 on Hydra".into(),
            persona,
            sections: klane_sec(1..=3),
        });
        tables.push(TableSpec {
            number: base + 1,
            caption: "k-lane Scatter for k=4,5,6 on Hydra".into(),
            persona,
            sections: klane_sec(4..=6),
        });
        tables.push(TableSpec {
            number: base + 2,
            caption: "k-ported Scatter for k=1,2,3 on Hydra".into(),
            persona,
            sections: kported_sec(1..=3),
        });
        tables.push(TableSpec {
            number: base + 3,
            caption: "k-ported Scatter for k=4,5,6 on Hydra".into(),
            persona,
            sections: kported_sec(4..=6),
        });
        tables.push(TableSpec {
            number: base + 4,
            caption: "full-lane Scatter and native MPI_Scatter on Hydra".into(),
            persona,
            sections: vec![
                Section {
                    heading: "Full-lane Scatter".into(),
                    cluster: hydra(),
                    op: OpKind::Scatter,
                    alg: registry::fulllane(),
                    counts: SCATTER_COUNTS,
                },
                Section {
                    heading: "MPI_Scatter".into(),
                    cluster: hydra(),
                    op: OpKind::Scatter,
                    alg: registry::native(),
                    counts: SCATTER_COUNTS,
                },
            ],
        });
    }

    // ---- §4.4: Tables 38–49 (alltoall) ----
    for pi in 0..3u32 {
        let base = 38 + pi * 4;
        let persona = persona_ord(pi as usize);
        let kported_sec = |ks: std::ops::RangeInclusive<u32>| -> Vec<Section> {
            ks.map(|k| Section {
                heading: format!("Alltoall, {k}-ported"),
                cluster: hydra(),
                op: OpKind::Alltoall,
                alg: registry::kported(k),
                counts: ALLTOALL_COUNTS,
            })
            .collect()
        };
        tables.push(TableSpec {
            number: base,
            caption: "k-lane Alltoall (32 virtual lanes) on Hydra".into(),
            persona,
            sections: vec![Section {
                heading: "Alltoall, 32 virtual lanes".into(),
                cluster: hydra(),
                op: OpKind::Alltoall,
                alg: registry::klane(1),
                counts: ALLTOALL_COUNTS,
            }],
        });
        tables.push(TableSpec {
            number: base + 1,
            caption: "k-ported Alltoall for k=1,2,3 on Hydra".into(),
            persona,
            sections: kported_sec(1..=3),
        });
        tables.push(TableSpec {
            number: base + 2,
            caption: "k-ported Alltoall for k=4,5,6 on Hydra".into(),
            persona,
            sections: kported_sec(4..=6),
        });
        tables.push(TableSpec {
            number: base + 3,
            caption: "full-lane Alltoall and native MPI_Alltoall on Hydra".into(),
            persona,
            sections: vec![
                Section {
                    heading: "Full-lane Alltoall".into(),
                    cluster: hydra(),
                    op: OpKind::Alltoall,
                    alg: registry::fulllane(),
                    counts: ALLTOALL_COUNTS,
                },
                Section {
                    heading: "MPI_Alltoall".into(),
                    cluster: hydra(),
                    op: OpKind::Alltoall,
                    alg: registry::native(),
                    counts: ALLTOALL_COUNTS,
                },
            ],
        });
    }

    tables.sort_by_key(|t| t.number);
    tables
}

/// Look up one table by paper number.
pub fn table(number: u32) -> Option<TableSpec> {
    registry().into_iter().find(|t| t.number == number)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_tables_2_through_49() {
        let r = registry();
        assert_eq!(r.len(), 48);
        let numbers: Vec<u32> = r.iter().map(|t| t.number).collect();
        assert_eq!(numbers, (2..=49).collect::<Vec<_>>());
    }

    #[test]
    fn personas_cycle_correctly() {
        // Table 8 = Open MPI, 13 = Intel, 18 = mpich (paper order).
        assert_eq!(table(8).unwrap().persona, PersonaName::OpenMpi);
        assert_eq!(table(13).unwrap().persona, PersonaName::IntelMpi);
        assert_eq!(table(18).unwrap().persona, PersonaName::Mpich);
        // Alltoall: 38 open, 42 intel, 46 mpich.
        assert_eq!(table(38).unwrap().persona, PersonaName::OpenMpi);
        assert_eq!(table(46).unwrap().persona, PersonaName::Mpich);
    }

    #[test]
    fn node_vs_net_tables_use_p32() {
        let t = table(2).unwrap();
        for s in &t.sections {
            assert_eq!(s.cluster.p(), 32);
        }
    }

    #[test]
    fn small_table_runs_and_renders() {
        // Shrink to one tiny section for test speed.
        let mut t = table(12).unwrap();
        t.sections.truncate(1);
        t.sections[0].cluster = Cluster::new(3, 4, 2);
        t.sections[0].counts = &[1, 600];
        std::env::set_var("MLANE_REPS", "2");
        let out = run_table(&t);
        std::env::remove_var("MLANE_REPS");
        assert_eq!(out.rows.len(), 2);
        let text = out.render();
        assert!(text.contains("Table 12"), "{text}");
        assert!(text.contains("avg(us)"));
    }

    #[test]
    fn parallel_rows_keep_serial_order() {
        // Per-cell values are deterministic by design (workers share
        // shapes behind per-shape locks; seeds don't depend on thread
        // count) — the bitwise cached-vs-fresh guarantees are covered by
        // the sweep engine and coordinator tests. Here: the parallel
        // fan-out must reassemble rows in exact section/count order.
        let mut t = table(12).unwrap();
        for s in &mut t.sections {
            s.cluster = Cluster::new(3, 4, 2);
            s.counts = &[1, 600, 6000];
        }
        std::env::set_var("MLANE_THREADS", "4");
        let out = run_table(&t);
        std::env::remove_var("MLANE_THREADS");
        let got: Vec<(&str, u64)> =
            out.rows.iter().map(|r| (r.section.as_str(), r.c)).collect();
        let want: Vec<(&str, u64)> = t
            .sections
            .iter()
            .flat_map(|s| s.counts.iter().map(move |&c| (s.heading.as_str(), c)))
            .collect();
        assert_eq!(got, want);
        assert!(out.rows.iter().all(|r| r.avg.is_finite() && r.avg >= r.min));
        // Env-override behavior, checked here to keep all MLANE_THREADS
        // mutation in one test (avoids races under parallel test runs).
        std::env::set_var("MLANE_THREADS", "3");
        assert_eq!(sweep_threads(), 3);
        std::env::set_var("MLANE_THREADS", "0"); // invalid: fall back
        assert!(sweep_threads() >= 1);
        std::env::remove_var("MLANE_THREADS");
        assert!(sweep_threads() >= 1);
    }

    #[test]
    fn csv_written() {
        let mut t = table(27).unwrap();
        t.sections.truncate(1);
        t.sections[0].cluster = Cluster::new(2, 4, 2);
        t.sections[0].counts = &[1];
        std::env::set_var("MLANE_REPS", "2");
        let out = run_table(&t);
        std::env::remove_var("MLANE_REPS");
        let dir = std::env::temp_dir().join("mlane_csv_test");
        let path = out.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.lines().count() >= 2);
        assert!(text.starts_with("table,persona"));
    }
}

//! Experiment harness: the **plan API** over the sweep engine.
//!
//! The paper's evaluation (Tables 2–49) is one instance of a general
//! shape: a *scenario grid* — (cluster × operation × algorithm) swept
//! over an element-count series, per library persona. The harness
//! exposes that shape directly:
//!
//! * [`Grid`] — composable scenario-grid builder; expands to typed
//!   [`Section`]s (`Grid::new().cluster(…).op(…).algs(…).counts(…)`);
//! * [`Plan`] — a set of [`TableSpec`]s built from grids.
//!   [`Plan::paper`] declares all 48 paper tables as grid data;
//!   [`Plan::appendix`] is a non-paper preset (two-phase vs. adapted
//!   k-lane broadcast);
//! * [`RunConfig`] — explicit run parameters (reps, warmup, worker
//!   threads, schedule-cache bound, output directory, seed). The
//!   library reads **no environment variables**; the CLI maps
//!   `MLANE_REPS`/`MLANE_THREADS`/`MLANE_CACHE_SHAPES` to a config via
//!   [`RunConfig::from_env`] at its edge;
//! * [`run_plan`] — the plan-level executor: every section of every
//!   table is scheduled over **one** work-stealing worker pool backed
//!   by the shared [`SweepEngine`], and rows are reassembled in spec
//!   order, so output is byte-identical to a serial run regardless of
//!   thread count;
//! * [`Report`] + [`Sink`] — emission layer ([`TextSink`] paper-style
//!   text, [`CsvSink`] per-table CSV files, [`JsonSink`] full
//!   spec-plus-rows JSON for trajectory tooling);
//! * [`shard`] — multi-process distribution: [`Plan::shard`] splits a
//!   plan into disjoint sub-plans by stable hashing, [`ShardSink`]
//!   emits self-describing shard artifacts, and [`merge_dir`]
//!   reassembles them byte-identical to a single-process run
//!   (DESIGN.md §Distributed execution).
//!
//! Table numbering follows the paper exactly: 2–7 — §4.1 node-vs-network
//! alltoall at p = 32; 8–22 — §4.2 broadcast; 23–37 — §4.3 scatter;
//! 38–49 — §4.4 alltoall (each family × three library personas).
//! Sections name their algorithm as a registry handle
//! (`algorithms::registry::Alg`), so grids track the catalog — a newly
//! registered algorithm needs no harness changes to be swept.
//!
//! Broken specs are typed [`PlanError`]s carrying the offending table,
//! section and the underlying `AlgError` — never panics.

pub mod anchors;
pub mod plan;
pub mod report;
pub mod shard;

pub use plan::{
    run_plan, run_plan_with, run_table, run_table_with, Grid, Plan, PlanError, RunConfig,
};
pub use report::{CsvSink, JsonSink, Report, Sink, TextSink};
pub use shard::{merge_dir, plan_fingerprint, write_shard, Merged, ShardSink};

use std::sync::{Arc, OnceLock};

use crate::algorithms::registry::{Alg, OpKind};
use crate::model::PersonaName;
use crate::sim::{sweep::DEFAULT_CACHE_SHAPES, SweepEngine};
use crate::topology::Cluster;

/// Count sweeps used by the paper (§4.2–4.4; MPI_INT elements).
pub const BCAST_COUNTS: &[u64] =
    &[1, 6, 10, 60, 100, 600, 1000, 6000, 10000, 60000, 100000, 600000, 1000000];
pub const SCATTER_COUNTS: &[u64] = &[1, 6, 9, 53, 87, 521, 869];
pub const ALLTOALL_COUNTS: &[u64] = &[1, 6, 9, 53, 87, 521, 869];
/// §4.1 sweep (p = 32).
pub const NODE_VS_NET_COUNTS: &[u64] =
    &[1, 2, 4, 19, 32, 188, 313, 1875, 3125, 18750, 31250];

/// The paper's default count series for an operation — the grid
/// `mlane sweep`/`mlane tune` fall back to and the one the `tuned`
/// meta-algorithm's auto-built decision tables sample.
pub fn default_counts(op: OpKind) -> &'static [u64] {
    match op {
        OpKind::Bcast => BCAST_COUNTS,
        OpKind::Scatter | OpKind::Gather => SCATTER_COUNTS,
        OpKind::Allgather | OpKind::Alltoall => ALLTOALL_COUNTS,
    }
}

/// One series within a table (the paper's tables stack 1–3 of these).
/// Usually produced by [`Grid::sections`] rather than written by hand.
#[derive(Clone, Debug)]
pub struct Section {
    pub heading: String,
    pub cluster: Cluster,
    pub op: OpKind,
    pub alg: Alg,
    /// The element-count series this section sweeps. Shared (`Arc`) so
    /// grids and their expanded sections stay cheap to clone.
    pub counts: Arc<[u64]>,
}

#[derive(Clone, Debug)]
pub struct TableSpec {
    /// Table number (paper tables use 2–49; presets and ad-hoc sweeps
    /// may use anything else).
    pub number: u32,
    pub caption: String,
    pub persona: PersonaName,
    pub sections: Vec<Section>,
}

impl TableSpec {
    /// Indices of the sections shard `index` of `shards` owns, in
    /// section order — the single definition of the shard partition,
    /// shared by [`Plan::shard`] (which runs the owned sections) and
    /// `shard::ShardSink` (which checks rows against the assignment).
    pub(crate) fn owned_sections(&self, shards: u32, index: u32) -> Vec<usize> {
        (0..self.sections.len())
            .filter(|&s| plan::section_shard(self.number, s, shards) == index)
            .collect()
    }

    /// Test/bench helper: re-target every section at a different
    /// cluster and count series, keeping headings and algorithms.
    pub fn with_grid(mut self, cluster: Cluster, counts: &[u64]) -> TableSpec {
        let counts: Arc<[u64]> = Arc::from(counts);
        for s in &mut self.sections {
            s.cluster = cluster;
            s.counts = counts.clone();
        }
        self
    }
}

/// One output row, matching the paper's columns.
#[derive(Clone, Debug)]
pub struct Row {
    pub section: String,
    pub k: u32,
    pub n: u32,
    pub nodes: u32,
    pub p: u32,
    pub c: u64,
    pub avg: f64,
    pub min: f64,
}

/// One completed table: its spec plus the measured rows, in section
/// order. Emitted through the [`Sink`] layer (see [`Report`]).
#[derive(Clone, Debug)]
pub struct TableOut {
    pub spec: TableSpec,
    pub rows: Vec<Row>,
}

impl TableOut {
    /// Paper-style text rendering (the [`TextSink`] format).
    pub fn text(&self) -> String {
        report::table_text(self)
    }
}

static ENGINE: OnceLock<Arc<SweepEngine>> = OnceLock::new();

/// The process-wide sweep engine behind [`run_plan`]: the cross-table
/// schedule cache. Personas are isolated by the engine's
/// model-fingerprinted keys.
pub fn shared_engine() -> Arc<SweepEngine> {
    shared_engine_sized(DEFAULT_CACHE_SHAPES)
}

/// [`shared_engine`] with a requested cache bound. The engine is a
/// process singleton, so the first caller's bound wins; pass an
/// explicit engine to [`run_plan_with`] for a guaranteed capacity.
pub(crate) fn shared_engine_sized(cache_shapes: usize) -> Arc<SweepEngine> {
    ENGINE.get_or_init(|| Arc::new(SweepEngine::with_capacity(cache_shapes))).clone()
}

/// All 48 paper tables (compatibility wrapper over [`Plan::paper`]).
pub fn registry() -> Vec<TableSpec> {
    Plan::paper().tables
}

/// Look up one paper table by number.
pub fn table(number: u32) -> Option<TableSpec> {
    Plan::paper().tables.into_iter().find(|t| t.number == number)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RunConfig {
        RunConfig::default().reps(2).warmup(0)
    }

    #[test]
    fn registry_covers_tables_2_through_49() {
        let r = registry();
        assert_eq!(r.len(), 48);
        let numbers: Vec<u32> = r.iter().map(|t| t.number).collect();
        assert_eq!(numbers, (2..=49).collect::<Vec<_>>());
    }

    #[test]
    fn personas_cycle_correctly() {
        // Table 8 = Open MPI, 13 = Intel, 18 = mpich (paper order).
        assert_eq!(table(8).unwrap().persona, PersonaName::OpenMpi);
        assert_eq!(table(13).unwrap().persona, PersonaName::IntelMpi);
        assert_eq!(table(18).unwrap().persona, PersonaName::Mpich);
        // Alltoall: 38 open, 42 intel, 46 mpich.
        assert_eq!(table(38).unwrap().persona, PersonaName::OpenMpi);
        assert_eq!(table(46).unwrap().persona, PersonaName::Mpich);
    }

    #[test]
    fn node_vs_net_tables_use_p32() {
        let t = table(2).unwrap();
        for s in &t.sections {
            assert_eq!(s.cluster.p(), 32);
        }
    }

    #[test]
    fn small_table_runs_and_renders() {
        // Shrink to one tiny section for test speed; the explicit
        // RunConfig replaces the old MLANE_REPS env mutation.
        let mut t = table(12).unwrap().with_grid(Cluster::new(3, 4, 2), &[1, 600]);
        t.sections.truncate(1);
        let out = run_table(&t, &cfg()).unwrap();
        assert_eq!(out.rows.len(), 2);
        let text = out.text();
        assert!(text.contains("Table 12"), "{text}");
        assert!(text.contains("avg(us)"));
    }

    #[test]
    fn parallel_rows_keep_serial_order() {
        // Per-cell values are deterministic by design (workers share
        // shapes behind per-shape locks; seeds don't depend on thread
        // count) — the bitwise cached-vs-fresh guarantees are covered by
        // the sweep engine and coordinator tests. Here: the parallel
        // fan-out must reassemble rows in exact section/count order.
        let t = table(12).unwrap().with_grid(Cluster::new(3, 4, 2), &[1, 600, 6000]);
        let out = run_table(&t, &cfg().threads(4)).unwrap();
        let got: Vec<(&str, u64)> =
            out.rows.iter().map(|r| (r.section.as_str(), r.c)).collect();
        let want: Vec<(&str, u64)> = t
            .sections
            .iter()
            .flat_map(|s| {
                let h = s.heading.as_str();
                s.counts.iter().map(move |&c| (h, c))
            })
            .collect();
        assert_eq!(got, want);
        assert!(out.rows.iter().all(|r| r.avg.is_finite() && r.avg >= r.min));
    }

    #[test]
    fn with_grid_retargets_every_section() {
        let t = table(27).unwrap().with_grid(Cluster::new(2, 4, 2), &[1]);
        for s in &t.sections {
            assert_eq!(s.cluster, Cluster::new(2, 4, 2));
            assert_eq!(&s.counts[..], &[1]);
        }
    }
}

//! Experiment plans: scenario grids, run configuration, and the
//! plan-level parallel executor.
//!
//! A [`Grid`] is the composable builder for one table's sections — the
//! cartesian product (algorithms × clusters × operations), each swept
//! over a shared count series. A [`Plan`] is a list of [`TableSpec`]s
//! built from grids; [`Plan::paper`] declares the paper's 48 tables as
//! grid data, [`Plan::appendix`] is a non-paper preset grown through
//! the same API.
//!
//! [`run_plan`] executes a whole plan: *all* sections of *all* tables
//! become one work queue served by a work-stealing pool of
//! `RunConfig::threads` workers over one shared [`SweepEngine`], so
//! overlapping shapes across tables are built once (the cross-table
//! schedule cache) and the outer table loop parallelises, not just the
//! sections of one table. Rows are reassembled in (table, section,
//! count) order, so the emitted report is byte-identical to a serial
//! run for any thread count.
//!
//! Configuration is explicit: [`RunConfig`] carries reps/warmup/threads/
//! cache bound/output dir/seed. The library never reads environment
//! variables; [`RunConfig::from_env`] exists for the CLI edge only.

use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::algorithms::registry::{self, Alg, AlgError, OpKind};
use crate::coordinator::Collectives;
use crate::model::PersonaName;
use crate::netsim::Backend;
use crate::sim::{self, sweep::DEFAULT_CACHE_SHAPES, SweepEngine};
use crate::topology::Cluster;

use super::report::Report;
use super::{
    shared_engine_sized, Row, Section, TableOut, TableSpec, ALLTOALL_COUNTS, BCAST_COUNTS,
    NODE_VS_NET_COUNTS, SCATTER_COUNTS,
};

/// Explicit run parameters for plan execution. Replaces the implicit
/// `MLANE_REPS`/`MLANE_THREADS`/`MLANE_CACHE_SHAPES` environment reads
/// that used to live inside the library — construct one (or use
/// [`RunConfig::from_env`] at a CLI edge) and pass it down.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Measured repetitions per cell (paper: 100, see `sim::PAPER_REPS`).
    pub reps: usize,
    /// Unmeasured warm-up repetitions per cell.
    pub warmup: usize,
    /// Worker threads for plan execution (sections are the work unit).
    pub threads: usize,
    /// Bound on the shared schedule cache, in shapes (see `sim::sweep`).
    /// Note: the default engine behind [`run_plan`] is a process-wide
    /// singleton sized by the **first** run's config; to guarantee a
    /// bound, pass your own engine to [`run_plan_with`].
    pub cache_shapes: usize,
    /// Directory file-writing sinks (CSV) default to.
    pub out_dir: PathBuf,
    /// Measurement seed (per-rep streams derive from it).
    pub seed: u64,
    /// Simulation backend every section runs on: the analytic
    /// closed-form model (default) or the event-driven network backend
    /// with its contention scenario. Part of the shard fingerprint —
    /// shards of different backends never merge.
    pub backend: Backend,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            reps: sim::DEFAULT_REPS,
            warmup: sim::DEFAULT_WARMUP,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            cache_shapes: DEFAULT_CACHE_SHAPES,
            out_dir: PathBuf::from("bench_out"),
            seed: sim::DEFAULT_SEED,
            backend: Backend::default(),
        }
    }
}

impl RunConfig {
    /// CLI-edge constructor: the defaults overridden by `MLANE_REPS`,
    /// `MLANE_THREADS` and `MLANE_CACHE_SHAPES` where set (> 0). This
    /// is the **only** place the harness touches the environment — the
    /// library itself runs purely off the config values.
    pub fn from_env() -> RunConfig {
        fn env_usize(key: &str) -> Option<usize> {
            std::env::var(key).ok().and_then(|v| v.parse().ok()).filter(|&n| n > 0)
        }
        let mut cfg = RunConfig::default();
        if let Some(r) = env_usize("MLANE_REPS") {
            cfg.reps = r;
        }
        if let Some(t) = env_usize("MLANE_THREADS") {
            cfg.threads = t;
        }
        if let Some(s) = env_usize("MLANE_CACHE_SHAPES") {
            cfg.cache_shapes = s;
        }
        cfg
    }

    pub fn reps(mut self, reps: usize) -> Self {
        self.reps = reps;
        self
    }

    pub fn warmup(mut self, warmup: usize) -> Self {
        self.warmup = warmup;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn cache_shapes(mut self, cache_shapes: usize) -> Self {
        self.cache_shapes = cache_shapes;
        self
    }

    pub fn out_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.out_dir = dir.into();
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }
}

/// FNV-1a 64: the stable, dependency-free hash behind shard assignment
/// and artifact fingerprints. Unlike `DefaultHasher` it is *specified*,
/// so shard partitions agree across processes, builds and toolchains —
/// the property distributed runs stand on.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The shard a section belongs to: stable hash of (table number,
/// section position) reduced mod `shards`.
pub(crate) fn section_shard(table: u32, section: usize, shards: u32) -> u32 {
    let mut id = [0u8; 12];
    id[..4].copy_from_slice(&table.to_le_bytes());
    id[4..].copy_from_slice(&(section as u64).to_le_bytes());
    (fnv1a(&id) % shards as u64) as u32
}

type HeadingFn = Arc<dyn Fn(Cluster, OpKind, &Alg) -> String + Send + Sync>;

/// Composable scenario-grid builder. Expands to the cartesian product
/// (algorithms × clusters × operations) — algorithms outermost, so a
/// multi-algorithm grid reads like the paper's stacked table sections —
/// each section sweeping the grid's count series.
///
/// ```ignore
/// let grid = Grid::new()
///     .cluster(Cluster::hydra(2))
///     .op(OpKind::Bcast)
///     .algs((1..=3).map(registry::klane))
///     .counts(BCAST_COUNTS);
/// let plan = Plan::new().table(8, "k-lane Bcast", PersonaName::OpenMpi, &grid);
/// ```
#[derive(Clone, Default)]
pub struct Grid {
    clusters: Vec<Cluster>,
    ops: Vec<OpKind>,
    algs: Vec<Alg>,
    counts: Vec<u64>,
    heading: Option<HeadingFn>,
}

impl fmt::Debug for Grid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Grid")
            .field("clusters", &self.clusters)
            .field("ops", &self.ops)
            .field("algs", &self.algs)
            .field("counts", &self.counts)
            .field("heading", &self.heading.as_ref().map(|_| "<fn>"))
            .finish()
    }
}

impl Grid {
    pub fn new() -> Grid {
        Grid::default()
    }

    pub fn cluster(mut self, cluster: Cluster) -> Self {
        self.clusters.push(cluster);
        self
    }

    pub fn clusters(mut self, clusters: impl IntoIterator<Item = Cluster>) -> Self {
        self.clusters.extend(clusters);
        self
    }

    pub fn op(mut self, op: OpKind) -> Self {
        self.ops.push(op);
        self
    }

    pub fn ops(mut self, ops: impl IntoIterator<Item = OpKind>) -> Self {
        self.ops.extend(ops);
        self
    }

    pub fn alg(mut self, alg: Alg) -> Self {
        self.algs.push(alg);
        self
    }

    pub fn algs(mut self, algs: impl IntoIterator<Item = Alg>) -> Self {
        self.algs.extend(algs);
        self
    }

    pub fn counts(mut self, counts: &[u64]) -> Self {
        self.counts = counts.to_vec();
        self
    }

    /// Override the section-heading function (defaults to
    /// `"<op> <algorithm label>"`).
    pub fn heading<F>(mut self, f: F) -> Self
    where
        F: Fn(Cluster, OpKind, &Alg) -> String + Send + Sync + 'static,
    {
        self.heading = Some(Arc::new(f));
        self
    }

    /// Expand to typed sections: for each algorithm, for each cluster,
    /// for each operation.
    pub fn sections(&self) -> Vec<Section> {
        let counts: Arc<[u64]> = Arc::from(&self.counts[..]);
        let mut out = Vec::with_capacity(self.algs.len() * self.clusters.len() * self.ops.len());
        for alg in &self.algs {
            for &cluster in &self.clusters {
                for &op in &self.ops {
                    let heading = match &self.heading {
                        Some(f) => f(cluster, op, alg),
                        None => format!("{op} {}", alg.label()),
                    };
                    out.push(Section {
                        heading,
                        cluster,
                        op,
                        alg: alg.clone(),
                        counts: counts.clone(),
                    });
                }
            }
        }
        out
    }
}

/// An experiment plan: tables built from scenario grids, executed as
/// one unit by [`run_plan`].
#[derive(Clone, Debug, Default)]
pub struct Plan {
    pub tables: Vec<TableSpec>,
}

impl Plan {
    pub fn new() -> Plan {
        Plan::default()
    }

    /// Append one table expanded from a grid (builder style).
    pub fn table(
        mut self,
        number: u32,
        caption: impl Into<String>,
        persona: PersonaName,
        grid: &Grid,
    ) -> Plan {
        self.tables.push(TableSpec {
            number,
            caption: caption.into(),
            persona,
            sections: grid.sections(),
        });
        self
    }

    pub fn get(&self, number: u32) -> Option<&TableSpec> {
        self.tables.iter().find(|t| t.number == number)
    }

    /// Total sections across the plan.
    pub fn num_sections(&self) -> usize {
        self.tables.iter().map(|t| t.sections.len()).sum()
    }

    /// Total measurement cells (section × count) across the plan.
    pub fn num_cells(&self) -> usize {
        self.tables
            .iter()
            .flat_map(|t| &t.sections)
            .map(|s| s.counts.len())
            .sum()
    }

    /// Deterministically partition the plan's sections into `shards`
    /// disjoint sub-plans and return shard `index` — the unit one
    /// process (or machine) of a distributed table run executes.
    ///
    /// Assignment is a stable hash of the section's id (table number +
    /// section position): no environment reads, no randomness, no
    /// dependence on `shards`' siblings — so the union over
    /// `index ∈ 0..shards` is exactly the original plan (exhaustive and
    /// disjoint; `rust/tests/plan_shard.rs` pins this for several
    /// shard counts) and every process computes the same partition from
    /// the plan alone. Tables whose sections all land elsewhere are
    /// dropped from the sub-plan; a shard may be empty (it still
    /// produces a — rowless — shard artifact, which `merge` requires
    /// for completeness).
    ///
    /// Cell values are independent of which sibling sections run
    /// (schedules and seeds depend only on the section spec and
    /// `RunConfig`), so re-merging shard rows reproduces a
    /// single-process run byte for byte — see `harness::shard`.
    ///
    /// `shards` must be ≥ 1 and `index < shards` (caller-validated at
    /// the CLI edge).
    pub fn shard(&self, shards: u32, index: u32) -> Plan {
        assert!(shards >= 1, "shards must be >= 1");
        assert!(index < shards, "shard index {index} out of range 0..{shards}");
        let mut tables = Vec::new();
        for spec in &self.tables {
            let owned = spec.owned_sections(shards, index);
            if !owned.is_empty() {
                tables.push(TableSpec {
                    number: spec.number,
                    caption: spec.caption.clone(),
                    persona: spec.persona,
                    sections: owned.into_iter().map(|s| spec.sections[s].clone()).collect(),
                });
            }
        }
        Plan { tables }
    }

    fn sorted(mut self) -> Plan {
        self.tables.sort_by_key(|t| t.number);
        self
    }

    /// Resolve a named preset (`mlane sweep --preset <name>`).
    pub fn preset(name: &str) -> Option<Plan> {
        match name {
            "paper" => Some(Plan::paper()),
            "appendix" => Some(Plan::appendix()),
            "tuned" => Some(Plan::tuned()),
            "contention" => Some(Plan::contention()),
            _ => None,
        }
    }

    /// Preset names accepted by [`Plan::preset`].
    pub const PRESETS: &[&str] = &["paper", "appendix", "tuned", "contention"];

    /// The paper's full evaluation: every table of Tables 2–49, as grid
    /// declarations. Algorithms are registry handles — the specs carry
    /// no algorithm enumeration of their own.
    pub fn paper() -> Plan {
        let mut plan = Plan::new();

        // ---- §4.1: Tables 2–7 (node vs network, p = 32) ----
        let net32 = Cluster::new(32, 1, 2); // N=32, n=1 (both rails usable, §4.1)
        let node32 = Cluster::new(1, 32, 2); // N=1, n=32
        for (base, label, alg) in [
            (2u32, "k-ported alltoall", registry::kported(31)),
            (3, "MPI_Alltoall", registry::native()),
        ] {
            for (pi, persona) in PersonaName::all().into_iter().enumerate() {
                let grid = Grid::new()
                    .clusters([net32, node32])
                    .op(OpKind::Alltoall)
                    .alg(alg.clone())
                    .counts(NODE_VS_NET_COUNTS)
                    .heading(move |cl: Cluster, _: OpKind, _: &Alg| {
                        format!("{label} N={}", cl.nodes)
                    });
                plan = plan.table(
                    base + pi as u32 * 2,
                    format!("{label}, N=32/n=1 vs N=1/n=32, p=32"),
                    persona,
                    &grid,
                );
            }
        }

        // ---- §4.2: Tables 8–22 (bcast) / §4.3: Tables 23–37 (scatter) ----
        for (pi, persona) in PersonaName::all().into_iter().enumerate() {
            plan = rooted_family(
                plan,
                8 + pi as u32 * 5,
                persona,
                OpKind::Bcast,
                BCAST_COUNTS,
                bcast_klane_heading,
            );
        }
        for (pi, persona) in PersonaName::all().into_iter().enumerate() {
            plan = rooted_family(
                plan,
                23 + pi as u32 * 5,
                persona,
                OpKind::Scatter,
                SCATTER_COUNTS,
                scatter_klane_heading,
            );
        }

        // ---- §4.4: Tables 38–49 (alltoall) ----
        for (pi, persona) in PersonaName::all().into_iter().enumerate() {
            let base = 38 + pi as u32 * 4;
            let hydra_grid =
                Grid::new().cluster(hydra()).op(OpKind::Alltoall).counts(ALLTOALL_COUNTS);
            let kported = |lo: u32, hi: u32| {
                hydra_grid
                    .clone()
                    .algs((lo..=hi).map(registry::kported))
                    .heading(|_: Cluster, _: OpKind, a: &Alg| {
                        format!("Alltoall, {}-ported", a.k().unwrap_or(0))
                    })
            };
            plan = plan.table(
                base,
                "k-lane Alltoall (32 virtual lanes) on Hydra",
                persona,
                &hydra_grid.clone().alg(registry::klane(1)).heading(
                    |_: Cluster, _: OpKind, _: &Alg| "Alltoall, 32 virtual lanes".to_string(),
                ),
            );
            plan = plan.table(
                base + 1,
                "k-ported Alltoall for k=1,2,3 on Hydra",
                persona,
                &kported(1, 3),
            );
            plan = plan.table(
                base + 2,
                "k-ported Alltoall for k=4,5,6 on Hydra",
                persona,
                &kported(4, 6),
            );
            plan = plan.table(
                base + 3,
                "full-lane Alltoall and native MPI_Alltoall on Hydra",
                persona,
                &hydra_grid
                    .clone()
                    .algs([registry::fulllane(), registry::native()])
                    .heading(fulllane_native_heading),
            );
        }

        plan.sorted()
    }

    /// Appendix preset (not in the paper): the §2.3 theoretical
    /// two-phase k-lane broadcast (`klane2p`) against the adapted
    /// k-lane implementation, side by side for k = 2, 4, 6 on Hydra —
    /// scenario growth through the grid API, one declaration per
    /// persona (tables 50–52).
    pub fn appendix() -> Plan {
        let grid = Grid::new()
            .cluster(hydra())
            .op(OpKind::Bcast)
            .algs(
                [2u32, 4, 6]
                    .into_iter()
                    .flat_map(|k| [registry::klane(k), registry::klane2p(k)]),
            )
            .counts(BCAST_COUNTS)
            .heading(|_: Cluster, _: OpKind, a: &Alg| {
                let k = a.k().unwrap_or(0);
                if a.name() == "klane2p" {
                    format!("Bcast, k = {k} lanes (two-phase)")
                } else {
                    format!("Bcast, k = {k} lanes")
                }
            });
        let mut plan = Plan::new();
        for (pi, persona) in PersonaName::all().into_iter().enumerate() {
            plan = plan.table(
                50 + pi as u32,
                "two-phase vs adapted k-lane Bcast on Hydra (appendix)",
                persona,
                &grid,
            );
        }
        plan
    }

    /// Tuned-selection preset (tables 53–55, one per persona): the
    /// `tuned` meta-algorithm side by side with every fixed algorithm
    /// of its default broadcast candidate set on Hydra, across the
    /// paper's count range — the end-to-end demonstration that per-size
    /// selection tracks the per-count winner where every fixed choice
    /// loses somewhere.
    pub fn tuned() -> Plan {
        let cl = hydra();
        let mut algs = vec![registry::tuned()];
        algs.extend(registry::registry().candidates(cl, OpKind::Bcast));
        let grid = Grid::new()
            .cluster(cl)
            .op(OpKind::Bcast)
            .algs(algs)
            .counts(BCAST_COUNTS)
            .heading(|_: Cluster, op: OpKind, a: &Alg| {
                if a.name() == "tuned" {
                    format!("{} (tuned selection)", op.title())
                } else if a.name() == "native" {
                    format!("MPI_{}", op.title())
                } else {
                    format!("{}, {}", op.title(), a.label())
                }
            });
        let mut plan = Plan::new();
        for (pi, persona) in PersonaName::all().into_iter().enumerate() {
            plan = plan.table(
                53 + pi as u32,
                "tuned selection vs fixed algorithms, Bcast on Hydra",
                persona,
                &grid,
            );
        }
        plan
    }

    /// Contention preset (tables 56–58, not in the paper): a small
    /// algorithm cross-section per operation on Hydra, intended for the
    /// event-driven network backend (`mlane sweep --preset contention`
    /// defaults to `--backend event` with the contended scenario — the
    /// plan itself is backend-agnostic; `RunConfig::backend` decides).
    /// Count grids are deliberately short: the event backend walks
    /// every message through explicit port queues, so a cell costs far
    /// more than an analytic recost.
    pub fn contention() -> Plan {
        let cl = hydra();
        let rooted = |op: OpKind| {
            Grid::new()
                .cluster(cl)
                .op(op)
                .algs([
                    registry::klane(2),
                    registry::kported(2),
                    registry::fulllane(),
                    registry::native(),
                ])
                .counts(&[1, 1000, 100_000])
        };
        let alltoall = Grid::new()
            .cluster(cl)
            .op(OpKind::Alltoall)
            .algs([
                registry::klane(2),
                registry::kported(2),
                registry::fulllane(),
                registry::bruck(2),
                registry::native(),
            ])
            .counts(&[1, 87, 869]);
        Plan::new()
            .table(
                56,
                "Bcast under background tenant traffic on Hydra (contention)",
                PersonaName::OpenMpi,
                &rooted(OpKind::Bcast),
            )
            .table(
                57,
                "Scatter under background tenant traffic on Hydra (contention)",
                PersonaName::OpenMpi,
                &rooted(OpKind::Scatter),
            )
            .table(
                58,
                "Alltoall under background tenant traffic on Hydra (contention)",
                PersonaName::OpenMpi,
                &alltoall,
            )
    }
}

fn hydra() -> Cluster {
    Cluster::hydra(2)
}

fn bcast_klane_heading(k: u32) -> String {
    format!("Bcast, k = {k} lanes")
}

fn scatter_klane_heading(k: u32) -> String {
    format!("Scatter, {k} lane{}", if k == 1 { "" } else { "s" })
}

fn fulllane_native_heading(_cl: Cluster, op: OpKind, alg: &Alg) -> String {
    if alg.name() == "native" {
        format!("MPI_{}", op.title())
    } else {
        format!("Full-lane {}", op.title())
    }
}

/// The five-table family shared by §4.2 (bcast) and §4.3 (scatter):
/// k-lane k=1..3 / k=4..6, k-ported k=1..3 / k=4..6, full-lane+native.
fn rooted_family(
    mut plan: Plan,
    base: u32,
    persona: PersonaName,
    op: OpKind,
    counts: &[u64],
    klane_heading: fn(u32) -> String,
) -> Plan {
    let title = op.title();
    let hydra_grid = Grid::new().cluster(hydra()).op(op).counts(counts);
    let klane = |lo: u32, hi: u32| {
        hydra_grid
            .clone()
            .algs((lo..=hi).map(registry::klane))
            .heading(move |_: Cluster, _: OpKind, a: &Alg| klane_heading(a.k().unwrap_or(0)))
    };
    let kported = |lo: u32, hi: u32| {
        hydra_grid
            .clone()
            .algs((lo..=hi).map(registry::kported))
            .heading(move |_: Cluster, _: OpKind, a: &Alg| {
                format!("{title}, {}-ported", a.k().unwrap_or(0))
            })
    };
    plan = plan.table(base, format!("k-lane {title} for k=1,2,3 on Hydra"), persona, &klane(1, 3));
    plan = plan.table(
        base + 1,
        format!("k-lane {title} for k=4,5,6 on Hydra"),
        persona,
        &klane(4, 6),
    );
    plan = plan.table(
        base + 2,
        format!("k-ported {title} for k=1,2,3 on Hydra"),
        persona,
        &kported(1, 3),
    );
    plan = plan.table(
        base + 3,
        format!("k-ported {title} for k=4,5,6 on Hydra"),
        persona,
        &kported(4, 6),
    );
    plan.table(
        base + 4,
        format!("full-lane {title} and native MPI_{title} on Hydra"),
        persona,
        &hydra_grid
            .clone()
            .algs([registry::fulllane(), registry::native()])
            .heading(fulllane_native_heading),
    )
}

/// Typed plan-execution errors: a broken spec surfaces as a `Result`,
/// never a panic, carrying where it broke and the underlying registry
/// error.
#[derive(Clone, Debug)]
pub enum PlanError {
    /// A section's (cluster, op, algorithm) failed to build or run.
    Section { table: u32, section: String, source: AlgError },
    /// A table with no sections, or a section with an empty count
    /// series — a grid-construction mistake (a forgotten `.counts(…)`
    /// or `.algs(…)`) that would otherwise emit a silently useless
    /// empty report.
    EmptySpec { table: u32, section: Option<String> },
    /// A shard artifact could not be read or written.
    ShardIo { path: PathBuf, detail: String },
    /// A shard artifact failed strict parsing or internal validation.
    ShardParse { path: PathBuf, detail: String },
    /// The artifacts of one merge disagree with each other — different
    /// spec fingerprints (shards of *different* plans or configs),
    /// different shard counts, or a duplicated shard index.
    ShardMismatch { detail: String },
    /// The merge set does not cover every shard of the run.
    ShardIncomplete { missing: Vec<u32>, shards: u32 },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::Section { table, section, source } => {
                write!(f, "table {table}, section {section}: {source}")
            }
            PlanError::EmptySpec { table, section: Some(section) } => {
                write!(f, "table {table}, section {section}: empty count series")
            }
            PlanError::EmptySpec { table, section: None } => {
                write!(f, "table {table}: no sections in spec")
            }
            PlanError::ShardIo { path, detail } => {
                write!(f, "shard {}: {detail}", path.display())
            }
            PlanError::ShardParse { path, detail } => {
                write!(f, "shard {}: {detail}", path.display())
            }
            PlanError::ShardMismatch { detail } => {
                write!(f, "shard set mismatch: {detail}")
            }
            PlanError::ShardIncomplete { missing, shards } => {
                let list: Vec<String> = missing.iter().map(|i| i.to_string()).collect();
                write!(
                    f,
                    "incomplete shard set: missing shard{} {} of {shards}",
                    if missing.len() == 1 { "" } else { "s" },
                    list.join(", ")
                )
            }
        }
    }
}

impl std::error::Error for PlanError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PlanError::Section { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// One section's count sweep. The `Collectives` shares the engine (so
/// shapes persist across sections and tables) but owns its rep state —
/// no allocation inside the sweep, no cross-thread contention except on
/// a shared shape. The whole section is one `run_series` call: the
/// engine resolves the cached shape once and walks the count grid in a
/// single pass, so a worker touches the cache locks once per section,
/// not once per cell.
fn run_section(
    engine: &Arc<SweepEngine>,
    cfg: &RunConfig,
    spec: &TableSpec,
    sec: &Section,
) -> Result<Vec<Row>, PlanError> {
    let mut coll = Collectives::with_engine(sec.cluster, spec.persona, engine.clone());
    coll.reps = cfg.reps;
    coll.warmup = cfg.warmup;
    coll.seed = cfg.seed;
    coll.backend = cfg.backend;
    let ms = coll.run_series(sec.op.op(1), &sec.counts, &sec.alg).map_err(|source| {
        PlanError::Section { table: spec.number, section: sec.heading.clone(), source }
    })?;
    Ok(ms
        .into_iter()
        .zip(sec.counts.iter())
        .map(|(m, &c)| Row {
            section: sec.heading.clone(),
            k: m.k,
            n: sec.cluster.cores,
            nodes: sec.cluster.nodes,
            p: sec.cluster.p(),
            c,
            avg: m.summary.avg,
            min: m.summary.min,
        })
        .collect())
}

type SectionResult = Result<Vec<Row>, PlanError>;

/// Execute a whole plan against the process-wide shared engine: every
/// section of every table goes into one work queue drained by
/// `cfg.threads` workers, so the *outer* table loop parallelises too
/// (persona-level sharding across tables). Output is deterministic and
/// identical to a serial run: rows are reassembled in (table, section,
/// count) order, and cell values depend only on (spec, model, config).
///
/// The shared engine is a process singleton sized by the first caller's
/// `cache_shapes` (later values are ignored); use [`run_plan_with`]
/// with your own engine for a guaranteed bound.
pub fn run_plan(plan: &Plan, cfg: &RunConfig) -> Result<Report, PlanError> {
    run_plan_with(&shared_engine_sized(cfg.cache_shapes), plan, cfg)
}

/// Reject statically-detectable spec errors before any simulation:
/// an (algorithm, op) mismatch is knowable from the registry alone, so
/// a broken grid fails in microseconds, not after a Hydra-scale sweep;
/// empty grids (no sections / no counts) would "succeed" with a
/// useless empty report at every entry point, so they fail here too.
fn check_plan(plan: &Plan) -> Result<(), PlanError> {
    for spec in &plan.tables {
        if spec.sections.is_empty() {
            return Err(PlanError::EmptySpec { table: spec.number, section: None });
        }
        for sec in &spec.sections {
            if sec.counts.is_empty() {
                return Err(PlanError::EmptySpec {
                    table: spec.number,
                    section: Some(sec.heading.clone()),
                });
            }
            if !sec.alg.supports(sec.op) {
                return Err(PlanError::Section {
                    table: spec.number,
                    section: sec.heading.clone(),
                    source: AlgError::UnsupportedCombination {
                        alg: sec.alg.name().to_string(),
                        op: sec.op,
                        supported: registry::registry().supporting(sec.op),
                    },
                });
            }
        }
    }
    Ok(())
}

/// [`run_plan`] against a caller-provided engine (isolated caches for
/// tests and benchmarks — and the way to get a *guaranteed*
/// cache bound, since the default shared engine is a process singleton
/// sized by its first user).
pub fn run_plan_with(
    engine: &Arc<SweepEngine>,
    plan: &Plan,
    cfg: &RunConfig,
) -> Result<Report, PlanError> {
    check_plan(plan)?;

    // Flatten to (table, section) work items; their index is the only
    // coordination between workers.
    let items: Vec<(usize, usize)> = plan
        .tables
        .iter()
        .enumerate()
        .flat_map(|(t, spec)| (0..spec.sections.len()).map(move |s| (t, s)))
        .collect();
    let workers = cfg.threads.min(items.len()).max(1);

    let mut slots: Vec<Vec<Option<SectionResult>>> =
        plan.tables.iter().map(|t| t.sections.iter().map(|_| None).collect()).collect();

    // Build-time failures that survive `check_plan` (e.g. invalid k for
    // the cluster) stop the run early rather than sweeping the rest of
    // the plan to completion first.
    let failed = AtomicBool::new(false);

    if workers <= 1 {
        for &(t, s) in &items {
            let spec = &plan.tables[t];
            let r = run_section(engine, cfg, spec, &spec.sections[s]);
            let is_err = r.is_err();
            slots[t][s] = Some(r);
            if is_err {
                break;
            }
        }
    } else {
        // Work-stealing over item indices; workers return ((t, s), rows)
        // pairs so ordering is reassembled exactly.
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut done = Vec::new();
                        loop {
                            if failed.load(Ordering::Relaxed) {
                                break;
                            }
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= items.len() {
                                break;
                            }
                            let (t, s) = items[i];
                            let spec = &plan.tables[t];
                            let r = run_section(engine, cfg, spec, &spec.sections[s]);
                            if r.is_err() {
                                failed.store(true, Ordering::Relaxed);
                            }
                            done.push(((t, s), r));
                        }
                        done
                    })
                })
                .collect();
            for h in handles {
                for ((t, s), r) in h.join().expect("plan worker panicked") {
                    slots[t][s] = Some(r);
                }
            }
        });
    }

    // On failure, surface the first recorded error in (table, section)
    // order. (With the early exit, *which* failing section is reported
    // may vary when several are broken — but whether the plan fails
    // never does, and successful output stays byte-deterministic.)
    for table_slots in &mut slots {
        for slot in table_slots.iter_mut() {
            if matches!(slot, Some(Err(_))) {
                if let Some(Err(e)) = slot.take() {
                    return Err(e);
                }
            }
        }
    }

    // Success: every slot is filled; reassemble rows in spec order.
    let mut tables = Vec::with_capacity(plan.tables.len());
    for (spec, table_slots) in plan.tables.iter().zip(&mut slots) {
        let mut rows = Vec::new();
        for slot in table_slots.iter_mut() {
            rows.extend(slot.take().expect("section not processed")?);
        }
        tables.push(TableOut { spec: spec.clone(), rows });
    }
    Ok(Report { tables })
}

/// Run a single table (a one-table plan) on the shared engine.
pub fn run_table(spec: &TableSpec, cfg: &RunConfig) -> Result<TableOut, PlanError> {
    run_table_with(&shared_engine_sized(cfg.cache_shapes), spec, cfg)
}

/// [`run_table`] against a caller-provided engine.
pub fn run_table_with(
    engine: &Arc<SweepEngine>,
    spec: &TableSpec,
    cfg: &RunConfig,
) -> Result<TableOut, PlanError> {
    let plan = Plan { tables: vec![spec.clone()] };
    let mut report = run_plan_with(engine, &plan, cfg)?;
    Ok(report.tables.pop().expect("one-table plan yields one table"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cluster {
        Cluster::new(2, 4, 2)
    }

    fn cfg() -> RunConfig {
        RunConfig::default().reps(2).warmup(0)
    }

    #[test]
    fn grid_expands_alg_major_then_cluster_then_op() {
        let grid = Grid::new()
            .clusters([tiny(), Cluster::new(3, 4, 2)])
            .ops([OpKind::Bcast, OpKind::Scatter])
            .algs([registry::klane(1), registry::klane(2)])
            .counts(&[1, 64]);
        let secs = grid.sections();
        assert_eq!(secs.len(), 8);
        // Algorithms outermost.
        assert!(secs[0].heading.starts_with("bcast 1-lane"), "{}", secs[0].heading);
        assert_eq!(secs[0].cluster, tiny());
        assert_eq!(secs[1].op, OpKind::Scatter);
        assert_eq!(secs[2].cluster, Cluster::new(3, 4, 2));
        assert!(secs[4].heading.contains("2-lane"), "{}", secs[4].heading);
        assert!(secs.iter().all(|s| s.counts[..] == [1, 64]));
    }

    #[test]
    fn default_heading_names_op_and_label() {
        let secs = Grid::new()
            .cluster(tiny())
            .op(OpKind::Alltoall)
            .alg(registry::fulllane())
            .counts(&[1])
            .sections();
        assert_eq!(secs[0].heading, "alltoall full-lane");
    }

    #[test]
    fn paper_plan_matches_legacy_registry_shape() {
        let plan = Plan::paper();
        assert_eq!(plan.tables.len(), 48);
        assert_eq!(plan.get(12).unwrap().sections.len(), 2);
        assert_eq!(plan.get(12).unwrap().sections[0].heading, "Full-lane Bcast");
        assert_eq!(plan.get(12).unwrap().sections[1].heading, "MPI_Bcast");
        assert_eq!(plan.get(23).unwrap().sections[0].heading, "Scatter, 1 lane");
        assert_eq!(plan.get(24).unwrap().sections[2].heading, "Scatter, 6 lanes");
        assert_eq!(plan.get(10).unwrap().sections[1].heading, "Bcast, 2-ported");
        assert_eq!(plan.get(38).unwrap().sections[0].heading, "Alltoall, 32 virtual lanes");
        assert_eq!(plan.get(2).unwrap().sections[0].heading, "k-ported alltoall N=32");
        assert_eq!(plan.get(2).unwrap().sections[1].heading, "k-ported alltoall N=1");
        assert_eq!(plan.get(7).unwrap().sections[0].heading, "MPI_Alltoall N=32");
    }

    #[test]
    fn appendix_preset_pairs_adapted_and_two_phase() {
        let plan = Plan::preset("appendix").unwrap();
        assert_eq!(plan.tables.len(), 3);
        let t = &plan.tables[0];
        assert_eq!(t.number, 50);
        assert_eq!(t.sections.len(), 6);
        assert_eq!(t.sections[0].heading, "Bcast, k = 2 lanes");
        assert_eq!(t.sections[1].heading, "Bcast, k = 2 lanes (two-phase)");
        assert_eq!(t.sections[5].heading, "Bcast, k = 6 lanes (two-phase)");
        assert!(Plan::preset("nosuch").is_none());
        assert!(Plan::PRESETS.contains(&"appendix"));
    }

    #[test]
    fn tuned_preset_compares_tuned_against_its_candidates() {
        let plan = Plan::preset("tuned").unwrap();
        assert_eq!(plan.tables.len(), 3);
        let t = &plan.tables[0];
        assert_eq!(t.number, 53);
        assert_eq!(t.sections[0].heading, "Bcast (tuned selection)");
        assert_eq!(t.sections[0].alg.name(), "tuned");
        // One section per fixed candidate rides along, none of them the
        // meta-entry itself.
        assert!(t.sections.len() >= 5, "{}", t.sections.len());
        assert!(t.sections.iter().skip(1).all(|s| s.alg.name() != "tuned"));
        assert!(t.sections.iter().any(|s| s.heading == "MPI_Bcast"));
        assert!(Plan::PRESETS.contains(&"tuned"));
    }

    #[test]
    fn tuned_preset_runs_end_to_end_on_a_small_grid() {
        // Shrunk grid: the tuned sections dispatch per count (building
        // auto decision tables on the way) and the whole plan completes
        // through the normal executor with one row per (section, count).
        let spec = Plan::tuned().tables.remove(0).with_grid(tiny(), &[1, 6000]);
        let out = run_table_with(&Arc::new(SweepEngine::new()), &spec, &cfg()).unwrap();
        assert_eq!(out.rows.len(), 2 * spec.sections.len());
        assert!(out.rows.iter().all(|r| r.avg.is_finite() && r.avg >= r.min));
    }

    #[test]
    fn contention_preset_shape() {
        let plan = Plan::preset("contention").unwrap();
        assert_eq!(plan.tables.len(), 3);
        assert_eq!(plan.tables[0].number, 56);
        assert_eq!(plan.tables[2].number, 58);
        assert_eq!(plan.tables[0].sections.len(), 4);
        assert_eq!(plan.tables[2].sections.len(), 5);
        assert!(plan.tables.iter().all(|t| t.persona == PersonaName::OpenMpi));
        // Short grids: event-backend cells are expensive.
        assert!(plan.num_cells() <= 40, "{}", plan.num_cells());
        assert!(Plan::PRESETS.contains(&"contention"));
    }

    #[test]
    fn contention_preset_runs_on_the_event_backend() {
        use crate::netsim::Scenario;
        let t = Plan::contention().tables.remove(0).with_grid(tiny(), &[1, 64]);
        let c = cfg().backend(Backend::Event(Scenario::contended()));
        let out = run_table_with(&Arc::new(SweepEngine::new()), &t, &c).unwrap();
        assert_eq!(out.rows.len(), 2 * t.sections.len());
        assert!(out.rows.iter().all(|r| r.avg.is_finite() && r.avg >= r.min));
    }

    #[test]
    fn event_backend_plan_is_deterministic_across_thread_counts() {
        use crate::netsim::Scenario;
        let grid = Grid::new()
            .cluster(tiny())
            .op(OpKind::Bcast)
            .algs([registry::klane(1), registry::klane(2), registry::fulllane()])
            .counts(&[1, 64, 6000]);
        let plan = Plan::new().table(1, "det", PersonaName::OpenMpi, &grid);
        let run = |threads| {
            let c = cfg().threads(threads).backend(Backend::Event(Scenario::contended()));
            run_plan_with(&Arc::new(SweepEngine::new()), &plan, &c).unwrap().text()
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn run_plan_propagates_broken_specs_as_typed_errors() {
        // bruck does not support bcast: the plan must fail with a typed
        // PlanError naming the table and section, not panic.
        let grid = Grid::new()
            .cluster(tiny())
            .op(OpKind::Bcast)
            .alg(registry::bruck(2))
            .counts(&[1]);
        let plan = Plan::new().table(99, "broken", PersonaName::OpenMpi, &grid);
        let err = run_plan_with(&Arc::new(SweepEngine::new()), &plan, &cfg()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.starts_with("table 99, section "), "{msg}");
        assert!(msg.contains("bruck does not support bcast"), "{msg}");
        assert!(matches!(
            err,
            PlanError::Section { table: 99, source: AlgError::UnsupportedCombination { .. }, .. }
        ));
    }

    #[test]
    fn empty_grids_are_rejected_not_silently_empty() {
        // Forgotten .counts(…): typed error, not an empty report.
        let grid = Grid::new().cluster(tiny()).op(OpKind::Bcast).alg(registry::klane(1));
        let plan = Plan::new().table(5, "no counts", PersonaName::OpenMpi, &grid);
        let err = run_plan_with(&Arc::new(SweepEngine::new()), &plan, &cfg()).unwrap_err();
        assert!(err.to_string().contains("empty count series"), "{err}");

        // Forgotten .algs(…) (no sections at all).
        let plan = Plan::new().table(6, "no sections", PersonaName::OpenMpi, &Grid::new());
        let err = run_plan_with(&Arc::new(SweepEngine::new()), &plan, &cfg()).unwrap_err();
        assert!(err.to_string().contains("no sections"), "{err}");
    }

    #[test]
    fn build_time_failures_stop_the_plan_early() {
        // klane supports bcast, so the static pre-pass passes; k > cores
        // surfaces at schedule build and must come back as a typed
        // error (after which remaining sections are skipped).
        let grid = Grid::new()
            .cluster(Cluster::new(2, 2, 2))
            .op(OpKind::Bcast)
            .alg(registry::klane(9))
            .counts(&[1]);
        let plan = Plan::new().table(7, "bad k", PersonaName::OpenMpi, &grid);
        let err = run_plan_with(&Arc::new(SweepEngine::new()), &plan, &cfg()).unwrap_err();
        assert!(
            matches!(
                err,
                PlanError::Section { source: AlgError::InvalidK { k: 9, .. }, .. }
            ),
            "{err}"
        );
    }

    #[test]
    fn appendix_runs_on_a_small_cluster() {
        // The preset's grid is valid end to end (klane2p builds).
        let t = Plan::appendix().tables.remove(0).with_grid(Cluster::new(2, 8, 2), &[1]);
        let out =
            run_table_with(&Arc::new(SweepEngine::new()), &t, &cfg()).unwrap();
        assert_eq!(out.rows.len(), 6);
    }

    #[test]
    fn one_worker_pool_spans_tables() {
        // Two tables sweeping the same shape through one engine: the
        // second table's sections must be served from the first's cached
        // schedules even when the plan runs multi-threaded.
        let engine = Arc::new(SweepEngine::new());
        let grid = Grid::new()
            .cluster(tiny())
            .op(OpKind::Bcast)
            .alg(registry::fulllane())
            .counts(&[1, 64]);
        let plan = Plan::new()
            .table(1, "first", PersonaName::OpenMpi, &grid)
            .table(2, "second", PersonaName::OpenMpi, &grid);
        let report = run_plan_with(&engine, &plan, &cfg().threads(4)).unwrap();
        assert_eq!(report.tables.len(), 2);
        let st = engine.stats();
        assert_eq!(st.schedules_built, 1, "{st:?}");
        assert_eq!(st.cells, 4, "{st:?}");
    }

    #[test]
    fn from_env_defaults_without_overrides() {
        // No env mutation in tests: just check the default shape (the
        // subprocess CLI tests pin the env-override path race-free).
        let cfg = RunConfig::default();
        assert_eq!(cfg.reps, sim::DEFAULT_REPS);
        assert_eq!(cfg.cache_shapes, DEFAULT_CACHE_SHAPES);
        assert!(cfg.threads >= 1);
        assert_eq!(cfg.out_dir, PathBuf::from("bench_out"));
    }
}

//! Paper reference anchors: measured values transcribed from the
//! paper's tables (avg µs of the slowest rank), used to check that the
//! simulation reproduces the paper's *shape* — who wins, by roughly what
//! factor, where the crossovers sit — without pretending to match a real
//! OmniPath testbed absolutely.

use super::{run_table, table, PlanError, RunConfig};

/// One transcribed cell of a paper table.
#[derive(Clone, Copy, Debug)]
pub struct Anchor {
    pub table: u32,
    /// Section heading substring to match (e.g. "k = 2 lanes").
    pub section: &'static str,
    pub c: u64,
    pub paper_avg_us: f64,
}

/// Key cells from every experiment family (small + large count per
/// series; the full tables live in the paper).
pub fn anchors() -> Vec<Anchor> {
    vec![
        // §4.1 — Table 2 (Open MPI k-ported alltoall, node vs net)
        Anchor { table: 2, section: "N=32", c: 1, paper_avg_us: 20.14 },
        Anchor { table: 2, section: "N=32", c: 31250, paper_avg_us: 448.03 },
        Anchor { table: 2, section: "N=1", c: 1, paper_avg_us: 17.85 },
        Anchor { table: 2, section: "N=1", c: 31250, paper_avg_us: 4618.21 },
        // Table 3 (Open MPI native alltoall)
        Anchor { table: 3, section: "N=32", c: 31250, paper_avg_us: 2087.67 },
        Anchor { table: 3, section: "N=1", c: 31250, paper_avg_us: 4400.47 },
        // §4.2 — broadcast, Open MPI
        Anchor { table: 8, section: "k = 1", c: 1000000, paper_avg_us: 19657.63 },
        Anchor { table: 8, section: "k = 2", c: 1000000, paper_avg_us: 28057.86 },
        Anchor { table: 10, section: "1-ported", c: 1000000, paper_avg_us: 9206.83 },
        Anchor { table: 10, section: "2-ported", c: 1000000, paper_avg_us: 8600.59 },
        Anchor { table: 12, section: "Full-lane", c: 1000000, paper_avg_us: 3309.16 },
        Anchor { table: 12, section: "MPI_Bcast", c: 60000, paper_avg_us: 642.72 },
        Anchor { table: 12, section: "MPI_Bcast", c: 100000, paper_avg_us: 8753.50 },
        Anchor { table: 12, section: "MPI_Bcast", c: 1000000, paper_avg_us: 18067.27 },
        // Intel
        Anchor { table: 17, section: "MPI_Bcast", c: 1, paper_avg_us: 965.34 },
        Anchor { table: 17, section: "Full-lane", c: 1000000, paper_avg_us: 4268.80 },
        // mpich
        Anchor { table: 22, section: "MPI_Bcast", c: 1000000, paper_avg_us: 5779.13 },
        Anchor { table: 22, section: "Full-lane", c: 1000000, paper_avg_us: 4878.80 },
        // §4.3 — scatter, Open MPI
        Anchor { table: 23, section: "1 lane", c: 869, paper_avg_us: 458.39 },
        Anchor { table: 25, section: "1-ported", c: 869, paper_avg_us: 453.82 },
        Anchor { table: 26, section: "6-ported", c: 869, paper_avg_us: 388.39 },
        Anchor { table: 27, section: "Full-lane", c: 869, paper_avg_us: 1444.02 },
        Anchor { table: 27, section: "MPI_Scatter", c: 869, paper_avg_us: 1001.17 },
        // §4.4 — alltoall, Open MPI
        Anchor { table: 38, section: "32 virtual", c: 1, paper_avg_us: 827.90 },
        Anchor { table: 38, section: "32 virtual", c: 869, paper_avg_us: 11848.12 },
        Anchor { table: 39, section: "1-ported", c: 1, paper_avg_us: 2210.90 },
        Anchor { table: 39, section: "1-ported", c: 869, paper_avg_us: 11784.61 },
        Anchor { table: 41, section: "Full-lane", c: 1, paper_avg_us: 121.41 },
        Anchor { table: 41, section: "Full-lane", c: 869, paper_avg_us: 12233.77 },
        Anchor { table: 41, section: "MPI_Alltoall", c: 521, paper_avg_us: 166279.34 },
    ]
}

/// Comparison of a simulated cell against its paper anchor.
#[derive(Clone, Debug)]
pub struct Comparison {
    pub anchor: Anchor,
    pub simulated_avg_us: f64,
    /// simulated / paper.
    pub ratio: f64,
}

/// Run all anchored tables and report simulated-vs-paper ratios.
/// Expensive (full Hydra-scale sims); used by `mlane compare` and the
/// EXPERIMENTS.md generation, not by unit tests.
pub fn compare_all(cfg: &RunConfig) -> Result<Vec<Comparison>, PlanError> {
    let mut out = Vec::new();
    let mut by_table: std::collections::BTreeMap<u32, Vec<Anchor>> = Default::default();
    for a in anchors() {
        by_table.entry(a.table).or_default().push(a);
    }
    for (num, anchs) in by_table {
        let Some(spec) = table(num) else { continue };
        let result = run_table(&spec, cfg)?;
        for a in anchs {
            let cell = result
                .rows
                .iter()
                .find(|r| r.c == a.c && r.section.contains(a.section));
            if let Some(cell) = cell {
                out.push(Comparison {
                    anchor: a,
                    simulated_avg_us: cell.avg,
                    ratio: cell.avg / a.paper_avg_us,
                });
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_reference_existing_tables() {
        for a in anchors() {
            let t = table(a.table).unwrap_or_else(|| panic!("table {} missing", a.table));
            assert!(
                t.sections.iter().any(|s| s.heading.contains(a.section)),
                "table {}: no section matching {:?} in {:?}",
                a.table,
                a.section,
                t.sections.iter().map(|s| &s.heading).collect::<Vec<_>>()
            );
            assert!(
                t.sections.iter().any(|s| s.counts.contains(&a.c)),
                "table {}: count {} not swept",
                a.table,
                a.c
            );
        }
    }

    #[test]
    fn anchors_cover_all_experiment_families() {
        let tables: std::collections::HashSet<u32> =
            anchors().iter().map(|a| a.table).collect();
        // node-vs-net, bcast × 3 libraries, scatter, alltoall
        for required in [2, 3, 8, 12, 17, 22, 23, 27, 38, 41] {
            assert!(tables.contains(&required), "table {required} unanchored");
        }
    }
}

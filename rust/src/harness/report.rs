//! Report emission: completed tables flow through [`Sink`]s.
//!
//! [`run_plan`](super::run_plan) returns a [`Report`] — the plan's
//! [`TableOut`]s in spec order. Emission is a separate, pluggable
//! layer:
//!
//! * [`TextSink`] — the paper-style text rendering (byte-identical to
//!   the pre-plan-API `TableOut::render`; the golden test pins it);
//! * [`CsvSink`] — one `table_<nn>.csv` per table in a directory, same
//!   schema as the old `write_csv`;
//! * [`JsonSink`] — a JSON array carrying the **full spec plus rows**
//!   per table (cluster, op, algorithm, count series — everything
//!   needed to re-run or diff a scenario), for the BENCH trajectory
//!   tooling and external analysis.
//!
//! Sinks receive tables one at a time (`table`) and a final `finish`,
//! so they can stream; [`Report::emit`] drives the sequence.

use std::io::{self, Write};
use std::path::{Path, PathBuf};

use super::TableOut;

/// A destination for completed tables.
pub trait Sink {
    /// Emit one completed table.
    fn table(&mut self, t: &TableOut) -> io::Result<()>;

    /// Called once after the last table (flush trailers).
    fn finish(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// The result of a plan run: completed tables in spec order.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub tables: Vec<TableOut>,
}

impl Report {
    /// Drive every table through a sink, then finish it.
    pub fn emit(&self, sink: &mut dyn Sink) -> io::Result<()> {
        for t in &self.tables {
            sink.table(t)?;
        }
        sink.finish()
    }

    /// The whole report as paper-style text (the [`TextSink`] format).
    pub fn text(&self) -> String {
        self.tables.iter().map(table_text).collect()
    }

    /// The whole report as a JSON array (the [`JsonSink`] format).
    pub fn json(&self) -> String {
        let mut buf = Vec::new();
        let mut sink = JsonSink::new(&mut buf);
        self.emit(&mut sink).expect("in-memory sink cannot fail");
        String::from_utf8(buf).expect("json sink emits utf-8")
    }
}

/// Paper-style text for one table — the exact format of the
/// pre-redesign renderer (`rust/tests/plan_report.rs` pins this
/// byte-for-byte against a verbatim copy of the old code).
pub(crate) fn table_text(t: &TableOut) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "Table {}: {} [{}]",
        t.spec.number,
        t.spec.caption,
        t.spec.persona.label()
    );
    let mut current: Option<&str> = None;
    for r in &t.rows {
        if current != Some(r.section.as_str()) {
            current = Some(r.section.as_str());
            let _ = writeln!(out, "  -- {} --", r.section);
            let _ = writeln!(
                out,
                "  {:>2} {:>4} {:>4} {:>5} {:>9} {:>12} {:>12}",
                "k", "n", "N", "p", "c", "avg(us)", "min(us)"
            );
        }
        let _ = writeln!(
            out,
            "  {:>2} {:>4} {:>4} {:>5} {:>9} {:>12.2} {:>12.2}",
            r.k, r.n, r.nodes, r.p, r.c, r.avg, r.min
        );
    }
    out
}

/// CSV lines for one table — the old `write_csv` schema.
pub(crate) fn table_csv(t: &TableOut) -> String {
    use std::fmt::Write as _;
    let mut out = String::from("table,persona,section,k,n,N,p,c,avg_us,min_us\n");
    for r in &t.rows {
        let _ = writeln!(
            out,
            "{},{},{},{},{},{},{},{},{:.2},{:.2}",
            t.spec.number,
            t.spec.persona.label(),
            r.section,
            r.k,
            r.n,
            r.nodes,
            r.p,
            r.c,
            r.avg,
            r.min
        );
    }
    out
}

/// Paper-style text to any writer.
pub struct TextSink<W: Write> {
    w: W,
}

impl<W: Write> TextSink<W> {
    pub fn new(w: W) -> Self {
        TextSink { w }
    }
}

impl<W: Write> Sink for TextSink<W> {
    fn table(&mut self, t: &TableOut) -> io::Result<()> {
        self.w.write_all(table_text(t).as_bytes())
    }

    fn finish(&mut self) -> io::Result<()> {
        self.w.flush()
    }
}

/// One `table_<nn>.csv` per table under a directory.
pub struct CsvSink {
    dir: PathBuf,
    written: Vec<PathBuf>,
}

impl CsvSink {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        CsvSink { dir: dir.into(), written: Vec::new() }
    }

    /// Paths written so far, in emission order.
    pub fn written(&self) -> &[PathBuf] {
        &self.written
    }

    /// The file a table lands in.
    pub fn path_for(dir: &Path, table: u32) -> PathBuf {
        dir.join(format!("table_{table:02}.csv"))
    }
}

impl Sink for CsvSink {
    fn table(&mut self, t: &TableOut) -> io::Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        let path = Self::path_for(&self.dir, t.spec.number);
        std::fs::write(&path, table_csv(t))?;
        self.written.push(path);
        Ok(())
    }
}

/// A JSON array of table objects, each carrying the full spec (per
/// section: heading, cluster dims, op, algorithm family, k, count
/// series) plus the measured rows.
pub struct JsonSink<W: Write> {
    w: W,
    started: bool,
}

impl<W: Write> JsonSink<W> {
    pub fn new(w: W) -> Self {
        JsonSink { w, started: false }
    }
}

impl<W: Write> Sink for JsonSink<W> {
    fn table(&mut self, t: &TableOut) -> io::Result<()> {
        let lead = if self.started { ",\n" } else { "[\n" };
        self.started = true;
        self.w.write_all(lead.as_bytes())?;
        self.w.write_all(table_json(t).as_bytes())
    }

    fn finish(&mut self) -> io::Result<()> {
        if !self.started {
            self.w.write_all(b"[")?;
        }
        self.w.write_all(b"\n]\n")?;
        self.w.flush()
    }
}

/// Minimal JSON string escaping (the emitted strings are ASCII labels,
/// but stay correct for anything). Shared with the `tuning` module's
/// decision-table writer so both hand-rolled emitters escape alike.
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// The spec part of a table's JSON object — the fields up to and
/// including the `sections` array, without the enclosing braces. Shared
/// between [`JsonSink`] table objects and the shard artifacts
/// (`harness::shard`), so a merged report re-emits the exact bytes a
/// single-process run would.
pub(crate) fn table_spec_fields(spec: &super::TableSpec) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = write!(
        out,
        "\"table\":{},\"caption\":\"{}\",\"persona\":\"{}\",\"persona_label\":\"{}\",\"sections\":[",
        spec.number,
        esc(&spec.caption),
        spec.persona.key(),
        esc(spec.persona.label()),
    );
    for (i, s) in spec.sections.iter().enumerate() {
        let k = match s.alg.k() {
            Some(k) => k.to_string(),
            None => "null".into(),
        };
        let counts: Vec<String> = s.counts.iter().map(|c| c.to_string()).collect();
        let _ = write!(
            out,
            "{}{{\"heading\":\"{}\",\"nodes\":{},\"cores\":{},\"lanes\":{},\"p\":{},\"op\":\"{}\",\"alg\":\"{}\",\"k\":{},\"counts\":[{}]}}",
            if i == 0 { "" } else { "," },
            esc(&s.heading),
            s.cluster.nodes,
            s.cluster.cores,
            s.cluster.lanes,
            s.cluster.p(),
            s.op.name(),
            s.alg.name(),
            k,
            counts.join(","),
        );
    }
    out.push(']');
    out
}

fn table_json(t: &TableOut) -> String {
    use std::fmt::Write as _;
    let mut out = String::from("{");
    out.push_str(&table_spec_fields(&t.spec));
    out.push_str(",\"rows\":[");
    for (i, r) in t.rows.iter().enumerate() {
        let _ = write!(
            out,
            "{}{{\"section\":\"{}\",\"k\":{},\"n\":{},\"N\":{},\"p\":{},\"c\":{},\"avg_us\":{},\"min_us\":{}}}",
            if i == 0 { "" } else { "," },
            esc(&r.section),
            r.k,
            r.n,
            r.nodes,
            r.p,
            r.c,
            r.avg,
            r.min,
        );
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::super::{Row, Section, TableSpec};
    use super::*;
    use crate::algorithms::registry::{self, OpKind};
    use crate::model::PersonaName;
    use crate::topology::Cluster;
    use std::sync::Arc;

    fn sample() -> TableOut {
        let spec = TableSpec {
            number: 7,
            caption: "sample \"quoted\" caption".into(),
            persona: PersonaName::Mpich,
            sections: vec![Section {
                heading: "Bcast, k = 2 lanes".into(),
                cluster: Cluster::new(2, 4, 2),
                op: OpKind::Bcast,
                alg: registry::klane(2),
                counts: Arc::from(&[1u64, 64][..]),
            }],
        };
        let rows = vec![
            Row {
                section: "Bcast, k = 2 lanes".into(),
                k: 2,
                n: 4,
                nodes: 2,
                p: 8,
                c: 1,
                avg: 12.5,
                min: 10.25,
            },
            Row {
                section: "Bcast, k = 2 lanes".into(),
                k: 2,
                n: 4,
                nodes: 2,
                p: 8,
                c: 64,
                avg: 14.0,
                min: 13.0,
            },
        ];
        TableOut { spec, rows }
    }

    #[test]
    fn text_sink_renders_paper_style() {
        let t = sample();
        let mut buf = Vec::new();
        let report = Report { tables: vec![t.clone()] };
        report.emit(&mut TextSink::new(&mut buf)).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert_eq!(text, report.text());
        assert!(text.starts_with("Table 7: sample \"quoted\" caption [mpich 3.3]\n"), "{text}");
        assert!(text.contains("  -- Bcast, k = 2 lanes --\n"), "{text}");
        assert!(text.contains("avg(us)"), "{text}");
        // One header pair + two rows, section printed once.
        assert_eq!(text.matches("-- Bcast").count(), 1);
        assert_eq!(text.lines().count(), 5);
    }

    #[test]
    fn csv_sink_writes_old_schema() {
        let t = sample();
        let dir = std::env::temp_dir().join("mlane_csv_sink_test");
        let mut sink = CsvSink::new(&dir);
        Report { tables: vec![t] }.emit(&mut sink).unwrap();
        assert_eq!(sink.written().len(), 1);
        let text = std::fs::read_to_string(&sink.written()[0]).unwrap();
        assert!(text.starts_with("table,persona,section,k,n,N,p,c,avg_us,min_us\n"), "{text}");
        assert!(text.contains("7,mpich 3.3,Bcast, k = 2 lanes,2,4,2,8,1,12.50,10.25"), "{text}");
        assert_eq!(text.lines().count(), 3);
    }

    #[test]
    fn json_sink_carries_spec_and_rows() {
        let report = Report { tables: vec![sample()] };
        let json = report.json();
        assert!(json.starts_with("[\n{\"table\":7,"), "{json}");
        assert!(json.trim_end().ends_with("]"), "{json}");
        assert!(json.contains("\"caption\":\"sample \\\"quoted\\\" caption\""), "{json}");
        assert!(json.contains("\"persona\":\"mpich\""), "{json}");
        assert!(json.contains("\"alg\":\"klane\""), "{json}");
        assert!(json.contains("\"k\":2"), "{json}");
        assert!(json.contains("\"counts\":[1,64]"), "{json}");
        assert!(json.contains("\"avg_us\":12.5"), "{json}");
    }

    #[test]
    fn empty_report_is_still_valid_json() {
        let json = Report::default().json();
        assert_eq!(json, "[\n]\n");
    }

    #[test]
    fn escaping_covers_controls() {
        assert_eq!(esc("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(esc("\u{1}"), "\\u0001");
    }
}

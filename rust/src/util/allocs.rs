//! Thread-local allocation counting, for verifying the sweep engine's
//! zero-steady-state-allocation contract.
//!
//! The crate installs a [`CountingAllocator`] as the global allocator:
//! a thin wrapper over [`System`] that bumps a thread-local counter on
//! every `alloc`/`realloc`/`alloc_zeroed` (deallocation is free). The
//! counter is per-thread, so concurrently running tests and worker
//! threads never perturb each other's readings, and it is active in
//! release builds too — `engine_perf` reports real allocation counts
//! for the warm series path (`series_steady_allocs` in
//! `BENCH_engine.json`), and `rust/tests/series_alloc.rs` gates them
//! at zero. The overhead is one thread-local increment per allocation,
//! far below the noise floor of anything the benches time.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    /// Allocations made by this thread (monotone; never reset). Const-
    /// initialised with no destructor, so the allocator itself may read
    /// and bump it without re-entering the allocator.
    static TALLY: Cell<u64> = const { Cell::new(0) };
}

/// Total heap allocations made by the current thread since it started.
/// Subtract two readings around a region to count its allocations —
/// the probe behind the zero-steady-state-allocation gate.
pub fn thread_allocations() -> u64 {
    TALLY.with(|t| t.get())
}

#[inline]
fn bump() {
    TALLY.with(|t| t.set(t.get() + 1));
}

/// [`System`], plus a thread-local allocation tally.
pub struct CountingAllocator;

// SAFETY: delegates every operation unchanged to `System`; the tally is
// a const-initialised thread-local Cell (no allocation, no destructor),
// so bumping it cannot recurse into the allocator. This is the one
// unsafe block the crate-level `#![deny(unsafe_code)]` exempts — a
// `GlobalAlloc` impl cannot be written without it.
#[allow(unsafe_code)]
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_this_threads_allocations() {
        let before = thread_allocations();
        let v: Vec<u64> = Vec::with_capacity(32);
        let after = thread_allocations();
        drop(v);
        assert!(after > before, "allocation not counted: {before} -> {after}");
        // Dropping does not count.
        let freed = thread_allocations();
        assert_eq!(freed, after);
    }

    #[test]
    fn other_threads_do_not_bleed_in() {
        let before = thread_allocations();
        std::thread::spawn(|| {
            let _v: Vec<u64> = vec![0; 1024];
        })
        .join()
        .unwrap();
        // Spawning takes allocations on the *spawning* thread (stack
        // handle, closure box), but the vec inside must count against
        // the child only — readings here stay self-consistent either
        // way; just pin that the counter is monotone and thread-local.
        let after = thread_allocations();
        assert!(after >= before);
    }
}

//! Deterministic PRNG (SplitMix64 + xoshiro256**), used for simulator
//! jitter, payload generation and property tests. No external deps.

/// xoshiro256** seeded via SplitMix64. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) (bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        // Lemire's multiply-shift; bias negligible for our bounds.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Exponentially-distributed positive value with the given mean.
    /// Used by the simulator's jitter model (OS noise looks heavy-tailed).
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..1000 {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut p = Prng::new(9);
        for _ in 0..1000 {
            assert!(p.below(10) < 10);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut p = Prng::new(11);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = p.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn exp_positive_and_mean_roughly_right() {
        let mut p = Prng::new(13);
        let mean = 4.0;
        let mut sum = 0.0;
        let reps = 20_000;
        for _ in 0..reps {
            let v = p.exp(mean);
            assert!(v >= 0.0);
            sum += v;
        }
        let emp = sum / reps as f64;
        assert!((emp - mean).abs() < 0.2, "empirical mean {emp}");
    }
}

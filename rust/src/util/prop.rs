//! Minimal property-testing harness (proptest is not available offline).
//!
//! `check(name, cases, |g| ...)` runs a closure against `cases`
//! independently-seeded [`Gen`]s; on failure it reports the failing seed
//! so the case can be replayed deterministically with [`replay`].

use super::prng::Prng;

/// Value generator wrapping a deterministic PRNG.
pub struct Gen {
    pub rng: Prng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Prng::new(seed), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as u64, hi as u64) as usize
    }

    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

/// Run `f` on `cases` generated inputs. Panics (with the seed) on the
/// first failure. `f` should panic/assert on property violation.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut f: F) {
    for i in 0..cases {
        let seed = 0x5EED_0000_0000 + i;
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            eprintln!("property `{name}` failed at case {i} (replay seed: {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnMut(&mut Gen)>(seed: u64, mut f: F) {
    let mut g = Gen::new(seed);
    f(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check("counting", 10, |_g| count += 1);
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check("fails", 5, |g| {
            let v = g.usize_in(0, 100);
            assert!(v < 1000); // passes
            assert!(v == usize::MAX); // fails
        });
    }

    #[test]
    fn gen_in_bounds() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let v = g.usize_in(2, 7);
            assert!((2..=7).contains(&v));
        }
    }
}

//! Small self-contained utilities: deterministic PRNG, statistics,
//! and a property-testing harness (no external crates are available
//! offline, so these are in-repo).

pub mod allocs;
pub mod prng;
pub mod stats;
pub mod prop;

pub use prng::Prng;
pub use stats::Summary;

//! Run statistics matching the paper's measurement scheme: average and
//! minimum of the slowest process over repetitions (§4: 100 reps, 5
//! warm-up not measured).

/// Summary of a series of per-repetition times (already the max over
/// ranks — "time of the slowest process").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub avg: f64,
    pub min: f64,
    pub max: f64,
    pub reps: usize,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "no samples");
        let mut sum = 0.0;
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for &s in samples {
            sum += s;
            if s < min {
                min = s;
            }
            if s > max {
                max = s;
            }
        }
        Self { avg: sum / samples.len() as f64, min, max, reps: samples.len() }
    }
}

/// Collects per-rep slowest-rank times, discarding warm-up reps,
/// mirroring the paper's MPI_Barrier + MPI_Wtime loop.
#[derive(Clone, Debug, Default)]
pub struct RepCollector {
    warmup_left: usize,
    samples: Vec<f64>,
}

impl RepCollector {
    pub fn new(warmup: usize, reps: usize) -> Self {
        Self { warmup_left: warmup, samples: Vec::with_capacity(reps) }
    }

    pub fn push(&mut self, slowest_rank_time: f64) {
        if self.warmup_left > 0 {
            self.warmup_left -= 1;
        } else {
            self.samples.push(slowest_rank_time);
        }
    }

    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples)
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.avg, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.reps, 3);
    }

    #[test]
    #[should_panic(expected = "no samples")]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn collector_discards_warmup() {
        let mut c = RepCollector::new(2, 3);
        for t in [100.0, 100.0, 1.0, 2.0, 3.0] {
            c.push(t);
        }
        let s = c.summary();
        assert_eq!(s.reps, 3);
        assert_eq!(s.avg, 2.0);
        assert_eq!(s.min, 1.0);
    }
}

//! Threaded execution backend ("mini-MPI"): really runs a [`Schedule`]
//! on real buffers, with one OS thread per rank, mailbox-based
//! nonblocking message passing, and per-round waitall — the same
//! semantics the simulator models. Used to (a) prove every schedule's
//! data movement is correct on actual payloads and (b) measure real
//! wallclock for the end-to-end examples.
//!
//! Node-local phases (consecutive rounds tagged with the same
//! [`LocalOpKind`] hint whose transfers are *all* on-node) form a *phase
//! group*. In [`PhaseMode::Xla`] a node leader executes a whole group as
//! one call into the AOT-compiled artifacts (see [`crate::runtime`]) —
//! the three-layer integration point: L3 coordination, L2/L1 compute.
//! Groups whose shape has no artifact fall back to channel execution.

mod payload;
mod phases;

#[cfg(test)]
mod tests;

pub use payload::{block_elem, gen_block};

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Barrier, Condvar, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::XlaService;
use crate::schedule::{LocalOpKind, Schedule, Sizing, Transfer};
use crate::util::stats::{RepCollector, Summary};

/// How node-phase rounds are executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseMode {
    /// Always pairwise messages through mailboxes.
    Channels,
    /// Use XLA artifacts for hinted phase groups when shapes match.
    Xla,
}

/// Execution report for one collective run.
#[derive(Clone, Debug)]
pub struct ExecReport {
    pub summary: Summary,
    /// Blocks verified against the payload generator at the final rep.
    pub blocks_verified: u64,
    /// Phase-group executions that went through XLA artifacts.
    pub xla_phases: u64,
}

/// A message: the transfer's blocks with their payloads.
pub(crate) type Message = Vec<(u64, Vec<i32>)>;

/// Per-rank block storage, shared with phase leaders.
pub(crate) type Store = Mutex<HashMap<u64, Vec<i32>>>;

/// Typed execution-layer errors. These were `debug_assert!`s — invisible
/// in release builds, and the duplicate-key case would then hang the
/// run (a single-slot mailbox overwrite leaves the second `take`
/// waiting forever). Now they surface as real errors everywhere.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecError {
    /// Two transfers share a (src, dst, round) mailbox key: the
    /// schedule breaks the one-message-per-pair-per-round invariant
    /// the mailbox protocol is keyed on.
    DuplicateMessage { src: u32, dst: u32, round: u32 },
    /// An XLA phase leader assembled fewer elements for a (src, dst)
    /// core pair than the group's uniform count promises.
    UnderfilledPair { i: u32, j: u32, expected: u64, got: u64 },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::DuplicateMessage { src, dst, round } => {
                write!(f, "duplicate message {src} -> {dst} in round {round}")
            }
            ExecError::UnderfilledPair { i, j, expected, got } => {
                write!(f, "pair ({i},{j}) underfilled: {got}/{expected} elements")
            }
        }
    }
}

impl std::error::Error for ExecError {}

/// Per-rank mailbox keyed by (src, round). Each key holds a queue, not
/// a slot: delivery can never silently overwrite, so even a schedule
/// that slips past validation cannot wedge a receiver — it fails with a
/// typed [`ExecError`] at preflight instead.
#[derive(Default)]
struct Mailbox {
    slots: Mutex<HashMap<(u32, u32), Vec<Message>>>,
    bell: Condvar,
}

impl Mailbox {
    fn put(&self, key: (u32, u32), msg: Message) {
        self.slots.lock().unwrap().entry(key).or_default().push(msg);
        self.bell.notify_all();
    }

    fn take(&self, key: (u32, u32)) -> Message {
        let mut slots = self.slots.lock().unwrap();
        loop {
            if let Some(q) = slots.get_mut(&key) {
                let m = q.pop().expect("emptied queues are removed");
                if q.is_empty() {
                    slots.remove(&key);
                }
                return m;
            }
            slots = self.bell.wait(slots).unwrap();
        }
    }
}

/// One rank's view of a round.
pub(crate) struct RankRound {
    round: u32,
    sends: Vec<Transfer>,
    recvs: Vec<(u32, u32)>, // (src, round) mailbox keys
}

/// A maximal run of same-kind hinted rounds, with everything the XLA
/// leader path needs. `c_eff` is the uniform per-(src,dst)-pair element
/// count (concatenating multi-block pairs); `None` if non-uniform.
#[derive(Clone, Debug)]
pub(crate) struct PhaseGroup {
    pub kind: LocalOpKind,
    pub first_round: u32,
    pub last_round: u32,
    pub pure_local: bool,
    pub c_eff: Option<u64>,
    /// Uniform per-source element count of the group's *first* round
    /// (the per-core contribution an allgather artifact needs).
    pub c_contrib: Option<u64>,
}

#[derive(Clone)]
enum Step {
    Rounds(std::ops::Range<usize>), // indexes into the rank's RankRound list
    Phase(usize),                   // index into the phase-group list
}

pub struct ExecRuntime {
    pub mode: PhaseMode,
    pub xla: Option<XlaService>,
    /// Maximum rank count we are willing to spawn threads for.
    pub max_threads: u32,
}

impl ExecRuntime {
    pub fn channels() -> Self {
        Self { mode: PhaseMode::Channels, xla: None, max_threads: 256 }
    }

    pub fn with_xla(svc: XlaService) -> Self {
        Self { mode: PhaseMode::Xla, xla: Some(svc), max_threads: 256 }
    }

    /// Execute the schedule `reps + warmup` times, verifying delivered
    /// payloads on the last repetition.
    pub fn run(&self, schedule: &Schedule, reps: usize, warmup: usize) -> Result<ExecReport> {
        let p = schedule.p();
        if p > self.max_threads {
            bail!("exec backend refuses p = {p} > {} threads", self.max_threads);
        }
        let cl = schedule.cluster;

        // ---- preflight: the mailbox protocol needs unique keys ----
        let mut seen: HashSet<(u32, u32, u32)> = HashSet::new();
        for (ri, round) in schedule.rounds.iter().enumerate() {
            for t in &round.transfers {
                if !seen.insert((t.src, t.dst, ri as u32)) {
                    return Err(ExecError::DuplicateMessage {
                        src: t.src,
                        dst: t.dst,
                        round: ri as u32,
                    }
                    .into());
                }
            }
        }

        // ---- preprocess: per-rank rounds ----
        let mut rank_rounds: Vec<Vec<RankRound>> = (0..p).map(|_| Vec::new()).collect();
        for (ri, round) in schedule.rounds.iter().enumerate() {
            for t in &round.transfers {
                let rr = &mut rank_rounds[t.src as usize];
                if rr.last().map(|r| r.round) != Some(ri as u32) {
                    rr.push(RankRound { round: ri as u32, sends: vec![], recvs: vec![] });
                }
                rr.last_mut().unwrap().sends.push(t.clone());
                let rr = &mut rank_rounds[t.dst as usize];
                if rr.last().map(|r| r.round) != Some(ri as u32) {
                    rr.push(RankRound { round: ri as u32, sends: vec![], recvs: vec![] });
                }
                rr.last_mut().unwrap().recvs.push((t.src, ri as u32));
            }
        }

        // ---- phase groups ----
        let groups = find_groups(schedule);
        let runnable: Vec<bool> = groups
            .iter()
            .map(|g| {
                self.mode == PhaseMode::Xla
                    && self.xla.is_some()
                    && g.pure_local
                    && phases::runnable(g, cl.cores)
            })
            .collect();

        // ---- per-rank step programs ----
        let progs: Vec<Vec<Step>> = (0..p as usize)
            .map(|r| build_steps(&rank_rounds[r], &groups, &runnable))
            .collect();

        // Every core of every node must reach the node barrier for each
        // runnable group — verify participation, else demote the group.
        // (All our builders' local collectives involve every core.)
        let mut phase_participants = vec![0u32; groups.len()];
        for prog in &progs {
            for s in prog {
                if let Step::Phase(gi) = s {
                    phase_participants[*gi] += 1;
                }
            }
        }
        for (gi, &n) in phase_participants.iter().enumerate() {
            if runnable[gi] && n != p {
                bail!(
                    "phase group {gi} ({:?}) reaches {n}/{p} ranks — builder bug",
                    groups[gi].kind
                );
            }
        }

        // ---- shared state ----
        let mailboxes: Arc<Vec<Mailbox>> =
            Arc::new((0..p).map(|_| Mailbox::default()).collect());
        let stores: Arc<Vec<Store>> =
            Arc::new((0..p).map(|_| Mutex::new(HashMap::new())).collect());
        let rep_barrier = Arc::new(Barrier::new(p as usize + 1));
        let node_barriers: Arc<Vec<Barrier>> = Arc::new(
            (0..cl.nodes).map(|_| Barrier::new(cl.cores as usize)).collect(),
        );
        let errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let xla_count = Arc::new(Mutex::new(0u64));

        let schedule = Arc::new(schedule.clone());
        let groups = Arc::new(groups);
        let total_reps = reps + warmup;

        let mut handles = Vec::new();
        for rank in 0..p {
            let ctx = WorkerCtx {
                rank,
                schedule: schedule.clone(),
                rounds: std::mem::take(&mut rank_rounds[rank as usize]),
                steps: progs[rank as usize].clone(),
                groups: groups.clone(),
                xla: self.xla.clone(),
                xla_count: xla_count.clone(),
                mailboxes: mailboxes.clone(),
                stores: stores.clone(),
                rep_barrier: rep_barrier.clone(),
                node_barriers: node_barriers.clone(),
                errors: errors.clone(),
                total_reps,
            };
            handles.push(std::thread::spawn(move || ctx.run()));
        }

        // Main thread paces reps and measures wallclock between barriers.
        let mut col = RepCollector::new(warmup, reps);
        for _rep in 0..total_reps {
            rep_barrier.wait(); // workers reset stores, ready to start
            let t0 = Instant::now();
            rep_barrier.wait(); // workers finished the collective
            col.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        for h in handles {
            h.join().expect("worker panicked");
        }
        let errs = errors.lock().unwrap();
        if !errs.is_empty() {
            bail!("exec verification failed: {}", errs.join("; "));
        }

        let blocks: u64 =
            (0..p).map(|r| schedule.op.required_blocks(r, p).count()).sum();
        let xla_phases = *xla_count.lock().unwrap();
        drop(errs);
        Ok(ExecReport { summary: col.summary(), blocks_verified: blocks, xla_phases })
    }
}

/// Scan the schedule for maximal runs of same-kind hinted rounds and
/// compute their properties.
pub(crate) fn find_groups(schedule: &Schedule) -> Vec<PhaseGroup> {
    let cl = schedule.cluster;
    let mut groups = Vec::new();
    let mut i = 0usize;
    while i < schedule.rounds.len() {
        let Some(kind) = schedule.rounds[i].node_phase else {
            i += 1;
            continue;
        };
        let first = i;
        while i + 1 < schedule.rounds.len() && schedule.rounds[i + 1].node_phase == Some(kind)
        {
            i += 1;
        }
        // Properties over the group's transfers.
        let mut pure_local = true;
        let mut pair_elems: HashMap<(u32, u32), u64> = HashMap::new();
        for round in &schedule.rounds[first..=i] {
            for t in &round.transfers {
                pure_local &= cl.same_node(t.src, t.dst);
                *pair_elems.entry((t.src, t.dst)).or_insert(0) += t.bytes / 4;
            }
        }
        let mut c_eff = None;
        let mut uniform = true;
        for &e in pair_elems.values() {
            match c_eff {
                None => c_eff = Some(e),
                Some(v) if v != e => uniform = false,
                _ => {}
            }
        }
        // Per-source contribution in the group's first round.
        let mut src_elems: HashMap<u32, u64> = HashMap::new();
        for t in &schedule.rounds[first].transfers {
            *src_elems.entry(t.src).or_insert(0) += t.bytes / 4;
        }
        let mut c_contrib = None;
        let mut contrib_uniform = true;
        for &e in src_elems.values() {
            match c_contrib {
                None => c_contrib = Some(e),
                Some(v) if v != e => contrib_uniform = false,
                _ => {}
            }
        }
        groups.push(PhaseGroup {
            kind,
            first_round: first as u32,
            last_round: i as u32,
            pure_local,
            c_eff: if uniform { c_eff } else { None },
            c_contrib: if contrib_uniform { c_contrib } else { None },
        });
        i += 1;
    }
    groups
}

fn build_steps(rounds: &[RankRound], groups: &[PhaseGroup], runnable: &[bool]) -> Vec<Step> {
    let in_runnable = |round: u32| -> Option<usize> {
        groups
            .iter()
            .enumerate()
            .find(|(gi, g)| runnable[*gi] && round >= g.first_round && round <= g.last_round)
            .map(|(gi, _)| gi)
    };
    let mut steps = Vec::new();
    let mut i = 0usize;
    while i < rounds.len() {
        if let Some(gi) = in_runnable(rounds[i].round) {
            let g = &groups[gi];
            while i < rounds.len() && rounds[i].round <= g.last_round {
                i += 1;
            }
            steps.push(Step::Phase(gi));
        } else {
            let start = i;
            while i < rounds.len() && in_runnable(rounds[i].round).is_none() {
                i += 1;
            }
            steps.push(Step::Rounds(start..i));
        }
    }
    steps
}

struct WorkerCtx {
    rank: u32,
    schedule: Arc<Schedule>,
    rounds: Vec<RankRound>,
    steps: Vec<Step>,
    groups: Arc<Vec<PhaseGroup>>,
    xla: Option<XlaService>,
    xla_count: Arc<Mutex<u64>>,
    mailboxes: Arc<Vec<Mailbox>>,
    stores: Arc<Vec<Store>>,
    rep_barrier: Arc<Barrier>,
    node_barriers: Arc<Vec<Barrier>>,
    errors: Arc<Mutex<Vec<String>>>,
    total_reps: usize,
}

impl WorkerCtx {
    fn run(self) {
        let schedule = &*self.schedule;
        let p = schedule.p();
        let cl = schedule.cluster;
        let sizing = schedule.op.sizing();
        let node = cl.node_of(self.rank);
        let core = cl.core_of(self.rank);

        for rep in 0..self.total_reps {
            {
                let mut st = self.stores[self.rank as usize].lock().unwrap();
                st.clear();
                for b in schedule.op.initial_blocks(self.rank, p).iter() {
                    st.insert(b, gen_block(b, block_elems(&sizing, b)));
                }
            }
            self.rep_barrier.wait(); // aligned start (the "MPI_Barrier")

            for step in &self.steps {
                match step {
                    Step::Rounds(range) => self.do_rounds(range.clone()),
                    Step::Phase(gi) => {
                        let g = &self.groups[*gi];
                        self.node_barriers[node as usize].wait();
                        if core == 0 {
                            let r = phases::run_leader(
                                schedule,
                                g,
                                node,
                                self.xla.as_ref().unwrap(),
                                &self.stores,
                            );
                            match r {
                                Ok(()) => *self.xla_count.lock().unwrap() += 1,
                                Err(e) => self
                                    .errors
                                    .lock()
                                    .unwrap()
                                    .push(format!("node {node} phase: {e}")),
                            }
                        }
                        self.node_barriers[node as usize].wait();
                    }
                }
            }

            self.rep_barrier.wait(); // end of rep

            if rep == self.total_reps - 1 {
                self.verify(p, &sizing);
            }
        }
    }

    fn do_rounds(&self, range: std::ops::Range<usize>) {
        for rr in &self.rounds[range] {
            for t in &rr.sends {
                let msg: Message = {
                    let st = self.stores[self.rank as usize].lock().unwrap();
                    t.blocks
                        .iter()
                        .map(|b| {
                            let data = st.get(&b).unwrap_or_else(|| {
                                panic!(
                                    "rank {} round {} missing block {b} ({})",
                                    self.rank, rr.round, self.schedule.algorithm
                                )
                            });
                            (b, data.clone())
                        })
                        .collect()
                };
                self.mailboxes[t.dst as usize].put((self.rank, rr.round), msg);
            }
            for &key in &rr.recvs {
                let msg = self.mailboxes[self.rank as usize].take(key);
                let mut st = self.stores[self.rank as usize].lock().unwrap();
                for (b, data) in msg {
                    st.insert(b, data);
                }
            }
        }
    }

    fn verify(&self, p: u32, sizing: &Sizing) {
        let st = self.stores[self.rank as usize].lock().unwrap();
        for b in self.schedule.op.required_blocks(self.rank, p).iter() {
            let want = gen_block(b, block_elems(sizing, b));
            match st.get(&b) {
                None => self
                    .errors
                    .lock()
                    .unwrap()
                    .push(format!("rank {}: missing block {b}", self.rank)),
                Some(got) if *got != want => self
                    .errors
                    .lock()
                    .unwrap()
                    .push(format!("rank {}: corrupt block {b}", self.rank)),
                _ => {}
            }
        }
    }
}

/// Per-block element count (Split sizing depends on the block id).
pub(crate) fn block_elems(sizing: &Sizing, b: u64) -> u64 {
    match sizing {
        Sizing::Uniform { elems } => *elems,
        Sizing::Split { .. } => sizing.elems(b),
    }
}

//! Deterministic payload generation: block contents are a pure function
//! of (block id, element index), so any rank can verify any delivered
//! block without reference copies.

/// Element `idx` of block `b` (splitmix-style mix, truncated to i32).
#[inline]
pub fn block_elem(b: u64, idx: u64) -> i32 {
    let mut z = b
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(idx.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (z >> 32) as i32
}

/// Materialise block `b` with `elems` elements.
pub fn gen_block(b: u64, elems: u64) -> Vec<i32> {
    (0..elems).map(|i| block_elem(b, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(gen_block(7, 16), gen_block(7, 16));
    }

    #[test]
    fn blocks_differ() {
        assert_ne!(gen_block(1, 8), gen_block(2, 8));
    }

    #[test]
    fn elements_differ_within_block() {
        let b = gen_block(3, 100);
        let distinct: std::collections::HashSet<_> = b.iter().collect();
        assert!(distinct.len() > 90);
    }
}

//! XLA-driven execution of node-local phase groups.
//!
//! A node leader (core 0) executes a whole phase group as one artifact
//! call: it assembles the input array from the member ranks' block
//! stores (they are parked at the node barrier, so the stores are
//! quiescent), calls [`XlaService::run`], and writes the outputs back.
//! Semantics are identical to executing the group's transfers pairwise
//! — the integration tests cross-check both paths block-for-block.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::{ExecError, PhaseGroup, Store};
use crate::runtime::XlaService;
use crate::schedule::{LocalOpKind, Schedule};

/// Shapes lowered by aot.py (NODE_SIZES × COUNTS in python/compile/aot.py).
fn artifact_shape_available(n: u32, c: u64) -> bool {
    matches!(n, 4 | 8) && matches!(c, 16 | 256 | 1024)
}

/// Can this group be run through an artifact at all?
pub(crate) fn runnable(g: &PhaseGroup, cores: u32) -> bool {
    let c = match g.kind {
        LocalOpKind::Alltoall | LocalOpKind::Bcast => g.c_eff,
        LocalOpKind::Allgather => g.c_contrib,
        LocalOpKind::Scatter => None,
    };
    c.is_some_and(|c| artifact_shape_available(cores, c))
}

/// Execute one phase group on one node through the XLA service.
pub(crate) fn run_leader(
    schedule: &Schedule,
    g: &PhaseGroup,
    node: u32,
    svc: &XlaService,
    stores: &[Store],
) -> Result<()> {
    match g.kind {
        LocalOpKind::Alltoall => alltoall(schedule, g, node, svc, stores),
        LocalOpKind::Bcast => bcast(schedule, g, node, svc, stores),
        LocalOpKind::Allgather => allgather(schedule, g, node, svc, stores),
        LocalOpKind::Scatter => Err(anyhow!("scatter groups are not XLA-runnable")),
    }
}

/// The (src_core, dst_core) → ordered block ids moved within the group on
/// this node. Blocks per pair are concatenated in ascending id order.
fn pair_blocks(
    schedule: &Schedule,
    g: &PhaseGroup,
    node: u32,
) -> HashMap<(u32, u32), Vec<u64>> {
    let cl = schedule.cluster;
    let mut pairs: HashMap<(u32, u32), Vec<u64>> = HashMap::new();
    for round in &schedule.rounds[g.first_round as usize..=g.last_round as usize] {
        for t in &round.transfers {
            if cl.node_of(t.src) != node {
                continue;
            }
            pairs
                .entry((cl.core_of(t.src), cl.core_of(t.dst)))
                .or_default()
                .extend(t.blocks.iter());
        }
    }
    for v in pairs.values_mut() {
        v.sort_unstable();
    }
    pairs
}

/// Node-local alltoall: input x[i][j] = concat of blocks core i sends to
/// core j; artifact transposes; write back y[i][j] (= x[j][i]) to core j…
/// i.e. the blocks core j *receives from* core i land at core j.
fn alltoall(
    schedule: &Schedule,
    g: &PhaseGroup,
    node: u32,
    svc: &XlaService,
    stores: &[Store],
) -> Result<()> {
    let cl = schedule.cluster;
    let n = cl.cores as usize;
    let c = g.c_eff.ok_or_else(|| anyhow!("non-uniform group"))? as usize;
    let pairs = pair_blocks(schedule, g, node);

    let mut x = vec![0i32; n * n * c];
    for (&(i, j), blocks) in &pairs {
        let src_rank = cl.rank_of(node, i);
        let st = stores[src_rank as usize].lock().unwrap();
        let off = (i as usize * n + j as usize) * c;
        let mut pos = off;
        for b in blocks {
            let d = st.get(b).ok_or_else(|| anyhow!("core {i} missing block {b}"))?;
            x[pos..pos + d.len()].copy_from_slice(d);
            pos += d.len();
        }
        if pos - off != c {
            return Err(ExecError::UnderfilledPair {
                i,
                j,
                expected: c as u64,
                got: (pos - off) as u64,
            }
            .into());
        }
    }

    let y = svc.run("node_alltoall", cl.cores, c as u64, x)?;

    // y[j][i] (after transpose) = x[i][j]: core j receives from core i.
    for (&(i, j), blocks) in &pairs {
        let dst_rank = cl.rank_of(node, j);
        let mut st = stores[dst_rank as usize].lock().unwrap();
        let off = (j as usize * n + i as usize) * c;
        let mut pos = off;
        for b in blocks {
            let len = crate::exec::block_elems(&schedule.op.sizing(), *b) as usize;
            st.insert(*b, y[pos..pos + len].to_vec());
            pos += len;
        }
    }
    Ok(())
}

/// Node-local broadcast: the group's root core (the unique core that only
/// sends in the group's first round) replicates one payload to all cores.
fn bcast(
    schedule: &Schedule,
    g: &PhaseGroup,
    node: u32,
    svc: &XlaService,
    stores: &[Store],
) -> Result<()> {
    let cl = schedule.cluster;
    // Entry core and blocks: the src of the group's earliest transfer on
    // this node.
    let mut entry: Option<(u32, Vec<u64>)> = None;
    'outer: for round in &schedule.rounds[g.first_round as usize..=g.last_round as usize] {
        for t in &round.transfers {
            if cl.node_of(t.src) == node {
                entry = Some((cl.core_of(t.src), t.blocks.iter().collect()));
                break 'outer;
            }
        }
    }
    let Some((root_core, blocks)) = entry else { return Ok(()) }; // group absent on node

    // Destination cores this group reaches.
    let mut dsts: Vec<u32> = Vec::new();
    for round in &schedule.rounds[g.first_round as usize..=g.last_round as usize] {
        for t in &round.transfers {
            if cl.node_of(t.dst) == node {
                dsts.push(cl.core_of(t.dst));
            }
        }
    }
    dsts.sort_unstable();
    dsts.dedup();

    let src_rank = cl.rank_of(node, root_core);
    let mut payload = Vec::new();
    {
        let st = stores[src_rank as usize].lock().unwrap();
        for b in &blocks {
            payload.extend_from_slice(
                st.get(b).ok_or_else(|| anyhow!("root missing block {b}"))?,
            );
        }
    }
    let c = payload.len() as u64;
    let y = svc.run("node_bcast", cl.cores, c, payload)?;
    let cc = c as usize;
    for &dcore in &dsts {
        let dst_rank = cl.rank_of(node, dcore);
        let mut st = stores[dst_rank as usize].lock().unwrap();
        let row = &y[dcore as usize * cc..(dcore as usize + 1) * cc];
        let mut pos = 0usize;
        for b in &blocks {
            let len = crate::exec::block_elems(&schedule.op.sizing(), *b) as usize;
            st.insert(*b, row[pos..pos + len].to_vec());
            pos += len;
        }
    }
    Ok(())
}

/// Node-local allgather: core j's contribution = the blocks it sends in
/// the group's *first* round (ring and recursive-doubling both start by
/// sending the own block set); artifact replicates all contributions to
/// every core.
fn allgather(
    schedule: &Schedule,
    g: &PhaseGroup,
    node: u32,
    svc: &XlaService,
    stores: &[Store],
) -> Result<()> {
    let cl = schedule.cluster;
    let n = cl.cores as usize;
    // Contribution of each core: blocks it holds at group start that the
    // group will disseminate = blocks it sends in the first round.
    let mut contrib: HashMap<u32, Vec<u64>> = HashMap::new();
    for t in &schedule.rounds[g.first_round as usize].transfers {
        if cl.node_of(t.src) == node {
            contrib
                .entry(cl.core_of(t.src))
                .or_default()
                .extend(t.blocks.iter());
        }
    }
    for v in contrib.values_mut() {
        v.sort_unstable();
        v.dedup();
    }
    if contrib.len() != n {
        return Err(anyhow!(
            "allgather group: {}/{n} cores contribute — unsupported shape",
            contrib.len()
        ));
    }
    let c = g.c_contrib.ok_or_else(|| anyhow!("non-uniform contributions"))? as usize;

    let mut x = vec![0i32; n * c];
    for (&j, blocks) in &contrib {
        let src_rank = cl.rank_of(node, j);
        let st = stores[src_rank as usize].lock().unwrap();
        let mut pos = j as usize * c;
        for b in blocks {
            let d = st.get(b).ok_or_else(|| anyhow!("core {j} missing block {b}"))?;
            x[pos..pos + d.len()].copy_from_slice(d);
            pos += d.len();
        }
    }

    let y = svc.run("node_allgather", cl.cores, c as u64, x)?;
    // y[i][j] = contribution of core j, delivered to every core i.
    for i in 0..n {
        let dst_rank = cl.rank_of(node, i as u32);
        let mut st = stores[dst_rank as usize].lock().unwrap();
        for (&j, blocks) in &contrib {
            let mut pos = (i * n + j as usize) * c;
            for b in blocks {
                let len = crate::exec::block_elems(&schedule.op.sizing(), *b) as usize;
                st.insert(*b, y[pos..pos + len].to_vec());
                pos += len;
            }
        }
    }
    Ok(())
}

//! Exec-backend integration tests: every algorithm really moves the
//! right bytes, and the XLA phase path agrees with the channel path.

use super::*;
use crate::algorithms::{alltoall, bcast, scatter};
use crate::topology::Cluster;

fn channels() -> ExecRuntime {
    ExecRuntime::channels()
}

fn xla_runtime() -> Option<ExecRuntime> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping XLA path: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(ExecRuntime::with_xla(XlaService::start(dir).unwrap()))
}

#[test]
fn bcast_all_algorithms_execute() {
    let cl = Cluster::new(4, 4, 2);
    for alg in [
        bcast::BcastAlg::KPorted { k: 2 },
        bcast::BcastAlg::KLane { k: 2, two_phase: false },
        bcast::BcastAlg::KLane { k: 2, two_phase: true },
        bcast::BcastAlg::FullLane,
        bcast::BcastAlg::Binomial,
        bcast::BcastAlg::ScatterAllgather,
    ] {
        let s = bcast::build(cl, 3, 64, alg);
        let rep = channels().run(&s, 2, 1).unwrap_or_else(|e| panic!("{}: {e}", s.algorithm));
        assert!(rep.blocks_verified > 0, "{}", s.algorithm);
    }
}

#[test]
fn scatter_all_algorithms_execute() {
    let cl = Cluster::new(4, 4, 2);
    for alg in [
        scatter::ScatterAlg::KPorted { k: 2 },
        scatter::ScatterAlg::KLane { k: 2 },
        scatter::ScatterAlg::FullLane,
        scatter::ScatterAlg::Binomial,
        scatter::ScatterAlg::Linear,
    ] {
        let s = scatter::build(cl, 5, 16, alg);
        let rep = channels().run(&s, 2, 1).unwrap_or_else(|e| panic!("{}: {e}", s.algorithm));
        assert_eq!(rep.blocks_verified, cl.p() as u64, "{}", s.algorithm);
    }
}

#[test]
fn alltoall_all_algorithms_execute() {
    let cl = Cluster::new(3, 4, 2);
    for alg in [
        alltoall::AlltoallAlg::KPorted { k: 3 },
        alltoall::AlltoallAlg::Bruck { k: 2 },
        alltoall::AlltoallAlg::KLane,
        alltoall::AlltoallAlg::FullLane,
        alltoall::AlltoallAlg::Pairwise,
    ] {
        let s = alltoall::build(cl, 8, alg);
        let rep = channels().run(&s, 2, 1).unwrap_or_else(|e| panic!("{}: {e}", s.algorithm));
        assert_eq!(rep.blocks_verified, (cl.p() as u64).pow(2), "{}", s.algorithm);
    }
}

#[test]
fn wallclock_is_positive_and_warmup_discarded() {
    let cl = Cluster::new(2, 2, 1);
    let s = bcast::build(cl, 0, 1024, bcast::BcastAlg::Binomial);
    let rep = channels().run(&s, 5, 2).unwrap();
    assert_eq!(rep.summary.reps, 5);
    assert!(rep.summary.min > 0.0);
    assert!(rep.summary.avg >= rep.summary.min);
}

#[test]
fn duplicate_messages_are_a_typed_preflight_error() {
    use crate::schedule::{BlockSet, Collective, Round, Schedule};
    // Two transfers sharing (src, dst) in one round would collide on a
    // mailbox key. This used to be a debug_assert — in release builds
    // the second receive waited forever. The preflight now rejects the
    // schedule with a typed ExecError before any worker thread spawns.
    let cl = Cluster::new(1, 2, 1);
    let mut s = Schedule::new(cl, Collective::Allgather { c: 2 }, "dup-test");
    let a = s.transfer(0, 1, BlockSet::single(0));
    let b = s.transfer(0, 1, BlockSet::single(0));
    s.push_round(Round::of(vec![a, b]));
    let err = channels().run(&s, 1, 0).unwrap_err();
    assert!(
        err.to_string().contains("duplicate message 0 -> 1 in round 0"),
        "unexpected error: {err}"
    );
    // The same shape is what `mlane lint` reports as a port-budget /
    // redundant-transfer finding; here we only pin the exec-layer guard.
    assert_eq!(
        ExecError::DuplicateMessage { src: 0, dst: 1, round: 0 }.to_string(),
        "duplicate message 0 -> 1 in round 0"
    );
}

#[test]
fn xla_phase_path_klane_alltoall() {
    // klane alltoall's final local phase is a pure-local Alltoall group;
    // with n = 4 cores and c = 16 the artifact exists.
    let Some(rt) = xla_runtime() else { return };
    let cl = Cluster::new(3, 4, 2);
    let s = alltoall::build(cl, 16, alltoall::AlltoallAlg::KLane);
    let rep = rt.run(&s, 2, 0).unwrap();
    assert!(rep.xla_phases > 0, "expected XLA phase execution");
    // correctness already asserted by internal verification
}

#[test]
fn xla_phase_path_fulllane_alltoall() {
    // fulllane phase 1 pairs carry N·c elements: N = 4 nodes, c = 4 →
    // c_eff = 16, artifact (n=4, c=16) exists.
    let Some(rt) = xla_runtime() else { return };
    let cl = Cluster::new(4, 4, 2);
    let s = alltoall::build(cl, 4, alltoall::AlltoallAlg::FullLane);
    let rep = rt.run(&s, 2, 0).unwrap();
    assert!(rep.xla_phases > 0);
}

#[test]
fn xla_phase_path_fulllane_bcast_allgather() {
    // fulllane bcast on 4 nodes × 4 cores with c = 64: segments of 16
    // elements; the final allgather group has c_contrib = 16.
    let Some(rt) = xla_runtime() else { return };
    let cl = Cluster::new(4, 4, 2);
    let s = bcast::build(cl, 0, 64, bcast::BcastAlg::FullLane);
    let rep = rt.run(&s, 2, 0).unwrap();
    assert!(rep.xla_phases > 0);
}

#[test]
fn xla_and_channel_paths_agree() {
    // Same schedule, both modes: identical verification outcome and
    // blocks — the cross-check that the XLA phase semantics are right.
    let Some(rt) = xla_runtime() else { return };
    let cl = Cluster::new(3, 4, 1);
    let s = alltoall::build(cl, 16, alltoall::AlltoallAlg::KLane);
    let a = channels().run(&s, 1, 0).unwrap();
    let b = rt.run(&s, 1, 0).unwrap();
    assert_eq!(a.blocks_verified, b.blocks_verified);
    assert_eq!(a.xla_phases, 0);
    assert!(b.xla_phases > 0);
}

#[test]
fn refuses_oversized_clusters() {
    let cl = Cluster::hydra(2);
    let s = bcast::build(cl, 0, 4, bcast::BcastAlg::Binomial);
    let err = channels().run(&s, 1, 0).unwrap_err();
    assert!(err.to_string().contains("refuses"), "{err}");
}

#[test]
fn single_rank_schedule() {
    let cl = Cluster::new(1, 1, 1);
    let s = bcast::build(cl, 0, 8, bcast::BcastAlg::Binomial);
    let rep = channels().run(&s, 1, 0).unwrap();
    assert_eq!(rep.blocks_verified, 1);
}

#[test]
fn gather_all_algorithms_execute() {
    use crate::algorithms::gather;
    let cl = Cluster::new(3, 4, 2);
    for alg in [
        gather::GatherAlg::KPorted { k: 2 },
        gather::GatherAlg::KLane { k: 2 },
        gather::GatherAlg::FullLane,
        gather::GatherAlg::Binomial,
        gather::GatherAlg::Linear,
    ] {
        let s = gather::build(cl, 5, 16, alg);
        let rep = channels().run(&s, 1, 0).unwrap_or_else(|e| panic!("{}: {e}", s.algorithm));
        // root needs all p blocks; everyone else keeps its own
        assert!(rep.blocks_verified >= cl.p() as u64, "{}", s.algorithm);
    }
}

#[test]
fn allgather_all_algorithms_execute() {
    use crate::algorithms::allgather;
    let cl = Cluster::new(2, 4, 2);
    for alg in [
        allgather::AllgatherAlg::Ring,
        allgather::AllgatherAlg::RecursiveDoubling,
        allgather::AllgatherAlg::Bruck { k: 2 },
        allgather::AllgatherAlg::FullLane,
    ] {
        let s = allgather::build(cl, 16, alg);
        let rep = channels().run(&s, 1, 0).unwrap_or_else(|e| panic!("{}: {e}", s.algorithm));
        assert_eq!(rep.blocks_verified, (cl.p() as u64) * cl.p() as u64, "{}", s.algorithm);
    }
}

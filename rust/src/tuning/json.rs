//! Strict, dependency-free JSON reader for persisted decision tables.
//!
//! The repo's JSON is hand-rolled in both directions (no serde
//! offline): `harness::report::JsonSink` and the tuning writers emit
//! it, and this parser reads it back. It is deliberately strict — the
//! whole document must parse, trailing bytes are an error, and numbers
//! must be valid `f64` text. Numbers keep their **raw text** so `u64`
//! values (counts, seeds) round-trip exactly instead of passing
//! through `f64` (which would corrupt anything above 2^53).

/// A parsed JSON value. Numbers carry the source text (see module doc).
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum Value {
    Null,
    Bool(bool),
    Num(String),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub(crate) fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(items) => items.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub(crate) fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(raw) => raw.parse().ok(),
            _ => None,
        }
    }

    pub(crate) fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(raw) => raw.parse().ok(),
            _ => None,
        }
    }

    pub(crate) fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub(crate) fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object entries in document order (`None` for non-objects) — the
    /// strict loaders use this to reject unknown and duplicate keys.
    pub(crate) fn entries(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse a complete document; trailing non-whitespace is an error.
pub(crate) fn parse(s: &str) -> Result<Value, String> {
    let mut p = Parser { s: s.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.s.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn ws(&mut self) {
        while self.peek().is_some_and(|b| b.is_ascii_whitespace()) {
            self.i += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.ws();
        match self.peek().ok_or("unexpected eof")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.quoted()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        while self.peek().is_some_and(|b| {
            b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E')
        }) {
            self.i += 1;
        }
        let raw = std::str::from_utf8(&self.s[start..self.i])
            .map_err(|_| "bad utf-8 in number".to_string())?;
        raw.parse::<f64>().map_err(|e| format!("bad number at byte {start}: {e}"))?;
        Ok(Value::Num(raw.to_string()))
    }

    fn quoted(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or("eof inside string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or("eof after escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .s
                                .get(self.i..self.i + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("truncated \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.i += 4;
                            out.push(char::from_u32(cp).ok_or("bad codepoint")?);
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                _ => {
                    let rest = std::str::from_utf8(&self.s[self.i..])
                        .map_err(|_| "bad utf-8 in string".to_string())?;
                    let ch = rest.chars().next().expect("non-empty");
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(items));
        }
        loop {
            self.ws();
            let key = self.quoted()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            items.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(items));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_documents() {
        let v = parse(r#"{"a":[1,2.5,"x\n"],"b":{"c":null,"d":true}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_u64(), Some(1));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_str(), Some("x\n"));
        assert_eq!(v.get("b").unwrap().get("c"), Some(&Value::Null));
        assert_eq!(v.get("b").unwrap().get("d"), Some(&Value::Bool(true)));
    }

    #[test]
    fn u64_precision_survives() {
        // 2^63 + 1 is not representable in f64; the raw-text numbers
        // must keep it exact.
        let v = parse("{\"seed\":9223372036854775809}").unwrap();
        assert_eq!(v.get("seed").unwrap().as_u64(), Some(9_223_372_036_854_775_809));
    }

    #[test]
    fn strictness_rejects_trailing_and_malformed() {
        assert!(parse("{} x").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("1.2.3").is_err());
    }
}

//! Per-size tuned algorithm selection (arXiv:cs/0408034, *Fast Tuning
//! of Intra-Cluster Collective Communications*).
//!
//! The paper's tables show that no single algorithm wins across message
//! sizes: the k-ported, k-lane and full-lane variants cross over as
//! counts grow. This module turns that observation into a persistent
//! product:
//!
//! * [`tune_scenario`] sweeps one (cluster, operation, persona) over a
//!   count grid through the shared [`SweepEngine`] (each candidate's
//!   schedule is built once and re-costed per count), computes the
//!   per-size winners via [`Collectives::autotune_counts`], and
//!   compresses them into a [`DecisionTable`] — sorted count
//!   breakpoints, each naming the fastest registry algorithm from that
//!   count up to the next breakpoint;
//! * [`TuningBook`] is a set of decision tables with hand-rolled JSON
//!   persistence ([`TuningBook::to_json`] / [`TuningBook::parse`], the
//!   `report::JsonSink` idiom — no external deps) — the `mlane tune`
//!   artifact;
//! * [`dispatch`] resolves (cluster, persona, op, count) to the winning
//!   algorithm: from an [`install`]ed book if one covers the scenario,
//!   otherwise from an auto-built table (default registry candidates ×
//!   the paper's count grid, cached process-wide). The registry's
//!   `tuned` meta-algorithm is a thin wrapper over this call.
//!
//! ## Breakpoint semantics
//!
//! `entries` are sorted by strictly-ascending `from` and deduplicated
//! (adjacent entries always name different algorithms). Entry *i*
//! applies to every count in `[from_i, from_{i+1})`; the last entry is
//! open-ended and counts below `entries[0].from` saturate to the first
//! entry, so [`DecisionTable::pick`] is total over the count domain.
//! Every `from` is one of the sampled grid counts — the winner at a
//! breakpoint is *exactly* the measured argmin there (the property
//! tests in `rust/tests/tuning_properties.rs` pin this); between
//! samples the table interpolates by holding the last winner.
//!
//! Determinism: winners are argmins of simulated averages under a fixed
//! [`TuneConfig`] (reps/warmup/seed), and the engine's recost path is
//! bitwise-identical to fresh builds, so the same scenario always
//! yields the same table — tables are reproducible artifacts, not
//! snapshots of a noisy run.

pub(crate) mod json;

use std::collections::HashMap;
use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use crate::algorithms::registry::{registry, Alg, AlgError, OpKind};
use crate::coordinator::Collectives;
use crate::harness::report::esc;
use crate::harness::{default_counts, shared_engine};
use crate::model::PersonaName;
use crate::netsim::{Backend, BackendKind, Scenario as NetScenario};
use crate::sim::{self, SweepEngine};
use crate::topology::Cluster;

use json::Value;

/// Default measured repetitions per tuning cell. Low on purpose: the
/// simulated averages separate algorithms well before the paper's 100
/// reps, and decision tables must stay cheap to (re)build.
pub const TUNE_REPS: usize = 5;
/// Default unmeasured warm-up repetitions per tuning cell.
pub const TUNE_WARMUP: usize = 1;

/// Measurement parameters a decision table is built under. Fixed
/// defaults (not `RunConfig`'s) so auto-built tables and `mlane tune`
/// artifacts agree byte-for-byte unless explicitly overridden.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TuneConfig {
    pub reps: usize,
    pub warmup: usize,
    pub seed: u64,
    /// Backend the winners were measured on. A tag, not a full
    /// scenario: tuning on the event backend always uses the
    /// contention-free scenario (a book tuned under one tenant load
    /// would silently mis-rank under another), so the tag alone pins
    /// the measurement semantics and `TuneConfig` stays `Copy + Eq`.
    pub backend: BackendKind,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            reps: TUNE_REPS,
            warmup: TUNE_WARMUP,
            seed: sim::DEFAULT_SEED,
            backend: BackendKind::Analytic,
        }
    }
}

impl TuneConfig {
    /// The full backend this config tunes on (event → contention-free).
    pub fn full_backend(&self) -> Backend {
        match self.backend {
            BackendKind::Analytic => Backend::Analytic,
            BackendKind::Event => Backend::Event(NetScenario::contention_free()),
        }
    }
}

/// Typed tuning errors — CLI-reachable paths must never panic.
#[derive(Clone, Debug, PartialEq)]
pub enum TuneError {
    /// A candidate sweep failed (carries the scenario and the registry
    /// error underneath).
    Alg { scenario: String, source: AlgError },
    /// After filtering to supporters of the operation, no candidate was
    /// left to tune over.
    NoCandidates { op: OpKind },
    /// The scenario's count grid was empty.
    EmptyCounts { scenario: String },
    /// A persisted book failed strict parsing or validation.
    Parse(String),
    /// A persisted book could not be read or written.
    Io(String),
    /// Two tables in one book cover the same (cluster, op, persona) —
    /// dispatch would silently depend on table order.
    DuplicateTable { label: String },
}

impl fmt::Display for TuneError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TuneError::Alg { scenario, source } => write!(f, "tuning {scenario}: {source}"),
            TuneError::NoCandidates { op } => write!(
                f,
                "no tuning candidates support {op} (registry supporters: {})",
                tunable_supporters(*op).join(", ")
            ),
            TuneError::EmptyCounts { scenario } => {
                write!(f, "tuning {scenario}: empty count grid")
            }
            TuneError::Parse(msg) => write!(f, "decision tables: {msg}"),
            TuneError::Io(msg) => write!(f, "decision tables: {msg}"),
            TuneError::DuplicateTable { label } => {
                write!(f, "decision tables: duplicate table for {label}")
            }
        }
    }
}

impl std::error::Error for TuneError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TuneError::Alg { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Registry families that can actually serve as tuning candidates for
/// `op` — `supporting(op)` minus `tuned` itself, which the candidate
/// filter always rejects (suggesting it in "supporters" help text would
/// send the user in a circle).
fn tunable_supporters(op: OpKind) -> Vec<&'static str> {
    registry().supporting(op).into_iter().filter(|n| *n != "tuned").collect()
}

impl TuneError {
    /// Map onto [`AlgError`] for the registry's `tuned` meta-algorithm
    /// (whose `build` contract is `Result<_, AlgError>`).
    fn into_alg_error(self, op: OpKind) -> AlgError {
        match self {
            TuneError::Alg { source, .. } => source,
            TuneError::NoCandidates { op } => AlgError::UnsupportedCombination {
                alg: "tuned".to_string(),
                op,
                supported: tunable_supporters(op),
            },
            // Unreachable from the auto path (fixed non-empty grids, no
            // parsing); surfaced as an unknown-algorithm error if a
            // future refactor ever routes one here.
            other => AlgError::UnknownAlgorithm {
                name: format!("tuned ({other} while tuning {op})"),
                known: registry().names(),
            },
        }
    }
}

/// One breakpoint: from this count (inclusive) up to the next entry,
/// dispatch to `(alg, k)`. `avg_us` records the winner's simulated
/// average at the grid count that opened the breakpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct Breakpoint {
    pub from: u64,
    /// Registry family name (`--alg` vocabulary).
    pub alg: String,
    /// Bound `k` (0 for unparameterized families).
    pub k: u32,
    pub avg_us: f64,
}

/// Per-size winners for one (cluster, operation, persona), compressed
/// to count breakpoints. See the module doc for breakpoint semantics.
#[derive(Clone, Debug, PartialEq)]
pub struct DecisionTable {
    pub cluster: Cluster,
    pub op: OpKind,
    pub persona: PersonaName,
    pub entries: Vec<Breakpoint>,
}

impl DecisionTable {
    /// Human-readable scenario id, used in errors and headings.
    pub fn label(&self) -> String {
        format!(
            "{} on {}x{} (lanes={}) [{}]",
            self.op,
            self.cluster.nodes,
            self.cluster.cores,
            self.cluster.lanes,
            self.persona.key()
        )
    }

    /// The breakpoint governing count `c` (total: counts below the
    /// first breakpoint saturate to it, the last is open-ended).
    ///
    /// Panics on an empty table; tables from `parse`/`tune_scenario`
    /// are never empty ([`DecisionTable::validate`] rejects them).
    /// Untrusted callers use [`DecisionTable::try_pick`].
    pub fn pick(&self, c: u64) -> &Breakpoint {
        self.try_pick(c).expect("decision table has no entries")
    }

    /// Total variant of [`DecisionTable::pick`]: `None` on an empty
    /// table instead of panicking.
    pub fn try_pick(&self, c: u64) -> Option<&Breakpoint> {
        let i = self.entries.partition_point(|b| b.from <= c);
        self.entries.get(i.saturating_sub(1))
    }

    /// Resolve the winning algorithm at count `c` against the registry.
    pub fn resolve(&self, c: u64) -> Result<Alg, AlgError> {
        let b = self.try_pick(c).ok_or_else(|| AlgError::Engine {
            detail: format!("decision table {} has no entries", self.label()),
        })?;
        // `validate`/`tune_scenario` exclude self-reference; builds
        // would recurse forever if one slipped through.
        if b.alg == "tuned" {
            return Err(AlgError::Engine {
                detail: format!("decision table {} dispatches back to `tuned`", self.label()),
            });
        }
        registry().resolve(&b.alg, b.k)
    }

    /// Structural invariants: non-empty, strictly-ascending `from`,
    /// adjacent entries name different algorithms, every entry resolves
    /// in the registry, and none dispatches back to `tuned`.
    pub fn validate(&self) -> Result<(), TuneError> {
        let at = self.label();
        if self.entries.is_empty() {
            return Err(TuneError::Parse(format!("{at}: no entries")));
        }
        for w in self.entries.windows(2) {
            if w[0].from >= w[1].from {
                return Err(TuneError::Parse(format!(
                    "{at}: breakpoints not strictly ascending ({} then {})",
                    w[0].from, w[1].from
                )));
            }
            if w[0].alg == w[1].alg && w[0].k == w[1].k {
                return Err(TuneError::Parse(format!(
                    "{at}: duplicate adjacent breakpoints at {} and {} ({})",
                    w[0].from, w[1].from, w[0].alg
                )));
            }
        }
        for b in &self.entries {
            if b.alg == "tuned" {
                return Err(TuneError::Parse(format!(
                    "{at}: a decision table may not dispatch to `tuned` itself"
                )));
            }
            if !b.avg_us.is_finite() {
                return Err(TuneError::Parse(format!(
                    "{at}: non-finite avg_us at from={}",
                    b.from
                )));
            }
            registry()
                .resolve(&b.alg, b.k)
                .map_err(|e| TuneError::Parse(format!("{at}: {e}")))?;
        }
        Ok(())
    }

    /// Compact single-line JSON object (the book's `tables` items).
    pub fn json(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\"op\":\"{}\",\"persona\":\"{}\",\"nodes\":{},\"cores\":{},\"lanes\":{},\"entries\":[",
            self.op.name(),
            self.persona.key(),
            self.cluster.nodes,
            self.cluster.cores,
            self.cluster.lanes,
        );
        for (i, b) in self.entries.iter().enumerate() {
            let _ = write!(
                out,
                "{}{{\"from\":{},\"alg\":\"{}\",\"k\":{},\"avg_us\":{}}}",
                if i == 0 { "" } else { "," },
                b.from,
                esc(&b.alg),
                b.k,
                b.avg_us,
            );
        }
        out.push_str("]}");
        out
    }

    /// Human-readable breakpoint listing (`mlane tune` default output).
    pub fn text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "decision table: {} on {}x{} (lanes={}) [{}]",
            self.op,
            self.cluster.nodes,
            self.cluster.cores,
            self.cluster.lanes,
            self.persona.label()
        );
        let _ = writeln!(out, "  {:>9} {:<10} {:>3} {:>12}", "from", "alg", "k", "avg(us)");
        for b in &self.entries {
            let k = if b.k == 0 { "-".to_string() } else { b.k.to_string() };
            let _ = writeln!(out, "  {:>9} {:<10} {:>3} {:>12.2}", b.from, b.alg, k, b.avg_us);
        }
        out
    }

    pub(crate) fn from_value(v: &Value) -> Result<DecisionTable, TuneError> {
        strict_obj(v, "table", &["op", "persona", "nodes", "cores", "lanes", "entries"])?;
        let op_name = str_field(v, "table", "op")?;
        let op = OpKind::parse(op_name)
            .ok_or_else(|| TuneError::Parse(format!("table: unknown op {op_name:?}")))?;
        let persona_key = str_field(v, "table", "persona")?;
        let persona = PersonaName::parse(persona_key)
            .ok_or_else(|| TuneError::Parse(format!("table: unknown persona {persona_key:?}")))?;
        let nodes = u32_field(v, "table", "nodes")?;
        let cores = u32_field(v, "table", "cores")?;
        let lanes = u32_field(v, "table", "lanes")?;
        if nodes == 0 || cores == 0 || lanes == 0 {
            return Err(TuneError::Parse("table: degenerate cluster dimensions".into()));
        }
        let entries_v = v
            .get("entries")
            .and_then(Value::as_arr)
            .ok_or_else(|| TuneError::Parse("table: entries must be an array".into()))?;
        let mut entries = Vec::with_capacity(entries_v.len());
        for e in entries_v {
            strict_obj(e, "entry", &["from", "alg", "k", "avg_us"])?;
            let from = e
                .get("from")
                .and_then(Value::as_u64)
                .ok_or_else(|| TuneError::Parse("entry: from must be a u64".into()))?;
            let alg = str_field(e, "entry", "alg")?.to_string();
            let k = u32_field(e, "entry", "k")?;
            let avg_us = e
                .get("avg_us")
                .and_then(Value::as_f64)
                .ok_or_else(|| TuneError::Parse("entry: avg_us must be a number".into()))?;
            entries.push(Breakpoint { from, alg, k, avg_us });
        }
        let table =
            DecisionTable { cluster: Cluster::new(nodes, cores, lanes), op, persona, entries };
        table.validate()?;
        Ok(table)
    }
}

// ---- strict-object field helpers --------------------------------------

/// Reject unknown and duplicate keys: both ends of the format are ours,
/// so any surprise key is a bug or a corrupted file, not extensibility.
fn strict_obj(v: &Value, what: &str, allowed: &[&str]) -> Result<(), TuneError> {
    let items = v
        .entries()
        .ok_or_else(|| TuneError::Parse(format!("{what}: expected an object")))?;
    for (i, (k, _)) in items.iter().enumerate() {
        if !allowed.contains(&k.as_str()) {
            return Err(TuneError::Parse(format!("{what}: unknown key {k:?}")));
        }
        if items[..i].iter().any(|(prev, _)| prev == k) {
            return Err(TuneError::Parse(format!("{what}: duplicate key {k:?}")));
        }
    }
    Ok(())
}

fn str_field<'v>(v: &'v Value, what: &str, key: &str) -> Result<&'v str, TuneError> {
    v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| TuneError::Parse(format!("{what}: {key} must be a string")))
}

fn u32_field(v: &Value, what: &str, key: &str) -> Result<u32, TuneError> {
    v.get(key)
        .and_then(Value::as_u64)
        .and_then(|n| u32::try_from(n).ok())
        .ok_or_else(|| TuneError::Parse(format!("{what}: {key} must be a u32")))
}

fn usize_field(v: &Value, what: &str, key: &str) -> Result<usize, TuneError> {
    v.get(key)
        .and_then(Value::as_u64)
        .and_then(|n| usize::try_from(n).ok())
        .ok_or_else(|| TuneError::Parse(format!("{what}: {key} must be a usize")))
}

// ---- the persisted book ------------------------------------------------

/// A set of decision tables plus the [`TuneConfig`] they were built
/// under — the `mlane tune` artifact. JSON is hand-rolled both ways
/// (`to_json`/`parse`, strict round-trip) with no dependencies.
#[derive(Clone, Debug, PartialEq)]
pub struct TuningBook {
    pub tune: TuneConfig,
    pub tables: Vec<DecisionTable>,
}

impl TuningBook {
    /// The table covering (cluster, op, persona), if the book has one.
    pub fn get(
        &self,
        cluster: Cluster,
        op: OpKind,
        persona: PersonaName,
    ) -> Option<&DecisionTable> {
        self.tables
            .iter()
            .find(|t| t.cluster == cluster && t.op == op && t.persona == persona)
    }

    /// Every table valid, and scenario keys unique (a duplicate would
    /// make [`TuningBook::get`] order-dependent).
    pub fn validate(&self) -> Result<(), TuneError> {
        for (i, t) in self.tables.iter().enumerate() {
            t.validate()?;
            if self.tables[..i]
                .iter()
                .any(|p| p.cluster == t.cluster && p.op == t.op && p.persona == t.persona)
            {
                return Err(TuneError::DuplicateTable { label: t.label() });
            }
        }
        Ok(())
    }

    /// The persisted format: one table object per line inside a
    /// `tables` array (the `JsonSink` layout idiom).
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\"version\":1,\"tune\":{{\"reps\":{},\"warmup\":{},\"seed\":{},\
             \"backend\":\"{}\"}},\"tables\":[",
            self.tune.reps,
            self.tune.warmup,
            self.tune.seed,
            self.tune.backend.key()
        );
        for (i, t) in self.tables.iter().enumerate() {
            out.push_str(if i == 0 { "\n" } else { ",\n" });
            out.push_str(&t.json());
        }
        out.push_str("\n]}\n");
        out
    }

    /// Strict parse + validation of the persisted format. Re-serializing
    /// the result is byte-identical to the input `to_json` produced
    /// (`rust/tests/tuning_roundtrip.rs` pins this).
    pub fn parse(s: &str) -> Result<TuningBook, TuneError> {
        let v = json::parse(s).map_err(TuneError::Parse)?;
        strict_obj(&v, "book", &["version", "tune", "tables"])?;
        let version = u32_field(&v, "book", "version")?;
        if version != 1 {
            return Err(TuneError::Parse(format!("unsupported version {version}")));
        }
        let tune_v = v
            .get("tune")
            .ok_or_else(|| TuneError::Parse("book: missing tune".into()))?;
        strict_obj(tune_v, "tune", &["reps", "warmup", "seed", "backend"])?;
        // Books written before the network backend carry no tag; absent
        // means analytic, so old artifacts keep parsing.
        let backend = match tune_v.get("backend") {
            None => BackendKind::Analytic,
            Some(b) => b.as_str().and_then(BackendKind::parse).ok_or_else(|| {
                TuneError::Parse("tune: backend must be \"analytic\" or \"event\"".into())
            })?,
        };
        let tune = TuneConfig {
            reps: usize_field(tune_v, "tune", "reps")?,
            warmup: usize_field(tune_v, "tune", "warmup")?,
            seed: tune_v
                .get("seed")
                .and_then(Value::as_u64)
                .ok_or_else(|| TuneError::Parse("tune: seed must be a u64".into()))?,
            backend,
        };
        let tables_v = v
            .get("tables")
            .and_then(Value::as_arr)
            .ok_or_else(|| TuneError::Parse("book: tables must be an array".into()))?;
        let tables = tables_v
            .iter()
            .map(DecisionTable::from_value)
            .collect::<Result<Vec<_>, _>>()?;
        let book = TuningBook { tune, tables };
        book.validate()?;
        Ok(book)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TuningBook, TuneError> {
        let path = path.as_ref();
        let s = std::fs::read_to_string(path)
            .map_err(|e| TuneError::Io(format!("read {}: {e}", path.display())))?;
        TuningBook::parse(&s)
    }

    /// All tables as breakpoint listings.
    pub fn text(&self) -> String {
        let mut out = String::new();
        for (i, t) in self.tables.iter().enumerate() {
            if i > 0 {
                out.push('\n');
            }
            out.push_str(&t.text());
        }
        out
    }
}

// ---- tuning sweeps -----------------------------------------------------

/// One tuning job: which (cluster, op, persona) to tune, over which
/// counts, among which candidates.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub cluster: Cluster,
    pub op: OpKind,
    pub persona: PersonaName,
    pub counts: Vec<u64>,
    pub candidates: Vec<Alg>,
}

impl Scenario {
    pub fn label(&self) -> String {
        format!(
            "{} on {}x{} (lanes={}) [{}]",
            self.op,
            self.cluster.nodes,
            self.cluster.cores,
            self.cluster.lanes,
            self.persona.key()
        )
    }

    /// The scenario for (cluster, op, persona) with the registry's
    /// default candidate set and the paper's count grid — what the
    /// `tuned` meta-algorithm auto-builds from.
    pub fn default_for(cluster: Cluster, op: OpKind, persona: PersonaName) -> Scenario {
        Scenario {
            cluster,
            op,
            persona,
            counts: default_counts(op).to_vec(),
            candidates: registry().candidates(cluster, op),
        }
    }
}

/// Sweep one scenario and compress the per-count winners into a
/// [`DecisionTable`]. Candidates that don't support the operation (and
/// `tuned` itself — it would recurse) are filtered out; an empty
/// remainder is a typed error, not a panic or an empty table.
///
/// The sweep rides the batched series path: `autotune_counts` makes one
/// `SweepEngine::measure_series` call per candidate, so tuning a
/// scenario costs one cache resolution per candidate rather than one
/// per (candidate, count) cell.
pub fn tune_scenario(
    engine: &Arc<SweepEngine>,
    sc: &Scenario,
    cfg: &TuneConfig,
) -> Result<DecisionTable, TuneError> {
    let cands: Vec<Alg> = sc
        .candidates
        .iter()
        .filter(|a| a.name() != "tuned" && a.supports(sc.op))
        .cloned()
        .collect();
    if cands.is_empty() {
        return Err(TuneError::NoCandidates { op: sc.op });
    }
    let mut counts = sc.counts.clone();
    counts.sort_unstable();
    counts.dedup();
    if counts.is_empty() {
        return Err(TuneError::EmptyCounts { scenario: sc.label() });
    }
    let mut coll = Collectives::with_engine(sc.cluster, sc.persona, engine.clone());
    coll.reps = cfg.reps;
    coll.warmup = cfg.warmup;
    coll.seed = cfg.seed;
    coll.backend = cfg.full_backend();
    let winners = coll
        .autotune_counts(sc.op.op(1), &counts, &cands)
        .map_err(|source| TuneError::Alg { scenario: sc.label(), source })?;
    let mut entries: Vec<Breakpoint> = Vec::new();
    for w in winners {
        let (alg, k) = (w.alg.name(), w.alg.k().unwrap_or(0));
        let same = entries.last().is_some_and(|last| last.alg == alg && last.k == k);
        if !same {
            entries.push(Breakpoint {
                from: w.c,
                alg: alg.to_string(),
                k,
                avg_us: w.measurement.summary.avg,
            });
        }
    }
    Ok(DecisionTable { cluster: sc.cluster, op: sc.op, persona: sc.persona, entries })
}

/// Tune every scenario (in parallel over `threads` workers — scenarios
/// are independent, so successful output is deterministic and ordered
/// like the input) into one [`TuningBook`]. On failure the first
/// recorded error (input order) is returned; remaining scenarios are
/// abandoned early (as in `run_plan`, *which* failure surfaces may vary
/// when several scenarios are broken, but whether the tune fails never
/// does).
pub fn tune_all(
    engine: &Arc<SweepEngine>,
    scenarios: &[Scenario],
    cfg: &TuneConfig,
    threads: usize,
) -> Result<TuningBook, TuneError> {
    let workers = threads.min(scenarios.len()).max(1);
    let mut slots: Vec<Option<Result<DecisionTable, TuneError>>> =
        scenarios.iter().map(|_| None).collect();
    if workers <= 1 {
        for (i, sc) in scenarios.iter().enumerate() {
            let r = tune_scenario(engine, sc, cfg);
            let is_err = r.is_err();
            slots[i] = Some(r);
            if is_err {
                break;
            }
        }
    } else {
        let next = AtomicUsize::new(0);
        // Mirror the serial early exit: once any scenario fails, workers
        // stop picking up new ones instead of sweeping the rest of a
        // (possibly Hydra-scale) grid just to discard it.
        let failed = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut done = Vec::new();
                        loop {
                            if failed.load(Ordering::Relaxed) {
                                break;
                            }
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= scenarios.len() {
                                break;
                            }
                            let r = tune_scenario(engine, &scenarios[i], cfg);
                            if r.is_err() {
                                failed.store(true, Ordering::Relaxed);
                            }
                            done.push((i, r));
                        }
                        done
                    })
                })
                .collect();
            for h in handles {
                for (i, r) in h.join().expect("tune worker panicked") {
                    slots[i] = Some(r);
                }
            }
        });
    }
    let mut tables = Vec::with_capacity(scenarios.len());
    for slot in slots {
        match slot {
            Some(Ok(t)) => tables.push(t),
            Some(Err(e)) => return Err(e),
            None => {} // serial early exit; the error already surfaced
        }
    }
    Ok(TuningBook { tune: *cfg, tables })
}

// ---- multi-process tune sharding --------------------------------------

/// The `kind` tag of a tune-shard artifact (see `harness::shard`:
/// `mlane merge` dispatches on it).
pub const TUNE_SHARD_KIND: &str = "tune-shard";

/// The shard a scenario belongs to: stable hash of its ordinal position
/// in the (deterministic) scenario list — the tuning mirror of
/// `Plan::shard`'s section assignment. No environment reads.
pub fn scenario_shard(index: usize, shards: u32) -> u32 {
    let hash = crate::harness::plan::fnv1a(format!("scenario:{index}").as_bytes());
    (hash % shards as u64) as u32
}

/// The global indices of the scenarios shard `index` owns, ascending.
/// Exhaustive and disjoint over `index ∈ 0..shards` by construction.
pub fn shard_scenarios(total: usize, shards: u32, index: u32) -> Vec<usize> {
    assert!(shards >= 1 && index < shards, "invalid shard coordinates");
    (0..total).filter(|&i| scenario_shard(i, shards) == index).collect()
}

/// Fingerprint binding the whole tuning job: every scenario's identity
/// (cluster/op/persona, count grid, candidate set) plus the
/// [`TuneConfig`] — merge-time proof that two artifacts shard the same
/// `mlane tune` invocation.
pub fn scenarios_fingerprint(scenarios: &[Scenario], cfg: &TuneConfig) -> u64 {
    use std::fmt::Write as _;
    let mut text = String::new();
    for sc in scenarios {
        let _ = write!(text, "{};counts=", sc.label());
        for c in &sc.counts {
            let _ = write!(text, "{c},");
        }
        text.push_str(";cands=");
        for a in &sc.candidates {
            let _ = write!(text, "{}:{},", a.name(), a.k().unwrap_or(0));
        }
        text.push('|');
    }
    let _ =
        write!(text, "tune={},{},{},{}", cfg.reps, cfg.warmup, cfg.seed, cfg.backend.key());
    crate::harness::plan::fnv1a(text.as_bytes())
}

/// Serialize one tune shard: the `book` produced by tuning the owned
/// scenarios (`indices`, ascending — `book.tables[i]` is scenario
/// `indices[i]`), self-described with the job fingerprint and shard
/// coordinates. `harness::shard::merge_dir` reassembles a directory of
/// these into the single-process [`TuningBook`], byte-identical through
/// [`TuningBook::to_json`].
pub fn tune_shard_json(
    scenarios: &[Scenario],
    cfg: &TuneConfig,
    shards: u32,
    index: u32,
    indices: &[usize],
    book: &TuningBook,
) -> String {
    use std::fmt::Write as _;
    assert_eq!(indices.len(), book.tables.len(), "one table per owned scenario");
    let idx: Vec<String> = indices.iter().map(|i| i.to_string()).collect();
    let mut out = format!(
        "{{\"version\":1,\"kind\":\"{TUNE_SHARD_KIND}\",\"fingerprint\":\"{:016x}\",\
         \"shards\":{shards},\"shard\":{index},\"scenario_count\":{},\"indices\":[{}],\
         \"tune\":{{\"reps\":{},\"warmup\":{},\"seed\":{},\"backend\":\"{}\"}},\"tables\":[",
        scenarios_fingerprint(scenarios, cfg),
        scenarios.len(),
        idx.join(","),
        cfg.reps,
        cfg.warmup,
        cfg.seed,
        cfg.backend.key(),
    );
    for (i, t) in book.tables.iter().enumerate() {
        out.push_str(if i == 0 { "\n" } else { ",\n" });
        out.push_str(&t.json());
    }
    let _ = write!(out, "{}]}}\n", if book.tables.is_empty() { "" } else { "\n" });
    out
}

// ---- dispatch (the `tuned` meta-algorithm's brain) ---------------------

/// The installed-book slot is an `RwLock` over an immutable `Arc`
/// snapshot: `install` builds and validates the whole book *before*
/// taking the brief write lock, so a concurrent [`dispatch`] either
/// sees the old snapshot or the new one — never a half-installed book.
/// Lock poisoning is recovered (`into_inner`): the slot only ever holds
/// a fully-swapped `Option<Arc>`, so a panicked peer cannot leave it
/// torn, and selection must keep serving.
fn installed_slot() -> &'static RwLock<Option<Arc<TuningBook>>> {
    static SLOT: OnceLock<RwLock<Option<Arc<TuningBook>>>> = OnceLock::new();
    SLOT.get_or_init(|| RwLock::new(None))
}

/// Install a book process-wide: [`dispatch`] consults it before falling
/// back to auto-built tables (`mlane run --table <file>` wires this).
pub fn install(book: TuningBook) -> Result<(), TuneError> {
    book.validate()?;
    let snapshot = Some(Arc::new(book));
    *installed_slot().write().unwrap_or_else(|e| e.into_inner()) = snapshot;
    Ok(())
}

/// The currently installed book, if any.
pub fn installed() -> Option<Arc<TuningBook>> {
    installed_slot().read().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Remove the installed book (test hygiene; auto tables take over).
pub fn clear_installed() {
    *installed_slot().write().unwrap_or_else(|e| e.into_inner()) = None;
}

type AutoKey = (Cluster, OpKind, PersonaName);

fn auto_cache() -> &'static Mutex<HashMap<AutoKey, Arc<DecisionTable>>> {
    static CACHE: OnceLock<Mutex<HashMap<AutoKey, Arc<DecisionTable>>>> = OnceLock::new();
    CACHE.get_or_init(Default::default)
}

/// The auto-built decision table for (cluster, persona, op): default
/// registry candidates over the paper's count grid under
/// [`TuneConfig::default`], computed once per process and cached.
/// Concurrent first calls may duplicate the sweep; results are
/// identical (deterministic tuning) and the first insert wins.
pub fn auto_table(
    cluster: Cluster,
    persona: PersonaName,
    op: OpKind,
) -> Result<Arc<DecisionTable>, AlgError> {
    let key = (cluster, op, persona);
    // Poison recovery mirrors `installed_slot`: the cache maps keys to
    // fully-constructed `Arc`s, so a panicked peer cannot leave a torn
    // entry behind.
    if let Some(t) = auto_cache()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .get(&key)
    {
        return Ok(t.clone());
    }
    // Compute outside the cache lock: a tuning sweep can be slow and
    // must not serialize unrelated (cluster, op, persona) lookups.
    let sc = Scenario::default_for(cluster, op, persona);
    let table = tune_scenario(&shared_engine(), &sc, &TuneConfig::default())
        .map_err(|e| e.into_alg_error(op))?;
    let arc = Arc::new(table);
    Ok(auto_cache()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .entry(key)
        .or_insert(arc)
        .clone())
}

/// Resolve (cluster, persona, op, count) to the winning algorithm: the
/// installed book's table if one covers the scenario, else the cached
/// auto-built table. This is the whole of the registry's `tuned`
/// meta-algorithm.
pub fn dispatch(
    cluster: Cluster,
    persona: PersonaName,
    op: OpKind,
    c: u64,
) -> Result<Alg, AlgError> {
    if let Some(book) = installed() {
        if let Some(t) = book.get(cluster, op, persona) {
            return t.resolve(c);
        }
    }
    auto_table(cluster, persona, op)?.resolve(c)
}

/// The whole decision table [`dispatch`] would consult for (cluster,
/// persona, op) — the installed book's table if one covers the
/// scenario, else the cached auto-built table. The symbolic certifier
/// reads the table's breakpoints to partition count space exactly
/// where `tuned` switches algorithms. A separate entry point (not a
/// refactor of [`dispatch`]) on purpose: the serve hot path calls
/// `dispatch` per query and must stay allocation-free, while this
/// clones installed tables into an `Arc` once per certification entry.
pub fn dispatch_table(
    cluster: Cluster,
    persona: PersonaName,
    op: OpKind,
) -> Result<Arc<DecisionTable>, AlgError> {
    if let Some(book) = installed() {
        if let Some(t) = book.get(cluster, op, persona) {
            return Ok(Arc::new(t.clone()));
        }
    }
    auto_table(cluster, persona, op)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cluster {
        Cluster::new(2, 4, 2)
    }

    fn fast() -> TuneConfig {
        TuneConfig { reps: 2, warmup: 0, seed: 7, ..TuneConfig::default() }
    }

    fn scenario(op: OpKind, counts: &[u64]) -> Scenario {
        Scenario {
            cluster: tiny(),
            op,
            persona: PersonaName::OpenMpi,
            counts: counts.to_vec(),
            candidates: registry().candidates(tiny(), op),
        }
    }

    #[test]
    fn tune_scenario_compresses_winners_into_breakpoints() {
        let eng = Arc::new(SweepEngine::new());
        let sc = scenario(OpKind::Bcast, &[1, 64, 6000, 600_000]);
        let t = tune_scenario(&eng, &sc, &fast()).unwrap();
        t.validate().unwrap();
        assert_eq!(t.entries[0].from, 1);
        assert!(t.entries.len() <= 4);
        // Every breakpoint opens at a sampled count.
        for b in &t.entries {
            assert!(sc.counts.contains(&b.from), "{}", b.from);
        }
    }

    #[test]
    fn tuning_is_deterministic() {
        let sc = scenario(OpKind::Alltoall, &[1, 9, 869]);
        let a = tune_scenario(&Arc::new(SweepEngine::new()), &sc, &fast()).unwrap();
        let b = tune_scenario(&Arc::new(SweepEngine::new()), &sc, &fast()).unwrap();
        assert_eq!(a, b);
        // And identical through a shared warm engine (recost path).
        let eng = Arc::new(SweepEngine::new());
        let c = tune_scenario(&eng, &sc, &fast()).unwrap();
        let d = tune_scenario(&eng, &sc, &fast()).unwrap();
        assert_eq!(c, d);
        assert_eq!(a, c);
    }

    #[test]
    fn pick_is_total_and_breakpoint_aligned() {
        let t = DecisionTable {
            cluster: tiny(),
            op: OpKind::Bcast,
            persona: PersonaName::OpenMpi,
            entries: vec![
                Breakpoint { from: 1, alg: "binomial".into(), k: 0, avg_us: 1.0 },
                Breakpoint { from: 600, alg: "klane".into(), k: 2, avg_us: 2.0 },
                Breakpoint { from: 60_000, alg: "fulllane".into(), k: 0, avg_us: 3.0 },
            ],
        };
        t.validate().unwrap();
        assert_eq!(t.pick(0).alg, "binomial"); // saturates below
        assert_eq!(t.pick(1).alg, "binomial");
        assert_eq!(t.pick(599).alg, "binomial");
        assert_eq!(t.pick(600).alg, "klane");
        assert_eq!(t.pick(59_999).alg, "klane");
        assert_eq!(t.pick(60_000).alg, "fulllane");
        assert_eq!(t.pick(u64::MAX).alg, "fulllane");
        assert_eq!(t.resolve(600).unwrap().label(), "2-lane");
    }

    #[test]
    fn validate_rejects_broken_tables() {
        let mk = |entries: Vec<Breakpoint>| DecisionTable {
            cluster: tiny(),
            op: OpKind::Bcast,
            persona: PersonaName::OpenMpi,
            entries,
        };
        let bp = |from: u64, alg: &str, k: u32| Breakpoint {
            from,
            alg: alg.into(),
            k,
            avg_us: 1.0,
        };
        assert!(mk(vec![]).validate().is_err(), "empty");
        assert!(
            mk(vec![bp(5, "fulllane", 0), bp(5, "binomial", 0)]).validate().is_err(),
            "not strictly ascending"
        );
        assert!(
            mk(vec![bp(1, "fulllane", 0), bp(9, "fulllane", 0)]).validate().is_err(),
            "adjacent duplicate"
        );
        assert!(mk(vec![bp(1, "tuned", 0)]).validate().is_err(), "self-reference");
        assert!(mk(vec![bp(1, "nosuch", 0)]).validate().is_err(), "unknown alg");
        assert!(mk(vec![bp(1, "klane", 0)]).validate().is_err(), "k=0 on parameterized");
    }

    #[test]
    fn empty_candidates_and_counts_are_typed_errors() {
        let eng = Arc::new(SweepEngine::new());
        let mut sc = scenario(OpKind::Bcast, &[1]);
        sc.candidates = vec![registry().resolve("ring", 0).unwrap()]; // no bcast
        let err = tune_scenario(&eng, &sc, &fast()).unwrap_err();
        assert!(matches!(err, TuneError::NoCandidates { op: OpKind::Bcast }), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("no tuning candidates support bcast"), "{msg}");
        // The supporter list must not send the user in a circle by
        // suggesting `tuned` itself.
        assert!(!msg.contains("tuned"), "{msg}");

        let sc = scenario(OpKind::Bcast, &[]);
        let err = tune_scenario(&eng, &sc, &fast()).unwrap_err();
        assert!(matches!(err, TuneError::EmptyCounts { .. }), "{err}");
    }

    #[test]
    fn book_json_round_trips_through_the_library_parser() {
        let eng = Arc::new(SweepEngine::new());
        let scs =
            [scenario(OpKind::Bcast, &[1, 64, 6000]), scenario(OpKind::Scatter, &[1, 16, 869])];
        let book = tune_all(&eng, &scs, &fast(), 2).unwrap();
        assert_eq!(book.tables.len(), 2);
        let json = book.to_json();
        let parsed = TuningBook::parse(&json).unwrap();
        assert_eq!(parsed, book);
        assert_eq!(parsed.to_json(), json, "re-serialization must be byte-identical");
    }

    #[test]
    fn parse_rejects_malformed_books() {
        let tune = "\"tune\":{\"reps\":1,\"warmup\":0,\"seed\":1}";
        let self_table = concat!(
            "{\"op\":\"bcast\",\"persona\":\"openmpi\",\"nodes\":2,\"cores\":4,",
            "\"lanes\":2,\"entries\":[{\"from\":1,\"alg\":\"tuned\",\"k\":0,",
            "\"avg_us\":1}]}"
        );
        for (what, s) in [
            ("version", format!("{{\"version\":2,{tune},\"tables\":[]}}")),
            ("unknown key", format!("{{\"version\":1,\"extra\":0,{tune},\"tables\":[]}}")),
            ("missing tune", "{\"version\":1,\"tables\":[]}".to_string()),
            ("trailing", format!("{{\"version\":1,{tune},\"tables\":[]}} x")),
            (
                "tuned self-dispatch",
                format!("{{\"version\":1,{tune},\"tables\":[\n{self_table}\n]}}"),
            ),
        ] {
            assert!(TuningBook::parse(&s).is_err(), "{what} should fail");
        }
    }

    #[test]
    fn duplicate_scenarios_rejected_at_book_level() {
        let eng = Arc::new(SweepEngine::new());
        let sc = scenario(OpKind::Bcast, &[1, 64]);
        let t = tune_scenario(&eng, &sc, &fast()).unwrap();
        let book = TuningBook { tune: fast(), tables: vec![t.clone(), t] };
        let err = book.validate().unwrap_err();
        assert!(matches!(err, TuneError::DuplicateTable { .. }), "{err:?}");
        assert!(err.to_string().contains("duplicate table"), "{err}");
        assert!(install(book).is_err());
    }

    #[test]
    fn empty_tables_resolve_to_typed_errors_not_panics() {
        let t = DecisionTable {
            cluster: tiny(),
            op: OpKind::Bcast,
            persona: PersonaName::OpenMpi,
            entries: vec![],
        };
        assert!(t.try_pick(0).is_none());
        assert!(t.try_pick(u64::MAX).is_none());
        let err = t.resolve(64).unwrap_err();
        assert!(err.to_string().contains("no entries"), "{err}");
        assert!(t.validate().is_err());
    }

    #[test]
    fn scenario_sharding_partitions_and_fingerprint_binds_the_job() {
        // Exhaustive + disjoint over every shard, like Plan::shard.
        for n in [1u32, 2, 3, 7] {
            let mut all: Vec<usize> =
                (0..n).flat_map(|i| shard_scenarios(5, n, i)).collect();
            all.sort_unstable();
            assert_eq!(all, (0..5).collect::<Vec<_>>(), "n={n}");
        }
        // The fingerprint is deterministic and sensitive to the job:
        // scenario set, counts and tune config all bind it.
        let scs =
            [scenario(OpKind::Bcast, &[1, 64]), scenario(OpKind::Scatter, &[1, 16])];
        let a = scenarios_fingerprint(&scs, &fast());
        assert_eq!(a, scenarios_fingerprint(&scs, &fast()));
        let mut slower = fast();
        slower.reps += 1;
        assert_ne!(a, scenarios_fingerprint(&scs, &slower), "config binds");
        assert_ne!(a, scenarios_fingerprint(&scs[..1], &fast()), "scenario set binds");
        let mut event = fast();
        event.backend = BackendKind::Event;
        assert_ne!(a, scenarios_fingerprint(&scs, &event), "backend binds");
    }

    #[test]
    fn event_backend_books_round_trip_and_old_artifacts_default_analytic() {
        let eng = Arc::new(SweepEngine::new());
        let mut cfg = fast();
        cfg.backend = BackendKind::Event;
        let scs = [scenario(OpKind::Bcast, &[1, 64])];
        let book = tune_all(&eng, &scs, &cfg, 1).unwrap();
        let json = book.to_json();
        assert!(json.contains("\"backend\":\"event\""), "{json}");
        let parsed = TuningBook::parse(&json).unwrap();
        assert_eq!(parsed, book);
        assert_eq!(parsed.to_json(), json);
        // Pre-backend artifacts (no tag) parse as analytic.
        let old = concat!(
            "{\"version\":1,\"tune\":{\"reps\":1,\"warmup\":0,\"seed\":1},",
            "\"tables\":[]}\n"
        );
        let parsed = TuningBook::parse(old).unwrap();
        assert_eq!(parsed.tune.backend, BackendKind::Analytic);
        // A bad tag is a parse error, not a silent analytic fallback.
        let bad = concat!(
            "{\"version\":1,\"tune\":{\"reps\":1,\"warmup\":0,\"seed\":1,",
            "\"backend\":\"quantum\"},\"tables\":[]}\n"
        );
        assert!(TuningBook::parse(bad).is_err());
    }

    #[test]
    fn tune_shard_artifact_is_self_describing() {
        let eng = Arc::new(SweepEngine::new());
        let scs = [scenario(OpKind::Bcast, &[1, 64]), scenario(OpKind::Scatter, &[1, 16])];
        let indices = shard_scenarios(scs.len(), 2, 0);
        let owned: Vec<Scenario> = indices.iter().map(|&i| scs[i].clone()).collect();
        let book = tune_all(&eng, &owned, &fast(), 1).unwrap();
        let artifact = tune_shard_json(&scs, &fast(), 2, 0, &indices, &book);
        assert!(artifact.starts_with("{\"version\":1,\"kind\":\"tune-shard\""), "{artifact}");
        assert!(artifact.contains("\"scenario_count\":2"), "{artifact}");
        assert!(artifact.contains("\"fingerprint\":\""), "{artifact}");
        // It parses with the strict in-library reader.
        json::parse(&artifact).expect("artifact is valid json");
    }

    #[test]
    fn dispatch_prefers_the_installed_book() {
        // An installed table that always says "binomial" must override
        // the auto table for its scenario — and only for its scenario.
        let cl = Cluster::new(3, 4, 2);
        let table = DecisionTable {
            cluster: cl,
            op: OpKind::Bcast,
            persona: PersonaName::Mpich,
            entries: vec![Breakpoint { from: 1, alg: "binomial".into(), k: 0, avg_us: 1.0 }],
        };
        install(TuningBook { tune: TuneConfig::default(), tables: vec![table] }).unwrap();
        let picked = dispatch(cl, PersonaName::Mpich, OpKind::Bcast, 1_000_000).unwrap();
        clear_installed();
        assert_eq!(picked.name(), "binomial");
        // Uncovered scenario falls through to the auto table.
        let auto = dispatch(cl, PersonaName::Mpich, OpKind::Scatter, 16).unwrap();
        assert_ne!(auto.name(), "tuned");
    }
}

//! API-compatible stand-in for the `xla` (PJRT) bindings.
//!
//! The build image ships no XLA/PJRT native libraries and no crates.io
//! access, so the real `xla` crate cannot be linked. This stub mirrors
//! the exact API surface `runtime::service_loop` uses; every entry point
//! returns an error, so the service thread fails each [`super::PhaseRequest`]
//! with a clear message and the exec backend falls back to channel
//! execution (callers already gate on `artifacts/manifest.txt`).
//!
//! Swapping the real bindings back in is a one-line change in
//! `runtime/mod.rs` (`use xla_stub as xla` → `use ::xla`).

use std::fmt;

const UNAVAILABLE: &str =
    "XLA/PJRT backend unavailable: mlane was built against the offline stub \
     (no PJRT native libraries in this environment)";

/// Error type matching the real crate's `xla::Error` display usage.
pub struct XlaError(String);

impl XlaError {
    fn unavailable() -> XlaError {
        XlaError(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

pub type Result<T> = std::result::Result<T, XlaError>;

pub struct PjRtClient;

impl PjRtClient {
    /// The real crate constructs a CPU PJRT client; the stub always fails,
    /// which makes `service_loop` answer every request with the error.
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError::unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[i32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(XlaError::unavailable())
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(XlaError::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable())
    }
}

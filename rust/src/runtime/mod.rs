//! XLA runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `python/compile/aot.py`) and executes node-local phases on the
//! PJRT CPU client. Python never runs on this path.
//!
//! The `xla` crate's types wrap raw pointers and are not `Send`, so all
//! PJRT state lives on one dedicated service thread ([`XlaService`]);
//! exec-runtime node leaders submit [`PhaseRequest`]s over a channel and
//! block on the reply. One compiled executable per (phase, n, c) triple,
//! compiled lazily and cached.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;

use anyhow::{anyhow, bail, Context, Result};

mod xla_stub;
// The build image has no PJRT native libraries; the stub mirrors the
// `xla` crate's API and fails each request at runtime (see its docs).
use xla_stub as xla;

/// Node-phase artifact key: (phase name, node width n, per-block count c).
pub type PhaseKey = (String, u32, u64);

/// Parsed `artifacts/manifest.txt` (written by aot.py):
/// `name \t n \t c \t dtype \t shapes \t file` per line.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: HashMap<PhaseKey, PathBuf>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let mut entries = HashMap::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 6 {
                bail!("malformed manifest line: {line:?}");
            }
            let key = (f[0].to_string(), f[1].parse()?, f[2].parse()?);
            entries.insert(key, dir.join(f[5]));
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn has(&self, name: &str, n: u32, c: u64) -> bool {
        self.entries.contains_key(&(name.to_string(), n, c))
    }
}

/// A request to run one node phase. Input/output are flat i32 buffers;
/// shapes are implied by the phase:
/// * `node_alltoall` / `shuffle_step`: in (n·n·c) → out (n·n·c)
/// * `node_allgather`: in (n·c) → out (n·n·c)
/// * `node_scatter`: in (n·c) → out (n·c) (reshape)
/// * `node_bcast`: in (c) → out (n·c)
/// * `checksum`: in (n·c) → out (1)
pub struct PhaseRequest {
    pub name: &'static str,
    pub n: u32,
    pub c: u64,
    pub input: Vec<i32>,
    pub reply: mpsc::Sender<Result<Vec<i32>>>,
}

/// Handle to the XLA service thread.
#[derive(Clone)]
pub struct XlaService {
    tx: mpsc::Sender<PhaseRequest>,
}

impl XlaService {
    /// Spawn the service thread over the given artifacts directory.
    /// Fails fast if the manifest is unreadable.
    pub fn start(artifacts_dir: &Path) -> Result<XlaService> {
        let manifest = Manifest::load(artifacts_dir)?;
        let (tx, rx) = mpsc::channel::<PhaseRequest>();
        std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || service_loop(manifest, rx))
            .context("spawning xla service thread")?;
        Ok(XlaService { tx })
    }

    /// Execute a phase synchronously (blocks until the service replies).
    pub fn run(&self, name: &'static str, n: u32, c: u64, input: Vec<i32>) -> Result<Vec<i32>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(PhaseRequest { name, n, c, input, reply: rtx })
            .map_err(|_| anyhow!("xla service thread is gone"))?;
        rrx.recv().map_err(|_| anyhow!("xla service dropped the reply"))?
    }
}

fn input_dims(name: &str, n: u32, c: u64) -> Vec<i64> {
    let (n, c) = (n as i64, c as i64);
    match name {
        "node_alltoall" | "shuffle_step" => vec![n, n, c],
        "node_allgather" => vec![n, c],
        "node_scatter" | "checksum" => vec![n * c],
        "node_bcast" => vec![c],
        other => panic!("unknown phase {other}"),
    }
}

fn service_loop(manifest: Manifest, rx: mpsc::Receiver<PhaseRequest>) {
    // All !Send XLA state is constructed and lives here.
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the construction error.
            while let Ok(req) = rx.recv() {
                let _ = req.reply.send(Err(anyhow!("PJRT client init failed: {e}")));
            }
            return;
        }
    };
    let mut cache: HashMap<PhaseKey, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        let result = run_phase(&manifest, &client, &mut cache, &req);
        let _ = req.reply.send(result);
    }
}

fn run_phase(
    manifest: &Manifest,
    client: &xla::PjRtClient,
    cache: &mut HashMap<PhaseKey, xla::PjRtLoadedExecutable>,
    req: &PhaseRequest,
) -> Result<Vec<i32>> {
    let key: PhaseKey = (req.name.to_string(), req.n, req.c);
    if !cache.contains_key(&key) {
        let path = manifest
            .entries
            .get(&key)
            .ok_or_else(|| anyhow!("no artifact for {key:?} — regenerate with aot.py"))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compiling {key:?}: {e}"))?;
        cache.insert(key.clone(), exe);
    }
    let exe = cache.get(&key).unwrap();

    let dims = input_dims(req.name, req.n, req.c);
    let want: i64 = dims.iter().product();
    if req.input.len() as i64 != want {
        bail!("{}: input len {} != {:?}", req.name, req.input.len(), dims);
    }
    let lit = xla::Literal::vec1(&req.input)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e}"))?;
    let mut out = exe
        .execute::<xla::Literal>(&[lit])
        .map_err(|e| anyhow!("execute {}: {e}", req.name))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch: {e}"))?;
    // aot.py lowers with return_tuple=True; `shuffle_step` returns a
    // 2-tuple (packed, checksum) — concatenate outputs flat.
    let tuple = out.decompose_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
    let mut flat = Vec::new();
    for t in tuple {
        flat.extend(t.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e}"))?);
    }
    Ok(flat)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        // tests run from the crate root
        PathBuf::from("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts().join("manifest.txt").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&artifacts()).unwrap();
        assert!(m.has("node_alltoall", 4, 256), "{:?}", m.entries.keys().take(4).collect::<Vec<_>>());
        assert!(m.has("node_bcast", 8, 1024));
    }

    #[test]
    fn alltoall_phase_is_block_transpose() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let svc = XlaService::start(&artifacts()).unwrap();
        let (n, c) = (4u32, 16u64);
        let len = (n as usize).pow(2) * c as usize;
        let input: Vec<i32> = (0..len as i32).collect();
        let out = svc.run("node_alltoall", n, c, input.clone()).unwrap();
        assert_eq!(out.len(), len);
        let cc = c as usize;
        for i in 0..n as usize {
            for j in 0..n as usize {
                for e in 0..cc {
                    assert_eq!(
                        out[(i * n as usize + j) * cc + e],
                        input[(j * n as usize + i) * cc + e],
                        "y[{i}][{j}][{e}]"
                    );
                }
            }
        }
    }

    #[test]
    fn bcast_phase_replicates() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let svc = XlaService::start(&artifacts()).unwrap();
        let (n, c) = (8u32, 256u64);
        let input: Vec<i32> = (0..c as i32).collect();
        let out = svc.run("node_bcast", n, c, input.clone()).unwrap();
        assert_eq!(out.len(), n as usize * c as usize);
        for i in 0..n as usize {
            assert_eq!(&out[i * c as usize..(i + 1) * c as usize], &input[..]);
        }
    }

    #[test]
    fn checksum_phase_sums() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let svc = XlaService::start(&artifacts()).unwrap();
        let (n, c) = (4u32, 16u64);
        let input: Vec<i32> = vec![3; (n as u64 * c) as usize];
        let out = svc.run("checksum", n, c, input).unwrap();
        assert_eq!(out, vec![3 * (n as i32) * c as i32]);
    }

    #[test]
    fn unknown_phase_shape_errors() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let svc = XlaService::start(&artifacts()).unwrap();
        let err = svc.run("node_alltoall", 3, 7, vec![0; 63]).unwrap_err();
        assert!(err.to_string().contains("no artifact"), "{err}");
    }
}

//! `mlane` — k-ported vs. k-lane collective algorithms.
// The one unsafe block in the crate is the counting global allocator
// (`util::allocs`), which carries a scoped allow + SAFETY comment.
#![deny(unsafe_code)]
// Library code never prints: output goes through `harness::report`
// sinks (the CLI binary and benches print, and are separate crates).
#![deny(clippy::print_stdout)]
pub mod topology;
pub mod schedule;
pub mod analysis;
pub mod algorithms;
pub mod model;
pub mod sim;
pub mod netsim;
pub mod exec;
pub mod runtime;
pub mod coordinator;
pub mod harness;
pub mod tuning;
pub mod serve;
pub mod util;

//! `mlane` — k-ported vs. k-lane collective algorithms.
pub mod topology;
pub mod schedule;
pub mod algorithms;
pub mod model;
pub mod sim;
pub mod exec;
pub mod runtime;
pub mod coordinator;
pub mod harness;
pub mod tuning;
pub mod util;

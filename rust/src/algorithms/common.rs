//! Shared communication-tree machinery used by the algorithm builders.
//!
//! Everything here works over an abstract index space `0..m` (global
//! ranks, a node's cores, or node ids); builders map indices to ranks.

/// An edge emitted by a tree generator: in `round`, `src` sends to `dst`,
/// and `dst` becomes responsible for the index range `[lo, hi)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Edge {
    pub round: usize,
    pub src: u32,
    pub dst: u32,
    pub lo: u32,
    pub hi: u32,
}

/// k-ported divide-and-conquer tree (paper §2.1).
///
/// All indices start in range `[0, m)` with the given `root`. Each round
/// every active root divides its range into `k+1` near-equal subranges
/// (sizes differ by ≤ 1) and sends to a new local root (the first index)
/// of every subrange not containing it. Rounds are globally aligned:
/// depth-d splits all land in round d. Terminates when ranges are
/// singletons; total rounds = ⌈log_{k+1} m⌉.
pub fn dnc_tree(m: u32, root: u32, k: u32) -> Vec<Edge> {
    assert!(m >= 1 && root < m && k >= 1);
    let mut edges = Vec::new();
    // (lo, hi, root, round)
    let mut stack = vec![(0u32, m, root, 0usize)];
    while let Some((lo, hi, r, round)) = stack.pop() {
        let len = hi - lo;
        if len <= 1 {
            continue;
        }
        let parts = (k + 1).min(len);
        // Near-equal split: first `extra` parts get base+1.
        let base = len / parts;
        let extra = len % parts;
        let mut s = lo;
        for i in 0..parts {
            let sz = base + u32::from(i < extra);
            let (plo, phi) = (s, s + sz);
            s = phi;
            if (plo..phi).contains(&r) {
                stack.push((plo, phi, r, round + 1));
            } else {
                let nr = plo; // paper: "r_i could be chosen as s_i"
                edges.push(Edge { round, src: r, dst: nr, lo: plo, hi: phi });
                stack.push((plo, phi, nr, round + 1));
            }
        }
    }
    edges.sort_by_key(|e| (e.round, e.src, e.dst));
    edges
}

/// Binomial tree (the k = 1 divide-and-conquer specialisation used by the
/// native baselines and node-local phases), over indices `0..m` rooted at
/// `root`. Uses the classic virtual-rank formulation: in round t, vranks
/// `< 2^t` send to `vrank + 2^t`. ⌈log2 m⌉ rounds. The edge's `[lo, hi)`
/// is the *virtual* rank range `dst` becomes responsible for (map back
/// with [`unvrank`]).
pub fn binomial_tree(m: u32, root: u32) -> Vec<Edge> {
    assert!(m >= 1 && root < m);
    let mut edges = Vec::new();
    let mut t = 0usize;
    let mut reach = 1u32;
    while reach < m {
        for v in 0..reach.min(m) {
            let w = v + reach;
            if w < m {
                let src = (v + root) % m;
                let dst = (w + root) % m;
                // dst becomes responsible for vranks [w, min(w + reach, m))
                edges.push(Edge { round: t, src, dst, lo: w, hi: (w + reach).min(m) });
            }
        }
        reach <<= 1;
        t += 1;
    }
    edges
}

/// Map a virtual rank (relative to `root`) back to a real index.
pub fn unvrank(v: u32, root: u32, m: u32) -> u32 {
    (v + root) % m
}

/// Binomial *scatter* tree in virtual-rank space (root = vrank 0):
/// recursive halving, so a holder only ever forwards subranges it has
/// already received — unlike [`binomial_tree`], which is a broadcast
/// ordering. Edge (round, src, dst, lo, hi): src hands vranks [lo, hi)
/// to dst = lo. ⌈log2 m⌉ rounds; each vrank ≥ 1 receives exactly once.
pub fn binomial_scatter_tree(m: u32) -> Vec<Edge> {
    assert!(m >= 1);
    let mut edges = Vec::new();
    // (lo, hi, round): holder is vrank `lo`, responsible for [lo, hi).
    let mut stack = vec![(0u32, m, 0usize)];
    while let Some((lo, hi, round)) = stack.pop() {
        let len = hi - lo;
        if len <= 1 {
            continue;
        }
        let mid = lo + len.div_ceil(2);
        edges.push(Edge { round, src: lo, dst: mid, lo: mid, hi });
        stack.push((lo, mid, round + 1));
        stack.push((mid, hi, round + 1));
    }
    edges.sort_by_key(|e| (e.round, e.src));
    edges
}

/// Ring-allgather pairing: in round r (0-based, of m-1), index i sends to
/// (i+1) mod m the block that originated at (i - r) mod m.
pub fn ring_allgather_origin(i: u32, r: u32, m: u32) -> u32 {
    (i + m - r % m) % m
}

/// Recursive-doubling allgather grouping (m must be a power of two):
/// in round d, index i exchanges with i XOR 2^d all blocks of its
/// 2^d-aligned group. Returns the group [lo, hi) whose blocks i holds
/// *before* round d.
pub fn rd_group(i: u32, d: u32) -> (u32, u32) {
    let w = 1u32 << d;
    let lo = i & !(w - 1);
    (lo, lo + w)
}

/// Pairwise/rotation alltoall pairing: in round r (1..m), index i sends
/// to (i + r) mod m and receives from (i - r) mod m. Works for any m.
pub fn rotation_peer(i: u32, r: u32, m: u32) -> (u32, u32) {
    ((i + r) % m, (i + m - r % m) % m)
}

pub fn is_pow2(m: u32) -> bool {
    m != 0 && m & (m - 1) == 0
}

/// ⌈log_{b} m⌉ for b ≥ 2.
pub fn ceil_log(m: u32, b: u32) -> u32 {
    assert!(b >= 2 && m >= 1);
    let mut rounds = 0;
    let mut reach = 1u64;
    while reach < m as u64 {
        reach *= b as u64;
        rounds += 1;
    }
    rounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn covered(m: u32, root: u32, edges: &[Edge]) -> bool {
        let mut have: HashSet<u32> = HashSet::from([root]);
        let max_round = edges.iter().map(|e| e.round).max().unwrap_or(0);
        for round in 0..=max_round {
            let this: Vec<_> = edges.iter().filter(|e| e.round == round).collect();
            for e in &this {
                assert!(have.contains(&e.src), "round {round}: src {} has no data", e.src);
            }
            for e in this {
                have.insert(e.dst);
            }
        }
        (0..m).all(|i| have.contains(&i))
    }

    #[test]
    fn dnc_covers_all_roots() {
        for m in [1u32, 2, 3, 7, 8, 13, 36, 100] {
            for k in [1u32, 2, 3, 5] {
                for root in [0, m / 2, m - 1] {
                    let edges = dnc_tree(m, root, k);
                    assert!(covered(m, root, &edges), "m={m} k={k} root={root}");
                    assert_eq!(edges.len() as u32, m - 1, "each index receives once");
                }
            }
        }
    }

    #[test]
    fn dnc_vrank_binomial_equivalence_note() {
        // binomial is NOT dnc with k=1 (different subtree labelling), but
        // both must cover with the same round count.
        let (m, root) = (32, 9);
        let d = dnc_tree(m, root, 1);
        let b = binomial_tree(m, root);
        let dr = d.iter().map(|e| e.round).max().unwrap();
        let br = b.iter().map(|e| e.round).max().unwrap();
        assert_eq!(dr, br);
    }

    #[test]
    fn dnc_round_count() {
        // ⌈log_{k+1} p⌉ rounds (paper §2.1)
        for (m, k, want) in
            [(8u32, 1u32, 3u32), (9, 2, 2), (36, 2, 4), (1152, 1, 11), (1152, 5, 4)]
        {
            let edges = dnc_tree(m, 0, k);
            let rounds = edges.iter().map(|e| e.round).max().unwrap() as u32 + 1;
            assert_eq!(rounds, want, "m={m} k={k}");
            assert_eq!(ceil_log(m, k + 1), want);
        }
    }

    #[test]
    fn dnc_port_limit_k() {
        let k = 3;
        let edges = dnc_tree(50, 7, k);
        let max_round = edges.iter().map(|e| e.round).max().unwrap();
        for round in 0..=max_round {
            let mut sends = std::collections::HashMap::new();
            for e in edges.iter().filter(|e| e.round == round) {
                *sends.entry(e.src).or_insert(0u32) += 1;
            }
            assert!(sends.values().all(|&s| s <= k));
        }
    }

    #[test]
    fn dnc_ranges_partition() {
        let edges = dnc_tree(10, 3, 2);
        // each non-root index appears as dst exactly once
        let mut seen = HashSet::new();
        for e in &edges {
            assert!(seen.insert(e.dst), "dst {} twice", e.dst);
            assert!(e.lo <= e.dst && e.dst < e.hi);
        }
        assert!(!seen.contains(&3));
        assert_eq!(seen.len(), 9);
    }

    #[test]
    fn binomial_covers_and_rounds() {
        for m in [1u32, 2, 5, 8, 32, 33] {
            for root in [0, m - 1] {
                let edges = binomial_tree(m, root);
                assert!(covered(m, root, &edges), "m={m} root={root}");
                if m > 1 {
                    let rounds = edges.iter().map(|e| e.round).max().unwrap() as u32 + 1;
                    assert_eq!(rounds, ceil_log(m, 2));
                }
            }
        }
    }

    #[test]
    fn binomial_port_limit_one() {
        let edges = binomial_tree(32, 5);
        let max_round = edges.iter().map(|e| e.round).max().unwrap();
        for round in 0..=max_round {
            let mut src_seen = HashSet::new();
            let mut dst_seen = HashSet::new();
            for e in edges.iter().filter(|e| e.round == round) {
                assert!(src_seen.insert(e.src));
                assert!(dst_seen.insert(e.dst));
            }
        }
    }

    #[test]
    fn scatter_tree_causal_and_complete() {
        for m in [1u32, 2, 3, 7, 8, 13, 32, 36, 100] {
            let edges = binomial_scatter_tree(m);
            assert_eq!(edges.len() as u32, m - 1.min(m));
            // causality: src must hold [lo, hi) when it sends, i.e. its
            // responsibility range still covers the sent range.
            let mut resp: Vec<(u32, u32)> = vec![(0, m); m as usize];
            for i in 1..m {
                resp[i as usize] = (i, i); // nothing yet
            }
            let max_round = edges.iter().map(|e| e.round).max().unwrap_or(0);
            for round in 0..=max_round {
                for e in edges.iter().filter(|e| e.round == round) {
                    let (rlo, rhi) = resp[e.src as usize];
                    assert!(rlo <= e.lo && e.hi <= rhi, "m={m} {e:?} resp=({rlo},{rhi})");
                }
                for e in edges.iter().filter(|e| e.round == round) {
                    resp[e.dst as usize] = (e.lo, e.hi);
                    resp[e.src as usize].1 = e.lo; // src keeps [rlo, e.lo)
                }
            }
            // completeness: every vrank ends responsible exactly for itself
            for v in 0..m {
                assert_eq!(resp[v as usize], (v, v + 1), "m={m} v={v}");
            }
            if m > 1 {
                assert_eq!(max_round as u32 + 1, ceil_log(m, 2), "m={m}");
            }
        }
    }

    #[test]
    fn ring_allgather_delivers_all() {
        let m = 5u32;
        // holder -> set of origins held
        let mut held: Vec<HashSet<u32>> = (0..m).map(|i| HashSet::from([i])).collect();
        for r in 0..m - 1 {
            let moves: Vec<(u32, u32, u32)> = (0..m)
                .map(|i| (i, (i + 1) % m, ring_allgather_origin(i, r, m)))
                .collect();
            for (src, dst, origin) in moves {
                assert!(
                    held[src as usize].contains(&origin),
                    "r={r} src={src} origin={origin}"
                );
                held[dst as usize].insert(origin);
            }
        }
        for i in 0..m {
            assert_eq!(held[i as usize].len(), m as usize);
        }
    }

    #[test]
    fn rd_group_growth() {
        assert_eq!(rd_group(5, 0), (5, 6));
        assert_eq!(rd_group(5, 1), (4, 6));
        assert_eq!(rd_group(5, 2), (4, 8));
    }

    #[test]
    fn rotation_peer_inverse() {
        let m = 7;
        for r in 1..m {
            for i in 0..m {
                let (to, _from) = rotation_peer(i, r, m);
                let (_to2, from2) = rotation_peer(to, r, m);
                assert_eq!(from2, i);
            }
        }
    }

    #[test]
    fn ceil_log_values() {
        assert_eq!(ceil_log(1, 2), 0);
        assert_eq!(ceil_log(2, 2), 1);
        assert_eq!(ceil_log(1152, 2), 11);
        assert_eq!(ceil_log(1152, 3), 7);
        assert_eq!(ceil_log(36, 7), 2);
    }
}

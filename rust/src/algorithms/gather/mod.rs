//! Gather algorithms, obtained by *schedule reversal* from scatter.
//!
//! The paper (§2): "The gather operation is the dual of the scatter
//! operation, and not treated further here." We treat it anyway, via the
//! classic duality: reversing a scatter schedule — rounds in reverse
//! order, every transfer's direction flipped — yields a valid gather
//! schedule with identical round count, port usage and traffic. The
//! reversal is generic ([`reverse_scatter`]), so every scatter algorithm
//! (k-ported §2.1, adapted k-lane §2.3, full-lane §2.2, binomial,
//! linear) comes with its gather dual for free.

use crate::algorithms::scatter::{self, ScatterAlg};
use crate::schedule::{Collective, Round, Schedule};
use crate::topology::{Cluster, Rank};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GatherAlg {
    KPorted { k: u32 },
    KLane { k: u32 },
    FullLane,
    Binomial,
    Linear,
}

impl GatherAlg {
    pub fn name(&self) -> &'static str {
        match self {
            GatherAlg::KPorted { .. } => "gather/k-ported",
            GatherAlg::KLane { .. } => "gather/k-lane",
            GatherAlg::FullLane => "gather/full-lane",
            GatherAlg::Binomial => "gather/binomial",
            GatherAlg::Linear => "gather/linear",
        }
    }

    fn dual(&self) -> ScatterAlg {
        match *self {
            GatherAlg::KPorted { k } => ScatterAlg::KPorted { k },
            GatherAlg::KLane { k } => ScatterAlg::KLane { k },
            GatherAlg::FullLane => ScatterAlg::FullLane,
            GatherAlg::Binomial => ScatterAlg::Binomial,
            GatherAlg::Linear => ScatterAlg::Linear,
        }
    }
}

pub fn build(cl: Cluster, root: Rank, c: u64, alg: GatherAlg) -> Schedule {
    let s = scatter::build(cl, root, c, alg.dual());
    reverse_scatter(s, alg.name())
}

/// Reverse a scatter schedule into its gather dual.
///
/// Correctness: in the scatter, a transfer in round r moves blocks B
/// from `src` to `dst`, and after round r the blocks' holder chain leads
/// to their destinations. Reversed and flipped, block `b`'s path is
/// walked backwards: rank `b` holds it initially (gather layout), each
/// flipped transfer hands it to the scatter-sender, and the last flipped
/// transfer (the scatter's first) delivers it to the root. Round
/// alignment is preserved, so port legality carries over.
pub fn reverse_scatter(mut s: Schedule, name: &'static str) -> Schedule {
    let (root, c) = match s.op {
        Collective::Scatter { root, c } => (root, c),
        other => panic!("reverse_scatter on {other:?}"),
    };
    s.op = Collective::Gather { root, c };
    s.algorithm = name;
    s.rounds.reverse();
    for round in &mut s.rounds {
        for t in &mut round.transfers {
            std::mem::swap(&mut t.src, &mut t.dst);
        }
    }
    // Node-phase hints: a reversed Scatter phase is a Gather-style fan-in
    // the exec XLA path has no artifact for — drop the hints.
    for round in &mut s.rounds {
        round.node_phase = None;
    }
    s
}

/// Reverse any gather schedule's rounds again to recover the scatter
/// (used by tests to pin the duality as an involution).
pub fn reverse_gather(mut s: Schedule, name: &'static str) -> Schedule {
    let (root, c) = match s.op {
        Collective::Gather { root, c } => (root, c),
        other => panic!("reverse_gather on {other:?}"),
    };
    s.op = Collective::Scatter { root, c };
    s.algorithm = name;
    s.rounds.reverse();
    for round in &mut s.rounds {
        for t in &mut round.transfers {
            std::mem::swap(&mut t.src, &mut t.dst);
        }
    }
    s
}

/// A Round helper for tests.
pub fn round_of(transfers: Vec<crate::schedule::Transfer>) -> Round {
    Round::of(transfers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::{validate, validate_ports};

    fn check(cl: Cluster, root: Rank, alg: GatherAlg, ports: u32) {
        let s = build(cl, root, 8, alg);
        validate(&s).unwrap_or_else(|v| panic!("{} invalid: {v}", s.algorithm));
        validate_ports(&s, ports).unwrap_or_else(|v| panic!("{} ports: {v}", s.algorithm));
    }

    #[test]
    fn all_duals_valid() {
        for (nodes, cores, lanes) in [(2, 3, 2), (4, 4, 2), (3, 5, 3), (1, 6, 2)] {
            let cl = Cluster::new(nodes, cores, lanes);
            for root in [0, cl.p() - 1, cl.p() / 2] {
                check(cl, root, GatherAlg::Binomial, 1);
                check(cl, root, GatherAlg::Linear, 1);
                check(cl, root, GatherAlg::FullLane, 1);
                for k in 1..=lanes {
                    check(cl, root, GatherAlg::KPorted { k }, k);
                    check(cl, root, GatherAlg::KLane { k }, 1);
                }
            }
        }
    }

    #[test]
    fn duality_preserves_structure() {
        let cl = Cluster::new(4, 4, 2);
        let sc = scatter::build(cl, 3, 16, ScatterAlg::KPorted { k: 2 });
        let ga = build(cl, 3, 16, GatherAlg::KPorted { k: 2 });
        assert_eq!(sc.rounds.len(), ga.rounds.len());
        assert_eq!(sc.num_transfers(), ga.num_transfers());
        assert_eq!(sc.offnode_bytes(), ga.offnode_bytes());
    }

    #[test]
    fn reversal_is_involution() {
        let cl = Cluster::new(3, 4, 2);
        let sc = scatter::build(cl, 5, 8, ScatterAlg::Binomial);
        let ga = reverse_scatter(sc.clone(), "gather/binomial");
        let back = reverse_gather(ga, "scatter/binomial");
        assert_eq!(back.rounds.len(), sc.rounds.len());
        for (a, b) in back.rounds.iter().zip(&sc.rounds) {
            assert_eq!(a.transfers.len(), b.transfers.len());
            for (x, y) in a.transfers.iter().zip(&b.transfers) {
                assert_eq!((x.src, x.dst), (y.src, y.dst));
                assert_eq!(x.blocks, y.blocks);
            }
        }
    }

    #[test]
    fn root_receives_exactly_total() {
        // gather dual of the message-size-optimal scatter: (p-1)·c
        // elements arrive at the root.
        let cl = Cluster::new(2, 4, 2);
        let c = 8u64;
        let s = build(cl, 0, c, GatherAlg::KPorted { k: 2 });
        let ingress: u64 = s
            .rounds
            .iter()
            .flat_map(|r| &r.transfers)
            .filter(|t| t.dst == 0)
            .map(|t| t.bytes)
            .sum();
        assert_eq!(ingress, (cl.p() as u64 - 1) * c * 4);
    }
}

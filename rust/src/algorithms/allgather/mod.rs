//! Allgather algorithms. Block `j` (c elements) originates at rank `j`;
//! every rank must end holding all p blocks.
//!
//! Allgather is the completion phase of the full-lane broadcast (§2.2)
//! and a first-class collective in the multi-lane family ([Träff 2019;
//! Träff & Hunold 2020]); the paper's §2.4 questions about shared-memory
//! sustained bandwidth apply to it directly.
//!
//! * [`AllgatherAlg::Ring`] — p-1 rounds, bandwidth-optimal; the shape
//!   used inside the full-lane broadcast on non-power-of-two nodes.
//! * [`AllgatherAlg::RecursiveDoubling`] — log2 p rounds (p a power of
//!   two; falls back to ring otherwise).
//! * [`AllgatherAlg::Bruck`] — the k-ported dissemination algorithm:
//!   ⌈log_{k+1} p⌉ rounds for any p; round d sends all held blocks to
//!   the k peers at distance e·(k+1)^d.
//! * [`AllgatherAlg::FullLane`] — problem splitting (§2.2): n concurrent
//!   inter-node allgathers (one per core class), then a node-local
//!   allgather of the collected class columns.

use crate::algorithms::common::*;
use crate::schedule::{BlockSet, Collective, LocalOpKind, Schedule};
use crate::topology::Cluster;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllgatherAlg {
    Ring,
    RecursiveDoubling,
    Bruck { k: u32 },
    FullLane,
}

impl AllgatherAlg {
    pub fn name(&self) -> &'static str {
        match self {
            AllgatherAlg::Ring => "allgather/ring",
            AllgatherAlg::RecursiveDoubling => "allgather/recursive-doubling",
            AllgatherAlg::Bruck { .. } => "allgather/bruck",
            AllgatherAlg::FullLane => "allgather/full-lane",
        }
    }
}

pub fn build(cl: Cluster, c: u64, alg: AllgatherAlg) -> Schedule {
    match alg {
        AllgatherAlg::Ring => ring(cl, c),
        AllgatherAlg::RecursiveDoubling => {
            if is_pow2(cl.p()) {
                recursive_doubling(cl, c)
            } else {
                ring(cl, c)
            }
        }
        AllgatherAlg::Bruck { k } => bruck(cl, c, k),
        AllgatherAlg::FullLane => fulllane(cl, c),
    }
}

/// Ring allgather: p-1 rounds; in round r rank i forwards the block that
/// originated at (i - r) mod p to (i + 1) mod p. Bandwidth-optimal.
pub fn ring(cl: Cluster, c: u64) -> Schedule {
    let p = cl.p();
    let mut s = Schedule::new(cl, Collective::Allgather { c }, AllgatherAlg::Ring.name());
    for r in 0..p.saturating_sub(1) {
        for i in 0..p {
            let origin = ring_allgather_origin(i, r, p) as u64;
            s.add_at(r as usize, i, (i + 1) % p, BlockSet::single(origin));
        }
    }
    s.finalize();
    s
}

/// Recursive doubling: log2 p exchange rounds (p must be a power of two).
pub fn recursive_doubling(cl: Cluster, c: u64) -> Schedule {
    let p = cl.p();
    assert!(is_pow2(p), "recursive doubling needs p = 2^m");
    let mut s = Schedule::new(
        cl,
        Collective::Allgather { c },
        AllgatherAlg::RecursiveDoubling.name(),
    );
    for d in 0..ceil_log(p, 2) {
        for i in 0..p {
            let peer = i ^ (1 << d);
            let (lo, hi) = rd_group(i, d);
            s.add_at(d as usize, i, peer, BlockSet::range(lo as u64, hi as u64));
        }
    }
    s.finalize();
    s
}

/// Bruck/dissemination allgather at radix k+1: round d (weight
/// w = (k+1)^d) sends, for e = 1..k, all currently-held blocks to rank
/// i - e·w (blocks travel "down" so rank i accumulates origins
/// i, i+1, …). ⌈log_{k+1} p⌉ rounds for any p, k sends per round.
pub fn bruck(cl: Cluster, c: u64, k: u32) -> Schedule {
    let p = cl.p();
    let pu = p as u64;
    let mut s =
        Schedule::new(cl, Collective::Allgather { c }, AllgatherAlg::Bruck { k }.name());
    // After processing weights < w, rank i holds origins {i .. i+have-1}
    // (mod p) where have = min(w, p) … standard dissemination invariant
    // at radix k+1: have grows ×(k+1) per round.
    let mut have = 1u64;
    let mut round = 0usize;
    while have < pu {
        for e in 1..=k as u64 {
            let step = e * have;
            if step >= pu {
                break;
            }
            // send current window, clipped so the receiver's window stays
            // contiguous and ≤ p blocks total
            let send = have.min(pu - step);
            for i in 0..p {
                let dst = ((i as u64 + pu - step) % pu) as u32;
                // origins i .. i+send-1 (mod p) — ≤ 2 runs
                let start = i as u64;
                let blocks = if start + send <= pu {
                    BlockSet::range(start, start + send)
                } else {
                    BlockSet::range(start, pu).union(BlockSet::range(0, start + send - pu))
                };
                s.add_at(round, i, dst, blocks);
            }
        }
        have = (have * (k as u64 + 1)).min(pu);
        round += 1;
    }
    s.finalize();
    s
}

/// §2.2 full-lane allgather: per core class u, an inter-node ring
/// allgather collects {block of (B, u) : all B} at every node; a final
/// node-local allgather spreads the n class columns to all cores.
pub fn fulllane(cl: Cluster, c: u64) -> Schedule {
    let n = cl.cores;
    let nn = cl.nodes;
    let mut s =
        Schedule::new(cl, Collective::Allgather { c }, AllgatherAlg::FullLane.name());
    // Phase 1 — n concurrent inter-node ring allgathers (class u moves
    // blocks {B·n + u}).
    let p1 = nn.saturating_sub(1) as usize;
    for r in 0..nn.saturating_sub(1) {
        for u in 0..n {
            for a in 0..nn {
                let origin_node = ring_allgather_origin(a, r, nn);
                let block = (origin_node * n + u) as u64;
                s.add_at(
                    r as usize,
                    cl.rank_of(a, u),
                    cl.rank_of((a + 1) % nn, u),
                    BlockSet::single(block),
                );
            }
        }
    }
    // Phase 2 — node-local allgather of class columns: core u of node A
    // holds blocks {B·n + u : all B}; a local ring spreads all columns.
    for r in 0..n.saturating_sub(1) {
        for a in 0..nn {
            for u in 0..n {
                let origin_core = ring_allgather_origin(u, r, n);
                let blocks = BlockSet::strided(origin_core as u64, n as u64, nn as u64);
                let t = s.transfer(
                    cl.rank_of(a, u),
                    cl.rank_of(a, (u + 1) % n),
                    blocks,
                );
                let rd = s.round_mut(p1 + r as usize);
                rd.transfers.push(t);
                rd.node_phase = Some(LocalOpKind::Allgather);
            }
        }
    }
    s.finalize();
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::{validate, validate_ports};

    fn check(cl: Cluster, alg: AllgatherAlg, ports: u32) {
        let s = build(cl, 8, alg);
        validate(&s).unwrap_or_else(|v| panic!("{} invalid: {v}", s.algorithm));
        validate_ports(&s, ports).unwrap_or_else(|v| panic!("{} ports: {v}", s.algorithm));
    }

    #[test]
    fn ring_valid() {
        for (nodes, cores) in [(1, 1), (2, 3), (4, 4), (3, 5)] {
            check(Cluster::new(nodes, cores, 2), AllgatherAlg::Ring, 1);
        }
    }

    #[test]
    fn recursive_doubling_valid_pow2() {
        for (nodes, cores) in [(2, 2), (4, 4), (2, 8)] {
            check(Cluster::new(nodes, cores, 2), AllgatherAlg::RecursiveDoubling, 1);
        }
        // non-power-of-two silently falls back to ring
        let s = build(Cluster::new(3, 3, 1), 8, AllgatherAlg::RecursiveDoubling);
        assert_eq!(s.algorithm, "allgather/ring");
    }

    #[test]
    fn rd_round_count() {
        let s = recursive_doubling(Cluster::new(4, 4, 2), 8);
        assert_eq!(s.rounds.len(), 4); // log2(16)
    }

    #[test]
    fn bruck_valid_various_k() {
        for (nodes, cores) in [(2, 3), (4, 4), (3, 5), (1, 7)] {
            let cl = Cluster::new(nodes, cores, 2);
            for k in 1..=4 {
                check(cl, AllgatherAlg::Bruck { k }, k);
            }
        }
    }

    #[test]
    fn bruck_round_count() {
        let cl = Cluster::new(2, 8, 2); // p = 16
        for (k, want) in [(1u32, 4u32), (2, 3), (3, 2), (15, 1)] {
            let s = bruck(cl, 4, k);
            assert_eq!(s.rounds.len() as u32, want, "k={k}");
        }
    }

    #[test]
    fn fulllane_valid() {
        for (nodes, cores) in [(2, 2), (4, 4), (3, 5), (2, 7)] {
            check(Cluster::new(nodes, cores, 2), AllgatherAlg::FullLane, 1);
        }
    }

    #[test]
    fn fulllane_round_count() {
        // (N-1) inter-node + (n-1) local ring rounds
        let s = fulllane(Cluster::new(4, 3, 2), 8);
        assert_eq!(s.rounds.len(), 3 + 2);
    }

    #[test]
    fn ring_is_bandwidth_optimal() {
        // every rank sends exactly (p-1)·c elements
        let cl = Cluster::new(2, 3, 1);
        let c = 8u64;
        let s = ring(cl, c);
        for i in 0..cl.p() {
            let sent: u64 = s
                .rounds
                .iter()
                .flat_map(|r| &r.transfers)
                .filter(|t| t.src == i)
                .map(|t| t.bytes)
                .sum();
            assert_eq!(sent, (cl.p() as u64 - 1) * c * 4);
        }
    }
}

//! Broadcast algorithms (paper §2.1–2.3).
//!
//! * [`BcastAlg::KPorted`] — divide-and-conquer over all p ranks, each
//!   root sending the full payload to k new subroots per round (§2.1).
//! * [`BcastAlg::KLane`] — the adapted k-lane algorithm (§2.3): the
//!   k-ported pattern over *nodes*, with k on-node cores jointly playing
//!   the k ports; a node-local broadcast distributes the payload on
//!   arrival. `two_phase = false` is the paper's implementation (full
//!   node broadcast on receive); `two_phase = true` is the theoretical
//!   variant (k-way broadcast on receive + final k × n/k-way broadcast).
//! * [`BcastAlg::FullLane`] — the problem-splitting algorithm of §2.2
//!   ([Träff 2019; Träff & Hunold 2020]): root-node scatter, n concurrent
//!   inter-node broadcasts, node-local allgather.
//! * [`BcastAlg::Binomial`] / [`BcastAlg::ScatterAllgather`] — the
//!   native-library baselines (small-/large-count `MPI_Bcast`).

use crate::algorithms::common::*;
use crate::schedule::{BlockSet, Collective, LocalOpKind, Schedule};
use crate::topology::{Cluster, Rank};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BcastAlg {
    KPorted { k: u32 },
    KLane { k: u32, two_phase: bool },
    FullLane,
    Binomial,
    ScatterAllgather,
}

impl BcastAlg {
    pub fn name(&self) -> &'static str {
        match self {
            BcastAlg::KPorted { .. } => "bcast/k-ported",
            BcastAlg::KLane { two_phase: false, .. } => "bcast/k-lane",
            BcastAlg::KLane { two_phase: true, .. } => "bcast/k-lane-2phase",
            BcastAlg::FullLane => "bcast/full-lane",
            BcastAlg::Binomial => "bcast/binomial",
            BcastAlg::ScatterAllgather => "bcast/scatter-allgather",
        }
    }
}

/// Build the broadcast schedule: `root` broadcasts `c` elements.
pub fn build(cl: Cluster, root: Rank, c: u64, alg: BcastAlg) -> Schedule {
    match alg {
        BcastAlg::KPorted { k } => kported(cl, root, c, k),
        BcastAlg::KLane { k, two_phase } => klane(cl, root, c, k, two_phase),
        BcastAlg::FullLane => fulllane(cl, root, c),
        BcastAlg::Binomial => binomial(cl, root, c),
        BcastAlg::ScatterAllgather => scatter_allgather(cl, root, c),
    }
}

fn payload() -> BlockSet {
    BlockSet::single(0)
}

/// §2.1 k-ported divide-and-conquer broadcast: ⌈log_{k+1} p⌉ rounds,
/// c elements per send.
pub fn kported(cl: Cluster, root: Rank, c: u64, k: u32) -> Schedule {
    let mut s = Schedule::new(
        cl,
        Collective::Bcast { root, c, segments: 1 },
        BcastAlg::KPorted { k }.name(),
    );
    for e in dnc_tree(cl.p(), root, k) {
        s.add_at(e.round, e.src, e.dst, payload());
    }
    s.finalize();
    s
}

/// Native baseline: binomial tree over all p ranks.
pub fn binomial(cl: Cluster, root: Rank, c: u64) -> Schedule {
    let mut s = Schedule::new(
        cl,
        Collective::Bcast { root, c, segments: 1 },
        BcastAlg::Binomial.name(),
    );
    for e in binomial_tree(cl.p(), root) {
        s.add_at(e.round, e.src, e.dst, payload());
    }
    s.finalize();
    s
}

/// Native large-count baseline (van de Geijn): binomial scatter of p
/// segments followed by a ring allgather over all p ranks.
pub fn scatter_allgather(cl: Cluster, root: Rank, c: u64) -> Schedule {
    let p = cl.p();
    let mut s = Schedule::new(
        cl,
        Collective::Bcast { root, c, segments: p },
        BcastAlg::ScatterAllgather.name(),
    );
    // Scatter phase: segment v is destined to vrank v (real rank
    // unvrank(v)); scatter-tree edges carry vrank ranges = segment ranges.
    let scatter_rounds = ceil_log(p, 2) as usize;
    for e in binomial_scatter_tree(p) {
        s.add_at(
            e.round,
            unvrank(e.src, root, p),
            unvrank(e.dst, root, p),
            BlockSet::range(e.lo as u64, e.hi as u64),
        );
    }
    // Allgather phase (ring in vrank space): p-1 rounds.
    for r in 0..p - 1 {
        for v in 0..p {
            let origin = ring_allgather_origin(v, r, p) as u64;
            let src = unvrank(v, root, p);
            let dst = unvrank((v + 1) % p, root, p);
            s.add_at(scatter_rounds + r as usize, src, dst, BlockSet::single(origin));
        }
    }
    s.finalize();
    s
}

/// §2.3 adapted k-lane broadcast.
pub fn klane(cl: Cluster, root: Rank, c: u64, k: u32, two_phase: bool) -> Schedule {
    assert!(k <= cl.cores, "k-lane bcast needs k <= n");
    let mut s = Schedule::new(
        cl,
        Collective::Bcast { root, c, segments: 1 },
        BcastAlg::KLane { k, two_phase }.name(),
    );
    let n = cl.cores;
    let root_node = cl.node_of(root);

    // Node-local broadcast from `entry` core; returns first round after it.
    // In the full (paper-implementation) variant this reaches all n cores;
    // in the two-phase variant only the k lane cores 0..k.
    let local_bcast = |s: &mut Schedule, node: u32, entry: u32, at: usize| -> usize {
        let width = if two_phase { k } else { n };
        // Broadcast over the core set {entry} ∪ {0..width} — when entry is
        // outside 0..width, route through a tree over width+1 slots.
        let cores: Vec<u32> = if entry < width {
            (0..width).collect()
        } else {
            std::iter::once(entry).chain(0..width).collect()
        };
        let rootpos = cores.iter().position(|&x| x == entry).unwrap() as u32;
        let m = cores.len() as u32;
        if m <= 1 {
            return at;
        }
        let mut last = at;
        for e in binomial_tree(m, rootpos) {
            let round = at + e.round;
            let src = cl.rank_of(node, cores[e.src as usize]);
            let dst = cl.rank_of(node, cores[e.dst as usize]);
            let t = s.transfer(src, dst, payload());
            let r = s.round_mut(round);
            r.transfers.push(t);
            r.node_phase = Some(LocalOpKind::Bcast);
            last = last.max(round + 1);
        }
        last
    };

    // Final two-phase fan-out: lane core i broadcasts to cores {j >= k :
    // j % k == i}; all groups concurrent. Returns rounds used (max depth).
    let final_fanout = |s: &mut Schedule, node: u32, at: usize| -> usize {
        let mut last = at;
        for i in 0..k {
            let group: Vec<u32> =
                std::iter::once(i).chain((k..n).filter(|j| j % k == i)).collect();
            let m = group.len() as u32;
            if m <= 1 {
                continue;
            }
            for e in binomial_tree(m, 0) {
                let round = at + e.round;
                let src = cl.rank_of(node, group[e.src as usize]);
                let dst = cl.rank_of(node, group[e.dst as usize]);
                let t = s.transfer(src, dst, payload());
                let r = s.round_mut(round);
                r.transfers.push(t);
                r.node_phase = Some(LocalOpKind::Bcast);
                last = last.max(round + 1);
            }
        }
        last
    };

    // Recursive node-level divide and conquer. `ready` = first round in
    // which the node's lane cores may send. Tracks the last network round
    // per node so the two-phase fan-out can be appended afterwards.
    let mut net_done: Vec<usize> = vec![0; cl.nodes as usize];
    // Explicit stack: (node_lo, node_hi, root_node, ready_round)
    let entry_ready = local_bcast(&mut s, root_node, cl.core_of(root), 0);
    let mut stack = vec![(0u32, cl.nodes, root_node, entry_ready)];
    while let Some((lo, hi, rn, ready)) = stack.pop() {
        net_done[rn as usize] = net_done[rn as usize].max(ready);
        let len = hi - lo;
        if len <= 1 {
            continue;
        }
        let parts = (k + 1).min(len);
        let base = len / parts;
        let extra = len % parts;
        let mut start = lo;
        let mut lane = 0u32;
        for i in 0..parts {
            let sz = base + u32::from(i < extra);
            let (plo, phi) = (start, start + sz);
            start = phi;
            if (plo..phi).contains(&rn) {
                stack.push((plo, phi, rn, ready + 1));
            } else {
                let sub = plo;
                // lane core `lane` of rn sends the payload to core 0 of sub
                let src_core = if two_phase || lane < k { lane } else { lane % k };
                s.add_at(ready, cl.rank_of(rn, src_core), cl.rank_of(sub, 0), payload());
                net_done[rn as usize] = net_done[rn as usize].max(ready + 1);
                let sub_ready = local_bcast(&mut s, sub, 0, ready + 1);
                stack.push((plo, phi, sub, sub_ready));
                lane += 1;
            }
        }
    }
    if two_phase {
        let max_round = s.rounds.len();
        for node in 0..cl.nodes {
            final_fanout(&mut s, node, max_round.max(net_done[node as usize]));
        }
    }
    s.finalize();
    s
}

/// §2.2 full-lane broadcast: root-node scatter into n blocks of c/n,
/// n concurrent inter-node binomial broadcasts (one per core class),
/// node-local allgather (recursive doubling when n is a power of two,
/// ring otherwise).
pub fn fulllane(cl: Cluster, root: Rank, c: u64) -> Schedule {
    let n = cl.cores;
    let nn = cl.nodes;
    let mut s = Schedule::new(
        cl,
        Collective::Bcast { root, c, segments: n },
        BcastAlg::FullLane.name(),
    );
    let root_node = cl.node_of(root);
    let root_core = cl.core_of(root);

    // Phase 1 — root-node scatter: segment v goes to core unvrank(v).
    let p1 = ceil_log(n, 2) as usize;
    for e in binomial_scatter_tree(n) {
        let t = s.transfer(
            cl.rank_of(root_node, unvrank(e.src, root_core, n)),
            cl.rank_of(root_node, unvrank(e.dst, root_core, n)),
            BlockSet::range(e.lo as u64, e.hi as u64),
        );
        let r = s.round_mut(e.round);
        r.transfers.push(t);
        r.node_phase = Some(LocalOpKind::Scatter);
    }

    // Phase 2 — per core class u: binomial broadcast of segment
    // v = (u - root_core) mod n over the N nodes.
    let p2 = p1 + ceil_log(nn, 2) as usize;
    for u in 0..n {
        let v = (u + n - root_core) % n;
        for e in binomial_tree(nn, root_node) {
            s.add_at(
                p1 + e.round,
                cl.rank_of(e.src, u),
                cl.rank_of(e.dst, u),
                BlockSet::single(v as u64),
            );
        }
    }

    // Phase 3 — node-local allgather of the n segments.
    if is_pow2(n) {
        for d in 0..ceil_log(n, 2) {
            for node in 0..nn {
                for vc in 0..n {
                    let peer = vc ^ (1 << d);
                    let (glo, ghi) = rd_group(vc, d);
                    // vcore vc holds segments of its group; send to peer.
                    let blocks = BlockSet::range(glo as u64, ghi as u64);
                    let src = cl.rank_of(node, unvrank(vc, root_core, n));
                    let dst = cl.rank_of(node, unvrank(peer, root_core, n));
                    let t = s.transfer(src, dst, blocks);
                    let r = s.round_mut(p2 + d as usize);
                    r.transfers.push(t);
                    r.node_phase = Some(LocalOpKind::Allgather);
                }
            }
        }
    } else {
        for r in 0..n - 1 {
            for node in 0..nn {
                for vc in 0..n {
                    let origin = ring_allgather_origin(vc, r, n) as u64;
                    let src = cl.rank_of(node, unvrank(vc, root_core, n));
                    let dst = cl.rank_of(node, unvrank((vc + 1) % n, root_core, n));
                    let t = s.transfer(src, dst, BlockSet::single(origin));
                    let rd = s.round_mut(p2 + r as usize);
                    rd.transfers.push(t);
                    rd.node_phase = Some(LocalOpKind::Allgather);
                }
            }
        }
    }
    s.finalize();
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::{validate, validate_ports};

    fn check(cl: Cluster, root: Rank, alg: BcastAlg, port_limit: u32) {
        let s = build(cl, root, 64, alg);
        validate(&s).unwrap_or_else(|v| panic!("{} invalid: {v}", s.algorithm));
        validate_ports(&s, port_limit)
            .unwrap_or_else(|v| panic!("{} ports: {v}", s.algorithm));
    }

    #[test]
    fn kported_valid_all_k() {
        let cl = Cluster::new(4, 4, 2);
        for k in 1..=4 {
            for root in [0, 5, 15] {
                check(cl, root, BcastAlg::KPorted { k }, k);
            }
        }
    }

    #[test]
    fn kported_round_count() {
        let cl = Cluster::hydra(2);
        for k in 1..=6 {
            let s = kported(cl, 0, 100, k);
            assert_eq!(s.rounds.len() as u32, ceil_log(1152, k + 1), "k={k}");
        }
    }

    #[test]
    fn binomial_valid() {
        for (nodes, cores) in [(1, 8), (4, 4), (3, 5)] {
            let cl = Cluster::new(nodes, cores, 1);
            for root in [0, cl.p() - 1] {
                check(cl, root, BcastAlg::Binomial, 1);
            }
        }
    }

    #[test]
    fn scatter_allgather_valid() {
        for (nodes, cores) in [(2, 4), (3, 3)] {
            let cl = Cluster::new(nodes, cores, 1);
            for root in [0, 3] {
                check(cl, root, BcastAlg::ScatterAllgather, 1);
            }
        }
    }

    #[test]
    fn scatter_allgather_moves_less_data_offnode() {
        // van de Geijn moves ~2c per rank vs log(p)·c for binomial.
        let cl = Cluster::new(8, 4, 1);
        let sag = build(cl, 0, 32_000, BcastAlg::ScatterAllgather);
        let bin = build(cl, 0, 32_000, BcastAlg::Binomial);
        assert!(
            sag.offnode_bytes() < bin.offnode_bytes(),
            "sag {} >= bin {}",
            sag.offnode_bytes(),
            bin.offnode_bytes()
        );
    }

    #[test]
    fn klane_valid_full_variant() {
        let cl = Cluster::new(4, 6, 3);
        for k in 1..=3 {
            for root in [0, 7, 23] {
                check(cl, root, BcastAlg::KLane { k, two_phase: false }, 1);
            }
        }
    }

    #[test]
    fn klane_valid_two_phase() {
        let cl = Cluster::new(4, 6, 3);
        for k in 1..=3 {
            for root in [0, 7, 23] {
                check(cl, root, BcastAlg::KLane { k, two_phase: true }, 1);
            }
        }
    }

    #[test]
    fn klane_hydra_shapes() {
        // Full-size sanity: schedules build and respect 1 send per rank
        // per round at the paper's dimensions.
        let cl = Cluster::hydra(2);
        for k in [1, 2, 6] {
            let s = klane(cl, 0, 1000, k, false);
            validate_ports(&s, 1).unwrap();
            assert!(s.num_transfers() >= (cl.p() - 1) as usize);
        }
    }

    #[test]
    fn fulllane_valid() {
        for (nodes, cores) in [(4, 4), (3, 5), (2, 8)] {
            let cl = Cluster::new(nodes, cores, 2);
            for root in [0, cl.p() / 2] {
                check(cl, root, BcastAlg::FullLane, 1);
            }
        }
    }

    #[test]
    fn fulllane_round_count_pow2() {
        // log n (scatter) + log N (bcast) + log n (rd allgather)
        let cl = Cluster::new(4, 8, 2);
        let s = fulllane(cl, 0, 64);
        assert_eq!(s.rounds.len(), 3 + 2 + 3);
    }

    #[test]
    fn fulllane_offnode_traffic_is_c_minus_c_over_n_per_edge() {
        // §2.2: "The amount of data leaving the root node is c - c/N"
        // (uniform trees: each of n segments of c/n crosses N-1 times in
        // total over the binomial tree => total off-node = c·(N-1)).
        let cl = Cluster::new(4, 4, 2);
        let c = 64u64;
        let s = fulllane(cl, 0, c);
        assert_eq!(s.offnode_bytes(), c * 4 * (4 - 1));
    }

    #[test]
    fn kported_sends_full_payload_every_round() {
        let cl = Cluster::new(2, 2, 1);
        let s = kported(cl, 0, 100, 1);
        for round in &s.rounds {
            for t in &round.transfers {
                assert_eq!(t.bytes, 400);
            }
        }
    }
}

//! Scatter algorithms (paper §2.1–2.3). Block `j` (c elements) is
//! destined to rank `j`; the root initially holds all p blocks.
//!
//! * [`ScatterAlg::KPorted`] — §2.1 divide-and-conquer: round- and
//!   message-size-optimal (the root's data leaves it exactly once).
//! * [`ScatterAlg::KLane`] — §2.3 adaptation: the k-ported pattern over
//!   nodes; on each node a local scatter hands the k per-subrange
//!   payloads to the k lane cores, which perform the k sends.
//! * [`ScatterAlg::FullLane`] — §2.2: root-node scatter into n
//!   per-core-class sub-problems solved by n concurrent inter-node
//!   binomial scatters. Round-optimal up to +1 (⌈log n⌉ + ⌈log N⌉).
//! * [`ScatterAlg::Binomial`] / [`ScatterAlg::Linear`] — native baselines.

use crate::algorithms::common::*;
use crate::schedule::{BlockSet, Collective, LocalOpKind, Schedule};
use crate::topology::{Cluster, Rank};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScatterAlg {
    KPorted { k: u32 },
    KLane { k: u32 },
    FullLane,
    Binomial,
    Linear,
}

impl ScatterAlg {
    pub fn name(&self) -> &'static str {
        match self {
            ScatterAlg::KPorted { .. } => "scatter/k-ported",
            ScatterAlg::KLane { .. } => "scatter/k-lane",
            ScatterAlg::FullLane => "scatter/full-lane",
            ScatterAlg::Binomial => "scatter/binomial",
            ScatterAlg::Linear => "scatter/linear",
        }
    }
}

pub fn build(cl: Cluster, root: Rank, c: u64, alg: ScatterAlg) -> Schedule {
    match alg {
        ScatterAlg::KPorted { k } => kported(cl, root, c, k),
        ScatterAlg::KLane { k } => klane(cl, root, c, k),
        ScatterAlg::FullLane => fulllane(cl, root, c),
        ScatterAlg::Binomial => binomial(cl, root, c),
        ScatterAlg::Linear => linear(cl, root, c),
    }
}

/// Blocks destined to real ranks `(vlo + shift) % m .. (vhi + shift) % m`
/// — a contiguous vrank range mapped back through a root shift, which can
/// wrap around into at most two runs.
fn vrange_blocks(vlo: u32, vhi: u32, shift: u32, m: u32) -> BlockSet {
    let lo = (vlo + shift) % m;
    let len = vhi - vlo;
    if lo + len <= m {
        BlockSet::range(lo as u64, (lo + len) as u64)
    } else {
        BlockSet::range(lo as u64, m as u64)
            .union(BlockSet::range(0, (lo + len - m) as u64))
    }
}

/// §2.1 k-ported divide-and-conquer scatter: ⌈log_{k+1} p⌉ rounds, total
/// data leaving the root exactly once.
pub fn kported(cl: Cluster, root: Rank, c: u64, k: u32) -> Schedule {
    let mut s = Schedule::new(
        cl,
        Collective::Scatter { root, c },
        ScatterAlg::KPorted { k }.name(),
    );
    for e in dnc_tree(cl.p(), root, k) {
        // dnc ranges are real rank ranges; block ids are real rank ids.
        s.add_at(e.round, e.src, e.dst, BlockSet::range(e.lo as u64, e.hi as u64));
    }
    s.finalize();
    s
}

/// Native baseline: binomial (recursive-halving) scatter.
pub fn binomial(cl: Cluster, root: Rank, c: u64) -> Schedule {
    let p = cl.p();
    let mut s =
        Schedule::new(cl, Collective::Scatter { root, c }, ScatterAlg::Binomial.name());
    for e in binomial_scatter_tree(p) {
        s.add_at(
            e.round,
            unvrank(e.src, root, p),
            unvrank(e.dst, root, p),
            vrange_blocks(e.lo, e.hi, root, p),
        );
    }
    s.finalize();
    s
}

/// Native baseline: linear scatter — the root sends each block directly,
/// one per round (what several MPI libraries do for large counts).
pub fn linear(cl: Cluster, root: Rank, c: u64) -> Schedule {
    let p = cl.p();
    let mut s =
        Schedule::new(cl, Collective::Scatter { root, c }, ScatterAlg::Linear.name());
    let mut round = 0;
    for j in 0..p {
        if j != root {
            s.add_at(round, root, j, BlockSet::single(j as u64));
            round += 1;
        }
    }
    s.finalize();
    s
}

/// §2.3 adapted k-lane scatter.
///
/// Node-level divide and conquer: at each level the holder core performs
/// a node-local scatter handing each lane core the blocks of one node
/// subrange (one "MPI_Scatter step" in the paper, a binomial tree over
/// the ≤ k+1 participating cores here), then the lane cores concurrently
/// send to the subrange roots' entry cores. When a node's range becomes
/// a single node, the holder core scatters the node's n blocks locally.
pub fn klane(cl: Cluster, root: Rank, c: u64, k: u32) -> Schedule {
    assert!(k <= cl.cores, "k-lane scatter needs k <= n");
    let n = cl.cores;
    let mut s =
        Schedule::new(cl, Collective::Scatter { root, c }, ScatterAlg::KLane { k }.name());
    let root_node = cl.node_of(root);

    // Blocks destined to a contiguous node range = contiguous rank range.
    let node_range_blocks =
        |lo: u32, hi: u32| BlockSet::range((lo * n) as u64, (hi * n) as u64);

    // (node_lo, node_hi, node, holder_core, at_round)
    let mut stack = vec![(0u32, cl.nodes, root_node, cl.core_of(root), 0usize)];
    while let Some((lo, hi, nd, holder, at)) = stack.pop() {
        let len = hi - lo;
        if len <= 1 {
            // Final node-local scatter of this node's n blocks.
            if n > 1 {
                for e in binomial_scatter_tree(n) {
                    let src = cl.rank_of(nd, unvrank(e.src, holder, n));
                    let dst = cl.rank_of(nd, unvrank(e.dst, holder, n));
                    let blocks = vrange_blocks(e.lo, e.hi, holder, n);
                    // block ids are global ranks: shift into this node
                    let blocks: BlockSet =
                        blocks.iter().map(|b| (nd * n) as u64 + b).collect();
                    let t = s.transfer(src, dst, blocks);
                    let r = s.round_mut(at + e.round);
                    r.transfers.push(t);
                    r.node_phase = Some(LocalOpKind::Scatter);
                }
            }
            continue;
        }
        // Divide the node range into ≤ k+1 parts.
        let parts = (k + 1).min(len);
        let base = len / parts;
        let extra = len % parts;
        let mut bounds = Vec::with_capacity(parts as usize + 1);
        let mut st = lo;
        bounds.push(st);
        for i in 0..parts {
            st += base + u32::from(i < extra);
            bounds.push(st);
        }
        // Identify the root part and the send parts.
        let mut send_parts: Vec<(u32, u32)> = Vec::new();
        let mut own_part = (lo, hi);
        for w in bounds.windows(2) {
            if (w[0]..w[1]).contains(&nd) {
                own_part = (w[0], w[1]);
            } else {
                send_parts.push((w[0], w[1]));
            }
        }
        // The paper's k senders: the holder plus k-1 helper lane cores
        // ("A receiving processor on a node scatters to k-1 processors
        // which then concurrently do the k send operations", §2.3). With
        // k = 1 there is no local scatter at all — the holder sends
        // everything itself, one part per network sub-round. Parts are
        // assigned to senders cyclically.
        let q = send_parts.len() as u32;
        let helpers: Vec<u32> = (0..n)
            .filter(|&cc| cc != holder)
            .take((k.saturating_sub(1)).min(q.saturating_sub(1)) as usize)
            .collect();
        let senders: Vec<u32> =
            std::iter::once(holder).chain(helpers.iter().copied()).collect();
        let ns = senders.len() as u32;
        let mut local_rounds = 0usize;
        if !helpers.is_empty() {
            // Binomial local scatter over the senders (slot 0 = holder);
            // helper slot j gets the union of its assigned parts' blocks.
            let slot_blocks = |slot: u32| -> BlockSet {
                let mut blocks = BlockSet::empty();
                for i in 0..q {
                    if i % ns == slot {
                        let (plo, phi) = send_parts[i as usize];
                        blocks = blocks.union(node_range_blocks(plo, phi));
                    }
                }
                blocks
            };
            for e in binomial_scatter_tree(ns) {
                let mut blocks = BlockSet::empty();
                for slot in e.lo..e.hi {
                    blocks = blocks.union(slot_blocks(slot));
                }
                if blocks.is_empty() {
                    continue;
                }
                let src = cl.rank_of(nd, senders[e.src as usize]);
                let dst = cl.rank_of(nd, senders[e.dst as usize]);
                let t = s.transfer(src, dst, blocks);
                let r = s.round_mut(at + e.round);
                r.transfers.push(t);
                r.node_phase = Some(LocalOpKind::Scatter);
                local_rounds = local_rounds.max(e.round + 1);
            }
        }
        // Network rounds: sender of part i transmits in sub-round i/ns.
        let net_round = at + local_rounds;
        let mut last_net = net_round;
        for (i, &(plo, phi)) in send_parts.iter().enumerate() {
            let sub = plo;
            let sub_round = net_round + i / ns as usize;
            let src = cl.rank_of(nd, senders[i % ns as usize]);
            s.add_at(sub_round, src, cl.rank_of(sub, 0), node_range_blocks(plo, phi));
            stack.push((plo, phi, sub, 0, sub_round + 1));
            last_net = last_net.max(sub_round);
        }
        stack.push((own_part.0, own_part.1, nd, holder, last_net + 1));
    }
    s.finalize();
    s
}

/// §2.2 full-lane scatter: root-node local scatter (core class u receives
/// all blocks for core-u ranks), then n concurrent binomial scatters over
/// the N nodes. ⌈log n⌉ + ⌈log N⌉ rounds; data leaving the root node is
/// sent exactly once.
pub fn fulllane(cl: Cluster, root: Rank, c: u64) -> Schedule {
    let n = cl.cores;
    let nn = cl.nodes;
    let mut s =
        Schedule::new(cl, Collective::Scatter { root, c }, ScatterAlg::FullLane.name());
    let root_node = cl.node_of(root);
    let root_core = cl.core_of(root);

    // Blocks for core class u across a node vrange (nodes shifted by
    // root_node): {B*n + u : B in real node range}, ≤ 2 strided runs.
    let class_blocks = |u: u32, vlo: u32, vhi: u32| -> BlockSet {
        let lo = (vlo + root_node) % nn;
        let len = vhi - vlo;
        let mut set = BlockSet::empty();
        if lo + len <= nn {
            set.push_run((lo * n + u) as u64, n as u64, len as u64);
        } else {
            set.push_run((lo * n + u) as u64, n as u64, (nn - lo) as u64);
            set.push_run(u as u64, n as u64, (lo + len - nn) as u64);
        }
        set
    };

    // Phase 1 — root-node local scatter: core class u = all blocks
    // {B*n + u : all B}; cores addressed in vrank space from root_core.
    let p1 = ceil_log(n, 2) as usize;
    for e in binomial_scatter_tree(n) {
        let mut blocks = BlockSet::empty();
        for v in e.lo..e.hi {
            let u = unvrank(v, root_core, n);
            blocks = blocks.union(class_blocks(u, 0, nn));
        }
        let t = s.transfer(
            cl.rank_of(root_node, unvrank(e.src, root_core, n)),
            cl.rank_of(root_node, unvrank(e.dst, root_core, n)),
            blocks,
        );
        let r = s.round_mut(e.round);
        r.transfers.push(t);
        r.node_phase = Some(LocalOpKind::Scatter);
    }

    // Phase 2 — per core class u: binomial scatter over N nodes (vrank
    // space shifted by root_node), all n classes concurrent.
    for u in 0..n {
        for e in binomial_scatter_tree(nn) {
            s.add_at(
                p1 + e.round,
                cl.rank_of(unvrank(e.src, root_node, nn), u),
                cl.rank_of(unvrank(e.dst, root_node, nn), u),
                class_blocks(u, e.lo, e.hi),
            );
        }
    }
    s.finalize();
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::{validate, validate_ports};

    fn check(cl: Cluster, root: Rank, alg: ScatterAlg, port_limit: u32) {
        let s = build(cl, root, 16, alg);
        validate(&s).unwrap_or_else(|v| panic!("{} invalid: {v}", s.algorithm));
        validate_ports(&s, port_limit)
            .unwrap_or_else(|v| panic!("{} ports: {v}", s.algorithm));
    }

    #[test]
    fn kported_valid() {
        let cl = Cluster::new(4, 4, 2);
        for k in 1..=4 {
            for root in [0, 6, 15] {
                check(cl, root, ScatterAlg::KPorted { k }, k);
            }
        }
    }

    #[test]
    fn kported_message_size_optimal() {
        // total data leaving the root = (p-1)·c (each block sent from the
        // root's subtree chain exactly once — total traffic over all
        // transfers is Σ depth·…; message-size optimality here: the root
        // itself sends exactly (p-1)·c elements).
        let cl = Cluster::new(2, 4, 1);
        let c = 16u64;
        let s = kported(cl, 0, c, 2);
        let root_bytes: u64 = s
            .rounds
            .iter()
            .flat_map(|r| &r.transfers)
            .filter(|t| t.src == 0)
            .map(|t| t.bytes)
            .sum();
        assert_eq!(root_bytes, (cl.p() as u64 - 1) * c * 4);
    }

    #[test]
    fn binomial_valid() {
        for (nodes, cores) in [(1, 8), (4, 4), (3, 5)] {
            let cl = Cluster::new(nodes, cores, 1);
            for root in [0, cl.p() - 1] {
                check(cl, root, ScatterAlg::Binomial, 1);
            }
        }
    }

    #[test]
    fn linear_valid() {
        let cl = Cluster::new(2, 3, 1);
        for root in [0, 5] {
            check(cl, root, ScatterAlg::Linear, 1);
        }
        let s = linear(cl, 0, 4);
        assert_eq!(s.rounds.len(), 5); // p-1 rounds
    }

    #[test]
    fn klane_valid() {
        for (nodes, cores, lanes) in [(4, 4, 2), (3, 6, 3), (2, 4, 1), (6, 5, 4)] {
            let cl = Cluster::new(nodes, cores, lanes);
            for k in 1..=lanes {
                for root in [0, cl.p() - 1, cl.p() / 2] {
                    check(cl, root, ScatterAlg::KLane { k }, 1);
                }
            }
        }
    }

    #[test]
    fn klane_hydra_ports() {
        let cl = Cluster::hydra(2);
        for k in [1, 3, 6] {
            let s = klane(cl, 0, 9, k);
            validate_ports(&s, 1).unwrap();
        }
    }

    #[test]
    fn fulllane_valid() {
        for (nodes, cores) in [(4, 4), (3, 5), (2, 8), (5, 3)] {
            let cl = Cluster::new(nodes, cores, 2);
            for root in [0, cl.p() / 2, cl.p() - 1] {
                check(cl, root, ScatterAlg::FullLane, 1);
            }
        }
    }

    #[test]
    fn fulllane_round_count() {
        // ⌈log n⌉ + ⌈log N⌉ (paper §2.2: ≤ ⌈log p⌉ + 1)
        let cl = Cluster::new(4, 8, 2);
        let s = fulllane(cl, 0, 16);
        assert_eq!(s.rounds.len() as u32, ceil_log(8, 2) + ceil_log(4, 2));
    }

    #[test]
    fn fulllane_root_node_egress_optimal() {
        // §2.2: the amount of data leaving the root *node* is exactly
        // total minus the root node's own share = (N-1)·n·c elements
        // (intermediate nodes forward more — that's tree traffic, not
        // root egress).
        let cl = Cluster::new(4, 4, 2);
        let c = 16u64;
        let s = fulllane(cl, 0, c);
        let root_node_egress: u64 = s
            .rounds
            .iter()
            .flat_map(|r| &r.transfers)
            .filter(|t| cl.node_of(t.src) == 0 && cl.node_of(t.dst) != 0)
            .map(|t| t.bytes)
            .sum();
        assert_eq!(root_node_egress, (4 - 1) * 4 * c * 4);
    }

    #[test]
    fn vrange_blocks_wraps() {
        let b = vrange_blocks(2, 5, 6, 8); // vranks 2..5 shifted by 6 mod 8 = {0,1,2}... real {(2+6)%8, (3+6)%8, (4+6)%8} = {0,1,2}
        assert_eq!(b.iter().collect::<Vec<_>>(), vec![0, 1, 2]);
        let b = vrange_blocks(1, 4, 6, 8); // {7, 0, 1}
        assert!(b.contains(7) && b.contains(0) && b.contains(1));
        assert_eq!(b.count(), 3);
    }
}

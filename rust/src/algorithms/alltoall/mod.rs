//! Alltoall algorithms (paper §2.1–2.3). Block `i·p + j` (c elements)
//! travels from rank `i` to rank `j`; every rank starts with its p
//! outgoing blocks.
//!
//! * [`AlltoallAlg::KPorted`] — §2.1 round-robin: ⌈(p-1)/k⌉ rounds, every
//!   block sent and received exactly once (message-size optimal).
//! * [`AlltoallAlg::Bruck`] — radix-(k+1) message combining: ⌈log_{k+1}
//!   p⌉ rounds at the cost of data traveling multiple hops.
//! * [`AlltoallAlg::KLane`] — §2.3: N-1 node rounds of n sub-steps each
//!   (in a sub-step all n cores of a node send to *distinct* cores of the
//!   target node, saturating the k lanes), then a node-local alltoall.
//! * [`AlltoallAlg::FullLane`] — §2.2: node-local alltoall that combines
//!   blocks by destination core class, then n concurrent inter-node
//!   rotation alltoalls. The complete data is communicated twice.
//! * [`AlltoallAlg::Pairwise`] — native baseline: p-1 rotation rounds.


use crate::schedule::{BlockSet, Collective, LocalOpKind, Schedule};
use crate::topology::Cluster;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlltoallAlg {
    KPorted { k: u32 },
    Bruck { k: u32 },
    KLane,
    FullLane,
    Pairwise,
}

impl AlltoallAlg {
    pub fn name(&self) -> &'static str {
        match self {
            AlltoallAlg::KPorted { .. } => "alltoall/k-ported",
            AlltoallAlg::Bruck { .. } => "alltoall/bruck",
            AlltoallAlg::KLane => "alltoall/k-lane",
            AlltoallAlg::FullLane => "alltoall/full-lane",
            AlltoallAlg::Pairwise => "alltoall/pairwise",
        }
    }
}

pub fn build(cl: Cluster, c: u64, alg: AlltoallAlg) -> Schedule {
    match alg {
        AlltoallAlg::KPorted { k } => kported(cl, c, k),
        AlltoallAlg::Bruck { k } => bruck(cl, c, k),
        AlltoallAlg::KLane => klane(cl, c),
        AlltoallAlg::FullLane => fulllane(cl, c),
        AlltoallAlg::Pairwise => pairwise(cl, c),
    }
}

#[inline]
fn bid(p: u32, src: u32, dst: u32) -> u64 {
    src as u64 * p as u64 + dst as u64
}

/// §2.1 k-ported round-robin alltoall: in round r, rank i sends its
/// blocks to the k "next" peers i + rk + 1 … i + rk + k and receives
/// from the k "previous" ones. ⌈(p-1)/k⌉ rounds.
pub fn kported(cl: Cluster, c: u64, k: u32) -> Schedule {
    let p = cl.p();
    let mut s =
        Schedule::new(cl, Collective::Alltoall { c }, AlltoallAlg::KPorted { k }.name());
    let mut round = 0usize;
    let mut off = 1u32;
    while off < p {
        for e in 0..k.min(p - off) {
            let d = off + e;
            for i in 0..p {
                let j = (i + d) % p;
                s.add_at(round, i, j, BlockSet::single(bid(p, i, j)));
            }
        }
        off += k;
        round += 1;
    }
    s.finalize();
    s
}

/// Native baseline: pairwise rotation alltoall (1-ported), p-1 rounds.
pub fn pairwise(cl: Cluster, c: u64) -> Schedule {
    kported_named(cl, c, 1, AlltoallAlg::Pairwise.name())
}

fn kported_named(cl: Cluster, c: u64, k: u32, name: &'static str) -> Schedule {
    let mut s = kported(cl, c, k);
    s.algorithm = name;
    s
}

/// Radix-(k+1) Bruck message-combining alltoall: ⌈log_{k+1} p⌉ rounds;
/// in digit round d (weight w = (k+1)^d), every rank sends to the k peers
/// at distance e·w (e = 1..k) all held blocks whose remaining journey has
/// digit e at position d.
///
/// A block (s → t) with offset δ = (t - s) mod p sits at rank
/// h = (s + δ mod w) mod p before digit d is processed; the transfer to
/// h + e·w carries, for each low-part λ < w with digit_d(λ + e·w …) — i.e.
/// the ids {(h-λ)·p + ((h-λ) + λ + e·w + m·w·(k+1))} for m = 0, 1, … —
/// emitted as ≤ 2 strided runs per λ (wrap-around splits one run).
pub fn bruck(cl: Cluster, c: u64, k: u32) -> Schedule {
    let p = cl.p();
    let pu = p as u64;
    let mut s =
        Schedule::new(cl, Collective::Alltoall { c }, AlltoallAlg::Bruck { k }.name());
    let radix = (k + 1) as u64;
    let mut w = 1u64; // (k+1)^d
    let mut round = 0usize;
    while w < pu {
        for h in 0..p {
            let hu = h as u64;
            for e in 1..=k as u64 {
                if e * w >= pu {
                    break;
                }
                let dst = ((hu + e * w) % pu) as u32;
                let mut blocks = BlockSet::empty();
                for lambda in 0..w.min(pu) {
                    // δ = λ + e·w + m·w·radix, δ < p
                    let d0 = lambda + e * w;
                    if d0 >= pu {
                        break;
                    }
                    let stride = w * radix;
                    let m_max = (pu - 1 - d0) / stride; // inclusive
                    let src = (hu + pu - lambda) % pu;
                    // t = (src + δ) mod p; id = src·p + t. As m grows, t
                    // increases by `stride` until it wraps past p.
                    let t0 = (src + d0) % pu;
                    let len = m_max + 1;
                    // number of terms before t wraps
                    let before_wrap = if t0 >= pu { 0 } else { (pu - t0).div_ceil(stride).min(len) };
                    if before_wrap > 0 {
                        blocks.push_run(src * pu + t0, stride, before_wrap);
                    }
                    if before_wrap < len {
                        let t1 = (t0 + before_wrap * stride) % pu;
                        blocks.push_run(src * pu + t1, stride, len - before_wrap);
                    }
                }
                if !blocks.is_empty() {
                    s.add_at(round, h, dst, blocks);
                }
            }
        }
        w *= radix;
        round += 1;
    }
    s.finalize();
    s
}

/// §2.3 k-lane alltoall: N-1 node rounds; in node round r every core
/// (A, i) posts nonblocking sends of its blocks for node B = A + r,
/// arranged so the n (src, dst) core pairings are distinct ("in each
/// step the n processors on a node send and receive from different
/// processors"); the sub-step ordering is left to the lanes, exactly as
/// the implementation posts one waitall per node round (§3). A final
/// node-local alltoall exchanges the on-node blocks. k is not a
/// parameter of the algorithm (§4.4).
pub fn klane(cl: Cluster, c: u64) -> Schedule {
    let p = cl.p();
    let n = cl.cores;
    let nn = cl.nodes;
    let mut s = Schedule::new(cl, Collective::Alltoall { c }, AlltoallAlg::KLane.name());
    let mut round = 0usize;
    for r in 1..nn {
        for a in 0..nn {
            let b = (a + r) % nn;
            for step in 0..n {
                for i in 0..n {
                    let j = (i + step) % n;
                    let src = cl.rank_of(a, i);
                    let dst = cl.rank_of(b, j);
                    s.add_at(round, src, dst, BlockSet::single(bid(p, src, dst)));
                }
            }
        }
        round += 1;
    }
    // Final round: node-local alltoall (one local waitall: every core
    // exchanges its remaining n-1 on-node blocks).
    for a in 0..nn {
        for i in 0..n {
            for r in 1..n {
                let src = cl.rank_of(a, i);
                let dst = cl.rank_of(a, (i + r) % n);
                let t = s.transfer(src, dst, BlockSet::single(bid(p, src, dst)));
                let rd = s.round_mut(round);
                rd.transfers.push(t);
                rd.node_phase = Some(LocalOpKind::Alltoall);
            }
        }
    }
    s.finalize();
    s
}

/// §2.2 full-lane alltoall.
///
/// Phase 1 (node-local alltoall, combining): core (A, j) hands core
/// (A, i) its blocks destined to core class i on every node — after the
/// phase, core (A, i) holds all of node A's blocks for core class i.
/// Phase 2: n concurrent rotation alltoalls, one per core class, over
/// the N nodes; the class-i exchange (A → B) carries node A's n·c
/// elements for (B, i). The complete data is communicated twice.
pub fn fulllane(cl: Cluster, c: u64) -> Schedule {
    let p = cl.p();
    let pu = p as u64;
    let n = cl.cores;
    let nn = cl.nodes;
    let mut s = Schedule::new(cl, Collective::Alltoall { c }, AlltoallAlg::FullLane.name());
    let mut round = 0usize;
    // Phase 1 — node-local rotation alltoall of per-class slices.
    for r in 1..n {
        for a in 0..nn {
            for j in 0..n {
                let i = (j + r) % n;
                let src = cl.rank_of(a, j);
                let dst = cl.rank_of(a, i);
                // blocks (A,j) -> (B,i) for all B: stride n over dst ranks
                let blocks = BlockSet::strided(src as u64 * pu + i as u64, n as u64, nn as u64);
                let t = s.transfer(src, dst, blocks);
                let rd = s.round_mut(round);
                rd.transfers.push(t);
                rd.node_phase = Some(LocalOpKind::Alltoall);
            }
        }
        round += 1;
    }
    // Phase 2 — per core class i, rotation alltoall over nodes.
    for r in 1..nn {
        for a in 0..nn {
            let b = (a + r) % nn;
            for i in 0..n {
                let src = cl.rank_of(a, i);
                let dst = cl.rank_of(b, i);
                // blocks (A,j) -> (B,i) for all j: ids (A·n+j)·p + B·n+i,
                // stride p over j.
                let first = (a as u64 * n as u64) * pu + b as u64 * n as u64 + i as u64;
                let blocks = BlockSet::strided(first, pu, n as u64);
                s.add_at(round, src, dst, blocks);
            }
        }
        round += 1;
    }
    s.finalize();
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::{validate, validate_ports};

    fn check(cl: Cluster, alg: AlltoallAlg, port_limit: u32) {
        let s = build(cl, 4, alg);
        validate(&s).unwrap_or_else(|v| panic!("{} invalid: {v}", s.algorithm));
        validate_ports(&s, port_limit)
            .unwrap_or_else(|v| panic!("{} ports: {v}", s.algorithm));
    }

    #[test]
    fn kported_valid() {
        let cl = Cluster::new(3, 4, 2);
        for k in [1, 2, 3, 5, 11] {
            check(cl, AlltoallAlg::KPorted { k }, k);
        }
    }

    #[test]
    fn kported_round_count() {
        let cl = Cluster::new(2, 8, 2); // p = 16
        for (k, want) in [(1u32, 15usize), (2, 8), (3, 5), (5, 3), (15, 1)] {
            let s = kported(cl, 4, k);
            assert_eq!(s.rounds.len(), want, "k={k}");
        }
    }

    #[test]
    fn kported_message_size_optimal() {
        // every block crosses exactly once: total bytes = p(p-1)·c·4
        let cl = Cluster::new(2, 3, 1);
        let c = 4u64;
        let s = kported(cl, c, 2);
        let total: u64 =
            s.rounds.iter().flat_map(|r| &r.transfers).map(|t| t.bytes).sum();
        let p = cl.p() as u64;
        assert_eq!(total, p * (p - 1) * c * 4);
    }

    #[test]
    fn pairwise_is_one_ported() {
        let cl = Cluster::new(2, 4, 1);
        check(cl, AlltoallAlg::Pairwise, 1);
        let s = pairwise(cl, 4);
        assert_eq!(s.rounds.len(), cl.p() as usize - 1);
    }

    #[test]
    fn bruck_valid() {
        for (nodes, cores) in [(2, 2), (2, 4), (3, 3), (2, 8), (5, 2)] {
            let cl = Cluster::new(nodes, cores, 2);
            for k in 1..=3 {
                check(cl, AlltoallAlg::Bruck { k }, k);
            }
        }
    }

    #[test]
    fn bruck_round_count() {
        let cl = Cluster::new(2, 8, 2); // p = 16
        for (k, want) in [(1u32, 4u32), (2, 3), (3, 2), (15, 1)] {
            let s = bruck(cl, 4, k);
            assert_eq!(s.rounds.len() as u32, want, "k={k}");
            assert_eq!(want, crate::algorithms::common::ceil_log(16, k + 1));
        }
    }

    #[test]
    fn bruck_sends_more_data_than_optimal() {
        // message combining: total traffic strictly exceeds the p(p-1)c
        // optimum for p > 2 (each block travels multiple hops).
        let cl = Cluster::new(2, 4, 1);
        let c = 4u64;
        let opt = cl.p() as u64 * (cl.p() as u64 - 1) * c * 4;
        let s = bruck(cl, c, 1);
        let total: u64 =
            s.rounds.iter().flat_map(|r| &r.transfers).map(|t| t.bytes).sum();
        assert!(total > opt, "bruck {total} <= optimal {opt}");
    }

    #[test]
    fn klane_valid() {
        for (nodes, cores) in [(2, 2), (3, 4), (4, 3), (2, 5)] {
            let cl = Cluster::new(nodes, cores, 2);
            // one waitall per node round: n nonblocking sends per rank
            check(cl, AlltoallAlg::KLane, cores);
        }
    }

    #[test]
    fn klane_round_structure() {
        // N-1 node rounds + 1 local round (one waitall each, §3)
        let cl = Cluster::new(3, 4, 2);
        let s = klane(cl, 4);
        assert_eq!(s.rounds.len(), (3 - 1) + 1);
    }

    #[test]
    fn klane_saturates_offnode_every_round() {
        // every node round moves n·n messages off-node per node — the
        // full off-node bandwidth possible with k lanes (§2.3)
        let cl = Cluster::new(3, 4, 2);
        let s = klane(cl, 4);
        for round in &s.rounds[..3 - 1] {
            let off = round
                .transfers
                .iter()
                .filter(|t| !cl.same_node(t.src, t.dst))
                .count();
            assert_eq!(off, 3 * 4 * 4);
        }
        // distinct pairings: each rank sends exactly n and receives n
        let mut sends = vec![0u32; cl.p() as usize];
        for t in &s.rounds[0].transfers {
            sends[t.src as usize] += 1;
        }
        assert!(sends.iter().all(|&x| x == 4));
    }

    #[test]
    fn fulllane_valid() {
        for (nodes, cores) in [(2, 2), (3, 4), (4, 3), (2, 5), (5, 3)] {
            let cl = Cluster::new(nodes, cores, 2);
            check(cl, AlltoallAlg::FullLane, 1);
        }
    }

    #[test]
    fn fulllane_communicates_data_twice() {
        // §2.2: total traffic = 2 × p²c (once on-node, once off-node;
        // self-node blocks only once… on-node phase moves ALL blocks,
        // off-node phase moves the (N-1)/N fraction headed off-node).
        let cl = Cluster::new(2, 3, 1);
        let c = 4u64;
        let p = cl.p() as u64;
        let s = fulllane(cl, c);
        let on = s.onnode_bytes();
        let off = s.offnode_bytes();
        // phase 1 moves p·(p - p/n… every rank sends n-1 messages of N·c:
        let n = 3u64;
        let nn = 2u64;
        assert_eq!(on, p * (n - 1) * nn * c * 4);
        assert_eq!(off, nn * (nn - 1) * n * n * c * 4);
    }

    #[test]
    fn fulllane_round_structure() {
        // (n-1) local + (N-1) network rounds
        let cl = Cluster::new(4, 3, 2);
        let s = fulllane(cl, 4);
        assert_eq!(s.rounds.len(), 2 + 3);
    }

    #[test]
    fn hydra_scale_schedules_build() {
        // p = 1152: make sure the big builders stay tractable.
        let cl = Cluster::hydra(2);
        let s = klane(cl, 1);
        // (N-1) node rounds × n·p transfers + (n-1)·p local
        assert_eq!(s.num_transfers(), (35 * 32 + 31) * 1152);
        let s = fulllane(cl, 1);
        validate_ports(&s, 1).unwrap();
        let s = bruck(cl, 1, 2);
        validate_ports(&s, 2).unwrap();
    }
}

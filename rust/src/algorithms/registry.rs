//! The algorithm catalog: one trait-based registry replacing the old
//! hand-maintained `Op × Algorithm` match in the coordinator.
//!
//! Every algorithm of the paper — k-ported (§2.1), the adapted k-lane
//! (§2.3, both the implemented and the theoretical two-phase variant),
//! the problem-splitting full-lane (§2.2), Bruck message combining, the
//! binomial/ring/recursive-doubling baselines, and the native-persona
//! wrappers — is registered exactly once in [`Registry::standard`].
//! Everything else derives from that single site:
//!
//! * `mlane run --alg <name>` resolves through [`Registry::resolve`];
//! * autotune candidate sets come from [`Registry::candidates`];
//! * `mlane validate` and the exhaustive validation test enumerate
//!   [`Registry::validation_instances`];
//! * the sweep engine's cache identity is
//!   [`CollectiveAlgorithm::cache_id`].
//!
//! Invalid (op, algorithm) combinations are typed
//! [`AlgError::UnsupportedCombination`] values, never panics.

use std::fmt;
use std::sync::{Arc, OnceLock};

use crate::algorithms::{allgather, alltoall, bcast, gather, scatter};
use crate::coordinator::Op;
use crate::model::Persona;
use crate::schedule::Schedule;
use crate::sim::AlgId;
use crate::topology::Cluster;

/// The five collective operations, stripped of count and root.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    Bcast,
    Scatter,
    Gather,
    Allgather,
    Alltoall,
}

impl OpKind {
    pub const ALL: [OpKind; 5] = [
        OpKind::Bcast,
        OpKind::Scatter,
        OpKind::Gather,
        OpKind::Allgather,
        OpKind::Alltoall,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Bcast => "bcast",
            OpKind::Scatter => "scatter",
            OpKind::Gather => "gather",
            OpKind::Allgather => "allgather",
            OpKind::Alltoall => "alltoall",
        }
    }

    pub fn parse(s: &str) -> Option<OpKind> {
        OpKind::ALL.into_iter().find(|k| k.name() == s)
    }

    /// Paper-style capitalised name ("Bcast"), as used in table
    /// captions and `MPI_<op>` headings.
    pub fn title(self) -> &'static str {
        match self {
            OpKind::Bcast => "Bcast",
            OpKind::Scatter => "Scatter",
            OpKind::Gather => "Gather",
            OpKind::Allgather => "Allgather",
            OpKind::Alltoall => "Alltoall",
        }
    }

    /// A root-0 instance of this operation with `c` elements (the
    /// harness and validation convention; rooted ops use root 0).
    pub fn op(self, c: u64) -> Op {
        match self {
            OpKind::Bcast => Op::Bcast { root: 0, c },
            OpKind::Scatter => Op::Scatter { root: 0, c },
            OpKind::Gather => Op::Gather { root: 0, c },
            OpKind::Allgather => Op::Allgather { c },
            OpKind::Alltoall => Op::Alltoall { c },
        }
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Typed errors for registry lookups and schedule construction.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AlgError {
    /// `--alg` name not in the catalog.
    UnknownAlgorithm { name: String, known: Vec<&'static str> },
    /// The algorithm does not implement this operation; `supported`
    /// lists the registry families that do.
    UnsupportedCombination { alg: String, op: OpKind, supported: Vec<&'static str> },
    /// The `k` parameter is outside the algorithm's valid range on this
    /// cluster (e.g. k-lane needs k ≤ cores-per-node).
    InvalidK { alg: String, k: u32, reason: String },
    /// The sweep engine's cached state disagreed with itself (see
    /// `sim::MeasureError::Sim`) — an internal cache-identity failure
    /// surfaced as an error rather than a panic.
    Engine { detail: String },
    /// The event-driven network backend refused the run: a drop-tail
    /// queue overflow, an invalid scenario, or an unsupported
    /// backend/cluster combination (see `netsim::NetError`). The
    /// detail is the backend's own self-describing message.
    Backend { detail: String },
}

impl fmt::Display for AlgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AlgError::UnknownAlgorithm { name, known } => {
                write!(f, "unknown algorithm {name}; known: {}", known.join(", "))
            }
            AlgError::UnsupportedCombination { alg, op, supported } => {
                write!(f, "{alg} does not support {op}; supported: {}", supported.join(", "))
            }
            AlgError::InvalidK { alg, k, reason } => {
                write!(f, "{alg}: k = {k} is invalid ({reason})")
            }
            AlgError::Engine { detail } => {
                write!(f, "sweep engine: {detail}")
            }
            AlgError::Backend { detail } => f.write_str(detail),
        }
    }
}

impl std::error::Error for AlgError {}

/// A compiled schedule plus the persona's quirk adjustment (0.0 / 1.0
/// for the paper's own algorithms; only native wrappers set them).
pub struct Built {
    pub schedule: Schedule,
    pub quirk_add: f64,
    pub quirk_mult: f64,
}

impl Built {
    fn plain(schedule: Schedule) -> Built {
        Built { schedule, quirk_add: 0.0, quirk_mult: 1.0 }
    }
}

impl From<crate::model::persona::NativeChoice> for Built {
    fn from(n: crate::model::persona::NativeChoice) -> Built {
        Built { schedule: n.schedule, quirk_add: n.quirk_add, quirk_mult: n.quirk_mult }
    }
}

/// One concrete collective algorithm (a family instance with its `k`
/// bound, if parameterized). The coordinator, harness, CLI and tests
/// all speak this trait; the per-operation builder modules stay private
/// behind it.
pub trait CollectiveAlgorithm: Send + Sync {
    /// Family name as accepted by `--alg` (e.g. "kported").
    fn name(&self) -> &'static str;

    /// Human-readable instance label (e.g. "2-ported"), as printed in
    /// autotune summaries.
    fn label(&self) -> String;

    /// The bound `k` parameter, `None` for unparameterized families.
    fn k(&self) -> Option<u32>;

    /// Does this algorithm implement `op`? Independent of `k`.
    fn supports(&self, op: OpKind) -> bool;

    /// Maximum concurrent sends per rank in any round — the limit
    /// `schedule::validate::validate_ports` must hold under.
    fn ports_required(&self, cl: Cluster, op: OpKind) -> u32;

    /// Sweep-engine cache identity. `Some` promises the communication
    /// structure depends only on (cluster, op shape) — count enters
    /// through block sizes alone — and that quirks are neutral. `None`
    /// (native wrappers) forces a rebuild per cell.
    fn cache_id(&self) -> Option<AlgId>;

    /// Compile (cluster, op) to a schedule plus quirk adjustment.
    fn build(&self, cl: Cluster, persona: &Persona, op: Op) -> Result<Built, AlgError>;
}

/// Shared handle to a registered algorithm instance. Cheap to clone;
/// derefs to [`CollectiveAlgorithm`].
#[derive(Clone)]
pub struct Alg(Arc<dyn CollectiveAlgorithm>);

impl Alg {
    pub fn new<A: CollectiveAlgorithm + 'static>(a: A) -> Alg {
        Alg(Arc::new(a))
    }
}

impl std::ops::Deref for Alg {
    type Target = dyn CollectiveAlgorithm;
    fn deref(&self) -> &Self::Target {
        self.0.as_ref()
    }
}

impl fmt::Debug for Alg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Alg({})", self.label())
    }
}

fn unsupported(alg: &dyn CollectiveAlgorithm, op: OpKind) -> AlgError {
    AlgError::UnsupportedCombination {
        alg: alg.name().to_string(),
        op,
        supported: registry().supporting(op),
    }
}

/// k-lane variants need k cores per node to drive the lanes.
fn need_k_cores(alg: &dyn CollectiveAlgorithm, cl: Cluster, k: u32) -> Result<(), AlgError> {
    if k > cl.cores {
        return Err(AlgError::InvalidK {
            alg: alg.name().to_string(),
            k,
            reason: format!("needs k <= cores per node ({})", cl.cores),
        });
    }
    Ok(())
}

// ---- family implementations -------------------------------------------

/// §2.1 k-ported divide-and-conquer (rooted ops) / round-robin
/// (alltoall).
struct KPorted {
    k: u32,
}

impl CollectiveAlgorithm for KPorted {
    fn name(&self) -> &'static str {
        "kported"
    }
    fn label(&self) -> String {
        format!("{}-ported", self.k)
    }
    fn k(&self) -> Option<u32> {
        Some(self.k)
    }
    fn supports(&self, op: OpKind) -> bool {
        matches!(op, OpKind::Bcast | OpKind::Scatter | OpKind::Gather | OpKind::Alltoall)
    }
    fn ports_required(&self, _cl: Cluster, _op: OpKind) -> u32 {
        self.k
    }
    fn cache_id(&self) -> Option<AlgId> {
        Some(AlgId { family: "kported", k: self.k })
    }
    fn build(&self, cl: Cluster, _persona: &Persona, op: Op) -> Result<Built, AlgError> {
        let k = self.k;
        Ok(Built::plain(match op {
            Op::Bcast { root, c } => bcast::build(cl, root, c, bcast::BcastAlg::KPorted { k }),
            Op::Scatter { root, c } => {
                scatter::build(cl, root, c, scatter::ScatterAlg::KPorted { k })
            }
            Op::Gather { root, c } => {
                gather::build(cl, root, c, gather::GatherAlg::KPorted { k })
            }
            Op::Alltoall { c } => alltoall::build(cl, c, alltoall::AlltoallAlg::KPorted { k }),
            Op::Allgather { .. } => return Err(unsupported(self, op.kind())),
        }))
    }
}

/// §2.3 adapted k-lane (the paper's implementation: full node broadcast
/// on receive). For alltoall the decomposition fixes k = n (§4.4); the
/// bound k is kept only as the reporting parameter.
struct KLane {
    k: u32,
}

impl CollectiveAlgorithm for KLane {
    fn name(&self) -> &'static str {
        "klane"
    }
    fn label(&self) -> String {
        format!("{}-lane", self.k)
    }
    fn k(&self) -> Option<u32> {
        Some(self.k)
    }
    fn supports(&self, op: OpKind) -> bool {
        matches!(op, OpKind::Bcast | OpKind::Scatter | OpKind::Gather | OpKind::Alltoall)
    }
    fn ports_required(&self, cl: Cluster, op: OpKind) -> u32 {
        // Alltoall sub-steps drive all n cores of a node concurrently;
        // the rooted ops send from one lane core at a time.
        if op == OpKind::Alltoall {
            cl.cores
        } else {
            1
        }
    }
    fn cache_id(&self) -> Option<AlgId> {
        Some(AlgId { family: "klane", k: self.k })
    }
    fn build(&self, cl: Cluster, _persona: &Persona, op: Op) -> Result<Built, AlgError> {
        let k = self.k;
        Ok(Built::plain(match op {
            Op::Bcast { root, c } => {
                need_k_cores(self, cl, k)?;
                bcast::build(cl, root, c, bcast::BcastAlg::KLane { k, two_phase: false })
            }
            Op::Scatter { root, c } => {
                need_k_cores(self, cl, k)?;
                scatter::build(cl, root, c, scatter::ScatterAlg::KLane { k })
            }
            Op::Gather { root, c } => {
                need_k_cores(self, cl, k)?;
                gather::build(cl, root, c, gather::GatherAlg::KLane { k })
            }
            Op::Alltoall { c } => alltoall::build(cl, c, alltoall::AlltoallAlg::KLane),
            Op::Allgather { .. } => return Err(unsupported(self, op.kind())),
        }))
    }
}

/// §2.3 theoretical two-phase k-lane broadcast variant: k-way broadcast
/// on receive plus a final k × n/k-way fan-out.
struct KLaneTwoPhase {
    k: u32,
}

impl CollectiveAlgorithm for KLaneTwoPhase {
    fn name(&self) -> &'static str {
        "klane2p"
    }
    fn label(&self) -> String {
        format!("{}-lane-2phase", self.k)
    }
    fn k(&self) -> Option<u32> {
        Some(self.k)
    }
    fn supports(&self, op: OpKind) -> bool {
        op == OpKind::Bcast
    }
    fn ports_required(&self, _cl: Cluster, _op: OpKind) -> u32 {
        1
    }
    fn cache_id(&self) -> Option<AlgId> {
        Some(AlgId { family: "klane2p", k: self.k })
    }
    fn build(&self, cl: Cluster, _persona: &Persona, op: Op) -> Result<Built, AlgError> {
        match op {
            Op::Bcast { root, c } => {
                need_k_cores(self, cl, self.k)?;
                Ok(Built::plain(bcast::build(
                    cl,
                    root,
                    c,
                    bcast::BcastAlg::KLane { k: self.k, two_phase: true },
                )))
            }
            _ => Err(unsupported(self, op.kind())),
        }
    }
}

/// §2.2 problem-splitting full-lane algorithm.
struct FullLane;

impl CollectiveAlgorithm for FullLane {
    fn name(&self) -> &'static str {
        "fulllane"
    }
    fn label(&self) -> String {
        "full-lane".into()
    }
    fn k(&self) -> Option<u32> {
        None
    }
    fn supports(&self, _op: OpKind) -> bool {
        true
    }
    fn ports_required(&self, _cl: Cluster, _op: OpKind) -> u32 {
        1
    }
    fn cache_id(&self) -> Option<AlgId> {
        Some(AlgId { family: "fulllane", k: 0 })
    }
    fn build(&self, cl: Cluster, _persona: &Persona, op: Op) -> Result<Built, AlgError> {
        Ok(Built::plain(match op {
            Op::Bcast { root, c } => bcast::build(cl, root, c, bcast::BcastAlg::FullLane),
            Op::Scatter { root, c } => {
                scatter::build(cl, root, c, scatter::ScatterAlg::FullLane)
            }
            Op::Gather { root, c } => gather::build(cl, root, c, gather::GatherAlg::FullLane),
            Op::Allgather { c } => allgather::build(cl, c, allgather::AllgatherAlg::FullLane),
            Op::Alltoall { c } => alltoall::build(cl, c, alltoall::AlltoallAlg::FullLane),
        }))
    }
}

/// Radix-(k+1) Bruck message combining (alltoall) / dissemination
/// (allgather).
struct Bruck {
    k: u32,
}

impl CollectiveAlgorithm for Bruck {
    fn name(&self) -> &'static str {
        "bruck"
    }
    fn label(&self) -> String {
        format!("bruck({})", self.k)
    }
    fn k(&self) -> Option<u32> {
        Some(self.k)
    }
    fn supports(&self, op: OpKind) -> bool {
        matches!(op, OpKind::Alltoall | OpKind::Allgather)
    }
    fn ports_required(&self, _cl: Cluster, _op: OpKind) -> u32 {
        self.k
    }
    fn cache_id(&self) -> Option<AlgId> {
        Some(AlgId { family: "bruck", k: self.k })
    }
    fn build(&self, cl: Cluster, _persona: &Persona, op: Op) -> Result<Built, AlgError> {
        let k = self.k;
        Ok(Built::plain(match op {
            Op::Alltoall { c } => alltoall::build(cl, c, alltoall::AlltoallAlg::Bruck { k }),
            Op::Allgather { c } => allgather::build(cl, c, allgather::AllgatherAlg::Bruck { k }),
            _ => return Err(unsupported(self, op.kind())),
        }))
    }
}

/// Binomial-tree baseline (the native libraries' small-count shape).
struct Binomial;

impl CollectiveAlgorithm for Binomial {
    fn name(&self) -> &'static str {
        "binomial"
    }
    fn label(&self) -> String {
        "binomial".into()
    }
    fn k(&self) -> Option<u32> {
        None
    }
    fn supports(&self, op: OpKind) -> bool {
        matches!(op, OpKind::Bcast | OpKind::Scatter | OpKind::Gather)
    }
    fn ports_required(&self, _cl: Cluster, _op: OpKind) -> u32 {
        1
    }
    fn cache_id(&self) -> Option<AlgId> {
        Some(AlgId { family: "binomial", k: 0 })
    }
    fn build(&self, cl: Cluster, _persona: &Persona, op: Op) -> Result<Built, AlgError> {
        Ok(Built::plain(match op {
            Op::Bcast { root, c } => bcast::build(cl, root, c, bcast::BcastAlg::Binomial),
            Op::Scatter { root, c } => {
                scatter::build(cl, root, c, scatter::ScatterAlg::Binomial)
            }
            Op::Gather { root, c } => gather::build(cl, root, c, gather::GatherAlg::Binomial),
            _ => return Err(unsupported(self, op.kind())),
        }))
    }
}

/// Ring allgather baseline (bandwidth-optimal, p-1 rounds).
struct Ring;

impl CollectiveAlgorithm for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }
    fn label(&self) -> String {
        "ring".into()
    }
    fn k(&self) -> Option<u32> {
        None
    }
    fn supports(&self, op: OpKind) -> bool {
        op == OpKind::Allgather
    }
    fn ports_required(&self, _cl: Cluster, _op: OpKind) -> u32 {
        1
    }
    fn cache_id(&self) -> Option<AlgId> {
        Some(AlgId { family: "ring", k: 0 })
    }
    fn build(&self, cl: Cluster, _persona: &Persona, op: Op) -> Result<Built, AlgError> {
        match op {
            Op::Allgather { c } => {
                Ok(Built::plain(allgather::build(cl, c, allgather::AllgatherAlg::Ring)))
            }
            _ => Err(unsupported(self, op.kind())),
        }
    }
}

/// Recursive-doubling allgather baseline (log2 p rounds when p is a
/// power of two; the builder falls back to ring otherwise).
struct RecursiveDoubling;

impl CollectiveAlgorithm for RecursiveDoubling {
    fn name(&self) -> &'static str {
        "rdouble"
    }
    fn label(&self) -> String {
        "recursive-doubling".into()
    }
    fn k(&self) -> Option<u32> {
        None
    }
    fn supports(&self, op: OpKind) -> bool {
        op == OpKind::Allgather
    }
    fn ports_required(&self, _cl: Cluster, _op: OpKind) -> u32 {
        1
    }
    fn cache_id(&self) -> Option<AlgId> {
        Some(AlgId { family: "rdouble", k: 0 })
    }
    fn build(&self, cl: Cluster, _persona: &Persona, op: Op) -> Result<Built, AlgError> {
        match op {
            Op::Allgather { c } => Ok(Built::plain(allgather::build(
                cl,
                c,
                allgather::AllgatherAlg::RecursiveDoubling,
            ))),
            _ => Err(unsupported(self, op.kind())),
        }
    }
}

/// Per-size tuned selection (arXiv:cs/0408034): dispatches each count
/// to the winner recorded in a `tuning::DecisionTable` — an installed
/// `TuningBook` when one covers the scenario, otherwise a table
/// auto-built from the registry's default candidates over the paper's
/// count grid and cached process-wide. The meta-entry holds no
/// algorithm knowledge of its own; `tuning::dispatch` is the brain.
struct Tuned;

impl CollectiveAlgorithm for Tuned {
    fn name(&self) -> &'static str {
        "tuned"
    }
    fn label(&self) -> String {
        "tuned".into()
    }
    fn k(&self) -> Option<u32> {
        None
    }
    fn supports(&self, _op: OpKind) -> bool {
        // Every operation has default candidates (full-lane and native
        // cover all five), so tuned dispatch is always well-defined.
        true
    }
    fn ports_required(&self, cl: Cluster, op: OpKind) -> u32 {
        // The meta-entry's *budget*: the widest candidate it may
        // dispatch to. Validating a specific built schedule should use
        // the dispatched algorithm's own budget instead (resolve it via
        // `tuning::dispatch` — see `cmd_validate` and
        // `rust/tests/registry_validation.rs`).
        registry()
            .candidates(cl, op)
            .iter()
            .map(|a| a.ports_required(cl, op))
            .max()
            .unwrap_or(1)
    }
    fn cache_id(&self) -> Option<AlgId> {
        // Dispatch switches algorithms by count — never shape-cacheable,
        // exactly like the native wrappers.
        None
    }
    fn build(&self, cl: Cluster, persona: &Persona, op: Op) -> Result<Built, AlgError> {
        let alg = crate::tuning::dispatch(cl, persona.name, op.kind(), op.count())?;
        // Table validation excludes self-reference, but a book is user
        // input: fail typed rather than recurse if one slips through.
        if alg.name() == "tuned" {
            return Err(AlgError::Engine {
                detail: "decision table dispatched back to `tuned` (self-referential table)"
                    .into(),
            });
        }
        alg.build(cl, persona, op)
    }
}

/// The persona's native MPI_<op>: count-dependent algorithm selection
/// plus the observed pathology quirks — never cacheable.
struct Native;

impl CollectiveAlgorithm for Native {
    fn name(&self) -> &'static str {
        "native"
    }
    fn label(&self) -> String {
        "native".into()
    }
    fn k(&self) -> Option<u32> {
        None
    }
    fn supports(&self, _op: OpKind) -> bool {
        true
    }
    fn ports_required(&self, _cl: Cluster, _op: OpKind) -> u32 {
        // Every native selection is a 1-ported shape (binomial,
        // pairwise, ring, recursive doubling, bruck(1)).
        1
    }
    fn cache_id(&self) -> Option<AlgId> {
        None
    }
    fn build(&self, cl: Cluster, persona: &Persona, op: Op) -> Result<Built, AlgError> {
        Ok(match op {
            Op::Bcast { root, c } => persona.native_bcast(cl, root, c).into(),
            Op::Scatter { root, c } => persona.native_scatter(cl, root, c).into(),
            Op::Gather { root, c } => persona.native_gather(cl, root, c).into(),
            Op::Allgather { c } => persona.native_allgather(cl, c).into(),
            Op::Alltoall { c } => persona.native_alltoall(cl, c).into(),
        })
    }
}

// ---- the registry ------------------------------------------------------

type MakeFn = fn(u32) -> Alg;
type DefaultKsFn = fn(Cluster, OpKind) -> Vec<u32>;
type ValidationKsFn = fn(Cluster) -> Vec<u32>;

/// One catalog entry: a family plus how to enumerate its instances.
pub struct Registration {
    name: &'static str,
    about: &'static str,
    /// Whether `--k` parameterizes this family.
    parameterized: bool,
    make: MakeFn,
    /// `k` values entered into the default autotune candidate set for
    /// an operation (empty = not a default candidate there).
    default_ks: DefaultKsFn,
    /// `k` values exercised by exhaustive validation.
    validation_ks: ValidationKsFn,
}

impl Registration {
    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn about(&self) -> &'static str {
        self.about
    }

    pub fn parameterized(&self) -> bool {
        self.parameterized
    }

    /// Instantiate with the given `k` (ignored by unparameterized
    /// families).
    pub fn instantiate(&self, k: u32) -> Alg {
        (self.make)(if self.parameterized { k } else { 0 })
    }

    /// Op support is a family property (independent of `k`).
    pub fn supports(&self, op: OpKind) -> bool {
        self.instantiate(1).supports(op)
    }
}

/// The algorithm catalog. Iterate [`Registry::entries`] for listings;
/// everything that used to be a hand-maintained enumeration (CLI flag
/// parsing, candidate sets, validation checklists, table specs) is a
/// query against this.
pub struct Registry {
    entries: Vec<Registration>,
}

fn k_one_and_lanes(cl: Cluster, _op: OpKind) -> Vec<u32> {
    let mut ks = vec![1, cl.lanes];
    ks.dedup();
    ks
}

fn lanes_within_cores(cl: Cluster) -> Vec<u32> {
    vec![cl.lanes.min(cl.cores)]
}

fn k_range(cl: Cluster) -> Vec<u32> {
    (1..=cl.lanes.min(cl.cores)).collect()
}

fn unparameterized(_cl: Cluster) -> Vec<u32> {
    vec![0]
}

impl Registry {
    /// The standard catalog: one registration per paper algorithm.
    /// **This is the single site where algorithms are added.**
    pub fn standard() -> Registry {
        Registry {
            entries: vec![
                Registration {
                    name: "kported",
                    about: "§2.1 k-ported divide-and-conquer (rooted) / round-robin (alltoall)",
                    parameterized: true,
                    make: |k| Alg::new(KPorted { k }),
                    default_ks: |cl, op| match op {
                        OpKind::Bcast | OpKind::Scatter | OpKind::Gather | OpKind::Alltoall => {
                            k_one_and_lanes(cl, op)
                        }
                        OpKind::Allgather => vec![],
                    },
                    validation_ks: k_range,
                },
                Registration {
                    name: "klane",
                    about: "§2.3 adapted k-lane (full node broadcast on receive)",
                    parameterized: true,
                    make: |k| Alg::new(KLane { k }),
                    default_ks: |cl, op| match op {
                        OpKind::Bcast | OpKind::Scatter | OpKind::Gather => {
                            lanes_within_cores(cl)
                        }
                        OpKind::Alltoall => vec![cl.lanes],
                        OpKind::Allgather => vec![],
                    },
                    validation_ks: k_range,
                },
                Registration {
                    name: "klane2p",
                    about: "§2.3 theoretical two-phase k-lane broadcast variant",
                    parameterized: true,
                    make: |k| Alg::new(KLaneTwoPhase { k }),
                    default_ks: |cl, op| match op {
                        OpKind::Bcast => lanes_within_cores(cl),
                        _ => vec![],
                    },
                    validation_ks: k_range,
                },
                Registration {
                    name: "fulllane",
                    about: "§2.2 problem-splitting full-lane algorithm",
                    parameterized: false,
                    make: |_| Alg::new(FullLane),
                    default_ks: |_, _| vec![0],
                    validation_ks: unparameterized,
                },
                Registration {
                    name: "bruck",
                    about: "radix-(k+1) Bruck combining (alltoall) / dissemination (allgather)",
                    parameterized: true,
                    make: |k| Alg::new(Bruck { k }),
                    default_ks: |cl, op| match op {
                        OpKind::Alltoall => vec![cl.lanes],
                        OpKind::Allgather => k_one_and_lanes(cl, op),
                        _ => vec![],
                    },
                    validation_ks: k_range,
                },
                Registration {
                    name: "binomial",
                    about: "binomial-tree baseline (native small-count shape)",
                    parameterized: false,
                    make: |_| Alg::new(Binomial),
                    default_ks: |_, _| vec![],
                    validation_ks: unparameterized,
                },
                Registration {
                    name: "ring",
                    about: "ring allgather baseline (bandwidth-optimal)",
                    parameterized: false,
                    make: |_| Alg::new(Ring),
                    default_ks: |_, _| vec![],
                    validation_ks: unparameterized,
                },
                Registration {
                    name: "rdouble",
                    about: "recursive-doubling allgather baseline",
                    parameterized: false,
                    make: |_| Alg::new(RecursiveDoubling),
                    default_ks: |_, _| vec![],
                    validation_ks: unparameterized,
                },
                Registration {
                    name: "native",
                    about: "the persona's native MPI_<op>, with its observed quirks",
                    parameterized: false,
                    make: |_| Alg::new(Native),
                    default_ks: |_, _| vec![0],
                    validation_ks: unparameterized,
                },
                Registration {
                    name: "tuned",
                    about: "per-size tuned selection via decision tables (arXiv:cs/0408034)",
                    parameterized: false,
                    make: |_| Alg::new(Tuned),
                    // Never its own autotune candidate: the candidate
                    // set is what tuned dispatches *over*; including it
                    // would recurse.
                    default_ks: |_, _| vec![],
                    validation_ks: unparameterized,
                },
            ],
        }
    }

    pub fn entries(&self) -> &[Registration] {
        &self.entries
    }

    /// All family names, registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    pub fn get(&self, name: &str) -> Option<&Registration> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Resolve a (name, k) pair — the `--alg`/`--k` flags — to an
    /// instance.
    pub fn resolve(&self, name: &str, k: u32) -> Result<Alg, AlgError> {
        let entry = self.get(name).ok_or_else(|| AlgError::UnknownAlgorithm {
            name: name.to_string(),
            known: self.names(),
        })?;
        if entry.parameterized && k == 0 {
            return Err(AlgError::InvalidK {
                alg: entry.name.to_string(),
                k,
                reason: "k must be >= 1".into(),
            });
        }
        Ok(entry.instantiate(k))
    }

    /// Family names implementing `op` (registration order) — the
    /// "supported: …" list in error messages and help output.
    pub fn supporting(&self, op: OpKind) -> Vec<&'static str> {
        self.entries.iter().filter(|e| e.supports(op)).map(|e| e.name).collect()
    }

    /// The default autotune candidate set for `op` on `cl`.
    pub fn candidates(&self, cl: Cluster, op: OpKind) -> Vec<Alg> {
        let mut out = Vec::new();
        for entry in &self.entries {
            if !entry.supports(op) {
                continue;
            }
            for k in (entry.default_ks)(cl, op) {
                out.push(entry.instantiate(k));
            }
        }
        out
    }

    /// Every instance exhaustive validation should exercise on `cl`
    /// (all families, parameterized ones over their valid k range).
    pub fn validation_instances(&self, cl: Cluster) -> Vec<Alg> {
        let mut out = Vec::new();
        for entry in &self.entries {
            for k in (entry.validation_ks)(cl) {
                out.push(entry.instantiate(k));
            }
        }
        out
    }
}

/// The process-wide catalog.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::standard)
}

// ---- convenience constructors (sugar over `registry().resolve`) --------

pub fn kported(k: u32) -> Alg {
    registry().resolve("kported", k).expect("kported")
}

pub fn klane(k: u32) -> Alg {
    registry().resolve("klane", k).expect("klane")
}

pub fn klane2p(k: u32) -> Alg {
    registry().resolve("klane2p", k).expect("klane2p")
}

pub fn fulllane() -> Alg {
    registry().resolve("fulllane", 0).expect("fulllane")
}

pub fn bruck(k: u32) -> Alg {
    registry().resolve("bruck", k).expect("bruck")
}

pub fn native() -> Alg {
    registry().resolve("native", 0).expect("native")
}

pub fn tuned() -> Alg {
    registry().resolve("tuned", 0).expect("tuned")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PersonaName;

    fn persona() -> Persona {
        Persona::get(PersonaName::OpenMpi)
    }

    #[test]
    fn resolve_known_and_unknown() {
        assert_eq!(registry().resolve("kported", 2).unwrap().label(), "2-ported");
        let err = registry().resolve("nosuch", 2).unwrap_err();
        assert!(matches!(err, AlgError::UnknownAlgorithm { .. }), "{err}");
        assert!(err.to_string().contains("kported"), "{err}");
    }

    #[test]
    fn zero_k_rejected_for_parameterized_families() {
        for name in ["kported", "klane", "klane2p", "bruck"] {
            let err = registry().resolve(name, 0).unwrap_err();
            assert!(matches!(err, AlgError::InvalidK { .. }), "{name}: {err}");
        }
        // Unparameterized families ignore k entirely.
        assert!(registry().resolve("fulllane", 0).is_ok());
        assert!(registry().resolve("native", 7).is_ok());
    }

    #[test]
    fn unsupported_combination_is_a_typed_error() {
        let cl = Cluster::new(2, 2, 1);
        let err =
            bruck(2).build(cl, &persona(), Op::Bcast { root: 0, c: 4 }).unwrap_err();
        match &err {
            AlgError::UnsupportedCombination { alg, op, supported } => {
                assert_eq!(alg, "bruck");
                assert_eq!(*op, OpKind::Bcast);
                assert!(supported.contains(&"kported"), "{supported:?}");
                assert!(!supported.contains(&"bruck"), "{supported:?}");
            }
            other => panic!("wrong error: {other:?}"),
        }
        assert!(
            err.to_string().starts_with("bruck does not support bcast; supported:"),
            "{err}"
        );
    }

    #[test]
    fn klane_rejects_k_beyond_cores() {
        let cl = Cluster::new(4, 2, 2); // 2 cores per node
        let err = klane(3).build(cl, &persona(), Op::Bcast { root: 0, c: 4 }).unwrap_err();
        assert!(matches!(err, AlgError::InvalidK { k: 3, .. }), "{err}");
        // But alltoall ignores k (decomposition fixes k = n).
        assert!(klane(3).build(cl, &persona(), Op::Alltoall { c: 4 }).is_ok());
    }

    #[test]
    fn default_candidates_match_the_paper_families() {
        let cl = Cluster::new(4, 4, 2);
        let names = |op: OpKind| -> Vec<String> {
            registry().candidates(cl, op).iter().map(|a| a.label()).collect()
        };
        assert_eq!(
            names(OpKind::Bcast),
            ["1-ported", "2-ported", "2-lane", "2-lane-2phase", "full-lane", "native"]
        );
        assert_eq!(names(OpKind::Allgather), ["full-lane", "bruck(1)", "bruck(2)", "native"]);
        assert_eq!(
            names(OpKind::Alltoall),
            ["1-ported", "2-ported", "2-lane", "full-lane", "bruck(2)", "native"]
        );
    }

    #[test]
    fn cache_ids_are_distinct_across_instances() {
        let cl = Cluster::new(4, 4, 2);
        let mut seen = std::collections::HashSet::new();
        for alg in registry().validation_instances(cl) {
            if let Some(id) = alg.cache_id() {
                assert!(seen.insert(id), "duplicate cache id {id:?} ({})", alg.label());
            } else {
                assert!(
                    matches!(alg.name(), "native" | "tuned"),
                    "only count-dependent selections may be uncacheable, not {}",
                    alg.label()
                );
            }
        }
    }

    #[test]
    fn tuned_registered_but_never_its_own_candidate() {
        let cl = Cluster::new(4, 4, 2);
        let alg = registry().resolve("tuned", 0).unwrap();
        assert!(OpKind::ALL.into_iter().all(|op| alg.supports(op)));
        assert!(alg.cache_id().is_none(), "dispatch is count-dependent");
        // The meta port budget covers the widest candidate (2-ported
        // bcast needs 2 ports on this cluster).
        assert!(alg.ports_required(cl, OpKind::Bcast) >= 2);
        for op in OpKind::ALL {
            let cands = registry().candidates(cl, op);
            assert!(!cands.is_empty(), "{op}: tuned needs candidates to dispatch over");
            assert!(
                cands.iter().all(|a| a.name() != "tuned"),
                "{op}: tuned must not be its own candidate (would recurse)"
            );
        }
    }

    #[test]
    fn tuned_builds_the_dispatched_schedule() {
        let cl = Cluster::new(2, 4, 2);
        let built =
            tuned().build(cl, &persona(), Op::Bcast { root: 0, c: 64 }).unwrap();
        // Whatever won, it is a real schedule of a concrete algorithm
        // with neutral-or-native quirks, not a meta artifact.
        assert!(!built.schedule.algorithm.is_empty());
        let direct = crate::tuning::dispatch(
            cl,
            crate::model::PersonaName::OpenMpi,
            OpKind::Bcast,
            64,
        )
        .unwrap();
        let direct_built =
            direct.build(cl, &persona(), Op::Bcast { root: 0, c: 64 }).unwrap();
        assert_eq!(built.schedule.algorithm, direct_built.schedule.algorithm);
    }

    #[test]
    fn two_phase_variant_registered_and_buildable() {
        let cl = Cluster::new(4, 4, 2);
        let alg = registry().resolve("klane2p", 2).unwrap();
        assert!(alg.supports(OpKind::Bcast) && !alg.supports(OpKind::Alltoall));
        let built = alg.build(cl, &persona(), Op::Bcast { root: 0, c: 64 }).unwrap();
        assert_eq!(built.schedule.algorithm, "bcast/k-lane-2phase");
        // And it rides into the default bcast candidate set.
        let labels: Vec<String> =
            registry().candidates(cl, OpKind::Bcast).iter().map(|a| a.label()).collect();
        assert!(labels.contains(&"2-lane-2phase".to_string()), "{labels:?}");
    }
}

//! Collective algorithm builders: each compiles to a `schedule::Schedule`.
//! The [`registry`] module is the catalog the rest of the system talks
//! to; the per-operation modules stay the low-level builders.
pub mod registry;
pub mod bcast;
pub mod scatter;
pub mod gather;
pub mod allgather;
pub mod alltoall;
pub mod common;

//! Collective algorithm builders: each compiles to a `schedule::Schedule`.
pub mod bcast;
pub mod scatter;
pub mod gather;
pub mod allgather;
pub mod alltoall;
pub mod common;

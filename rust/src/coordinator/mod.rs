//! The coordinator: a single façade over topology, algorithms, personas
//! and the two backends — the "improved MPI library" the paper's
//! conclusion calls for ("the native MPI library implementations … can
//! easily be improved, and sometimes quite considerably").
//!
//! * [`Collectives::run`] builds + times any (operation, algorithm)
//!   combination on the simulator;
//! * [`Collectives::execute`] runs it for real on the threaded backend;
//! * [`Collectives::autotune`] picks the fastest algorithm for an
//!   operation and size — the algorithm-selection layer real libraries
//!   get wrong in the paper's tables.

use std::cell::RefCell;

use anyhow::Result;

use crate::algorithms::{allgather, alltoall, bcast, gather, scatter};
use crate::exec::{ExecReport, ExecRuntime};
use crate::model::{Persona, PersonaName};
use crate::schedule::Schedule;
use crate::sim::{self, AlgId, OpShape, SweepEngine, SweepKey, SweepStats};
use crate::topology::{Cluster, Rank};
use crate::util::Summary;

/// A collective operation request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Bcast { root: Rank, c: u64 },
    Scatter { root: Rank, c: u64 },
    Gather { root: Rank, c: u64 },
    Allgather { c: u64 },
    Alltoall { c: u64 },
}

impl Op {
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Bcast { .. } => "bcast",
            Op::Scatter { .. } => "scatter",
            Op::Gather { .. } => "gather",
            Op::Allgather { .. } => "allgather",
            Op::Alltoall { .. } => "alltoall",
        }
    }

    pub fn count(&self) -> u64 {
        match self {
            Op::Bcast { c, .. }
            | Op::Scatter { c, .. }
            | Op::Gather { c, .. }
            | Op::Allgather { c }
            | Op::Alltoall { c } => *c,
        }
    }
}

/// Unified algorithm selector across the three operations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// §2.1 k-ported algorithm with the given k.
    KPorted { k: u32 },
    /// §2.3 adapted k-lane algorithm (k ignored for alltoall, §4.4).
    KLane { k: u32 },
    /// §2.2 problem-splitting full-lane algorithm.
    FullLane,
    /// Radix-(k+1) message-combining (alltoall only).
    Bruck { k: u32 },
    /// The persona's native MPI_<op> (with its observed quirks).
    Native,
}

impl Algorithm {
    pub fn label(&self) -> String {
        match self {
            Algorithm::KPorted { k } => format!("{k}-ported"),
            Algorithm::KLane { k } => format!("{k}-lane"),
            Algorithm::FullLane => "full-lane".into(),
            Algorithm::Bruck { k } => format!("bruck({k})"),
            Algorithm::Native => "native".into(),
        }
    }
}

/// One measurement row (matches the paper's table columns).
#[derive(Clone, Debug)]
pub struct Measurement {
    pub algorithm: String,
    pub k: u32,
    pub c: u64,
    pub summary: Summary,
}

pub struct Collectives {
    pub cluster: Cluster,
    pub persona: Persona,
    pub reps: usize,
    pub warmup: usize,
    pub seed: u64,
    /// Schedule cache + shared rep state: count sweeps (tables,
    /// autotune candidate grids) build each communication structure once
    /// and re-cost it per count (see `sim::sweep`). Keyed by (cluster,
    /// op shape, algorithm) — do not mutate `persona.model` between
    /// runs (cached simulators bake the model in); build a fresh
    /// `Collectives` instead.
    engine: RefCell<SweepEngine>,
}

/// The sweep-invariant part of an operation (cache-key component).
fn op_shape(op: Op) -> OpShape {
    match op {
        Op::Bcast { root, .. } => OpShape::Bcast { root },
        Op::Scatter { root, .. } => OpShape::Scatter { root },
        Op::Gather { root, .. } => OpShape::Gather { root },
        Op::Allgather { .. } => OpShape::Allgather,
        Op::Alltoall { .. } => OpShape::Alltoall,
    }
}

/// Cache identity of an algorithm, or `None` if its schedule (or quirk
/// adjustment) depends on the element count and must be rebuilt per
/// cell — the native personas switch algorithms and pathologies by size.
fn alg_id(alg: Algorithm) -> Option<AlgId> {
    match alg {
        Algorithm::KPorted { k } => Some(AlgId { family: "kported", k }),
        Algorithm::KLane { k } => Some(AlgId { family: "klane", k }),
        Algorithm::FullLane => Some(AlgId { family: "fulllane", k: 0 }),
        Algorithm::Bruck { k } => Some(AlgId { family: "bruck", k }),
        Algorithm::Native => None,
    }
}

impl Collectives {
    pub fn new(cluster: Cluster, persona: PersonaName) -> Self {
        Self {
            cluster,
            persona: Persona::get(persona),
            reps: sim::default_reps(),
            warmup: 2,
            seed: 0xC0FFEE,
            engine: RefCell::new(SweepEngine::new()),
        }
    }

    /// Sweep-engine counters (cells measured, schedules built, recosts).
    pub fn sweep_stats(&self) -> SweepStats {
        self.engine.borrow().stats()
    }

    /// Compile (op, algorithm) to a schedule plus the persona's native
    /// quirk adjustment (1.0/0.0 for non-native algorithms).
    pub fn schedule(&self, op: Op, alg: Algorithm) -> (Schedule, f64, f64) {
        let cl = self.cluster;
        match (op, alg) {
            (Op::Bcast { root, c }, Algorithm::KPorted { k }) => {
                (bcast::build(cl, root, c, bcast::BcastAlg::KPorted { k }), 0.0, 1.0)
            }
            (Op::Bcast { root, c }, Algorithm::KLane { k }) => (
                bcast::build(cl, root, c, bcast::BcastAlg::KLane { k, two_phase: false }),
                0.0,
                1.0,
            ),
            (Op::Bcast { root, c }, Algorithm::FullLane) => {
                (bcast::build(cl, root, c, bcast::BcastAlg::FullLane), 0.0, 1.0)
            }
            (Op::Bcast { root, c }, Algorithm::Native) => {
                let n = self.persona.native_bcast(cl, root, c);
                (n.schedule, n.quirk_add, n.quirk_mult)
            }
            (Op::Bcast { .. }, Algorithm::Bruck { .. }) => {
                panic!("bruck is an alltoall algorithm")
            }
            (Op::Scatter { root, c }, Algorithm::KPorted { k }) => {
                (scatter::build(cl, root, c, scatter::ScatterAlg::KPorted { k }), 0.0, 1.0)
            }
            (Op::Scatter { root, c }, Algorithm::KLane { k }) => {
                (scatter::build(cl, root, c, scatter::ScatterAlg::KLane { k }), 0.0, 1.0)
            }
            (Op::Scatter { root, c }, Algorithm::FullLane) => {
                (scatter::build(cl, root, c, scatter::ScatterAlg::FullLane), 0.0, 1.0)
            }
            (Op::Scatter { root, c }, Algorithm::Native) => {
                let n = self.persona.native_scatter(cl, root, c);
                (n.schedule, n.quirk_add, n.quirk_mult)
            }
            (Op::Scatter { .. }, Algorithm::Bruck { .. }) => {
                panic!("bruck is an alltoall algorithm")
            }
            (Op::Alltoall { c }, Algorithm::KPorted { k }) => {
                (alltoall::build(cl, c, alltoall::AlltoallAlg::KPorted { k }), 0.0, 1.0)
            }
            (Op::Alltoall { c }, Algorithm::KLane { .. }) => {
                (alltoall::build(cl, c, alltoall::AlltoallAlg::KLane), 0.0, 1.0)
            }
            (Op::Alltoall { c }, Algorithm::FullLane) => {
                (alltoall::build(cl, c, alltoall::AlltoallAlg::FullLane), 0.0, 1.0)
            }
            (Op::Alltoall { c }, Algorithm::Bruck { k }) => {
                (alltoall::build(cl, c, alltoall::AlltoallAlg::Bruck { k }), 0.0, 1.0)
            }
            (Op::Alltoall { c }, Algorithm::Native) => {
                let n = self.persona.native_alltoall(cl, c);
                (n.schedule, n.quirk_add, n.quirk_mult)
            }
            // Gather: every scatter algorithm's dual (paper §2: "the
            // gather operation is the dual of the scatter operation").
            (Op::Gather { root, c }, Algorithm::KPorted { k }) => {
                (gather::build(cl, root, c, gather::GatherAlg::KPorted { k }), 0.0, 1.0)
            }
            (Op::Gather { root, c }, Algorithm::KLane { k }) => {
                (gather::build(cl, root, c, gather::GatherAlg::KLane { k }), 0.0, 1.0)
            }
            (Op::Gather { root, c }, Algorithm::FullLane) => {
                (gather::build(cl, root, c, gather::GatherAlg::FullLane), 0.0, 1.0)
            }
            (Op::Gather { root, c }, Algorithm::Native) => {
                // libraries use binomial gather across sizes
                (gather::build(cl, root, c, gather::GatherAlg::Binomial), 0.0, 1.0)
            }
            (Op::Gather { .. }, Algorithm::Bruck { .. }) => {
                panic!("bruck is not a gather algorithm")
            }
            // Allgather.
            (Op::Allgather { c }, Algorithm::KPorted { k } | Algorithm::Bruck { k }) => {
                (allgather::build(cl, c, allgather::AllgatherAlg::Bruck { k }), 0.0, 1.0)
            }
            (Op::Allgather { c }, Algorithm::KLane { .. } | Algorithm::FullLane) => {
                (allgather::build(cl, c, allgather::AllgatherAlg::FullLane), 0.0, 1.0)
            }
            (Op::Allgather { c }, Algorithm::Native) => {
                // ring for large, recursive doubling for small (MPI-like)
                let alg = if c * 4 <= 8192 {
                    allgather::AllgatherAlg::RecursiveDoubling
                } else {
                    allgather::AllgatherAlg::Ring
                };
                (allgather::build(cl, c, alg), 0.0, 1.0)
            }
        }
    }

    /// Simulate (op, algorithm) under the persona's cost model and
    /// return paper-style (avg, min) of the slowest rank.
    ///
    /// Count-invariant algorithms are served through the sweep engine:
    /// the first count for a given (cluster, op shape, algorithm) builds
    /// the schedule, later counts only re-cost it, so count sweeps and
    /// repeated autotune calls share one cached structure per candidate.
    pub fn run(&self, op: Op, alg: Algorithm) -> Measurement {
        let model = self.persona.model;
        let (cell, add, mult) = match alg_id(alg) {
            Some(alg_key) => {
                let key =
                    SweepKey { cluster: self.cluster, op: op_shape(op), alg: alg_key };
                let cell = self.engine.borrow_mut().measure(
                    key,
                    op.count(),
                    &model,
                    self.reps,
                    self.warmup,
                    self.seed,
                    |_| {
                        let (schedule, add, mult) = self.schedule(op, alg);
                        // Cacheable algorithms must have neutral quirks
                        // (quirks vary with count; the cache would pin
                        // the first cell's values).
                        debug_assert!(
                            add == 0.0 && mult == 1.0,
                            "non-neutral quirk on cacheable algorithm {alg:?}"
                        );
                        schedule
                    },
                );
                (cell, 0.0, 1.0)
            }
            None => {
                let (schedule, add, mult) = self.schedule(op, alg);
                let cell = self.engine.borrow_mut().measure_uncached(
                    &schedule,
                    &model,
                    self.reps,
                    self.warmup,
                    self.seed,
                );
                (cell, add, mult)
            }
        };
        let adj = |t: f64| t * mult + add;
        Measurement {
            algorithm: cell.algorithm.to_string(),
            k: match alg {
                Algorithm::KPorted { k } | Algorithm::KLane { k } | Algorithm::Bruck { k } => k,
                _ => self.cluster.lanes,
            },
            c: op.count(),
            summary: Summary {
                avg: adj(cell.summary.avg),
                min: adj(cell.summary.min),
                max: adj(cell.summary.max),
                reps: cell.summary.reps,
            },
        }
    }

    /// Execute (op, algorithm) for real on the threaded backend.
    pub fn execute(&self, op: Op, alg: Algorithm, rt: &ExecRuntime) -> Result<ExecReport> {
        let (schedule, _, _) = self.schedule(op, alg);
        rt.run(&schedule, self.reps, self.warmup)
    }

    /// Pick the fastest algorithm (by simulated average) among the
    /// candidates. This is the coordinator's answer to the paper's
    /// conclusion that native selection "can easily be improved".
    pub fn autotune(&self, op: Op, candidates: &[Algorithm]) -> (Algorithm, Measurement) {
        assert!(!candidates.is_empty());
        let mut best: Option<(Algorithm, Measurement)> = None;
        for &alg in candidates {
            let m = self.run(op, alg);
            if best.as_ref().is_none_or(|(_, b)| m.summary.avg < b.summary.avg) {
                best = Some((alg, m));
            }
        }
        best.unwrap()
    }

    /// Sensible candidate set per operation.
    pub fn default_candidates(&self, op: Op) -> Vec<Algorithm> {
        let lanes = self.cluster.lanes;
        match op {
            Op::Bcast { .. } | Op::Scatter { .. } | Op::Gather { .. } => vec![
                Algorithm::KPorted { k: 1 },
                Algorithm::KPorted { k: lanes },
                Algorithm::KLane { k: lanes },
                Algorithm::FullLane,
                Algorithm::Native,
            ],
            Op::Allgather { .. } => vec![
                Algorithm::Bruck { k: 1 },
                Algorithm::Bruck { k: lanes },
                Algorithm::FullLane,
                Algorithm::Native,
            ],
            Op::Alltoall { .. } => vec![
                Algorithm::KPorted { k: 1 },
                Algorithm::KPorted { k: lanes },
                Algorithm::Bruck { k: lanes },
                Algorithm::KLane { k: lanes },
                Algorithm::FullLane,
                Algorithm::Native,
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coll() -> Collectives {
        let mut c = Collectives::new(Cluster::new(4, 4, 2), PersonaName::OpenMpi);
        c.reps = 3;
        c.warmup = 1;
        c
    }

    #[test]
    fn run_all_op_alg_pairs() {
        let c = coll();
        for op in [
            Op::Bcast { root: 0, c: 64 },
            Op::Scatter { root: 0, c: 16 },
            Op::Gather { root: 0, c: 16 },
            Op::Allgather { c: 16 },
            Op::Alltoall { c: 8 },
        ] {
            for alg in c.default_candidates(op) {
                let m = c.run(op, alg);
                assert!(m.summary.avg > 0.0, "{op:?} {alg:?}");
                assert!(m.summary.min <= m.summary.avg);
            }
        }
    }

    #[test]
    fn native_quirks_applied() {
        let mut c = Collectives::new(Cluster::hydra(2), PersonaName::IntelMpi);
        c.reps = 2;
        c.warmup = 0;
        let m = c.run(Op::Bcast { root: 0, c: 1 }, Algorithm::Native);
        assert!(m.summary.avg > 900.0, "Intel small-bcast floor: {}", m.summary.avg);
    }

    #[test]
    fn autotune_beats_native_where_paper_says_so() {
        // Table 12: full-lane bcast ≫ native MPI_Bcast at c = 1e6.
        let mut c = Collectives::new(Cluster::hydra(2), PersonaName::OpenMpi);
        c.reps = 2;
        c.warmup = 0;
        let op = Op::Bcast { root: 0, c: 1_000_000 };
        let native = c.run(op, Algorithm::Native);
        let (best_alg, best) = c.autotune(op, &c.default_candidates(op));
        assert!(best.summary.avg < native.summary.avg, "autotune should beat native");
        assert!(
            matches!(best_alg, Algorithm::FullLane | Algorithm::KPorted { .. }),
            "{best_alg:?}"
        );
    }

    #[test]
    #[should_panic(expected = "bruck is an alltoall algorithm")]
    fn bruck_rejected_for_bcast() {
        coll().schedule(Op::Bcast { root: 0, c: 4 }, Algorithm::Bruck { k: 2 });
    }

    #[test]
    fn count_sweep_shares_one_cached_schedule() {
        let c = coll();
        for count in [64u64, 6000, 64, 100_000] {
            c.run(Op::Bcast { root: 0, c: count }, Algorithm::FullLane);
        }
        let st = c.sweep_stats();
        assert_eq!(st.schedules_built, 1, "{st:?}");
        assert_eq!(st.cells, 4, "{st:?}");
        assert!(st.recosts >= 2, "{st:?}");
    }

    #[test]
    fn cached_run_equals_per_cell_rebuild() {
        let c = coll();
        let op = Op::Scatter { root: 0, c: 16 };
        let alg = Algorithm::KLane { k: 2 };
        c.run(Op::Scatter { root: 0, c: 869 }, alg); // prime the cache
        let cached = c.run(op, alg); // served by recost
        let fresh = sim::measure(
            &c.schedule(op, alg).0,
            &c.persona.model,
            c.reps,
            c.warmup,
            c.seed,
        );
        assert_eq!(cached.summary, fresh);
    }

    #[test]
    fn native_runs_bypass_the_shape_cache() {
        let c = coll();
        c.run(Op::Bcast { root: 0, c: 16 }, Algorithm::Native);
        c.run(Op::Bcast { root: 0, c: 1_000_000 }, Algorithm::Native);
        let st = c.sweep_stats();
        assert_eq!(st.schedules_built, 2, "{st:?}");
        assert_eq!(st.recosts + st.cache_hits, 0, "{st:?}");
    }
}

//! The coordinator: a single façade over topology, the algorithm
//! registry, personas and the two backends — the "improved MPI library"
//! the paper's conclusion calls for ("the native MPI library
//! implementations … can easily be improved, and sometimes quite
//! considerably").
//!
//! * [`Collectives::run`] builds + times any (operation, algorithm)
//!   combination on the simulator;
//! * [`Collectives::execute`] runs it for real on the threaded backend;
//! * [`Collectives::autotune`] picks the fastest algorithm for an
//!   operation and size — the algorithm-selection layer real libraries
//!   get wrong in the paper's tables.
//!
//! Algorithms are [`registry::Alg`] handles from the catalog in
//! `algorithms::registry` — this module contains no per-algorithm
//! knowledge; adding an algorithm is one registration there. Invalid
//! (operation, algorithm) pairs surface as typed
//! [`AlgError::UnsupportedCombination`] results, never panics.

use std::cell::RefCell;
use std::sync::Arc;

use anyhow::Result;

use crate::algorithms::registry::{self, Alg, AlgError, Built, OpKind};
use crate::exec::{ExecReport, ExecRuntime};
use crate::model::{Persona, PersonaName};
use crate::netsim::{Backend, NetError};
use crate::sim::{self, MeasureError, OpShape, RepState, SweepEngine, SweepKey, SweepStats};
use crate::topology::{Cluster, Rank};
use crate::util::Summary;

/// A collective operation request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Bcast { root: Rank, c: u64 },
    Scatter { root: Rank, c: u64 },
    Gather { root: Rank, c: u64 },
    Allgather { c: u64 },
    Alltoall { c: u64 },
}

impl Op {
    pub fn kind(&self) -> OpKind {
        match self {
            Op::Bcast { .. } => OpKind::Bcast,
            Op::Scatter { .. } => OpKind::Scatter,
            Op::Gather { .. } => OpKind::Gather,
            Op::Allgather { .. } => OpKind::Allgather,
            Op::Alltoall { .. } => OpKind::Alltoall,
        }
    }

    pub fn count(&self) -> u64 {
        match self {
            Op::Bcast { c, .. }
            | Op::Scatter { c, .. }
            | Op::Gather { c, .. }
            | Op::Allgather { c }
            | Op::Alltoall { c } => *c,
        }
    }

    /// The same operation shape (kind and root) at a different element
    /// count — the step a count sweep takes between cells.
    pub fn with_count(self, c: u64) -> Op {
        match self {
            Op::Bcast { root, .. } => Op::Bcast { root, c },
            Op::Scatter { root, .. } => Op::Scatter { root, c },
            Op::Gather { root, .. } => Op::Gather { root, c },
            Op::Allgather { .. } => Op::Allgather { c },
            Op::Alltoall { .. } => Op::Alltoall { c },
        }
    }
}

/// One measurement row (matches the paper's table columns).
#[derive(Clone, Debug)]
pub struct Measurement {
    pub algorithm: String,
    pub k: u32,
    pub c: u64,
    pub summary: Summary,
}

/// One per-count autotune winner: the fastest candidate at element
/// count `c` and its measurement. A series of these is the raw material
/// a `tuning::DecisionTable` compresses into breakpoints.
#[derive(Clone, Debug)]
pub struct CountWinner {
    pub c: u64,
    pub alg: Alg,
    pub measurement: Measurement,
}

pub struct Collectives {
    pub cluster: Cluster,
    pub persona: Persona,
    pub reps: usize,
    pub warmup: usize,
    pub seed: u64,
    /// Which simulation backend times the schedules: the analytic
    /// closed-form [`sim::Simulator`] (default) or the event-driven
    /// [`crate::netsim::NetSim`] with its contention scenario.
    pub backend: Backend,
    /// Shared schedule cache: count sweeps (tables, autotune candidate
    /// grids) build each communication structure once and re-cost it per
    /// count (see `sim::sweep`). Keyed by (cluster, op shape, algorithm,
    /// model fingerprint), so one engine may be shared across
    /// `Collectives` instances — even across personas and threads
    /// (`Collectives::with_engine`).
    engine: Arc<SweepEngine>,
    /// Per-instance rep state (thread-local by construction): reused
    /// across cells so the rep loop stays allocation-free.
    state: RefCell<Option<RepState>>,
}

/// Collapse an engine error into the coordinator's error type: build
/// errors pass through unchanged; an engine cache-identity failure
/// (unreachable unless the cache itself is buggy) maps onto
/// [`AlgError::Engine`].
fn engine_err(e: MeasureError<AlgError>) -> AlgError {
    match e {
        MeasureError::Build(e) => e,
        MeasureError::Sim(s) => AlgError::Engine { detail: s.to_string() },
        MeasureError::Net(n) => net_err(n),
    }
}

/// Surface a network-backend refusal (overflow, bad scenario,
/// unsupported combination) as the coordinator's typed error.
fn net_err(e: NetError) -> AlgError {
    AlgError::Backend { detail: e.to_string() }
}

/// The sweep-invariant part of an operation (cache-key component).
fn op_shape(op: Op) -> OpShape {
    match op {
        Op::Bcast { root, .. } => OpShape::Bcast { root },
        Op::Scatter { root, .. } => OpShape::Scatter { root },
        Op::Gather { root, .. } => OpShape::Gather { root },
        Op::Allgather { .. } => OpShape::Allgather,
        Op::Alltoall { .. } => OpShape::Alltoall,
    }
}

impl Collectives {
    pub fn new(cluster: Cluster, persona: PersonaName) -> Self {
        Self::with_engine(cluster, persona, Arc::new(SweepEngine::new()))
    }

    /// Share an existing sweep engine (the cross-table schedule cache):
    /// the model-fingerprinted cache key keeps personas isolated, so any
    /// mix of `Collectives` may share one engine.
    pub fn with_engine(cluster: Cluster, persona: PersonaName, engine: Arc<SweepEngine>) -> Self {
        Self {
            cluster,
            persona: Persona::get(persona),
            reps: sim::DEFAULT_REPS,
            warmup: sim::DEFAULT_WARMUP,
            seed: sim::DEFAULT_SEED,
            backend: Backend::default(),
            engine,
            state: RefCell::new(None),
        }
    }

    /// The shared sweep engine handle.
    pub fn engine(&self) -> &Arc<SweepEngine> {
        &self.engine
    }

    /// Sweep-engine counters (cells measured, schedules built, recosts).
    pub fn sweep_stats(&self) -> SweepStats {
        self.engine.stats()
    }

    /// Compile (op, algorithm) to a schedule plus the persona's native
    /// quirk adjustment (1.0/0.0 for non-native algorithms).
    pub fn schedule(&self, op: Op, alg: &Alg) -> Result<Built, AlgError> {
        alg.build(self.cluster, &self.persona, op)
    }

    /// Simulate (op, algorithm) under the persona's cost model and
    /// return paper-style (avg, min) of the slowest rank.
    ///
    /// Count-invariant algorithms (`cache_id() == Some`) are served
    /// through the sweep engine: the first count for a given (cluster,
    /// op shape, algorithm) builds the schedule, later counts only
    /// re-cost it, so count sweeps and repeated autotune calls share one
    /// cached structure per candidate.
    pub fn run(&self, op: Op, alg: &Alg) -> Result<Measurement, AlgError> {
        let model = self.persona.model;
        let (cell, add, mult) = match alg.cache_id() {
            Some(alg_key) => {
                let key =
                    SweepKey { cluster: self.cluster, op: op_shape(op), alg: alg_key };
                let build = |_| {
                    let built = self.schedule(op, alg)?;
                    // Cacheable algorithms must have neutral quirks
                    // (quirks vary with count; the cache would pin
                    // the first cell's values).
                    debug_assert!(
                        built.quirk_add == 0.0 && built.quirk_mult == 1.0,
                        "non-neutral quirk on cacheable algorithm {}",
                        alg.label()
                    );
                    Ok(built.schedule)
                };
                let cell = match &self.backend {
                    Backend::Analytic => {
                        let mut state = self.state.borrow_mut();
                        self.engine.measure(
                            key,
                            op.count(),
                            &model,
                            self.reps,
                            self.warmup,
                            self.seed,
                            &mut *state,
                            build,
                        )
                    }
                    Backend::Event(sc) => self
                        .engine
                        .measure_series_event(
                            key,
                            std::slice::from_ref(&op.count()),
                            &model,
                            sc,
                            self.reps,
                            self.warmup,
                            self.seed,
                            build,
                        )
                        .map(|mut v| v.pop().expect("one count in, one cell out")),
                }
                .map_err(engine_err)?;
                (cell, 0.0, 1.0)
            }
            None => {
                let built = self.schedule(op, alg)?;
                let cell = match &self.backend {
                    Backend::Analytic => {
                        let mut state = self.state.borrow_mut();
                        self.engine.measure_uncached(
                            &built.schedule,
                            &model,
                            self.reps,
                            self.warmup,
                            self.seed,
                            &mut *state,
                        )
                    }
                    Backend::Event(sc) => self
                        .engine
                        .measure_uncached_event(
                            &built.schedule,
                            &model,
                            sc,
                            self.reps,
                            self.warmup,
                            self.seed,
                        )
                        .map_err(net_err)?,
                };
                (cell, built.quirk_add, built.quirk_mult)
            }
        };
        let adj = |t: f64| t * mult + add;
        Ok(Measurement {
            algorithm: cell.algorithm.to_string(),
            k: alg.k().unwrap_or(self.cluster.lanes),
            c: op.count(),
            summary: Summary {
                avg: adj(cell.summary.avg),
                min: adj(cell.summary.min),
                max: adj(cell.summary.max),
                reps: cell.summary.reps,
            },
        })
    }

    /// Simulate (op shape, algorithm) over a whole count grid with one
    /// engine call — the batched form of [`Collectives::run`]. For
    /// count-invariant algorithms the engine resolves the cached shape
    /// once and walks the grid in a single pass
    /// (`SweepEngine::measure_series`); count-dependent ones (native,
    /// tuned) fall back to a per-count [`Collectives::run`] loop, so the
    /// results are element-for-element bitwise identical to calling
    /// `run` per count in either case. `op`'s own count is ignored —
    /// only its shape (kind, root) matters.
    pub fn run_series(
        &self,
        op: Op,
        counts: &[u64],
        alg: &Alg,
    ) -> Result<Vec<Measurement>, AlgError> {
        let Some(alg_key) = alg.cache_id() else {
            return counts.iter().map(|&c| self.run(op.with_count(c), alg)).collect();
        };
        let model = self.persona.model;
        let key = SweepKey { cluster: self.cluster, op: op_shape(op), alg: alg_key };
        let build = |c| {
            let built = self.schedule(op.with_count(c), alg)?;
            // Cacheable algorithms must have neutral quirks
            // (quirks vary with count; the cache would pin
            // the first cell's values).
            debug_assert!(
                built.quirk_add == 0.0 && built.quirk_mult == 1.0,
                "non-neutral quirk on cacheable algorithm {}",
                alg.label()
            );
            Ok(built.schedule)
        };
        let cells = match &self.backend {
            Backend::Analytic => {
                let mut state = self.state.borrow_mut();
                self.engine.measure_series(
                    key,
                    counts,
                    &model,
                    self.reps,
                    self.warmup,
                    self.seed,
                    &mut state,
                    build,
                )
            }
            Backend::Event(sc) => self.engine.measure_series_event(
                key,
                counts,
                &model,
                sc,
                self.reps,
                self.warmup,
                self.seed,
                build,
            ),
        }
        .map_err(engine_err)?;
        let k = alg.k().unwrap_or(self.cluster.lanes);
        Ok(cells
            .into_iter()
            .zip(counts)
            .map(|(cell, &c)| Measurement {
                algorithm: cell.algorithm.to_string(),
                k,
                c,
                summary: cell.summary,
            })
            .collect())
    }

    /// Execute (op, algorithm) for real on the threaded backend.
    pub fn execute(&self, op: Op, alg: &Alg, rt: &ExecRuntime) -> Result<ExecReport> {
        let built = self.schedule(op, alg)?;
        rt.run(&built.schedule, self.reps, self.warmup)
    }

    /// Pick the fastest algorithm (by simulated average) among the
    /// candidates. This is the coordinator's answer to the paper's
    /// conclusion that native selection "can easily be improved".
    pub fn autotune(&self, op: Op, candidates: &[Alg]) -> Result<(Alg, Measurement), AlgError> {
        let w = self
            .autotune_counts(op, &[op.count()], candidates)?
            .pop()
            .expect("one count in, one winner out");
        Ok((w.alg, w.measurement))
    }

    /// Per-count winners over a whole count grid: for every `c` in
    /// `counts`, the candidate with the lowest simulated average (ties
    /// keep the earlier candidate, so the result is deterministic in
    /// candidate order). The sweep is candidate-major — one
    /// [`Collectives::run_series`] engine call per candidate covers the
    /// whole grid — but winners and values are identical to a per-count
    /// loop: each count still compares candidates in candidate order
    /// with a strict `<`. This is the sweep the `tuning` module
    /// compresses into decision tables.
    pub fn autotune_counts(
        &self,
        op: Op,
        counts: &[u64],
        candidates: &[Alg],
    ) -> Result<Vec<CountWinner>, AlgError> {
        // Candidate sets come from user-facing paths (`--alg` lists,
        // tuning scenarios): an empty one is an input error, not a bug.
        if candidates.is_empty() {
            return Err(AlgError::Engine {
                detail: format!("autotune over an empty candidate set ({})", op.kind()),
            });
        }
        let mut best: Vec<Option<CountWinner>> = counts.iter().map(|_| None).collect();
        for alg in candidates {
            let ms = self.run_series(op, counts, alg)?;
            for ((slot, m), &c) in best.iter_mut().zip(ms).zip(counts) {
                if slot
                    .as_ref()
                    .is_none_or(|b| m.summary.avg < b.measurement.summary.avg)
                {
                    *slot = Some(CountWinner { c, alg: alg.clone(), measurement: m });
                }
            }
        }
        Ok(best.into_iter().map(|w| w.expect("non-empty candidates")).collect())
    }

    /// The registry's default candidate set for this operation.
    pub fn default_candidates(&self, op: Op) -> Vec<Alg> {
        registry::registry().candidates(self.cluster, op.kind())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coll() -> Collectives {
        let mut c = Collectives::new(Cluster::new(4, 4, 2), PersonaName::OpenMpi);
        c.reps = 3;
        c.warmup = 1;
        c
    }

    #[test]
    fn run_all_op_alg_pairs() {
        let c = coll();
        for op in [
            Op::Bcast { root: 0, c: 64 },
            Op::Scatter { root: 0, c: 16 },
            Op::Gather { root: 0, c: 16 },
            Op::Allgather { c: 16 },
            Op::Alltoall { c: 8 },
        ] {
            for alg in c.default_candidates(op) {
                let m = c.run(op, &alg).unwrap_or_else(|e| panic!("{op:?} {alg:?}: {e}"));
                assert!(m.summary.avg > 0.0, "{op:?} {alg:?}");
                assert!(m.summary.min <= m.summary.avg);
            }
        }
    }

    #[test]
    fn every_unsupported_pair_is_a_typed_error() {
        // Exhaustive: no user-reachable (op, algorithm) combination may
        // panic — unsupported ones must report UnsupportedCombination.
        let c = coll();
        for entry in registry::registry().entries() {
            let alg = entry.instantiate(2);
            for kind in OpKind::ALL {
                let op = kind.op(8);
                if entry.supports(kind) {
                    c.run(op, &alg).unwrap_or_else(|e| panic!("{kind} {alg:?}: {e}"));
                } else {
                    let err = c.run(op, &alg).unwrap_err();
                    assert!(
                        matches!(err, AlgError::UnsupportedCombination { .. }),
                        "{kind} {alg:?}: {err}"
                    );
                }
            }
        }
    }

    #[test]
    fn native_quirks_applied() {
        let mut c = Collectives::new(Cluster::hydra(2), PersonaName::IntelMpi);
        c.reps = 2;
        c.warmup = 0;
        let m = c.run(Op::Bcast { root: 0, c: 1 }, &registry::native()).unwrap();
        assert!(m.summary.avg > 900.0, "Intel small-bcast floor: {}", m.summary.avg);
    }

    #[test]
    fn autotune_beats_native_where_paper_says_so() {
        // Table 12: full-lane bcast ≫ native MPI_Bcast at c = 1e6.
        let mut c = Collectives::new(Cluster::hydra(2), PersonaName::OpenMpi);
        c.reps = 2;
        c.warmup = 0;
        let op = Op::Bcast { root: 0, c: 1_000_000 };
        let native = c.run(op, &registry::native()).unwrap();
        let (best_alg, best) = c.autotune(op, &c.default_candidates(op)).unwrap();
        assert!(best.summary.avg < native.summary.avg, "autotune should beat native");
        assert!(
            matches!(best_alg.name(), "fulllane" | "kported"),
            "{best_alg:?}"
        );
    }

    #[test]
    fn autotune_counts_matches_per_count_autotune() {
        // The grid form must agree with N single-count autotunes — the
        // refactor only batches, it must not change winners or values.
        let c = coll();
        let counts = [1u64, 600, 100_000];
        let op = Op::Bcast { root: 0, c: 1 };
        let cands = c.default_candidates(op);
        let winners = c.autotune_counts(op, &counts, &cands).unwrap();
        assert_eq!(winners.len(), counts.len());
        for (w, &count) in winners.iter().zip(&counts) {
            assert_eq!(w.c, count);
            let (alg, m) = c.autotune(op.with_count(count), &cands).unwrap();
            assert_eq!((w.alg.name(), w.alg.k()), (alg.name(), alg.k()), "c={count}");
            assert_eq!(w.measurement.summary, m.summary, "c={count}");
        }
    }

    #[test]
    fn with_count_preserves_the_shape() {
        let op = Op::Scatter { root: 3, c: 8 };
        assert_eq!(op.with_count(99), Op::Scatter { root: 3, c: 99 });
        assert_eq!(Op::Alltoall { c: 1 }.with_count(7), Op::Alltoall { c: 7 });
    }

    #[test]
    fn bruck_rejected_for_bcast_without_panic() {
        let err = coll().run(Op::Bcast { root: 0, c: 4 }, &registry::bruck(2)).unwrap_err();
        assert!(matches!(err, AlgError::UnsupportedCombination { .. }), "{err}");
        let msg = err.to_string();
        assert!(msg.starts_with("bruck does not support bcast; supported:"), "{msg}");
        assert!(msg.contains("klane2p"), "registry-driven candidate list: {msg}");
    }

    #[test]
    fn count_sweep_shares_one_cached_schedule() {
        let c = coll();
        for count in [64u64, 6000, 64, 100_000] {
            c.run(Op::Bcast { root: 0, c: count }, &registry::fulllane()).unwrap();
        }
        let st = c.sweep_stats();
        assert_eq!(st.schedules_built, 1, "{st:?}");
        assert_eq!(st.cells, 4, "{st:?}");
        assert!(st.recosts >= 2, "{st:?}");
    }

    #[test]
    fn cached_run_equals_per_cell_rebuild() {
        let c = coll();
        let op = Op::Scatter { root: 0, c: 16 };
        let alg = registry::klane(2);
        c.run(Op::Scatter { root: 0, c: 869 }, &alg).unwrap(); // prime the cache
        let cached = c.run(op, &alg).unwrap(); // served by recost
        let fresh = sim::measure(
            &c.schedule(op, &alg).unwrap().schedule,
            &c.persona.model,
            c.reps,
            c.warmup,
            c.seed,
        );
        assert_eq!(cached.summary, fresh);
    }

    #[test]
    fn native_runs_bypass_the_shape_cache() {
        let c = coll();
        c.run(Op::Bcast { root: 0, c: 16 }, &registry::native()).unwrap();
        c.run(Op::Bcast { root: 0, c: 1_000_000 }, &registry::native()).unwrap();
        let st = c.sweep_stats();
        assert_eq!(st.schedules_built, 2, "{st:?}");
        assert_eq!(st.recosts + st.cache_hits, 0, "{st:?}");
    }

    #[test]
    fn shared_engine_reused_across_collectives() {
        // Two Collectives over one engine: the second run of the same
        // sweep is served entirely from the first one's cached shape.
        let engine = Arc::new(SweepEngine::new());
        let mk = || {
            let mut c = Collectives::with_engine(
                Cluster::new(3, 4, 2),
                PersonaName::OpenMpi,
                engine.clone(),
            );
            c.reps = 2;
            c.warmup = 0;
            c
        };
        let op = Op::Bcast { root: 0, c: 64 };
        let a = mk().run(op, &registry::fulllane()).unwrap();
        let b = mk().run(op, &registry::fulllane()).unwrap();
        assert_eq!(a.summary, b.summary, "deterministic across sharers");
        let st = engine.stats();
        assert_eq!(st.schedules_built, 1, "{st:?}");
        assert_eq!(st.cache_hits, 1, "{st:?}");
    }

    #[test]
    fn event_backend_run_matches_fresh_netsim() {
        use crate::netsim::{NetSim, Scenario};
        let mut c = coll();
        c.backend = Backend::Event(Scenario::contention_free());
        let op = Op::Bcast { root: 0, c: 64 };
        let alg = registry::klane(2);
        let m = c.run(op, &alg).unwrap();
        let s = c.schedule(op, &alg).unwrap().schedule;
        let net =
            NetSim::new(&s, &c.persona.model, &Scenario::contention_free()).unwrap();
        let mut st = net.new_state();
        let fresh = sim::measure_backend(&net, &mut st, c.reps, c.warmup, c.seed).unwrap();
        assert_eq!(m.summary, fresh);
    }

    #[test]
    fn event_backend_series_matches_per_count_runs() {
        use crate::netsim::Scenario;
        let mut c = coll();
        c.backend = Backend::Event(Scenario::contended());
        let op = Op::Scatter { root: 0, c: 1 };
        let alg = registry::fulllane();
        let counts = [1u64, 100, 10_000];
        let series = c.run_series(op, &counts, &alg).unwrap();
        for (m, &count) in series.iter().zip(&counts) {
            let single = c.run(op.with_count(count), &alg).unwrap();
            assert_eq!(m.summary, single.summary, "c={count}");
        }
    }

    #[test]
    fn event_backend_applies_native_quirks() {
        use crate::netsim::Scenario;
        let mut c = Collectives::new(Cluster::hydra(2), PersonaName::IntelMpi);
        c.reps = 2;
        c.warmup = 0;
        c.backend = Backend::Event(Scenario::contention_free());
        let m = c.run(Op::Bcast { root: 0, c: 1 }, &registry::native()).unwrap();
        assert!(m.summary.avg > 900.0, "Intel small-bcast floor: {}", m.summary.avg);
    }

    #[test]
    fn event_backend_overflow_is_a_typed_error() {
        use crate::netsim::Scenario;
        let mut c = Collectives::new(Cluster::new(3, 4, 2), PersonaName::OpenMpi);
        c.reps = 1;
        c.warmup = 0;
        let mut sc = Scenario::contention_free();
        sc.queue_capacity = Some(0);
        c.backend = Backend::Event(sc);
        let err = c.run(Op::Alltoall { c: 10_000 }, &registry::fulllane()).unwrap_err();
        assert!(matches!(err, AlgError::Backend { .. }), "{err}");
        assert!(err.to_string().contains("queue overflow"), "{err}");
    }
}

//! Contention-aware discrete-event network backend.
//!
//! The analytic engine (`sim::engine`) prices a schedule with a
//! closed-form reservation model: every port is a pool of
//! earliest-free servers and a transfer's wire time is reserved the
//! instant its preconditions are met, even if that instant is in the
//! future. That is exact and fast, but it cannot express *dynamic*
//! effects: finite switch buffers, background traffic stealing lane
//! time, or slow nodes stretching their posting overheads. This module
//! is the second backend behind [`crate::sim::SimBackend`]: the same
//! `Schedule` is compiled into the same CSR round-program layout, but
//! execution is a discrete-event simulation over explicit FIFO port
//! queues.
//!
//! ## Event model
//!
//! Messages are flow-level units (one event per message per hop, not
//! per packet). An off-node transfer travels store-and-forward through
//! two ports: the source node's **net-out** port (one server per
//! physical lane), then — one wire latency `alpha_net` after its
//! egress service *starts*, i.e. cut-through, exactly the analytic
//! `in_ready` — the destination node's **net-in** port. On-node
//! transfers serialize on the node's **bus** port (`bus_servers`
//! servers) and arrive `alpha_shm` after service end. Posting
//! overheads (`o_post`, `o_match`, `node_collective_call`, jitter) and
//! the eager/rendezvous protocol follow the analytic engine
//! expression-for-expression, so on a contention-free scenario the two
//! backends differ only in service *order* under port contention: the
//! analytic model reserves earliest-free at post time, this backend
//! queues FIFO-by-ready-time. Both disciplines are work-conserving,
//! which is what bounds the cross-validation tolerance
//! (`rust/tests/backend_crossval.rs`, DESIGN.md §Network backend).
//!
//! ## Determinism
//!
//! One `BinaryHeap` event queue with an insertion-sequence tie-break
//! ([`queue`]), two seeded [`Prng`] streams (jitter mirrors the
//! engine's; tenants get an independent stream so enabling them does
//! not perturb jitter), no wall clock, no global state. A run is a
//! pure function of (schedule, model, scenario, seed).
//!
//! ## Scenario knobs
//!
//! [`Scenario`] adds what the paper's testbed could not isolate:
//! drop-tail port queues with finite capacity, per-node background
//! tenant flows (Poisson arrivals, exponential sizes), and straggler
//! nodes whose CPU-side overheads are scaled by a slowdown factor.
//! The knobs deliberately live *outside* [`CostModel`] so the sweep
//! cache's model fingerprint (and every analytic artifact) is
//! untouched.

mod queue;

use std::collections::VecDeque;
use std::fmt;

use crate::model::CostModel;
use crate::schedule::{CountSizer, Schedule};
use crate::sim::{SimBackend, SimResult};
use crate::sim::trace::Span;
use crate::util::Prng;

use queue::{EvKind, EventQueue, Job, JobId};

/// Typed event-backend failures. CLI-reachable paths surface these as
/// exit-1 messages (`rust/tests/cli_errors.rs`); the sweep layer wraps
/// them in `sweep::MeasureError::Net` the way `SimError` rides
/// `MeasureError::Sim`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetError {
    /// A *collective* message hit a full drop-tail queue. Collectives
    /// have no retransmit layer here, so a dropped message would hang
    /// the schedule — the run aborts with the drop site instead.
    /// (Tenant messages are dropped silently, as real best-effort
    /// background traffic would be.)
    QueueOverflow { node: u32, port: &'static str, capacity: u32 },
    /// The scenario's knobs are self-contradictory or non-physical.
    InvalidScenario { reason: &'static str },
    /// The scenario asks for something this cluster shape cannot
    /// express (e.g. tenant traffic with no inter-node network).
    BackendUnsupported { what: &'static str },
}

impl fmt::Display for NetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NetError::QueueOverflow { node, port, capacity } => write!(
                f,
                "event backend: drop-tail queue overflow on node {node} {port} \
                 (capacity {capacity}): a collective message was dropped; raise \
                 --queue-capacity or reduce background load"
            ),
            NetError::InvalidScenario { reason } => {
                write!(f, "event backend: invalid scenario: {reason}")
            }
            NetError::BackendUnsupported { what } => {
                write!(f, "event backend does not support {what}")
            }
        }
    }
}

impl std::error::Error for NetError {}

/// Contention scenario for one event-backend run. All knobs off (the
/// [`Scenario::contention_free`] default) reproduces the analytic
/// model's assumptions: infinite buffers, idle network, uniform nodes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scenario {
    /// Drop-tail waiting-room capacity per port (jobs in service not
    /// counted). `None` = unbounded.
    pub queue_capacity: Option<u32>,
    /// Background tenant flows per node. Each flow injects messages at
    /// its node's net-out port with exponential inter-arrival gaps.
    pub tenant_flows: u32,
    /// Mean inter-arrival gap per tenant flow (µs).
    pub tenant_gap_us: f64,
    /// Mean tenant message size (bytes, exponentially distributed).
    pub tenant_bytes: f64,
    /// The first `straggler_nodes` nodes are stragglers.
    pub straggler_nodes: u32,
    /// CPU-side slowdown multiplier (≥ 1.0) applied to straggler
    /// ranks' `o_post`, `o_match`, `node_collective_call`, and jitter.
    pub straggler_factor: f64,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario::contention_free()
    }
}

impl Scenario {
    /// Infinite buffers, no tenants, no stragglers — the scenario the
    /// analytic model prices, used by `backend_crossval.rs`.
    pub fn contention_free() -> Scenario {
        Scenario {
            queue_capacity: None,
            tenant_flows: 0,
            tenant_gap_us: 0.0,
            tenant_bytes: 0.0,
            straggler_nodes: 0,
            straggler_factor: 1.0,
        }
    }

    /// The canned contended scenario behind the `contention` sweep
    /// preset: moderate tenant load, a couple of stragglers, finite
    /// (but generous) buffers.
    pub fn contended() -> Scenario {
        Scenario {
            queue_capacity: Some(64),
            tenant_flows: 4,
            tenant_gap_us: 50.0,
            tenant_bytes: 16_384.0,
            straggler_nodes: 2,
            straggler_factor: 1.5,
        }
    }

    /// True iff every knob is at its analytic-equivalent setting.
    pub fn is_contention_free(&self) -> bool {
        self.queue_capacity.is_none()
            && self.tenant_flows == 0
            && (self.straggler_nodes == 0 || self.straggler_factor == 1.0)
    }

    /// Reject non-physical knobs with a typed error (CLI surfaces the
    /// reason verbatim).
    pub fn validate(&self) -> Result<(), NetError> {
        let bad = |reason| Err(NetError::InvalidScenario { reason });
        if !self.straggler_factor.is_finite() || self.straggler_factor < 1.0 {
            return bad("straggler factor must be a finite slowdown multiplier >= 1.0");
        }
        if self.tenant_flows > 0 {
            if !self.tenant_gap_us.is_finite() || self.tenant_gap_us <= 0.0 {
                return bad("tenant gap must be a finite positive mean inter-arrival (us)");
            }
            if !self.tenant_bytes.is_finite() || self.tenant_bytes <= 0.0 {
                return bad("tenant bytes must be a finite positive mean message size");
            }
        }
        Ok(())
    }

    /// Canonical knob listing for artifact fingerprints (`f64` Display
    /// is shortest-round-trip, so this is deterministic).
    pub fn key_text(&self) -> String {
        format!(
            "qcap={},tenants={},gap={},bytes={},stragglers={},factor={}",
            match self.queue_capacity {
                Some(c) => c.to_string(),
                None => "inf".to_string(),
            },
            self.tenant_flows,
            self.tenant_gap_us,
            self.tenant_bytes,
            self.straggler_nodes,
            self.straggler_factor
        )
    }
}

/// Which backend measures a cell, with the event backend's scenario
/// riding along. `RunConfig`, `Collectives`, and the CLI all carry
/// this; the analytic path is the default everywhere.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum Backend {
    #[default]
    Analytic,
    Event(Scenario),
}

impl Backend {
    pub fn kind(&self) -> BackendKind {
        match self {
            Backend::Analytic => BackendKind::Analytic,
            Backend::Event(_) => BackendKind::Event,
        }
    }

    /// Full identity text for shard fingerprints: the kind plus, for
    /// the event backend, every scenario knob (different knobs measure
    /// different numbers, so they must never merge).
    pub fn fingerprint_text(&self) -> String {
        match self {
            Backend::Analytic => "analytic".to_string(),
            Backend::Event(sc) => format!("event({})", sc.key_text()),
        }
    }
}

/// Scenario-free backend tag — what tuned books record (a book tuned
/// under the event backend must not silently mix with analytic
/// shards; the tuning path always uses the contention-free scenario,
/// so the tag alone identifies it).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendKind {
    #[default]
    Analytic,
    Event,
}

impl BackendKind {
    pub fn key(&self) -> &'static str {
        match self {
            BackendKind::Analytic => "analytic",
            BackendKind::Event => "event",
        }
    }

    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "analytic" => Some(BackendKind::Analytic),
            "event" => Some(BackendKind::Event),
            _ => None,
        }
    }
}

// ---- port identity -----------------------------------------------------

const PORTS_PER_NODE: u32 = 3;
const NET_OUT: u32 = 0;
const NET_IN: u32 = 1;
const BUS: u32 = 2;

#[inline]
fn port_id(node: u32, kind: u32) -> u32 {
    node * PORTS_PER_NODE + kind
}

#[inline]
fn port_kind(port: u32) -> u32 {
    port % PORTS_PER_NODE
}

#[inline]
fn port_name(port: u32) -> &'static str {
    match port_kind(port) {
        NET_OUT => "net-out",
        NET_IN => "net-in",
        _ => "bus",
    }
}

// ---- tracing -----------------------------------------------------------

/// Per-event trace kinds (`mlane trace --backend event`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetEventKind {
    /// A job reached a port (may start service immediately).
    Enqueue,
    /// A port server started serializing a job.
    Dequeue,
    /// A collective message fully arrived at its destination rank.
    Deliver,
    /// A job hit a full drop-tail queue.
    Drop,
}

impl NetEventKind {
    pub fn label(&self) -> &'static str {
        match self {
            NetEventKind::Enqueue => "enqueue",
            NetEventKind::Dequeue => "dequeue",
            NetEventKind::Deliver => "deliver",
            NetEventKind::Drop => "drop",
        }
    }
}

/// One queue-level event captured by a traced run. `src`/`dst` are
/// ranks for collective messages and *nodes* for tenant messages
/// (`tenant` disambiguates). `depth` is the port's waiting-queue
/// length at the instant (after the event's own effect for `Enqueue`
/// refusals, before service for `Dequeue`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetEvent {
    pub t: f64,
    pub kind: NetEventKind,
    pub port: &'static str,
    pub node: u32,
    pub depth: u32,
    pub src: u32,
    pub dst: u32,
    pub bytes: u64,
    pub tenant: bool,
}

#[derive(Debug, Default)]
struct TraceBuf {
    spans: Vec<Span>,
    events: Vec<NetEvent>,
}

// ---- the simulator -----------------------------------------------------

/// Count-invariant per-transfer shape (mirrors the analytic engine's).
#[derive(Clone, Copy, Debug)]
struct XferShape {
    src: u32,
    dst: u32,
    offnode: bool,
    src_node: u32,
    dst_node: u32,
}

#[derive(Clone, Copy)]
struct XferState {
    send_posted: f64, // NaN = not yet
    recv_posted: f64,
    arrived: f64,
    started: bool,
}

const XFER_INIT: XferState =
    XferState { send_posted: f64::NAN, recv_posted: f64::NAN, arrived: f64::NAN, started: false };

/// A FIFO multi-server port: `busy` servers in service plus a
/// drop-tail waiting room.
#[derive(Debug, Default)]
struct Port {
    busy: u32,
    waiting: VecDeque<Job>,
}

/// Decorrelates the tenant stream from the jitter stream.
const TENANT_SEED_XOR: u64 = 0x7E4A_17B6_5D3C_29F1;

/// Immutable event-simulation input, reusable across repetitions and
/// (via [`NetSim::recost_count`]) across sweep counts.
pub struct NetSim {
    p: u32,
    nodes: u32,
    model: CostModel,
    scenario: Scenario,
    shapes: Vec<XferShape>,
    bytes: Vec<u64>,
    dur: Vec<f64>,
    eager: Vec<bool>,
    beta: Vec<f64>,
    eager_limit: Vec<u64>,
    sizer: CountSizer,
    rank_off: Vec<u32>,
    slot_hinted: Vec<bool>,
    send_off: Vec<u32>,
    send_ids: Vec<u32>,
    recv_off: Vec<u32>,
    recv_ids: Vec<u32>,
    /// Straggler slowdown per rank (1.0 for healthy nodes).
    rank_factor: Vec<f64>,
    /// Ranks with a non-empty program (run-loop termination target).
    participants: u32,
}

/// Mutable per-repetition state; reset-in-place keeps allocations
/// across the rep loop (the event backend is not the zero-alloc series
/// path, but the rep loop itself should not thrash the allocator).
pub struct NetState {
    q: EventQueue,
    ports: Vec<Port>,
    rank_pos: Vec<u32>,
    rank_outstanding: Vec<u32>,
    rank_clock: Vec<f64>,
    xs: Vec<XferState>,
    rng: Prng,
    trng: Prng,
    finished: u32,
    events: u64,
    /// Tenant messages dropped by full queues this rep (best-effort
    /// traffic; informational).
    pub tenants_dropped: u64,
    trace: Option<TraceBuf>,
}

impl NetSim {
    /// Compile a schedule for the event backend. Validates the
    /// scenario up front so every later `run_into` failure is a
    /// genuine dynamic outcome (queue overflow), not a knob typo.
    pub fn new(
        schedule: &Schedule,
        model: &CostModel,
        scenario: &Scenario,
    ) -> Result<NetSim, NetError> {
        scenario.validate()?;
        let cl = schedule.cluster;
        if scenario.tenant_flows > 0 && cl.nodes < 2 {
            return Err(NetError::BackendUnsupported {
                what: "tenant traffic on a single-node cluster (no inter-node lanes to contend on)",
            });
        }
        let p = schedule.p();
        let n = schedule.num_transfers();
        let mut shapes = Vec::with_capacity(n);
        let mut bytes = Vec::with_capacity(n);
        let mut dur = Vec::with_capacity(n);
        let mut eager = Vec::with_capacity(n);
        let mut beta = Vec::with_capacity(n);
        let mut eager_limit = Vec::with_capacity(n);

        // Per-rank round programs, then CSR-flattened — the same
        // construction as `sim::engine::Simulator::new` so both
        // backends walk identical programs.
        #[derive(Clone, Default)]
        struct RoundOps {
            round: u32,
            sends: Vec<u32>,
            recvs: Vec<u32>,
            hinted: bool,
        }
        let mut progs: Vec<Vec<RoundOps>> = vec![Vec::new(); p as usize];
        let mut push_op = |rank: u32, round: u32, id: u32, is_send: bool, hinted: bool| {
            let prog = &mut progs[rank as usize];
            if prog.last().map(|r| r.round) != Some(round) {
                prog.push(RoundOps { round, hinted, ..Default::default() });
            }
            let ops = prog.last_mut().unwrap();
            ops.hinted |= hinted;
            if is_send {
                ops.sends.push(id);
            } else {
                ops.recvs.push(id);
            }
        };

        for (ri, round) in schedule.rounds.iter().enumerate() {
            let hinted = round.node_phase.is_some();
            for t in &round.transfers {
                let id = shapes.len() as u32;
                let offnode = !cl.same_node(t.src, t.dst);
                let (b, lim) = if offnode {
                    (model.beta_net, model.eager_net)
                } else {
                    (model.beta_shm, model.eager_shm)
                };
                shapes.push(XferShape {
                    src: t.src,
                    dst: t.dst,
                    offnode,
                    src_node: cl.node_of(t.src),
                    dst_node: cl.node_of(t.dst),
                });
                bytes.push(t.bytes);
                dur.push(t.bytes as f64 * b);
                eager.push(t.bytes <= lim);
                beta.push(b);
                eager_limit.push(lim);
                push_op(t.src, ri as u32, id, true, hinted);
                push_op(t.dst, ri as u32, id, false, hinted);
            }
        }

        let slots: usize = progs.iter().map(|pr| pr.len()).sum();
        let mut rank_off = Vec::with_capacity(p as usize + 1);
        let mut slot_hinted = Vec::with_capacity(slots);
        let mut send_off = Vec::with_capacity(slots + 1);
        let mut recv_off = Vec::with_capacity(slots + 1);
        let mut send_ids = Vec::new();
        let mut recv_ids = Vec::new();
        rank_off.push(0u32);
        send_off.push(0u32);
        recv_off.push(0u32);
        for prog in &progs {
            for ops in prog {
                slot_hinted.push(ops.hinted);
                send_ids.extend_from_slice(&ops.sends);
                recv_ids.extend_from_slice(&ops.recvs);
                send_off.push(send_ids.len() as u32);
                recv_off.push(recv_ids.len() as u32);
            }
            rank_off.push(slot_hinted.len() as u32);
        }

        let rank_factor: Vec<f64> = (0..p)
            .map(|r| {
                if cl.node_of(r) < scenario.straggler_nodes {
                    scenario.straggler_factor
                } else {
                    1.0
                }
            })
            .collect();
        let participants =
            (0..p as usize).filter(|&r| rank_off[r + 1] > rank_off[r]).count() as u32;

        Ok(NetSim {
            p,
            nodes: cl.nodes,
            model: *model,
            scenario: *scenario,
            shapes,
            bytes,
            dur,
            eager,
            beta,
            eager_limit,
            sizer: schedule.count_sizer(),
            rank_off,
            slot_hinted,
            send_off,
            send_ids,
            recv_off,
            recv_ids,
            rank_factor,
            participants,
        })
    }

    pub fn num_xfers(&self) -> usize {
        self.shapes.len()
    }

    pub fn model(&self) -> &CostModel {
        &self.model
    }

    pub fn scenario(&self) -> &Scenario {
        &self.scenario
    }

    /// Schedule-free recost to element count `c` — the event mirror of
    /// `Simulator::recost_count`, same two flat passes.
    pub fn recost_count(&mut self, c: u64) {
        self.sizer.resize_count_into(c, &mut self.bytes);
        for i in 0..self.bytes.len() {
            let b = self.bytes[i];
            self.dur[i] = b as f64 * self.beta[i];
            self.eager[i] = b <= self.eager_limit[i];
        }
    }

    /// Allocate a reusable per-repetition state.
    pub fn new_state(&self) -> NetState {
        let nports = (self.nodes * PORTS_PER_NODE) as usize;
        NetState {
            q: EventQueue::new(),
            ports: (0..nports).map(|_| Port::default()).collect(),
            rank_pos: vec![0; self.p as usize],
            rank_outstanding: vec![0; self.p as usize],
            rank_clock: vec![0.0; self.p as usize],
            xs: vec![XFER_INIT; self.shapes.len()],
            rng: Prng::new(0),
            trng: Prng::new(0),
            finished: 0,
            events: 0,
            tenants_dropped: 0,
            trace: None,
        }
    }

    fn reset(&self, st: &mut NetState, seed: u64) {
        st.q.clear();
        for p in &mut st.ports {
            p.busy = 0;
            p.waiting.clear();
        }
        st.rank_pos.iter_mut().for_each(|x| *x = 0);
        st.rank_outstanding.iter_mut().for_each(|x| *x = 0);
        st.rank_clock.iter_mut().for_each(|x| *x = 0.0);
        st.xs.iter_mut().for_each(|x| *x = XFER_INIT);
        st.rng = Prng::new(seed);
        st.trng = Prng::new(seed ^ TENANT_SEED_XOR);
        st.finished = 0;
        st.events = 0;
        st.tenants_dropped = 0;
        if let Some(t) = &mut st.trace {
            t.spans.clear();
            t.events.clear();
        }
    }

    /// Runaway guard: tenant streams self-re-arm, so a pathological
    /// gap/makespan combination could generate unbounded events. The
    /// budget is far above any legitimate run (≤ ~6 events per
    /// transfer plus generous tenant slack).
    fn event_budget(&self) -> u64 {
        10_000_000 + 64 * self.shapes.len() as u64
    }

    /// Run one repetition reusing `st`.
    pub fn run_into(&self, st: &mut NetState, seed: u64) -> Result<SimResult, NetError> {
        self.reset(st, seed);

        for r in 0..self.p as usize {
            if self.rank_off[r + 1] > self.rank_off[r] {
                st.q.push(0.0, EvKind::Post { rank: r as u32 });
            }
        }
        if self.scenario.tenant_flows > 0 {
            for node in 0..self.nodes {
                for _ in 0..self.scenario.tenant_flows {
                    let t = st.trng.exp(self.scenario.tenant_gap_us);
                    st.q.push(t, EvKind::Tenant { node });
                }
            }
        }

        let budget = self.event_budget();
        // Terminate on collective completion, not heap exhaustion:
        // tenant streams never drain on their own. Leftover events die
        // with the reset.
        while st.finished < self.participants {
            let Some(ev) = st.q.pop() else { break };
            st.events += 1;
            if st.events > budget {
                return Err(NetError::InvalidScenario {
                    reason: "event budget exhausted (tenant rate far exceeds what this \
                             schedule can absorb)",
                });
            }
            match ev.kind {
                EvKind::Post { rank } => self.do_post(st, rank, ev.t),
                EvKind::Ready { xfer } => self.enqueue_xfer(st, xfer, ev.t)?,
                EvKind::Forward { job } => self.forward(st, job, ev.t)?,
                EvKind::SvcDone { port, job } => self.svc_done(st, port, job, ev.t),
                EvKind::Deliver { xfer } => self.do_arrive(st, xfer, ev.t),
                EvKind::Tenant { node } => self.tenant_arrival(st, node, ev.t)?,
            }
        }

        let makespan = st.rank_clock.iter().copied().fold(0.0f64, f64::max);
        Ok(SimResult { makespan, events: st.events })
    }

    /// Run one repetition on fresh state.
    pub fn run(&self, seed: u64) -> Result<SimResult, NetError> {
        let mut st = self.new_state();
        self.run_into(&mut st, seed)
    }

    /// Run one repetition recording wire spans and queue events.
    pub fn run_traced(
        &self,
        seed: u64,
    ) -> Result<(SimResult, Vec<Span>, Vec<NetEvent>), NetError> {
        let mut st = self.new_state();
        st.trace = Some(TraceBuf::default());
        let r = self.run_into(&mut st, seed)?;
        let buf = st.trace.take().expect("trace buffer");
        Ok((r, buf.spans, buf.events))
    }

    // ---- event handlers (CPU side mirrors sim::engine) -----------------

    fn do_post(&self, st: &mut NetState, rank: u32, now: f64) {
        let m = &self.model;
        let r = rank as usize;
        let f = self.rank_factor[r];
        let slot = (self.rank_off[r] + st.rank_pos[r]) as usize;
        let sends =
            &self.send_ids[self.send_off[slot] as usize..self.send_off[slot + 1] as usize];
        let recvs =
            &self.recv_ids[self.recv_off[slot] as usize..self.recv_off[slot + 1] as usize];
        let mut clock = now;
        if self.slot_hinted[slot] {
            clock += m.node_collective_call * f;
        }
        let jitter = |st: &mut NetState| {
            if m.jitter_mean > 0.0 {
                st.rng.exp(m.jitter_mean * f)
            } else {
                0.0
            }
        };
        // +1 posting token, exactly as in the analytic engine: ops may
        // complete synchronously mid-post; the token makes advance()
        // fire once, after the whole round is posted.
        st.rank_outstanding[r] = (sends.len() + recvs.len()) as u32 + 1;

        for &x in recvs {
            clock += m.o_post * f + jitter(st);
            st.xs[x as usize].recv_posted = clock;
            self.try_ready(st, x);
            self.try_complete_recv(st, x, clock);
        }
        for &x in sends {
            clock += m.o_post * f + jitter(st);
            st.xs[x as usize].send_posted = clock;
            let eager = self.eager[x as usize];
            self.try_ready(st, x);
            if eager {
                self.op_done(st, self.shapes[x as usize].src, clock);
            }
        }
        if clock > st.rank_clock[r] {
            st.rank_clock[r] = clock;
        }
        self.op_done(st, rank, clock);
    }

    /// Schedule the transfer's port enqueue once its protocol
    /// preconditions hold (eager: send posted; rendezvous: both
    /// posted) — the event analog of the engine's `try_start`.
    fn try_ready(&self, st: &mut NetState, x: u32) {
        let xi = x as usize;
        let xst = st.xs[xi];
        if xst.started {
            return;
        }
        let sp = xst.send_posted;
        if sp.is_nan() {
            return;
        }
        let ready = if self.eager[xi] {
            sp
        } else {
            let rp = xst.recv_posted;
            if rp.is_nan() {
                return;
            }
            sp.max(rp)
        };
        st.xs[xi].started = true;
        st.q.push(ready, EvKind::Ready { xfer: x });
    }

    fn enqueue_xfer(&self, st: &mut NetState, x: u32, now: f64) -> Result<(), NetError> {
        let sh = self.shapes[x as usize];
        let job = Job { id: JobId::Xfer(x), dur: self.dur[x as usize], bytes: self.bytes[x as usize] };
        let port = if sh.offnode {
            port_id(sh.src_node, NET_OUT)
        } else {
            port_id(sh.src_node, BUS)
        };
        self.enqueue(st, port, job, now)
    }

    /// Put a job on a port: start service if a server is free, else
    /// wait — or drop against the capacity limit.
    fn enqueue(&self, st: &mut NetState, port: u32, job: Job, now: f64) -> Result<(), NetError> {
        let pi = port as usize;
        let depth = st.ports[pi].waiting.len() as u32;
        self.note(st, now, NetEventKind::Enqueue, port, depth, job);
        if st.ports[pi].busy < self.servers(port) {
            st.ports[pi].busy += 1;
            self.start_service(st, port, job, now);
            return Ok(());
        }
        if let Some(cap) = self.scenario.queue_capacity {
            if depth >= cap {
                self.note(st, now, NetEventKind::Drop, port, depth, job);
                return match job.id {
                    JobId::Xfer(_) => Err(NetError::QueueOverflow {
                        node: port / PORTS_PER_NODE,
                        port: port_name(port),
                        capacity: cap,
                    }),
                    JobId::Tenant { .. } => {
                        st.tenants_dropped += 1;
                        Ok(())
                    }
                };
            }
        }
        st.ports[pi].waiting.push_back(job);
        Ok(())
    }

    fn start_service(&self, st: &mut NetState, port: u32, job: Job, now: f64) {
        let depth = st.ports[port as usize].waiting.len() as u32;
        self.note(st, now, NetEventKind::Dequeue, port, depth, job);
        st.q.push(now + job.dur, EvKind::SvcDone { port, job });
        match port_kind(port) {
            NET_OUT => {
                if let JobId::Xfer(x) = job.id {
                    self.span(st, x, now, now + job.dur, true);
                }
                // Cut-through: the head reaches the far side one wire
                // latency after serialization starts (the analytic
                // model's `in_ready = start_e + alpha_net`).
                st.q.push(now + self.model.alpha_net, EvKind::Forward { job });
            }
            BUS => {
                if let JobId::Xfer(x) = job.id {
                    self.span(st, x, now, now + job.dur, false);
                }
            }
            _ => {}
        }
    }

    fn forward(&self, st: &mut NetState, job: Job, now: f64) -> Result<(), NetError> {
        let dst_node = match job.id {
            JobId::Xfer(x) => self.shapes[x as usize].dst_node,
            JobId::Tenant { dst_node, .. } => dst_node,
        };
        self.enqueue(st, port_id(dst_node, NET_IN), job, now)
    }

    fn svc_done(&self, st: &mut NetState, port: u32, job: Job, now: f64) {
        let pi = port as usize;
        st.ports[pi].busy -= 1;
        if let Some(next) = st.ports[pi].waiting.pop_front() {
            st.ports[pi].busy += 1;
            self.start_service(st, port, next, now);
        }
        match (port_kind(port), job.id) {
            // Arrival = ingress service end (the engine's `end_i`).
            (NET_IN, JobId::Xfer(x)) => st.q.push(now, EvKind::Deliver { xfer: x }),
            // On-node arrival = bus service end + alpha_shm.
            (BUS, JobId::Xfer(x)) => {
                st.q.push(now + self.model.alpha_shm, EvKind::Deliver { xfer: x })
            }
            _ => {}
        }
    }

    fn do_arrive(&self, st: &mut NetState, x: u32, now: f64) {
        let xi = x as usize;
        let sh = self.shapes[xi];
        if st.trace.is_some() {
            let port = if sh.offnode {
                port_id(sh.dst_node, NET_IN)
            } else {
                port_id(sh.src_node, BUS)
            };
            let depth = st.ports[port as usize].waiting.len() as u32;
            let job = Job { id: JobId::Xfer(x), dur: self.dur[xi], bytes: self.bytes[xi] };
            self.note(st, now, NetEventKind::Deliver, port, depth, job);
        }
        st.xs[xi].arrived = now;
        if !self.eager[xi] {
            // Rendezvous: the sender's op completes at arrival too.
            self.op_done(st, sh.src, now);
        }
        self.try_complete_recv(st, x, now);
    }

    fn try_complete_recv(&self, st: &mut NetState, x: u32, now: f64) {
        let xi = x as usize;
        let arr = st.xs[xi].arrived;
        let rp = st.xs[xi].recv_posted;
        if arr.is_nan() || rp.is_nan() {
            return;
        }
        let dst = self.shapes[xi].dst;
        let t = arr.max(rp) + self.model.o_match * self.rank_factor[dst as usize];
        self.op_done(st, dst, t.max(now));
    }

    fn op_done(&self, st: &mut NetState, rank: u32, t: f64) {
        let r = rank as usize;
        debug_assert!(st.rank_outstanding[r] > 0);
        st.rank_outstanding[r] -= 1;
        if t > st.rank_clock[r] {
            st.rank_clock[r] = t;
        }
        if st.rank_outstanding[r] == 0 {
            self.advance(st, rank);
        }
    }

    fn advance(&self, st: &mut NetState, rank: u32) {
        let r = rank as usize;
        st.rank_pos[r] += 1;
        if self.rank_off[r] + st.rank_pos[r] < self.rank_off[r + 1] {
            st.q.push(st.rank_clock[r], EvKind::Post { rank });
        } else {
            st.finished += 1;
        }
    }

    fn tenant_arrival(&self, st: &mut NetState, node: u32, now: f64) -> Result<(), NetError> {
        let sc = &self.scenario;
        let bytes = st.trng.exp(sc.tenant_bytes).max(1.0);
        let mut d = st.trng.below((self.nodes - 1) as u64) as u32;
        if d >= node {
            d += 1;
        }
        let job = Job {
            id: JobId::Tenant { src_node: node, dst_node: d },
            dur: bytes * self.model.beta_net,
            bytes: bytes as u64,
        };
        // Re-arm this flow first so a dropped message doesn't silence
        // the stream.
        st.q.push(now + st.trng.exp(sc.tenant_gap_us), EvKind::Tenant { node });
        self.enqueue(st, port_id(node, NET_OUT), job, now)
    }

    fn servers(&self, port: u32) -> u32 {
        match port_kind(port) {
            BUS => self.model.bus_servers.max(1),
            _ => self.model.phys_lanes.max(1),
        }
    }

    fn span(&self, st: &mut NetState, x: u32, start: f64, end: f64, offnode: bool) {
        if let Some(tr) = &mut st.trace {
            let sh = self.shapes[x as usize];
            tr.spans.push(Span {
                src: sh.src,
                dst: sh.dst,
                start,
                end,
                bytes: self.bytes[x as usize],
                offnode,
            });
        }
    }

    fn note(
        &self,
        st: &mut NetState,
        t: f64,
        kind: NetEventKind,
        port: u32,
        depth: u32,
        job: Job,
    ) {
        let Some(tr) = &mut st.trace else { return };
        let node = port / PORTS_PER_NODE;
        let (src, dst, tenant) = match job.id {
            JobId::Xfer(x) => {
                let sh = self.shapes[x as usize];
                (sh.src, sh.dst, false)
            }
            JobId::Tenant { src_node, dst_node } => (src_node, dst_node, true),
        };
        tr.events.push(NetEvent {
            t,
            kind,
            port: port_name(port),
            node,
            depth,
            src,
            dst,
            bytes: job.bytes,
            tenant,
        });
    }
}

impl SimBackend for NetSim {
    type State = NetState;
    type Error = NetError;

    fn new_state(&self) -> NetState {
        NetSim::new_state(self)
    }

    fn run_rep(&self, st: &mut NetState, seed: u64) -> Result<SimResult, NetError> {
        self.run_into(st, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{alltoall, bcast};
    use crate::topology::Cluster;

    fn quiet() -> CostModel {
        let mut m = CostModel::hydra_baseline();
        m.jitter_mean = 0.0;
        m
    }

    fn free() -> Scenario {
        Scenario::contention_free()
    }

    #[test]
    fn single_transfer_matches_closed_form() {
        // Mirrors the analytic engine's unit test: one rendezvous
        // transfer costs o_post + bytes·β + α + o_match on both
        // backends, exactly.
        let cl = Cluster::new(2, 1, 1);
        let m = quiet();
        let c = 10_000u64;
        let s = bcast::build(cl, 0, c, bcast::BcastAlg::Binomial);
        let net = NetSim::new(&s, &m, &free()).expect("scenario");
        let got = net.run(1).expect("run").makespan;
        let bytes = (c * 4) as f64;
        let want = m.o_post + bytes * m.beta_net + m.alpha_net + m.o_match;
        assert!((got - want).abs() < 1e-9, "got {got} want {want}");
    }

    #[test]
    fn eager_transfer_matches_closed_form() {
        let cl = Cluster::new(2, 1, 1);
        let m = quiet();
        let s = bcast::build(cl, 0, 4, bcast::BcastAlg::Binomial); // 16 B eager
        let net = NetSim::new(&s, &m, &free()).expect("scenario");
        let got = net.run(1).expect("run").makespan;
        let want = m.o_post + 16.0 * m.beta_net + m.alpha_net + m.o_match;
        assert!((got - want).abs() < 1e-9, "got {got} want {want}");
    }

    #[test]
    fn repeated_runs_are_bitwise_identical() {
        let cl = Cluster::new(3, 4, 2);
        let m = CostModel::hydra_baseline(); // jitter on: exercises rng
        let s = alltoall::build(cl, 500, alltoall::AlltoallAlg::Pairwise);
        let mut sc = Scenario::contended();
        sc.queue_capacity = None; // keep the run infallible
        let net = NetSim::new(&s, &m, &sc).expect("scenario");
        let a = net.run(42).expect("run");
        let b = net.run(42).expect("run");
        assert_eq!(a, b);
        assert!(net.run(43).expect("run").makespan != a.makespan, "seed must matter");
    }

    #[test]
    fn recost_count_matches_fresh_build() {
        let cl = Cluster::new(3, 4, 2);
        let m = quiet();
        let mut s = bcast::build(cl, 0, 1, bcast::BcastAlg::FullLane);
        let mut via_count = NetSim::new(&s, &m, &free()).expect("scenario");
        for c in [7u64, 869, 60_000, 1] {
            via_count.recost_count(c);
            s.resize_count(c);
            let fresh = NetSim::new(&s, &m, &free()).expect("scenario");
            assert_eq!(
                via_count.run(5).expect("run"),
                fresh.run(5).expect("run"),
                "c={c}"
            );
        }
    }

    #[test]
    fn lane_serialization_queues() {
        // 4 concurrent off-node messages over 1 lane must serialize;
        // over 4 lanes they overlap (the engine's contention test,
        // replayed on the event backend).
        let mk = |lanes: u32| {
            let mut m = quiet();
            m.phys_lanes = lanes;
            m
        };
        let cl = Cluster::new(2, 4, 4);
        let s = alltoall::build(cl, 50_000, alltoall::AlltoallAlg::KLane);
        let t1 = NetSim::new(&s, &mk(1), &free()).unwrap().run(1).unwrap().makespan;
        let t4 = NetSim::new(&s, &mk(4), &free()).unwrap().run(1).unwrap().makespan;
        assert!(t1 > 2.0 * t4, "1 lane {t1} vs 4 lanes {t4}");
    }

    #[test]
    fn stragglers_slow_the_collective() {
        let cl = Cluster::new(2, 1, 1);
        let m = quiet();
        let s = bcast::build(cl, 0, 10_000, bcast::BcastAlg::Binomial);
        let base = NetSim::new(&s, &m, &free()).unwrap().run(1).unwrap().makespan;
        let mut sc = free();
        sc.straggler_nodes = 1;
        sc.straggler_factor = 3.0;
        let slow = NetSim::new(&s, &m, &sc).unwrap().run(1).unwrap().makespan;
        // Root (node 0) posts at 3× o_post; the whole chain shifts.
        assert!(slow > base, "straggler {slow} vs base {base}");
    }

    #[test]
    fn tenant_traffic_delays_the_collective() {
        let cl = Cluster::new(2, 2, 2);
        let m = quiet();
        let s = bcast::build(cl, 0, 100_000, bcast::BcastAlg::KPorted { k: 2 });
        let base = NetSim::new(&s, &m, &free()).unwrap().run(9).unwrap().makespan;
        let mut sc = free();
        sc.tenant_flows = 32;
        sc.tenant_gap_us = 0.2;
        sc.tenant_bytes = 800_000.0;
        let loaded = NetSim::new(&s, &m, &sc).unwrap().run(9).unwrap().makespan;
        assert!(loaded > base, "tenants {loaded} vs idle {base}");
    }

    #[test]
    fn queue_overflow_is_a_typed_error() {
        // 4 ranks per node push concurrent off-node sends through 2
        // lane servers with zero waiting room: the third concurrent
        // message must drop, and a dropped collective message aborts.
        let cl = Cluster::new(3, 4, 2);
        let m = quiet();
        let s = alltoall::build(cl, 10_000, alltoall::AlltoallAlg::Pairwise);
        let mut sc = free();
        sc.queue_capacity = Some(0);
        let err = NetSim::new(&s, &m, &sc).unwrap().run(1).unwrap_err();
        assert!(matches!(err, NetError::QueueOverflow { .. }), "{err}");
        assert!(err.to_string().contains("queue overflow"), "{err}");
    }

    #[test]
    fn tenant_drops_are_silent_and_counted() {
        let cl = Cluster::new(2, 1, 1);
        let m = quiet();
        let s = bcast::build(cl, 0, 1, bcast::BcastAlg::Binomial);
        let mut sc = free();
        sc.tenant_flows = 16;
        sc.tenant_gap_us = 0.05;
        sc.tenant_bytes = 1_000_000.0;
        sc.queue_capacity = Some(1);
        let net = NetSim::new(&s, &m, &sc).unwrap();
        let mut st = net.new_state();
        // The tiny eager bcast may or may not squeeze through ahead of
        // the flood; either way tenant drops must not be errors.
        match net.run_into(&mut st, 3) {
            Ok(_) => assert!(st.tenants_dropped > 0, "flood must drop tenants"),
            Err(e) => assert!(matches!(e, NetError::QueueOverflow { .. }), "{e}"),
        }
    }

    #[test]
    fn invalid_scenarios_are_rejected() {
        let cl = Cluster::new(2, 1, 1);
        let s = bcast::build(cl, 0, 4, bcast::BcastAlg::Binomial);
        let mut sc = free();
        sc.straggler_factor = 0.5;
        let err = NetSim::new(&s, &quiet(), &sc).unwrap_err();
        assert!(matches!(err, NetError::InvalidScenario { .. }), "{err}");
        let mut sc = free();
        sc.tenant_flows = 2; // gap/bytes left at 0
        let err = NetSim::new(&s, &quiet(), &sc).unwrap_err();
        assert!(matches!(err, NetError::InvalidScenario { .. }), "{err}");
    }

    #[test]
    fn tenants_on_single_node_cluster_unsupported() {
        let cl = Cluster::new(1, 4, 2);
        let s = bcast::build(cl, 0, 64, bcast::BcastAlg::Binomial);
        let mut sc = Scenario::contended();
        sc.queue_capacity = None;
        let err = NetSim::new(&s, &quiet(), &sc).unwrap_err();
        assert!(matches!(err, NetError::BackendUnsupported { .. }), "{err}");
        assert!(err.to_string().contains("does not support"), "{err}");
    }

    #[test]
    fn traced_run_matches_untraced_and_covers_transfers() {
        let cl = Cluster::new(2, 2, 2);
        let m = quiet();
        let s = bcast::build(cl, 0, 1000, bcast::BcastAlg::KPorted { k: 2 });
        let net = NetSim::new(&s, &m, &free()).unwrap();
        let (r, spans, events) = net.run_traced(1).expect("traced");
        assert_eq!(r.makespan, net.run(1).unwrap().makespan);
        assert_eq!(spans.len(), s.num_transfers(), "one wire span per transfer");
        assert!(!events.is_empty());
        // Every collective transfer delivers exactly once.
        let delivers =
            events.iter().filter(|e| e.kind == NetEventKind::Deliver && !e.tenant).count();
        assert_eq!(delivers, s.num_transfers());
    }

    #[test]
    fn scenario_key_text_is_stable() {
        assert_eq!(
            Scenario::contention_free().key_text(),
            "qcap=inf,tenants=0,gap=0,bytes=0,stragglers=0,factor=1"
        );
        assert_eq!(
            Scenario::contended().key_text(),
            "qcap=64,tenants=4,gap=50,bytes=16384,stragglers=2,factor=1.5"
        );
    }

    #[test]
    fn backend_tags_round_trip() {
        assert_eq!(BackendKind::parse("analytic"), Some(BackendKind::Analytic));
        assert_eq!(BackendKind::parse("event"), Some(BackendKind::Event));
        assert_eq!(BackendKind::parse("exec"), None);
        assert_eq!(Backend::Analytic.fingerprint_text(), "analytic");
        assert!(Backend::Event(Scenario::contended())
            .fingerprint_text()
            .starts_with("event(qcap=64,"));
    }
}

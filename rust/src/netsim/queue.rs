//! Deterministic discrete-event queue for the network backend.
//!
//! Same idiom as the analytic engine's heap (`sim::engine::Ev`): a
//! `BinaryHeap` ordered earliest-first with a monotonically increasing
//! insertion sequence as the tie-break, so events at equal timestamps
//! pop in FIFO order and a run is a pure function of (schedule, model,
//! scenario, seed) — no wall clock, no global RNG. Unlike the engine's
//! packed 16-byte entry, netsim events carry structured payloads
//! (jobs move *through* queues here, they are not just completion
//! notifications), so the entry is a plain struct and the sequence is
//! 64-bit — tenant streams can push far more events than a collective
//! has transfers.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What a port is currently serializing (or holding in its drop-tail
/// queue): a collective transfer or a background tenant message.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum JobId {
    /// Flattened transfer id into the simulator's CSR arrays.
    Xfer(u32),
    /// Background tenant message from `src_node`'s egress, headed for
    /// `dst_node`'s ingress.
    Tenant { src_node: u32, dst_node: u32 },
}

/// A unit of port work: serialization time plus the payload size (the
/// latter only for tracing — `dur` is already priced at enqueue time).
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) struct Job {
    pub id: JobId,
    pub dur: f64,
    pub bytes: u64,
}

/// Event payloads. `Post`/`Deliver` mirror the analytic engine's two
/// kinds; the rest drive the store-and-forward port machinery.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum EvKind {
    /// A rank posts all ops of its current round.
    Post { rank: u32 },
    /// A transfer's preconditions are met: enqueue it at its source port.
    Ready { xfer: u32 },
    /// A message cleared its egress head: enqueue at the destination
    /// node's ingress (scheduled one wire latency after service start —
    /// cut-through, matching the analytic `in_ready`).
    Forward { job: Job },
    /// A port server finished serializing `job`.
    SvcDone { port: u32, job: Job },
    /// A collective message fully arrived at its destination rank.
    Deliver { xfer: u32 },
    /// One tenant flow's next injection on `node` (self-re-arming).
    Tenant { node: u32 },
}

#[derive(Clone, Copy, Debug)]
pub(crate) struct Ev {
    pub t: f64,
    /// Insertion sequence: unique per event, FIFO tie-break at equal `t`.
    pub seq: u64,
    pub kind: EvKind,
}

// Ordering is by (t, seq) only; `seq` is unique, so `cmp == Equal`
// implies the same event and the manual Eq is consistent with Ord.
impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    #[inline]
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reversed for earliest-first.
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The queue itself: push with a timestamp, pop earliest (FIFO among
/// equals). `clear` keeps the heap's capacity for rep-loop reuse.
#[derive(Debug, Default)]
pub(crate) struct EventQueue {
    heap: BinaryHeap<Ev>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Ev { t, seq: self.seq, kind });
    }

    #[inline]
    pub fn pop(&mut self) -> Option<Ev> {
        self.heap.pop()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EvKind::Post { rank: 3 });
        q.push(1.0, EvKind::Post { rank: 1 });
        q.push(2.0, EvKind::Post { rank: 2 });
        let order: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.t)).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn equal_timestamps_pop_fifo() {
        let mut q = EventQueue::new();
        for rank in 0..100u32 {
            q.push(5.0, EvKind::Post { rank });
        }
        for want in 0..100u32 {
            match q.pop().expect("event").kind {
                EvKind::Post { rank } => assert_eq!(rank, want),
                other => panic!("unexpected kind {other:?}"),
            }
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn interleaved_push_pop_is_deterministic() {
        let run = || {
            let mut q = EventQueue::new();
            let mut log = Vec::new();
            q.push(1.0, EvKind::Post { rank: 0 });
            q.push(1.0, EvKind::Deliver { xfer: 7 });
            let e = q.pop().unwrap();
            log.push((e.t, e.seq));
            q.push(0.5, EvKind::Tenant { node: 2 });
            while let Some(e) = q.pop() {
                log.push((e.t, e.seq));
            }
            log
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn clear_resets_sequence() {
        let mut q = EventQueue::new();
        q.push(1.0, EvKind::Post { rank: 0 });
        q.clear();
        assert_eq!(q.len(), 0);
        q.push(1.0, EvKind::Post { rank: 1 });
        let e = q.pop().unwrap();
        assert_eq!(e.seq, 1);
    }
}

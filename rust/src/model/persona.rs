//! MPI-library personas.
//!
//! The paper evaluates against three real libraries (Table 1): Open MPI
//! 3.1.3, Intel MPI 2018, and mpich 3.3. We cannot run those libraries;
//! instead each persona bundles (a) a [`CostModel`] parameter set, (b)
//! the library's *native collective algorithm selection* policy, and (c)
//! observed pathologies ("quirks") the paper's tables document — e.g.
//! Intel MPI's ~1 ms small-count `MPI_Bcast` (Table 17) or Open MPI's
//! mid-size `MPI_Alltoall` blow-up (Table 41). Quirks apply to *native*
//! collectives only; the paper's own algorithms run on the plain model.

use super::CostModel;
use crate::algorithms::registry::OpKind;
use crate::algorithms::{allgather, alltoall, bcast, gather, scatter};
use crate::schedule::Schedule;
use crate::topology::{Cluster, Rank};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PersonaName {
    OpenMpi,
    IntelMpi,
    Mpich,
}

impl PersonaName {
    pub fn label(&self) -> &'static str {
        match self {
            PersonaName::OpenMpi => "Open MPI 3.1.3",
            PersonaName::IntelMpi => "Intel MPI 2018",
            PersonaName::Mpich => "mpich 3.3",
        }
    }

    pub fn all() -> [PersonaName; 3] {
        [PersonaName::OpenMpi, PersonaName::IntelMpi, PersonaName::Mpich]
    }

    /// Short machine name: the CLI `--persona` value and the JSON
    /// sink's `persona` key.
    pub fn key(&self) -> &'static str {
        match self {
            PersonaName::OpenMpi => "openmpi",
            PersonaName::IntelMpi => "intelmpi",
            PersonaName::Mpich => "mpich",
        }
    }

    /// Inverse of [`PersonaName::key`].
    pub fn parse(s: &str) -> Option<PersonaName> {
        PersonaName::all().into_iter().find(|p| p.key() == s)
    }
}

/// A native collective choice: the schedule the library would run plus
/// the persona's observed-pathology adjustment.
pub struct NativeChoice {
    pub schedule: Schedule,
    /// Additive overhead in µs (per invocation).
    pub quirk_add: f64,
    /// Multiplicative slowdown.
    pub quirk_mult: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct Persona {
    pub name: PersonaName,
    pub model: CostModel,
}

impl Persona {
    pub fn get(name: PersonaName) -> Persona {
        match name {
            PersonaName::OpenMpi => Self::openmpi(),
            PersonaName::IntelMpi => Self::intelmpi(),
            PersonaName::Mpich => Self::mpich(),
        }
    }

    /// Open MPI 3.1.3: fast small-message path, moderate posting
    /// overhead; weakest large-message on-node pipelining (Table 2:
    /// on-node alltoall 10× slower than across nodes at large counts).
    pub fn openmpi() -> Persona {
        let mut m = CostModel::hydra_baseline();
        m.alpha_net = 1.2;
        m.beta_net = 1.9e-4; // ≈5.2 GB/s achieved per flow
        m.o_post = 0.15;
        m.o_match = 0.10;
        m.alpha_shm = 0.22;
        // single-copy shm at ~8 GB/s but only ~3 concurrent copies at
        // full rate (Table 2: on-node alltoall ≈ 10× slower than
        // across-nodes at 125 KB blocks)
        m.beta_shm = 1.2e-4;
        m.bus_servers = 3;
        m.eager_net = 4096;
        m.jitter_mean = 0.15;
        Persona { name: PersonaName::OpenMpi, model: m }
    }

    /// Intel MPI 2018: lowest small-message latency on node (Table 5),
    /// higher per-call collective setup.
    pub fn intelmpi() -> Persona {
        let mut m = CostModel::hydra_baseline();
        m.alpha_net = 1.5;
        m.beta_net = 1.9e-4;
        m.o_post = 0.30;
        m.alpha_shm = 0.17;
        m.beta_shm = 1.7e-4;
        m.bus_servers = 8;
        m.eager_net = 16384;
        m.node_collective_call = 0.9;
        Persona { name: PersonaName::IntelMpi, model: m }
    }

    /// mpich 3.3: highest posting overhead (Table 6: 32 nonblocking ops
    /// on node cost ~52 µs vs ~18 for Open MPI) but good on-node
    /// pipelining for large messages.
    pub fn mpich() -> Persona {
        let mut m = CostModel::hydra_baseline();
        m.alpha_net = 1.6;
        m.beta_net = 2.0e-4;
        m.o_post = 0.9;
        m.o_match = 0.3;
        m.alpha_shm = 0.3;
        m.beta_shm = 1.3e-4;
        m.bus_servers = 8;
        m.eager_net = 8192;
        Persona { name: PersonaName::Mpich, model: m }
    }

    // ---- native collective selection (what MPI_Bcast & co. run) ----

    /// Native `MPI_Bcast`.
    pub fn native_bcast(&self, cl: Cluster, root: Rank, c: u64) -> NativeChoice {
        let bytes = c * 4;
        let (alg, add, mult) = match self.name {
            PersonaName::OpenMpi => {
                if bytes <= 32_768 {
                    (bcast::BcastAlg::Binomial, 0.0, 1.0)
                } else if bytes <= 262_144 {
                    (bcast::BcastAlg::ScatterAllgather, 0.0, 1.0)
                } else {
                    // Table 12: Open MPI falls off a cliff past 256 KiB
                    // (c = 100000 → 8.7 ms while 60000 → 0.64 ms).
                    (bcast::BcastAlg::ScatterAllgather, 0.0, 3.2)
                }
            }
            PersonaName::IntelMpi => {
                // Table 17: ~1 ms floor at every small count — the
                // library's (mis)tuned selection.
                if bytes <= 65_536 {
                    (bcast::BcastAlg::Binomial, 950.0, 1.0)
                } else {
                    (bcast::BcastAlg::ScatterAllgather, 950.0, 1.6)
                }
            }
            PersonaName::Mpich => {
                if bytes <= 32_768 {
                    (bcast::BcastAlg::Binomial, 0.0, 1.0)
                } else {
                    // Table 22: best-in-class large bcast (5.8 ms @ 4 MB)
                    (bcast::BcastAlg::ScatterAllgather, 0.0, 1.0)
                }
            }
        };
        NativeChoice {
            schedule: bcast::build(cl, root, c, alg),
            quirk_add: add,
            quirk_mult: mult,
        }
    }

    /// Native `MPI_Scatter`.
    pub fn native_scatter(&self, cl: Cluster, root: Rank, c: u64) -> NativeChoice {
        let bytes = c * 4;
        let (alg, add, mult) = match self.name {
            PersonaName::OpenMpi => {
                if bytes <= 1024 {
                    (scatter::ScatterAlg::Binomial, 0.0, 1.0)
                } else {
                    // Table 27: mid-size penalty (c = 87 → 483 µs).
                    (scatter::ScatterAlg::Binomial, 0.0, 2.6)
                }
            }
            PersonaName::IntelMpi => {
                if bytes <= 128 {
                    (scatter::ScatterAlg::Binomial, 0.0, 1.0)
                } else {
                    // Table 32: flat ~540 µs plateau from c = 53.
                    (scatter::ScatterAlg::Binomial, 430.0, 1.0)
                }
            }
            PersonaName::Mpich => (scatter::ScatterAlg::Binomial, 0.0, 1.0),
        };
        NativeChoice {
            schedule: scatter::build(cl, root, c, alg),
            quirk_add: add,
            quirk_mult: mult,
        }
    }

    /// Native `MPI_Gather`: all three libraries run a binomial gather
    /// across sizes (gather is scatter's dual, paper §2).
    pub fn native_gather(&self, cl: Cluster, root: Rank, c: u64) -> NativeChoice {
        NativeChoice {
            schedule: gather::build(cl, root, c, gather::GatherAlg::Binomial),
            quirk_add: 0.0,
            quirk_mult: 1.0,
        }
    }

    /// Native `MPI_Allgather`: recursive doubling for small counts,
    /// ring for large (the MPI-like size switch).
    pub fn native_allgather(&self, cl: Cluster, c: u64) -> NativeChoice {
        let alg = if c * 4 <= 8192 {
            allgather::AllgatherAlg::RecursiveDoubling
        } else {
            allgather::AllgatherAlg::Ring
        };
        NativeChoice {
            schedule: allgather::build(cl, c, alg),
            quirk_add: 0.0,
            quirk_mult: 1.0,
        }
    }

    /// Native `MPI_Alltoall`.
    pub fn native_alltoall(&self, cl: Cluster, c: u64) -> NativeChoice {
        let bytes = c * 4;
        let (alg, add, mult) = match self.name {
            PersonaName::OpenMpi => {
                if bytes <= 32 {
                    (alltoall::AlltoallAlg::Bruck { k: 1 }, 0.0, 1.0)
                } else if bytes <= 2100 && cl.p() > 256 {
                    // Table 41: catastrophic mid-size instability
                    // (c = 521 → 166 ms avg). A contended linear
                    // algorithm; modelled as a large multiplier.
                    (alltoall::AlltoallAlg::Pairwise, 0.0, 20.0)
                } else {
                    (alltoall::AlltoallAlg::Pairwise, 0.0, 1.0)
                }
            }
            PersonaName::IntelMpi => {
                if bytes <= 256 {
                    (alltoall::AlltoallAlg::Bruck { k: 1 }, 0.0, 1.0)
                } else {
                    (alltoall::AlltoallAlg::Pairwise, 0.0, 1.15)
                }
            }
            PersonaName::Mpich => {
                if bytes <= 256 {
                    (alltoall::AlltoallAlg::Bruck { k: 1 }, 150.0, 1.0)
                } else {
                    (alltoall::AlltoallAlg::Pairwise, 0.0, 1.0)
                }
            }
        };
        NativeChoice {
            schedule: alltoall::build(cl, c, alg),
            quirk_add: add,
            quirk_mult: mult,
        }
    }

    /// The counts where this persona's native selection changes the
    /// *schedule structure*: `c` is listed iff the native algorithm at
    /// `c` differs from the one at `c - 1`. These are the structural
    /// cell boundaries the symbolic certifier
    /// (`analysis::symbolic`) partitions `[1, max]` at. Quirk-only
    /// switches (pure cost adjustments on an unchanged schedule —
    /// Open MPI's large-bcast cliff, the scatter plateaus, the
    /// mid-size alltoall pathology) are deliberately absent: the
    /// analyzer reads structure, never cost. Kept beside the
    /// selection code above so a threshold edit cannot silently drift
    /// from its break; `native_breaks_match_selection` probes every
    /// boundary.
    pub fn native_structure_breaks(&self, op: OpKind) -> Vec<u64> {
        match op {
            // Binomial → scatter-allgather at bytes > 32 KiB (64 KiB
            // for Intel MPI); bytes = 4c.
            OpKind::Bcast => match self.name {
                PersonaName::OpenMpi | PersonaName::Mpich => vec![8_193],
                PersonaName::IntelMpi => vec![16_385],
            },
            // Always binomial (gather is scatter's dual).
            OpKind::Scatter | OpKind::Gather => Vec::new(),
            // Recursive doubling → ring at bytes > 8 KiB, all personas.
            OpKind::Allgather => vec![2_049],
            // Bruck → pairwise at bytes > 32 (Open MPI) / 256 bytes.
            OpKind::Alltoall => match self.name {
                PersonaName::OpenMpi => vec![9],
                PersonaName::IntelMpi | PersonaName::Mpich => vec![65],
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn personas_distinct() {
        let o = Persona::openmpi();
        let i = Persona::intelmpi();
        let m = Persona::mpich();
        assert!(m.model.o_post > o.model.o_post, "mpich posting slower");
        assert!(i.model.alpha_shm < o.model.alpha_shm, "intel on-node latency lowest");
    }

    #[test]
    fn native_bcast_switches_algorithm_with_size() {
        let cl = Cluster::new(4, 4, 2);
        let p = Persona::openmpi();
        let small = p.native_bcast(cl, 0, 16);
        let large = p.native_bcast(cl, 0, 1_000_000);
        assert_eq!(small.schedule.algorithm, "bcast/binomial");
        assert_eq!(large.schedule.algorithm, "bcast/scatter-allgather");
        assert!(large.quirk_mult > 1.0);
    }

    #[test]
    fn intel_bcast_has_small_count_floor() {
        let cl = Cluster::new(4, 4, 2);
        let choice = Persona::intelmpi().native_bcast(cl, 0, 1);
        assert!(choice.quirk_add > 500.0, "Table 17 pathology encoded");
    }

    #[test]
    fn openmpi_alltoall_midsize_pathology() {
        let cl = Cluster::hydra(2);
        let choice = Persona::openmpi().native_alltoall(cl, 521);
        assert!(choice.quirk_mult > 5.0, "Table 41 pathology encoded");
        // but not at small or large counts
        assert!(Persona::openmpi().native_alltoall(cl, 1).quirk_mult <= 1.0);
        assert!(Persona::openmpi().native_alltoall(cl, 869).quirk_mult <= 1.0);
    }

    #[test]
    fn native_breaks_match_selection() {
        // At every advertised break the built structure changes; at
        // probes inside a cell it does not. This pins the break table
        // to the selection code above — the symbolic certifier's
        // soundness rests on it.
        let cl = Cluster::new(4, 4, 2);
        let structure = |p: &Persona, op: OpKind, c: u64| -> &'static str {
            match op {
                OpKind::Bcast => p.native_bcast(cl, 0, c).schedule.algorithm,
                OpKind::Scatter => p.native_scatter(cl, 0, c).schedule.algorithm,
                OpKind::Gather => p.native_gather(cl, 0, c).schedule.algorithm,
                OpKind::Allgather => p.native_allgather(cl, c).schedule.algorithm,
                OpKind::Alltoall => p.native_alltoall(cl, c).schedule.algorithm,
            }
        };
        for name in PersonaName::all() {
            let p = Persona::get(name);
            for op in OpKind::ALL {
                let breaks = p.native_structure_breaks(op);
                for &b in &breaks {
                    assert!(b > 1, "{name:?} {op}: break {b} below domain");
                    assert_ne!(
                        structure(&p, op, b - 1),
                        structure(&p, op, b),
                        "{name:?} {op}: no structure change at advertised break {b}"
                    );
                }
                // Cell interiors: walk [1, 100k] boundaries and probe
                // that structure is constant between adjacent breaks.
                let mut bounds = vec![1u64];
                bounds.extend(breaks.iter().copied());
                bounds.push(100_001);
                for w in bounds.windows(2) {
                    let (lo, hi) = (w[0], w[1] - 1);
                    let probe = [lo, (lo + hi) / 2, hi];
                    for c in probe {
                        assert_eq!(
                            structure(&p, op, lo),
                            structure(&p, op, c),
                            "{name:?} {op}: structure changes inside cell [{lo}, {hi}] at {c}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn all_personas_produce_valid_native_schedules() {
        use crate::schedule::validate::validate;
        let cl = Cluster::new(3, 4, 2);
        for name in PersonaName::all() {
            let p = Persona::get(name);
            validate(&p.native_bcast(cl, 0, 8).schedule).unwrap();
            validate(&p.native_scatter(cl, 0, 8).schedule).unwrap();
            validate(&p.native_alltoall(cl, 8).schedule).unwrap();
        }
    }
}

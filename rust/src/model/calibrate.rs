//! Cost-model calibration: least-squares fitting of the linear (α-β)
//! channel parameters from (message size, time) observations.
//!
//! Used two ways:
//! * deriving the persona parameter sets from the paper's own table
//!   cells (the anchors in `harness::anchors`) — how the shipped
//!   personas were produced;
//! * re-calibrating against a user's own measurements (CSV of
//!   `bytes,us` pairs) to model a different machine.

/// Ordinary least squares for `t = alpha + beta · bytes`.
/// Returns (alpha µs, beta µs/B). Needs ≥ 2 distinct sizes.
pub fn fit_linear(points: &[(f64, f64)]) -> Option<(f64, f64)> {
    if points.len() < 2 {
        return None;
    }
    let n = points.len() as f64;
    let sx: f64 = points.iter().map(|p| p.0).sum();
    let sy: f64 = points.iter().map(|p| p.1).sum();
    let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return None;
    }
    let beta = (n * sxy - sx * sy) / denom;
    let alpha = (sy - beta * sx) / n;
    Some((alpha, beta))
}

/// Coefficient of determination for a fitted line.
pub fn r_squared(points: &[(f64, f64)], alpha: f64, beta: f64) -> f64 {
    let n = points.len() as f64;
    if n < 2.0 {
        return 1.0;
    }
    let mean = points.iter().map(|p| p.1).sum::<f64>() / n;
    let ss_tot: f64 = points.iter().map(|p| (p.1 - mean).powi(2)).sum();
    let ss_res: f64 =
        points.iter().map(|p| (p.1 - alpha - beta * p.0).powi(2)).sum();
    if ss_tot < 1e-12 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Fit a per-flow network channel from paper-style alltoall rows at
/// p ranks: each rank moves (p-1)·c elements serially over its lane, so
/// t ≈ α' + (p-1)·c·4·β with α' absorbing posting overheads.
pub fn fit_alltoall_channel(p: u32, rows: &[(u64, f64)]) -> Option<(f64, f64)> {
    let pts: Vec<(f64, f64)> = rows
        .iter()
        .map(|&(c, us)| (((p as u64 - 1) * c * 4) as f64, us))
        .collect();
    fit_linear(&pts)
}

/// Parse `bytes,us` CSV text (one pair per line, `#` comments allowed).
pub fn parse_csv(text: &str) -> Vec<(f64, f64)> {
    text.lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .filter_map(|l| {
            let mut it = l.split(',');
            let b: f64 = it.next()?.trim().parse().ok()?;
            let t: f64 = it.next()?.trim().parse().ok()?;
            Some((b, t))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let pts: Vec<(f64, f64)> =
            (1..10).map(|i| (i as f64 * 1000.0, 2.5 + 1e-4 * i as f64 * 1000.0)).collect();
        let (a, b) = fit_linear(&pts).unwrap();
        assert!((a - 2.5).abs() < 1e-9, "alpha {a}");
        assert!((b - 1e-4).abs() < 1e-12, "beta {b}");
        assert!(r_squared(&pts, a, b) > 0.999999);
    }

    #[test]
    fn degenerate_inputs_rejected() {
        assert!(fit_linear(&[]).is_none());
        assert!(fit_linear(&[(1.0, 2.0)]).is_none());
        assert!(fit_linear(&[(5.0, 1.0), (5.0, 2.0)]).is_none(), "no size variation");
    }

    #[test]
    fn paper_table2_offnode_beta_recovered() {
        // Table 2, N=32 rows (c, avg µs): the fitted per-flow β should be
        // in the few-GB/s range the persona encodes.
        let rows: &[(u64, f64)] = &[
            (1875, 72.78),
            (3125, 108.60),
            (18750, 307.48),
            (31250, 448.03),
        ];
        let (_a, b) = fit_alltoall_channel(32, rows).unwrap();
        let gbps = 1.0 / b / 1000.0; // (B/µs) → GB/s
        assert!(
            (1.0..20.0).contains(&gbps),
            "fitted per-flow bandwidth {gbps} GB/s out of range"
        );
    }

    #[test]
    fn paper_table2_onnode_slower_than_offnode() {
        let off = fit_alltoall_channel(
            32,
            &[(1875, 72.78), (3125, 108.60), (18750, 307.48), (31250, 448.03)],
        )
        .unwrap();
        let on = fit_alltoall_channel(
            32,
            &[(1875, 995.89), (3125, 1389.12), (18750, 4744.03), (31250, 4618.21)],
        )
        .unwrap();
        assert!(on.1 > 3.0 * off.1, "on-node β {} vs off-node β {}", on.1, off.1);
    }

    #[test]
    fn csv_parsing() {
        let pts = parse_csv("# comment\n1000, 2.5\n\n2000,3.0\nbad line\n");
        assert_eq!(pts, vec![(1000.0, 2.5), (2000.0, 3.0)]);
    }
}

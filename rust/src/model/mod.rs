//! Hierarchical communication cost model (paper §2.4).
//!
//! Two linear (α-β) channels — shared memory on the node, network lanes
//! off the node — plus the contention resources that make the k-lane
//! question interesting: each node has `k` physical lane servers (more
//! than k concurrent off-node messages per node queue), and a node memory
//! bus with limited multiplicity (the §2.4 question "can all processors
//! communicate at the same time achieving the same memory bandwidth?").
//!
//! Per-message CPU overheads (`o_post`, `o_match`) model nonblocking
//! send/recv posting and completion; an eager/rendezvous threshold
//! switches between buffered and synchronising transfer semantics, as in
//! real MPI libraries.

pub mod calibrate;
pub mod persona;

pub use persona::{Persona, PersonaName};

/// All times in microseconds, sizes in bytes — matching the paper's
/// tables (µs, MPI_INT elements of 4 bytes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    // --- off-node (network lanes) ---
    /// Per-message network latency (µs).
    pub alpha_net: f64,
    /// Transmission cost per byte per lane (µs/B). 100 Gbit/s OmniPath
    /// ≈ 12.5 GB/s ≈ 8.0e-5 µs/B.
    pub beta_net: f64,
    /// Physical lanes per node (Hydra: dual OmniPath = 2). Each lane is a
    /// full-duplex server: egress and ingress pools of this size.
    pub phys_lanes: u32,
    /// Eager threshold for off-node messages (bytes).
    pub eager_net: u64,

    // --- on-node (shared memory) ---
    /// Per-message shared-memory latency (µs).
    pub alpha_shm: f64,
    /// Copy cost per byte through shared memory (µs/B), single copy.
    pub beta_shm: f64,
    /// How many on-node copies can run at full `beta_shm` rate before
    /// queueing (memory-bus multiplicity, §2.4's k').
    pub bus_servers: u32,
    /// Eager threshold for on-node messages (bytes).
    pub eager_shm: u64,

    // --- CPU / library ---
    /// Overhead of posting one nonblocking send or recv (µs, serial per
    /// core).
    pub o_post: f64,
    /// Overhead of matching/completing one message (µs).
    pub o_match: f64,
    /// Extra per-call setup charged when a round is a hinted node-local
    /// collective (the cost of an `MPI_Bcast`/`MPI_Scatter` call on the
    /// node communicator, §3).
    pub node_collective_call: f64,

    // --- noise ---
    /// Mean of exponential per-op jitter (µs); produces the avg-vs-min
    /// spread the paper reports over 100 repetitions.
    pub jitter_mean: f64,
}

impl CostModel {
    /// A neutral baseline roughly shaped like the Hydra system: 2 lanes
    /// of 100 Gbit/s, ~1 µs network latency, shared-memory copies at
    /// ~10 GB/s with 8-way bus concurrency.
    pub fn hydra_baseline() -> Self {
        Self {
            alpha_net: 1.4,
            beta_net: 8.0e-5,
            phys_lanes: 2,
            eager_net: 8192,
            alpha_shm: 0.25,
            beta_shm: 1.0e-4,
            bus_servers: 8,
            eager_shm: 4096,
            o_post: 0.25,
            o_match: 0.15,
            node_collective_call: 0.4,
            jitter_mean: 0.4,
        }
    }

    /// Uncontended transfer time for a message of `bytes` (no queueing).
    pub fn uncontended(&self, bytes: u64, offnode: bool) -> f64 {
        if offnode {
            self.alpha_net + bytes as f64 * self.beta_net
        } else {
            self.alpha_shm + bytes as f64 * self.beta_shm
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_sane() {
        let m = CostModel::hydra_baseline();
        assert!(m.alpha_net > m.alpha_shm, "network latency exceeds shm");
        // 4 MB bcast payload ≈ 4e6 B × 8e-5 µs/B ≈ 320 µs per hop
        let t = m.uncontended(4_000_000, true);
        assert!((300.0..400.0).contains(&t), "t={t}");
    }

    #[test]
    fn uncontended_monotone_in_size() {
        let m = CostModel::hydra_baseline();
        assert!(m.uncontended(100, true) < m.uncontended(1000, true));
        assert!(m.uncontended(100, false) < m.uncontended(1000, false));
    }
}

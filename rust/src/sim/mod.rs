//! Discrete-event simulator: executes a [`Schedule`] under a
//! [`CostModel`] and reports per-repetition slowest-rank times, exactly
//! the quantity the paper measures (§4: `MPI_Barrier` + `MPI_Wtime`,
//! average and minimum of the slowest process over 100 repetitions with
//! 5 warm-up).
//!
//! ## Semantics
//!
//! Rounds are *per-rank* programs, not global barriers: each rank walks
//! its own sequence of rounds it participates in, posting all of a
//! round's nonblocking sends/recvs (serial `o_post` per op on the core)
//! and then waiting for all of them (waitall) before advancing — the MPI
//! pattern §3 describes. A rank that does not appear in a round skips it,
//! so node-local phases of one node overlap with network traffic of
//! others.
//!
//! ## Resources
//!
//! * per-node egress and ingress lane pools (`phys_lanes` servers each,
//!   full duplex) — off-node messages queue here;
//! * per-node memory-bus pool (`bus_servers`) — on-node copies queue
//!   here;
//! * per-rank serial posting (built into the rank clock).
//!
//! An off-node transmission holds one egress server of the source node
//! and one ingress server of the destination node for `bytes · β_net`;
//! acquisition is egress-then-ingress (deadlock-free: ingress holders
//! never wait on egress). Eager messages (≤ threshold) start when the
//! send is posted; rendezvous messages wait for both sides.

mod engine;
pub mod sweep;
pub mod trace;

pub use engine::{RepState, SimError, SimResult, Simulator};
pub use sweep::{AlgId, CellResult, MeasureError, OpShape, SweepEngine, SweepKey, SweepStats};

use crate::model::CostModel;
use crate::schedule::Schedule;
use crate::util::stats::Summary;

/// A simulation backend: something that can run one repetition of a
/// compiled schedule against reusable per-rep state. The analytic
/// [`Simulator`] (closed-form reservations, infallible) and the
/// event-driven [`crate::netsim::NetSim`] (explicit FIFO port queues,
/// fallible — drop-tail overflow is a typed error) both implement it,
/// so measurement loops ([`measure_backend`]) and the sweep layer are
/// generic over the backend.
pub trait SimBackend {
    type State;
    type Error: std::error::Error;

    /// Allocate per-repetition state sized for this backend.
    fn new_state(&self) -> Self::State;

    /// Run one repetition with the given jitter seed, reusing `st`.
    fn run_rep(&self, st: &mut Self::State, seed: u64) -> Result<SimResult, Self::Error>;
}

impl SimBackend for Simulator {
    type State = RepState;
    type Error = SimError;

    fn new_state(&self) -> RepState {
        Simulator::new_state(self)
    }

    fn run_rep(&self, st: &mut RepState, seed: u64) -> Result<SimResult, SimError> {
        Ok(self.run_into(st, seed))
    }
}

/// Per-repetition seed derivation — one shared definition so the
/// analytic hot path ([`measure_sim`]) and the generic backend loop
/// ([`measure_backend`]) sample identical jitter streams for the same
/// (seed, rep).
#[inline]
pub fn rep_seed(seed: u64, rep: usize) -> u64 {
    seed ^ (rep as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Backend-generic rep loop: `reps` measured repetitions after
/// `warmup` unmeasured ones. Unlike [`measure_sim`] this allocates a
/// small sample buffer per call — the event backend is not part of the
/// zero-alloc series contract (`rust/tests/series_alloc.rs` gates the
/// analytic path only).
pub fn measure_backend<B: SimBackend>(
    backend: &B,
    st: &mut B::State,
    reps: usize,
    warmup: usize,
    seed: u64,
) -> Result<Summary, B::Error> {
    let mut samples = Vec::with_capacity(reps);
    for rep in 0..reps + warmup {
        let r = backend.run_rep(st, rep_seed(seed, rep))?;
        if rep >= warmup {
            samples.push(r.makespan);
        }
    }
    Ok(Summary::of(&samples))
}

/// Simulate `reps` measured repetitions (after `warmup` unmeasured ones)
/// and summarise like the paper's tables.
pub fn measure(
    schedule: &Schedule,
    model: &CostModel,
    reps: usize,
    warmup: usize,
    seed: u64,
) -> Summary {
    let sim = Simulator::new(schedule, model);
    let mut state = sim.new_state();
    measure_sim(&sim, &mut state, reps, warmup, seed)
}

/// Rep loop over an already-built simulator and state — the sweep-engine
/// hot path ([`sweep::SweepEngine`] reuses both across cells). `st` must
/// match the simulator's dimensions (see [`Simulator::ensure_state`]).
/// Measured samples go into the arena owned by `st`, so a warm state
/// makes the whole loop allocation-free (its capacity persists across
/// cells of a series — see `rust/tests/series_alloc.rs`).
pub fn measure_sim(
    sim: &Simulator,
    st: &mut RepState,
    reps: usize,
    warmup: usize,
    seed: u64,
) -> Summary {
    st.begin_samples(reps);
    for rep in 0..reps + warmup {
        let r = sim.run_into(st, rep_seed(seed, rep));
        if rep >= warmup {
            st.push_sample(r.makespan);
        }
    }
    Summary::of(st.samples())
}

/// Paper measurement parameters (§4). The harness defaults to fewer
/// repetitions for the large sweeps (see [`DEFAULT_REPS`]).
pub const PAPER_REPS: usize = 100;
pub const PAPER_WARMUP: usize = 5;

/// Default repetitions for the table harness (jitter converges well
/// before 100 reps in simulation). The library reads no environment;
/// the CLI maps `MLANE_REPS` onto `harness::RunConfig::reps`.
pub const DEFAULT_REPS: usize = 20;

/// Default unmeasured warm-up repetitions. Single source for both
/// `Collectives` and `harness::RunConfig`, so coordinator-level and
/// plan-level runs of the same scenario cannot silently drift.
pub const DEFAULT_WARMUP: usize = 2;

/// Default measurement seed (per-rep streams derive from it); shared by
/// `Collectives` and `harness::RunConfig` for the same reason.
pub const DEFAULT_SEED: u64 = 0xC0FFEE;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bcast::{self, BcastAlg};
    use crate::model::CostModel;
    use crate::topology::Cluster;

    fn quiet(mut m: CostModel) -> CostModel {
        m.jitter_mean = 0.0;
        m
    }

    #[test]
    fn measure_is_deterministic_per_seed() {
        let cl = Cluster::new(4, 4, 2);
        let s = bcast::build(cl, 0, 1000, BcastAlg::Binomial);
        let m = CostModel::hydra_baseline();
        let a = measure(&s, &m, 5, 1, 42);
        let b = measure(&s, &m, 5, 1, 42);
        assert_eq!(a, b);
    }

    #[test]
    fn zero_jitter_gives_zero_spread() {
        let cl = Cluster::new(4, 4, 2);
        let s = bcast::build(cl, 0, 1000, BcastAlg::Binomial);
        let m = quiet(CostModel::hydra_baseline());
        let sum = measure(&s, &m, 5, 0, 7);
        assert!((sum.avg - sum.min).abs() < 1e-9);
    }

    #[test]
    fn jitter_creates_avg_min_spread() {
        let cl = Cluster::new(4, 4, 2);
        let s = bcast::build(cl, 0, 1000, BcastAlg::Binomial);
        let m = CostModel::hydra_baseline();
        let sum = measure(&s, &m, 30, 2, 7);
        assert!(sum.avg > sum.min, "avg {} min {}", sum.avg, sum.min);
    }
}

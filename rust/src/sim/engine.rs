//! The event-driven core of the simulator. See module docs in `mod.rs`.
//!
//! ## Data layout
//!
//! Per-rank round programs are stored CSR-style (flat id arrays with
//! offset tables) rather than as nested `Vec<Vec<...>>`: the event loop
//! walks `send_ids`/`recv_ids` slices via two offset lookups, so a whole
//! round's ops sit contiguously in cache and `Simulator` construction is
//! the only place that allocates.
//!
//! Transfer data is split by count-dependence: the shape
//! (endpoints, node ids, on/off-node) lives in one array, while the
//! sizing fields (`bytes`, `dur`, `eager`) and the per-transfer model
//! constants (β, eager threshold) each get their own parallel array.
//! Re-targeting a cached simulator to a new element count
//! ([`Simulator::recost_count`]) is then two contiguous, branch-light
//! passes over flat arrays — no rounds walk, no schedule. Combined with
//! [`Simulator::ensure_state`] (reshape a [`RepState`] for reuse), a
//! count sweep touches the allocator only on its first cell — see
//! `sim::sweep`.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::fmt;

use crate::model::CostModel;
use crate::schedule::{CountSizer, Schedule};
use crate::util::Prng;

/// Typed failure of [`Simulator::recost`]: the schedule handed in is
/// structurally different from the one this simulator was built from,
/// so re-costing it would silently time the wrong communication
/// structure. Surfaced through `SweepEngine::measure` as
/// `sweep::MeasureError::Sim` (a cache-identity bug is an error, not a
/// panic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimError {
    /// The schedule's transfer count differs from the simulator's.
    TransferCountMismatch { simulator: usize, schedule: usize },
    /// Transfer `index` connects different endpoints (src, dst).
    EndpointMismatch { index: usize, simulator: (u32, u32), schedule: (u32, u32) },
}

impl fmt::Display for SimError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimError::TransferCountMismatch { simulator, schedule } => write!(
                f,
                "recost on a structurally different schedule: simulator has {simulator} \
                 transfers, schedule has {schedule}"
            ),
            SimError::EndpointMismatch { index, simulator, schedule } => write!(
                f,
                "recost on a structurally different schedule: transfer {index} is \
                 {}->{} in the simulator but {}->{} in the schedule",
                simulator.0, simulator.1, schedule.0, schedule.1
            ),
        }
    }
}

impl std::error::Error for SimError {}

/// One rank's participation in one schedule round (construction-time
/// temporary; flattened into the CSR arrays before simulation).
#[derive(Clone, Debug, Default)]
struct RoundOps {
    round: u32,
    sends: Vec<u32>, // transfer ids
    recvs: Vec<u32>,
    /// Per-call node-collective overhead applies to this round.
    hinted: bool,
}

/// Count-invariant per-transfer shape. The count-dependent sizing
/// (`bytes`, `dur`, `eager`) lives in parallel arrays on [`Simulator`]
/// so [`Simulator::recost_count`] rewrites it with contiguous passes.
#[derive(Clone, Copy, Debug)]
struct XferShape {
    src: u32,
    dst: u32,
    offnode: bool,
    src_node: u32,
    dst_node: u32,
}

/// Immutable simulation input, reusable across repetitions.
pub struct Simulator {
    p: u32,
    nodes: u32,
    model: CostModel,
    /// Count-invariant transfer shape, indexed by transfer id.
    shapes: Vec<XferShape>,
    /// Count-dependent sizing, parallel to `shapes`. Rewritten in place
    /// by [`Simulator::recost`] / [`Simulator::recost_count`].
    bytes: Vec<u64>,
    /// Precomputed transmission duration (bytes × β for its path).
    dur: Vec<f64>,
    eager: Vec<bool>,
    /// Per-transfer model constants (β and eager threshold of the
    /// transfer's channel), hoisting the on/off-node branch out of the
    /// recost loop.
    beta: Vec<f64>,
    eager_limit: Vec<u64>,
    /// Flattened count→bytes function of the source schedule — lets
    /// [`Simulator::recost_count`] re-target counts schedule-free.
    sizer: CountSizer,
    /// CSR offsets: rank `r` owns slots `rank_off[r]..rank_off[r+1]`
    /// (one slot per round the rank participates in). Length p + 1.
    rank_off: Vec<u32>,
    /// Per-slot node-collective hint. Length = total slots.
    slot_hinted: Vec<bool>,
    /// Slot `s` sends `send_ids[send_off[s]..send_off[s+1]]`.
    send_off: Vec<u32>,
    send_ids: Vec<u32>,
    /// Slot `s` receives `recv_ids[recv_off[s]..recv_off[s+1]]`.
    recv_off: Vec<u32>,
    recv_ids: Vec<u32>,
}

/// One transmission span captured by the tracer (see `sim::trace`).
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub src: u32,
    pub dst: u32,
    pub start: f64,
    pub end: f64,
    pub bytes: u64,
    pub offnode: bool,
}

/// Result of one repetition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimResult {
    /// Time at which the slowest rank finished (µs).
    pub makespan: f64,
    pub events: u64,
}

/// min-heap entry, packed to 16 bytes: the heap dominates the event
/// loop's cache traffic, so `kind` (1 bit) + payload id (31 bits) +
/// insertion sequence (32 bits, tie-break for determinism) share a word.
#[derive(PartialEq, Clone, Copy)]
struct Ev {
    t: f64,
    /// bit 63 = kind (0 Post, 1 Arrive); bits 62..32 = payload id;
    /// bits 31..0 = insertion sequence.
    tag: u64,
}

const EV_ARRIVE: u64 = 1 << 63;

impl Ev {
    #[inline]
    fn post(t: f64, rank: u32, seq: u32) -> Ev {
        Ev { t, tag: ((rank as u64) << 32) | seq as u64 }
    }

    #[inline]
    fn arrive(t: f64, xfer: u32, seq: u32) -> Ev {
        Ev { t, tag: EV_ARRIVE | ((xfer as u64) << 32) | seq as u64 }
    }

    #[inline]
    fn is_arrive(&self) -> bool {
        self.tag & EV_ARRIVE != 0
    }

    #[inline]
    fn id(&self) -> u32 {
        ((self.tag >> 32) & 0x7FFF_FFFF) as u32
    }
}

impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    #[inline]
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reversed for earliest-first; the
        // tag's low 32 bits (insertion sequence) keep it deterministic.
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then_with(|| (other.tag as u32).cmp(&(self.tag as u32)))
    }
}

/// A pool of identical FIFO servers; reservation picks the earliest-free.
#[derive(Clone, Debug)]
struct Pool {
    free: Vec<f64>,
}

impl Pool {
    fn new(servers: u32) -> Self {
        Self { free: vec![0.0; servers.max(1) as usize] }
    }

    /// Reserve the earliest-free server from `ready` for `dur`; returns
    /// (start, end).
    fn reserve(&mut self, ready: f64, dur: f64) -> (f64, f64) {
        let mut best = 0usize;
        for i in 1..self.free.len() {
            if self.free[i] < self.free[best] {
                best = i;
            }
        }
        let start = ready.max(self.free[best]);
        let end = start + dur;
        self.free[best] = end;
        (start, end)
    }
}

/// Per-transfer mutable state, packed together for cache locality on
/// the hot path (one line per transfer instead of four array walks).
#[derive(Clone, Copy)]
struct XferState {
    send_posted: f64, // NaN = not yet
    recv_posted: f64,
    arrived: f64,
    started: bool,
}

const XFER_INIT: XferState =
    XferState { send_posted: f64::NAN, recv_posted: f64::NAN, arrived: f64::NAN, started: false };

/// Mutable per-repetition state, reusable across repetitions via
/// [`RepState::reset`] (allocation-free rep loop) and across sweep cells
/// via [`Simulator::ensure_state`] (reshape without reallocation when
/// dimensions already match).
pub struct RepState {
    rank_pos: Vec<u32>, // round index within the rank's CSR slot range
    rank_outstanding: Vec<u32>,
    rank_clock: Vec<f64>,
    xs: Vec<XferState>,
    egress: Vec<Pool>, // per node
    ingress: Vec<Pool>,
    bus: Vec<Pool>,
    heap: BinaryHeap<Ev>,
    seq: u32,
    rng: Prng,
    events: u64,
    /// When set, every transmission records a span (tracing mode).
    trace: Option<Vec<Span>>,
    /// Measured-rep sample arena for `measure_sim`: owned here so a
    /// series of cells reuses one buffer (capacity survives across
    /// cells; the rep loop is allocation-free in steady state).
    samples: Vec<f64>,
}

impl RepState {
    /// Start a new measured-rep collection (clears, keeps capacity).
    pub(crate) fn begin_samples(&mut self, reps: usize) {
        self.samples.clear();
        self.samples.reserve(reps);
    }

    pub(crate) fn push_sample(&mut self, t: f64) {
        self.samples.push(t);
    }

    pub(crate) fn samples(&self) -> &[f64] {
        &self.samples
    }

    fn reset(&mut self, seed: u64) {
        self.rank_pos.iter_mut().for_each(|x| *x = 0);
        self.rank_outstanding.iter_mut().for_each(|x| *x = 0);
        self.rank_clock.iter_mut().for_each(|x| *x = 0.0);
        self.xs.iter_mut().for_each(|x| *x = XFER_INIT);
        for pools in [&mut self.egress, &mut self.ingress, &mut self.bus] {
            for p in pools.iter_mut() {
                p.free.iter_mut().for_each(|f| *f = 0.0);
            }
        }
        self.heap.clear();
        self.seq = 0;
        self.rng = Prng::new(seed);
        self.events = 0;
        if let Some(t) = &mut self.trace {
            t.clear();
        }
    }
}

/// Reshape a pool vector to `count` pools of `servers` servers each,
/// reallocating only on dimension change (values are reset by `reset`).
fn ensure_pools(pools: &mut Vec<Pool>, count: usize, servers: u32) {
    let want = servers.max(1) as usize;
    let ok = pools.len() == count && pools.iter().all(|p| p.free.len() == want);
    if !ok {
        *pools = vec![Pool::new(servers); count];
    }
}

impl Simulator {
    pub fn new(schedule: &Schedule, model: &CostModel) -> Self {
        let p = schedule.p();
        let cl = schedule.cluster;
        let n = schedule.num_transfers();
        let mut shapes = Vec::with_capacity(n);
        let mut bytes = Vec::with_capacity(n);
        let mut dur = Vec::with_capacity(n);
        let mut eager = Vec::with_capacity(n);
        let mut beta = Vec::with_capacity(n);
        let mut eager_limit = Vec::with_capacity(n);
        let mut progs: Vec<Vec<RoundOps>> = vec![Vec::new(); p as usize];

        let mut push_op = |rank: u32, round: u32, id: u32, is_send: bool, hinted: bool| {
            let prog = &mut progs[rank as usize];
            if prog.last().map(|r| r.round) != Some(round) {
                prog.push(RoundOps { round, hinted, ..Default::default() });
            }
            let ops = prog.last_mut().unwrap();
            ops.hinted |= hinted;
            if is_send {
                ops.sends.push(id);
            } else {
                ops.recvs.push(id);
            }
        };

        for (ri, round) in schedule.rounds.iter().enumerate() {
            let hinted = round.node_phase.is_some();
            for t in &round.transfers {
                let id = shapes.len() as u32;
                let offnode = !cl.same_node(t.src, t.dst);
                let (b, lim) = if offnode {
                    (model.beta_net, model.eager_net)
                } else {
                    (model.beta_shm, model.eager_shm)
                };
                shapes.push(XferShape {
                    src: t.src,
                    dst: t.dst,
                    offnode,
                    src_node: cl.node_of(t.src),
                    dst_node: cl.node_of(t.dst),
                });
                bytes.push(t.bytes);
                dur.push(t.bytes as f64 * b);
                eager.push(t.bytes <= lim);
                beta.push(b);
                eager_limit.push(lim);
                push_op(t.src, ri as u32, id, true, hinted);
                push_op(t.dst, ri as u32, id, false, hinted);
            }
        }

        // CSR-flatten the per-rank programs: contiguous slot/op arrays
        // keep the post loop on a handful of cache lines.
        let slots: usize = progs.iter().map(|pr| pr.len()).sum();
        let mut rank_off = Vec::with_capacity(p as usize + 1);
        let mut slot_hinted = Vec::with_capacity(slots);
        let mut send_off = Vec::with_capacity(slots + 1);
        let mut recv_off = Vec::with_capacity(slots + 1);
        let mut send_ids = Vec::new();
        let mut recv_ids = Vec::new();
        rank_off.push(0u32);
        send_off.push(0u32);
        recv_off.push(0u32);
        for prog in &progs {
            for ops in prog {
                slot_hinted.push(ops.hinted);
                send_ids.extend_from_slice(&ops.sends);
                recv_ids.extend_from_slice(&ops.recvs);
                send_off.push(send_ids.len() as u32);
                recv_off.push(recv_ids.len() as u32);
            }
            rank_off.push(slot_hinted.len() as u32);
        }

        Self {
            p,
            nodes: cl.nodes,
            model: *model,
            shapes,
            bytes,
            dur,
            eager,
            beta,
            eager_limit,
            sizer: schedule.count_sizer(),
            rank_off,
            slot_hinted,
            send_off,
            send_ids,
            recv_off,
            recv_ids,
        }
    }

    /// Number of flattened transfers (sweep-engine bookkeeping).
    pub fn num_xfers(&self) -> usize {
        self.shapes.len()
    }

    /// The cost model this simulator was built with (baked into every
    /// precomputed duration; sweep-engine cache-consistency checks).
    pub fn model(&self) -> &CostModel {
        &self.model
    }

    /// Rewrite the count-dependent sizing fields (`bytes`, `dur`,
    /// `eager`) of every transfer from `schedule`, which must be the
    /// *same communication structure* this simulator was built from —
    /// typically the cached schedule after [`Schedule::resize_count`].
    /// Everything shape-derived (round programs, node ids, on/off-node
    /// classification) is reused unchanged, so a sweep cell costs one
    /// linear pass instead of a full rebuild. The computation matches
    /// [`Simulator::new`] expression-for-expression, so a recost-ed
    /// simulator is bitwise-identical to a freshly built one.
    ///
    /// A structurally different schedule (transfer count or endpoints)
    /// is a typed [`SimError`] — the checks are always on, in release
    /// builds too, since a silent mismatch would time the wrong
    /// structure.
    pub fn recost(&mut self, schedule: &Schedule) -> Result<(), SimError> {
        let mut i = 0usize;
        for round in &schedule.rounds {
            for t in &round.transfers {
                let Some(sh) = self.shapes.get(i) else {
                    return Err(SimError::TransferCountMismatch {
                        simulator: self.shapes.len(),
                        schedule: schedule.num_transfers(),
                    });
                };
                if (sh.src, sh.dst) != (t.src, t.dst) {
                    return Err(SimError::EndpointMismatch {
                        index: i,
                        simulator: (sh.src, sh.dst),
                        schedule: (t.src, t.dst),
                    });
                }
                self.bytes[i] = t.bytes;
                self.dur[i] = t.bytes as f64 * self.beta[i];
                self.eager[i] = t.bytes <= self.eager_limit[i];
                i += 1;
            }
        }
        if i != self.shapes.len() {
            return Err(SimError::TransferCountMismatch {
                simulator: self.shapes.len(),
                schedule: i,
            });
        }
        Ok(())
    }

    /// Schedule-free recost: re-target this simulator to element count
    /// `c` via the flattened [`CountSizer`] captured at build time. Two
    /// contiguous passes over flat arrays (bytes, then dur/eager) — the
    /// series hot path, with no rounds walk and no branch on the
    /// channel. Bitwise-identical to [`Schedule::resize_count`] followed
    /// by [`Simulator::recost`]; `rust/tests/recost_equivalence.rs`
    /// gates this for every algorithm. Infallible by construction: the
    /// sizer always matches this simulator's transfer count.
    pub fn recost_count(&mut self, c: u64) {
        self.sizer.resize_count_into(c, &mut self.bytes);
        for i in 0..self.bytes.len() {
            let b = self.bytes[i];
            self.dur[i] = b as f64 * self.beta[i];
            self.eager[i] = b <= self.eager_limit[i];
        }
    }

    /// Allocate a reusable per-repetition state.
    pub fn new_state(&self) -> RepState {
        let m = &self.model;
        RepState {
            rank_pos: vec![0; self.p as usize],
            rank_outstanding: vec![0; self.p as usize],
            rank_clock: vec![0.0; self.p as usize],
            xs: vec![XFER_INIT; self.shapes.len()],
            egress: vec![Pool::new(m.phys_lanes); self.nodes as usize],
            ingress: vec![Pool::new(m.phys_lanes); self.nodes as usize],
            bus: vec![Pool::new(m.bus_servers); self.nodes as usize],
            heap: BinaryHeap::with_capacity(self.p as usize * 2),
            seq: 0,
            rng: Prng::new(0),
            events: 0,
            trace: None,
            samples: Vec::new(),
        }
    }

    /// Reshape `st` (possibly built for a different simulator) to this
    /// simulator's dimensions, reusing every allocation whose size
    /// already matches — the sweep-cell fast path is a no-op.
    pub fn ensure_state(&self, st: &mut RepState) {
        let p = self.p as usize;
        st.rank_pos.resize(p, 0);
        st.rank_outstanding.resize(p, 0);
        st.rank_clock.resize(p, 0.0);
        st.xs.resize(self.shapes.len(), XFER_INIT);
        let m = &self.model;
        ensure_pools(&mut st.egress, self.nodes as usize, m.phys_lanes);
        ensure_pools(&mut st.ingress, self.nodes as usize, m.phys_lanes);
        ensure_pools(&mut st.bus, self.nodes as usize, m.bus_servers);
    }

    /// Run one repetition recording every transmission span.
    pub fn run_traced(&self, seed: u64) -> (SimResult, Vec<Span>) {
        let mut st = self.new_state();
        st.trace = Some(Vec::new());
        let r = self.run_into(&mut st, seed);
        (r, st.trace.take().unwrap())
    }

    /// Run one repetition with the given jitter seed (allocates fresh
    /// state; use [`Simulator::run_into`] in rep loops).
    pub fn run(&self, seed: u64) -> SimResult {
        let mut st = self.new_state();
        self.run_into(&mut st, seed)
    }

    /// Run one repetition reusing `st` (no allocation).
    pub fn run_into(&self, st: &mut RepState, seed: u64) -> SimResult {
        st.reset(seed);

        // Kick off: every rank with a program posts its first round at 0.
        for r in 0..self.p as usize {
            if self.rank_off[r + 1] > self.rank_off[r] {
                st.seq = st.seq.wrapping_add(1);
                st.heap.push(Ev::post(0.0, r as u32, st.seq));
            }
        }

        while let Some(ev) = st.heap.pop() {
            st.events += 1;
            if ev.is_arrive() {
                self.do_arrive(st, ev.id(), ev.t);
            } else {
                self.do_post(st, ev.id(), ev.t);
            }
        }

        let makespan =
            st.rank_clock.iter().copied().fold(0.0f64, f64::max);
        SimResult { makespan, events: st.events }
    }

    /// Rank posts all ops of its current round, then waits for them.
    fn do_post(&self, st: &mut RepState, rank: u32, now: f64) {
        let m = &self.model;
        let slot =
            (self.rank_off[rank as usize] + st.rank_pos[rank as usize]) as usize;
        let sends = &self.send_ids
            [self.send_off[slot] as usize..self.send_off[slot + 1] as usize];
        let recvs = &self.recv_ids
            [self.recv_off[slot] as usize..self.recv_off[slot + 1] as usize];
        let mut clock = now;
        if self.slot_hinted[slot] {
            clock += m.node_collective_call;
        }
        let jitter = |st: &mut RepState| {
            if m.jitter_mean > 0.0 {
                st.rng.exp(m.jitter_mean)
            } else {
                0.0
            }
        };
        // +1 "posting token": ops may complete synchronously while we are
        // still posting; the token guarantees advance() fires exactly once,
        // after the whole round is posted.
        st.rank_outstanding[rank as usize] =
            (sends.len() + recvs.len()) as u32 + 1;

        // Post receives first (as a real implementation would), then sends.
        for &x in recvs {
            clock += m.o_post + jitter(st);
            st.xs[x as usize].recv_posted = clock;
            self.try_start(st, x);
            // If the message already arrived (eager), the recv completes
            // immediately at max(arrival, post) — handled in try_complete.
            self.try_complete_recv(st, x, clock);
        }
        for &x in sends {
            clock += m.o_post + jitter(st);
            st.xs[x as usize].send_posted = clock;
            let eager = self.eager[x as usize];
            self.try_start(st, x);
            if eager {
                // Buffered: the send op completes locally at post time.
                self.op_done(st, self.shapes[x as usize].src, clock);
            }
        }
        if clock > st.rank_clock[rank as usize] {
            st.rank_clock[rank as usize] = clock;
        }
        // Release the posting token (may trigger advance if all ops
        // already completed synchronously).
        self.op_done(st, rank, clock);
    }

    /// Start the transmission if its preconditions are met.
    fn try_start(&self, st: &mut RepState, x: u32) {
        let xi = x as usize;
        let xst = st.xs[xi];
        if xst.started {
            return;
        }
        let sp = xst.send_posted;
        if sp.is_nan() {
            return;
        }
        let ready = if self.eager[xi] {
            sp
        } else {
            let rp = xst.recv_posted;
            if rp.is_nan() {
                return;
            }
            sp.max(rp)
        };
        st.xs[xi].started = true;
        let m = &self.model;
        let sh = self.shapes[xi];
        let dur = self.dur[xi];
        let arrival = if sh.offnode {
            // Store-and-forward over the lanes: the message first holds an
            // egress lane server of the source node, then queues on an
            // ingress lane server of the destination node. The two stages
            // are decoupled (no hold-and-wait), so a saturated receiver
            // delays the arrival without blocking the sender's lane —
            // matching how NICs drain send queues independently.
            let (start_e, end_e) = st.egress[sh.src_node as usize].reserve(ready, dur);
            if let Some(t) = &mut st.trace {
                t.push(Span { src: sh.src, dst: sh.dst, start: start_e, end: end_e, bytes: self.bytes[xi], offnode: true });
            }
            // Wire latency, then queue for the receive side. The ingress
            // occupancy models the receiver lane being busy `dur` per
            // message; overlapping with its own start is fine (cut-through).
            let in_ready = end_e - dur + m.alpha_net;
            let (_s2, end_i) = st.ingress[sh.dst_node as usize].reserve(in_ready, dur);
            end_i
        } else {
            let (start, end) = st.bus[sh.src_node as usize].reserve(ready, dur);
            if let Some(t) = &mut st.trace {
                t.push(Span { src: sh.src, dst: sh.dst, start, end, bytes: self.bytes[xi], offnode: false });
            }
            end + m.alpha_shm
        };
        st.seq = st.seq.wrapping_add(1);
        st.heap.push(Ev::arrive(arrival, x, st.seq));
    }

    fn do_arrive(&self, st: &mut RepState, x: u32, now: f64) {
        st.xs[x as usize].arrived = now;
        if !self.eager[x as usize] {
            // Rendezvous: the sender's op completes at arrival too.
            self.op_done(st, self.shapes[x as usize].src, now);
        }
        self.try_complete_recv(st, x, now);
    }

    fn try_complete_recv(&self, st: &mut RepState, x: u32, now: f64) {
        let arr = st.xs[x as usize].arrived;
        let rp = st.xs[x as usize].recv_posted;
        if arr.is_nan() || rp.is_nan() {
            return;
        }
        let t = arr.max(rp) + self.model.o_match;
        let dst = self.shapes[x as usize].dst;
        self.op_done(st, dst, t.max(now));
    }

    /// One of `rank`'s outstanding round ops completed at time `t`.
    fn op_done(&self, st: &mut RepState, rank: u32, t: f64) {
        let r = rank as usize;
        debug_assert!(st.rank_outstanding[r] > 0);
        st.rank_outstanding[r] -= 1;
        if t > st.rank_clock[r] {
            st.rank_clock[r] = t;
        }
        if st.rank_outstanding[r] == 0 {
            let clock = st.rank_clock[r];
            self.advance(st, rank, clock);
        }
    }

    /// Waitall finished: move to the next participating round.
    fn advance(&self, st: &mut RepState, rank: u32, now: f64) {
        let r = rank as usize;
        st.rank_pos[r] += 1;
        if self.rank_off[r] + st.rank_pos[r] < self.rank_off[r + 1] {
            st.seq = st.seq.wrapping_add(1);
            st.heap.push(Ev::post(now, rank, st.seq));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{alltoall, bcast, scatter};
    use crate::model::CostModel;
    use crate::schedule::Schedule;
    use crate::topology::Cluster;

    fn quiet() -> CostModel {
        let mut m = CostModel::hydra_baseline();
        m.jitter_mean = 0.0;
        m
    }

    fn makespan(s: &Schedule, m: &CostModel) -> f64 {
        Simulator::new(s, m).run(1).makespan
    }

    #[test]
    fn empty_like_schedule_single_rank() {
        // Bcast on p=1: no transfers at all, makespan 0.
        let cl = Cluster::new(1, 1, 1);
        let s = bcast::build(cl, 0, 100, bcast::BcastAlg::Binomial);
        assert_eq!(makespan(&s, &quiet()), 0.0);
    }

    #[test]
    fn single_transfer_cost_matches_model() {
        let cl = Cluster::new(2, 1, 1);
        let m = quiet();
        let c = 10_000u64; // 40 KB > eager: rendezvous
        let s = bcast::build(cl, 0, c, bcast::BcastAlg::Binomial);
        let bytes = (c * 4) as f64;
        // recv posted at o_post (dst), send posted at o_post (src);
        // tx = bytes·β + α; recv completes at arrival + o_match.
        let want = m.o_post + bytes * m.beta_net + m.alpha_net + m.o_match;
        let got = makespan(&s, &m);
        assert!((got - want).abs() < 1e-6, "got {got} want {want}");
    }

    #[test]
    fn eager_send_completes_early() {
        let cl = Cluster::new(2, 1, 1);
        let m = quiet();
        let s = bcast::build(cl, 0, 4, bcast::BcastAlg::Binomial); // 16 B eager
        let got = makespan(&s, &m);
        let bytes = 16.0;
        let want = m.o_post + bytes * m.beta_net + m.alpha_net + m.o_match;
        assert!((got - want).abs() < 1e-6, "got {got} want {want}");
    }

    #[test]
    fn lane_contention_queues() {
        // 1 node of 4 cores sending 4 concurrent off-node messages over 1
        // lane must serialise; with 4 lanes they run in parallel.
        let mk = |lanes: u32| {
            let mut m = quiet();
            m.phys_lanes = lanes;
            m
        };
        let cl = Cluster::new(2, 4, 4);
        // alltoall k-lane: node rounds have 4 concurrent off-node sends
        let s = alltoall::build(cl, 50_000, alltoall::AlltoallAlg::KLane);
        let t1 = makespan(&s, &mk(1));
        let t4 = makespan(&s, &mk(4));
        assert!(t1 > 2.0 * t4, "1 lane {t1} vs 4 lanes {t4}");
    }

    #[test]
    fn more_ports_help_kported_bcast() {
        let cl = Cluster::hydra(2);
        let m = quiet();
        let t1 = makespan(&bcast::build(cl, 0, 100_000, bcast::BcastAlg::KPorted { k: 1 }), &m);
        let t4 = makespan(&bcast::build(cl, 0, 100_000, bcast::BcastAlg::KPorted { k: 4 }), &m);
        assert!(t4 < t1, "k=4 {t4} not faster than k=1 {t1}");
    }

    #[test]
    fn fulllane_beats_binomial_for_large_bcast() {
        // The headline Table 12 shape: full-lane ≫ single-tree for 4 MB.
        let cl = Cluster::hydra(2);
        let m = quiet();
        let tb = makespan(&bcast::build(cl, 0, 1_000_000, bcast::BcastAlg::Binomial), &m);
        let tf = makespan(&bcast::build(cl, 0, 1_000_000, bcast::BcastAlg::FullLane), &m);
        assert!(tf < tb / 2.0, "full-lane {tf} vs binomial {tb}");
    }

    #[test]
    fn scatter_sim_runs_all_algorithms() {
        let cl = Cluster::new(4, 4, 2);
        let m = quiet();
        for alg in [
            scatter::ScatterAlg::KPorted { k: 2 },
            scatter::ScatterAlg::KLane { k: 2 },
            scatter::ScatterAlg::FullLane,
            scatter::ScatterAlg::Binomial,
            scatter::ScatterAlg::Linear,
        ] {
            let s = scatter::build(cl, 0, 64, alg);
            let t = makespan(&s, &m);
            assert!(t > 0.0 && t.is_finite(), "{}: {t}", s.algorithm);
        }
    }

    #[test]
    fn events_counted() {
        let cl = Cluster::new(2, 2, 1);
        let s = bcast::build(cl, 0, 8, bcast::BcastAlg::Binomial);
        let r = Simulator::new(&s, &quiet()).run(3);
        assert!(r.events > 0);
    }

    #[test]
    fn recost_matches_fresh_build_bitwise() {
        // The sweep-engine correctness contract on a couple of shapes;
        // rust/tests/recost_equivalence.rs covers every algorithm.
        let cl = Cluster::new(3, 4, 2);
        let m = CostModel::hydra_baseline(); // jitter on: exercises rng
        for (from, to) in [(1u64, 60_000u64), (60_000, 1), (7, 869)] {
            let mut s = bcast::build(cl, 0, from, bcast::BcastAlg::FullLane);
            let mut sim = Simulator::new(&s, &m);
            s.resize_count(to);
            sim.recost(&s).expect("same structure");
            let fresh = Simulator::new(&bcast::build(cl, 0, to, bcast::BcastAlg::FullLane), &m);
            for seed in [0u64, 42] {
                assert_eq!(sim.run(seed), fresh.run(seed), "{from}->{to} seed {seed}");
            }
        }
    }

    #[test]
    fn recost_count_matches_schedule_recost() {
        // The schedule-free path must agree with resize_count + recost
        // (full per-algorithm coverage: rust/tests/recost_equivalence.rs).
        let cl = Cluster::new(3, 4, 2);
        let m = CostModel::hydra_baseline();
        let mut s = bcast::build(cl, 0, 1, bcast::BcastAlg::FullLane);
        let mut via_schedule = Simulator::new(&s, &m);
        let mut via_count = Simulator::new(&s, &m);
        for c in [7u64, 869, 60_000, 1] {
            s.resize_count(c);
            via_schedule.recost(&s).expect("same structure");
            via_count.recost_count(c);
            for seed in [0u64, 42] {
                assert_eq!(via_count.run(seed), via_schedule.run(seed), "c={c} seed={seed}");
            }
        }
    }

    #[test]
    fn recost_rejects_transfer_count_mismatch() {
        let cl = Cluster::new(2, 4, 2);
        let bcast_s = bcast::build(cl, 0, 64, bcast::BcastAlg::Binomial);
        let a2a_s = alltoall::build(cl, 64, alltoall::AlltoallAlg::Pairwise);
        let mut sim = Simulator::new(&bcast_s, &quiet());
        let err = sim.recost(&a2a_s).unwrap_err();
        assert!(
            matches!(
                err,
                SimError::TransferCountMismatch { .. } | SimError::EndpointMismatch { .. }
            ),
            "{err}"
        );
        assert!(err.to_string().contains("structurally different"), "{err}");
        // The simulator is still usable for its own schedule afterwards.
        let mut good = bcast_s.clone();
        good.resize_count(869);
        sim.recost(&good).expect("own structure still recosts");
    }

    #[test]
    fn recost_rejects_endpoint_mismatch() {
        // Same algorithm, different root: identical transfer count,
        // different endpoints.
        let cl = Cluster::new(2, 4, 2);
        let root0 = bcast::build(cl, 0, 64, bcast::BcastAlg::Binomial);
        let root7 = bcast::build(cl, cl.p() - 1, 64, bcast::BcastAlg::Binomial);
        assert_eq!(root0.num_transfers(), root7.num_transfers());
        let mut sim = Simulator::new(&root0, &quiet());
        let err = sim.recost(&root7).unwrap_err();
        assert!(matches!(err, SimError::EndpointMismatch { .. }), "{err}");
    }

    #[test]
    fn ensure_state_reuse_is_deterministic() {
        // A state reshaped across differently-sized simulators gives the
        // same results as a fresh state.
        let m = CostModel::hydra_baseline();
        let a = Simulator::new(
            &alltoall::build(Cluster::new(4, 4, 2), 64, alltoall::AlltoallAlg::KLane),
            &m,
        );
        let b = Simulator::new(
            &bcast::build(Cluster::new(2, 8, 2), 0, 1000, bcast::BcastAlg::Binomial),
            &m,
        );
        let mut st = a.new_state();
        assert_eq!(a.run_into(&mut st, 5), a.run(5));
        b.ensure_state(&mut st);
        assert_eq!(b.run_into(&mut st, 9), b.run(9));
        a.ensure_state(&mut st);
        assert_eq!(a.run_into(&mut st, 5), a.run(5));
    }

    #[test]
    fn csr_layout_covers_all_ops() {
        // Every transfer id appears exactly once in send_ids and once in
        // recv_ids, and slot offsets are monotone.
        let cl = Cluster::new(3, 5, 2);
        let s = alltoall::build(cl, 16, alltoall::AlltoallAlg::Bruck { k: 2 });
        let sim = Simulator::new(&s, &quiet());
        assert_eq!(sim.send_ids.len(), sim.num_xfers());
        assert_eq!(sim.recv_ids.len(), sim.num_xfers());
        let mut seen_s = vec![false; sim.num_xfers()];
        let mut seen_r = vec![false; sim.num_xfers()];
        for &x in &sim.send_ids {
            assert!(!seen_s[x as usize], "transfer {x} sent twice");
            seen_s[x as usize] = true;
        }
        for &x in &sim.recv_ids {
            assert!(!seen_r[x as usize], "transfer {x} received twice");
            seen_r[x as usize] = true;
        }
        assert!(sim.rank_off.windows(2).all(|w| w[0] <= w[1]));
        assert!(sim.send_off.windows(2).all(|w| w[0] <= w[1]));
        assert!(sim.recv_off.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*sim.rank_off.last().unwrap() as usize, sim.slot_hinted.len());
    }
}

//! The sweep engine: schedule caching + re-costing for count sweeps.
//!
//! The paper's evaluation is a grid of 48 tables sweeping element counts
//! over every (operation, algorithm, k, persona) combination. Naively
//! each cell rebuilds the `Schedule` and re-runs `Simulator::new`; but
//! for the paper's own algorithms the communication structure depends
//! only on (cluster, operation shape, algorithm) — count enters through
//! block sizes alone, the lane-decomposition property observed in
//! *Decomposing Collectives for Exploiting Multi-lane Communication*
//! (arXiv:1910.13373). [`SweepEngine`] therefore builds each distinct
//! shape once, and per cell only:
//!
//! 1. [`Schedule::resize_count`] — rewrite transfer byte sizes in place;
//! 2. [`Simulator::recost`] — rewrite per-transfer `bytes`/`dur`/`eager`;
//! 3. [`Simulator::ensure_state`] — reuse the caller's [`RepState`].
//!
//! Count-*dependent* selections (the native personas switch algorithms
//! and quirks by size) go through [`SweepEngine::measure_uncached`],
//! which still reuses the rep state but rebuilds the schedule.
//!
//! ## Sharing
//!
//! The engine is thread-safe and intended to be shared behind an `Arc`:
//! one engine serves every `harness::run_table` section worker and every
//! table of a `mlane tables` run (the cross-table schedule cache). The
//! shape map is keyed by (cluster, op shape, algorithm, **cost-model
//! fingerprint**), so personas with different models coexist in one
//! engine without cross-talk; each shape sits behind its own lock, so
//! workers sweeping different shapes never contend. [`RepState`] is
//! per-caller (pass `&mut Option<RepState>`), keeping the rep loop
//! allocation-free and thread-local.
//!
//! The cache holds at most [`SweepEngine::max_shapes`] shapes
//! (default [`DEFAULT_CACHE_SHAPES`]; the CLI maps `MLANE_CACHE_SHAPES`
//! through `harness::RunConfig`), evicting the oldest insertion —
//! this bounds memory of long `mlane tables` runs at roughly
//! `max_shapes × largest-shape` (a Hydra-scale alltoall shape is
//! ~10^2 MB; paper tables have ≤ 3 sections, so 8 keeps whole tables
//! plus cross-table reuse without unbounded growth).
//!
//! The recost path is bitwise-identical to a fresh build — the property
//! test `rust/tests/recost_equivalence.rs` is the correctness gate.

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::model::CostModel;
use crate::schedule::Schedule;
use crate::topology::{Cluster, Rank};
use crate::util::stats::Summary;

use super::engine::{RepState, Simulator};
use super::measure_sim;

/// An operation minus its element count: the sweep-invariant part.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpShape {
    Bcast { root: Rank },
    Scatter { root: Rank },
    Gather { root: Rank },
    Allgather,
    Alltoall,
}

/// Algorithm identity for cache keying: family label plus its k
/// parameter (0 for parameterless algorithms). Derived from
/// `algorithms::registry::CollectiveAlgorithm::cache_id`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AlgId {
    pub family: &'static str,
    pub k: u32,
}

/// Cache key: one entry per distinct communication structure.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SweepKey {
    pub cluster: Cluster,
    pub op: OpShape,
    pub alg: AlgId,
}

/// Internal key: the public key plus the cost model's fingerprint, so
/// one shared engine serves several personas without collisions.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct ShapeKey {
    key: SweepKey,
    model_fp: u64,
}

/// Fingerprint of a cost model for cache keying. Runs on the per-cell
/// hot path, so no allocation: hash the raw field bits. The exhaustive
/// destructuring (no `..`) makes adding a `CostModel` field a compile
/// error here, so a new parameter can never silently alias two models.
fn model_fingerprint(model: &CostModel) -> u64 {
    let CostModel {
        alpha_net,
        beta_net,
        phys_lanes,
        eager_net,
        alpha_shm,
        beta_shm,
        bus_servers,
        eager_shm,
        o_post,
        o_match,
        node_collective_call,
        jitter_mean,
    } = *model;
    let mut h = DefaultHasher::new();
    let floats = [
        alpha_net,
        beta_net,
        alpha_shm,
        beta_shm,
        o_post,
        o_match,
        node_collective_call,
        jitter_mean,
    ];
    for f in floats {
        f.to_bits().hash(&mut h);
    }
    (phys_lanes, eager_net, bus_servers, eager_shm).hash(&mut h);
    h.finish()
}

/// Counters for benchmarking and regression tracking (BENCH_engine.json).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepStats {
    /// Cells measured (cached + uncached).
    pub cells: u64,
    /// Full `Schedule` + `Simulator` constructions.
    pub schedules_built: u64,
    /// Cells served by resize + recost of a cached shape.
    pub recosts: u64,
    /// Cells whose cached shape was already at the right count.
    pub cache_hits: u64,
}

#[derive(Default)]
struct Counters {
    cells: AtomicU64,
    schedules_built: AtomicU64,
    recosts: AtomicU64,
    cache_hits: AtomicU64,
}

struct CachedShape {
    schedule: Schedule,
    sim: Simulator,
    /// Element count the cached shape is currently sized for.
    count: u64,
}

/// Lazily-filled per-shape slot; empty until the first successful build.
type Slot = Arc<Mutex<Option<CachedShape>>>;

/// One result cell, paper-style.
#[derive(Clone, Copy, Debug)]
pub struct CellResult {
    pub summary: Summary,
    /// The schedule's human-readable algorithm name.
    pub algorithm: &'static str,
}

/// Shared, thread-safe schedule cache for fast count sweeps. Cheap to
/// construct; clone the `Arc` to share one cache across section workers,
/// tables, and personas.
pub struct SweepEngine {
    shapes: Mutex<ShapeMap>,
    stats: Counters,
    max_shapes: usize,
}

#[derive(Default)]
struct ShapeMap {
    slots: HashMap<ShapeKey, Slot>,
    /// Insertion order, for bounded-size eviction.
    order: VecDeque<ShapeKey>,
}

impl Default for SweepEngine {
    fn default() -> Self {
        Self::new()
    }
}

/// Default bound on cached shapes. The library reads no environment;
/// the CLI maps `MLANE_CACHE_SHAPES` onto
/// `harness::RunConfig::cache_shapes`.
pub const DEFAULT_CACHE_SHAPES: usize = 8;

impl SweepEngine {
    /// An engine with the default shape bound ([`DEFAULT_CACHE_SHAPES`]).
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_CACHE_SHAPES)
    }

    /// An engine holding at most `max_shapes` cached shapes.
    pub fn with_capacity(max_shapes: usize) -> Self {
        SweepEngine {
            shapes: Mutex::new(ShapeMap::default()),
            stats: Counters::default(),
            max_shapes: max_shapes.max(1),
        }
    }

    pub fn stats(&self) -> SweepStats {
        SweepStats {
            cells: self.stats.cells.load(Ordering::Relaxed),
            schedules_built: self.stats.schedules_built.load(Ordering::Relaxed),
            recosts: self.stats.recosts.load(Ordering::Relaxed),
            cache_hits: self.stats.cache_hits.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct cached communication structures. Snapshots
    /// the slot list first — probing a slot can block behind an
    /// in-flight measure, and the map lock must not be held then (it
    /// would stall every other worker's cache lookup).
    pub fn cached_shapes(&self) -> usize {
        let slots: Vec<Slot> = self.shapes.lock().unwrap().slots.values().cloned().collect();
        slots.iter().filter(|s| s.lock().unwrap().is_some()).count()
    }

    /// Cache-size bound (shapes).
    pub fn max_shapes(&self) -> usize {
        self.max_shapes
    }

    /// Fetch (or create, evicting the oldest entry when full) the slot
    /// for a key. The map lock is held only for this lookup; building
    /// and measuring happen under the slot's own lock.
    fn slot(&self, skey: ShapeKey) -> Slot {
        let mut map = self.shapes.lock().unwrap();
        if let Some(slot) = map.slots.get(&skey) {
            return slot.clone();
        }
        if map.slots.len() >= self.max_shapes {
            if let Some(old) = map.order.pop_front() {
                // In-flight users keep the shape alive via their Arc;
                // it drops when the last of them finishes its cell.
                map.slots.remove(&old);
            }
        }
        let slot: Slot = Arc::new(Mutex::new(None));
        map.slots.insert(skey, slot.clone());
        map.order.push_back(skey);
        slot
    }

    /// Drop `skey` from the map if it still refers to `slot` — used to
    /// un-register a slot whose build failed, so it cannot pin cache
    /// capacity (and evict live shapes) forever.
    fn forget(&self, skey: ShapeKey, slot: &Slot) {
        let mut map = self.shapes.lock().unwrap();
        if map.slots.get(&skey).is_some_and(|s| Arc::ptr_eq(s, slot)) {
            map.slots.remove(&skey);
            map.order.retain(|k| *k != skey);
        }
    }

    /// Measure one cell of a count sweep for a count-invariant
    /// algorithm. `build` constructs the schedule for a given count and
    /// is only called when `key` misses the cache (a build error leaves
    /// the cache unchanged); subsequent counts are served by resize +
    /// recost. `state` is the caller's reusable rep state — pass the
    /// same `Option` across cells to keep the rep loop allocation-free.
    #[allow(clippy::too_many_arguments)]
    pub fn measure<E>(
        &self,
        key: SweepKey,
        count: u64,
        model: &CostModel,
        reps: usize,
        warmup: usize,
        seed: u64,
        state: &mut Option<RepState>,
        build: impl FnOnce(u64) -> Result<Schedule, E>,
    ) -> Result<CellResult, E> {
        let skey = ShapeKey { key, model_fp: model_fingerprint(model) };
        let slot = self.slot(skey);
        let mut guard = slot.lock().unwrap();
        let mut built = false;
        let mut recosted = false;
        if guard.is_none() {
            built = true;
            let schedule = match build(count) {
                Ok(s) => s,
                Err(e) => {
                    // Waiters on this slot keep their Arc and retry
                    // the build themselves; the map entry must go.
                    drop(guard);
                    self.forget(skey, &slot);
                    return Err(e);
                }
            };
            let sim = Simulator::new(&schedule, model);
            *guard = Some(CachedShape { schedule, sim, count });
        } else {
            let shape = guard.as_mut().expect("checked above");
            // Hard assert (cheap vs. a rep loop): a fingerprint
            // collision would silently produce timings under the
            // wrong model parameters otherwise.
            assert_eq!(
                shape.sim.model(),
                model,
                "sweep key reused with a different cost model"
            );
            if shape.count != count {
                recosted = true;
                shape.schedule.resize_count(count);
                shape.sim.recost(&shape.schedule);
                shape.count = count;
            }
        }
        let shape = guard.as_ref().expect("slot filled above");
        let st = state.get_or_insert_with(|| shape.sim.new_state());
        shape.sim.ensure_state(st);
        let summary = measure_sim(&shape.sim, st, reps, warmup, seed);
        let algorithm = shape.schedule.algorithm;
        self.stats.cells.fetch_add(1, Ordering::Relaxed);
        if built {
            self.stats.schedules_built.fetch_add(1, Ordering::Relaxed);
        } else if recosted {
            self.stats.recosts.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        Ok(CellResult { summary, algorithm })
    }

    /// Measure a prebuilt schedule without caching it (count-dependent
    /// algorithm selection — native personas). Still reuses the caller's
    /// rep state, so the rep loop stays allocation-free.
    pub fn measure_uncached(
        &self,
        schedule: &Schedule,
        model: &CostModel,
        reps: usize,
        warmup: usize,
        seed: u64,
        state: &mut Option<RepState>,
    ) -> CellResult {
        let sim = Simulator::new(schedule, model);
        let st = state.get_or_insert_with(|| sim.new_state());
        sim.ensure_state(st);
        let summary = measure_sim(&sim, st, reps, warmup, seed);
        self.stats.cells.fetch_add(1, Ordering::Relaxed);
        self.stats.schedules_built.fetch_add(1, Ordering::Relaxed);
        CellResult { summary, algorithm: schedule.algorithm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bcast::{self, BcastAlg};
    use crate::model::CostModel;
    use crate::sim;
    use crate::topology::Cluster;

    /// Infallible build helper for the tests.
    fn ok(s: Schedule) -> Result<Schedule, std::convert::Infallible> {
        Ok(s)
    }

    fn key(cl: Cluster) -> SweepKey {
        SweepKey {
            cluster: cl,
            op: OpShape::Bcast { root: 0 },
            alg: AlgId { family: "klane", k: 2 },
        }
    }

    fn build(cl: Cluster) -> impl Fn(u64) -> Result<Schedule, std::convert::Infallible> {
        move |c| ok(bcast::build(cl, 0, c, BcastAlg::KLane { k: 2, two_phase: false }))
    }

    #[test]
    fn sweep_matches_per_cell_rebuild() {
        let cl = Cluster::new(4, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        for &c in &[1u64, 100, 6000, 100_000, 100] {
            let cell = eng.measure(key(cl), c, &m, 4, 1, 7, &mut st, build(cl)).unwrap();
            let fresh = sim::measure(
                &bcast::build(cl, 0, c, BcastAlg::KLane { k: 2, two_phase: false }),
                &m,
                4,
                1,
                7,
            );
            assert_eq!(cell.summary, fresh, "c = {c}");
            assert_eq!(cell.algorithm, "bcast/k-lane");
        }
    }

    #[test]
    fn cache_counters_track_the_paths() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        eng.measure(key(cl), 1, &m, 2, 0, 1, &mut st, build(cl)).unwrap(); // build
        eng.measure(key(cl), 50, &m, 2, 0, 1, &mut st, build(cl)).unwrap(); // recost
        eng.measure(key(cl), 50, &m, 2, 0, 1, &mut st, build(cl)).unwrap(); // hit
        eng.measure(key(cl), 1, &m, 2, 0, 1, &mut st, build(cl)).unwrap(); // recost back
        let st = eng.stats();
        assert_eq!(
            (st.cells, st.schedules_built, st.recosts, st.cache_hits),
            (4, 1, 2, 1)
        );
        assert_eq!(eng.cached_shapes(), 1);
    }

    #[test]
    fn uncached_path_reuses_state_but_rebuilds() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        for &c in &[1u64, 16_384] {
            let cell = eng.measure_uncached(
                &bcast::build(cl, 0, c, BcastAlg::Binomial),
                &m,
                3,
                1,
                9,
                &mut st,
            );
            let fresh =
                sim::measure(&bcast::build(cl, 0, c, BcastAlg::Binomial), &m, 3, 1, 9);
            assert_eq!(cell.summary, fresh, "c = {c}");
        }
        assert_eq!(eng.stats().schedules_built, 2);
        assert_eq!(eng.cached_shapes(), 0);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        let a = eng.measure(key(cl), 64, &m, 2, 0, 3, &mut st, build(cl)).unwrap();
        let mut k2 = key(cl);
        k2.alg = AlgId { family: "kported", k: 2 };
        let b = eng
            .measure(k2, 64, &m, 2, 0, 3, &mut st, |c| {
                ok(bcast::build(cl, 0, c, BcastAlg::KPorted { k: 2 }))
            })
            .unwrap();
        assert_eq!(eng.cached_shapes(), 2);
        assert_ne!(a.algorithm, b.algorithm);
    }

    #[test]
    fn distinct_models_shard_the_same_key() {
        // Two personas sweeping the same (cluster, op, alg) through one
        // shared engine must each get their own cached shape.
        let cl = Cluster::new(2, 4, 2);
        let m1 = CostModel::hydra_baseline();
        let mut m2 = CostModel::hydra_baseline();
        m2.alpha_net *= 2.0;
        let eng = SweepEngine::new();
        let mut st = None;
        let a = eng.measure(key(cl), 64, &m1, 2, 0, 3, &mut st, build(cl)).unwrap();
        let b = eng.measure(key(cl), 64, &m2, 2, 0, 3, &mut st, build(cl)).unwrap();
        assert_eq!(eng.cached_shapes(), 2);
        assert_eq!(eng.stats().schedules_built, 2);
        assert!(b.summary.avg > a.summary.avg, "slower model must cost more");
        // Re-measuring under each model hits its own shape.
        eng.measure(key(cl), 64, &m1, 2, 0, 3, &mut st, build(cl)).unwrap();
        assert_eq!(eng.stats().cache_hits, 1);
    }

    #[test]
    fn build_errors_propagate_and_leave_cache_empty() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        let err = eng
            .measure(key(cl), 8, &m, 2, 0, 1, &mut st, |_| Err::<Schedule, _>("nope"))
            .unwrap_err();
        assert_eq!(err, "nope");
        assert_eq!(eng.cached_shapes(), 0);
        assert_eq!(eng.stats().cells, 0);
        // The key is retried on the next attempt.
        eng.measure(key(cl), 8, &m, 2, 0, 1, &mut st, build(cl)).unwrap();
        assert_eq!(eng.cached_shapes(), 1);
    }

    #[test]
    fn failed_builds_do_not_pin_cache_capacity() {
        // A failing key must be fully un-registered: distinct failing
        // keys must never evict a live shape from a bounded cache.
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::with_capacity(2);
        let mut st = None;
        eng.measure(key(cl), 8, &m, 2, 0, 1, &mut st, build(cl)).unwrap();
        for k in 10..=11u32 {
            let mut bad = key(cl);
            bad.alg = AlgId { family: "broken", k };
            eng.measure(bad, 8, &m, 2, 0, 1, &mut st, |_| Err::<Schedule, _>("nope"))
                .unwrap_err();
        }
        // Same key, same count: must be a cache hit, not a rebuild.
        eng.measure(key(cl), 8, &m, 2, 0, 1, &mut st, build(cl)).unwrap();
        let stats = eng.stats();
        assert_eq!(stats.schedules_built, 1, "{stats:?}");
        assert_eq!(stats.cache_hits, 1, "{stats:?}");
    }

    #[test]
    fn eviction_bounds_the_shape_count() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::with_capacity(2);
        let mut st = None;
        for k in 1..=3u32 {
            let mut key = key(cl);
            key.alg = AlgId { family: "kported", k };
            eng.measure(key, 8, &m, 1, 0, 1, &mut st, |c| {
                ok(bcast::build(cl, 0, c, BcastAlg::KPorted { k }))
            })
            .unwrap();
        }
        assert_eq!(eng.stats().schedules_built, 3);
        assert!(eng.cached_shapes() <= 2, "{}", eng.cached_shapes());
    }

    #[test]
    fn shared_engine_is_safe_across_threads() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = std::sync::Arc::new(SweepEngine::new());
        std::thread::scope(|scope| {
            for t in 0..4u32 {
                let eng = eng.clone();
                scope.spawn(move || {
                    let k = t % 2 + 1;
                    let mut st = None;
                    let mut key = key(cl);
                    key.alg = AlgId { family: "kported", k };
                    for &c in &[1u64, 64, 1000] {
                        let cell = eng
                            .measure(key, c, &m, 2, 0, 5, &mut st, |c| {
                                ok(bcast::build(cl, 0, c, BcastAlg::KPorted { k }))
                            })
                            .unwrap();
                        let fresh = sim::measure(
                            &bcast::build(cl, 0, c, BcastAlg::KPorted { k }),
                            &m,
                            2,
                            0,
                            5,
                        );
                        assert_eq!(cell.summary, fresh, "k={k} c={c}");
                    }
                });
            }
        });
        assert_eq!(eng.cached_shapes(), 2);
        assert_eq!(eng.stats().cells, 12);
    }
}

//! The sweep engine: schedule caching + re-costing for count sweeps.
//!
//! The paper's evaluation is a grid of 48 tables sweeping element counts
//! over every (operation, algorithm, k, persona) combination. Naively
//! each cell rebuilds the `Schedule` and re-runs `Simulator::new`; but
//! for the paper's own algorithms the communication structure depends
//! only on (cluster, operation shape, algorithm) — count enters through
//! block sizes alone, the lane-decomposition property observed in
//! *Decomposing Collectives for Exploiting Multi-lane Communication*
//! (arXiv:1910.13373). [`SweepEngine`] therefore builds each distinct
//! shape once, and per cell only:
//!
//! 1. [`Schedule::resize_count`] — rewrite transfer byte sizes in place;
//! 2. [`Simulator::recost`] — rewrite per-transfer `bytes`/`dur`/`eager`;
//! 3. [`Simulator::ensure_state`] — reuse the [`RepState`] allocations.
//!
//! Count-*dependent* selections (the native personas switch algorithms
//! and quirks by size) go through [`SweepEngine::measure_uncached`],
//! which still reuses the rep state but rebuilds the schedule.
//!
//! The recost path is bitwise-identical to a fresh build — the property
//! test `rust/tests/recost_equivalence.rs` is the correctness gate.

use std::collections::hash_map::Entry as MapEntry;
use std::collections::HashMap;

use crate::model::CostModel;
use crate::schedule::Schedule;
use crate::topology::{Cluster, Rank};
use crate::util::stats::Summary;

use super::engine::{RepState, Simulator};
use super::measure_sim;

/// An operation minus its element count: the sweep-invariant part.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpShape {
    Bcast { root: Rank },
    Scatter { root: Rank },
    Gather { root: Rank },
    Allgather,
    Alltoall,
}

/// Algorithm identity for cache keying: family label plus its k
/// parameter (0 for parameterless algorithms).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AlgId {
    pub family: &'static str,
    pub k: u32,
}

/// Cache key: one entry per distinct communication structure.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SweepKey {
    pub cluster: Cluster,
    pub op: OpShape,
    pub alg: AlgId,
}

/// Counters for benchmarking and regression tracking (BENCH_engine.json).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepStats {
    /// Cells measured (cached + uncached).
    pub cells: u64,
    /// Full `Schedule` + `Simulator` constructions.
    pub schedules_built: u64,
    /// Cells served by resize + recost of a cached shape.
    pub recosts: u64,
    /// Cells whose cached shape was already at the right count.
    pub cache_hits: u64,
}

struct CachedShape {
    schedule: Schedule,
    sim: Simulator,
    /// Element count the cached shape is currently sized for.
    count: u64,
}

/// One result cell, paper-style.
#[derive(Clone, Copy, Debug)]
pub struct CellResult {
    pub summary: Summary,
    /// The schedule's human-readable algorithm name.
    pub algorithm: &'static str,
}

/// Schedule cache + shared rep state for fast count sweeps. Cheap to
/// construct; intended to live as long as a sweep (one per
/// `coordinator::Collectives`, one per table section worker).
#[derive(Default)]
pub struct SweepEngine {
    shapes: HashMap<SweepKey, CachedShape>,
    /// Shared across cells; reshaped by `Simulator::ensure_state`.
    state: Option<RepState>,
    stats: SweepStats,
}

impl SweepEngine {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn stats(&self) -> SweepStats {
        self.stats
    }

    /// Number of distinct cached communication structures.
    pub fn cached_shapes(&self) -> usize {
        self.shapes.len()
    }

    /// Measure one cell of a count sweep for a count-invariant
    /// algorithm. `build` constructs the schedule for a given count and
    /// is only called when `key` misses the cache; subsequent counts are
    /// served by resize + recost.
    #[allow(clippy::too_many_arguments)]
    pub fn measure(
        &mut self,
        key: SweepKey,
        count: u64,
        model: &CostModel,
        reps: usize,
        warmup: usize,
        seed: u64,
        build: impl FnOnce(u64) -> Schedule,
    ) -> CellResult {
        let mut built = false;
        let mut recosted = false;
        let entry = match self.shapes.entry(key) {
            MapEntry::Occupied(e) => e.into_mut(),
            MapEntry::Vacant(v) => {
                built = true;
                let schedule = build(count);
                let sim = Simulator::new(&schedule, model);
                v.insert(CachedShape { schedule, sim, count })
            }
        };
        // Hard assert (cheap vs. a rep loop): a stale model would
        // silently produce timings under the old parameters otherwise —
        // e.g. mutating a pub `persona.model` between runs.
        assert_eq!(
            entry.sim.model(),
            model,
            "sweep key reused with a different cost model — \
             build a fresh engine/Collectives per model"
        );
        if entry.count != count {
            recosted = true;
            entry.schedule.resize_count(count);
            entry.sim.recost(&entry.schedule);
            entry.count = count;
        }
        let st = self.state.get_or_insert_with(|| entry.sim.new_state());
        entry.sim.ensure_state(st);
        let summary = measure_sim(&entry.sim, st, reps, warmup, seed);
        let algorithm = entry.schedule.algorithm;
        self.stats.cells += 1;
        if built {
            self.stats.schedules_built += 1;
        } else if recosted {
            self.stats.recosts += 1;
        } else {
            self.stats.cache_hits += 1;
        }
        CellResult { summary, algorithm }
    }

    /// Measure a prebuilt schedule without caching it (count-dependent
    /// algorithm selection — native personas). Still reuses the shared
    /// rep state, so the rep loop stays allocation-free.
    pub fn measure_uncached(
        &mut self,
        schedule: &Schedule,
        model: &CostModel,
        reps: usize,
        warmup: usize,
        seed: u64,
    ) -> CellResult {
        let sim = Simulator::new(schedule, model);
        let st = self.state.get_or_insert_with(|| sim.new_state());
        sim.ensure_state(st);
        let summary = measure_sim(&sim, st, reps, warmup, seed);
        self.stats.cells += 1;
        self.stats.schedules_built += 1;
        CellResult { summary, algorithm: schedule.algorithm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bcast::{self, BcastAlg};
    use crate::model::CostModel;
    use crate::sim;
    use crate::topology::Cluster;

    fn key(cl: Cluster) -> SweepKey {
        SweepKey {
            cluster: cl,
            op: OpShape::Bcast { root: 0 },
            alg: AlgId { family: "klane", k: 2 },
        }
    }

    fn build(cl: Cluster) -> impl Fn(u64) -> Schedule {
        move |c| bcast::build(cl, 0, c, BcastAlg::KLane { k: 2, two_phase: false })
    }

    #[test]
    fn sweep_matches_per_cell_rebuild() {
        let cl = Cluster::new(4, 4, 2);
        let m = CostModel::hydra_baseline();
        let mut eng = SweepEngine::new();
        for &c in &[1u64, 100, 6000, 100_000, 100] {
            let cell = eng.measure(key(cl), c, &m, 4, 1, 7, build(cl));
            let fresh = sim::measure(
                &bcast::build(cl, 0, c, BcastAlg::KLane { k: 2, two_phase: false }),
                &m,
                4,
                1,
                7,
            );
            assert_eq!(cell.summary, fresh, "c = {c}");
            assert_eq!(cell.algorithm, "bcast/k-lane");
        }
    }

    #[test]
    fn cache_counters_track_the_paths() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let mut eng = SweepEngine::new();
        eng.measure(key(cl), 1, &m, 2, 0, 1, build(cl)); // build
        eng.measure(key(cl), 50, &m, 2, 0, 1, build(cl)); // recost
        eng.measure(key(cl), 50, &m, 2, 0, 1, build(cl)); // hit
        eng.measure(key(cl), 1, &m, 2, 0, 1, build(cl)); // recost back
        let st = eng.stats();
        assert_eq!(
            (st.cells, st.schedules_built, st.recosts, st.cache_hits),
            (4, 1, 2, 1)
        );
        assert_eq!(eng.cached_shapes(), 1);
    }

    #[test]
    fn uncached_path_reuses_state_but_rebuilds() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let mut eng = SweepEngine::new();
        for &c in &[1u64, 16_384] {
            let cell = eng.measure_uncached(
                &bcast::build(cl, 0, c, BcastAlg::Binomial),
                &m,
                3,
                1,
                9,
            );
            let fresh =
                sim::measure(&bcast::build(cl, 0, c, BcastAlg::Binomial), &m, 3, 1, 9);
            assert_eq!(cell.summary, fresh, "c = {c}");
        }
        assert_eq!(eng.stats().schedules_built, 2);
        assert_eq!(eng.cached_shapes(), 0);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let mut eng = SweepEngine::new();
        let a = eng.measure(key(cl), 64, &m, 2, 0, 3, build(cl));
        let mut k2 = key(cl);
        k2.alg = AlgId { family: "kported", k: 2 };
        let b = eng.measure(k2, 64, &m, 2, 0, 3, |c| {
            bcast::build(cl, 0, c, BcastAlg::KPorted { k: 2 })
        });
        assert_eq!(eng.cached_shapes(), 2);
        assert_ne!(a.algorithm, b.algorithm);
    }
}

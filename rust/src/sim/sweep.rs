//! The sweep engine: schedule caching + re-costing for count sweeps.
//!
//! The paper's evaluation is a grid of 48 tables sweeping element counts
//! over every (operation, algorithm, k, persona) combination. Naively
//! each cell rebuilds the `Schedule` and re-runs `Simulator::new`; but
//! for the paper's own algorithms the communication structure depends
//! only on (cluster, operation shape, algorithm) — count enters through
//! block sizes alone, the lane-decomposition property observed in
//! *Decomposing Collectives for Exploiting Multi-lane Communication*
//! (arXiv:1910.13373). [`SweepEngine`] therefore builds each distinct
//! shape once, and per cell only:
//!
//! 1. [`Simulator::recost_count`] — rewrite the flat per-transfer
//!    `bytes`/`dur`/`eager` arrays for the new count (schedule-free);
//! 2. [`Simulator::ensure_state`] — reuse the caller's [`RepState`].
//!
//! [`SweepEngine::measure_series`] is the batched form and the single
//! code path ([`SweepEngine::measure`] is a one-count series): the
//! cached shape is resolved *once* per series — one cache lookup, one
//! slot lock acquisition, one batched stats update — and the count grid
//! is walked in a tight loop over the flattened simulator. With a warm
//! shape and a reused [`RepState`], a series performs zero steady-state
//! allocations (gated by `rust/tests/series_alloc.rs`).
//!
//! Count-*dependent* selections (the native personas switch algorithms
//! and quirks by size) go through [`SweepEngine::measure_uncached`],
//! which still reuses the rep state but rebuilds the schedule.
//!
//! ## Sharing
//!
//! The engine is thread-safe and intended to be shared behind an `Arc`:
//! one engine serves every `harness::run_table` section worker and every
//! table of a `mlane tables` run (the cross-table schedule cache). The
//! shape map is keyed by (cluster, op shape, algorithm, **cost-model
//! fingerprint**), so personas with different models coexist in one
//! engine without cross-talk; each shape sits behind its own lock, so
//! workers sweeping different shapes never contend. [`RepState`] is
//! per-caller (pass `&mut Option<RepState>`), keeping the rep loop
//! allocation-free and thread-local.
//!
//! The cache holds at most [`SweepEngine::max_shapes`] shapes
//! (default [`DEFAULT_CACHE_SHAPES`]; the CLI maps `MLANE_CACHE_SHAPES`
//! through `harness::RunConfig`), evicting the oldest insertion —
//! this bounds memory of long `mlane tables` runs at roughly
//! `max_shapes × largest-shape` (a Hydra-scale alltoall shape is
//! ~10^2 MB; paper tables have ≤ 3 sections, so 8 keeps whole tables
//! plus cross-table reuse without unbounded growth).
//!
//! The recost path is bitwise-identical to a fresh build — the property
//! test `rust/tests/recost_equivalence.rs` is the correctness gate.

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::model::CostModel;
use crate::netsim::{NetError, NetSim, Scenario};
use crate::schedule::Schedule;
use crate::topology::{Cluster, Rank};
use crate::util::stats::Summary;

use super::engine::{RepState, SimError, Simulator};
use super::{measure_backend, measure_sim};

/// Error from [`SweepEngine::measure`] / [`SweepEngine::measure_series`]:
/// either the caller's build closure failed (the only user-reachable
/// case), or the cached schedule and its simulator disagreed
/// structurally — the cache-identity failure that used to be a panic
/// inside `Simulator::recost`, surfaced as a typed error instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MeasureError<E> {
    /// The schedule build closure failed on a cache miss.
    Build(E),
    /// Cached schedule and simulator are out of sync (an engine bug,
    /// not a user error — reported rather than panicking).
    Sim(SimError),
    /// The event-driven network backend rejected the scenario or hit
    /// a drop-tail overflow mid-measurement.
    Net(NetError),
}

impl<E: std::fmt::Display> std::fmt::Display for MeasureError<E> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MeasureError::Build(e) => e.fmt(f),
            MeasureError::Sim(e) => write!(f, "sweep cache: {e}"),
            MeasureError::Net(e) => e.fmt(f),
        }
    }
}

impl<E: std::error::Error + 'static> std::error::Error for MeasureError<E> {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MeasureError::Build(e) => Some(e),
            MeasureError::Sim(e) => Some(e),
            MeasureError::Net(e) => Some(e),
        }
    }
}

/// An operation minus its element count: the sweep-invariant part.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpShape {
    Bcast { root: Rank },
    Scatter { root: Rank },
    Gather { root: Rank },
    Allgather,
    Alltoall,
}

/// Algorithm identity for cache keying: family label plus its k
/// parameter (0 for parameterless algorithms). Derived from
/// `algorithms::registry::CollectiveAlgorithm::cache_id`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AlgId {
    pub family: &'static str,
    pub k: u32,
}

/// Cache key: one entry per distinct communication structure.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SweepKey {
    pub cluster: Cluster,
    pub op: OpShape,
    pub alg: AlgId,
}

/// Internal key: the public key plus the cost model's fingerprint, so
/// one shared engine serves several personas without collisions.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct ShapeKey {
    key: SweepKey,
    model_fp: u64,
}

/// Fingerprint of a cost model for cache keying. Runs on the per-cell
/// hot path, so no allocation: hash the raw field bits. The exhaustive
/// destructuring (no `..`) makes adding a `CostModel` field a compile
/// error here, so a new parameter can never silently alias two models.
fn model_fingerprint(model: &CostModel) -> u64 {
    let CostModel {
        alpha_net,
        beta_net,
        phys_lanes,
        eager_net,
        alpha_shm,
        beta_shm,
        bus_servers,
        eager_shm,
        o_post,
        o_match,
        node_collective_call,
        jitter_mean,
    } = *model;
    let mut h = DefaultHasher::new();
    let floats = [
        alpha_net,
        beta_net,
        alpha_shm,
        beta_shm,
        o_post,
        o_match,
        node_collective_call,
        jitter_mean,
    ];
    for f in floats {
        f.to_bits().hash(&mut h);
    }
    (phys_lanes, eager_net, bus_servers, eager_shm).hash(&mut h);
    h.finish()
}

/// Counters for benchmarking and regression tracking (BENCH_engine.json).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepStats {
    /// Cells measured (cached + uncached).
    pub cells: u64,
    /// Full `Schedule` + `Simulator` constructions.
    pub schedules_built: u64,
    /// Cells served by resize + recost of a cached shape.
    pub recosts: u64,
    /// Cells whose cached shape was already at the right count.
    pub cache_hits: u64,
}

#[derive(Default)]
struct Counters {
    cells: AtomicU64,
    schedules_built: AtomicU64,
    recosts: AtomicU64,
    cache_hits: AtomicU64,
}

struct CachedShape {
    schedule: Schedule,
    sim: Simulator,
    /// Element count the cached shape is currently sized for.
    count: u64,
}

/// Lazily-filled per-shape slot; empty until the first successful build.
type Slot = Arc<Mutex<Option<CachedShape>>>;

/// One result cell, paper-style.
#[derive(Clone, Copy, Debug)]
pub struct CellResult {
    pub summary: Summary,
    /// The schedule's human-readable algorithm name.
    pub algorithm: &'static str,
}

/// Shared, thread-safe schedule cache for fast count sweeps. Cheap to
/// construct; clone the `Arc` to share one cache across section workers,
/// tables, and personas.
pub struct SweepEngine {
    shapes: Mutex<ShapeMap>,
    stats: Counters,
    max_shapes: usize,
}

#[derive(Default)]
struct ShapeMap {
    slots: HashMap<ShapeKey, Slot>,
    /// Insertion order, for bounded-size eviction.
    order: VecDeque<ShapeKey>,
}

impl Default for SweepEngine {
    fn default() -> Self {
        Self::new()
    }
}

/// Default bound on cached shapes. The library reads no environment;
/// the CLI maps `MLANE_CACHE_SHAPES` onto
/// `harness::RunConfig::cache_shapes`.
pub const DEFAULT_CACHE_SHAPES: usize = 8;

impl SweepEngine {
    /// An engine with the default shape bound ([`DEFAULT_CACHE_SHAPES`]).
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_CACHE_SHAPES)
    }

    /// An engine holding at most `max_shapes` cached shapes.
    pub fn with_capacity(max_shapes: usize) -> Self {
        SweepEngine {
            shapes: Mutex::new(ShapeMap::default()),
            stats: Counters::default(),
            max_shapes: max_shapes.max(1),
        }
    }

    pub fn stats(&self) -> SweepStats {
        SweepStats {
            cells: self.stats.cells.load(Ordering::Relaxed),
            schedules_built: self.stats.schedules_built.load(Ordering::Relaxed),
            recosts: self.stats.recosts.load(Ordering::Relaxed),
            cache_hits: self.stats.cache_hits.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct cached communication structures. Snapshots
    /// the slot list first — probing a slot can block behind an
    /// in-flight measure, and the map lock must not be held then (it
    /// would stall every other worker's cache lookup).
    pub fn cached_shapes(&self) -> usize {
        let slots: Vec<Slot> = self.shapes.lock().unwrap().slots.values().cloned().collect();
        slots.iter().filter(|s| s.lock().unwrap().is_some()).count()
    }

    /// Cache-size bound (shapes).
    pub fn max_shapes(&self) -> usize {
        self.max_shapes
    }

    /// Fetch (or create, evicting the oldest entry when full) the slot
    /// for a key. The map lock is held only for this lookup; building
    /// and measuring happen under the slot's own lock.
    fn slot(&self, skey: ShapeKey) -> Slot {
        let mut map = self.shapes.lock().unwrap();
        if let Some(slot) = map.slots.get(&skey) {
            return slot.clone();
        }
        if map.slots.len() >= self.max_shapes {
            if let Some(old) = map.order.pop_front() {
                // In-flight users keep the shape alive via their Arc;
                // it drops when the last of them finishes its cell.
                map.slots.remove(&old);
            }
        }
        let slot: Slot = Arc::new(Mutex::new(None));
        map.slots.insert(skey, slot.clone());
        map.order.push_back(skey);
        slot
    }

    /// Drop `skey` from the map if it still refers to `slot` — used to
    /// un-register a slot whose build failed, so it cannot pin cache
    /// capacity (and evict live shapes) forever.
    fn forget(&self, skey: ShapeKey, slot: &Slot) {
        let mut map = self.shapes.lock().unwrap();
        if map.slots.get(&skey).is_some_and(|s| Arc::ptr_eq(s, slot)) {
            map.slots.remove(&skey);
            map.order.retain(|k| *k != skey);
        }
    }

    /// Measure one cell of a count sweep for a count-invariant
    /// algorithm. `build` constructs the schedule for a given count and
    /// is only called when `key` misses the cache (a build error leaves
    /// the cache unchanged); subsequent counts are served by recost.
    /// `state` is the caller's reusable rep state — pass the same
    /// `Option` across cells to keep the rep loop allocation-free.
    ///
    /// A one-count [`SweepEngine::measure_series`]: same code path,
    /// same stats accounting, same bitwise results.
    #[allow(clippy::too_many_arguments)]
    pub fn measure<E>(
        &self,
        key: SweepKey,
        count: u64,
        model: &CostModel,
        reps: usize,
        warmup: usize,
        seed: u64,
        state: &mut Option<RepState>,
        build: impl FnOnce(u64) -> Result<Schedule, E>,
    ) -> Result<CellResult, MeasureError<E>> {
        let mut out = Vec::with_capacity(1);
        self.measure_series_into(
            key,
            std::slice::from_ref(&count),
            model,
            reps,
            warmup,
            seed,
            state,
            &mut out,
            build,
        )?;
        Ok(out.pop().expect("one count in, one cell out"))
    }

    /// Measure a whole count series against one cached shape: resolve
    /// the slot once (one cache lookup, one lock acquisition), then walk
    /// `counts` in a single pass over the flattened simulator —
    /// [`Simulator::recost_count`] per distinct count, [`measure_sim`]
    /// per cell — and batch the stats counters (one `fetch_add` per
    /// counter for the whole series). Results are bitwise-identical to
    /// per-cell [`SweepEngine::measure`] calls, cell for cell (gated by
    /// `rust/tests/series_equivalence.rs`).
    #[allow(clippy::too_many_arguments)]
    pub fn measure_series<E>(
        &self,
        key: SweepKey,
        counts: &[u64],
        model: &CostModel,
        reps: usize,
        warmup: usize,
        seed: u64,
        state: &mut Option<RepState>,
        build: impl FnOnce(u64) -> Result<Schedule, E>,
    ) -> Result<Vec<CellResult>, MeasureError<E>> {
        let mut out = Vec::with_capacity(counts.len());
        self.measure_series_into(key, counts, model, reps, warmup, seed, state, &mut out, build)?;
        Ok(out)
    }

    /// [`SweepEngine::measure_series`] into a caller-owned buffer:
    /// appends one [`CellResult`] per count to `out`, reusing its
    /// capacity — with a warm shape, a warm `state` and a pre-sized
    /// `out`, the entire series performs zero allocations (see
    /// `rust/tests/series_alloc.rs`). An empty `counts` is a no-op.
    #[allow(clippy::too_many_arguments)]
    pub fn measure_series_into<E>(
        &self,
        key: SweepKey,
        counts: &[u64],
        model: &CostModel,
        reps: usize,
        warmup: usize,
        seed: u64,
        state: &mut Option<RepState>,
        out: &mut Vec<CellResult>,
        build: impl FnOnce(u64) -> Result<Schedule, E>,
    ) -> Result<(), MeasureError<E>> {
        let Some(&first) = counts.first() else {
            return Ok(());
        };
        let skey = ShapeKey { key, model_fp: model_fingerprint(model) };
        let slot = self.slot(skey);
        let mut guard = slot.lock().unwrap();
        let built = guard.is_none();
        if built {
            let schedule = match build(first) {
                Ok(s) => s,
                Err(e) => {
                    // Waiters on this slot keep their Arc and retry
                    // the build themselves; the map entry must go.
                    drop(guard);
                    self.forget(skey, &slot);
                    return Err(MeasureError::Build(e));
                }
            };
            let sim = Simulator::new(&schedule, model);
            *guard = Some(CachedShape { schedule, sim, count: first });
        } else {
            let shape = guard.as_ref().expect("checked above");
            // Hard assert (cheap vs. a rep loop): a fingerprint
            // collision would silently produce timings under the
            // wrong model parameters otherwise.
            assert_eq!(shape.sim.model(), model, "sweep key reused with a different cost model");
            // The cache-identity check recost used to panic on: a
            // cached schedule that desynced from its simulator is a
            // typed error now.
            let (in_sim, in_sched) = (shape.sim.num_xfers(), shape.schedule.num_transfers());
            if in_sim != in_sched {
                return Err(MeasureError::Sim(SimError::TransferCountMismatch {
                    simulator: in_sim,
                    schedule: in_sched,
                }));
            }
        }
        let shape = guard.as_mut().expect("slot filled above");
        let st = state.get_or_insert_with(|| shape.sim.new_state());
        shape.sim.ensure_state(st);

        // The tight per-cell loop: recost only on a count change, stats
        // accumulated locally (one atomic update per counter below).
        let entry_count = shape.count;
        let mut recost_cells = 0u64;
        let mut hit_cells = 0u64;
        out.reserve(counts.len());
        // The build already sized the simulator at counts[0]; consume
        // that first cell without classifying it as recost or hit.
        let mut building = built;
        for &c in counts {
            if building {
                building = false;
            } else if c != shape.count {
                shape.sim.recost_count(c);
                shape.count = c;
                recost_cells += 1;
            } else {
                hit_cells += 1;
            }
            let summary = measure_sim(&shape.sim, st, reps, warmup, seed);
            out.push(CellResult { summary, algorithm: shape.schedule.algorithm });
        }
        // Keep the cached schedule byte-synced with its simulator: one
        // nested-rounds resize per series instead of one per cell.
        if shape.count != entry_count {
            shape.schedule.resize_count(shape.count);
        }

        self.stats.cells.fetch_add(counts.len() as u64, Ordering::Relaxed);
        if built {
            self.stats.schedules_built.fetch_add(1, Ordering::Relaxed);
        }
        if recost_cells > 0 {
            self.stats.recosts.fetch_add(recost_cells, Ordering::Relaxed);
        }
        if hit_cells > 0 {
            self.stats.cache_hits.fetch_add(hit_cells, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Measure a prebuilt schedule without caching it (count-dependent
    /// algorithm selection — native personas). Still reuses the caller's
    /// rep state, so the rep loop stays allocation-free.
    pub fn measure_uncached(
        &self,
        schedule: &Schedule,
        model: &CostModel,
        reps: usize,
        warmup: usize,
        seed: u64,
        state: &mut Option<RepState>,
    ) -> CellResult {
        let sim = Simulator::new(schedule, model);
        let st = state.get_or_insert_with(|| sim.new_state());
        sim.ensure_state(st);
        let summary = measure_sim(&sim, st, reps, warmup, seed);
        self.stats.cells.fetch_add(1, Ordering::Relaxed);
        self.stats.schedules_built.fetch_add(1, Ordering::Relaxed);
        CellResult { summary, algorithm: schedule.algorithm }
    }

    /// Measure a count series on the event-driven network backend,
    /// sharing the analytic path's schedule cache: the slot is resolved
    /// (and built on a miss) exactly like
    /// [`SweepEngine::measure_series`], but the cached simulator and
    /// schedule are read-only here — a [`NetSim`] is compiled from the
    /// cached schedule once per series and re-costed per count. The
    /// event backend allocates its state per series; it is not part of
    /// the zero-alloc series contract (`rust/tests/series_alloc.rs`
    /// gates the analytic path only).
    #[allow(clippy::too_many_arguments)]
    pub fn measure_series_event<E>(
        &self,
        key: SweepKey,
        counts: &[u64],
        model: &CostModel,
        scenario: &Scenario,
        reps: usize,
        warmup: usize,
        seed: u64,
        build: impl FnOnce(u64) -> Result<Schedule, E>,
    ) -> Result<Vec<CellResult>, MeasureError<E>> {
        let Some(&first) = counts.first() else {
            return Ok(Vec::new());
        };
        let skey = ShapeKey { key, model_fp: model_fingerprint(model) };
        let slot = self.slot(skey);
        let mut guard = slot.lock().unwrap();
        let built = guard.is_none();
        if built {
            let schedule = match build(first) {
                Ok(s) => s,
                Err(e) => {
                    drop(guard);
                    self.forget(skey, &slot);
                    return Err(MeasureError::Build(e));
                }
            };
            let sim = Simulator::new(&schedule, model);
            *guard = Some(CachedShape { schedule, sim, count: first });
        } else {
            let shape = guard.as_ref().expect("checked above");
            assert_eq!(shape.sim.model(), model, "sweep key reused with a different cost model");
            let (in_sim, in_sched) = (shape.sim.num_xfers(), shape.schedule.num_transfers());
            if in_sim != in_sched {
                return Err(MeasureError::Sim(SimError::TransferCountMismatch {
                    simulator: in_sim,
                    schedule: in_sched,
                }));
            }
        }
        let shape = guard.as_ref().expect("slot filled above");
        // The cached schedule may be sized for whatever count the last
        // analytic series left it at; every cell below recosts, so the
        // construction count is irrelevant.
        let mut net =
            NetSim::new(&shape.schedule, model, scenario).map_err(MeasureError::Net)?;
        let mut st = net.new_state();
        let mut out = Vec::with_capacity(counts.len());
        for &c in counts {
            net.recost_count(c);
            let summary =
                measure_backend(&net, &mut st, reps, warmup, seed).map_err(MeasureError::Net)?;
            out.push(CellResult { summary, algorithm: shape.schedule.algorithm });
        }
        self.stats.cells.fetch_add(counts.len() as u64, Ordering::Relaxed);
        self.stats.recosts.fetch_add(counts.len() as u64, Ordering::Relaxed);
        if built {
            self.stats.schedules_built.fetch_add(1, Ordering::Relaxed);
        }
        Ok(out)
    }

    /// Event-backend analogue of [`SweepEngine::measure_uncached`]:
    /// measure a prebuilt schedule (count-dependent algorithm selection
    /// — native personas) on the network backend without caching it.
    pub fn measure_uncached_event(
        &self,
        schedule: &Schedule,
        model: &CostModel,
        scenario: &Scenario,
        reps: usize,
        warmup: usize,
        seed: u64,
    ) -> Result<CellResult, NetError> {
        let net = NetSim::new(schedule, model, scenario)?;
        let mut st = net.new_state();
        let summary = measure_backend(&net, &mut st, reps, warmup, seed)?;
        self.stats.cells.fetch_add(1, Ordering::Relaxed);
        self.stats.schedules_built.fetch_add(1, Ordering::Relaxed);
        Ok(CellResult { summary, algorithm: schedule.algorithm })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bcast::{self, BcastAlg};
    use crate::model::CostModel;
    use crate::sim;
    use crate::topology::Cluster;

    /// Infallible build helper for the tests.
    fn ok(s: Schedule) -> Result<Schedule, std::convert::Infallible> {
        Ok(s)
    }

    fn key(cl: Cluster) -> SweepKey {
        SweepKey {
            cluster: cl,
            op: OpShape::Bcast { root: 0 },
            alg: AlgId { family: "klane", k: 2 },
        }
    }

    fn build(cl: Cluster) -> impl Fn(u64) -> Result<Schedule, std::convert::Infallible> {
        move |c| ok(bcast::build(cl, 0, c, BcastAlg::KLane { k: 2, two_phase: false }))
    }

    #[test]
    fn sweep_matches_per_cell_rebuild() {
        let cl = Cluster::new(4, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        for &c in &[1u64, 100, 6000, 100_000, 100] {
            let cell = eng.measure(key(cl), c, &m, 4, 1, 7, &mut st, build(cl)).unwrap();
            let fresh = sim::measure(
                &bcast::build(cl, 0, c, BcastAlg::KLane { k: 2, two_phase: false }),
                &m,
                4,
                1,
                7,
            );
            assert_eq!(cell.summary, fresh, "c = {c}");
            assert_eq!(cell.algorithm, "bcast/k-lane");
        }
    }

    #[test]
    fn cache_counters_track_the_paths() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        eng.measure(key(cl), 1, &m, 2, 0, 1, &mut st, build(cl)).unwrap(); // build
        eng.measure(key(cl), 50, &m, 2, 0, 1, &mut st, build(cl)).unwrap(); // recost
        eng.measure(key(cl), 50, &m, 2, 0, 1, &mut st, build(cl)).unwrap(); // hit
        eng.measure(key(cl), 1, &m, 2, 0, 1, &mut st, build(cl)).unwrap(); // recost back
        let st = eng.stats();
        assert_eq!(
            (st.cells, st.schedules_built, st.recosts, st.cache_hits),
            (4, 1, 2, 1)
        );
        assert_eq!(eng.cached_shapes(), 1);
    }

    #[test]
    fn uncached_path_reuses_state_but_rebuilds() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        for &c in &[1u64, 16_384] {
            let cell = eng.measure_uncached(
                &bcast::build(cl, 0, c, BcastAlg::Binomial),
                &m,
                3,
                1,
                9,
                &mut st,
            );
            let fresh =
                sim::measure(&bcast::build(cl, 0, c, BcastAlg::Binomial), &m, 3, 1, 9);
            assert_eq!(cell.summary, fresh, "c = {c}");
        }
        assert_eq!(eng.stats().schedules_built, 2);
        assert_eq!(eng.cached_shapes(), 0);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        let a = eng.measure(key(cl), 64, &m, 2, 0, 3, &mut st, build(cl)).unwrap();
        let mut k2 = key(cl);
        k2.alg = AlgId { family: "kported", k: 2 };
        let b = eng
            .measure(k2, 64, &m, 2, 0, 3, &mut st, |c| {
                ok(bcast::build(cl, 0, c, BcastAlg::KPorted { k: 2 }))
            })
            .unwrap();
        assert_eq!(eng.cached_shapes(), 2);
        assert_ne!(a.algorithm, b.algorithm);
    }

    #[test]
    fn distinct_models_shard_the_same_key() {
        // Two personas sweeping the same (cluster, op, alg) through one
        // shared engine must each get their own cached shape.
        let cl = Cluster::new(2, 4, 2);
        let m1 = CostModel::hydra_baseline();
        let mut m2 = CostModel::hydra_baseline();
        m2.alpha_net *= 2.0;
        let eng = SweepEngine::new();
        let mut st = None;
        let a = eng.measure(key(cl), 64, &m1, 2, 0, 3, &mut st, build(cl)).unwrap();
        let b = eng.measure(key(cl), 64, &m2, 2, 0, 3, &mut st, build(cl)).unwrap();
        assert_eq!(eng.cached_shapes(), 2);
        assert_eq!(eng.stats().schedules_built, 2);
        assert!(b.summary.avg > a.summary.avg, "slower model must cost more");
        // Re-measuring under each model hits its own shape.
        eng.measure(key(cl), 64, &m1, 2, 0, 3, &mut st, build(cl)).unwrap();
        assert_eq!(eng.stats().cache_hits, 1);
    }

    #[test]
    fn build_errors_propagate_and_leave_cache_empty() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        let err = eng
            .measure(key(cl), 8, &m, 2, 0, 1, &mut st, |_| Err::<Schedule, _>("nope"))
            .unwrap_err();
        assert_eq!(err, MeasureError::Build("nope"));
        assert_eq!(err.to_string(), "nope");
        assert_eq!(eng.cached_shapes(), 0);
        assert_eq!(eng.stats().cells, 0);
        // The key is retried on the next attempt.
        eng.measure(key(cl), 8, &m, 2, 0, 1, &mut st, build(cl)).unwrap();
        assert_eq!(eng.cached_shapes(), 1);
    }

    #[test]
    fn failed_builds_do_not_pin_cache_capacity() {
        // A failing key must be fully un-registered: distinct failing
        // keys must never evict a live shape from a bounded cache.
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::with_capacity(2);
        let mut st = None;
        eng.measure(key(cl), 8, &m, 2, 0, 1, &mut st, build(cl)).unwrap();
        for k in 10..=11u32 {
            let mut bad = key(cl);
            bad.alg = AlgId { family: "broken", k };
            eng.measure(bad, 8, &m, 2, 0, 1, &mut st, |_| Err::<Schedule, _>("nope"))
                .unwrap_err();
        }
        // Same key, same count: must be a cache hit, not a rebuild.
        eng.measure(key(cl), 8, &m, 2, 0, 1, &mut st, build(cl)).unwrap();
        let stats = eng.stats();
        assert_eq!(stats.schedules_built, 1, "{stats:?}");
        assert_eq!(stats.cache_hits, 1, "{stats:?}");
    }

    #[test]
    fn eviction_bounds_the_shape_count() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::with_capacity(2);
        let mut st = None;
        for k in 1..=3u32 {
            let mut key = key(cl);
            key.alg = AlgId { family: "kported", k };
            eng.measure(key, 8, &m, 1, 0, 1, &mut st, |c| {
                ok(bcast::build(cl, 0, c, BcastAlg::KPorted { k }))
            })
            .unwrap();
        }
        assert_eq!(eng.stats().schedules_built, 3);
        assert!(eng.cached_shapes() <= 2, "{}", eng.cached_shapes());
    }

    #[test]
    fn series_matches_per_cell_measure_bitwise() {
        // One series call vs N measure calls on separate engines: cells
        // and stats totals must be identical (the series batches the
        // counter updates but may not change what they add up to).
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let counts = [1u64, 50, 50, 1, 7, 7, 60_000];
        let per = SweepEngine::new();
        let mut st_a = None;
        let cells_a: Vec<CellResult> = counts
            .iter()
            .map(|&c| per.measure(key(cl), c, &m, 3, 1, 7, &mut st_a, build(cl)).unwrap())
            .collect();
        let ser = SweepEngine::new();
        let mut st_b = None;
        let cells_b =
            ser.measure_series(key(cl), &counts, &m, 3, 1, 7, &mut st_b, build(cl)).unwrap();
        assert_eq!(cells_a.len(), cells_b.len());
        for (i, (a, b)) in cells_a.iter().zip(&cells_b).enumerate() {
            assert_eq!(a.summary, b.summary, "cell {i} (c={})", counts[i]);
            assert_eq!(a.algorithm, b.algorithm, "cell {i}");
        }
        assert_eq!(per.stats(), ser.stats(), "stats totals must batch losslessly");
        let st = ser.stats();
        assert_eq!(
            (st.cells, st.schedules_built, st.recosts, st.cache_hits),
            (7, 1, 4, 2),
            "{st:?}"
        );
    }

    #[test]
    fn empty_series_is_a_no_op() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        let cells = eng.measure_series(key(cl), &[], &m, 2, 0, 1, &mut st, build(cl)).unwrap();
        assert!(cells.is_empty());
        assert_eq!(eng.stats(), SweepStats::default());
        assert_eq!(eng.cached_shapes(), 0);
    }

    #[test]
    fn series_build_error_leaves_cache_empty() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = SweepEngine::new();
        let mut st = None;
        let err = eng
            .measure_series(key(cl), &[1, 2, 3], &m, 2, 0, 1, &mut st, |_| {
                Err::<Schedule, _>("nope")
            })
            .unwrap_err();
        assert!(matches!(err, MeasureError::Build("nope")), "{err:?}");
        assert_eq!(eng.cached_shapes(), 0);
        assert_eq!(eng.stats().cells, 0);
    }

    #[test]
    fn event_series_matches_fresh_netsim_and_shares_the_cache() {
        use crate::netsim::{NetSim, Scenario};
        let cl = Cluster::new(2, 4, 2);
        let mut m = CostModel::hydra_baseline();
        m.jitter_mean = 0.0;
        let eng = SweepEngine::new();
        let sc = Scenario::contention_free();
        let counts = [1u64, 100, 6000];
        // Analytic series first: the event series must reuse its shape.
        let mut st = None;
        eng.measure_series(key(cl), &counts, &m, 2, 0, 7, &mut st, build(cl)).unwrap();
        let cells = eng
            .measure_series_event(key(cl), &counts, &m, &sc, 2, 0, 7, build(cl))
            .unwrap();
        assert_eq!(eng.stats().schedules_built, 1, "event series must not rebuild");
        for (i, &c) in counts.iter().enumerate() {
            let s = bcast::build(cl, 0, c, BcastAlg::KLane { k: 2, two_phase: false });
            let net = NetSim::new(&s, &m, &sc).unwrap();
            let mut nst = net.new_state();
            let fresh = sim::measure_backend(&net, &mut nst, 2, 0, 7).unwrap();
            assert_eq!(cells[i].summary, fresh, "c = {c}");
            assert_eq!(cells[i].algorithm, "bcast/k-lane");
        }
    }

    #[test]
    fn shared_engine_is_safe_across_threads() {
        let cl = Cluster::new(2, 4, 2);
        let m = CostModel::hydra_baseline();
        let eng = std::sync::Arc::new(SweepEngine::new());
        std::thread::scope(|scope| {
            for t in 0..4u32 {
                let eng = eng.clone();
                scope.spawn(move || {
                    let k = t % 2 + 1;
                    let mut st = None;
                    let mut key = key(cl);
                    key.alg = AlgId { family: "kported", k };
                    for &c in &[1u64, 64, 1000] {
                        let cell = eng
                            .measure(key, c, &m, 2, 0, 5, &mut st, |c| {
                                ok(bcast::build(cl, 0, c, BcastAlg::KPorted { k }))
                            })
                            .unwrap();
                        let fresh = sim::measure(
                            &bcast::build(cl, 0, c, BcastAlg::KPorted { k }),
                            &m,
                            2,
                            0,
                            5,
                        );
                        assert_eq!(cell.summary, fresh, "k={k} c={c}");
                    }
                });
            }
        });
        assert_eq!(eng.cached_shapes(), 2);
        assert_eq!(eng.stats().cells, 12);
    }
}

//! Execution tracing: capture every simulated transmission as a span and
//! export Chrome-trace JSON (viewable in chrome://tracing or Perfetto).
//! Spans are grouped pid = node, tid = rank, so lane contention and the
//! on-node/off-node interleaving of the k-lane algorithms are visible.

pub use super::engine::Span;

use crate::model::CostModel;
use crate::schedule::Schedule;
use crate::sim::Simulator;

pub struct Trace {
    pub spans: Vec<Span>,
    pub makespan: f64,
    pub cluster: crate::topology::Cluster,
}

/// Simulate one repetition of `schedule` and capture all spans.
pub fn trace_run(schedule: &Schedule, model: &CostModel, seed: u64) -> Trace {
    let sim = Simulator::new(schedule, model);
    let (r, spans) = sim.run_traced(seed);
    Trace { spans, makespan: r.makespan, cluster: schedule.cluster }
}

impl Trace {
    /// Chrome-trace JSON ("X" complete events; ts/dur in µs).
    pub fn to_chrome_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, sp) in self.spans.iter().enumerate() {
            let node = self.cluster.node_of(sp.src);
            let path = if sp.offnode { "net" } else { "shm" };
            out.push_str(&format!(
                "{{\"name\":\"{}->{} ({}B {})\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":{},\"tid\":{}}}{}\n",
                sp.src,
                sp.dst,
                sp.bytes,
                path,
                sp.start,
                (sp.end - sp.start).max(0.001),
                node,
                sp.src,
                if i + 1 == self.spans.len() { "" } else { "," }
            ));
        }
        out.push(']');
        out
    }

    /// Aggregate per-lane busy time (off-node bytes·β per node) — a quick
    /// utilisation check without opening the JSON.
    pub fn offnode_busy_by_node(&self) -> Vec<f64> {
        let mut busy = vec![0.0; self.cluster.nodes as usize];
        for sp in self.spans.iter().filter(|s| s.offnode) {
            busy[self.cluster.node_of(sp.src) as usize] += sp.end - sp.start;
        }
        busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bcast;
    use crate::topology::Cluster;

    fn quiet() -> CostModel {
        let mut m = CostModel::hydra_baseline();
        m.jitter_mean = 0.0;
        m
    }

    #[test]
    fn trace_covers_all_transfers() {
        let cl = Cluster::new(2, 4, 2);
        let s = bcast::build(cl, 0, 100, bcast::BcastAlg::Binomial);
        let t = trace_run(&s, &quiet(), 1);
        assert_eq!(t.spans.len(), s.num_transfers());
        assert!(t.makespan > 0.0);
    }

    #[test]
    fn spans_within_makespan() {
        let cl = Cluster::new(3, 4, 2);
        let s = bcast::build(cl, 0, 5000, bcast::BcastAlg::FullLane);
        let t = trace_run(&s, &quiet(), 1);
        for sp in &t.spans {
            assert!(sp.start >= 0.0 && sp.end <= t.makespan + 1e-9, "{sp:?}");
            assert!(sp.end >= sp.start);
        }
    }

    #[test]
    fn chrome_json_is_wellformed_ish() {
        let cl = Cluster::new(2, 2, 1);
        let s = bcast::build(cl, 0, 8, bcast::BcastAlg::Binomial);
        let t = trace_run(&s, &quiet(), 1);
        let j = t.to_chrome_json();
        assert!(j.starts_with('[') && j.ends_with(']'));
        assert_eq!(j.matches("\"ph\":\"X\"").count(), t.spans.len());
    }

    #[test]
    fn busy_accounting() {
        let cl = Cluster::new(2, 2, 1);
        let s = bcast::build(cl, 0, 10_000, bcast::BcastAlg::Binomial);
        let t = trace_run(&s, &quiet(), 1);
        let busy = t.offnode_busy_by_node();
        assert_eq!(busy.len(), 2);
        assert!(busy[0] > 0.0, "root node sends off-node");
    }
}

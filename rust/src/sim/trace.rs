//! Execution tracing: capture every simulated transmission as a span and
//! export Chrome-trace JSON (viewable in chrome://tracing or Perfetto).
//! Spans are grouped pid = node, tid = rank, so lane contention and the
//! on-node/off-node interleaving of the k-lane algorithms are visible.

pub use super::engine::Span;
pub use crate::netsim::{NetEvent, NetEventKind};

use crate::model::CostModel;
use crate::netsim::{NetError, NetSim, Scenario};
use crate::schedule::Schedule;
use crate::sim::Simulator;

pub struct Trace {
    pub spans: Vec<Span>,
    pub makespan: f64,
    pub cluster: crate::topology::Cluster,
}

/// Simulate one repetition of `schedule` and capture all spans.
pub fn trace_run(schedule: &Schedule, model: &CostModel, seed: u64) -> Trace {
    let sim = Simulator::new(schedule, model);
    let (r, spans) = sim.run_traced(seed);
    Trace { spans, makespan: r.makespan, cluster: schedule.cluster }
}

/// An event-backend trace: the wire spans (same shape as the analytic
/// [`Trace`]) plus the per-port queue events — enqueue/dequeue/deliver
/// (and drop) with the queue depth at each instant, so contention is
/// inspectable rather than inferred.
pub struct EventTrace {
    pub trace: Trace,
    pub events: Vec<NetEvent>,
}

/// Run one repetition of `schedule` on the event-driven network backend
/// under `scenario`, capturing spans and queue events.
pub fn trace_run_event(
    schedule: &Schedule,
    model: &CostModel,
    scenario: &Scenario,
    seed: u64,
) -> Result<EventTrace, NetError> {
    let net = NetSim::new(schedule, model, scenario)?;
    let (r, spans, events) = net.run_traced(seed)?;
    Ok(EventTrace {
        trace: Trace { spans, makespan: r.makespan, cluster: schedule.cluster },
        events,
    })
}

impl EventTrace {
    /// Chrome-trace JSON: the wire spans as "X" complete events (same
    /// encoding as [`Trace::to_chrome_json`]) followed by the queue
    /// events as "i" instant events carrying the queue depth, grouped
    /// pid = node, tid = port name via the args block.
    pub fn to_chrome_json(&self) -> String {
        let mut out = self.trace.to_chrome_json();
        // Splice the instants before the closing ']'.
        out.pop();
        for (i, ev) in self.events.iter().enumerate() {
            let who = if ev.tenant { "tenant" } else { "xfer" };
            out.push_str(&format!(
                "{}{{\"name\":\"{} {} {}->{}\",\"ph\":\"i\",\"ts\":{:.3},\"pid\":{},\"s\":\"t\",\"args\":{{\"port\":\"{}\",\"depth\":{},\"bytes\":{},\"kind\":\"{}\"}}}}\n",
                if i == 0 && self.trace.spans.is_empty() { "" } else { "," },
                ev.kind.label(),
                who,
                ev.src,
                ev.dst,
                ev.t,
                ev.node,
                ev.port,
                ev.depth,
                ev.bytes,
                ev.kind.label(),
            ));
        }
        out.push(']');
        out
    }

    /// One line per queue event — the golden-snapshot surface
    /// (`rust/tests/netsim_trace.rs` pins the time-stripped form).
    pub fn text(&self) -> String {
        let mut out = String::new();
        for ev in &self.events {
            let who = if ev.tenant { "tenant " } else { "" };
            out.push_str(&format!(
                "{:.3} {} {} node={} {}{}->{} {}B depth={}\n",
                ev.t,
                ev.kind.label(),
                ev.port,
                ev.node,
                who,
                ev.src,
                ev.dst,
                ev.bytes,
                ev.depth,
            ));
        }
        out
    }
}

impl Trace {
    /// Chrome-trace JSON ("X" complete events; ts/dur in µs).
    pub fn to_chrome_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, sp) in self.spans.iter().enumerate() {
            let node = self.cluster.node_of(sp.src);
            let path = if sp.offnode { "net" } else { "shm" };
            out.push_str(&format!(
                "{{\"name\":\"{}->{} ({}B {})\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":{},\"tid\":{}}}{}\n",
                sp.src,
                sp.dst,
                sp.bytes,
                path,
                sp.start,
                (sp.end - sp.start).max(0.001),
                node,
                sp.src,
                if i + 1 == self.spans.len() { "" } else { "," }
            ));
        }
        out.push(']');
        out
    }

    /// Aggregate per-lane busy time (off-node bytes·β per node) — a quick
    /// utilisation check without opening the JSON.
    pub fn offnode_busy_by_node(&self) -> Vec<f64> {
        let mut busy = vec![0.0; self.cluster.nodes as usize];
        for sp in self.spans.iter().filter(|s| s.offnode) {
            busy[self.cluster.node_of(sp.src) as usize] += sp.end - sp.start;
        }
        busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bcast;
    use crate::topology::Cluster;

    fn quiet() -> CostModel {
        let mut m = CostModel::hydra_baseline();
        m.jitter_mean = 0.0;
        m
    }

    #[test]
    fn trace_covers_all_transfers() {
        let cl = Cluster::new(2, 4, 2);
        let s = bcast::build(cl, 0, 100, bcast::BcastAlg::Binomial);
        let t = trace_run(&s, &quiet(), 1);
        assert_eq!(t.spans.len(), s.num_transfers());
        assert!(t.makespan > 0.0);
    }

    #[test]
    fn spans_within_makespan() {
        let cl = Cluster::new(3, 4, 2);
        let s = bcast::build(cl, 0, 5000, bcast::BcastAlg::FullLane);
        let t = trace_run(&s, &quiet(), 1);
        for sp in &t.spans {
            assert!(sp.start >= 0.0 && sp.end <= t.makespan + 1e-9, "{sp:?}");
            assert!(sp.end >= sp.start);
        }
    }

    #[test]
    fn chrome_json_is_wellformed_ish() {
        let cl = Cluster::new(2, 2, 1);
        let s = bcast::build(cl, 0, 8, bcast::BcastAlg::Binomial);
        let t = trace_run(&s, &quiet(), 1);
        let j = t.to_chrome_json();
        assert!(j.starts_with('[') && j.ends_with(']'));
        assert_eq!(j.matches("\"ph\":\"X\"").count(), t.spans.len());
    }

    #[test]
    fn event_trace_covers_transfers_and_json_is_wellformed() {
        use crate::netsim::Scenario;
        let cl = Cluster::new(2, 2, 1);
        let s = bcast::build(cl, 0, 100, bcast::BcastAlg::Binomial);
        let t = trace_run_event(&s, &quiet(), &Scenario::contention_free(), 1).unwrap();
        assert_eq!(t.trace.spans.len(), s.num_transfers());
        let delivers =
            t.events.iter().filter(|e| e.kind == NetEventKind::Deliver).count();
        assert_eq!(delivers, s.num_transfers());
        let j = t.to_chrome_json();
        assert!(j.starts_with('[') && j.ends_with(']'));
        assert_eq!(j.matches("\"ph\":\"i\"").count(), t.events.len());
        assert_eq!(t.text().lines().count(), t.events.len());
    }

    #[test]
    fn busy_accounting() {
        let cl = Cluster::new(2, 2, 1);
        let s = bcast::build(cl, 0, 10_000, bcast::BcastAlg::Binomial);
        let t = trace_run(&s, &quiet(), 1);
        let busy = t.offnode_busy_by_node();
        assert_eq!(busy.len(), 2);
        assert!(busy[0] > 0.0, "root node sends off-node");
    }
}

//! Compact block-id sets and block sizing.
//!
//! Alltoall at p = 1152 has p² ≈ 1.3M blocks; schedules reference blocks
//! as unions of arithmetic ranges rather than materialised id lists.

/// A set of block ids, stored as a sorted union of strided runs
/// `(start, stride, len)`. Contiguous ranges use stride 1.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BlockSet {
    runs: Vec<(u64, u64, u64)>, // (start, stride, len), stride >= 1, len >= 1
}

impl BlockSet {
    pub fn empty() -> Self {
        Self { runs: Vec::new() }
    }

    pub fn single(id: u64) -> Self {
        Self { runs: vec![(id, 1, 1)] }
    }

    /// Contiguous ids [start, end).
    pub fn range(start: u64, end: u64) -> Self {
        if start >= end {
            Self::empty()
        } else {
            Self { runs: vec![(start, 1, end - start)] }
        }
    }

    /// ids start, start+stride, ... (len terms).
    pub fn strided(start: u64, stride: u64, len: u64) -> Self {
        assert!(stride >= 1);
        if len == 0 {
            Self::empty()
        } else {
            Self { runs: vec![(start, stride, len)] }
        }
    }

    /// Union (no normalisation; runs may overlap only if the caller makes
    /// them overlap — builders never do, and `count` assumes disjoint).
    pub fn union(mut self, other: BlockSet) -> BlockSet {
        self.runs.extend(other.runs);
        self
    }

    pub fn push_run(&mut self, start: u64, stride: u64, len: u64) {
        assert!(stride >= 1);
        if len > 0 {
            self.runs.push((start, stride, len));
        }
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    /// Number of ids (runs assumed disjoint).
    pub fn count(&self) -> u64 {
        self.runs.iter().map(|&(_, _, l)| l).sum()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.runs.iter().any(|&(s, st, l)| {
            id >= s && (id - s) % st == 0 && (id - s) / st < l
        })
    }

    /// Iterate all ids (ascending within each run).
    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.runs
            .iter()
            .flat_map(|&(s, st, l)| (0..l).map(move |i| s + i * st))
    }

    /// True if every id of `self` is in `other`.
    pub fn subset_of(&self, other: &BlockSet) -> bool {
        self.iter().all(|id| other.contains(id))
    }
}

impl FromIterator<u64> for BlockSet {
    fn from_iter<I: IntoIterator<Item = u64>>(iter: I) -> Self {
        // Coalesce sorted consecutive ids into ranges where possible.
        let mut ids: Vec<u64> = iter.into_iter().collect();
        ids.sort_unstable();
        ids.dedup();
        let mut set = BlockSet::empty();
        let mut i = 0;
        while i < ids.len() {
            let start = ids[i];
            let mut len = 1;
            while i + (len as usize) < ids.len() && ids[i + len as usize] == start + len {
                len += 1;
            }
            set.push_run(start, 1, len);
            i += len as usize;
        }
        set
    }
}

/// Block sizing in elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sizing {
    /// Every block has exactly `elems` elements.
    Uniform { elems: u64 },
    /// `total` elements split into `parts` blocks differing by ≤ 1
    /// element (paper §2.1: subranges "differing in size by at most one").
    Split { total: u64, parts: u32 },
}

impl Sizing {
    /// Elements of block `id` (for `Split`, id indexes the parts).
    pub fn elems(&self, id: u64) -> u64 {
        match *self {
            Sizing::Uniform { elems } => elems,
            Sizing::Split { total, parts } => {
                let parts = parts as u64;
                debug_assert!(id < parts);
                let base = total / parts;
                let extra = total % parts;
                base + u64::from(id < extra)
            }
        }
    }

    /// Total elements of a block set.
    pub fn elems_of(&self, blocks: &BlockSet) -> u64 {
        match *self {
            Sizing::Uniform { elems } => elems * blocks.count(),
            Sizing::Split { .. } => blocks.iter().map(|id| self.elems(id)).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_contains() {
        let s = BlockSet::range(5, 10);
        assert!(s.contains(5) && s.contains(9));
        assert!(!s.contains(4) && !s.contains(10));
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn strided_contains() {
        let s = BlockSet::strided(3, 4, 5); // 3, 7, 11, 15, 19
        for id in [3, 7, 11, 15, 19] {
            assert!(s.contains(id));
        }
        assert!(!s.contains(4) && !s.contains(23));
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![3, 7, 11, 15, 19]);
    }

    #[test]
    fn union_and_subset() {
        let s = BlockSet::range(0, 3).union(BlockSet::single(10));
        assert_eq!(s.count(), 4);
        assert!(BlockSet::single(10).subset_of(&s));
        assert!(!BlockSet::single(5).subset_of(&s));
    }

    #[test]
    fn from_iter_coalesces() {
        let s: BlockSet = vec![3u64, 1, 2, 7, 8, 5].into_iter().collect();
        assert_eq!(s.count(), 6);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![1, 2, 3, 5, 7, 8]);
    }

    #[test]
    fn empty_set() {
        let s = BlockSet::empty();
        assert!(s.is_empty());
        assert_eq!(s.count(), 0);
        assert!(s.subset_of(&BlockSet::empty()));
    }

    #[test]
    fn split_sizing_distributes_remainder() {
        let sz = Sizing::Split { total: 11, parts: 4 };
        let sizes: Vec<u64> = (0..4).map(|i| sz.elems(i)).collect();
        assert_eq!(sizes, vec![3, 3, 3, 2]);
        assert_eq!(sizes.iter().sum::<u64>(), 11);
    }

    #[test]
    fn uniform_sizing() {
        let sz = Sizing::Uniform { elems: 8 };
        assert_eq!(sz.elems_of(&BlockSet::range(0, 5)), 40);
    }
}

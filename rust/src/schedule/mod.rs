//! The communication-schedule IR.
//!
//! Every collective algorithm in this library *compiles* to a
//! [`Schedule`]: a sequence of rounds, each holding point-to-point
//! [`Transfer`]s (which may be on-node — shared memory — or off-node —
//! network lanes) and optional [`LocalOp`]s (node-local phases executed as
//! XLA executables by the exec backend, costed as memory traffic by the
//! simulator).
//!
//! Two backends consume the IR unchanged:
//! * `sim::Engine` — discrete-event timing under a persona cost model;
//! * `exec::Runtime` — real threaded execution on real buffers.
//!
//! Data is tracked at *block* granularity. Each collective defines a block
//! layout (see [`Collective::num_blocks`]) so schedules can be validated:
//! causality (only held blocks are sent), port limits (the k-ported
//! constraint), and delivery (the collective's postcondition).

pub mod blocks;
pub mod validate;

pub use blocks::{BlockSet, Sizing};
pub use validate::{validate, validate_ports, Violation};

use crate::topology::{Cluster, Rank};

/// Which collective a schedule implements, with its parameters.
/// `c` is the element count per block in MPI convention (paper §4:
/// bcast: c elements total; scatter: c elements received per rank;
/// alltoall: c elements per (src, dst) pair).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Collective {
    /// Root broadcasts `c` elements to all p ranks. The schedule's block
    /// layout splits the payload into `segments` equal parts (1 for
    /// non-splitting algorithms, n for full-lane).
    Bcast { root: Rank, c: u64, segments: u32 },
    /// Root sends a distinct block of `c` elements to every rank.
    /// Block `j` is destined to rank `j`.
    Scatter { root: Rank, c: u64 },
    /// Every rank sends a distinct block of `c` elements to every rank.
    /// Block `i·p + j` travels from rank `i` to rank `j`.
    Alltoall { c: u64 },
    /// Every rank contributes a block of `c` elements (block `j`
    /// originates at rank `j`) and must end holding all p blocks.
    Allgather { c: u64 },
    /// Dual of scatter (paper §2: "the gather operation is the dual of
    /// the scatter operation"): block `j` starts at rank `j`; the root
    /// must end holding all p blocks.
    Gather { root: Rank, c: u64 },
}

impl Collective {
    /// Number of data blocks in this collective's layout (p = total ranks).
    pub fn num_blocks(&self, p: u32) -> u64 {
        match self {
            Collective::Bcast { segments, .. } => *segments as u64,
            Collective::Scatter { .. }
            | Collective::Allgather { .. }
            | Collective::Gather { .. } => p as u64,
            Collective::Alltoall { .. } => p as u64 * p as u64,
        }
    }

    /// Block sizing in elements.
    pub fn sizing(&self) -> Sizing {
        match self {
            Collective::Bcast { c, segments, .. } => Sizing::Split { total: *c, parts: *segments },
            Collective::Scatter { c, .. }
            | Collective::Alltoall { c }
            | Collective::Allgather { c }
            | Collective::Gather { c, .. } => Sizing::Uniform { elems: *c },
        }
    }

    /// The same collective re-targeted to a new element count `c`
    /// (block structure — segments, roots — is preserved).
    pub fn with_count(&self, c: u64) -> Collective {
        match *self {
            Collective::Bcast { root, segments, .. } => Collective::Bcast { root, c, segments },
            Collective::Scatter { root, .. } => Collective::Scatter { root, c },
            Collective::Alltoall { .. } => Collective::Alltoall { c },
            Collective::Allgather { .. } => Collective::Allgather { c },
            Collective::Gather { root, .. } => Collective::Gather { root, c },
        }
    }

    /// Blocks initially held by `rank`.
    pub fn initial_blocks(&self, rank: Rank, p: u32) -> BlockSet {
        match self {
            Collective::Bcast { root, segments, .. } => {
                if rank == *root {
                    BlockSet::range(0, *segments as u64)
                } else {
                    BlockSet::empty()
                }
            }
            Collective::Scatter { root, .. } => {
                if rank == *root {
                    BlockSet::range(0, p as u64)
                } else {
                    BlockSet::empty()
                }
            }
            Collective::Alltoall { .. } => {
                BlockSet::range(rank as u64 * p as u64, (rank as u64 + 1) * p as u64)
            }
            Collective::Allgather { .. } | Collective::Gather { .. } => {
                BlockSet::single(rank as u64)
            }
        }
    }

    /// Blocks `rank` must hold when the schedule completes.
    pub fn required_blocks(&self, rank: Rank, p: u32) -> BlockSet {
        match self {
            Collective::Bcast { segments, .. } => BlockSet::range(0, *segments as u64),
            Collective::Scatter { .. } => BlockSet::single(rank as u64),
            Collective::Alltoall { .. } => {
                // blocks i*p + rank for all i — a strided set.
                BlockSet::strided(rank as u64, p as u64, p as u64)
            }
            Collective::Allgather { .. } => BlockSet::range(0, p as u64),
            Collective::Gather { root, .. } => {
                if rank == *root {
                    BlockSet::range(0, p as u64)
                } else {
                    BlockSet::single(rank as u64)
                }
            }
        }
    }
}

/// One point-to-point message within a round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Transfer {
    pub src: Rank,
    pub dst: Rank,
    /// The data blocks carried by this message.
    pub blocks: BlockSet,
    /// Message size in bytes (cached; derived from blocks × sizing).
    pub bytes: u64,
}

/// Recognisable node-local collective phases. Semantically a round is
/// always its transfers; the hint tells backends the round *is* a node
/// collective so they may implement it specially (exec: run the AOT XLA
/// artifact for the phase; sim: charge the persona's node-collective
/// call overhead on top of the modelled memory traffic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocalOpKind {
    /// Node-local alltoall (block transpose) — `node_alltoall` artifact.
    Alltoall,
    /// Node-local allgather — `node_allgather` artifact.
    Allgather,
    /// Node-local scatter from an on-node root core — `node_scatter`.
    Scatter,
    /// Node-local broadcast from an on-node root core — `node_bcast`.
    Bcast,
}

/// One communication round. All transfers in a round may proceed
/// concurrently, subject to the port/lane limits the backends model.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Round {
    pub transfers: Vec<Transfer>,
    /// Set when every transfer in this round belongs to one node-local
    /// collective phase per node (see [`LocalOpKind`]).
    pub node_phase: Option<LocalOpKind>,
}

impl Round {
    pub fn of(transfers: Vec<Transfer>) -> Self {
        Self { transfers, node_phase: None }
    }

    pub fn node_collective(transfers: Vec<Transfer>, kind: LocalOpKind) -> Self {
        Self { transfers, node_phase: Some(kind) }
    }

    pub fn is_empty(&self) -> bool {
        self.transfers.is_empty()
    }
}

/// A compiled collective: the algorithm's full communication structure.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub cluster: Cluster,
    pub op: Collective,
    /// Bytes per element (the paper uses MPI_INT = 4).
    pub elem_bytes: u64,
    pub rounds: Vec<Round>,
    /// Human-readable algorithm name (for tables and error messages).
    pub algorithm: &'static str,
}

pub const ELEM_BYTES: u64 = 4; // MPI_INT

impl Schedule {
    pub fn new(cluster: Cluster, op: Collective, algorithm: &'static str) -> Self {
        Self { cluster, op, elem_bytes: ELEM_BYTES, rounds: Vec::new(), algorithm }
    }

    pub fn p(&self) -> u32 {
        self.cluster.p()
    }

    /// Bytes of a block set under this schedule's sizing.
    pub fn bytes_of(&self, blocks: &BlockSet) -> u64 {
        self.op.sizing().elems_of(blocks) * self.elem_bytes
    }

    /// Append a round (dropping it if empty).
    pub fn push_round(&mut self, round: Round) {
        if !round.is_empty() {
            self.rounds.push(round);
        }
    }

    /// Mutable access to round `idx`, extending with empty rounds as
    /// needed (builders place transfers at computed round indices; call
    /// [`Schedule::finalize`] afterwards to drop gaps).
    pub fn round_mut(&mut self, idx: usize) -> &mut Round {
        if idx >= self.rounds.len() {
            self.rounds.resize(idx + 1, Round::default());
        }
        &mut self.rounds[idx]
    }

    /// Place a transfer at a specific round.
    pub fn add_at(&mut self, round: usize, src: Rank, dst: Rank, blocks: BlockSet) {
        let t = self.transfer(src, dst, blocks);
        self.round_mut(round).transfers.push(t);
    }

    /// Drop empty rounds left by index-based construction.
    pub fn finalize(&mut self) {
        self.rounds.retain(|r| !r.is_empty());
    }

    /// Convenience: build a transfer with its byte size computed.
    pub fn transfer(&self, src: Rank, dst: Rank, blocks: BlockSet) -> Transfer {
        let bytes = self.bytes_of(&blocks);
        Transfer { src, dst, blocks, bytes }
    }

    /// Re-target this schedule to a new element count, recomputing every
    /// transfer's byte size from its blocks under the new sizing. The
    /// round structure is reused as-is, which is exactly right for the
    /// paper's algorithms: their communication structure depends only on
    /// (cluster, algorithm, k) — count enters through block sizes alone
    /// (the lane-decomposition property of arXiv:1910.13373). Callers
    /// sweeping count-*dependent* selections (native personas switch
    /// algorithm by size) must rebuild instead — see `sim::sweep`.
    pub fn resize_count(&mut self, c: u64) {
        self.op = self.op.with_count(c);
        let sizing = self.op.sizing();
        let elem_bytes = self.elem_bytes;
        for round in &mut self.rounds {
            for t in &mut round.transfers {
                t.bytes = sizing.elems_of(&t.blocks) * elem_bytes;
            }
        }
    }

    /// Total bytes crossing the network (off-node transfers only).
    pub fn offnode_bytes(&self) -> u64 {
        self.rounds
            .iter()
            .flat_map(|r| &r.transfers)
            .filter(|t| !self.cluster.same_node(t.src, t.dst))
            .map(|t| t.bytes)
            .sum()
    }

    /// Total bytes moved over shared memory (on-node transfers).
    pub fn onnode_bytes(&self) -> u64 {
        self.rounds
            .iter()
            .flat_map(|r| &r.transfers)
            .filter(|t| self.cluster.same_node(t.src, t.dst))
            .map(|t| t.bytes)
            .sum()
    }

    pub fn num_transfers(&self) -> usize {
        self.rounds.iter().map(|r| r.transfers.len()).sum()
    }

    /// Flatten this schedule's (sizing × per-transfer blocks) structure
    /// into a [`CountSizer`]: the count→bytes function of every transfer,
    /// in round-major order (the simulator's flattened transfer order),
    /// detached from the nested rounds. A count sweep can then recompute
    /// all byte sizes for a new count in one contiguous pass
    /// ([`CountSizer::resize_count_into`]) without walking rounds or
    /// holding the schedule — the sweep-engine series hot path.
    pub fn count_sizer(&self) -> CountSizer {
        let parts = match self.op.sizing() {
            Sizing::Uniform { .. } => 0u64,
            Sizing::Split { parts, .. } => u64::from(parts),
        };
        let n = self.num_transfers();
        let mut nblocks = Vec::with_capacity(n);
        let mut id_off = Vec::new();
        let mut ids = Vec::new();
        if parts != 0 {
            id_off.reserve(n + 1);
            id_off.push(0u32);
        }
        for round in &self.rounds {
            for t in &round.transfers {
                nblocks.push(t.blocks.count());
                if parts != 0 {
                    let start = ids.len();
                    ids.extend(t.blocks.iter());
                    // Sorted for the partition-point remainder count;
                    // sums are order-independent, so sorting cannot
                    // change the recomputed sizes.
                    ids[start..].sort_unstable();
                    id_off.push(ids.len() as u32);
                }
            }
        }
        CountSizer { elem_bytes: self.elem_bytes, parts, nblocks, id_off, ids }
    }
}

/// The count→bytes function of one schedule, flattened: per transfer
/// (round-major) everything needed to recompute its byte size at any
/// element count. Built once per cached shape by
/// [`Schedule::count_sizer`]; [`CountSizer::resize_count_into`] is then
/// a branch-light loop over flat arrays, bitwise-identical to
/// [`Schedule::resize_count`] (same u64 arithmetic; `Split` sums are
/// reassociated over sorted ids, which is exact in integers).
#[derive(Clone, Debug)]
pub struct CountSizer {
    elem_bytes: u64,
    /// `Split { parts }` sizing; 0 encodes `Uniform` (a schedule's
    /// `Split` always has ≥ 1 part, so 0 is free as a marker).
    parts: u64,
    /// Per transfer: number of blocks carried.
    nblocks: Vec<u64>,
    /// `Split` only — CSR of each transfer's sorted block ids, for the
    /// remainder term (`base + 1` elements for ids below `c % parts`).
    id_off: Vec<u32>,
    ids: Vec<u64>,
}

impl CountSizer {
    /// Number of transfers this sizer covers.
    pub fn num_transfers(&self) -> usize {
        self.nblocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nblocks.is_empty()
    }

    /// Byte size of transfer `i` (round-major index) at element count
    /// `c` — one slot of [`CountSizer::resize_count_into`], same exact
    /// u64 arithmetic.
    pub fn bytes_at(&self, i: usize, c: u64) -> u64 {
        let eb = self.elem_bytes;
        if self.parts == 0 {
            c * self.nblocks[i] * eb
        } else {
            let base = c / self.parts;
            let extra = c % self.parts;
            let ids = &self.ids[self.id_off[i] as usize..self.id_off[i + 1] as usize];
            let below = ids.partition_point(|&id| id < extra) as u64;
            (self.nblocks[i] * base + below) * eb
        }
    }

    /// `bytes_at` in overflow-proof u128 arithmetic, for domain-bound
    /// and crossover searches that probe counts past the u64-safe
    /// range.
    fn bytes_at_wide(&self, i: usize, c: u64) -> u128 {
        let eb = u128::from(self.elem_bytes);
        let nb = u128::from(self.nblocks[i]);
        if self.parts == 0 {
            u128::from(c) * nb * eb
        } else {
            let base = u128::from(c / self.parts);
            let extra = c % self.parts;
            let ids = &self.ids[self.id_off[i] as usize..self.id_off[i + 1] as usize];
            let below = ids.partition_point(|&id| id < extra) as u128;
            (nb * base + below) * eb
        }
    }

    /// The largest element count at which **every** transfer's byte
    /// size still fits in u64 — the overflow-safe certification domain
    /// bound. `bytes(c)` is non-decreasing in `c` per transfer (Uniform
    /// is affine, Split a monotone staircase), so the bound is exact.
    /// A schedule with no transfers (or only empty ones) is safe at any
    /// count.
    pub fn max_safe_count(&self) -> u64 {
        let mut safe = u64::MAX;
        for i in 0..self.nblocks.len() {
            if self.bytes_at_wide(i, safe) <= u128::from(u64::MAX) {
                continue;
            }
            // Largest c with bytes(c) <= u64::MAX; bytes(0) = 0 always
            // fits, so lo is a valid floor.
            let (mut lo, mut hi) = (0u64, safe);
            while lo < hi {
                let mid = lo + (hi - lo).div_ceil(2);
                if self.bytes_at_wide(i, mid) <= u128::from(u64::MAX) {
                    lo = mid;
                } else {
                    hi = mid - 1;
                }
            }
            safe = lo;
        }
        safe
    }

    /// The smallest count in `[1, hi]` at which transfer `i` exceeds
    /// `threshold` bytes — the eager→rendezvous crossover for that
    /// transfer. `None` when the transfer never exceeds the threshold
    /// within the domain (including `threshold == u64::MAX`). Uniform
    /// sizing solves in closed form; Split binary-searches the monotone
    /// staircase (≤ 64 evaluations, exact integers throughout).
    pub fn first_count_above(&self, i: usize, threshold: u64, hi: u64) -> Option<u64> {
        if hi == 0 || self.bytes_at_wide(i, hi) <= u128::from(threshold) {
            return None;
        }
        if self.parts == 0 {
            // bytes = c·nb·eb > T  ⇔  c > T / (nb·eb)  (exact floor div;
            // nb·eb > 0 here, else bytes(hi) would be 0 ≤ threshold).
            let per = u128::from(self.nblocks[i]) * u128::from(self.elem_bytes);
            let c = u128::from(threshold) / per + 1;
            return u64::try_from(c).ok().filter(|&c| c >= 1 && c <= hi);
        }
        let (mut lo, mut hi) = (1u64, hi);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.bytes_at_wide(i, mid) > u128::from(threshold) {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        Some(lo)
    }

    /// [`Schedule::resize_count`], flat form: write every transfer's
    /// byte size at element count `c` into `out` (round-major order) in
    /// one pass. `out.len()` must equal [`CountSizer::num_transfers`].
    pub fn resize_count_into(&self, c: u64, out: &mut [u64]) {
        assert_eq!(
            out.len(),
            self.nblocks.len(),
            "resize_count_into: output length does not match transfer count"
        );
        let eb = self.elem_bytes;
        if self.parts == 0 {
            // Uniform: bytes = (c · nblocks) · elem_bytes.
            for (o, &nb) in out.iter_mut().zip(&self.nblocks) {
                *o = c * nb * eb;
            }
        } else {
            // Split: each id holds base = c / parts elements, plus one
            // more for ids below c % parts.
            let base = c / self.parts;
            let extra = c % self.parts;
            for (i, (o, &nb)) in out.iter_mut().zip(&self.nblocks).enumerate() {
                let ids = &self.ids[self.id_off[i] as usize..self.id_off[i + 1] as usize];
                let below = ids.partition_point(|&id| id < extra) as u64;
                *o = (nb * base + below) * eb;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cl() -> Cluster {
        Cluster::new(2, 4, 2)
    }

    #[test]
    fn bcast_block_layout() {
        let op = Collective::Bcast { root: 3, c: 100, segments: 4 };
        assert_eq!(op.num_blocks(8), 4);
        assert_eq!(op.initial_blocks(3, 8).count(), 4);
        assert_eq!(op.initial_blocks(0, 8).count(), 0);
        assert_eq!(op.required_blocks(7, 8).count(), 4);
    }

    #[test]
    fn scatter_block_layout() {
        let op = Collective::Scatter { root: 0, c: 10 };
        assert_eq!(op.num_blocks(8), 8);
        assert!(op.required_blocks(5, 8).contains(5));
        assert_eq!(op.required_blocks(5, 8).count(), 1);
    }

    #[test]
    fn alltoall_block_layout() {
        let op = Collective::Alltoall { c: 7 };
        let p = 4;
        assert_eq!(op.num_blocks(p), 16);
        // rank 2 starts with blocks 8..12 and must end with {2, 6, 10, 14}
        assert_eq!(op.initial_blocks(2, p).count(), 4);
        let req = op.required_blocks(2, p);
        for i in 0..4u64 {
            assert!(req.contains(i * 4 + 2), "missing block {}", i * 4 + 2);
        }
    }

    #[test]
    fn transfer_bytes_follow_sizing() {
        let mut s =
            Schedule::new(cl(), Collective::Scatter { root: 0, c: 10 }, "test");
        let t = s.transfer(0, 1, BlockSet::single(1));
        assert_eq!(t.bytes, 40);
        s.push_round(Round::of(vec![t]));
        assert_eq!(s.offnode_bytes(), 0); // ranks 0,1 are on node 0
        assert_eq!(s.onnode_bytes(), 40);
    }

    #[test]
    fn split_sizing_uneven() {
        let op = Collective::Bcast { root: 0, c: 10, segments: 3 };
        let sz = op.sizing();
        // 10 split 3 ways: 4 + 3 + 3
        assert_eq!(sz.elems(0), 4);
        assert_eq!(sz.elems(1), 3);
        assert_eq!(sz.elems(2), 3);
        assert_eq!(sz.elems_of(&BlockSet::range(0, 3)), 10);
    }

    #[test]
    fn resize_count_recomputes_bytes_in_place() {
        let mut s =
            Schedule::new(cl(), Collective::Scatter { root: 0, c: 10 }, "test");
        let t = s.transfer(0, 1, BlockSet::single(1));
        s.push_round(Round::of(vec![t]));
        s.resize_count(25);
        assert_eq!(s.op, Collective::Scatter { root: 0, c: 25 });
        assert_eq!(s.rounds[0].transfers[0].bytes, 100);
    }

    #[test]
    fn with_count_preserves_structure() {
        let op = Collective::Bcast { root: 3, c: 100, segments: 4 };
        assert_eq!(op.with_count(7), Collective::Bcast { root: 3, c: 7, segments: 4 });
        assert_eq!(Collective::Alltoall { c: 1 }.with_count(9), Collective::Alltoall { c: 9 });
    }

    #[test]
    fn count_sizer_matches_resize_count_uniform() {
        let mut s = Schedule::new(cl(), Collective::Alltoall { c: 3 }, "test");
        let t0 = s.transfer(0, 1, BlockSet::range(0, 2));
        let t1 = s.transfer(2, 3, BlockSet::single(7));
        s.push_round(Round::of(vec![t0]));
        s.push_round(Round::of(vec![t1]));
        let sizer = s.count_sizer();
        assert_eq!(sizer.num_transfers(), 2);
        let mut out = vec![0u64; 2];
        for c in [0u64, 1, 25, 60_000] {
            sizer.resize_count_into(c, &mut out);
            s.resize_count(c);
            let want: Vec<u64> = s
                .rounds
                .iter()
                .flat_map(|r| r.transfers.iter().map(|t| t.bytes))
                .collect();
            assert_eq!(out, want, "c={c}");
        }
    }

    #[test]
    fn count_sizer_matches_resize_count_split() {
        // Uneven split with out-of-order, strided block references:
        // exercises the sorted-ids remainder count.
        let mut s = Schedule::new(
            cl(),
            Collective::Bcast { root: 0, c: 10, segments: 3 },
            "test",
        );
        let t0 = s.transfer(0, 1, BlockSet::strided(2, 2, 1).union(BlockSet::single(0)));
        let t1 = s.transfer(0, 2, BlockSet::range(0, 3));
        s.push_round(Round::of(vec![t0, t1]));
        let sizer = s.count_sizer();
        let mut out = vec![0u64; 2];
        for c in [0u64, 1, 2, 3, 10, 869] {
            sizer.resize_count_into(c, &mut out);
            s.resize_count(c);
            let want: Vec<u64> = s.rounds[0].transfers.iter().map(|t| t.bytes).collect();
            assert_eq!(out, want, "c={c}");
        }
    }

    #[test]
    fn bytes_at_matches_resize_count_into() {
        let mut s = Schedule::new(
            cl(),
            Collective::Bcast { root: 0, c: 10, segments: 3 },
            "test",
        );
        let t0 = s.transfer(0, 1, BlockSet::range(1, 3));
        let t1 = s.transfer(0, 2, BlockSet::single(0));
        s.push_round(Round::of(vec![t0, t1]));
        let sizer = s.count_sizer();
        let mut out = vec![0u64; 2];
        for c in [0u64, 1, 2, 3, 7, 1000] {
            sizer.resize_count_into(c, &mut out);
            for i in 0..2 {
                assert_eq!(sizer.bytes_at(i, c), out[i], "i={i} c={c}");
            }
        }
    }

    #[test]
    fn max_safe_count_is_tight() {
        let mut s = Schedule::new(cl(), Collective::Alltoall { c: 1 }, "test");
        let t = s.transfer(0, 1, BlockSet::range(0, 3)); // 3 blocks x 4 bytes
        s.push_round(Round::of(vec![t]));
        let sizer = s.count_sizer();
        let safe = sizer.max_safe_count();
        assert_eq!(safe, u64::MAX / 12);
        assert_eq!(sizer.bytes_at(0, safe), safe * 12);
        // one past the bound overflows in u128 terms
        let wide = u128::from(safe + 1) * 12;
        assert!(wide > u128::from(u64::MAX));
    }

    #[test]
    fn max_safe_count_unbounded_without_transfers() {
        let s = Schedule::new(cl(), Collective::Alltoall { c: 1 }, "test");
        assert_eq!(s.count_sizer().max_safe_count(), u64::MAX);
    }

    #[test]
    fn first_count_above_uniform_closed_form() {
        let mut s = Schedule::new(cl(), Collective::Scatter { root: 0, c: 1 }, "test");
        let t = s.transfer(0, 1, BlockSet::range(0, 2)); // 2 blocks: bytes = 8c
        s.push_round(Round::of(vec![t]));
        let sizer = s.count_sizer();
        // 8c > 4096  ⇔  c >= 513
        assert_eq!(sizer.first_count_above(0, 4096, 1 << 40), Some(513));
        assert_eq!(sizer.bytes_at(0, 512), 4096);
        assert_eq!(sizer.bytes_at(0, 513), 4104);
        assert_eq!(sizer.first_count_above(0, 4096, 512), None);
        assert_eq!(sizer.first_count_above(0, u64::MAX, u64::MAX), None);
        assert_eq!(sizer.first_count_above(0, 0, 100), Some(1));
    }

    #[test]
    fn first_count_above_split_staircase() {
        // 3-way split, transfer carries segments {1, 2}: the staircase
        // steps unevenly with c % 3.
        let mut s = Schedule::new(
            cl(),
            Collective::Bcast { root: 0, c: 10, segments: 3 },
            "test",
        );
        let t = s.transfer(0, 1, BlockSet::range(1, 3));
        s.push_round(Round::of(vec![t]));
        let sizer = s.count_sizer();
        for threshold in [0u64, 4, 8, 100, 4096] {
            let hit = sizer.first_count_above(0, threshold, 1 << 20);
            match hit {
                Some(c) => {
                    assert!(sizer.bytes_at(0, c) > threshold, "t={threshold}");
                    assert!(
                        c == 1 || sizer.bytes_at(0, c - 1) <= threshold,
                        "t={threshold} not minimal"
                    );
                }
                None => assert!(sizer.bytes_at(0, 1 << 20) <= threshold),
            }
        }
    }

    #[test]
    #[should_panic(expected = "output length")]
    fn count_sizer_rejects_wrong_output_length() {
        let mut s = Schedule::new(cl(), Collective::Alltoall { c: 3 }, "test");
        let t = s.transfer(0, 1, BlockSet::single(0));
        s.push_round(Round::of(vec![t]));
        s.count_sizer().resize_count_into(5, &mut []);
    }

    #[test]
    fn empty_rounds_dropped() {
        let mut s = Schedule::new(cl(), Collective::Alltoall { c: 1 }, "test");
        s.push_round(Round::default());
        assert!(s.rounds.is_empty());
    }
}

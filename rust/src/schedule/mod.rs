//! The communication-schedule IR.
//!
//! Every collective algorithm in this library *compiles* to a
//! [`Schedule`]: a sequence of rounds, each holding point-to-point
//! [`Transfer`]s (which may be on-node — shared memory — or off-node —
//! network lanes) and optional [`LocalOp`]s (node-local phases executed as
//! XLA executables by the exec backend, costed as memory traffic by the
//! simulator).
//!
//! Two backends consume the IR unchanged:
//! * `sim::Engine` — discrete-event timing under a persona cost model;
//! * `exec::Runtime` — real threaded execution on real buffers.
//!
//! Data is tracked at *block* granularity. Each collective defines a block
//! layout (see [`Collective::num_blocks`]) so schedules can be validated:
//! causality (only held blocks are sent), port limits (the k-ported
//! constraint), and delivery (the collective's postcondition).

pub mod blocks;
pub mod validate;

pub use blocks::{BlockSet, Sizing};
pub use validate::{validate, validate_ports, Violation};

use crate::topology::{Cluster, Rank};

/// Which collective a schedule implements, with its parameters.
/// `c` is the element count per block in MPI convention (paper §4:
/// bcast: c elements total; scatter: c elements received per rank;
/// alltoall: c elements per (src, dst) pair).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Collective {
    /// Root broadcasts `c` elements to all p ranks. The schedule's block
    /// layout splits the payload into `segments` equal parts (1 for
    /// non-splitting algorithms, n for full-lane).
    Bcast { root: Rank, c: u64, segments: u32 },
    /// Root sends a distinct block of `c` elements to every rank.
    /// Block `j` is destined to rank `j`.
    Scatter { root: Rank, c: u64 },
    /// Every rank sends a distinct block of `c` elements to every rank.
    /// Block `i·p + j` travels from rank `i` to rank `j`.
    Alltoall { c: u64 },
    /// Every rank contributes a block of `c` elements (block `j`
    /// originates at rank `j`) and must end holding all p blocks.
    Allgather { c: u64 },
    /// Dual of scatter (paper §2: "the gather operation is the dual of
    /// the scatter operation"): block `j` starts at rank `j`; the root
    /// must end holding all p blocks.
    Gather { root: Rank, c: u64 },
}

impl Collective {
    /// Number of data blocks in this collective's layout (p = total ranks).
    pub fn num_blocks(&self, p: u32) -> u64 {
        match self {
            Collective::Bcast { segments, .. } => *segments as u64,
            Collective::Scatter { .. }
            | Collective::Allgather { .. }
            | Collective::Gather { .. } => p as u64,
            Collective::Alltoall { .. } => p as u64 * p as u64,
        }
    }

    /// Block sizing in elements.
    pub fn sizing(&self) -> Sizing {
        match self {
            Collective::Bcast { c, segments, .. } => Sizing::Split { total: *c, parts: *segments },
            Collective::Scatter { c, .. }
            | Collective::Alltoall { c }
            | Collective::Allgather { c }
            | Collective::Gather { c, .. } => Sizing::Uniform { elems: *c },
        }
    }

    /// The same collective re-targeted to a new element count `c`
    /// (block structure — segments, roots — is preserved).
    pub fn with_count(&self, c: u64) -> Collective {
        match *self {
            Collective::Bcast { root, segments, .. } => Collective::Bcast { root, c, segments },
            Collective::Scatter { root, .. } => Collective::Scatter { root, c },
            Collective::Alltoall { .. } => Collective::Alltoall { c },
            Collective::Allgather { .. } => Collective::Allgather { c },
            Collective::Gather { root, .. } => Collective::Gather { root, c },
        }
    }

    /// Blocks initially held by `rank`.
    pub fn initial_blocks(&self, rank: Rank, p: u32) -> BlockSet {
        match self {
            Collective::Bcast { root, segments, .. } => {
                if rank == *root {
                    BlockSet::range(0, *segments as u64)
                } else {
                    BlockSet::empty()
                }
            }
            Collective::Scatter { root, .. } => {
                if rank == *root {
                    BlockSet::range(0, p as u64)
                } else {
                    BlockSet::empty()
                }
            }
            Collective::Alltoall { .. } => {
                BlockSet::range(rank as u64 * p as u64, (rank as u64 + 1) * p as u64)
            }
            Collective::Allgather { .. } | Collective::Gather { .. } => {
                BlockSet::single(rank as u64)
            }
        }
    }

    /// Blocks `rank` must hold when the schedule completes.
    pub fn required_blocks(&self, rank: Rank, p: u32) -> BlockSet {
        match self {
            Collective::Bcast { segments, .. } => BlockSet::range(0, *segments as u64),
            Collective::Scatter { .. } => BlockSet::single(rank as u64),
            Collective::Alltoall { .. } => {
                // blocks i*p + rank for all i — a strided set.
                BlockSet::strided(rank as u64, p as u64, p as u64)
            }
            Collective::Allgather { .. } => BlockSet::range(0, p as u64),
            Collective::Gather { root, .. } => {
                if rank == *root {
                    BlockSet::range(0, p as u64)
                } else {
                    BlockSet::single(rank as u64)
                }
            }
        }
    }
}

/// One point-to-point message within a round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Transfer {
    pub src: Rank,
    pub dst: Rank,
    /// The data blocks carried by this message.
    pub blocks: BlockSet,
    /// Message size in bytes (cached; derived from blocks × sizing).
    pub bytes: u64,
}

/// Recognisable node-local collective phases. Semantically a round is
/// always its transfers; the hint tells backends the round *is* a node
/// collective so they may implement it specially (exec: run the AOT XLA
/// artifact for the phase; sim: charge the persona's node-collective
/// call overhead on top of the modelled memory traffic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocalOpKind {
    /// Node-local alltoall (block transpose) — `node_alltoall` artifact.
    Alltoall,
    /// Node-local allgather — `node_allgather` artifact.
    Allgather,
    /// Node-local scatter from an on-node root core — `node_scatter`.
    Scatter,
    /// Node-local broadcast from an on-node root core — `node_bcast`.
    Bcast,
}

/// One communication round. All transfers in a round may proceed
/// concurrently, subject to the port/lane limits the backends model.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Round {
    pub transfers: Vec<Transfer>,
    /// Set when every transfer in this round belongs to one node-local
    /// collective phase per node (see [`LocalOpKind`]).
    pub node_phase: Option<LocalOpKind>,
}

impl Round {
    pub fn of(transfers: Vec<Transfer>) -> Self {
        Self { transfers, node_phase: None }
    }

    pub fn node_collective(transfers: Vec<Transfer>, kind: LocalOpKind) -> Self {
        Self { transfers, node_phase: Some(kind) }
    }

    pub fn is_empty(&self) -> bool {
        self.transfers.is_empty()
    }
}

/// A compiled collective: the algorithm's full communication structure.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub cluster: Cluster,
    pub op: Collective,
    /// Bytes per element (the paper uses MPI_INT = 4).
    pub elem_bytes: u64,
    pub rounds: Vec<Round>,
    /// Human-readable algorithm name (for tables and error messages).
    pub algorithm: &'static str,
}

pub const ELEM_BYTES: u64 = 4; // MPI_INT

impl Schedule {
    pub fn new(cluster: Cluster, op: Collective, algorithm: &'static str) -> Self {
        Self { cluster, op, elem_bytes: ELEM_BYTES, rounds: Vec::new(), algorithm }
    }

    pub fn p(&self) -> u32 {
        self.cluster.p()
    }

    /// Bytes of a block set under this schedule's sizing.
    pub fn bytes_of(&self, blocks: &BlockSet) -> u64 {
        self.op.sizing().elems_of(blocks) * self.elem_bytes
    }

    /// Append a round (dropping it if empty).
    pub fn push_round(&mut self, round: Round) {
        if !round.is_empty() {
            self.rounds.push(round);
        }
    }

    /// Mutable access to round `idx`, extending with empty rounds as
    /// needed (builders place transfers at computed round indices; call
    /// [`Schedule::finalize`] afterwards to drop gaps).
    pub fn round_mut(&mut self, idx: usize) -> &mut Round {
        if idx >= self.rounds.len() {
            self.rounds.resize(idx + 1, Round::default());
        }
        &mut self.rounds[idx]
    }

    /// Place a transfer at a specific round.
    pub fn add_at(&mut self, round: usize, src: Rank, dst: Rank, blocks: BlockSet) {
        let t = self.transfer(src, dst, blocks);
        self.round_mut(round).transfers.push(t);
    }

    /// Drop empty rounds left by index-based construction.
    pub fn finalize(&mut self) {
        self.rounds.retain(|r| !r.is_empty());
    }

    /// Convenience: build a transfer with its byte size computed.
    pub fn transfer(&self, src: Rank, dst: Rank, blocks: BlockSet) -> Transfer {
        let bytes = self.bytes_of(&blocks);
        Transfer { src, dst, blocks, bytes }
    }

    /// Re-target this schedule to a new element count, recomputing every
    /// transfer's byte size from its blocks under the new sizing. The
    /// round structure is reused as-is, which is exactly right for the
    /// paper's algorithms: their communication structure depends only on
    /// (cluster, algorithm, k) — count enters through block sizes alone
    /// (the lane-decomposition property of arXiv:1910.13373). Callers
    /// sweeping count-*dependent* selections (native personas switch
    /// algorithm by size) must rebuild instead — see `sim::sweep`.
    pub fn resize_count(&mut self, c: u64) {
        self.op = self.op.with_count(c);
        let sizing = self.op.sizing();
        let elem_bytes = self.elem_bytes;
        for round in &mut self.rounds {
            for t in &mut round.transfers {
                t.bytes = sizing.elems_of(&t.blocks) * elem_bytes;
            }
        }
    }

    /// Total bytes crossing the network (off-node transfers only).
    pub fn offnode_bytes(&self) -> u64 {
        self.rounds
            .iter()
            .flat_map(|r| &r.transfers)
            .filter(|t| !self.cluster.same_node(t.src, t.dst))
            .map(|t| t.bytes)
            .sum()
    }

    /// Total bytes moved over shared memory (on-node transfers).
    pub fn onnode_bytes(&self) -> u64 {
        self.rounds
            .iter()
            .flat_map(|r| &r.transfers)
            .filter(|t| self.cluster.same_node(t.src, t.dst))
            .map(|t| t.bytes)
            .sum()
    }

    pub fn num_transfers(&self) -> usize {
        self.rounds.iter().map(|r| r.transfers.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cl() -> Cluster {
        Cluster::new(2, 4, 2)
    }

    #[test]
    fn bcast_block_layout() {
        let op = Collective::Bcast { root: 3, c: 100, segments: 4 };
        assert_eq!(op.num_blocks(8), 4);
        assert_eq!(op.initial_blocks(3, 8).count(), 4);
        assert_eq!(op.initial_blocks(0, 8).count(), 0);
        assert_eq!(op.required_blocks(7, 8).count(), 4);
    }

    #[test]
    fn scatter_block_layout() {
        let op = Collective::Scatter { root: 0, c: 10 };
        assert_eq!(op.num_blocks(8), 8);
        assert!(op.required_blocks(5, 8).contains(5));
        assert_eq!(op.required_blocks(5, 8).count(), 1);
    }

    #[test]
    fn alltoall_block_layout() {
        let op = Collective::Alltoall { c: 7 };
        let p = 4;
        assert_eq!(op.num_blocks(p), 16);
        // rank 2 starts with blocks 8..12 and must end with {2, 6, 10, 14}
        assert_eq!(op.initial_blocks(2, p).count(), 4);
        let req = op.required_blocks(2, p);
        for i in 0..4u64 {
            assert!(req.contains(i * 4 + 2), "missing block {}", i * 4 + 2);
        }
    }

    #[test]
    fn transfer_bytes_follow_sizing() {
        let mut s =
            Schedule::new(cl(), Collective::Scatter { root: 0, c: 10 }, "test");
        let t = s.transfer(0, 1, BlockSet::single(1));
        assert_eq!(t.bytes, 40);
        s.push_round(Round::of(vec![t]));
        assert_eq!(s.offnode_bytes(), 0); // ranks 0,1 are on node 0
        assert_eq!(s.onnode_bytes(), 40);
    }

    #[test]
    fn split_sizing_uneven() {
        let op = Collective::Bcast { root: 0, c: 10, segments: 3 };
        let sz = op.sizing();
        // 10 split 3 ways: 4 + 3 + 3
        assert_eq!(sz.elems(0), 4);
        assert_eq!(sz.elems(1), 3);
        assert_eq!(sz.elems(2), 3);
        assert_eq!(sz.elems_of(&BlockSet::range(0, 3)), 10);
    }

    #[test]
    fn resize_count_recomputes_bytes_in_place() {
        let mut s =
            Schedule::new(cl(), Collective::Scatter { root: 0, c: 10 }, "test");
        let t = s.transfer(0, 1, BlockSet::single(1));
        s.push_round(Round::of(vec![t]));
        s.resize_count(25);
        assert_eq!(s.op, Collective::Scatter { root: 0, c: 25 });
        assert_eq!(s.rounds[0].transfers[0].bytes, 100);
    }

    #[test]
    fn with_count_preserves_structure() {
        let op = Collective::Bcast { root: 3, c: 100, segments: 4 };
        assert_eq!(op.with_count(7), Collective::Bcast { root: 3, c: 7, segments: 4 });
        assert_eq!(Collective::Alltoall { c: 1 }.with_count(9), Collective::Alltoall { c: 9 });
    }

    #[test]
    fn empty_rounds_dropped() {
        let mut s = Schedule::new(cl(), Collective::Alltoall { c: 1 }, "test");
        s.push_round(Round::default());
        assert!(s.rounds.is_empty());
    }
}

//! Schedule invariant checking — the first-error API.
//!
//! Three invariants, used both as library assertions and as the targets
//! of the property tests:
//!
//! 1. **Causality** — a rank only sends blocks it currently holds
//!    (initial layout ∪ blocks received in *earlier* rounds; within a
//!    round sends use the pre-round state, as in message passing).
//! 2. **Port limits** — within a round, no rank is the source of more
//!    than `limit` transfers or the destination of more than `limit`
//!    (the k-ported constraint, §2.1).
//! 3. **Delivery** — after the last round, every rank holds the blocks
//!    the collective's postcondition requires.
//!
//! Both checks are thin wrappers over the `analysis` lint driver (which
//! replays holdings in domain-indexed bitsets, so they scale to the
//! full p = 1152 schedules): run the relevant passes, return the first
//! diagnostic as a typed [`Violation`]. Exhaustive callers — `mlane
//! lint`, registry validation, CI — use [`crate::analysis::analyze`]
//! directly and get *every* finding.

use crate::analysis::flow::Flow;
use crate::analysis::{codes, passes, DiagSink, Diagnostic};
use crate::topology::Rank;

use super::{Schedule, Violation::*};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// Rank sent a block it did not hold. (round, src, block)
    CausalityViolated { round: usize, src: Rank, block: u64 },
    /// Rank exceeded the per-round send or receive limit.
    PortLimitExceeded { round: usize, rank: Rank, sends: u32, recvs: u32, limit: u32 },
    /// Rank is missing a required block at completion.
    NotDelivered { rank: Rank, block: u64 },
    /// Transfer references a block id outside the collective's layout.
    UnknownBlock { round: usize, block: u64 },
    /// Transfer src/dst out of range or self-message.
    BadEndpoints { round: usize, src: Rank, dst: Rank },
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CausalityViolated { round, src, block } => {
                write!(f, "round {round}: rank {src} sent block {block} it does not hold")
            }
            PortLimitExceeded { round, rank, sends, recvs, limit } => write!(
                f,
                "round {round}: rank {rank} uses {sends} send / {recvs} recv ports (limit {limit})"
            ),
            NotDelivered { rank, block } => {
                write!(f, "completion: rank {rank} missing required block {block}")
            }
            UnknownBlock { round, block } => {
                write!(f, "round {round}: unknown block id {block}")
            }
            BadEndpoints { round, src, dst } => {
                write!(f, "round {round}: bad endpoints {src} -> {dst}")
            }
        }
    }
}

/// Map the first invariant diagnostic (in emission order, which matches
/// the legacy first-error walk) back to a typed [`Violation`].
/// Non-invariant lints riding along (e.g. redundant transfers the flow
/// replay noticed) are ignored here.
fn first_violation(diags: Vec<Diagnostic>) -> Result<(), Violation> {
    for d in diags {
        let round = d.span.round.unwrap_or(0);
        let g = |k: &str| d.u64_field(k).unwrap_or(0);
        match d.code {
            codes::BAD_ENDPOINTS => {
                return Err(BadEndpoints { round, src: g("src") as Rank, dst: g("dst") as Rank })
            }
            codes::UNKNOWN_BLOCK => return Err(UnknownBlock { round, block: g("block") }),
            codes::CAUSALITY => {
                return Err(CausalityViolated { round, src: g("src") as Rank, block: g("block") })
            }
            codes::DELIVERY => {
                return Err(NotDelivered { rank: g("rank") as Rank, block: g("block") })
            }
            codes::PORT_BUDGET => {
                return Err(PortLimitExceeded {
                    round,
                    rank: g("rank") as Rank,
                    sends: g("sends") as u32,
                    recvs: g("recvs") as u32,
                    limit: g("limit") as u32,
                })
            }
            _ => {}
        }
    }
    Ok(())
}

/// Check port limits only (cheap; scales to p = 1152 alltoall schedules).
/// `limit` is the k of the k-ported model; k-lane schedules are built so
/// each *rank* still sends/receives ≤ 1 message per round (lane sharing
/// is a backend cost concern, not a schedule-shape one), so they pass
/// with limit = 1.
pub fn validate_ports(s: &Schedule, limit: u32) -> Result<(), Violation> {
    let mut sink = DiagSink::new(1);
    passes::ports(s, limit, true, &mut sink);
    first_violation(sink.finish())
}

/// Full semantic validation: causality + delivery (+ endpoint sanity).
pub fn validate(s: &Schedule) -> Result<(), Violation> {
    let mut sink = DiagSink::new(1);
    let flow = Flow::run(s, &mut sink);
    passes::delivery(s, &flow, &mut sink);
    first_violation(sink.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{BlockSet, Collective, Round, Schedule};
    use crate::topology::Cluster;

    fn sched() -> Schedule {
        // 1 node × 4 cores; bcast root 0, single segment.
        Schedule::new(
            Cluster::new(1, 4, 1),
            Collective::Bcast { root: 0, c: 8, segments: 1 },
            "test",
        )
    }

    #[test]
    fn valid_binomial_bcast_passes() {
        let mut s = sched();
        let t1 = s.transfer(0, 2, BlockSet::single(0));
        s.push_round(Round::of(vec![t1]));
        let t2 = s.transfer(0, 1, BlockSet::single(0));
        let t3 = s.transfer(2, 3, BlockSet::single(0));
        s.push_round(Round::of(vec![t2, t3]));
        assert_eq!(validate(&s), Ok(()));
        assert_eq!(validate_ports(&s, 1), Ok(()));
    }

    #[test]
    fn causality_violation_detected() {
        let mut s = sched();
        // rank 1 sends before receiving
        let t = s.transfer(1, 2, BlockSet::single(0));
        s.push_round(Round::of(vec![t]));
        assert!(matches!(validate(&s), Err(CausalityViolated { src: 1, .. })));
    }

    #[test]
    fn missing_delivery_detected() {
        let mut s = sched();
        let t = s.transfer(0, 1, BlockSet::single(0));
        s.push_round(Round::of(vec![t]));
        // ranks 2, 3 never receive
        assert!(matches!(validate(&s), Err(NotDelivered { .. })));
    }

    #[test]
    fn port_limit_detected() {
        let mut s = sched();
        let t1 = s.transfer(0, 1, BlockSet::single(0));
        let t2 = s.transfer(0, 2, BlockSet::single(0));
        s.push_round(Round::of(vec![t1, t2]));
        assert!(matches!(
            validate_ports(&s, 1),
            Err(PortLimitExceeded { rank: 0, sends: 2, .. })
        ));
        assert_eq!(validate_ports(&s, 2), Ok(()));
    }

    #[test]
    fn self_message_rejected() {
        let mut s = sched();
        let t = s.transfer(0, 0, BlockSet::single(0));
        s.push_round(Round::of(vec![t]));
        assert!(matches!(validate(&s), Err(BadEndpoints { .. })));
    }

    #[test]
    fn unknown_block_rejected() {
        let mut s = sched();
        // hand-built transfer: only block 0 exists in this layout
        let t = crate::schedule::Transfer {
            src: 0,
            dst: 1,
            blocks: BlockSet::single(5),
            bytes: 4,
        };
        s.push_round(Round::of(vec![t]));
        assert!(matches!(validate(&s), Err(UnknownBlock { block: 5, .. })));
    }

    #[test]
    fn violation_fields_survive_the_diagnostic_round_trip() {
        // The wrapper rebuilds typed violations from diagnostic
        // payloads; pin every field, not just the variant.
        let mut s = sched();
        let t = s.transfer(1, 2, BlockSet::single(0));
        s.push_round(Round::of(vec![t]));
        s.rounds.insert(0, Round::of(vec![]));
        assert_eq!(
            validate(&s),
            Err(CausalityViolated { round: 1, src: 1, block: 0 })
        );

        let mut s = sched();
        let t1 = s.transfer(0, 1, BlockSet::single(0));
        let t2 = s.transfer(0, 2, BlockSet::single(0));
        s.push_round(Round::of(vec![t1, t2]));
        assert_eq!(
            validate_ports(&s, 1),
            Err(PortLimitExceeded { round: 0, rank: 0, sends: 2, recvs: 0, limit: 1 })
        );
    }
}

//! Schedule invariant checking.
//!
//! Three invariants, used both as library assertions and as the targets
//! of the property tests:
//!
//! 1. **Causality** — a rank only sends blocks it currently holds
//!    (initial layout ∪ blocks received in *earlier* rounds; within a
//!    round sends use the pre-round state, as in message passing).
//! 2. **Port limits** — within a round, no rank is the source of more
//!    than `limit` transfers or the destination of more than `limit`
//!    (the k-ported constraint, §2.1).
//! 3. **Delivery** — after the last round, every rank holds the blocks
//!    the collective's postcondition requires.
//!
//! Causality/delivery track holdings with per-rank hash sets: O(total
//! block movements). Fine for test-scale p; port checking is cheap and
//! scales to the full p = 1152 schedules.

use std::collections::HashSet;

use super::{Schedule, Violation::*};
use crate::topology::Rank;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// Rank sent a block it did not hold. (round, src, block)
    CausalityViolated { round: usize, src: Rank, block: u64 },
    /// Rank exceeded the per-round send or receive limit.
    PortLimitExceeded { round: usize, rank: Rank, sends: u32, recvs: u32, limit: u32 },
    /// Rank is missing a required block at completion.
    NotDelivered { rank: Rank, block: u64 },
    /// Transfer references a block id outside the collective's layout.
    UnknownBlock { round: usize, block: u64 },
    /// Transfer src/dst out of range or self-message.
    BadEndpoints { round: usize, src: Rank, dst: Rank },
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CausalityViolated { round, src, block } => {
                write!(f, "round {round}: rank {src} sent block {block} it does not hold")
            }
            PortLimitExceeded { round, rank, sends, recvs, limit } => write!(
                f,
                "round {round}: rank {rank} uses {sends} send / {recvs} recv ports (limit {limit})"
            ),
            NotDelivered { rank, block } => {
                write!(f, "completion: rank {rank} missing required block {block}")
            }
            UnknownBlock { round, block } => {
                write!(f, "round {round}: unknown block id {block}")
            }
            BadEndpoints { round, src, dst } => {
                write!(f, "round {round}: bad endpoints {src} -> {dst}")
            }
        }
    }
}

/// Check port limits only (cheap; scales to p = 1152 alltoall schedules).
/// `limit` is the k of the k-ported model; k-lane schedules are built so
/// each *rank* still sends/receives ≤ 1 message per round (lane sharing
/// is a backend cost concern, not a schedule-shape one), so they pass
/// with limit = 1.
pub fn validate_ports(s: &Schedule, limit: u32) -> Result<(), Violation> {
    let p = s.p() as usize;
    let mut sends = vec![0u32; p];
    let mut recvs = vec![0u32; p];
    for (ri, round) in s.rounds.iter().enumerate() {
        for t in &round.transfers {
            if t.src >= s.p() || t.dst >= s.p() || t.src == t.dst {
                return Err(BadEndpoints { round: ri, src: t.src, dst: t.dst });
            }
            sends[t.src as usize] += 1;
            recvs[t.dst as usize] += 1;
        }
        for t in &round.transfers {
            for r in [t.src, t.dst] {
                let (sn, rc) = (sends[r as usize], recvs[r as usize]);
                if sn > limit || rc > limit {
                    return Err(PortLimitExceeded {
                        round: ri,
                        rank: r,
                        sends: sn,
                        recvs: rc,
                        limit,
                    });
                }
            }
        }
        for t in &round.transfers {
            sends[t.src as usize] = 0;
            recvs[t.dst as usize] = 0;
        }
    }
    Ok(())
}

/// Full semantic validation: causality + delivery (+ endpoint sanity).
pub fn validate(s: &Schedule) -> Result<(), Violation> {
    let p = s.p();
    let nb = s.op.num_blocks(p);
    let mut held: Vec<HashSet<u64>> = (0..p)
        .map(|r| s.op.initial_blocks(r, p).iter().collect())
        .collect();

    for (ri, round) in s.rounds.iter().enumerate() {
        // Sends read the pre-round state.
        for t in &round.transfers {
            if t.src >= p || t.dst >= p || t.src == t.dst {
                return Err(BadEndpoints { round: ri, src: t.src, dst: t.dst });
            }
            for b in t.blocks.iter() {
                if b >= nb {
                    return Err(UnknownBlock { round: ri, block: b });
                }
                if !held[t.src as usize].contains(&b) {
                    return Err(CausalityViolated { round: ri, src: t.src, block: b });
                }
            }
        }
        for t in &round.transfers {
            let dst = t.dst as usize;
            for b in t.blocks.iter() {
                held[dst].insert(b);
            }
        }
    }

    for r in 0..p {
        for b in s.op.required_blocks(r, p).iter() {
            if !held[r as usize].contains(&b) {
                return Err(NotDelivered { rank: r, block: b });
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{BlockSet, Collective, Round, Schedule};
    use crate::topology::Cluster;

    fn sched() -> Schedule {
        // 1 node × 4 cores; bcast root 0, single segment.
        Schedule::new(
            Cluster::new(1, 4, 1),
            Collective::Bcast { root: 0, c: 8, segments: 1 },
            "test",
        )
    }

    #[test]
    fn valid_binomial_bcast_passes() {
        let mut s = sched();
        let t1 = s.transfer(0, 2, BlockSet::single(0));
        s.push_round(Round::of(vec![t1]));
        let t2 = s.transfer(0, 1, BlockSet::single(0));
        let t3 = s.transfer(2, 3, BlockSet::single(0));
        s.push_round(Round::of(vec![t2, t3]));
        assert_eq!(validate(&s), Ok(()));
        assert_eq!(validate_ports(&s, 1), Ok(()));
    }

    #[test]
    fn causality_violation_detected() {
        let mut s = sched();
        // rank 1 sends before receiving
        let t = s.transfer(1, 2, BlockSet::single(0));
        s.push_round(Round::of(vec![t]));
        assert!(matches!(validate(&s), Err(CausalityViolated { src: 1, .. })));
    }

    #[test]
    fn missing_delivery_detected() {
        let mut s = sched();
        let t = s.transfer(0, 1, BlockSet::single(0));
        s.push_round(Round::of(vec![t]));
        // ranks 2, 3 never receive
        assert!(matches!(validate(&s), Err(NotDelivered { .. })));
    }

    #[test]
    fn port_limit_detected() {
        let mut s = sched();
        let t1 = s.transfer(0, 1, BlockSet::single(0));
        let t2 = s.transfer(0, 2, BlockSet::single(0));
        s.push_round(Round::of(vec![t1, t2]));
        assert!(matches!(
            validate_ports(&s, 1),
            Err(PortLimitExceeded { rank: 0, sends: 2, .. })
        ));
        assert_eq!(validate_ports(&s, 2), Ok(()));
    }

    #[test]
    fn self_message_rejected() {
        let mut s = sched();
        let t = s.transfer(0, 0, BlockSet::single(0));
        s.push_round(Round::of(vec![t]));
        assert!(matches!(validate(&s), Err(BadEndpoints { .. })));
    }

    #[test]
    fn unknown_block_rejected() {
        let mut s = sched();
        // hand-built transfer: only block 0 exists in this layout
        let t = crate::schedule::Transfer {
            src: 0,
            dst: 1,
            blocks: BlockSet::single(5),
            bytes: 4,
        };
        s.push_round(Round::of(vec![t]));
        assert!(matches!(validate(&s), Err(UnknownBlock { block: 5, .. })));
    }
}

//! The shared holdings/flow computation every lint pass reads.
//!
//! One replay of the schedule in execution order (sends read the
//! pre-round state; receives land when the round completes). Holdings
//! are domain-indexed bitsets: each rank's *domain* is the sorted set
//! of block ids it can ever hold (initial layout ∪ blocks addressed to
//! it), so an alltoall at p = 1152 costs ~2p bits per rank instead of
//! a p² hash set — the whole point of replacing the `HashSet` walk.
//!
//! The replay itself emits the per-transfer semantic facts — bad
//! endpoints, unknown blocks, causality violations, redundant
//! deliveries — in exactly the order the legacy first-error validator
//! discovered them, which is what lets `schedule::validate` remain a
//! thin "first diagnostic" wrapper.

use super::{codes, DiagSink, Diagnostic, Severity};
use crate::schedule::{Schedule, Transfer};

/// Sentinel for "never" in the round-index tables.
pub(crate) const NEVER: u32 = u32::MAX;

/// Endpoint sanity shared by every pass: in-range, no self-message.
pub(crate) fn endpoints_ok(s: &Schedule, t: &Transfer) -> bool {
    let p = s.p();
    t.src < p && t.dst < p && t.src != t.dst
}

fn word_len(bits: usize) -> usize {
    bits.div_ceil(64)
}

fn test_bit(bits: &[u64], i: usize) -> bool {
    bits[i >> 6] >> (i & 63) & 1 == 1
}

fn set_bit(bits: &mut [u64], i: usize) {
    bits[i >> 6] |= 1 << (i & 63);
}

pub(crate) struct Flow {
    /// Per rank: sorted, deduplicated block-id domain.
    pub domain: Vec<Vec<u64>>,
    /// Per rank: bitset over domain indices — holdings after the last
    /// round.
    held: Vec<Vec<u64>>,
    /// Per rank, per domain index: round of first receive ([`NEVER`] =
    /// initial or never held).
    pub first_recv: Vec<Vec<u32>>,
    /// Per rank, per domain index: round of the last send of a held
    /// block ([`NEVER`] = never sent).
    pub last_send: Vec<Vec<u32>>,
}

impl Flow {
    /// Does `rank` hold `block` after the last round?
    pub(crate) fn holds(&self, rank: usize, block: u64) -> bool {
        self.domain[rank]
            .binary_search(&block)
            .is_ok_and(|i| test_bit(&self.held[rank], i))
    }

    // Invariant expects only: the domain was built from exactly the
    // initial layouts and addressed blocks probed below.
    #[allow(clippy::expect_used)]
    pub(crate) fn run(s: &Schedule, sink: &mut DiagSink) -> Flow {
        let p = s.p() as usize;
        let nb = s.op.num_blocks(s.p());

        let mut domain: Vec<Vec<u64>> =
            (0..s.p()).map(|r| s.op.initial_blocks(r, s.p()).iter().collect()).collect();
        for round in &s.rounds {
            for t in &round.transfers {
                if endpoints_ok(s, t) {
                    domain[t.dst as usize].extend(t.blocks.iter());
                }
            }
        }
        for d in &mut domain {
            d.sort_unstable();
            d.dedup();
        }

        let mut held: Vec<Vec<u64>> =
            domain.iter().map(|d| vec![0u64; word_len(d.len())]).collect();
        let mut first_recv: Vec<Vec<u32>> = domain.iter().map(|d| vec![NEVER; d.len()]).collect();
        let mut last_send = first_recv.clone();
        for r in 0..p {
            for b in s.op.initial_blocks(r as u32, s.p()).iter() {
                let i = domain[r].binary_search(&b).expect("initial block is in the domain");
                set_bit(&mut held[r], i);
            }
        }

        for (ri, round) in s.rounds.iter().enumerate() {
            // Sends read the pre-round state.
            for (ti, t) in round.transfers.iter().enumerate() {
                if !endpoints_ok(s, t) {
                    sink.push(
                        Diagnostic::new(
                            Severity::Error,
                            codes::BAD_ENDPOINTS,
                            format!("bad endpoints {} -> {}", t.src, t.dst),
                        )
                        .at(ri, ti)
                        .with("src", t.src)
                        .with("dst", t.dst),
                    );
                    continue;
                }
                let src = t.src as usize;
                for b in t.blocks.iter() {
                    if b >= nb {
                        sink.push(
                            Diagnostic::new(
                                Severity::Error,
                                codes::UNKNOWN_BLOCK,
                                format!("unknown block id {b}"),
                            )
                            .at(ri, ti)
                            .with("block", b),
                        );
                        continue;
                    }
                    match domain[src].binary_search(&b) {
                        Ok(i) if test_bit(&held[src], i) => last_send[src][i] = ri as u32,
                        _ => sink.push(
                            Diagnostic::new(
                                Severity::Error,
                                codes::CAUSALITY,
                                format!("rank {} sent block {b} it does not hold", t.src),
                            )
                            .at(ri, ti)
                            .with("src", t.src)
                            .with("block", b),
                        ),
                    }
                }
            }
            // Receives land when the round completes (bad-endpoint
            // transfers deliver nothing).
            for (ti, t) in round.transfers.iter().enumerate() {
                if !endpoints_ok(s, t) {
                    continue;
                }
                let dst = t.dst as usize;
                let mut redundant = 0u64;
                let mut sample = None;
                for b in t.blocks.iter() {
                    let i = domain[dst].binary_search(&b).expect("received block is in the domain");
                    if test_bit(&held[dst], i) {
                        redundant += 1;
                        if sample.is_none() {
                            sample = Some(b);
                        }
                    } else {
                        set_bit(&mut held[dst], i);
                        first_recv[dst][i] = ri as u32;
                    }
                }
                if let Some(b) = sample {
                    sink.push(
                        Diagnostic::new(
                            Severity::Warn,
                            codes::REDUNDANT_TRANSFER,
                            format!(
                                "rank {} receives {redundant} block(s) it already holds (e.g. block {b})",
                                t.dst
                            ),
                        )
                        .at(ri, ti)
                        .with("dst", t.dst)
                        .with("count", redundant)
                        .with("block", b),
                    );
                }
            }
        }

        Flow { domain, held, first_recv, last_send }
    }
}

//! Schedule static analysis: a lint driver over the schedule IR.
//!
//! The schedule is to this module what an AST is to a compiler front
//! end. One shared flow computation ([`flow::Flow`]) replays the
//! schedule once — per-rank holdings in domain-indexed bitsets, so full
//! semantic analysis scales to the paper's p = 1152 alltoall schedules —
//! and a registered set of lint passes (the staged tables in
//! [`passes`]) reads the result. Every finding becomes a structured
//! [`Diagnostic`]; nothing stops at the first violation. The
//! [`symbolic`] layer lifts the same pass tables from single counts to
//! whole count intervals (`mlane certify`).
//!
//! Severities:
//! * **error** — the schedule does not implement its collective
//!   (causality, port budget, delivery, endpoint/block sanity) or
//!   cannot complete (rendezvous deadlock);
//! * **warn** — the schedule is correct but wasteful (redundant
//!   transfers, dead data) or oversubscribes node lanes (§2.2);
//! * **info** — optimality observations (round count vs. the §2 lower
//!   bound, mergeable rounds) and truncation notices.
//!
//! `schedule::validate`'s first-error API is now a thin wrapper over
//! this driver; `mlane lint` and `registry_validation.rs` consume it
//! exhaustively.

// Production analysis code must surface findings as diagnostics or
// typed errors, never by panicking on user input; load-time/invariant
// panics carry a scoped, justified allow.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub(crate) mod flow;
pub(crate) mod passes;
pub mod symbolic;

pub use symbolic::{
    analyze_series, certify, certify_into, certify_registry, CertArena, CertInterval,
    CertReport, Certificate, CertifyOptions,
};

use crate::harness::report::esc;
use crate::topology::Cluster;
use crate::{model::CostModel, schedule::Schedule};

/// Stable lint codes — one per pass output kind. These are API: tests,
/// CI and downstream tooling match on them.
pub mod codes {
    /// A rank sends a block it does not hold.
    pub const CAUSALITY: &str = "causality";
    /// A rank exceeds the per-round k-ported send/recv budget (§2.1).
    pub const PORT_BUDGET: &str = "port-budget";
    /// A rank is missing a required block at completion.
    pub const DELIVERY: &str = "delivery";
    /// A transfer references a block id outside the collective layout.
    pub const UNKNOWN_BLOCK: &str = "unknown-block";
    /// Transfer src/dst out of range or self-message.
    pub const BAD_ENDPOINTS: &str = "bad-endpoints";
    /// A node drives more concurrent off-node messages than it has
    /// lanes (§2.2) in some round.
    pub const LANE_CONTENTION: &str = "lane-contention";
    /// Schedule-level summary of lane contention: worst per-round
    /// serialization factor.
    pub const LANE_SERIALIZATION: &str = "lane-serialization";
    /// A round's rendezvous sends form a waits-for cycle.
    pub const DEADLOCK: &str = "deadlock";
    /// A rank receives blocks it already holds.
    pub const REDUNDANT_TRANSFER: &str = "redundant-transfer";
    /// A rank receives blocks it neither requires nor forwards.
    pub const DEAD_DATA: &str = "dead-data";
    /// Round count exceeds the k-ported lower bound ceil(log_{k+1} p).
    pub const ROUND_BOUND: &str = "round-bound";
    /// Two adjacent rounds are independent and fit the port budget
    /// merged.
    pub const MERGEABLE_ROUNDS: &str = "mergeable-rounds";
    /// Per-code diagnostic cap reached; the drop count is reported
    /// instead of silently truncating.
    pub const TRUNCATED: &str = "truncated";
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warn,
    Info,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
            Severity::Info => "info",
        }
    }
}

impl std::fmt::Display for Severity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Where in the schedule a diagnostic points: a round, a (round,
/// transfer index) pair, or the whole schedule (both `None`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Span {
    pub round: Option<usize>,
    pub transfer: Option<usize>,
}

/// A machine-readable payload value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    U64(u64),
    F64(f64),
    Str(String),
}

impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::U64(v)
    }
}

impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::U64(u64::from(v))
    }
}

impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::U64(v as u64)
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::F64(v)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

impl Value {
    fn push_json(&self, out: &mut String) {
        match self {
            Value::U64(v) => out.push_str(&v.to_string()),
            Value::F64(v) => out.push_str(&format!("{v}")),
            Value::Str(v) => {
                out.push('"');
                out.push_str(&esc(v));
                out.push('"');
            }
        }
    }
}

/// One lint finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Stable code from [`codes`].
    pub code: &'static str,
    pub span: Span,
    pub message: String,
    /// Machine-readable fields, in emission order.
    pub payload: Vec<(&'static str, Value)>,
}

impl Diagnostic {
    pub fn new(severity: Severity, code: &'static str, message: String) -> Self {
        Diagnostic { severity, code, span: Span::default(), message, payload: Vec::new() }
    }

    pub fn at_round(mut self, round: usize) -> Self {
        self.span.round = Some(round);
        self
    }

    pub fn at(mut self, round: usize, transfer: usize) -> Self {
        self.span = Span { round: Some(round), transfer: Some(transfer) };
        self
    }

    pub fn with(mut self, key: &'static str, value: impl Into<Value>) -> Self {
        self.payload.push((key, value.into()));
        self
    }

    /// Payload lookup for integer fields.
    pub fn u64_field(&self, key: &str) -> Option<u64> {
        self.payload.iter().find_map(|(k, v)| match v {
            Value::U64(n) if *k == key => Some(*n),
            _ => None,
        })
    }

    /// One human-readable line: `severity[code] span: message`.
    pub fn text_line(&self) -> String {
        let span = match (self.span.round, self.span.transfer) {
            (Some(r), Some(t)) => format!("round {r}/t{t}"),
            (Some(r), None) => format!("round {r}"),
            _ => "schedule".to_string(),
        };
        format!("{}[{}] {}: {}", self.severity, self.code, span, self.message)
    }

    fn push_json(&self, out: &mut String) {
        out.push_str("{\"severity\":\"");
        out.push_str(self.severity.name());
        out.push_str("\",\"code\":\"");
        out.push_str(self.code);
        out.push_str("\",\"round\":");
        match self.span.round {
            Some(r) => out.push_str(&r.to_string()),
            None => out.push_str("null"),
        }
        out.push_str(",\"transfer\":");
        match self.span.transfer {
            Some(t) => out.push_str(&t.to_string()),
            None => out.push_str("null"),
        }
        out.push_str(",\"message\":\"");
        out.push_str(&esc(&self.message));
        out.push_str("\",\"payload\":{");
        for (i, (k, v)) in self.payload.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            out.push_str(k);
            out.push_str("\":");
            v.push_json(out);
        }
        out.push_str("}}");
    }
}

/// Lint configuration. The defaults describe the shipped backends: the
/// k of the k-ported model must be supplied (it is per-algorithm —
/// `ports_required`); rendezvous thresholds default to "never" because
/// the threaded exec backend buffers every message and cannot block a
/// sender.
#[derive(Clone, Copy, Debug)]
pub struct LintConfig {
    /// k of the k-ported model: per-rank per-round send/recv budget.
    pub port_limit: u32,
    /// Rendezvous threshold for off-node transfers, in bytes: messages
    /// strictly larger are modelled as blocking the sender until the
    /// receiver posts (what the deadlock pass searches for cycles
    /// over). `u64::MAX` = fully buffered (our exec layer); set to a
    /// persona's `eager_net` to lint portability against a
    /// synchronous-rendezvous MPI.
    pub rendezvous_net: u64,
    /// Same threshold for on-node (shared-memory) transfers.
    pub rendezvous_shm: u64,
    /// Per-lint-code diagnostic cap; overflow surfaces as one
    /// [`codes::TRUNCATED`] info per code, never silently.
    pub max_per_lint: usize,
}

impl LintConfig {
    pub fn new(port_limit: u32) -> Self {
        LintConfig {
            port_limit,
            rendezvous_net: u64::MAX,
            rendezvous_shm: u64::MAX,
            max_per_lint: 50,
        }
    }

    /// Model a synchronous-rendezvous backend: messages above the
    /// given eager thresholds block the sender.
    pub fn with_rendezvous(mut self, net: u64, shm: u64) -> Self {
        self.rendezvous_net = net;
        self.rendezvous_shm = shm;
        self
    }

    /// Rendezvous thresholds from the baseline cost model's eager
    /// limits (`CostModel::hydra_baseline`).
    pub fn with_baseline_rendezvous(self) -> Self {
        let m = CostModel::hydra_baseline();
        self.with_rendezvous(m.eager_net, m.eager_shm)
    }
}

/// Collects diagnostics with a per-code cap. Passes push findings in
/// discovery order; `finish` appends one truncation notice per capped
/// code so no drop is silent.
pub(crate) struct DiagSink {
    cap: usize,
    diags: Vec<Diagnostic>,
    kept: Vec<(&'static str, usize)>,
    dropped: Vec<(&'static str, usize)>,
}

impl DiagSink {
    pub(crate) fn new(cap: usize) -> Self {
        DiagSink { cap: cap.max(1), diags: Vec::new(), kept: Vec::new(), dropped: Vec::new() }
    }

    pub(crate) fn push(&mut self, d: Diagnostic) {
        match self.kept.iter_mut().find(|(c, _)| *c == d.code) {
            Some((_, n)) if *n >= self.cap => {
                match self.dropped.iter_mut().find(|(c, _)| *c == d.code) {
                    Some((_, m)) => *m += 1,
                    None => self.dropped.push((d.code, 1)),
                }
            }
            Some((_, n)) => {
                *n += 1;
                self.diags.push(d);
            }
            None => {
                self.kept.push((d.code, 1));
                self.diags.push(d);
            }
        }
    }

    pub(crate) fn finish(mut self) -> Vec<Diagnostic> {
        let cap = self.cap;
        for (code, n) in std::mem::take(&mut self.dropped) {
            self.diags.push(truncation_notice(code, n, cap));
        }
        self.diags
    }

    /// The kept diagnostics plus the per-code drop counts (first-drop
    /// order), *without* appending truncation notices — the symbolic
    /// layer runs the pass stages through separate sinks and renders
    /// the notices itself, in the exact order one combined sink would
    /// have ([`truncation_notice`]).
    pub(crate) fn into_parts(self) -> (Vec<Diagnostic>, Vec<(&'static str, usize)>) {
        (self.diags, self.dropped)
    }
}

/// The one rendering of a [`codes::TRUNCATED`] notice, shared by
/// [`DiagSink::finish`] and the symbolic layer's segment reassembly so
/// the two stay bitwise-identical.
pub(crate) fn truncation_notice(code: &'static str, n: usize, cap: usize) -> Diagnostic {
    Diagnostic::new(
        Severity::Info,
        codes::TRUNCATED,
        format!("{n} more {code} diagnostic(s) suppressed (cap {cap} per lint)"),
    )
    .with("lint", code)
    .with("dropped", n)
    .with("cap", cap)
}

/// The result of linting one schedule: every finding, in pass order.
#[derive(Clone, Debug)]
pub struct Analysis {
    pub diagnostics: Vec<Diagnostic>,
}

impl Analysis {
    pub fn count_of(&self, sev: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == sev).count()
    }

    pub fn errors(&self) -> usize {
        self.count_of(Severity::Error)
    }

    pub fn warnings(&self) -> usize {
        self.count_of(Severity::Warn)
    }

    pub fn infos(&self) -> usize {
        self.count_of(Severity::Info)
    }

    /// No error-severity findings (warnings/infos allowed).
    pub fn is_clean(&self) -> bool {
        self.errors() == 0
    }

    pub fn first_error(&self) -> Option<&Diagnostic> {
        self.diagnostics.iter().find(|d| d.severity == Severity::Error)
    }

    /// One text line per diagnostic (empty string when clean and quiet).
    pub fn text(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.text_line());
            out.push('\n');
        }
        out
    }

    /// JSON array of diagnostics (strict, machine-readable).
    pub fn to_json(&self) -> String {
        let mut out = String::from("[");
        for (i, d) in self.diagnostics.iter().enumerate() {
            out.push_str(if i == 0 { "\n  " } else { ",\n  " });
            d.push_json(&mut out);
        }
        if !self.diagnostics.is_empty() {
            out.push('\n');
        }
        out.push(']');
        out
    }
}

/// Run every registered lint pass over one shared flow computation and
/// collect all findings.
pub fn analyze(s: &Schedule, cfg: &LintConfig) -> Analysis {
    let mut sink = DiagSink::new(cfg.max_per_lint);
    let flow = flow::Flow::run(s, &mut sink);
    let ctx = passes::PassCtx { s, cfg, flow: &flow };
    for stage in [passes::PREFIX_PASSES, passes::BYTE_PASSES, passes::SUFFIX_PASSES] {
        for (_, pass) in stage {
            pass(&ctx, &mut sink);
        }
    }
    Analysis { diagnostics: sink.finish() }
}

/// One linted (algorithm, op, count) cell of a lint run.
#[derive(Clone, Debug)]
pub struct LintEntry {
    pub algorithm: String,
    pub op: &'static str,
    pub count: u64,
    pub persona: &'static str,
    pub cluster: Cluster,
    pub port_limit: u32,
    pub analysis: Analysis,
}

/// A full `mlane lint` run: one entry per linted schedule, renderable
/// as text or strict JSON. Rendering lives here (not in the CLI) so it
/// shares the report layer's string escaping.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub entries: Vec<LintEntry>,
}

impl LintReport {
    pub fn errors(&self) -> usize {
        self.entries.iter().map(|e| e.analysis.errors()).sum()
    }

    pub fn warnings(&self) -> usize {
        self.entries.iter().map(|e| e.analysis.warnings()).sum()
    }

    pub fn infos(&self) -> usize {
        self.entries.iter().map(|e| e.analysis.infos()).sum()
    }

    /// Text rendering: clean schedules stay silent; every finding is
    /// listed under its schedule header; one summary line at the end.
    pub fn text(&self) -> String {
        let mut out = String::new();
        for e in &self.entries {
            if e.analysis.diagnostics.is_empty() {
                continue;
            }
            out.push_str(&format!(
                "== {} {} c={} on {}x{} (lanes={}) [{}] ports={}: {} error(s), {} warning(s), {} info(s)\n",
                e.algorithm,
                e.op,
                e.count,
                e.cluster.nodes,
                e.cluster.cores,
                e.cluster.lanes,
                e.persona,
                e.port_limit,
                e.analysis.errors(),
                e.analysis.warnings(),
                e.analysis.infos(),
            ));
            for d in &e.analysis.diagnostics {
                out.push_str("  ");
                out.push_str(&d.text_line());
                out.push('\n');
            }
        }
        out.push_str(&format!(
            "linted {} schedule(s): {} error(s), {} warning(s), {} info(s)\n",
            self.entries.len(),
            self.errors(),
            self.warnings(),
            self.infos(),
        ));
        out
    }

    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\n  \"schedules\": {},\n  \"errors\": {},\n  \"warnings\": {},\n  \"infos\": {},\n  \"entries\": [",
            self.entries.len(),
            self.errors(),
            self.warnings(),
            self.infos(),
        ));
        for (i, e) in self.entries.iter().enumerate() {
            out.push_str(if i == 0 { "\n    " } else { ",\n    " });
            out.push_str(&format!(
                "{{\"algorithm\":\"{}\",\"op\":\"{}\",\"count\":{},\"persona\":\"{}\",\"nodes\":{},\"cores\":{},\"lanes\":{},\"port_limit\":{},\"errors\":{},\"warnings\":{},\"infos\":{},\"diagnostics\":[",
                esc(&e.algorithm),
                e.op,
                e.count,
                e.persona,
                e.cluster.nodes,
                e.cluster.cores,
                e.cluster.lanes,
                e.port_limit,
                e.analysis.errors(),
                e.analysis.warnings(),
                e.analysis.infos(),
            ));
            for (j, d) in e.analysis.diagnostics.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                d.push_json(&mut out);
            }
            out.push_str("]}");
        }
        if !self.entries.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("]\n}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn text_line_spans() {
        let d = Diagnostic::new(Severity::Error, codes::CAUSALITY, "boom".into());
        assert_eq!(d.clone().text_line(), "error[causality] schedule: boom");
        assert_eq!(d.clone().at_round(3).text_line(), "error[causality] round 3: boom");
        assert_eq!(d.at(3, 1).text_line(), "error[causality] round 3/t1: boom");
    }

    #[test]
    fn sink_caps_per_code_and_reports_drops() {
        let mut sink = DiagSink::new(2);
        for _ in 0..5 {
            sink.push(Diagnostic::new(Severity::Warn, codes::REDUNDANT_TRANSFER, "dup".into()));
        }
        sink.push(Diagnostic::new(Severity::Error, codes::CAUSALITY, "real".into()));
        let diags = sink.finish();
        // 2 kept + 1 other-code + 1 truncation notice
        assert_eq!(diags.len(), 4);
        let trunc = diags.last().unwrap();
        assert_eq!(trunc.code, codes::TRUNCATED);
        assert_eq!(trunc.u64_field("dropped"), Some(3));
        assert_eq!(trunc.u64_field("cap"), Some(2));
    }

    #[test]
    fn json_escapes_and_nulls() {
        let d = Diagnostic::new(Severity::Info, codes::ROUND_BOUND, "a \"b\"".into())
            .with("rounds", 3u64);
        let a = Analysis { diagnostics: vec![d] };
        let j = a.to_json();
        assert!(j.contains("\"round\":null"), "{j}");
        assert!(j.contains("a \\\"b\\\""), "{j}");
        assert!(j.contains("\"payload\":{\"rounds\":3}"), "{j}");
    }
}
